//! Figure 17 (accuracy panel): GossipGraD vs AGD-every-log(p)-steps on
//! the LeNet3/MLP task.  The paper's observation: at matched (possibly
//! mis-tuned) hyperparameters, "only GossipGraD was learning" — gossip
//! is less sensitive to scaling hyperparameters because each rank keeps
//! its single-device learning rate.
//!
//!     cargo run --release --example fig17_learning [-- --ranks 16]

use gossipgrad::config::{Algo, RunConfig};
use gossipgrad::coordinator;
use gossipgrad::metrics::{sparkline, write_csv};
use gossipgrad::util::args::Args;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&["native"]).map_err(anyhow::Error::msg)?;
    let ranks = args.usize_or("ranks", 16);
    let steps = args.usize_or("steps", 150);
    let native = args.flag("native")
        || !Path::new("artifacts/mlp.meta.json").exists();

    let mut rows = Vec::new();
    // the mis-tuned regime from the figure: the periodic baseline also
    // inherits the sqrt(p)-scaled learning rate, gossip keeps lr as-is
    for (algo, lr_scaling) in [(Algo::PeriodicAgd, true), (Algo::Gossip, false)] {
        let cfg = RunConfig {
            model: "mlp".into(),
            algo,
            ranks,
            steps,
            lr: 0.08,
            krizhevsky_lr_scaling: lr_scaling,
            eval_every: (steps / 6).max(1),
            rows_per_rank: 256,
            val_rows: 128,
            use_artifacts: !native,
            seed: 11,
            ..Default::default()
        };
        let res = coordinator::run(&cfg)?;
        let acc: Vec<f64> = res.per_rank[0]
            .accuracy
            .iter()
            .map(|&(_, a)| a)
            .collect();
        let losses: Vec<f64> =
            res.per_rank[0].loss.iter().map(|&(_, l)| l).collect();
        println!(
            "{:<14} (lr_eff {:.3}) loss {}  acc {}  final {:.1}%",
            algo.name(),
            cfg.effective_lr(),
            sparkline(&losses, 20),
            sparkline(&acc, 20),
            100.0 * acc.last().unwrap_or(&0.0)
        );
        for (i, &(s, a)) in res.per_rank[0].accuracy.iter().enumerate() {
            let _ = i;
            rows.push(vec![
                s as f64,
                if algo == Algo::Gossip { 1.0 } else { 0.0 },
                a,
            ]);
        }
    }
    write_csv(
        Path::new("results/fig17_learning.csv"),
        &["step", "is_gossip", "accuracy"],
        &rows,
    )?;
    println!("wrote results/fig17_learning.csv");
    Ok(())
}
