//! End-to-end driver: distributed training of the transformer LM through
//! the full three-layer stack — Rust gossip coordinator (L3) driving the
//! AOT-compiled JAX model (L2) whose projections, loss and optimizer run
//! as Pallas kernels (L1).
//!
//!     make artifacts && cargo run --release --example train_e2e
//!     # options: -- --ranks 4 --steps 300 --algo gossip --schedule step
//!     #          --model transformer        (the 5M-param variant; ~20 s/step
//!     #           on this single-core testbed — see EXPERIMENTS.md §Perf)
//!
//! Trains the decoder-only LM (863k-param `transformer_small` preset by
//! default; pass `--model transformer` for the 5M variant) on a synthetic Markov corpus
//! for a few hundred steps across gossiping ranks, logging the loss
//! curve; the loss must descend from ~ln(vocab) toward the corpus'
//! conditional entropy (~1.2 nats for the default chain).  Results are
//! appended to results/e2e_loss.csv and recorded in EXPERIMENTS.md.
//!
//! `--schedule step` reproduces the Fig 14 training regimen shape
//! (learning rate ×0.1 every third of the run).

use gossipgrad::config::{LrSchedule, RunConfig};
use gossipgrad::coordinator;
use gossipgrad::metrics::{sparkline, write_csv};
use gossipgrad::util::args::Args;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]).map_err(anyhow::Error::msg)?;
    let ranks = args.usize_or("ranks", 4);
    let steps = args.usize_or("steps", 300);
    let algo = gossipgrad::config::Algo::parse(&args.get_or("algo", "gossip"))
        .map_err(anyhow::Error::msg)?;
    let model = args.get_or("model", "transformer_small");
    anyhow::ensure!(
        Path::new(&format!("artifacts/{model}.meta.json")).exists(),
        "{model} artifacts missing — run `make artifacts` first"
    );

    let mut cfg = RunConfig {
        model: model.clone(),
        algo,
        ranks,
        steps,
        lr: 0.2,
        eval_every: (steps / 6).max(1),
        rows_per_rank: 64, // sequences per rank
        val_rows: 16,
        seed: 7,
        ..Default::default()
    };
    if args.get_or("schedule", "const") == "step" {
        cfg.lr_schedule = LrSchedule::Step {
            every: (steps / 3).max(1),
            gamma: 0.1,
        };
    }

    println!(
        "e2e: {model} LM | {} | {ranks} ranks | {steps} steps | lr {} ({})",
        algo.name(),
        cfg.lr,
        args.get_or("schedule", "const"),
    );
    let t0 = std::time::Instant::now();
    let res = coordinator::run(&cfg)?;

    let m0 = &res.per_rank[0];
    let losses: Vec<f64> = m0.loss.iter().map(|&(_, l)| l).collect();
    println!(
        "\nrank0 train loss {}  {:.3} -> {:.3}",
        sparkline(&losses, 48),
        losses.first().unwrap_or(&f64::NAN),
        losses.last().unwrap_or(&f64::NAN)
    );
    for &(s, a) in &m0.accuracy {
        println!("  step {s:>5}: next-token accuracy {:.1}%", 100.0 * a);
    }
    println!(
        "step {:.0} ms | efficiency {:.1}% | cross-rank disagreement {:.2e} | wall {:.0}s",
        1e3 * res.mean_step_secs(),
        res.mean_efficiency_pct(),
        res.max_disagreement(),
        t0.elapsed().as_secs_f64()
    );

    let rows: Vec<Vec<f64>> =
        m0.loss.iter().map(|&(s, l)| vec![s as f64, l]).collect();
    write_csv(Path::new("results/e2e_loss.csv"), &["step", "loss"], &rows)?;
    println!("wrote results/e2e_loss.csv");

    // hard gate: the run must have actually learned
    let first = losses.first().copied().unwrap_or(f64::NAN);
    let last = losses.last().copied().unwrap_or(f64::NAN);
    anyhow::ensure!(
        last < 0.7 * first,
        "e2e loss did not improve enough: {first:.3} -> {last:.3}"
    );
    println!("E2E OK: all three layers compose and the model learns.");
    Ok(())
}
