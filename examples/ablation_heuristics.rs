//! Ablation of GossipGraD's two §4.5 heuristics — partner rotation and
//! the distributed sample shuffle — plus the straggler-noise sweep that
//! motivates O(1) communication in the first place.
//!
//!     cargo run --release --example ablation_heuristics [-- --ranks 8 --steps 120]
//!
//! DESIGN.md calls these out as the design choices to ablate: the paper
//! asserts (without an ablation table of its own) that rotation improves
//! diffusion and the shuffle prevents over-fitting; here we measure the
//! effect of switching each off.

use gossipgrad::config::{Algo, RunConfig};
use gossipgrad::coordinator::trainer::run_with_backend;
use gossipgrad::nativenet::NativeMlp;
use gossipgrad::sim::straggler::{mean_step_time, SyncKind};
use gossipgrad::sim::Workload;
use gossipgrad::util::args::Args;
use gossipgrad::util::bench::Table;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]).map_err(anyhow::Error::msg)?;
    let ranks = args.usize_or("ranks", 8);
    let steps = args.usize_or("steps", 120);

    // ---- heuristic on/off matrix (real runs, native backend) ----------
    let mut t = Table::new(&[
        "rotation",
        "shuffle",
        "final acc %",
        "disagreement",
        "msgs/rank/step",
    ]);
    for (rot, shuf) in [(true, true), (true, false), (false, true), (false, false)]
    {
        let cfg = RunConfig {
            model: "mlp".into(),
            algo: Algo::Gossip,
            ranks,
            steps,
            lr: 0.05,
            rotation: rot,
            sample_shuffle: shuf,
            eval_every: steps,
            rows_per_rank: 192,
            use_artifacts: false,
            seed: 5,
            ..Default::default()
        };
        let backend = Arc::new(NativeMlp::new(vec![784, 64, 10], 32, 0));
        let res = run_with_backend(&cfg, backend)?;
        let msgs = res.per_rank.iter().map(|m| m.msgs_sent).sum::<u64>() as f64
            / (ranks * steps) as f64;
        t.row(&[
            rot.to_string(),
            shuf.to_string(),
            format!("{:.1}", 100.0 * res.final_accuracy.unwrap_or(0.0)),
            format!("{:.2e}", res.max_disagreement()),
            format!("{msgs:.1}"),
        ]);
    }
    t.print("GossipGraD §4.5 heuristics ablation (MLP, native backend)");

    // ---- straggler-noise sweep (DES) ----------------------------------
    let w = Workload::lenet3(1.0);
    let mut t = Table::new(&["noise", "barrier step ms", "gossip step ms", "gossip advantage"]);
    for noise in [0.0, 0.1, 0.2, 0.4] {
        let g = mean_step_time(&w, 32, SyncKind::Global, noise, 300, 11);
        let p = mean_step_time(&w, 32, SyncKind::Partner, noise, 300, 11);
        t.row(&[
            format!("{noise}"),
            format!("{:.2}", 1e3 * g),
            format!("{:.2}", 1e3 * p),
            format!("{:.2}x", g / p),
        ]);
    }
    t.print("OS-noise straggler amplification, p=32 (discrete-event sim)");
    println!("\nbarrier schedules pay E[max of p] jitter per step; gossip pays one partner's.");
    Ok(())
}
