//! Quickstart: train the MLP with GossipGraD on 8 simulated ranks and
//! compare against the AGD baseline — the 60-second tour of the library.
//!
//!     cargo run --release --example quickstart
//!
//! Uses the PJRT artifacts if `make artifacts` has been run, otherwise
//! falls back to the native backend automatically.

use gossipgrad::config::{Algo, RunConfig};
use gossipgrad::coordinator;
use gossipgrad::metrics::sparkline;

fn main() -> anyhow::Result<()> {
    let mut cfg = RunConfig {
        model: "mlp".into(),
        ranks: 8,
        steps: 60,
        lr: 0.05,
        eval_every: 20,
        rows_per_rank: 512,
        // calibrated-but-scaled network: 50 µs latency, 2 GB/s — slow
        // enough that an unhidden exchange would show up in step time
        net_alpha: 50e-6,
        net_beta: 1.0 / 2.0e9,
        ..Default::default()
    };
    cfg.use_artifacts =
        std::path::Path::new(&cfg.artifacts_dir).join("mlp.meta.json").exists();
    if !cfg.use_artifacts {
        eprintln!("(artifacts not built; using native backend — run `make artifacts` for the PJRT path)");
    }

    for algo in [Algo::Gossip, Algo::Agd] {
        cfg.algo = algo;
        let res = coordinator::run(&cfg)?;
        let losses: Vec<f64> =
            res.per_rank[0].loss.iter().map(|&(_, l)| l).collect();
        println!(
            "{:<12} loss {}  acc {:>5.1}%  step {:>7.2} ms  eff {:>5.1}%  msgs/rank/step {:.1}",
            algo.name(),
            sparkline(&losses, 24),
            100.0 * res.final_accuracy.unwrap_or(0.0),
            1e3 * res.mean_step_secs(),
            res.mean_efficiency_pct(),
            res.per_rank.iter().map(|m| m.msgs_sent).sum::<u64>() as f64
                / (cfg.ranks * cfg.steps) as f64,
        );
    }
    println!("\nGossipGraD sends O(1) messages per step and hides them under compute;\nAGD pays a log(p)-round all-reduce per layer. See EXPERIMENTS.md.");
    Ok(())
}
