//! Figures 12 & 13: validation accuracy vs training progress for AGD vs
//! GossipGraD on the MNIST-analog (LeNet3/MLP) and CIFAR-analog
//! (CIFARNet/CNN) tasks, 32 ranks (the paper's largest MNIST scale).
//!
//!     cargo run --release --example accuracy_comparison [-- --ranks 32 --steps 300]
//!
//! Emits results/fig12_mnist_accuracy.csv and
//! results/fig13_cifar_accuracy.csv, and prints the curves.  The paper's
//! claim under reproduction: the GossipGraD and AGD curves track each
//! other and saturate at the same accuracy (§7.2.2).

use gossipgrad::config::{Algo, RunConfig};
use gossipgrad::coordinator;
use gossipgrad::metrics::{sparkline, write_csv};
use gossipgrad::util::args::Args;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&["native"]).map_err(anyhow::Error::msg)?;
    let ranks = args.usize_or("ranks", 32);
    let steps = args.usize_or("steps", 200);
    let native = args.flag("native")
        || !Path::new("artifacts/mlp.meta.json").exists();

    for (fig, model, lr) in [("fig12_mnist", "mlp", 0.05), ("fig13_cifar", "cnn", 0.02)]
    {
        if native && model == "cnn" {
            println!("(skipping {model}: native backend is mlp-only; run `make artifacts`)");
            continue;
        }
        println!("== {fig}: {model}, {ranks} ranks, {steps} steps ==");
        let mut rows: Vec<Vec<f64>> = Vec::new();
        let mut curves = Vec::new();
        for algo in [Algo::Agd, Algo::Gossip] {
            let cfg = RunConfig {
                model: model.into(),
                algo,
                ranks,
                steps,
                lr,
                eval_every: (steps / 8).max(1),
                rows_per_rank: 256,
                val_rows: 100,
                krizhevsky_lr_scaling: algo == Algo::Agd, // §7.1 baseline setup
                use_artifacts: !native,
                seed: 42,
                ..Default::default()
            };
            let res = coordinator::run(&cfg)?;
            let acc = &res.per_rank[0].accuracy;
            for &(s, a) in acc {
                rows.push(vec![
                    s as f64,
                    if algo == Algo::Agd { 0.0 } else { 1.0 },
                    a,
                ]);
            }
            let ys: Vec<f64> = acc.iter().map(|&(_, a)| a).collect();
            println!(
                "  {:<10} acc {}  final {:.1}%",
                algo.name(),
                sparkline(&ys, 30),
                100.0 * ys.last().unwrap_or(&0.0)
            );
            curves.push((algo, *ys.last().unwrap_or(&0.0)));
        }
        let path = format!("results/{fig}_accuracy.csv");
        write_csv(Path::new(&path), &["step", "is_gossip", "accuracy"], &rows)?;
        println!("  wrote {path}");
        if curves.len() == 2 {
            let gap = (curves[0].1 - curves[1].1).abs();
            println!(
                "  final-accuracy gap (paper: within noise): {:.2} pts\n",
                100.0 * gap
            );
        }
    }
    Ok(())
}
