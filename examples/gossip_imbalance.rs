//! §4.2's critique, quantified: random gossip (Jin et al. / Blot et al.)
//! vs GossipGraD's dissemination exchange.
//!
//!     cargo run --release --example gossip_imbalance
//!
//! Three measurements per topology:
//! 1. per-step receive histogram (balanced ⇔ every rank receives exactly 1);
//! 2. diffusion time of one rank's update to all ranks;
//! 3. distinct direct partners over a training horizon (rotation's win).

use gossipgrad::topology::{
    diffusion_time, random::recv_histogram, Dissemination, RandomGossip,
    Rotation, Topology,
};
use gossipgrad::util::bench::Table;
use gossipgrad::util::ceil_log2;
use std::collections::HashSet;

fn main() {
    let p = 64;
    let steps = 200;

    // --- 1. receive balance -------------------------------------------
    let rnd = RandomGossip::new(p, 3);
    let mut max_load = 0usize;
    let mut starved = 0usize;
    for step in 0..steps {
        let h = recv_histogram(&rnd, step);
        max_load = max_load.max(*h.iter().max().unwrap());
        starved += h.iter().filter(|&&c| c == 0).count();
    }
    println!("random gossip, p={p}, {steps} steps:");
    println!("  worst per-step receive load: {max_load} (balanced = 1)");
    println!(
        "  starved rank-steps (received nothing): {starved} ({:.1}%)",
        100.0 * starved as f64 / (p * steps) as f64
    );
    println!("  dissemination: every step is a permutation — load 1, starvation 0 (checked by `cargo test prop_dissemination_balanced`)\n");

    // --- 2. diffusion -------------------------------------------------
    let dis = Dissemination::new(p);
    let t_dis = diffusion_time(&dis, 0, 10 * p).unwrap();
    // random gossip diffusion: measure empirically (expected O(log p),
    // but with a tail)
    let mut t_rnd_worst = 0usize;
    for seed in 0..20u64 {
        let r = RandomGossip::new(p, seed);
        let t = diffusion_time(&r, 0, 10 * p).unwrap_or(10 * p);
        t_rnd_worst = t_rnd_worst.max(t);
    }
    let mut t = Table::new(&["topology", "diffusion steps (p=64)", "bound"]);
    t.row(&[
        "dissemination".into(),
        t_dis.to_string(),
        format!("⌈log2 p⌉ = {}", ceil_log2(p)),
    ]);
    t.row(&[
        "random (worst of 20 seeds)".into(),
        t_rnd_worst.to_string(),
        "O(log p) w.h.p., unbounded tail".into(),
    ]);
    t.print("indirect diffusion of one rank's update");

    // --- 3. direct partner coverage (rotation, §4.5.1) -----------------
    let horizon = 50 * ceil_log2(p);
    let direct = |t: &dyn Topology| {
        let mut s = HashSet::new();
        for step in 0..horizon {
            let e = t.exchange(0, step);
            s.insert(e.send_to);
            s.insert(e.recv_from);
        }
        s.len()
    };
    let plain = Dissemination::new(p);
    let rot = Rotation::new(Dissemination::new(p), 9);
    let mut t = Table::new(&["topology", &format!("direct partners of rank 0 in {horizon} steps")]);
    t.row(&["dissemination (no rotation)".into(), direct(&plain).to_string()]);
    t.row(&["dissemination + rotation".into(), direct(&rot).to_string()]);
    t.print("partner rotation widens direct diffusion (§4.5.1)");
    assert!(direct(&rot) > 3 * direct(&plain));
}
