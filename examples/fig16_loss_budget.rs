//! Figure 16: training loss after a fixed wall-clock budget — AGD vs
//! GossipGraD at equal time, 32 simulated GPUs on the GoogLeNet-analog
//! (CNN) workload.  GossipGraD fits more updates into the budget because
//! its communication is hidden, hence lower loss at the cutoff (§7.4).
//!
//!     cargo run --release --example fig16_loss_budget [-- --budget-secs 20]

use gossipgrad::config::{Algo, RunConfig};
use gossipgrad::coordinator;
use gossipgrad::metrics::write_csv;
use gossipgrad::util::args::Args;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&["native"]).map_err(anyhow::Error::msg)?;
    let budget = args.f64_or("budget-secs", 15.0);
    let ranks = args.usize_or("ranks", 8);
    let native = args.flag("native")
        || !Path::new("artifacts/mlp.meta.json").exists();
    let model = if native {
        "mlp".to_string()
    } else {
        args.get_or("model", "cnn")
    };

    // calibrate steps/sec with a tiny probe run, then give both
    // algorithms the same wall budget
    let mut rows = Vec::new();
    for algo in [Algo::Agd, Algo::Gossip] {
        let probe = RunConfig {
            model: model.to_string(),
            algo,
            ranks,
            steps: 8,
            use_artifacts: !native,
            // non-trivial simulated network so comm costs bite
            net_alpha: 100e-6,
            net_beta: 1.0 / 1.0e9,
            ..Default::default()
        };
        let pres = coordinator::run(&probe)?;
        let steps_in_budget =
            ((budget / pres.mean_step_secs()) as usize).clamp(8, 4000);
        let cfg = RunConfig {
            steps: steps_in_budget,
            lr: 0.02,
            ..probe
        };
        let t0 = std::time::Instant::now();
        let res = coordinator::run(&cfg)?;
        let loss = res.per_rank[0]
            .loss
            .last()
            .map(|&(_, l)| l)
            .unwrap_or(f64::NAN);
        println!(
            "{:<10} {:>5} steps in {:>5.1}s budget -> loss {:.4}",
            algo.name(),
            steps_in_budget,
            t0.elapsed().as_secs_f64(),
            loss
        );
        rows.push(vec![
            if algo == Algo::Agd { 0.0 } else { 1.0 },
            steps_in_budget as f64,
            loss,
        ]);
    }
    write_csv(
        Path::new("results/fig16_loss_budget.csv"),
        &["is_gossip", "steps", "final_loss"],
        &rows,
    )?;
    println!("wrote results/fig16_loss_budget.csv");
    if rows.len() == 2 {
        println!(
            "paper's claim (Fig 16): gossip >= as low a loss at equal time. gossip {:.4} vs agd {:.4}",
            rows[1][2], rows[0][2]
        );
    }
    Ok(())
}
