"""L2: JAX model definitions (fwd/bwd/update), built on the L1 Pallas kernels.

Three model families, mirroring the paper's evaluation matrix:

* ``mlp``         — LeNet3 analog for the MNIST-analog dataset (paper §7.2).
* ``cnn``         — CIFARNet analog for the CIFAR10-analog dataset (§7.2).
                    Convolutions are lowered via im2col so that every FLOP
                    flows through the Pallas ``linear``/``matmul`` kernel.
* ``transformer`` — decoder-only LM used by the end-to-end driver
                    (examples/train_e2e.rs); stands in for the paper's
                    ResNet50/GoogLeNet "large model" runs (Figs 14-16).

The L2<->L3 contract (DESIGN.md "Artifact contract"): parameters are ONE
flat f32[N] vector on both sides.  ``layer_table()`` exports the
(name, offset, len) table that the Rust coordinator uses to slice the flat
gradient for layer-wise asynchronous exchange.

Everything here is build-time only; aot.py lowers the functions below to
HLO text that the Rust runtime executes.
"""

import functools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import linear, matmul, softmax_xent, sgd_momentum, mix

MOMENTUM = 0.9


# --------------------------------------------------------------------------
# Parameter bookkeeping: named leaves in a fixed order -> flat f32[N].
# --------------------------------------------------------------------------


@dataclass
class ParamSpec:
    """Ordered list of named parameter tensors defining the flat layout."""

    names: list = field(default_factory=list)
    shapes: list = field(default_factory=list)

    def add(self, name, shape):
        self.names.append(name)
        self.shapes.append(tuple(shape))

    @property
    def sizes(self):
        return [int(np.prod(s)) for s in self.shapes]

    @property
    def total(self):
        return int(sum(self.sizes))

    def offsets(self):
        off, out = 0, []
        for n, s, sz in zip(self.names, self.shapes, self.sizes):
            out.append((n, off, sz, s))
            off += sz
        return out

    def unflatten(self, flat):
        out, off = {}, 0
        for n, s, sz in zip(self.names, self.shapes, self.sizes):
            out[n] = flat[off : off + sz].reshape(s)
            off += sz
        return out

    def init(self, seed):
        """He-style init, matching Caffe's msra filler used by the paper's nets.

        1-D parameters: biases (`.b`) start at zero; layernorm gains
        (1-D `.w`, e.g. `blk0.ln1.w`) start at one.  The final classifier
        weight is scaled by 0.1 so the initial loss sits near log(C)
        regardless of network depth (standard small-head init).
        """
        key = jax.random.PRNGKey(seed)
        last_w = next(
            (
                n
                for n, s in zip(reversed(self.names), reversed(self.shapes))
                if len(s) >= 2
            ),
            None,
        )
        chunks = []
        for n, s in zip(self.names, self.shapes):
            key, sub = jax.random.split(key)
            if len(s) == 1 and n.endswith(".w"):  # layernorm gain
                chunks.append(jnp.ones(s, jnp.float32))
            elif len(s) == 1:  # bias
                chunks.append(jnp.zeros(s, jnp.float32))
            else:
                fan_in = int(np.prod(s[:-1]))
                scale = jnp.sqrt(2.0 / fan_in)
                if n == last_w:
                    scale = scale * 0.1
                chunks.append(
                    (jax.random.normal(sub, s, jnp.float32) * scale).reshape(-1)
                )
        return jnp.concatenate([c.reshape(-1) for c in chunks])

    def layer_table(self):
        """Grouped per-layer (name, offset, len) for layer-wise comm.

        A "layer" groups a weight and its bias (the granularity at which
        the paper exchanges gradients asynchronously)."""
        groups = {}
        order = []
        for n, off, sz, _ in self.offsets():
            layer = n.rsplit(".", 1)[0]
            if layer not in groups:
                groups[layer] = [off, 0]
                order.append(layer)
            g = groups[layer]
            g[0] = min(g[0], off)
            g[1] += sz
        return [
            {"name": layer, "offset": groups[layer][0], "len": groups[layer][1]}
            for layer in order
        ]


# --------------------------------------------------------------------------
# Model family: MLP (LeNet3 analog — MNIST-analog 28x28 grayscale, 10 cls)
# --------------------------------------------------------------------------


def mlp_spec(din=784, hidden=(512, 256), classes=10):
    spec = ParamSpec()
    dims = [din, *hidden, classes]
    for i in range(len(dims) - 1):
        spec.add(f"fc{i}.w", (dims[i], dims[i + 1]))
        spec.add(f"fc{i}.b", (dims[i + 1],))
    return spec


def mlp_logits(spec, flat, x):
    p = spec.unflatten(flat)
    h = x.reshape(x.shape[0], -1)
    n_layers = len(spec.names) // 2
    for i in range(n_layers):
        act = "relu" if i < n_layers - 1 else "none"
        h = linear(h, p[f"fc{i}.w"], p[f"fc{i}.b"], act)
    return h


# --------------------------------------------------------------------------
# Model family: CNN (CIFARNet analog — 32x32x3, 10 classes)
#   conv5x5/32 - pool2 - conv5x5/32 - pool2 - conv5x5/64 - pool2 - fc64 - fc10
#   Convs run as im2col + Pallas matmul (DESIGN.md §Hardware-Adaptation).
# --------------------------------------------------------------------------


def cnn_spec(channels=3, classes=10):
    spec = ParamSpec()
    spec.add("conv0.w", (5 * 5 * channels, 32))
    spec.add("conv0.b", (32,))
    spec.add("conv1.w", (5 * 5 * 32, 32))
    spec.add("conv1.b", (32,))
    spec.add("conv2.w", (5 * 5 * 32, 64))
    spec.add("conv2.b", (64,))
    spec.add("fc0.w", (4 * 4 * 64, 64))
    spec.add("fc0.b", (64,))
    spec.add("fc1.w", (64, classes))
    spec.add("fc1.b", (classes,))
    return spec


def _conv_im2col(x, w, b):
    """5x5 SAME conv via patch extraction + Pallas matmul.

    x: [B, H, W, C] -> [B, H, W, O].  All FLOPs go through kernels.linear.
    """
    bsz, h, wdt, c = x.shape
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(5, 5),
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )  # [B, H, W, 5*5*C] with channel-major patch layout
    cols = patches.reshape(bsz * h * wdt, 5 * 5 * c)
    out = linear(cols, w, b, "relu")
    return out.reshape(bsz, h, wdt, -1)


def _maxpool2(x):
    b, h, w, c = x.shape
    return jnp.max(x.reshape(b, h // 2, 2, w // 2, 2, c), axis=(2, 4))


def cnn_logits(spec, flat, x):
    p = spec.unflatten(flat)
    h = x.reshape(x.shape[0], 32, 32, 3)
    h = _maxpool2(_conv_im2col(h, p["conv0.w"], p["conv0.b"]))
    h = _maxpool2(_conv_im2col(h, p["conv1.w"], p["conv1.b"]))
    h = _maxpool2(_conv_im2col(h, p["conv2.w"], p["conv2.b"]))
    h = h.reshape(h.shape[0], -1)
    h = linear(h, p["fc0.w"], p["fc0.b"], "relu")
    return linear(h, p["fc1.w"], p["fc1.b"], "none")


# --------------------------------------------------------------------------
# Model family: decoder-only transformer LM (stand-in for ResNet50 scale)
# --------------------------------------------------------------------------


@dataclass
class TransformerCfg:
    vocab: int = 512
    d_model: int = 256
    n_layers: int = 6
    n_heads: int = 8
    d_ff: int = 1024
    seq: int = 64

    @property
    def d_head(self):
        return self.d_model // self.n_heads


def transformer_spec(cfg: TransformerCfg):
    spec = ParamSpec()
    spec.add("embed.w", (cfg.vocab, cfg.d_model))
    spec.add("pos.w", (cfg.seq, cfg.d_model))
    for i in range(cfg.n_layers):
        spec.add(f"blk{i}.ln1.w", (cfg.d_model,))
        spec.add(f"blk{i}.ln1.b", (cfg.d_model,))
        spec.add(f"blk{i}.qkv.w", (cfg.d_model, 3 * cfg.d_model))
        spec.add(f"blk{i}.qkv.b", (3 * cfg.d_model,))
        spec.add(f"blk{i}.proj.w", (cfg.d_model, cfg.d_model))
        spec.add(f"blk{i}.proj.b", (cfg.d_model,))
        spec.add(f"blk{i}.ln2.w", (cfg.d_model,))
        spec.add(f"blk{i}.ln2.b", (cfg.d_model,))
        spec.add(f"blk{i}.ff1.w", (cfg.d_model, cfg.d_ff))
        spec.add(f"blk{i}.ff1.b", (cfg.d_ff,))
        spec.add(f"blk{i}.ff2.w", (cfg.d_ff, cfg.d_model))
        spec.add(f"blk{i}.ff2.b", (cfg.d_model,))
    spec.add("lnf.w", (cfg.d_model,))
    spec.add("lnf.b", (cfg.d_model,))
    spec.add("head.w", (cfg.d_model, cfg.vocab))
    spec.add("head.b", (cfg.vocab,))
    return spec


def _layernorm(x, w, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * w + b


def transformer_logits(spec, cfg: TransformerCfg, flat, tokens):
    """tokens: int32[B, S] -> logits f32[B*S, vocab].

    QKV/proj/FF projections run through the Pallas linear kernel (the bulk
    of the FLOPs); the attention score/value einsums stay in jnp."""
    p = spec.unflatten(flat)
    bsz, seq = tokens.shape
    h = p["embed.w"][tokens] + p["pos.w"][None, :seq, :]
    mask = jnp.tril(jnp.ones((seq, seq), jnp.float32))
    neg = jnp.float32(-1e9)
    for i in range(cfg.n_layers):
        x = _layernorm(h, p[f"blk{i}.ln1.w"], p[f"blk{i}.ln1.b"])
        qkv = linear(
            x.reshape(bsz * seq, cfg.d_model),
            p[f"blk{i}.qkv.w"],
            p[f"blk{i}.qkv.b"],
            "none",
        ).reshape(bsz, seq, 3, cfg.n_heads, cfg.d_head)
        q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
        att = jnp.einsum("bshd,bthd->bhst", q, k) / jnp.sqrt(
            jnp.float32(cfg.d_head)
        )
        att = jnp.where(mask[None, None] > 0, att, neg)
        att = jax.nn.softmax(att, axis=-1)
        ctx = jnp.einsum("bhst,bthd->bshd", att, v).reshape(
            bsz * seq, cfg.d_model
        )
        h = h + linear(
            ctx, p[f"blk{i}.proj.w"], p[f"blk{i}.proj.b"], "none"
        ).reshape(bsz, seq, cfg.d_model)
        x = _layernorm(h, p[f"blk{i}.ln2.w"], p[f"blk{i}.ln2.b"])
        y = linear(
            x.reshape(bsz * seq, cfg.d_model),
            p[f"blk{i}.ff1.w"],
            p[f"blk{i}.ff1.b"],
            "gelu",
        )
        y = linear(y, p[f"blk{i}.ff2.w"], p[f"blk{i}.ff2.b"], "none")
        h = h + y.reshape(bsz, seq, cfg.d_model)
    h = _layernorm(h, p["lnf.w"], p["lnf.b"])
    return linear(
        h.reshape(bsz * seq, cfg.d_model), p["head.w"], p["head.b"], "none"
    )


# --------------------------------------------------------------------------
# Model registry + the three lowered entry points per model
# --------------------------------------------------------------------------


@dataclass
class Model:
    """A model family instance: spec + logits fn + static batch shapes."""

    name: str
    spec: ParamSpec
    logits_fn: object  # (flat, x) -> logits [rows, classes]
    x_shape: tuple  # per-batch input shape (incl. batch dim)
    x_dtype: object
    labels_rows: int  # number of label rows (B, or B*S for the LM)
    classes: int
    batch: int

    def loss(self, flat, x, y):
        return softmax_xent(self.logits_fn(flat, x), y)

    def grad_fn(self):
        """(params, x, y) -> (grads flat, loss)."""

        def f(flat, x, y):
            loss, grads = jax.value_and_grad(self.loss)(flat, x, y)
            return grads, loss

        return f

    def train_step_fn(self):
        """(params, mom, x, y, lr) -> (params', mom', loss). Fused update."""

        def f(flat, momv, x, y, lr):
            loss, grads = jax.value_and_grad(self.loss)(flat, x, y)
            new_p, new_m = sgd_momentum(flat, momv, grads, lr, MOMENTUM)
            return new_p, new_m, loss

        return f

    def eval_fn(self):
        """(params, x, y) -> (loss, correct_count)."""

        def f(flat, x, y):
            logits = self.logits_fn(flat, x)
            loss = softmax_xent(logits, y)
            correct = jnp.sum(
                (jnp.argmax(logits, axis=-1) == y).astype(jnp.float32)
            )
            return loss, correct

        return f


def build_model(name, batch=None, tcfg: TransformerCfg = None) -> Model:
    if name == "mlp":
        b = batch or 64  # paper: MNIST batch 64 per device
        spec = mlp_spec()
        return Model(
            name,
            spec,
            functools.partial(mlp_logits, spec),
            (b, 784),
            jnp.float32,
            b,
            10,
            b,
        )
    if name == "cnn":
        b = batch or 50  # paper uses 100 for CIFAR10; 50 keeps CPU steps fast
        spec = cnn_spec()
        return Model(
            name,
            spec,
            functools.partial(cnn_logits, spec),
            (b, 3072),
            jnp.float32,
            b,
            10,
            b,
        )
    if name == "transformer":
        cfg = tcfg or TransformerCfg()
        b = batch or 8
    elif name == "transformer_small":
        # e2e-driver preset sized for the single-core CPU testbed (the
        # xla_extension 0.5.1 backend is ~15-30x slower than current
        # XLA on this HLO — see EXPERIMENTS.md §Perf)
        cfg = tcfg or TransformerCfg(
            vocab=256, d_model=128, n_layers=4, n_heads=4, d_ff=512, seq=32
        )
        b = batch or 4
    else:
        raise ValueError(f"unknown model {name!r}")
    spec = transformer_spec(cfg)
    return Model(
        name,
        spec,
        functools.partial(transformer_logits, spec, cfg),
        (b, cfg.seq),
        jnp.int32,
        b * cfg.seq,
        cfg.vocab,
        b,
    )


def mix_fn(a, b):
    """(a, b) -> (a+b)/2 via the Pallas mix kernel (artifacts/mix.hlo.txt)."""
    return mix(a, b)


def update_fn(params, momv, grads, lr):
    """Standalone fused momentum-SGD artifact (L3 owns grads/comm ordering)."""
    return sgd_momentum(params, momv, grads, lr, MOMENTUM)
