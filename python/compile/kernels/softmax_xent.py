"""L1 Pallas kernel: fused softmax + cross-entropy (mean over batch).

Forward computes the per-row negative log-likelihood in one pass
(max-subtracted logsumexp, label logit gathered in-kernel); backward is
the closed-form (softmax - onehot) / m, also fused.  Wrapped in a
custom_vjp so jax.grad flows through it.

Row blocks: each grid step owns BR full rows (all classes resident —
class counts here are <= vocab 512, so a row block is < 256 KiB VMEM).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BR = 256  # rows per grid step


def _xent_fwd_kernel(logits_ref, labels_ref, nll_ref):
    z = logits_ref[...]
    zmax = jnp.max(z, axis=-1, keepdims=True)
    ez = jnp.exp(z - zmax)
    logz = jnp.log(jnp.sum(ez, axis=-1)) + zmax[:, 0]
    onehot = (
        labels_ref[...][:, None]
        == jax.lax.broadcasted_iota(jnp.int32, z.shape, 1)
    ).astype(z.dtype)
    picked = jnp.sum(z * onehot, axis=-1)
    nll_ref[...] = logz - picked


def _xent_bwd_kernel(logits_ref, labels_ref, scale_ref, dlogits_ref):
    z = logits_ref[...]
    zmax = jnp.max(z, axis=-1, keepdims=True)
    ez = jnp.exp(z - zmax)
    p = ez / jnp.sum(ez, axis=-1, keepdims=True)
    onehot = (
        labels_ref[...][:, None]
        == jax.lax.broadcasted_iota(jnp.int32, z.shape, 1)
    ).astype(z.dtype)
    dlogits_ref[...] = (p - onehot) * scale_ref[0]


def _nll_rows(logits, labels, br=BR):
    m, c = logits.shape
    br = min(br, m)
    pad = (-m) % br
    if pad:
        logits = jnp.pad(logits, ((0, pad), (0, 0)))
        # padded labels point at class 0; padded rows are dropped below
        labels = jnp.pad(labels, (0, pad))
    mp = logits.shape[0]
    nll = pl.pallas_call(
        _xent_fwd_kernel,
        grid=(mp // br,),
        in_specs=[
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((br,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((br,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((mp,), jnp.float32),
        interpret=True,
    )(logits, labels.astype(jnp.int32))
    return nll[:m]


@jax.custom_vjp
def softmax_xent(logits, labels):
    """Mean cross-entropy.  logits: f32[m, c], labels: int[m] -> f32[]."""
    return jnp.mean(_nll_rows(logits, labels))


def _fwd(logits, labels):
    return softmax_xent(logits, labels), (logits, labels)


def _bwd(res, g):
    logits, labels = res
    m, c = logits.shape
    br = min(BR, m)
    pad = (-m) % br
    lp = jnp.pad(logits, ((0, pad), (0, 0))) if pad else logits
    yp = jnp.pad(labels, (0, pad)) if pad else labels
    mp = lp.shape[0]
    scale = jnp.reshape(g / m, (1,)).astype(jnp.float32)
    dl = pl.pallas_call(
        _xent_bwd_kernel,
        grid=(mp // br,),
        in_specs=[
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((br,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, c), jnp.float32),
        interpret=True,
    )(lp, yp.astype(jnp.int32), scale)
    return dl[:m], None


softmax_xent.defvjp(_fwd, _bwd)
