"""L1 Pallas kernel: fused momentum-SGD parameter update.

    v' = mu * v + g
    p' = p - lr * v'

Operates on the flat f32[N] parameter vector (the L2<->L3 contract keeps
all model parameters as one flat vector; see DESIGN.md "Artifact
contract").  Fusing the two updates into one kernel reads each of p/v/g
exactly once and writes p'/v' once — the update is memory-bound, so this
halves traffic vs. two separate elementwise passes.

TPU mapping: 1-D grid over VPU-lane-aligned blocks (8 * 128 = 1024-float
multiples); each block is an HBM->VMEM stream with no reuse, so block
size only needs to amortize DMA setup — 64 KiB blocks (16384 floats) keep
the pipeline full while bounding VMEM to ~200 KiB for the 3 input streams.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Floats per grid step.  On a real TPU a 16K-float (64 KiB) streaming
# block amortizes DMA setup; under CPU interpret the grid is a sequential
# HLO loop, so one big block wins (§Perf).  Multiple of the 1024-float
# VPU tile either way.
BLOCK = 4 * 1024 * 1024


def _sgd_kernel(p_ref, v_ref, g_ref, lr_ref, po_ref, vo_ref, *, mu):
    lr = lr_ref[0]
    v = mu * v_ref[...] + g_ref[...]
    vo_ref[...] = v
    po_ref[...] = p_ref[...] - lr * v


def sgd_momentum(params, mom, grads, lr, mu=0.9, block=BLOCK):
    """Fused momentum-SGD over flat vectors.  lr is a scalar (traced)."""
    (n,) = params.shape
    block = min(block, n)
    pad = (-n) % block
    if pad:
        params = jnp.pad(params, (0, pad))
        mom = jnp.pad(mom, (0, pad))
        grads = jnp.pad(grads, (0, pad))
    np_ = params.shape[0]
    lr_arr = jnp.reshape(lr, (1,)).astype(jnp.float32)
    grid = (np_ // block,)
    p2, v2 = pl.pallas_call(
        functools.partial(_sgd_kernel, mu=mu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_,), jnp.float32),
            jax.ShapeDtypeStruct((np_,), jnp.float32),
        ],
        interpret=True,
    )(params, mom, grads, lr_arr)
    return p2[:n], v2[:n]
