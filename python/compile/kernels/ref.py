"""Pure-jnp oracles for every Pallas kernel in this package.

Each function here is the *definition of correctness* for the matching
Pallas kernel; python/tests/test_kernels.py asserts allclose between the
two across a hypothesis-driven sweep of shapes and dtypes.
"""

import jax
import jax.numpy as jnp


def linear_ref(x, w, b, activation="none"):
    """y = act(x @ w + b).  x: [m, k], w: [k, n], b: [n]."""
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b
    return activate_ref(y, activation)


def activate_ref(y, activation):
    if activation == "none":
        return y
    if activation == "relu":
        return jnp.maximum(y, 0.0)
    if activation == "gelu":
        return jax.nn.gelu(y)
    raise ValueError(f"unknown activation {activation!r}")


def linear_bwd_ref(x, w, g, activation="none", pre=None):
    """Backward of linear_ref.  g: [m, n] cotangent of the output.

    `pre` is the pre-activation (x @ w + b), required for relu/gelu.
    Returns (dx, dw, db).
    """
    if activation == "relu":
        g = g * (pre > 0.0).astype(g.dtype)
    elif activation == "gelu":
        g = g * gelu_grad_ref(pre)
    dx = jnp.dot(g, w.T, preferred_element_type=jnp.float32)
    dw = jnp.dot(x.T, g, preferred_element_type=jnp.float32)
    db = jnp.sum(g, axis=0)
    return dx, dw, db


def gelu_grad_ref(z):
    """Derivative of the tanh-approximated gelu used by jax.nn.gelu."""
    c = jnp.sqrt(2.0 / jnp.pi).astype(z.dtype)
    inner = c * (z + 0.044715 * z**3)
    t = jnp.tanh(inner)
    dinner = c * (1.0 + 3 * 0.044715 * z**2)
    return 0.5 * (1.0 + t) + 0.5 * z * (1.0 - t**2) * dinner


def sgd_momentum_ref(params, mom, grads, lr, mu=0.9):
    """Fused momentum-SGD: v' = mu*v + g ; p' = p - lr*v'."""
    new_mom = mu * mom + grads
    return params - lr * new_mom, new_mom


def mix_ref(a, b):
    """GossipGraD pairwise model mixing: elementwise (a + b) / 2."""
    return (a + b) * 0.5


def softmax_xent_ref(logits, labels):
    """Mean cross-entropy over the batch.  logits: [m, c], labels: int32[m]."""
    logz = jax.nn.logsumexp(logits, axis=-1, keepdims=True)
    logp = logits - logz
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(nll)


def softmax_xent_bwd_ref(logits, labels, g):
    """d loss / d logits = g * (softmax - onehot) / m."""
    m, c = logits.shape
    p = jax.nn.softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, c, dtype=logits.dtype)
    return g * (p - onehot) / m
