"""L1 Pallas kernel: GossipGraD pairwise model mixing.

    w <- (w_local + w_remote) / 2

This is the paper's §6 averaging step — after a dissemination exchange,
each rank averages its flat parameter vector with its partner's.  The
kernel is the AOT (artifacts/mix.hlo.txt) side of the mixing ablation;
the Rust coordinator also has a native SIMD mixer (nativenet::mix) and
benches/hotpath.rs compares the two.

Memory-bound: 2 reads + 1 write per element.  Same blocking rationale as
update.py (64 KiB streaming blocks, VPU-aligned).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 4 * 1024 * 1024  # see update.py's block-size note (§Perf)


def _mix_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = (a_ref[...] + b_ref[...]) * 0.5


def mix(a, b, block=BLOCK):
    """Elementwise (a + b) / 2 over flat f32 vectors of equal length."""
    (n,) = a.shape
    assert a.shape == b.shape
    block = min(block, n)
    pad = (-n) % block
    if pad:
        a = jnp.pad(a, (0, pad))
        b = jnp.pad(b, (0, pad))
    np_ = a.shape[0]
    out = pl.pallas_call(
        _mix_kernel,
        grid=(np_ // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), jnp.float32),
        interpret=True,
    )(a, b)
    return out[:n]
