"""L1 Pallas kernel: tiled linear layer  y = act(x @ w + b).

This is the compute hot-spot of every model in this repo (the paper's
conv/FC layers reduce to matmuls here — CIFARNet convs are lowered via
im2col in model.py, so *all* FLOPs flow through this kernel).

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles the output
into (BM, BN) VMEM blocks and marches over the K dimension in BK chunks —
the BlockSpec index maps express the HBM->VMEM schedule that a CUDA
implementation would express with threadblocks + shared-memory staging.
Default tiles are MXU-aligned (128x128 output, 512-deep K), giving a
working set of (BM*BK + BK*BN + BM*BN) * 4B ~= 0.75 MB << 16 MB VMEM,
leaving room for double buffering.

The kernel is exposed through a jax.custom_vjp so models can be
differentiated: the backward pass reuses the same tiled-matmul kernel for
dx = g @ w^T and dw = x^T @ g (activation gradient fused into g first).

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret mode lowers the same schedule to plain HLO.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# MXU-aligned tiles for a real TPU lowering (see module docstring).
TPU_BM, TPU_BN, TPU_BK = 128, 128, 512

# Block-size budget for the auto policy below: keep each tile's working
# set under ~16 MB (the VMEM envelope on TPUv4; also the point past which
# CPU-interpret execution stops improving).
BLOCK_BUDGET_FLOATS = 4 * 1024 * 1024

# Interpret-mode grids lower to sequential HLO while-loops, so on the CPU
# testbed FEWER, BIGGER tiles win (EXPERIMENTS.md §Perf: the CNN step
# dropped ~20x moving from fixed 128x128x512 tiles to this policy).  Set
# GOSSIPGRAD_TPU_TILES=1 when lowering for a real TPU to get the
# MXU-aligned tiling instead.
import os

USE_TPU_TILES = os.environ.get("GOSSIPGRAD_TPU_TILES") == "1"


def _auto_blocks(m, k, n):
    """Pick (bm, bk, bn) minimizing grid steps under the block budget.

    Strategy: never split k or n (they are small in every model here —
    k,n <= 3*d_model); split m only as needed to fit the budget.
    """
    if USE_TPU_TILES:
        return TPU_BM, TPU_BK, TPU_BN
    bk, bn = _rup(k, 8), _rup(n, 8)
    # floats held per tile: bm*bk + bk*bn + bm*bn
    denom = max(bk + bn, 1)
    bm_max = max((BLOCK_BUDGET_FLOATS - bk * bn) // denom, 8)
    bm = min(_rup(m, 8), _rup(bm_max, 8))
    return bm, bk, bn


def _matmul_kernel(x_ref, w_ref, o_ref, *, nk):
    """One (m, n) output tile; the k grid axis accumulates partial sums."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _pad2(a, bm, bn):
    m, n = a.shape
    pm = (-m) % bm
    pn = (-n) % bn
    if pm or pn:
        a = jnp.pad(a, ((0, pm), (0, pn)))
    return a


def matmul(x, w, bm=None, bn=None, bk=None):
    """Tiled Pallas matmul on arbitrary [m,k] @ [k,n] (zero-padded to tiles)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    abm, abk, abn = _auto_blocks(m, k, n)
    bm, bk, bn = bm or abm, bk or abk, bn or abn
    bm, bk, bn = min(bm, _rup(m, 8)), min(bk, _rup(k, 8)), min(bn, _rup(n, 8))
    xp = _pad2(x, bm, bk)
    wp = _pad2(w, bk, bn)
    mp, kp = xp.shape
    _, np_ = wp.shape
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp)
    return out[:m, :n]


def _rup(v, q):
    """Round v up to a multiple of q (so tiny dims still get a legal tile)."""
    return ((v + q - 1) // q) * q


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def linear(x, w, b, activation="none"):
    """act(x @ w + b) with Pallas matmul; differentiable via custom_vjp."""
    pre = matmul(x, w) + b
    return ref.activate_ref(pre, activation)


def _linear_fwd(x, w, b, activation):
    pre = matmul(x, w) + b
    return ref.activate_ref(pre, activation), (x, w, pre)


def _linear_bwd(activation, res, g):
    x, w, pre = res
    if activation == "relu":
        g = g * (pre > 0.0).astype(g.dtype)
    elif activation == "gelu":
        g = g * ref.gelu_grad_ref(pre)
    dx = matmul(g, w.T)
    dw = matmul(x.T, g)
    db = jnp.sum(g, axis=0)
    return dx, dw, db


linear.defvjp(_linear_fwd, _linear_bwd)
