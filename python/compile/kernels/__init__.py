"""Pallas (L1) kernels for the GossipGraD reproduction.

All kernels run with interpret=True (CPU PJRT cannot execute Mosaic
custom-calls); the BlockSpecs still encode the TPU HBM<->VMEM schedule —
see DESIGN.md §Hardware-Adaptation and EXPERIMENTS.md §Perf for the
analytic VMEM/MXU analysis.
"""

from .linear import linear, matmul
from .mix import mix
from .softmax_xent import softmax_xent
from .update import sgd_momentum

__all__ = ["linear", "matmul", "mix", "softmax_xent", "sgd_momentum"]
