"""AOT pipeline: lower every L2 entry point to HLO *text* + meta.json.

Run once via ``make artifacts``; Python never touches the training path.

Interchange format is HLO text, NOT serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (proto.id() <= INT_MAX); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Per model family m in {mlp, cnn, transformer} this emits:

    artifacts/grad_{m}.hlo.txt        (params, x, y)            -> (grads, loss)
    artifacts/train_step_{m}.hlo.txt  (params, mom, x, y, lr)   -> (p', m', loss)
    artifacts/eval_{m}.hlo.txt        (params, x, y)            -> (loss, correct)
    artifacts/update_{m}.hlo.txt      (params, mom, grads, lr)  -> (p', m')
    artifacts/mix_{m}.hlo.txt         (a, b)                    -> ((a+b)/2,)
    artifacts/init_{m}.f32            raw little-endian f32 initial params
    artifacts/{m}.meta.json           shapes, layer table, artifact index
"""

import argparse
import json
import os
import struct

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower(fn, *specs):
    return to_hlo_text(jax.jit(fn).lower(*specs))


def write(path, text):
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text)} chars)")


def emit_model(m: M.Model, outdir: str):
    n = m.spec.total
    pv = jax.ShapeDtypeStruct((n,), jnp.float32)
    xs = jax.ShapeDtypeStruct(m.x_shape, m.x_dtype)
    ys = jax.ShapeDtypeStruct((m.labels_rows,), jnp.int32)
    lr = jax.ShapeDtypeStruct((), jnp.float32)

    print(f"[{m.name}] {n} params, batch {m.batch}, x{m.x_shape}")
    write(f"{outdir}/grad_{m.name}.hlo.txt", lower(m.grad_fn(), pv, xs, ys))
    write(
        f"{outdir}/train_step_{m.name}.hlo.txt",
        lower(m.train_step_fn(), pv, pv, xs, ys, lr),
    )
    write(f"{outdir}/eval_{m.name}.hlo.txt", lower(m.eval_fn(), pv, xs, ys))
    write(
        f"{outdir}/update_{m.name}.hlo.txt",
        lower(M.update_fn, pv, pv, pv, lr),
    )
    write(f"{outdir}/mix_{m.name}.hlo.txt", lower(M.mix_fn, pv, pv))

    init = m.spec.init(seed=0)
    raw = struct.pack(f"<{n}f", *map(float, init))
    with open(f"{outdir}/init_{m.name}.f32", "wb") as f:
        f.write(raw)
    print(f"  wrote {outdir}/init_{m.name}.f32 ({len(raw)} bytes)")

    meta = {
        "model": m.name,
        "param_count": n,
        "batch": m.batch,
        "x_shape": list(m.x_shape),
        "x_dtype": "i32" if m.x_dtype == jnp.int32 else "f32",
        "labels_rows": m.labels_rows,
        "classes": m.classes,
        "momentum": M.MOMENTUM,
        "layers": m.spec.layer_table(),
        "artifacts": {
            "grad": f"grad_{m.name}.hlo.txt",
            "train_step": f"train_step_{m.name}.hlo.txt",
            "eval": f"eval_{m.name}.hlo.txt",
            "update": f"update_{m.name}.hlo.txt",
            "mix": f"mix_{m.name}.hlo.txt",
            "init": f"init_{m.name}.f32",
        },
    }
    with open(f"{outdir}/{m.name}.meta.json", "w") as f:
        json.dump(meta, f, indent=1)
    print(f"  wrote {outdir}/{m.name}.meta.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--models",
        default="mlp,cnn,transformer,transformer_small",
        help="comma-separated",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    for name in args.models.split(","):
        emit_model(M.build_model(name.strip()), args.out)
    print("AOT done.")


if __name__ == "__main__":
    main()
