"""Kernel-vs-oracle correctness: the CORE numerical signal of L1.

hypothesis sweeps shapes (ragged, non-tile-aligned) for every Pallas
kernel and asserts allclose against the pure-jnp oracle in kernels/ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import linear, matmul, mix, sgd_momentum, softmax_xent
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

SET = dict(max_examples=12, deadline=None)


def rng(seed):
    return np.random.default_rng(seed)


@settings(**SET)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 70),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    r = rng(seed)
    x = r.standard_normal((m, k), dtype=np.float32)
    w = r.standard_normal((k, n), dtype=np.float32)
    got = matmul(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_allclose(got, x @ w, rtol=2e-4, atol=2e-4)


def test_matmul_tile_aligned_exact_shape():
    # shapes exactly matching the default tiles must not be padded/sliced
    r = rng(0)
    x = r.standard_normal((128, 512), dtype=np.float32)
    w = r.standard_normal((512, 128), dtype=np.float32)
    got = matmul(jnp.asarray(x), jnp.asarray(w))
    assert got.shape == (128, 128)
    np.testing.assert_allclose(got, x @ w, rtol=2e-4, atol=2e-3)


@settings(**SET)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 40),
    n=st.integers(1, 40),
    act=st.sampled_from(["none", "relu", "gelu"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_linear_fwd_matches_ref(m, k, n, act, seed):
    r = rng(seed)
    x = jnp.asarray(r.standard_normal((m, k), dtype=np.float32))
    w = jnp.asarray(r.standard_normal((k, n), dtype=np.float32))
    b = jnp.asarray(r.standard_normal(n, dtype=np.float32))
    np.testing.assert_allclose(
        linear(x, w, b, act), ref.linear_ref(x, w, b, act), rtol=1e-4, atol=1e-4
    )


@settings(**SET)
@given(
    m=st.integers(2, 24),
    k=st.integers(2, 24),
    n=st.integers(2, 24),
    act=st.sampled_from(["none", "relu", "gelu"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_linear_grad_matches_autodiff_of_ref(m, k, n, act, seed):
    r = rng(seed)
    x = jnp.asarray(r.standard_normal((m, k), dtype=np.float32))
    w = jnp.asarray(r.standard_normal((k, n), dtype=np.float32))
    b = jnp.asarray(r.standard_normal(n, dtype=np.float32))

    def f_pallas(w, b, x):
        return jnp.sum(linear(x, w, b, act) ** 2)

    def f_ref(w, b, x):
        return jnp.sum(ref.linear_ref(x, w, b, act) ** 2)

    gw, gb, gx = jax.grad(f_pallas, argnums=(0, 1, 2))(w, b, x)
    rw, rb, rx = jax.grad(f_ref, argnums=(0, 1, 2))(w, b, x)
    np.testing.assert_allclose(gw, rw, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(gb, rb, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(gx, rx, rtol=1e-3, atol=1e-3)


@settings(**SET)
@given(
    n=st.integers(1, 100_000),
    lr=st.floats(1e-4, 1.0),
    mu=st.sampled_from([0.0, 0.5, 0.9, 0.99]),
    seed=st.integers(0, 2**31 - 1),
)
def test_sgd_momentum_matches_ref(n, lr, mu, seed):
    r = rng(seed)
    p = jnp.asarray(r.standard_normal(n, dtype=np.float32))
    v = jnp.asarray(r.standard_normal(n, dtype=np.float32))
    g = jnp.asarray(r.standard_normal(n, dtype=np.float32))
    p2, v2 = sgd_momentum(p, v, g, lr, mu)
    pr, vr = ref.sgd_momentum_ref(p, v, g, lr, mu)
    np.testing.assert_allclose(p2, pr, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(v2, vr, rtol=1e-5, atol=1e-5)


@settings(**SET)
@given(n=st.integers(1, 200_000), seed=st.integers(0, 2**31 - 1))
def test_mix_matches_ref(n, seed):
    r = rng(seed)
    a = jnp.asarray(r.standard_normal(n, dtype=np.float32))
    b = jnp.asarray(r.standard_normal(n, dtype=np.float32))
    np.testing.assert_allclose(mix(a, b), ref.mix_ref(a, b), rtol=1e-6)


def test_mix_is_symmetric_and_idempotent_on_equal():
    a = jnp.linspace(-3, 3, 4097)
    b = jnp.linspace(5, -5, 4097)
    np.testing.assert_allclose(mix(a, b), mix(b, a), rtol=0, atol=0)
    np.testing.assert_allclose(mix(a, a), a, rtol=0, atol=0)


@settings(**SET)
@given(
    m=st.integers(1, 300),
    c=st.integers(2, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_softmax_xent_fwd_matches_ref(m, c, seed):
    r = rng(seed)
    logits = jnp.asarray(5 * r.standard_normal((m, c), dtype=np.float32))
    labels = jnp.asarray(r.integers(0, c, m, dtype=np.int32))
    np.testing.assert_allclose(
        softmax_xent(logits, labels),
        ref.softmax_xent_ref(logits, labels),
        rtol=1e-5,
        atol=1e-5,
    )


@settings(**SET)
@given(
    m=st.integers(1, 64),
    c=st.integers(2, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_softmax_xent_bwd_matches_ref(m, c, seed):
    r = rng(seed)
    logits = jnp.asarray(r.standard_normal((m, c), dtype=np.float32))
    labels = jnp.asarray(r.integers(0, c, m, dtype=np.int32))
    got = jax.grad(lambda l: softmax_xent(l, labels))(logits)
    want = ref.softmax_xent_bwd_ref(logits, labels, 1.0)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)


def test_softmax_xent_extreme_logits_stable():
    logits = jnp.asarray([[1e4, -1e4, 0.0], [-1e4, 1e4, 0.0]], jnp.float32)
    labels = jnp.asarray([0, 1], jnp.int32)
    loss = softmax_xent(logits, labels)
    assert np.isfinite(float(loss))
    np.testing.assert_allclose(float(loss), 0.0, atol=1e-5)


def test_mix_preserves_mean():
    # the §6 conservation property the Rust side also proptest-checks
    r = rng(7)
    a = jnp.asarray(r.standard_normal(5000, dtype=np.float32))
    b = jnp.asarray(r.standard_normal(5000, dtype=np.float32))
    m = mix(a, b)
    np.testing.assert_allclose(
        2 * np.asarray(m), np.asarray(a) + np.asarray(b), rtol=1e-6, atol=1e-6
    )
