"""Transformer-specific L2 checks: causality, shapes, presets, and the
learnability smoke test on the small preset."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")


def small_cfg():
    return M.TransformerCfg(
        vocab=32, d_model=16, n_layers=2, n_heads=2, d_ff=32, seq=8
    )


def build_small(batch=2):
    cfg = small_cfg()
    spec = M.transformer_spec(cfg)
    return cfg, spec


def test_causality_future_tokens_do_not_affect_past_logits():
    cfg, spec = build_small()
    flat = spec.init(0)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, cfg.seq)), jnp.int32)
    logits = M.transformer_logits(spec, cfg, flat, toks).reshape(
        cfg.seq, cfg.vocab
    )
    # perturb the LAST token: logits at positions < seq-1 must not change
    toks2 = toks.at[0, -1].set((toks[0, -1] + 1) % cfg.vocab)
    logits2 = M.transformer_logits(spec, cfg, flat, toks2).reshape(
        cfg.seq, cfg.vocab
    )
    np.testing.assert_allclose(
        logits[: cfg.seq - 1], logits2[: cfg.seq - 1], rtol=1e-5, atol=1e-5
    )
    # ...and the last position must change (head depends on the token)
    assert not np.allclose(logits[-1], logits2[-1])


def test_position_embedding_breaks_permutation_symmetry():
    cfg, spec = build_small()
    flat = spec.init(1)
    a = jnp.asarray([[1, 2] * (cfg.seq // 2)], jnp.int32)
    b = jnp.asarray([[2, 1] * (cfg.seq // 2)], jnp.int32)
    la = M.transformer_logits(spec, cfg, flat, a)
    lb = M.transformer_logits(spec, cfg, flat, b)
    assert not np.allclose(la, lb)


def test_spec_layer_table_matches_param_count():
    cfg, spec = build_small()
    table = spec.layer_table()
    assert sum(e["len"] for e in table) == spec.total
    # qkv weight+bias grouped as one layer entry
    names = [e["name"] for e in table]
    assert "blk0.qkv" in names and "blk1.ff2" in names


def test_gradients_flow_to_all_parameters():
    cfg, spec = build_small()
    flat = spec.init(2)
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, cfg.seq)), jnp.int32)
    targets = jnp.asarray(
        rng.integers(0, cfg.vocab, 2 * cfg.seq), jnp.int32
    )

    def loss(f):
        from compile.kernels import ref

        logits = M.transformer_logits(spec, cfg, f, toks)
        return ref.softmax_xent_ref(logits, targets)

    g = jax.grad(loss)(flat)
    # every layer must receive some gradient signal
    for e in spec.layer_table():
        sl = g[e["offset"] : e["offset"] + e["len"]]
        assert float(jnp.abs(sl).max()) > 0.0, f"dead layer {e['name']}"


def test_transformer_small_preset_shapes():
    m = M.build_model("transformer_small")
    assert m.classes == 256
    assert m.x_shape == (4, 32)
    assert m.labels_rows == 4 * 32
    assert m.spec.total < 1_500_000


def test_unknown_model_rejected():
    with pytest.raises(ValueError):
        M.build_model("resnet5000")


def test_train_step_learns_bigram_structure():
    # tiny end-to-end learnability: memorize a deterministic bigram chain
    cfg, spec = build_small()
    m = M.Model(
        "t",
        spec,
        lambda f, x: M.transformer_logits(spec, cfg, f, x),
        (2, cfg.seq),
        jnp.int32,
        2 * cfg.seq,
        cfg.vocab,
        2,
    )
    flat = spec.init(3)
    mom = jnp.zeros_like(flat)
    # chain: token t -> (t+1) % vocab
    base = np.arange(cfg.seq + 1) % cfg.vocab
    x = jnp.asarray(np.stack([base[:-1], base[:-1]]), jnp.int32)
    y = jnp.asarray(np.concatenate([base[1:], base[1:]]), jnp.int32)
    step = jax.jit(m.train_step_fn())
    first = None
    last = None
    for _ in range(80):
        flat, mom, loss = step(flat, mom, x, y, jnp.float32(0.3))
        if first is None:
            first = float(loss)
        last = float(loss)
    assert last < 0.3 * first, f"{first} -> {last}"
