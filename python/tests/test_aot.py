"""AOT lowering contract: HLO text loads back through xla_client, and the
compiled module reproduces the traced function bit-for-bit-ish.

This is the python-side mirror of the Rust runtime integration tests —
it validates the *format* (HLO text with reassigned ids) without needing
the Rust binary.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot
from compile import model as M

jax.config.update("jax_platform_name", "cpu")


def roundtrip(fn, *specs, args):
    text = aot.lower(fn, *specs)
    assert text.startswith("HloModule")
    # parse the text back and execute on the CPU backend
    comp = xc._xla.hlo_module_from_text(text)
    backend = xc.get_local_backend("cpu")
    exe = backend.compile(
        xc._xla.computation_from_hlo_module(comp)
        if hasattr(xc._xla, "computation_from_hlo_module")
        else comp
    )
    outs = exe.execute([backend.buffer_from_pyval(np.asarray(a)) for a in args])
    return [np.asarray(o) for o in outs]


def test_hlo_text_parses():
    spec = jax.ShapeDtypeStruct((4,), jnp.float32)
    text = aot.lower(lambda a, b: (a + b,), spec, spec)
    assert text.startswith("HloModule")
    assert "f32[4]" in text


def test_mix_artifact_numerics():
    spec = jax.ShapeDtypeStruct((300,), jnp.float32)
    text = aot.lower(M.mix_fn, spec, spec)
    assert "f32[300]" in text


@pytest.mark.parametrize("name", ["mlp"])
def test_emitted_meta_consistent(tmp_path, name):
    m = M.build_model(name)
    aot.emit_model(m, str(tmp_path))
    meta = json.load(open(tmp_path / f"{name}.meta.json"))
    assert meta["param_count"] == m.spec.total
    assert sum(l["len"] for l in meta["layers"]) == m.spec.total
    init = np.fromfile(tmp_path / f"init_{name}.f32", dtype="<f4")
    assert init.shape == (m.spec.total,)
    assert np.isfinite(init).all()
    for key, fname in meta["artifacts"].items():
        assert os.path.exists(tmp_path / fname), (key, fname)
        if fname.endswith(".hlo.txt"):
            head = open(tmp_path / fname).read(9)
            assert head == "HloModule"


def test_grad_artifact_shapes_in_text():
    m = M.build_model("mlp")
    n = m.spec.total
    pv = jax.ShapeDtypeStruct((n,), jnp.float32)
    xs = jax.ShapeDtypeStruct(m.x_shape, m.x_dtype)
    ys = jax.ShapeDtypeStruct((m.labels_rows,), jnp.int32)
    text = aot.lower(m.grad_fn(), pv, xs, ys)
    assert f"f32[{n}]" in text
