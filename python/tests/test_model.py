"""L2 model checks: flat-param plumbing, shapes, loss/grad sanity,
pallas-model vs pure-jnp-model equivalence for the MLP family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def small_mlp():
    spec = M.mlp_spec(din=20, hidden=(16,), classes=5)
    return spec


def test_paramspec_roundtrip():
    spec = small_mlp()
    flat = spec.init(0)
    assert flat.shape == (spec.total,)
    p = spec.unflatten(flat)
    assert p["fc0.w"].shape == (20, 16)
    assert p["fc1.b"].shape == (5,)
    # re-flatten equals original
    reflat = jnp.concatenate([p[n].reshape(-1) for n in spec.names])
    np.testing.assert_array_equal(flat, reflat)


def test_layer_table_covers_all_params_contiguously():
    for name in ["mlp", "cnn", "transformer"]:
        m = M.build_model(name)
        table = m.spec.layer_table()
        off = 0
        for entry in table:
            assert entry["offset"] == off, (name, entry)
            off += entry["len"]
        assert off == m.spec.total


def test_bias_init_zero_weights_scaled():
    spec = small_mlp()
    p = spec.unflatten(spec.init(3))
    np.testing.assert_array_equal(p["fc0.b"], 0)
    # He init: std ~ sqrt(2/fan_in)
    std = float(jnp.std(p["fc0.w"]))
    assert 0.5 * np.sqrt(2 / 20) < std < 2.0 * np.sqrt(2 / 20)


def mlp_logits_jnp_ref(spec, flat, x):
    p = spec.unflatten(flat)
    h = x
    n_layers = len(spec.names) // 2
    for i in range(n_layers):
        act = "relu" if i < n_layers - 1 else "none"
        h = ref.linear_ref(h, p[f"fc{i}.w"], p[f"fc{i}.b"], act)
    return h


def test_mlp_pallas_model_matches_jnp_model():
    spec = small_mlp()
    flat = spec.init(1)
    x = jax.random.normal(jax.random.PRNGKey(2), (9, 20))
    got = M.mlp_logits(spec, flat, x)
    want = mlp_logits_jnp_ref(spec, flat, x)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_mlp_grad_matches_jnp_model_grad():
    spec = small_mlp()
    flat = spec.init(1)
    x = jax.random.normal(jax.random.PRNGKey(2), (9, 20))
    y = jnp.asarray(np.arange(9) % 5, jnp.int32)

    def loss_pallas(f):
        return ref.softmax_xent_ref(M.mlp_logits(spec, f, x), y)

    def loss_jnp(f):
        return ref.softmax_xent_ref(mlp_logits_jnp_ref(spec, f, x), y)

    g1 = jax.grad(loss_pallas)(flat)
    g2 = jax.grad(loss_jnp)(flat)
    np.testing.assert_allclose(g1, g2, rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("name", ["mlp", "cnn", "transformer"])
def test_model_loss_finite_and_near_log_classes(name):
    m = M.build_model(name)
    flat = m.spec.init(0)
    r = np.random.default_rng(0)
    if m.x_dtype == jnp.int32:
        x = jnp.asarray(r.integers(0, m.classes, m.x_shape, dtype=np.int32))
    else:
        x = jnp.asarray(r.standard_normal(m.x_shape, dtype=np.float32))
    y = jnp.asarray(r.integers(0, m.classes, m.labels_rows, dtype=np.int32))
    loss = m.loss(flat, x, y)
    assert np.isfinite(float(loss))
    # fresh random init => loss near log(C); He-init through the conv
    # stack inflates CIFARNet logits somewhat, hence the loose bound
    assert abs(float(loss) - np.log(m.classes)) < 3.5


def test_train_step_decreases_loss_on_fixed_batch():
    m = M.build_model("mlp")
    flat = m.spec.init(0)
    mom = jnp.zeros_like(flat)
    r = np.random.default_rng(1)
    x = jnp.asarray(r.standard_normal(m.x_shape, dtype=np.float32))
    y = jnp.asarray(r.integers(0, 10, m.batch, dtype=np.int32))
    step = jax.jit(m.train_step_fn())
    losses = []
    for _ in range(5):
        flat, mom, loss = step(flat, mom, x, y, jnp.float32(0.05))
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_eval_counts_bounded():
    m = M.build_model("mlp")
    flat = m.spec.init(0)
    r = np.random.default_rng(2)
    x = jnp.asarray(r.standard_normal(m.x_shape, dtype=np.float32))
    y = jnp.asarray(r.integers(0, 10, m.batch, dtype=np.int32))
    loss, correct = m.eval_fn()(flat, x, y)
    assert 0.0 <= float(correct) <= m.batch


def test_update_fn_matches_ref():
    n = 1234
    r = np.random.default_rng(3)
    p = jnp.asarray(r.standard_normal(n, dtype=np.float32))
    v = jnp.asarray(r.standard_normal(n, dtype=np.float32))
    g = jnp.asarray(r.standard_normal(n, dtype=np.float32))
    p2, v2 = M.update_fn(p, v, g, jnp.float32(0.1))
    pr, vr = ref.sgd_momentum_ref(p, v, g, 0.1, M.MOMENTUM)
    np.testing.assert_allclose(p2, pr, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(v2, vr, rtol=1e-5, atol=1e-5)
