//! Figures 10 & 11: relative speedup of GossipGraD over AGD on the
//! MNIST (LeNet3) and CIFAR10 (CIFARNet) workloads, P100- and KNL-speed
//! devices, 2–32 ranks, weak scaling.
//!
//!     cargo bench --bench fig10_11_speedup
//!
//! Three layers of evidence:
//! 1. simulator sweep at the paper's device speeds (P100 ≈ 4x KNL for
//!    these nets) — regenerates the figures' curves;
//! 2. a real measured grid (threads + native backend + α–β fabric) at a
//!    few rank counts to confirm the simulated ordering holds in running
//!    code;
//! 3. a **virtual-clock** measured grid (deterministic discrete-event
//!    timing, docs/virtual-time.md) that pushes the measured path to
//!    p = 256 — rank counts the wall-clock fabric cannot reach — in
//!    seconds of real time, with bit-reproducible step timings.
//!
//! All measured sections run on the experiment engine (`exp::Grid` +
//! `exp::Engine`): the grid is declared once (`algo × p`, or
//! `comm_thread × p`) and the engine owns fabric/dataset/backend setup.
//!
//! Expected shape: speedup > 1 everywhere, increasing with p, larger on
//! the faster device (P100) — the paper reports ~1.9x for MNIST at 32.

use gossipgrad::collectives::Algorithm;
use gossipgrad::config::{Algo, RunConfig};
use gossipgrad::exp::{Engine, Grid};
use gossipgrad::sim::efficiency::{avg_efficiency, overlapped_agd_step_time};
use gossipgrad::sim::{Schedule, Workload};
use gossipgrad::transport::CostModel;
use gossipgrad::util::bench::Table;

fn sim_sweep(name: &str, mk: &dyn Fn(f64) -> Workload) -> (f64, f64) {
    let cost = CostModel::ib_edr(0);
    let mut t = Table::new(&["p", "speedup P100", "speedup KNL"]);
    let mut last = (0.0, 0.0);
    for p in [2usize, 4, 8, 16, 32] {
        let mut row = vec![p.to_string()];
        let mut sp = Vec::new();
        for speed in [4.0, 1.0] {
            // device_speed scales compute time; comm unchanged
            let w = mk(speed);
            let agd = avg_efficiency(
                Schedule::Agd(Algorithm::RecursiveDoubling),
                &w,
                p,
                &cost,
                32,
            );
            let g = avg_efficiency(Schedule::Gossip, &w, p, &cost, 32);
            sp.push(agd.t_step / g.t_step);
            row.push(format!("{:.2}", agd.t_step / g.t_step));
        }
        last = (sp[0], sp[1]);
        t.row(&row);
    }
    t.print(&format!(
        "{name} — simulated GossipGraD speedup over AGD (weak scaling)"
    ));
    last
}

fn real_runs() {
    let base = RunConfig {
        model: "mlp".into(),
        steps: 20,
        use_artifacts: false, // native backend: stable timing
        rows_per_rank: 256,
        // slow fabric so the schedules separate measurably
        net_alpha: 200e-6,
        net_beta: 1.0 / 0.5e9,
        ..Default::default()
    };
    let ranks = [2usize, 4, 8];
    let grid = Grid::new(base).algos(&[Algo::Agd, Algo::Gossip]).ranks(&ranks);
    // wall-clock timing: one scenario at a time, or they'd contend
    let sweep = Engine::with_threads(1).run(&grid).expect("measured grid");
    let mut t = Table::new(&["ranks", "agd step ms", "gossip step ms", "speedup"]);
    for &p in &ranks {
        let agd = sweep.get("agd", |c| c.algo == Algo::Agd && c.ranks == p);
        let g = sweep.get("gossip", |c| c.algo == Algo::Gossip && c.ranks == p);
        t.row(&[
            p.to_string(),
            format!("{:.2}", 1e3 * agd.mean_step_secs),
            format!("{:.2}", 1e3 * g.mean_step_secs),
            format!("{:.2}", agd.mean_step_secs / g.mean_step_secs),
        ]);
    }
    t.print("measured (threads + fabric, MLP/native): AGD vs GossipGraD");
}

/// Virtual-clock measured grid: same coordinator + transport code as
/// `real_runs`, but with per-rank logical clocks charging the LeNet3
/// compute model through the **layer-wise pipeline** (per-layer backprop
/// slices, per-layer sends at grad-ready instants).  Timing is
/// deterministic and the wall cost per rank is only the backend's real
/// compute, so p = 256 finishes in seconds.  The overlap column is the
/// measured fraction of received wire time hidden under compute.
fn virtual_runs() {
    let w = Workload::lenet3(4.0);
    let mut base = RunConfig {
        model: "mlp-small".into(),
        ranks: 64,
        steps: 8,
        use_artifacts: false,
        rows_per_rank: 32,
        layerwise: true, // per-layer pipelined schedule
        ..Default::default()
    };
    // slow fabric so the schedules separate measurably (matches real_runs)
    base.virtualize(&w, 200e-6, 1.0 / 0.5e9);
    let ranks = [64usize, 128, 256];
    let grid = Grid::new(base).algos(&[Algo::Agd, Algo::Gossip]).ranks(&ranks);
    let t0 = std::time::Instant::now();
    let sweep = Engine::default().run(&grid).expect("virtual grid");
    let mut t = Table::new(&[
        "ranks",
        "agd step ms",
        "gossip step ms",
        "speedup",
        "gossip eff %",
        "gossip overlap %",
        "agd overlap %",
    ]);
    let mut last_speedup = 0.0f64;
    let mut last_overlap = 0.0f64;
    for &p in &ranks {
        let agd = sweep.get("agd", |c| c.algo == Algo::Agd && c.ranks == p);
        let g = sweep.get("gossip", |c| c.algo == Algo::Gossip && c.ranks == p);
        last_speedup = agd.mean_step_secs / g.mean_step_secs;
        last_overlap = 100.0 * g.mean_overlap_frac;
        t.row(&[
            p.to_string(),
            format!("{:.2}", 1e3 * agd.mean_step_secs),
            format!("{:.2}", 1e3 * g.mean_step_secs),
            format!("{:.2}", last_speedup),
            format!("{:.1}", g.mean_efficiency_pct),
            format!("{:.1}", 100.0 * g.mean_overlap_frac),
            format!("{:.1}", 100.0 * agd.mean_overlap_frac),
        ]);
    }
    t.print(
        "measured on the VIRTUAL-CLOCK fabric, layer-wise pipeline \
         (deterministic, p to 256, experiment engine)",
    );
    assert!(
        last_overlap > 50.0,
        "pipelined gossip should hide most wire time (overlap {last_overlap:.1}%)"
    );
    println!(
        "  swept p = 64/128/256 in {:.1}s wall (simulated seconds are free)",
        t0.elapsed().as_secs_f64()
    );
    assert!(
        last_speedup > 1.0,
        "gossip must beat AGD at p=256 (speedup {last_speedup:.2})"
    );
}

/// Comm-thread AGD vs the blocking chain on the measured fabric, with
/// the closed-form overlapped-AGD curve as the analytic twin (same
/// stand-in layer table, same α–β, sample shuffle off so only
/// collective traffic is timed).  AGD stops being unfairly pessimistic:
/// its rounds hide under remaining backprop exactly as a dedicated MPI
/// progress thread would hide them.
fn comm_thread_runs() {
    let w = Workload::lenet3(4.0);
    let dims = [784usize, 32, 10]; // = the mlp-small backend's stack
    let mut base = RunConfig {
        model: "mlp-small".into(),
        algo: Algo::Agd,
        steps: 6,
        use_artifacts: false,
        rows_per_rank: 32,
        sample_shuffle: false,
        layerwise: true,
        ..Default::default()
    };
    base.virtualize(&w, 200e-6, 1.0 / 0.5e9);
    let standin = Workload::standin_mlp(
        base.virt_fwd_secs,
        base.virt_compute_secs - base.virt_fwd_secs,
        &dims,
    );
    let cost = base.cost_model();
    let ranks = [64usize, 256, 1024];
    let grid = Grid::new(base).ranks(&ranks).comm_threads(&[false, true]);
    let sweep = Engine::default().run(&grid).expect("comm-thread grid");
    let mut t = Table::new(&[
        "ranks",
        "blocking step ms",
        "comm-thread step ms",
        "closed form ms",
        "blocking overlap %",
        "comm-thread overlap %",
    ]);
    for &p in &ranks {
        let blocking = sweep.get("blocking", |c| !c.comm_thread && c.ranks == p);
        let ct = sweep.get("comm-thread", |c| c.comm_thread && c.ranks == p);
        let analytic =
            overlapped_agd_step_time(Algorithm::RecursiveDoubling, &standin, p, &cost);
        assert_eq!(
            blocking.param_hash, ct.param_hash,
            "p={p}: comm thread changed AGD numerics"
        );
        assert!(
            ct.mean_overlap_frac > blocking.mean_overlap_frac,
            "p={p}: comm-thread overlap {:.4} !> blocking {:.4}",
            ct.mean_overlap_frac,
            blocking.mean_overlap_frac
        );
        let got = ct.mean_step_secs;
        assert!(
            (got - analytic).abs() / analytic < 0.05,
            "p={p}: measured comm-thread AGD {got}s vs closed form {analytic}s"
        );
        t.row(&[
            p.to_string(),
            format!("{:.2}", 1e3 * blocking.mean_step_secs),
            format!("{:.2}", 1e3 * got),
            format!("{:.2}", 1e3 * analytic),
            format!("{:.1}", 100.0 * blocking.mean_overlap_frac),
            format!("{:.1}", 100.0 * ct.mean_overlap_frac),
        ]);
    }
    t.print(
        "comm-thread AGD (non-blocking collective engine) vs blocking \
         chain vs closed-form overlapped-AGD, measured virtual fabric",
    );
    println!("  comm-thread AGD matches the closed form within 5% up to p = 1024");
}

fn main() {
    let (p100, knl) = sim_sweep("Fig 10 — MNIST/LeNet3", &Workload::lenet3);
    sim_sweep("Fig 11 — CIFAR10/CIFARNet", &Workload::cifarnet);
    real_runs();
    virtual_runs();
    comm_thread_runs();
    println!(
        "\nshape check @32: P100 speedup {p100:.2} > KNL speedup {knl:.2} > 1 (paper: ~1.9x MNIST/P100)"
    );
    assert!(p100 > knl && knl > 1.0);
}
