//! Figures 10 & 11: relative speedup of GossipGraD over AGD on the
//! MNIST (LeNet3) and CIFAR10 (CIFARNet) workloads, P100- and KNL-speed
//! devices, 2–32 ranks, weak scaling.
//!
//!     cargo bench --bench fig10_11_speedup
//!
//! Three layers of evidence:
//! 1. simulator sweep at the paper's device speeds (P100 ≈ 4x KNL for
//!    these nets) — regenerates the figures' curves;
//! 2. a real measured run (threads + native backend + α–β fabric) at a
//!    few rank counts to confirm the simulated ordering holds in running
//!    code;
//! 3. a **virtual-clock** measured sweep (deterministic discrete-event
//!    timing, docs/virtual-time.md) that pushes the measured path to
//!    p = 256 — rank counts the wall-clock fabric cannot reach — in
//!    seconds of real time, with bit-reproducible step timings.
//!
//! Expected shape: speedup > 1 everywhere, increasing with p, larger on
//! the faster device (P100) — the paper reports ~1.9x for MNIST at 32.

use gossipgrad::collectives::Algorithm;
use gossipgrad::config::{Algo, RunConfig};
use gossipgrad::coordinator::trainer::run_with_backend;
use gossipgrad::nativenet::NativeMlp;
use gossipgrad::sim::efficiency::{avg_efficiency, overlapped_agd_step_time};
use gossipgrad::sim::{Schedule, Workload};
use gossipgrad::transport::CostModel;
use gossipgrad::util::bench::Table;
use std::sync::Arc;

fn sim_sweep(name: &str, mk: &dyn Fn(f64) -> Workload) -> (f64, f64) {
    let cost = CostModel::ib_edr(0);
    let mut t = Table::new(&["p", "speedup P100", "speedup KNL"]);
    let mut last = (0.0, 0.0);
    for p in [2usize, 4, 8, 16, 32] {
        let mut row = vec![p.to_string()];
        let mut sp = Vec::new();
        for speed in [4.0, 1.0] {
            // device_speed scales compute time; comm unchanged
            let w = mk(speed);
            let agd = avg_efficiency(
                Schedule::Agd(Algorithm::RecursiveDoubling),
                &w,
                p,
                &cost,
                32,
            );
            let g = avg_efficiency(Schedule::Gossip, &w, p, &cost, 32);
            sp.push(agd.t_step / g.t_step);
            row.push(format!("{:.2}", agd.t_step / g.t_step));
        }
        last = (sp[0], sp[1]);
        t.row(&row);
    }
    t.print(&format!(
        "{name} — simulated GossipGraD speedup over AGD (weak scaling)"
    ));
    last
}

fn real_runs() {
    let mut t = Table::new(&["ranks", "agd step ms", "gossip step ms", "speedup"]);
    for ranks in [2usize, 4, 8] {
        let mut step_ms = [0.0f64; 2];
        for (i, algo) in [Algo::Agd, Algo::Gossip].into_iter().enumerate() {
            let cfg = RunConfig {
                model: "mlp".into(),
                algo,
                ranks,
                steps: 20,
                use_artifacts: false, // native backend: stable timing
                rows_per_rank: 256,
                // slow fabric so the schedules separate measurably
                net_alpha: 200e-6,
                net_beta: 1.0 / 0.5e9,
                ..Default::default()
            };
            let res = gossipgrad::coordinator::run(&cfg).expect("run");
            step_ms[i] = 1e3 * res.mean_step_secs();
        }
        t.row(&[
            ranks.to_string(),
            format!("{:.2}", step_ms[0]),
            format!("{:.2}", step_ms[1]),
            format!("{:.2}", step_ms[0] / step_ms[1]),
        ]);
    }
    t.print("measured (threads + fabric, MLP/native): AGD vs GossipGraD");
}

/// Virtual-clock measured sweep: same coordinator + transport code as
/// `real_runs`, but with per-rank logical clocks charging the LeNet3
/// compute model through the **layer-wise pipeline** (per-layer backprop
/// slices, per-layer sends at grad-ready instants).  Timing is
/// deterministic and the wall cost per rank is only the backend's real
/// compute, so p = 256 finishes in seconds.  The overlap column is the
/// measured fraction of received wire time hidden under compute.
fn virtual_runs() {
    let w = Workload::lenet3(4.0);
    let mut t = Table::new(&[
        "ranks",
        "agd step ms",
        "gossip step ms",
        "speedup",
        "gossip eff %",
        "gossip overlap %",
        "agd overlap %",
    ]);
    let mut last_speedup = 0.0f64;
    let mut last_overlap = 0.0f64;
    let t0 = std::time::Instant::now();
    for ranks in [64usize, 128, 256] {
        let mut step_ms = [0.0f64; 2];
        let mut overlap = [0.0f64; 2];
        let mut eff = 0.0f64;
        for (i, algo) in [Algo::Agd, Algo::Gossip].into_iter().enumerate() {
            let mut cfg = RunConfig {
                model: "mlp".into(),
                algo,
                ranks,
                steps: 8,
                use_artifacts: false,
                rows_per_rank: 32,
                layerwise: true, // per-layer pipelined schedule
                // slow fabric so the schedules separate measurably
                // (matches real_runs)
                ..Default::default()
            };
            cfg.virtualize(&w, 200e-6, 1.0 / 0.5e9);
            // small native net: wall cost is the real compute, virtual
            // timing comes from the workload model
            let backend = Arc::new(NativeMlp::new(vec![784, 32, 10], 16, 0));
            let res = run_with_backend(&cfg, backend).expect("virtual run");
            step_ms[i] = 1e3 * res.mean_step_secs();
            overlap[i] = 100.0 * res.mean_overlap_frac();
            if algo == Algo::Gossip {
                eff = res.mean_efficiency_pct();
            }
        }
        last_speedup = step_ms[0] / step_ms[1];
        last_overlap = overlap[1];
        t.row(&[
            ranks.to_string(),
            format!("{:.2}", step_ms[0]),
            format!("{:.2}", step_ms[1]),
            format!("{:.2}", last_speedup),
            format!("{eff:.1}"),
            format!("{:.1}", overlap[1]),
            format!("{:.1}", overlap[0]),
        ]);
    }
    t.print(
        "measured on the VIRTUAL-CLOCK fabric, layer-wise pipeline \
         (deterministic, p to 256)",
    );
    assert!(
        last_overlap > 50.0,
        "pipelined gossip should hide most wire time (overlap {last_overlap:.1}%)"
    );
    println!(
        "  swept p = 64/128/256 in {:.1}s wall (simulated seconds are free)",
        t0.elapsed().as_secs_f64()
    );
    assert!(
        last_speedup > 1.0,
        "gossip must beat AGD at p=256 (speedup {last_speedup:.2})"
    );
}

/// Comm-thread AGD vs the blocking chain on the measured fabric, with
/// the closed-form overlapped-AGD curve as the analytic twin (same
/// stand-in layer table, same α–β, sample shuffle off so only
/// collective traffic is timed).  AGD stops being unfairly pessimistic:
/// its rounds hide under remaining backprop exactly as a dedicated MPI
/// progress thread would hide them.
fn comm_thread_runs() {
    let w = Workload::lenet3(4.0);
    let dims = vec![784usize, 32, 10];
    let mk = |p: usize, comm_thread: bool| {
        let mut cfg = RunConfig {
            model: "mlp".into(),
            algo: Algo::Agd,
            ranks: p,
            steps: 6,
            use_artifacts: false,
            rows_per_rank: 32,
            sample_shuffle: false,
            layerwise: true,
            comm_thread,
            ..Default::default()
        };
        cfg.virtualize(&w, 200e-6, 1.0 / 0.5e9);
        cfg
    };
    let run = |p: usize, comm_thread: bool| {
        let backend = Arc::new(NativeMlp::new(dims.clone(), 16, 0));
        run_with_backend(&mk(p, comm_thread), backend).expect("virtual run")
    };
    let cfg0 = mk(2, true);
    let standin = Workload::standin_mlp(
        cfg0.virt_fwd_secs,
        cfg0.virt_compute_secs - cfg0.virt_fwd_secs,
        &dims,
    );
    let mut t = Table::new(&[
        "ranks",
        "blocking step ms",
        "comm-thread step ms",
        "closed form ms",
        "blocking overlap %",
        "comm-thread overlap %",
    ]);
    for p in [64usize, 256, 1024] {
        let blocking = run(p, false);
        let ct = run(p, true);
        let analytic = overlapped_agd_step_time(
            Algorithm::RecursiveDoubling,
            &standin,
            p,
            &cfg0.cost_model(),
        );
        assert_eq!(
            blocking.final_params, ct.final_params,
            "p={p}: comm thread changed AGD numerics"
        );
        assert!(
            ct.mean_overlap_frac() > blocking.mean_overlap_frac(),
            "p={p}: comm-thread overlap {:.4} !> blocking {:.4}",
            ct.mean_overlap_frac(),
            blocking.mean_overlap_frac()
        );
        let got = ct.mean_step_secs();
        assert!(
            (got - analytic).abs() / analytic < 0.05,
            "p={p}: measured comm-thread AGD {got}s vs closed form {analytic}s"
        );
        t.row(&[
            p.to_string(),
            format!("{:.2}", 1e3 * blocking.mean_step_secs()),
            format!("{:.2}", 1e3 * got),
            format!("{:.2}", 1e3 * analytic),
            format!("{:.1}", 100.0 * blocking.mean_overlap_frac()),
            format!("{:.1}", 100.0 * ct.mean_overlap_frac()),
        ]);
    }
    t.print(
        "comm-thread AGD (non-blocking collective engine) vs blocking \
         chain vs closed-form overlapped-AGD, measured virtual fabric",
    );
    println!("  comm-thread AGD matches the closed form within 5% up to p = 1024");
}

fn main() {
    let (p100, knl) = sim_sweep("Fig 10 — MNIST/LeNet3", &Workload::lenet3);
    sim_sweep("Fig 11 — CIFAR10/CIFARNet", &Workload::cifarnet);
    real_runs();
    virtual_runs();
    comm_thread_runs();
    println!(
        "\nshape check @32: P100 speedup {p100:.2} > KNL speedup {knl:.2} > 1 (paper: ~1.9x MNIST/P100)"
    );
    assert!(p100 > knl && knl > 1.0);
}
