//! Table 7: compute efficiency (%) of GossipGraD vs PowerAI-style
//! hierarchical-ring all-reduce, ResNet50 @ batch 32/device, 4–128 P100s.
//!
//!     cargo bench --bench table7_efficiency
//!
//! Regenerates the table's rows from the discrete-event scale simulator
//! (calibrated to the paper's published per-step times; see
//! sim/workload.rs).  Expected shape: gossip pinned at ~100% everywhere;
//! ring-allreduce AGD slowly decaying to the mid-90s at 128 — matching
//! the paper's PowerAI column (100, 100, 98, 99, 97, 95).

use gossipgrad::collectives::Algorithm;
use gossipgrad::sim::{efficiency::avg_efficiency, Schedule, Workload};
use gossipgrad::transport::CostModel;
use gossipgrad::util::bench::Table;

fn main() {
    let w = Workload::resnet50_p100();
    let cost = CostModel::ib_edr(0);
    let ps = [4usize, 8, 16, 32, 64, 128];

    let mut t = Table::new(&[
        "p",
        "GossipGraD",
        "AGD ring (PowerAI-like)",
        "AGD rec-dbl",
        "SGD sync",
        "paper GossipGraD",
        "paper PowerAI",
    ]);
    let paper_gossip = [100, 100, 100, 100, 100, 100];
    let paper_powerai = [100, 100, 98, 99, 97, 95];
    for (i, &p) in ps.iter().enumerate() {
        let g = avg_efficiency(Schedule::Gossip, &w, p, &cost, 32);
        let ring = avg_efficiency(Schedule::Agd(Algorithm::Ring), &w, p, &cost, 32);
        let rd = avg_efficiency(
            Schedule::Agd(Algorithm::RecursiveDoubling),
            &w,
            p,
            &cost,
            32,
        );
        let sgd = avg_efficiency(
            Schedule::SgdSync(Algorithm::RecursiveDoubling),
            &w,
            p,
            &cost,
            32,
        );
        t.row(&[
            p.to_string(),
            format!("{:.1}", g.percent()),
            format!("{:.1}", ring.percent()),
            format!("{:.1}", rd.percent()),
            format!("{:.1}", sgd.percent()),
            paper_gossip[i].to_string(),
            paper_powerai[i].to_string(),
        ]);
    }
    t.print("Table 7 — compute efficiency (%), ResNet50, batch 32/device, IB-EDR model");

    let g128 = avg_efficiency(Schedule::Gossip, &w, 128, &cost, 32);
    println!(
        "\nheadline check: gossip @128 = {:.1}% (paper ~100%), {:.1} updates/s/device (paper 10.4)",
        g128.percent(),
        g128.updates_per_sec()
    );
    assert!(g128.percent() > 98.5, "gossip must stay ~100% at 128");
}
