//! Table 7: compute efficiency (%) of GossipGraD vs PowerAI-style
//! hierarchical-ring all-reduce, ResNet50 @ batch 32/device, 4–128 P100s.
//!
//!     cargo bench --bench table7_efficiency
//!
//! Regenerates the table's rows from the discrete-event scale simulator
//! (calibrated to the paper's published per-step times; see
//! sim/workload.rs).  Expected shape: gossip pinned at ~100% everywhere;
//! ring-allreduce AGD slowly decaying to the mid-90s at 128 — matching
//! the paper's PowerAI column (100, 100, 98, 99, 97, 95).

use gossipgrad::collectives::Algorithm;
use gossipgrad::config::{Algo, RunConfig};
use gossipgrad::coordinator::trainer::run_with_backend;
use gossipgrad::nativenet::NativeMlp;
use gossipgrad::sim::efficiency::{avg_efficiency, overlapped_agd_step_time};
use gossipgrad::sim::{Schedule, Workload};
use gossipgrad::transport::CostModel;
use gossipgrad::util::bench::Table;
use std::sync::Arc;

fn main() {
    let w = Workload::resnet50_p100();
    let cost = CostModel::ib_edr(0);
    let ps = [4usize, 8, 16, 32, 64, 128];

    let mut t = Table::new(&[
        "p",
        "GossipGraD",
        "AGD ring (PowerAI-like)",
        "AGD rec-dbl",
        "SGD sync",
        "paper GossipGraD",
        "paper PowerAI",
    ]);
    let paper_gossip = [100, 100, 100, 100, 100, 100];
    let paper_powerai = [100, 100, 98, 99, 97, 95];
    for (i, &p) in ps.iter().enumerate() {
        let g = avg_efficiency(Schedule::Gossip, &w, p, &cost, 32);
        let ring = avg_efficiency(Schedule::Agd(Algorithm::Ring), &w, p, &cost, 32);
        let rd = avg_efficiency(
            Schedule::Agd(Algorithm::RecursiveDoubling),
            &w,
            p,
            &cost,
            32,
        );
        let sgd = avg_efficiency(
            Schedule::SgdSync(Algorithm::RecursiveDoubling),
            &w,
            p,
            &cost,
            32,
        );
        t.row(&[
            p.to_string(),
            format!("{:.1}", g.percent()),
            format!("{:.1}", ring.percent()),
            format!("{:.1}", rd.percent()),
            format!("{:.1}", sgd.percent()),
            paper_gossip[i].to_string(),
            paper_powerai[i].to_string(),
        ]);
    }
    t.print("Table 7 — compute efficiency (%), ResNet50, batch 32/device, IB-EDR model");

    let g128 = avg_efficiency(Schedule::Gossip, &w, 128, &cost, 32);
    println!(
        "\nheadline check: gossip @128 = {:.1}% (paper ~100%), {:.1} updates/s/device (paper 10.4)",
        g128.percent(),
        g128.updates_per_sec()
    );
    assert!(g128.percent() > 98.5, "gossip must stay ~100% at 128");

    virtual_measured(&w);
}

/// Measured (not closed-form) efficiency on the virtual-clock fabric:
/// the real coordinator + transport running ResNet50's calibrated
/// compute window with the **layer-wise asynchronous pipeline** (each
/// layer's backprop slice charged individually, each layer's exchange
/// posted at its grad-ready instant), β scaled so the small native
/// stand-in model's messages cost what ResNet50's 100 MB would on
/// IB-EDR.  AGD is measured under both collective schedules: blocking
/// (dependency-chained rounds) and `comm_thread` (non-blocking engine,
/// rounds advancing at arrival instants under later backprop) — the
/// latter asserted against the closed-form overlapped-AGD curve.
/// Deterministic discrete-event timing makes the p = 1024 rows
/// seconds-long runs — and lets us assert they are bit-reproducible.
fn virtual_measured(w: &Workload) {
    // stand-in net: fc0 = 784x32+32 params dominates its message sizes
    let dims = vec![784usize, 32, 10];
    let standin_bytes = Workload::standin_mlp(0.0, 0.0, &dims).model_bytes();
    let beta = (w.model_bytes() as f64 / standin_bytes as f64) / 12.0e9;
    let mk_cfg = |algo: Algo, p: usize, comm_thread: bool| {
        let mut cfg = RunConfig {
            model: "mlp".into(),
            algo,
            ranks: p,
            steps: 6,
            use_artifacts: false,
            rows_per_rank: 32,
            sample_shuffle: false, // isolate gradient traffic
            layerwise: true,       // per-layer pipelined schedule
            comm_thread,
            ..Default::default()
        };
        cfg.virtualize(w, 1.0e-6, beta);
        cfg
    };
    let run = |algo: Algo, p: usize, comm_thread: bool| {
        let backend = Arc::new(NativeMlp::new(dims.clone(), 16, 0));
        run_with_backend(&mk_cfg(algo, p, comm_thread), backend)
            .expect("virtual run")
    };
    let mut t = Table::new(&[
        "p",
        "gossip eff % (measured)",
        "gossip overlap %",
        "AGD blocking eff %",
        "AGD blocking overlap %",
        "AGD comm-thread eff %",
        "AGD comm-thread overlap %",
        "overlapped-AGD closed form %",
    ]);
    // analytic twin of the measured comm-thread AGD: the stand-in's own
    // layer table (backprop order) under the same α–β and compute split
    let ct_cfg = mk_cfg(Algo::Agd, 2, true);
    let standin = Workload::standin_mlp(
        ct_cfg.virt_fwd_secs,
        ct_cfg.virt_compute_secs - ct_cfg.virt_fwd_secs,
        &dims,
    );
    let mut last = (0.0f64, 0.0f64, 0.0f64);
    for p in [16usize, 128, 1024] {
        let g = run(Algo::Gossip, p, false);
        let a = run(Algo::Agd, p, false);
        let ct = run(Algo::Agd, p, true);
        let analytic_step =
            overlapped_agd_step_time(Algorithm::RecursiveDoubling, &standin, p, &ct_cfg.cost_model());
        let analytic_eff = 100.0 * standin.t_compute() / analytic_step;
        if p == 1024 {
            // acceptance: the p = 1024 rows are bit-reproducible
            let g2 = run(Algo::Gossip, p, false);
            assert_eq!(g.final_params, g2.final_params, "p=1024 model bits");
            for (ma, mb) in g.per_rank.iter().zip(&g2.per_rank) {
                assert_eq!(ma.step_secs, mb.step_secs, "rank {}", ma.rank);
                assert_eq!(ma.recv_wait_secs, mb.recv_wait_secs);
                assert_eq!(ma.comm_hidden_secs, mb.comm_hidden_secs);
                assert_eq!(
                    ma.overlap_frac().to_bits(),
                    mb.overlap_frac().to_bits()
                );
            }
            let ct2 = run(Algo::Agd, p, true);
            assert_eq!(
                ct.final_params, ct2.final_params,
                "p=1024 comm-thread model bits"
            );
            for (ma, mb) in ct.per_rank.iter().zip(&ct2.per_rank) {
                assert_eq!(ma.step_secs, mb.step_secs, "rank {}", ma.rank);
                assert_eq!(ma.recv_wait_secs, mb.recv_wait_secs);
                assert_eq!(ma.comm_hidden_secs, mb.comm_hidden_secs);
            }
            // comm-thread numerics must equal the blocking schedule's
            assert_eq!(
                a.final_params, ct.final_params,
                "comm thread changed AGD numerics at p=1024"
            );
            // acceptance: overlap strictly above the blocking schedule
            assert!(
                ct.mean_overlap_frac() > a.mean_overlap_frac(),
                "p=1024 comm-thread overlap {:.4} !> blocking {:.4}",
                ct.mean_overlap_frac(),
                a.mean_overlap_frac()
            );
            // acceptance: measured comm-thread AGD matches the
            // closed-form overlapped-AGD curve within 5%
            let got = ct.mean_step_secs();
            assert!(
                (got - analytic_step).abs() / analytic_step < 0.05,
                "p=1024 measured comm-thread AGD {got}s vs closed form {analytic_step}s"
            );
            println!(
                "p=1024 rows verified bit-reproducible; comm-thread AGD \
                 within 5% of the closed-form overlapped-AGD curve"
            );
        }
        last = (
            g.mean_efficiency_pct(),
            a.mean_efficiency_pct(),
            ct.mean_efficiency_pct(),
        );
        t.row(&[
            p.to_string(),
            format!("{:.1}", g.mean_efficiency_pct()),
            format!("{:.1}", 100.0 * g.mean_overlap_frac()),
            format!("{:.1}", a.mean_efficiency_pct()),
            format!("{:.1}", 100.0 * a.mean_overlap_frac()),
            format!("{:.1}", ct.mean_efficiency_pct()),
            format!("{:.1}", 100.0 * ct.mean_overlap_frac()),
            format!("{analytic_eff:.1}"),
        ]);
    }
    t.print(
        "Table 7 shape, measured on the VIRTUAL-CLOCK fabric with the \
         layer-wise pipeline (ResNet50 compute window, byte-scaled wire \
         costs, per-layer grad_ready_times; AGD blocking vs comm-thread)",
    );
    assert!(
        last.0 > 97.0,
        "measured gossip efficiency at 1024 should stay ~100%, got {:.1}",
        last.0
    );
    assert!(
        last.0 > last.1,
        "gossip ({:.1}%) must beat blocking AGD ({:.1}%) at 1024",
        last.0,
        last.1
    );
    assert!(
        last.2 >= last.1,
        "comm-thread AGD ({:.1}%) must not lose to blocking AGD ({:.1}%)",
        last.2,
        last.1
    );
}
