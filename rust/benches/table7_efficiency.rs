//! Table 7: compute efficiency (%) of GossipGraD vs PowerAI-style
//! hierarchical-ring all-reduce, ResNet50 @ batch 32/device, 4–128 P100s.
//!
//!     cargo bench --bench table7_efficiency
//!
//! Regenerates the table's rows from the discrete-event scale simulator
//! (calibrated to the paper's published per-step times; see
//! sim/workload.rs).  Expected shape: gossip pinned at ~100% everywhere;
//! ring-allreduce AGD slowly decaying to the mid-90s at 128 — matching
//! the paper's PowerAI column (100, 100, 98, 99, 97, 95).

use gossipgrad::collectives::Algorithm;
use gossipgrad::config::{Algo, RunConfig};
use gossipgrad::coordinator::trainer::run_with_backend;
use gossipgrad::nativenet::NativeMlp;
use gossipgrad::sim::{efficiency::avg_efficiency, Schedule, Workload};
use gossipgrad::transport::CostModel;
use gossipgrad::util::bench::Table;
use std::sync::Arc;

fn main() {
    let w = Workload::resnet50_p100();
    let cost = CostModel::ib_edr(0);
    let ps = [4usize, 8, 16, 32, 64, 128];

    let mut t = Table::new(&[
        "p",
        "GossipGraD",
        "AGD ring (PowerAI-like)",
        "AGD rec-dbl",
        "SGD sync",
        "paper GossipGraD",
        "paper PowerAI",
    ]);
    let paper_gossip = [100, 100, 100, 100, 100, 100];
    let paper_powerai = [100, 100, 98, 99, 97, 95];
    for (i, &p) in ps.iter().enumerate() {
        let g = avg_efficiency(Schedule::Gossip, &w, p, &cost, 32);
        let ring = avg_efficiency(Schedule::Agd(Algorithm::Ring), &w, p, &cost, 32);
        let rd = avg_efficiency(
            Schedule::Agd(Algorithm::RecursiveDoubling),
            &w,
            p,
            &cost,
            32,
        );
        let sgd = avg_efficiency(
            Schedule::SgdSync(Algorithm::RecursiveDoubling),
            &w,
            p,
            &cost,
            32,
        );
        t.row(&[
            p.to_string(),
            format!("{:.1}", g.percent()),
            format!("{:.1}", ring.percent()),
            format!("{:.1}", rd.percent()),
            format!("{:.1}", sgd.percent()),
            paper_gossip[i].to_string(),
            paper_powerai[i].to_string(),
        ]);
    }
    t.print("Table 7 — compute efficiency (%), ResNet50, batch 32/device, IB-EDR model");

    let g128 = avg_efficiency(Schedule::Gossip, &w, 128, &cost, 32);
    println!(
        "\nheadline check: gossip @128 = {:.1}% (paper ~100%), {:.1} updates/s/device (paper 10.4)",
        g128.percent(),
        g128.updates_per_sec()
    );
    assert!(g128.percent() > 98.5, "gossip must stay ~100% at 128");

    virtual_measured(&w);
}

/// Measured (not closed-form) efficiency on the virtual-clock fabric:
/// the real coordinator + transport running ResNet50's calibrated
/// compute window with the **layer-wise asynchronous pipeline** (each
/// layer's backprop slice charged individually, each layer's exchange
/// posted at its grad-ready instant), β scaled so the small native
/// stand-in model's messages cost what ResNet50's 100 MB would on
/// IB-EDR.  Deterministic discrete-event timing makes the p = 1024 row
/// a seconds-long run — and lets us assert it is bit-reproducible.
fn virtual_measured(w: &Workload) {
    // stand-in net: fc0 = 784x32+32 params dominates its message sizes
    let dims = vec![784usize, 32, 10];
    let standin_bytes: usize =
        (0..dims.len() - 1).map(|i| (dims[i] * dims[i + 1] + dims[i + 1]) * 4).sum();
    let beta = (w.model_bytes() as f64 / standin_bytes as f64) / 12.0e9;
    let run = |algo: Algo, p: usize| {
        let mut cfg = RunConfig {
            model: "mlp".into(),
            algo,
            ranks: p,
            steps: 6,
            use_artifacts: false,
            rows_per_rank: 32,
            sample_shuffle: false, // isolate gradient traffic
            layerwise: true,       // per-layer pipelined schedule
            ..Default::default()
        };
        cfg.virtualize(w, 1.0e-6, beta);
        let backend = Arc::new(NativeMlp::new(dims.clone(), 16, 0));
        run_with_backend(&cfg, backend).expect("virtual run")
    };
    let mut t = Table::new(&[
        "p",
        "gossip eff % (measured)",
        "gossip overlap %",
        "AGD rec-dbl eff % (measured)",
        "AGD overlap %",
    ]);
    let mut last = (0.0f64, 0.0f64);
    for p in [16usize, 128, 1024] {
        let g = run(Algo::Gossip, p);
        let a = run(Algo::Agd, p);
        if p == 1024 {
            // acceptance: the p = 1024 layer-wise row is bit-reproducible
            let g2 = run(Algo::Gossip, p);
            assert_eq!(g.final_params, g2.final_params, "p=1024 model bits");
            for (ma, mb) in g.per_rank.iter().zip(&g2.per_rank) {
                assert_eq!(ma.step_secs, mb.step_secs, "rank {}", ma.rank);
                assert_eq!(ma.recv_wait_secs, mb.recv_wait_secs);
                assert_eq!(ma.comm_hidden_secs, mb.comm_hidden_secs);
                assert_eq!(
                    ma.overlap_frac().to_bits(),
                    mb.overlap_frac().to_bits()
                );
            }
            println!("p=1024 layer-wise row verified bit-reproducible across two runs");
        }
        last = (g.mean_efficiency_pct(), a.mean_efficiency_pct());
        t.row(&[
            p.to_string(),
            format!("{:.1}", g.mean_efficiency_pct()),
            format!("{:.1}", 100.0 * g.mean_overlap_frac()),
            format!("{:.1}", a.mean_efficiency_pct()),
            format!("{:.1}", 100.0 * a.mean_overlap_frac()),
        ]);
    }
    t.print(
        "Table 7 shape, measured on the VIRTUAL-CLOCK fabric with the \
         layer-wise pipeline (ResNet50 compute window, byte-scaled wire \
         costs, per-layer grad_ready_times)",
    );
    assert!(
        last.0 > 97.0,
        "measured gossip efficiency at 1024 should stay ~100%, got {:.1}",
        last.0
    );
    assert!(
        last.0 > last.1,
        "gossip ({:.1}%) must beat blocking AGD ({:.1}%) at 1024",
        last.0,
        last.1
    );
}
