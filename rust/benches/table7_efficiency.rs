//! Table 7: compute efficiency (%) of GossipGraD vs PowerAI-style
//! hierarchical-ring all-reduce, ResNet50 @ batch 32/device, 4–128 P100s.
//!
//!     cargo bench --bench table7_efficiency
//!
//! Regenerates the table's rows from the discrete-event scale simulator
//! (calibrated to the paper's published per-step times; see
//! sim/workload.rs).  Expected shape: gossip pinned at ~100% everywhere;
//! ring-allreduce AGD slowly decaying to the mid-90s at 128 — matching
//! the paper's PowerAI column (100, 100, 98, 99, 97, 95).
//!
//! The measured section runs on the experiment engine: one declared
//! `algo × p × comm_thread` grid replaces the hand-rolled per-point
//! config/backend plumbing, and the p = 1024 bit-reproducibility check
//! is a whole-sweep artifact diff (two engine runs must serialize
//! byte-identically).

use gossipgrad::collectives::Algorithm;
use gossipgrad::config::{Algo, RunConfig};
use gossipgrad::exp::{Engine, Grid};
use gossipgrad::sim::efficiency::{avg_efficiency, overlapped_agd_step_time};
use gossipgrad::sim::{Schedule, Workload};
use gossipgrad::transport::CostModel;
use gossipgrad::util::bench::Table;

fn main() {
    let w = Workload::resnet50_p100();
    let cost = CostModel::ib_edr(0);
    let ps = [4usize, 8, 16, 32, 64, 128];

    let mut t = Table::new(&[
        "p",
        "GossipGraD",
        "AGD ring (PowerAI-like)",
        "AGD rec-dbl",
        "SGD sync",
        "paper GossipGraD",
        "paper PowerAI",
    ]);
    let paper_gossip = [100, 100, 100, 100, 100, 100];
    let paper_powerai = [100, 100, 98, 99, 97, 95];
    for (i, &p) in ps.iter().enumerate() {
        let g = avg_efficiency(Schedule::Gossip, &w, p, &cost, 32);
        let ring = avg_efficiency(Schedule::Agd(Algorithm::Ring), &w, p, &cost, 32);
        let rd = avg_efficiency(
            Schedule::Agd(Algorithm::RecursiveDoubling),
            &w,
            p,
            &cost,
            32,
        );
        let sgd = avg_efficiency(
            Schedule::SgdSync(Algorithm::RecursiveDoubling),
            &w,
            p,
            &cost,
            32,
        );
        t.row(&[
            p.to_string(),
            format!("{:.1}", g.percent()),
            format!("{:.1}", ring.percent()),
            format!("{:.1}", rd.percent()),
            format!("{:.1}", sgd.percent()),
            paper_gossip[i].to_string(),
            paper_powerai[i].to_string(),
        ]);
    }
    t.print("Table 7 — compute efficiency (%), ResNet50, batch 32/device, IB-EDR model");

    let g128 = avg_efficiency(Schedule::Gossip, &w, 128, &cost, 32);
    println!(
        "\nheadline check: gossip @128 = {:.1}% (paper ~100%), {:.1} updates/s/device (paper 10.4)",
        g128.percent(),
        g128.updates_per_sec()
    );
    assert!(g128.percent() > 98.5, "gossip must stay ~100% at 128");

    virtual_measured(&w);
}

/// Measured (not closed-form) efficiency on the virtual-clock fabric:
/// the real coordinator + transport running ResNet50's calibrated
/// compute window with the **layer-wise asynchronous pipeline**, β
/// scaled so the small native stand-in model's messages cost what
/// ResNet50's 100 MB would on IB-EDR.  AGD is measured under both
/// collective schedules — blocking (dependency-chained rounds) and
/// `comm_thread` (non-blocking engine, rounds advancing at arrival
/// instants under later backprop) — the latter asserted against the
/// closed-form overlapped-AGD curve.  Deterministic discrete-event
/// timing makes the p = 1024 rows seconds-long runs — and lets us
/// assert the whole sweep is bit-reproducible by diffing two engine
/// runs' serialized artifacts.
fn virtual_measured(w: &Workload) {
    // stand-in net: fc0 = 784x32+32 params dominates its message sizes
    let dims = [784usize, 32, 10]; // = the mlp-small backend's stack
    let standin_bytes = Workload::standin_mlp(0.0, 0.0, &dims).model_bytes();
    let beta = (w.model_bytes() as f64 / standin_bytes as f64) / 12.0e9;
    let mut base = RunConfig {
        model: "mlp-small".into(),
        algo: Algo::Gossip,
        steps: 6,
        use_artifacts: false,
        rows_per_rank: 32,
        sample_shuffle: false, // isolate gradient traffic
        layerwise: true,       // per-layer pipelined schedule
        ..Default::default()
    };
    base.virtualize(w, 1.0e-6, beta);
    // analytic twin of the measured comm-thread AGD: the stand-in's own
    // layer table (backprop order) under the same α–β and compute split
    let standin = Workload::standin_mlp(
        base.virt_fwd_secs,
        base.virt_compute_secs - base.virt_fwd_secs,
        &dims,
    );
    let cost = base.cost_model();
    let ranks = [16usize, 128, 1024];
    // Gossip never uses a comm thread, AGD is measured both ways: the
    // grid drops nothing (comm_thread needs layerwise, which is on),
    // but a gossip × comm_thread point would silently measure the same
    // schedule twice — declare the axes per algo instead.
    let grid_gossip = Grid::new(base.clone())
        .algos(&[Algo::Gossip])
        .ranks(&ranks);
    let mut agd_base = base.clone();
    agd_base.algo = Algo::Agd;
    let grid_agd = Grid::new(agd_base)
        .ranks(&ranks)
        .comm_threads(&[false, true]);
    let engine = Engine::default();
    let gossip = engine.run(&grid_gossip).expect("gossip grid");
    let agd = engine.run(&grid_agd).expect("agd grid");

    // acceptance: the whole measured sweep (p = 1024 rows included) is
    // bit-reproducible — a second pass on a *fresh* engine (so its
    // in-memory memo can't short-circuit the re-run) serializes
    // byte-identically
    let engine2 = Engine::default();
    let gossip2 = engine2.run(&grid_gossip).expect("gossip grid, 2nd pass");
    assert_eq!(
        gossip.to_json().to_string(),
        gossip2.to_json().to_string(),
        "gossip sweep must be bit-reproducible"
    );
    let agd2 = engine2.run(&grid_agd).expect("agd grid, 2nd pass");
    assert_eq!(
        agd.to_json().to_string(),
        agd2.to_json().to_string(),
        "AGD sweep must be bit-reproducible"
    );
    println!("p=16/128/1024 sweeps verified bit-reproducible (artifact diff)");

    let mut t = Table::new(&[
        "p",
        "gossip eff % (measured)",
        "gossip overlap %",
        "AGD blocking eff %",
        "AGD blocking overlap %",
        "AGD comm-thread eff %",
        "AGD comm-thread overlap %",
        "overlapped-AGD closed form %",
    ]);
    let mut last = (0.0f64, 0.0f64, 0.0f64);
    for &p in &ranks {
        let g = gossip.get("gossip", |c| c.ranks == p);
        let a = agd.get("blocking agd", |c| c.ranks == p && !c.comm_thread);
        let ct = agd.get("comm-thread agd", |c| c.ranks == p && c.comm_thread);
        let analytic_step =
            overlapped_agd_step_time(Algorithm::RecursiveDoubling, &standin, p, &cost);
        let analytic_eff = 100.0 * standin.t_compute() / analytic_step;
        // comm-thread numerics must equal the blocking schedule's
        assert_eq!(
            a.param_hash, ct.param_hash,
            "p={p}: comm thread changed AGD numerics"
        );
        if p == 1024 {
            // acceptance: overlap strictly above the blocking schedule
            assert!(
                ct.mean_overlap_frac > a.mean_overlap_frac,
                "p=1024 comm-thread overlap {:.4} !> blocking {:.4}",
                ct.mean_overlap_frac,
                a.mean_overlap_frac
            );
            // acceptance: measured comm-thread AGD matches the
            // closed-form overlapped-AGD curve within 5%
            let got = ct.mean_step_secs;
            assert!(
                (got - analytic_step).abs() / analytic_step < 0.05,
                "p=1024 measured comm-thread AGD {got}s vs closed form {analytic_step}s"
            );
            println!(
                "p=1024 comm-thread AGD within 5% of the closed-form \
                 overlapped-AGD curve"
            );
        }
        last = (
            g.mean_efficiency_pct,
            a.mean_efficiency_pct,
            ct.mean_efficiency_pct,
        );
        t.row(&[
            p.to_string(),
            format!("{:.1}", g.mean_efficiency_pct),
            format!("{:.1}", 100.0 * g.mean_overlap_frac),
            format!("{:.1}", a.mean_efficiency_pct),
            format!("{:.1}", 100.0 * a.mean_overlap_frac),
            format!("{:.1}", ct.mean_efficiency_pct),
            format!("{:.1}", 100.0 * ct.mean_overlap_frac),
            format!("{analytic_eff:.1}"),
        ]);
    }
    t.print(
        "Table 7 shape, measured on the VIRTUAL-CLOCK fabric with the \
         layer-wise pipeline (ResNet50 compute window, byte-scaled wire \
         costs, per-layer grad_ready_times; AGD blocking vs comm-thread; \
         experiment engine)",
    );
    assert!(
        last.0 > 97.0,
        "measured gossip efficiency at 1024 should stay ~100%, got {:.1}",
        last.0
    );
    assert!(
        last.0 > last.1,
        "gossip ({:.1}%) must beat blocking AGD ({:.1}%) at 1024",
        last.0,
        last.1
    );
    assert!(
        last.2 >= last.1,
        "comm-thread AGD ({:.1}%) must not lose to blocking AGD ({:.1}%)",
        last.2,
        last.1
    );
}
