//! L3 hot-path microbenchmarks + the AOT-vs-native mixing ablation.
//!
//!     cargo bench --bench hotpath
//!
//! Covers every per-step cost the coordinator adds on top of compute:
//! * gossip mixing (native SIMD loop vs the Pallas AOT artifact),
//! * fused momentum-SGD update,
//! * model slicing + transport round-trip,
//! * partner-selection (topology) lookups.
//!
//! §Perf targets: mixing at memory bandwidth (GB/s printed below);
//! coordinator overhead per step ≪ model compute time.

use gossipgrad::nativenet::ops;
use gossipgrad::topology::{Dissemination, Rotation, Topology};
use gossipgrad::transport::{CostModel, Fabric, Tag};
use gossipgrad::util::bench::{bench, Table};
use gossipgrad::util::Rng;

fn main() {
    let n = 5_018_112; // transformer param count
    let mut rng = Rng::new(1);
    let mut a: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
    let b: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
    let g: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
    let mut mom = vec![0.0f32; n];

    // --- mixing: native ------------------------------------------------
    let s = bench("mix_into native (5M params)", 3, 20, || {
        ops::mix_into(&mut a, &b);
    });
    let gbs = (n as f64 * 4.0 * 3.0) / s.median() / 1e9; // 2R + 1W
    println!("  -> {gbs:.1} GB/s effective (2R+1W)");

    // --- mixing: Pallas AOT artifact (ablation) ------------------------
    if std::path::Path::new("artifacts/mlp.meta.json").exists() {
        let m = gossipgrad::runtime::PjrtModel::load(
            std::path::Path::new("artifacts"),
            "mlp",
        )
        .expect("load mlp artifacts");
        let nn = m.meta().param_count;
        let aa = vec![1.0f32; nn];
        let bb = vec![2.0f32; nn];
        let sp = bench("mix via Pallas AOT artifact (536k params)", 2, 10, || {
            let _ = m.mix(&aa, &bb).unwrap();
        });
        let mut an = vec![1.0f32; nn];
        let sn = bench("mix_into native        (536k params)", 2, 10, || {
            ops::mix_into(&mut an, &bb);
        });
        println!(
            "  -> ablation: AOT mix {:.1}x native (host<->device copies dominate; native wins on CPU)",
            sp.median() / sn.median()
        );
    } else {
        println!("(skipping AOT mix ablation: run `make artifacts`)");
    }

    // --- fused momentum update -----------------------------------------
    let s = bench("sgd_momentum fused (5M params)", 3, 20, || {
        ops::sgd_momentum(&mut a, &mut mom, &g, 1e-4, 0.9);
    });
    let gbs = (n as f64 * 4.0 * 5.0) / s.median() / 1e9; // 3R + 2W
    println!("  -> {gbs:.1} GB/s effective (3R+2W)");

    // --- transport round trip -------------------------------------------
    let fabric = Fabric::new(2, CostModel::zero());
    let e0 = fabric.endpoint(0);
    let e1 = fabric.endpoint(1);
    let payload: Vec<f32> = vec![0.0; 1 << 20];
    bench("transport send+recv 4 MiB", 3, 50, || {
        e0.isend(1, Tag::MODEL, payload.clone());
        let _ = e1.recv(0, Tag::MODEL);
    });

    // --- partner selection ------------------------------------------------
    let topo = Rotation::new(Dissemination::new(128), 7);
    let mut acc = 0usize;
    bench("rotated dissemination exchange() x1e5", 2, 20, || {
        for s in 0..100_000usize {
            acc ^= topo.exchange(s & 127, s).send_to;
        }
    });
    std::hint::black_box(acc);

    // --- per-step coordinator overhead summary ---------------------------
    let mut t = Table::new(&["component", "per gossip step (5M model)", "notes"]);
    t.row(&[
        "mix".into(),
        "see above".into(),
        "1x per step".into(),
    ]);
    t.row(&[
        "update".into(),
        "see above".into(),
        "1x per step".into(),
    ]);
    t.row(&[
        "partner lookup".into(),
        "~ns".into(),
        "negligible".into(),
    ]);
    t.print("coordinator overhead inventory");
}
