//! L3 hot-path microbenchmarks + the AOT-vs-native mixing ablation.
//!
//!     cargo bench --bench hotpath
//!     cargo bench --bench hotpath -- --json [BENCH_hotpath.json]
//!
//! Covers every per-step cost the coordinator adds on top of compute:
//! * gossip mixing (native chunked kernel vs the Pallas AOT artifact),
//! * fused momentum-SGD update,
//! * model slicing + transport round-trip (fresh-alloc vs pooled),
//! * partner-selection (topology) lookups.
//!
//! `--json` emits `BENCH_hotpath.json` (or the given path) for the CI
//! regression gate: `tools/bench_diff.py` hard-fails on `allocs` and
//! `gbs` regressions against the committed repo-root baseline and
//! treats timings as advisory (docs/perf.md).
//!
//! §Perf targets: mixing at memory bandwidth (GB/s printed below);
//! coordinator overhead per step ≪ model compute time; steady-state
//! pooled transport at ZERO payload allocations per message.

use gossipgrad::nativenet::ops;
use gossipgrad::topology::{Dissemination, Rotation, Topology};
use gossipgrad::transport::{CostModel, Fabric, Tag};
use gossipgrad::util::bench::{bench, json_out_path, BenchReport, Table};
use gossipgrad::util::Rng;

fn main() {
    let mut report = BenchReport::new("hotpath");
    let n = 5_018_112; // transformer param count
    let mut rng = Rng::new(1);
    let mut a: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
    let b: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
    let g: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
    let mut mom = vec![0.0f32; n];

    // --- mixing: native ------------------------------------------------
    let s = bench("mix_into native (5M params)", 3, 20, || {
        ops::mix_into(&mut a, &b);
    });
    let gbs = (n as f64 * 4.0 * 3.0) / s.median() / 1e9; // 2R + 1W
    println!("  -> {gbs:.1} GB/s effective (2R+1W)");
    report.entry("mix_into_5m", &[("gbs", gbs), ("median_secs", s.median())]);

    // --- mixing: Pallas AOT artifact (ablation) ------------------------
    // (kept out of the JSON report: the artifact dir is optional, and
    // the gate treats missing baseline entries as failures)
    if std::path::Path::new("artifacts/mlp.meta.json").exists() {
        let m = gossipgrad::runtime::PjrtModel::load(
            std::path::Path::new("artifacts"),
            "mlp",
        )
        .expect("load mlp artifacts");
        let nn = m.meta().param_count;
        let aa = vec![1.0f32; nn];
        let bb = vec![2.0f32; nn];
        let sp = bench("mix via Pallas AOT artifact (536k params)", 2, 10, || {
            let _ = m.mix(&aa, &bb).unwrap();
        });
        let mut an = vec![1.0f32; nn];
        let sn = bench("mix_into native        (536k params)", 2, 10, || {
            ops::mix_into(&mut an, &bb);
        });
        println!(
            "  -> ablation: AOT mix {:.1}x native (host<->device copies dominate; native wins on CPU)",
            sp.median() / sn.median()
        );
    } else {
        println!("(skipping AOT mix ablation: run `make artifacts`)");
    }

    // --- fused momentum update -----------------------------------------
    let s = bench("sgd_momentum fused (5M params)", 3, 20, || {
        ops::sgd_momentum(&mut a, &mut mom, &g, 1e-4, 0.9);
    });
    let gbs = (n as f64 * 4.0 * 5.0) / s.median() / 1e9; // 3R + 2W
    println!("  -> {gbs:.1} GB/s effective (3R+2W)");
    report.entry(
        "sgd_momentum_5m",
        &[("gbs", gbs), ("median_secs", s.median())],
    );

    // --- transport round trip: fresh allocation per message -------------
    let fabric = Fabric::new(2, CostModel::zero());
    let e0 = fabric.endpoint(0);
    let e1 = fabric.endpoint(1);
    let payload: Vec<f32> = vec![0.0; 1 << 20];
    let s = bench("transport send+recv 4 MiB (fresh alloc)", 3, 50, || {
        e0.isend(1, Tag::MODEL, payload.clone());
        let _ = e1.recv(0, Tag::MODEL);
    });
    report.entry("transport_4mib_fresh", &[("median_secs", s.median())]);

    // --- transport round trip: pooled (the steady-state training path) --
    // Single-threaded, so the pool's allocation counter is exact: after
    // warm-up every payload draw must hit a recycled buffer — the
    // zero-allocation invariant the CI gate pins (allocs must stay 0).
    let pool = e0.pool();
    for _ in 0..4 {
        e0.isend(1, Tag::MODEL, pool.copy_f32(&payload));
        pool.put_f32(e1.recv(0, Tag::MODEL));
    }
    let before = pool.stats();
    let s = bench("transport send+recv 4 MiB (pooled)", 0, 50, || {
        e0.isend(1, Tag::MODEL, pool.copy_f32(&payload));
        pool.put_f32(e1.recv(0, Tag::MODEL));
    });
    let allocs = (pool.stats().allocs - before.allocs) as f64;
    let gbs = (payload.len() as f64 * 4.0) / s.median() / 1e9;
    println!("  -> {gbs:.1} GB/s wire, {allocs} pool allocs over 50 round trips");
    report.entry(
        "transport_4mib_pooled",
        &[("gbs", gbs), ("allocs", allocs), ("median_secs", s.median())],
    );

    // --- cost model: deterministic charge path --------------------------
    // noise_frac = 0 carries no RNG at all, so the per-message charge —
    // taken once per send on the virtual clock's hot path — is pure
    // arithmetic.  The noisy twin pays a Mutex lock per call; the gap is
    // the satellite-1 before/after line in BENCH_hotpath.json.
    let det = CostModel::new(1.0e-6, 1.0 / 12.0e9, 0.0, 0);
    let noisy = CostModel::ib_edr(7);
    let mut acc_t = 0.0f64;
    let s_det = bench("cost_model message_time x1e6 (deterministic)", 2, 20, || {
        for b in 0..1_000_000usize {
            acc_t += det.message_time(b & 0xffff);
        }
    });
    let s_noisy = bench("cost_model message_time x1e6 (5% noise, rng lock)", 2, 20, || {
        for b in 0..1_000_000usize {
            acc_t += noisy.message_time(b & 0xffff);
        }
    });
    std::hint::black_box(acc_t);
    println!(
        "  -> lock-free deterministic path is {:.1}x faster than the noisy (mutex) path",
        s_noisy.median() / s_det.median()
    );
    report.entry(
        "cost_model_message_time_det_1e6",
        &[("median_secs", s_det.median())],
    );

    // --- partner selection ------------------------------------------------
    let topo = Rotation::new(Dissemination::new(128), 7);
    let mut acc = 0usize;
    let s = bench("rotated dissemination exchange() x1e5", 2, 20, || {
        for s in 0..100_000usize {
            acc ^= topo.exchange(s & 127, s).send_to;
        }
    });
    std::hint::black_box(acc);
    report.entry("partner_lookup_1e5", &[("median_secs", s.median())]);

    // --- per-step coordinator overhead summary ---------------------------
    let mut t = Table::new(&["component", "per gossip step (5M model)", "notes"]);
    t.row(&[
        "mix".into(),
        "see above".into(),
        "1x per step".into(),
    ]);
    t.row(&[
        "update".into(),
        "see above".into(),
        "1x per step".into(),
    ]);
    t.row(&[
        "payload buffers".into(),
        "0 allocs".into(),
        "pooled after warm-up".into(),
    ]);
    t.row(&[
        "partner lookup".into(),
        "~ns".into(),
        "negligible".into(),
    ]);
    t.print("coordinator overhead inventory");

    if let Some(path) = json_out_path("BENCH_hotpath.json") {
        report.write(&path).expect("write bench json");
    }
}
