//! Rank-scheduler scaling bench: wall time and peak OS thread count for
//! p ∈ {256, 1024} virtual-clock gossip scenarios, cooperative scheduler
//! vs the legacy thread-per-rank oracle, plus a 4-point mini-sweep on
//! the experiment engine.
//!
//!     cargo bench --bench sweep_scale
//!     cargo bench --bench sweep_scale -- --json [BENCH_sweep_scale.json]
//!
//! `--json` emits `BENCH_sweep_scale.json` for the CI regression gate
//! (`tools/bench_diff.py`, docs/perf.md): `threads` and `allocs` are
//! hard gates, timings advisory.  The committed baseline pins the
//! headline claims of the scheduler change:
//!
//! * peak thread count under the scheduler is bounded by `sim_threads +
//!   O(1)` (here 4 workers → baseline ceiling 16) while the legacy path
//!   peaks at ~p threads (baselines 300 / 1100) — the order-of-magnitude
//!   drop;
//! * p = 1024 wall time under the scheduler is ≥ 2x faster than
//!   thread-per-rank (committed `median_secs`, advisory);
//! * two identical `--sim-threads 1` runs see an identical pool
//!   allocation count (`alloc_determinism_p256.allocs` = 0, hard gate).
//!
//! The sched arms pin `sim_threads = 4` so the thread gate means the
//! same thing on any host; `--sim-threads 0` (default = cores) is
//! exercised by `tests/scheduler.rs` instead.

use gossipgrad::codec::Codec;
use gossipgrad::config::RunConfig;
use gossipgrad::coordinator;
use gossipgrad::exp::{Engine, Grid, ScenarioReport};
use gossipgrad::sim::Workload;
use gossipgrad::util::bench::{json_out_path, BenchReport};

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// The swept scenario: layer-wise gossip on the virtual-clock fabric,
/// LeNet3 compute model on a slow (α = 200 µs, β = 0.5 GB/s) wire so
/// communication actually matters.  `sim_threads` is pinned at 4 so the
/// committed thread baseline is host-independent.
fn scenario(p: usize, legacy: bool) -> RunConfig {
    let mut cfg = RunConfig {
        model: "mlp-small".into(),
        ranks: p,
        steps: 8,
        use_artifacts: false,
        rows_per_rank: 32,
        layerwise: true,
        seed: 7,
        sim_threads: 4,
        legacy_ranks: legacy,
        ..Default::default()
    };
    cfg.virtualize(&Workload::lenet3(4.0), 200e-6, 1.0 / 0.5e9);
    cfg
}

/// Current OS thread count of this process (`Threads:` from
/// /proc/self/status).  Returns 1 where procfs is unavailable — the
/// thread gate only binds on the Linux CI runner.
fn os_threads() -> usize {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|s| {
            s.lines()
                .find_map(|l| l.strip_prefix("Threads:").and_then(|v| v.trim().parse().ok()))
        })
        .unwrap_or(1)
}

struct Run {
    secs: f64,
    peak_threads: usize,
    allocs: u64,
    report: ScenarioReport,
}

/// Execute one scenario while a monitor thread samples the process
/// thread count; asserts the fabric drained clean.
fn timed_run(cfg: &RunConfig) -> Run {
    let stop = Arc::new(AtomicBool::new(false));
    let peak = Arc::new(AtomicUsize::new(os_threads()));
    let monitor = {
        let (stop, peak) = (Arc::clone(&stop), Arc::clone(&peak));
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                peak.fetch_max(os_threads(), Ordering::Relaxed);
                std::thread::sleep(Duration::from_millis(1));
            }
        })
    };
    let t0 = Instant::now();
    let res = coordinator::run(cfg).expect("scenario run");
    let secs = t0.elapsed().as_secs_f64();
    stop.store(true, Ordering::Relaxed);
    monitor.join().expect("monitor thread");
    assert_eq!(res.in_flight_msgs, 0, "fabric not drained (msgs)");
    assert_eq!(res.in_flight_bytes, 0, "fabric not drained (bytes)");
    Run {
        secs,
        peak_threads: peak.load(Ordering::Relaxed),
        allocs: res.pool_stats.allocs,
        report: ScenarioReport::from_run(cfg, &res),
    }
}

/// Best-of-two wall time, worst-of-two thread peak.
fn arm(cfg: &RunConfig) -> Run {
    let a = timed_run(cfg);
    let b = timed_run(cfg);
    Run {
        secs: a.secs.min(b.secs),
        peak_threads: a.peak_threads.max(b.peak_threads),
        allocs: a.allocs,
        report: b.report,
    }
}

fn main() {
    let mut report = BenchReport::new("sweep_scale");

    // --- scheduler vs thread-per-rank, p = 256 and 1024 -----------------
    let mut speedup_1024 = 0.0;
    for p in [256usize, 1024] {
        let sched = arm(&scenario(p, false));
        let legacy = arm(&scenario(p, true));
        assert_eq!(
            sched.report.param_hash, legacy.report.param_hash,
            "p={p}: scheduler changed the numerics"
        );
        println!(
            "gossip p={p}: sched {:.2}s / {} threads  vs  legacy {:.2}s / {} threads  ({:.2}x)",
            sched.secs,
            sched.peak_threads,
            legacy.secs,
            legacy.peak_threads,
            legacy.secs / sched.secs
        );
        if p == 1024 {
            speedup_1024 = legacy.secs / sched.secs;
        }
        report.entry(
            &format!("gossip_p{p}_sched"),
            &[("median_secs", sched.secs), ("threads", sched.peak_threads as f64)],
        );
        report.entry(
            &format!("gossip_p{p}_legacy"),
            &[("median_secs", legacy.secs), ("threads", legacy.peak_threads as f64)],
        );
    }
    println!("  -> p=1024 scheduler speedup over thread-per-rank: {speedup_1024:.2}x");

    // --- determinism: identical 1-worker runs, identical allocations ----
    let mut det = scenario(256, false);
    det.sim_threads = 1;
    let a = timed_run(&det);
    let b = timed_run(&det);
    assert_eq!(a.report.param_hash, b.report.param_hash, "repeat run diverged");
    let delta = a.allocs.abs_diff(b.allocs) as f64;
    println!("  -> alloc determinism @ sim-threads 1: |Δallocs| = {delta}");
    report.entry("alloc_determinism_p256", &[("allocs", delta)]);

    // --- 4-point mini-sweep through the experiment engine ----------------
    // Two engine threads × scheduled scenarios: the global execution
    // budget keeps the product bounded (docs/perf.md).
    let grid = Grid::new(scenario(64, false))
        .gossip_periods(&[1, 2])
        .codecs(&[Codec::F32, Codec::Bf16]);
    let t0 = Instant::now();
    let sweep = Engine::with_threads(2).run(&grid).expect("mini sweep");
    let secs = t0.elapsed().as_secs_f64();
    assert_eq!(sweep.reports.len(), 4, "mini-sweep grid shape");
    println!("  -> 4-point mini-sweep (period x codec, 2 engine threads): {secs:.2}s");
    report.entry("mini_sweep_4pt", &[("median_secs", secs)]);

    if let Some(path) = json_out_path("BENCH_sweep_scale.json") {
        report.write(&path).expect("write bench json");
    }
}
