//! Figure 17 (performance panel): GossipGraD vs "AGD every log(p)
//! iterations" on LeNet3.  Amortizing the all-reduce over log(p) steps
//! narrows the throughput gap, but gossip stays ahead — and (see
//! examples/fig17_learning.rs for the accuracy panel) keeps learning
//! where the periodic baseline is hyperparameter-fragile.
//!
//!     cargo bench --bench fig17_periodic
//!
//! The measured section runs on the experiment engine (`exp::Grid` +
//! `exp::Engine`): the algo axis is declared once and the engine owns
//! fabric/dataset/backend setup.  A second grid mechanizes the figure's
//! *trade-off* as a gossip-period autotune: largest period within 2% of
//! peak throughput whose consensus still shrinks vs the no-mixing
//! reference.

use gossipgrad::collectives::Algorithm;
use gossipgrad::config::{Algo, RunConfig};
use gossipgrad::exp::{autotune, Engine, Grid};
use gossipgrad::sim::{efficiency::avg_efficiency, Schedule, Workload};
use gossipgrad::transport::CostModel;
use gossipgrad::util::bench::Table;

fn main() {
    // --- simulated sweep (the figure's x-axis goes to 32) ------------
    let w = Workload::lenet3(4.0);
    let cost = CostModel::ib_edr(0);
    let mut t = Table::new(&[
        "p",
        "gossip batches/s",
        "periodic-AGD batches/s",
        "AGD batches/s",
    ]);
    let mut at32 = (0.0, 0.0);
    for p in [2usize, 4, 8, 16, 32] {
        let g = avg_efficiency(Schedule::Gossip, &w, p, &cost, 64);
        let per = avg_efficiency(
            Schedule::PeriodicAgd(Algorithm::RecursiveDoubling),
            &w,
            p,
            &cost,
            64,
        );
        let agd = avg_efficiency(
            Schedule::Agd(Algorithm::RecursiveDoubling),
            &w,
            p,
            &cost,
            64,
        );
        at32 = (g.updates_per_sec(), per.updates_per_sec());
        t.row(&[
            p.to_string(),
            format!("{:.1}", g.updates_per_sec()),
            format!("{:.1}", per.updates_per_sec()),
            format!("{:.1}", agd.updates_per_sec()),
        ]);
    }
    t.print("Fig 17 — throughput: gossip vs periodic-AGD vs AGD (LeNet3, sim)");
    println!(
        "\nshape check @32: gossip {:.1} vs periodic {:.1} — the paper notes the two\n\
         \"might eventually perform similarly at large scales\"; gossip must stay\n\
         within 2% and the accuracy panel (examples/fig17_learning.rs) decides",
        at32.0, at32.1
    );
    assert!(at32.0 >= at32.1 * 0.98);

    // --- measured run on the experiment engine (virtual clock:
    // deterministic, host-independent, scalable) ----------------------
    let mut base = RunConfig {
        model: "mlp-small".into(),
        algo: Algo::Gossip,
        ranks: 32,
        steps: 24,
        use_artifacts: false,
        rows_per_rank: 32,
        ..Default::default()
    };
    base.virtualize(&w, 200e-6, 1.0 / 0.5e9);
    let grid = Grid::new(base.clone())
        .algos(&[Algo::Gossip, Algo::PeriodicAgd, Algo::Agd]);
    // one engine for the measured grid *and* the autotune below: its
    // in-memory memo hands the autotuner the period-1 gossip scenario
    // (same config) without a re-run
    let engine = Engine::default();
    let sweep = engine.run(&grid).expect("measured sweep");
    let mut m = Table::new(&["algo", "step ms (simulated)", "msgs/rank/step"]);
    for r in &sweep.reports {
        m.row(&[
            r.config.algo.name().to_string(),
            format!("{:.2}", 1e3 * r.mean_step_secs),
            format!("{:.1}", r.msgs_per_rank_step()),
        ]);
    }
    m.print("measured (32 ranks, mlp-small/native, virtual-clock fabric, experiment engine)");

    // --- the figure's trade-off, mechanized: gossip-period autotune --
    let periods = [1usize, 2, 4, 8];
    let tuned = autotune::autotune_gossip_period(
        &engine,
        &base,
        &periods,
        autotune::AutotuneParams::default(),
    )
    .expect("autotune");
    let mut a = Table::new(&["period", "steps/s", "disagreement", "fast", "mixes"]);
    for c in &tuned.candidates {
        a.row(&[
            c.period.to_string(),
            format!("{:.2}", c.steps_per_sec),
            format!("{:.3e}", c.disagreement),
            (if c.fast_enough { "y" } else { "n" }).to_string(),
            (if c.consensus_shrinks { "y" } else { "n" }).to_string(),
        ]);
    }
    a.print(&format!(
        "gossip-period autotune @32 (peak {:.2} steps/s, no-mix drift {:.3e})",
        tuned.peak_steps_per_sec, tuned.no_mix_disagreement
    ));
    assert_eq!(tuned.candidates.len(), periods.len());
    assert!(
        tuned.no_mix_disagreement > 0.0,
        "independent SGD on distinct shards must drift"
    );
    // every-step mixing is the consensus gold standard: it must qualify
    let c1 = &tuned.candidates[0];
    assert!(
        c1.consensus_shrinks,
        "period 1 disagreement {:.3e} !< half of no-mix drift {:.3e}",
        c1.disagreement, tuned.no_mix_disagreement
    );
    match tuned.chosen_period {
        Some(p) => {
            assert!(periods.contains(&p));
            println!("chosen gossip_period = {p}");
        }
        None => println!("no period passed both gates (candidates above)"),
    }
}
