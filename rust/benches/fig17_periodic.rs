//! Figure 17 (performance panel): GossipGraD vs "AGD every log(p)
//! iterations" on LeNet3.  Amortizing the all-reduce over log(p) steps
//! narrows the throughput gap, but gossip stays ahead — and (see
//! examples/fig17_learning.rs for the accuracy panel) keeps learning
//! where the periodic baseline is hyperparameter-fragile.
//!
//!     cargo bench --bench fig17_periodic

use gossipgrad::collectives::Algorithm;
use gossipgrad::config::{Algo, RunConfig};
use gossipgrad::coordinator::trainer::run_with_backend;
use gossipgrad::nativenet::NativeMlp;
use gossipgrad::sim::{efficiency::avg_efficiency, Schedule, Workload};
use gossipgrad::transport::CostModel;
use gossipgrad::util::bench::Table;
use std::sync::Arc;

fn main() {
    // --- simulated sweep (the figure's x-axis goes to 32) ------------
    let w = Workload::lenet3(4.0);
    let cost = CostModel::ib_edr(0);
    let mut t = Table::new(&[
        "p",
        "gossip batches/s",
        "periodic-AGD batches/s",
        "AGD batches/s",
    ]);
    let mut at32 = (0.0, 0.0);
    for p in [2usize, 4, 8, 16, 32] {
        let g = avg_efficiency(Schedule::Gossip, &w, p, &cost, 64);
        let per = avg_efficiency(
            Schedule::PeriodicAgd(Algorithm::RecursiveDoubling),
            &w,
            p,
            &cost,
            64,
        );
        let agd = avg_efficiency(
            Schedule::Agd(Algorithm::RecursiveDoubling),
            &w,
            p,
            &cost,
            64,
        );
        at32 = (g.updates_per_sec(), per.updates_per_sec());
        t.row(&[
            p.to_string(),
            format!("{:.1}", g.updates_per_sec()),
            format!("{:.1}", per.updates_per_sec()),
            format!("{:.1}", agd.updates_per_sec()),
        ]);
    }
    t.print("Fig 17 — throughput: gossip vs periodic-AGD vs AGD (LeNet3, sim)");
    println!(
        "\nshape check @32: gossip {:.1} vs periodic {:.1} — the paper notes the two\n\
         \"might eventually perform similarly at large scales\"; gossip must stay\n\
         within 2% and the accuracy panel (examples/fig17_learning.rs) decides",
        at32.0, at32.1
    );
    assert!(at32.0 >= at32.1 * 0.98);

    // --- measured run (virtual clock: deterministic, host-independent,
    // and scalable to the figure's larger rank counts) -----------------
    let mut m = Table::new(&["algo", "step ms (simulated)", "msgs/rank/step"]);
    for algo in [Algo::Gossip, Algo::PeriodicAgd, Algo::Agd] {
        let mut cfg = RunConfig {
            model: "mlp".into(),
            algo,
            ranks: 32,
            steps: 24,
            use_artifacts: false,
            rows_per_rank: 32,
            ..Default::default()
        };
        cfg.virtualize(&w, 200e-6, 1.0 / 0.5e9);
        let backend = Arc::new(NativeMlp::new(vec![784, 32, 10], 16, 0));
        let res = run_with_backend(&cfg, backend).expect("run");
        let msgs = res.per_rank.iter().map(|r| r.msgs_sent).sum::<u64>() as f64
            / (cfg.ranks * cfg.steps) as f64;
        m.row(&[
            algo.name().to_string(),
            format!("{:.2}", 1e3 * res.mean_step_secs()),
            format!("{msgs:.1}"),
        ]);
    }
    m.print("measured (32 ranks, MLP/native, virtual-clock fabric)");
}
