//! Ablation bench: the three all-reduce algorithms over the in-process
//! fabric, across vector sizes and rank counts — the substrate numbers
//! behind the AGD baselines.
//!
//!     cargo bench --bench collectives
//!     cargo bench --bench collectives -- --json [BENCH_collectives.json]
//!
//! The timed path is the non-blocking [`IAllreduce`] engine (post /
//! progress / wait) — the same machinery `--comm-thread` AGD trains
//! through — with the historical blocking [`Algorithm::run`] kept as an
//! ablation column.  `--json` additionally emits the CI gate report
//! (docs/perf.md): effective bus bandwidth per algorithm plus a
//! deterministic single-threaded pool-allocation count that must stay
//! at zero.

use gossipgrad::collectives::{Algorithm, IAllreduce};
use gossipgrad::transport::{CostModel, Fabric};
use gossipgrad::util::bench::{fmt_dur, json_out_path, BenchReport, Table};
use std::thread;
use std::time::Instant;

/// Engine path: post the collective, pump progress, harvest with wait.
/// Work buffers cycle through the fabric's pool exactly as training does.
fn time_engine(alg: Algorithm, p: usize, n: usize, iters: usize) -> f64 {
    let fabric = Fabric::new(p, CostModel::zero());
    let t0 = Instant::now();
    let handles: Vec<_> = (0..p)
        .map(|r| {
            let ep = fabric.endpoint(r);
            thread::spawn(move || {
                let buf = vec![r as f32; n];
                for it in 0..iters {
                    let work = ep.pool().copy_f32(&buf);
                    let out = IAllreduce::post(&ep, alg, work, it).wait(&ep);
                    ep.pool().put_f32(out);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

/// Historical blocking path (ablation column): dependency-chained
/// rounds on the caller, via [`Algorithm::run`].
fn time_blocking(alg: Algorithm, p: usize, n: usize, iters: usize) -> f64 {
    let fabric = Fabric::new(p, CostModel::zero());
    let t0 = Instant::now();
    let handles: Vec<_> = (0..p)
        .map(|r| {
            let ep = fabric.endpoint(r);
            thread::spawn(move || {
                let mut buf = vec![r as f32; n];
                for it in 0..iters {
                    alg.run(&ep, &mut buf, it);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

/// Deterministic steady-state allocation count: both ranks of a p = 2
/// engine all-reduce pumped from one thread, so the pool's counters are
/// exact.  After warm-up every buffer draw (caller work buffers and the
/// machine's internal round payloads) must recycle — the CI gate pins
/// this at zero.
fn pooled_allocs_p2(n: usize, warm: usize, iters: usize) -> u64 {
    let fabric = Fabric::new(2, CostModel::zero());
    let e0 = fabric.endpoint(0);
    let e1 = fabric.endpoint(1);
    let pool = e0.pool();
    let src0 = vec![1.0f32; n];
    let src1 = vec![3.0f32; n];
    let cycle = |it: usize| {
        let mut a =
            IAllreduce::post(&e0, Algorithm::RecursiveDoubling, pool.copy_f32(&src0), it);
        let mut b =
            IAllreduce::post(&e1, Algorithm::RecursiveDoubling, pool.copy_f32(&src1), it);
        while !(a.progress(&e0) && b.progress(&e1)) {}
        let ra = a.wait(&e0);
        let rb = b.wait(&e1);
        assert_eq!(ra[0], 2.0);
        pool.put_f32(ra);
        pool.put_f32(rb);
    };
    for it in 0..warm {
        cycle(it);
    }
    let before = pool.stats().allocs;
    for it in 0..iters {
        cycle(warm + it);
    }
    pool.stats().allocs - before
}

fn alg_slug(alg: Algorithm) -> &'static str {
    match alg {
        Algorithm::RecursiveDoubling => "rec_doubling",
        Algorithm::BinomialTree => "binomial",
        Algorithm::Ring => "ring",
    }
}

fn main() {
    let mut report = BenchReport::new("collectives");
    let algs = [
        Algorithm::RecursiveDoubling,
        Algorithm::BinomialTree,
        Algorithm::Ring,
    ];
    for &n in &[4_096usize, 535_818 /* = MLP params */, 4_000_000] {
        let mut t = Table::new(&[
            "p",
            "rec-doubling",
            "binomial",
            "ring",
            "ring (blocking)",
        ]);
        for p in [2usize, 4, 8] {
            let mut row = vec![p.to_string()];
            for alg in algs {
                let secs = time_engine(alg, p, n, 5);
                row.push(fmt_dur(secs));
                if p == 4 && n == 4_000_000 {
                    // effective bus bandwidth: 2(p-1)/p · payload / time
                    let gbs = 2.0 * (p - 1) as f64 / p as f64 * (n as f64 * 4.0)
                        / secs
                        / 1e9;
                    report.entry(
                        &format!("engine_{}_p4_4m", alg_slug(alg)),
                        &[("gbs", gbs), ("median_secs", secs)],
                    );
                }
            }
            row.push(fmt_dur(time_blocking(Algorithm::Ring, p, n, 5)));
            t.row(&row);
        }
        t.print(&format!(
            "engine all-reduce wall time per call, n = {n} f32"
        ));
    }

    let allocs = pooled_allocs_p2(535_818, 4, 20);
    println!("\npooled engine all-reduce (p=2, single-thread): {allocs} allocs over 20 calls");
    report.entry("engine_p2_pooled", &[("allocs", allocs as f64)]);

    if let Some(path) = json_out_path("BENCH_collectives.json") {
        report.write(&path).expect("write bench json");
    }
}
