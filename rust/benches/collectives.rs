//! Ablation bench: the three all-reduce algorithms over the in-process
//! fabric, across vector sizes and rank counts — the substrate numbers
//! behind the AGD baselines.
//!
//!     cargo bench --bench collectives

use gossipgrad::collectives::Algorithm;
use gossipgrad::transport::{CostModel, Fabric};
use gossipgrad::util::bench::{fmt_dur, Table};
use std::thread;
use std::time::Instant;

fn time_allreduce(alg: Algorithm, p: usize, n: usize, iters: usize) -> f64 {
    let fabric = Fabric::new(p, CostModel::zero());
    let t0 = Instant::now();
    let handles: Vec<_> = (0..p)
        .map(|r| {
            let ep = fabric.endpoint(r);
            thread::spawn(move || {
                let mut buf = vec![r as f32; n];
                for it in 0..iters {
                    alg.run(&ep, &mut buf, it);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn main() {
    let algs = [
        Algorithm::RecursiveDoubling,
        Algorithm::BinomialTree,
        Algorithm::Ring,
    ];
    for &n in &[4_096usize, 535_818 /* = MLP params */, 4_000_000] {
        let mut t = Table::new(&["p", "rec-doubling", "binomial", "ring"]);
        for p in [2usize, 4, 8] {
            let mut row = vec![p.to_string()];
            for alg in algs {
                let secs = time_allreduce(alg, p, n, 5);
                row.push(fmt_dur(secs));
            }
            t.row(&row);
        }
        t.print(&format!("all-reduce wall time per call, n = {n} f32"));
    }
}
