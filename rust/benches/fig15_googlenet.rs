//! Figure 15: relative speedup of GossipGraD over AGD for GoogLeNet
//! (batch 16/device) on up to 32 P100s.
//!
//!     cargo bench --bench fig15_googlenet
//!
//! GoogLeNet's comm:compute ratio is at least ResNet50's (20 MB model,
//! ~5x less compute per step), so AGD's exposed communication grows
//! faster with p and the gossip speedup curve rises — the effect §7.4
//! describes.

use gossipgrad::collectives::Algorithm;
use gossipgrad::sim::{efficiency::avg_efficiency, Schedule, Workload};
use gossipgrad::transport::CostModel;
use gossipgrad::util::bench::Table;

fn main() {
    let w = Workload::googlenet_p100();
    let r = Workload::resnet50_p100();
    let cost = CostModel::ib_edr(0);

    let mut t = Table::new(&["p", "googlenet speedup", "resnet50 speedup"]);
    let mut series = Vec::new();
    for p in [2usize, 4, 8, 16, 32] {
        let mut row = vec![p.to_string()];
        let mut speedups = Vec::new();
        for wl in [&w, &r] {
            let agd = avg_efficiency(
                Schedule::Agd(Algorithm::RecursiveDoubling),
                wl,
                p,
                &cost,
                32,
            );
            let g = avg_efficiency(Schedule::Gossip, wl, p, &cost, 32);
            speedups.push(agd.t_step / g.t_step);
            row.push(format!("{:.3}", agd.t_step / g.t_step));
        }
        series.push(speedups[0]);
        t.row(&row);
    }
    t.print("Fig 15 — GossipGraD speedup over AGD (batch 16, P100, IB-EDR)");
    println!(
        "\nshape check: speedup rises with p ({:.3} -> {:.3}) and exceeds 1 at 32",
        series[0],
        series[series.len() - 1]
    );
    assert!(series[series.len() - 1] > series[0]);
    assert!(series[series.len() - 1] > 1.0);
}
