//! Failure-injection & adversarial-condition tests: slow/noisy networks,
//! straggler ranks, degenerate configurations.  The coordinator must
//! stay deadlock-free and correct under all of them.

use gossipgrad::config::{Algo, RunConfig};
use gossipgrad::coordinator::trainer::run_with_backend;
use gossipgrad::nativenet::NativeMlp;
use gossipgrad::transport::{CostModel, Fabric, Tag};
use std::sync::Arc;
use std::time::Duration;

fn backend() -> gossipgrad::coordinator::worker::Backend {
    Arc::new(NativeMlp::new(vec![784, 32, 10], 16, 0))
}

fn cfg(algo: Algo, ranks: usize, steps: usize) -> RunConfig {
    RunConfig {
        model: "mlp".into(),
        algo,
        ranks,
        steps,
        rows_per_rank: 96,
        use_artifacts: false,
        ..Default::default()
    }
}

#[test]
fn survives_high_latency_noisy_network() {
    for algo in [Algo::Gossip, Algo::Agd, Algo::PeriodicAgd, Algo::ParamServer] {
        let mut c = cfg(algo, 4, 12);
        c.net_alpha = 2e-3;
        c.net_beta = 1.0 / 0.2e9;
        c.net_noise = 0.5;
        let res = run_with_backend(&c, backend())
            .unwrap_or_else(|e| panic!("{} deadlocked/failed: {e}", algo.name()));
        assert_eq!(res.per_rank.len(), 4);
        // exposed comm must be measured, not silently dropped
        let waited: f64 = res.per_rank.iter().map(|m| m.mean_comm_wait()).sum();
        if algo != Algo::Gossip {
            assert!(waited > 0.0, "{}: no comm wait recorded", algo.name());
        }
    }
}

#[test]
fn single_rank_degenerates_to_sequential_sgd() {
    for algo in [Algo::Gossip, Algo::Agd, Algo::SgdSync, Algo::PeriodicAgd] {
        let mut c = cfg(algo, 1, 20);
        c.eval_every = 20;
        let res = run_with_backend(&c, backend()).unwrap();
        assert!(res.final_accuracy.unwrap() > 0.5, "{}", algo.name());
        // no gradient messages on the wire for p = 1 (shuffle is a no-op)
        assert_eq!(res.per_rank[0].msgs_sent, 0, "{}", algo.name());
    }
}

#[test]
fn two_ranks_minimum_topology() {
    let mut c = cfg(Algo::Gossip, 2, 30);
    c.eval_every = 30;
    let res = run_with_backend(&c, backend()).unwrap();
    assert!(res.final_accuracy.unwrap() > 0.8);
    // p=2 dissemination always pairs the two ranks: after the final
    // drain both hold the same mixed model
    assert!(res.max_disagreement() < 1e-5);
}

#[test]
fn odd_and_prime_rank_counts() {
    for ranks in [3usize, 5, 7, 11] {
        let c = cfg(Algo::Gossip, ranks, 15);
        let res = run_with_backend(&c, backend())
            .unwrap_or_else(|e| panic!("p={ranks}: {e}"));
        assert_eq!(res.per_rank.len(), ranks);
    }
}

#[test]
fn straggler_rank_does_not_deadlock_gossip() {
    // one rank is slowed by a per-message penalty; async gossip must
    // still complete (bounded skew: each wait is on an already-sent or
    // inevitably-sent message)
    let mut c = cfg(Algo::Gossip, 4, 15);
    c.net_alpha = 1e-3;
    c.net_noise = 2.0; // up to 3x jitter per message
    let res = run_with_backend(&c, backend()).unwrap();
    assert_eq!(res.per_rank.len(), 4);
}

#[test]
fn shuffle_disabled_and_rotation_disabled_combinations() {
    for (rot, shuf) in [(false, false), (true, false), (false, true)] {
        let mut c = cfg(Algo::Gossip, 4, 15);
        c.rotation = rot;
        c.sample_shuffle = shuf;
        run_with_backend(&c, backend())
            .unwrap_or_else(|e| panic!("rot={rot} shuf={shuf}: {e}"));
    }
}

#[test]
fn unconsumed_messages_do_not_corrupt_later_traffic() {
    // send on a tag nobody reads, then do a normal exchange — the stale
    // message must not be delivered to a different (src, tag) channel
    let f = Fabric::new(2, CostModel::zero());
    let a = f.endpoint(0);
    let b = f.endpoint(1);
    a.isend(1, Tag::CTRL.round(999), vec![666.0]);
    a.isend(1, Tag::MODEL, vec![1.0, 2.0]);
    assert_eq!(b.recv(0, Tag::MODEL), vec![1.0, 2.0]);
    let mut stale = b.irecv(0, Tag::CTRL.round(998));
    assert!(!stale.test());
}

#[test]
fn recv_wait_accounts_real_blocking_time() {
    let f = Fabric::new(2, CostModel::new(30e-3, 0.0, 0.0, 0));
    let a = f.endpoint(0);
    let b = f.endpoint(1);
    a.isend(1, Tag::MODEL, vec![0.0]);
    let _ = b.recv(0, Tag::MODEL);
    let waited = f.counters(1).recv_wait_ns.load(std::sync::atomic::Ordering::Relaxed);
    assert!(
        Duration::from_nanos(waited) >= Duration::from_millis(20),
        "recorded wait {waited}ns"
    );
}

#[test]
fn gossip_period_greater_than_one() {
    let mut c = cfg(Algo::Gossip, 4, 20);
    c.gossip_period = 4;
    c.eval_every = 20;
    let res = run_with_backend(&c, backend()).unwrap();
    // 5 gossip exchanges × layers(2...) + shuffle traffic — far fewer
    // gradient messages than gossiping every step
    let c2 = cfg(Algo::Gossip, 4, 20);
    let res2 = run_with_backend(&c2, backend()).unwrap();
    assert!(
        res.per_rank[0].msgs_sent < res2.per_rank[0].msgs_sent,
        "period did not reduce traffic"
    );
}
