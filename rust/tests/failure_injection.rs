//! Failure-injection & adversarial-condition tests: slow/noisy networks,
//! straggler ranks, degenerate configurations, and planned fault
//! injection (kills, late joins, frame drop/dup chaos) through the
//! membership/View layer (docs/fault-tolerance.md).  The coordinator
//! must stay deadlock-free and correct under all of them, and every
//! fault run must be a bit-reproducible pure function of the plan.

use gossipgrad::config::{Algo, RunConfig, Transport};
use gossipgrad::coordinator::trainer::run_with_backend;
use gossipgrad::nativenet::NativeMlp;
use gossipgrad::transport::{CostModel, Fabric, Tag};
use std::sync::Arc;
use std::time::Duration;

fn backend() -> gossipgrad::coordinator::worker::Backend {
    Arc::new(NativeMlp::new(vec![784, 32, 10], 16, 0))
}

fn cfg(algo: Algo, ranks: usize, steps: usize) -> RunConfig {
    RunConfig {
        model: "mlp".into(),
        algo,
        ranks,
        steps,
        rows_per_rank: 96,
        use_artifacts: false,
        ..Default::default()
    }
}

#[test]
fn survives_high_latency_noisy_network() {
    for algo in [Algo::Gossip, Algo::Agd, Algo::PeriodicAgd, Algo::ParamServer] {
        let mut c = cfg(algo, 4, 12);
        c.net_alpha = 2e-3;
        c.net_beta = 1.0 / 0.2e9;
        c.net_noise = 0.5;
        let res = run_with_backend(&c, backend())
            .unwrap_or_else(|e| panic!("{} deadlocked/failed: {e}", algo.name()));
        assert_eq!(res.per_rank.len(), 4);
        // exposed comm must be measured, not silently dropped
        let waited: f64 = res.per_rank.iter().map(|m| m.mean_comm_wait()).sum();
        if algo != Algo::Gossip {
            assert!(waited > 0.0, "{}: no comm wait recorded", algo.name());
        }
    }
}

#[test]
fn single_rank_degenerates_to_sequential_sgd() {
    for algo in [Algo::Gossip, Algo::Agd, Algo::SgdSync, Algo::PeriodicAgd] {
        let mut c = cfg(algo, 1, 20);
        c.eval_every = 20;
        let res = run_with_backend(&c, backend()).unwrap();
        assert!(res.final_accuracy.unwrap() > 0.5, "{}", algo.name());
        // no gradient messages on the wire for p = 1 (shuffle is a no-op)
        assert_eq!(res.per_rank[0].msgs_sent, 0, "{}", algo.name());
    }
}

#[test]
fn two_ranks_minimum_topology() {
    let mut c = cfg(Algo::Gossip, 2, 30);
    c.eval_every = 30;
    let res = run_with_backend(&c, backend()).unwrap();
    assert!(res.final_accuracy.unwrap() > 0.8);
    // p=2 dissemination always pairs the two ranks: after the final
    // drain both hold the same mixed model
    assert!(res.max_disagreement() < 1e-5);
}

#[test]
fn odd_and_prime_rank_counts() {
    for ranks in [3usize, 5, 7, 11] {
        let c = cfg(Algo::Gossip, ranks, 15);
        let res = run_with_backend(&c, backend())
            .unwrap_or_else(|e| panic!("p={ranks}: {e}"));
        assert_eq!(res.per_rank.len(), ranks);
    }
}

#[test]
fn straggler_rank_does_not_deadlock_gossip() {
    // one rank is slowed by a per-message penalty; async gossip must
    // still complete (bounded skew: each wait is on an already-sent or
    // inevitably-sent message)
    let mut c = cfg(Algo::Gossip, 4, 15);
    c.net_alpha = 1e-3;
    c.net_noise = 2.0; // up to 3x jitter per message
    let res = run_with_backend(&c, backend()).unwrap();
    assert_eq!(res.per_rank.len(), 4);
}

#[test]
fn shuffle_disabled_and_rotation_disabled_combinations() {
    for (rot, shuf) in [(false, false), (true, false), (false, true)] {
        let mut c = cfg(Algo::Gossip, 4, 15);
        c.rotation = rot;
        c.sample_shuffle = shuf;
        run_with_backend(&c, backend())
            .unwrap_or_else(|e| panic!("rot={rot} shuf={shuf}: {e}"));
    }
}

#[test]
fn unconsumed_messages_do_not_corrupt_later_traffic() {
    // send on a tag nobody reads, then do a normal exchange — the stale
    // message must not be delivered to a different (src, tag) channel
    let f = Fabric::new(2, CostModel::zero());
    let a = f.endpoint(0);
    let b = f.endpoint(1);
    a.isend(1, Tag::CTRL.round(999), vec![666.0]);
    a.isend(1, Tag::MODEL, vec![1.0, 2.0]);
    assert_eq!(b.recv(0, Tag::MODEL), vec![1.0, 2.0]);
    let mut stale = b.irecv(0, Tag::CTRL.round(998));
    assert!(!stale.test());
}

#[test]
fn recv_wait_accounts_real_blocking_time() {
    let f = Fabric::new(2, CostModel::new(30e-3, 0.0, 0.0, 0));
    let a = f.endpoint(0);
    let b = f.endpoint(1);
    a.isend(1, Tag::MODEL, vec![0.0]);
    let _ = b.recv(0, Tag::MODEL);
    let waited = f.counters(1).recv_wait_ns.load(std::sync::atomic::Ordering::Relaxed);
    assert!(
        Duration::from_nanos(waited) >= Duration::from_millis(20),
        "recorded wait {waited}ns"
    );
}

// ---- planned fault injection (membership/View layer) ----------------

/// The headline fault scenario: p = 8 gossip, rank 3 killed at step 10.
/// The seven survivors must route around the hole and finish, the run
/// must drain to zero in-flight frames, and two identical runs must
/// produce the same parameter bits — deaths are part of the plan, not a
/// source of nondeterminism.
#[test]
fn killed_rank_mid_run_survivors_complete_and_reproduce() {
    let mut c = cfg(Algo::Gossip, 8, 20);
    c.fault_plan.kills = vec![(3, 10)];
    let a = run_with_backend(&c, backend()).unwrap();
    let b = run_with_backend(&c, backend()).unwrap();
    assert_eq!(a.survivors(), vec![0, 1, 2, 4, 5, 6, 7]);
    assert_eq!(a.per_rank[3].death_step, Some(10));
    assert_eq!(
        a.param_hash(),
        b.param_hash(),
        "a planned kill must be bit-reproducible"
    );
    assert_eq!(a.in_flight_msgs, 0, "kill run leaked in-flight frames");
    assert_eq!(a.in_flight_bytes, 0, "kill run leaked in-flight bytes");
}

/// The same kill over real loopback sockets: fault verdicts are pure
/// functions of the shared plan, so the TCP run reproduces the in-proc
/// run bit for bit AND reproduces itself.
#[test]
fn killed_rank_over_loopback_tcp_matches_inproc() {
    let mut c = cfg(Algo::Gossip, 8, 20);
    c.fault_plan.kills = vec![(3, 10)];
    let inproc = run_with_backend(&c, backend()).unwrap();
    let mut t = c.clone();
    t.transport = Transport::Tcp;
    let tcp = run_with_backend(&t, backend()).unwrap();
    let tcp2 = run_with_backend(&t, backend()).unwrap();
    assert_eq!(
        tcp.param_hash(),
        inproc.param_hash(),
        "kill run diverged between tcp and in-proc"
    );
    assert_eq!(
        tcp.param_hash(),
        tcp2.param_hash(),
        "tcp kill run is not reproducible"
    );
    assert_eq!(tcp.survivors(), vec![0, 1, 2, 4, 5, 6, 7]);
    assert_eq!(tcp.per_rank[3].death_step, Some(10));
    assert_eq!(tcp.in_flight_msgs, 0);
    assert_eq!(tcp.in_flight_bytes, 0);
}

/// Frame chaos (drop + duplicate) keyed on a fixed seed: two runs are
/// bit-identical, the chaos demonstrably bites (differs from a clean
/// run), a different seed picks different victims, and the same
/// verdicts fire over TCP.
#[test]
fn drop_and_dup_chaos_is_deterministic_under_a_fixed_seed() {
    let mut c = cfg(Algo::Gossip, 8, 12);
    c.fault_plan.drop_frac = 0.2;
    c.fault_plan.dup_frac = 0.1;
    c.fault_plan.seed = 42;
    let a = run_with_backend(&c, backend()).unwrap();
    let b = run_with_backend(&c, backend()).unwrap();
    assert_eq!(
        a.param_hash(),
        b.param_hash(),
        "chaos run is not a pure function of the plan"
    );
    assert_eq!(a.in_flight_msgs, 0, "dropped/dup'd frames must still drain");

    let clean = run_with_backend(&cfg(Algo::Gossip, 8, 12), backend()).unwrap();
    assert_ne!(
        a.param_hash(),
        clean.param_hash(),
        "drop_frac=0.2 over ~100 model frames dropped nothing"
    );

    let mut reseeded = c.clone();
    reseeded.fault_plan.seed = 43;
    let s = run_with_backend(&reseeded, backend()).unwrap();
    assert_ne!(
        a.param_hash(),
        s.param_hash(),
        "fault seed does not select the victim frames"
    );

    let mut t = c.clone();
    t.transport = Transport::Tcp;
    let tcp = run_with_backend(&t, backend()).unwrap();
    assert_eq!(
        tcp.param_hash(),
        a.param_hash(),
        "chaos verdicts diverged between tcp and in-proc"
    );
    assert_eq!(tcp.in_flight_msgs, 0);
}

/// Late-rank bootstrap: rank 3 joins a p = 4 run at step 8 by fetching
/// a donor snapshot.  Both sides hash the snapshot at the moment of
/// transfer — the joiner must proceed from exactly the donor's bits.
#[test]
fn late_joiner_bootstraps_from_donor_and_matches_its_snapshot() {
    let mut c = cfg(Algo::Gossip, 4, 16);
    c.fault_plan.joins = vec![(3, 8)];
    let a = run_with_backend(&c, backend()).unwrap();
    let b = run_with_backend(&c, backend()).unwrap();
    assert_eq!(
        a.param_hash(),
        b.param_hash(),
        "join run is not bit-reproducible"
    );
    assert_eq!(a.per_rank[3].joined_step, Some(8));
    // the donor is the smallest alive non-joining rank: rank 0
    let donor_hash = a.per_rank[0]
        .join_hash
        .expect("donor recorded no snapshot hash");
    assert_eq!(
        a.per_rank[3].join_hash,
        Some(donor_hash),
        "joiner's bootstrap params differ from the donor's snapshot"
    );
    assert_eq!(a.per_rank[3].death_step, None);
    assert_eq!(a.in_flight_msgs, 0);
}

/// A slow rank changes when frames arrive, never what is computed:
/// every receive is keyed by (src, tag), so the slowed run's parameter
/// bits equal the clean run's.
#[test]
fn slow_links_change_timing_but_not_numerics() {
    let mut c = cfg(Algo::Gossip, 4, 12);
    c.fault_plan.slows = vec![(1, 2, 4.0)];
    let slowed = run_with_backend(&c, backend()).unwrap();
    let clean = run_with_backend(&cfg(Algo::Gossip, 4, 12), backend()).unwrap();
    assert_eq!(
        slowed.param_hash(),
        clean.param_hash(),
        "a slow link must not change the numerics"
    );
}

#[test]
fn gossip_period_greater_than_one() {
    let mut c = cfg(Algo::Gossip, 4, 20);
    c.gossip_period = 4;
    c.eval_every = 20;
    let res = run_with_backend(&c, backend()).unwrap();
    // 5 gossip exchanges × layers(2...) + shuffle traffic — far fewer
    // gradient messages than gossiping every step
    let c2 = cfg(Algo::Gossip, 4, 20);
    let res2 = run_with_backend(&c2, backend()).unwrap();
    assert!(
        res.per_rank[0].msgs_sent < res2.per_rank[0].msgs_sent,
        "period did not reduce traffic"
    );
}
