//! Fabric-drain invariant: after a full run of ANY algorithm, no
//! message may remain queued on the fabric.  Leaked `isend`/`irecv`
//! pairs (an unconsumed final-step exchange, an undrained sample-ring
//! refill, a collective abandoned mid-chain) would silently strand
//! payloads in mailboxes — invisible to the numerics, poisonous to any
//! accounting that reuses the fabric.
//!
//! The grid covers every algorithm × layerwise × sync_mix at worker
//! counts exercising the edge topologies (p = 2 pairs, p = 3 non-power-
//! of-two fold/ragged-ring, p = 8 full trees), plus the comm-thread AGD
//! engine path, plus a **transport axis**: the same invariant over the
//! loopback-TCP link, where `in_flight` additionally counts frames in
//! writer queues and each rank's post-quiesce mailbox (a frame sent but
//! never harvested lands in the receiver's count).
//!
//! Since the wire-codec layer the invariant is two-sided: zero leaked
//! *messages* and zero leaked *bytes* (`in_flight_bytes`, the encoded
//! payload bytes still queued) — a codec bug that dropped a frame but
//! decremented the count, or vice versa, trips exactly one of the two.

use gossipgrad::config::{Algo, CostModelKind, RunConfig, Transport};
use gossipgrad::coordinator::trainer::run_with_backend;
use gossipgrad::nativenet::NativeMlp;
use gossipgrad::sim::Workload;
use std::sync::Arc;

fn tiny_backend() -> gossipgrad::coordinator::worker::Backend {
    Arc::new(NativeMlp::new(vec![784, 16, 10], 16, 0))
}

fn vcfg(algo: Algo, ranks: usize, steps: usize) -> RunConfig {
    let mut c = RunConfig {
        model: "mlp".into(),
        algo,
        ranks,
        steps,
        rows_per_rank: 32,
        use_artifacts: false,
        eval_every: 0,
        seed: 42,
        ..Default::default()
    };
    c.virtualize(&Workload::lenet3(4.0), 200e-6, 1.0 / 0.5e9);
    c
}

#[test]
fn no_in_flight_messages_after_any_schedule() {
    for algo in [Algo::Gossip, Algo::Agd, Algo::PeriodicAgd, Algo::ParamServer] {
        for layerwise in [false, true] {
            for sync_mix in [false, true] {
                for p in [2usize, 3, 8] {
                    let mut c = vcfg(algo, p, 4);
                    c.layerwise = layerwise;
                    c.sync_mix = sync_mix;
                    let res = run_with_backend(&c, tiny_backend())
                        .unwrap_or_else(|e| {
                            panic!("{algo:?} p={p} lw={layerwise} sm={sync_mix}: {e}")
                        });
                    assert_eq!(
                        res.in_flight_msgs, 0,
                        "{algo:?} p={p} layerwise={layerwise} \
                         sync_mix={sync_mix}: leaked messages on the fabric"
                    );
                    assert_eq!(
                        res.in_flight_bytes, 0,
                        "{algo:?} p={p} layerwise={layerwise} \
                         sync_mix={sync_mix}: leaked bytes on the fabric"
                    );
                }
            }
        }
    }
}

#[test]
fn no_in_flight_messages_after_comm_thread_agd() {
    for p in [2usize, 3, 8] {
        let mut c = vcfg(Algo::Agd, p, 4);
        c.layerwise = true;
        c.comm_thread = true;
        let res = run_with_backend(&c, tiny_backend()).unwrap();
        assert_eq!(
            res.in_flight_msgs, 0,
            "comm-thread AGD p={p}: leaked collective-internal messages"
        );
        assert_eq!(
            res.in_flight_bytes, 0,
            "comm-thread AGD p={p}: leaked collective-internal bytes"
        );
    }
}

/// Wall-clock config for the TCP link (which rejects the virtual
/// clock): zero wire cost, same tiny shard shape as the virtual grid.
fn tcpcfg(algo: Algo, ranks: usize, steps: usize) -> RunConfig {
    RunConfig {
        model: "mlp".into(),
        algo,
        ranks,
        steps,
        rows_per_rank: 32,
        use_artifacts: false,
        eval_every: 0,
        seed: 42,
        transport: Transport::Tcp,
        ..Default::default()
    }
}

#[test]
fn no_in_flight_messages_over_the_tcp_link() {
    // p kept small: each scenario is a real socket mesh (2 threads +
    // 2 io threads per rank); the message-pairing logic under test is
    // identical at larger p
    for algo in [Algo::Gossip, Algo::Agd, Algo::ParamServer] {
        for layerwise in [false, true] {
            for p in [2usize, 3] {
                let mut c = tcpcfg(algo, p, 3);
                c.layerwise = layerwise;
                let res = run_with_backend(&c, tiny_backend())
                    .unwrap_or_else(|e| {
                        panic!("tcp {algo:?} p={p} lw={layerwise}: {e}")
                    });
                assert_eq!(
                    res.in_flight_msgs, 0,
                    "tcp {algo:?} p={p} layerwise={layerwise}: frames \
                     left on the mesh after quiesce"
                );
                assert_eq!(
                    res.in_flight_bytes, 0,
                    "tcp {algo:?} p={p} layerwise={layerwise}: frame \
                     bytes left on the mesh after quiesce"
                );
            }
        }
    }
}

#[test]
fn no_in_flight_messages_on_the_hierarchical_fabric() {
    // the group_size axis (docs/topology.md): the two-level schedule
    // re-routes exchanges between mailbox tiers, so the drain invariant
    // must hold per tier — a frame stranded in a group mailbox is just
    // as leaked as one in a socket writer queue
    for (ranks, group_size) in [(4usize, 2usize), (8, 4)] {
        for inter_period in [1usize, 2] {
            // in-proc fabric, two-tier costs charged on the virtual clock
            let mut c = vcfg(Algo::Gossip, ranks, 4);
            c.group_size = group_size;
            c.inter_period = inter_period;
            c.cost_model = CostModelKind::Hier;
            let res = run_with_backend(&c, tiny_backend()).unwrap_or_else(|e| {
                panic!("hier p={ranks} g={group_size} k={inter_period}: {e}")
            });
            assert_eq!(
                res.in_flight_msgs, 0,
                "hier p={ranks} g={group_size} k={inter_period}: leaked messages"
            );
            assert_eq!(
                res.in_flight_bytes, 0,
                "hier p={ranks} g={group_size} k={inter_period}: leaked bytes"
            );

            // hybrid loopback link: in-proc mailboxes inside each group,
            // real sockets between groups — both halves must drain
            let mut c = tcpcfg(Algo::Gossip, ranks, 3);
            c.group_size = group_size;
            c.inter_period = inter_period;
            let res = run_with_backend(&c, tiny_backend()).unwrap_or_else(|e| {
                panic!("hybrid p={ranks} g={group_size} k={inter_period}: {e}")
            });
            assert_eq!(
                res.in_flight_msgs, 0,
                "hybrid p={ranks} g={group_size} k={inter_period}: frames \
                 left in a mailbox or writer queue after quiesce"
            );
            assert_eq!(
                res.in_flight_bytes, 0,
                "hybrid p={ranks} g={group_size} k={inter_period}: frame \
                 bytes left on the fabric after quiesce"
            );
        }
    }
}

#[test]
fn no_in_flight_messages_for_remaining_gossip_variants() {
    // random gossip's unbalanced blocking drain and the hypercube
    // topology (power-of-two only) have their own send/recv pairings
    for (algo, ps) in [
        (Algo::GossipRandom, vec![2usize, 3, 8]),
        (Algo::GossipHypercube, vec![2usize, 8]),
        (Algo::SgdSync, vec![2usize, 3, 8]),
    ] {
        for p in ps {
            for layerwise in [false, true] {
                let mut c = vcfg(algo, p, 4);
                c.layerwise = layerwise;
                let res = run_with_backend(&c, tiny_backend()).unwrap();
                assert_eq!(
                    res.in_flight_msgs, 0,
                    "{algo:?} p={p} layerwise={layerwise}: leaked messages"
                );
                assert_eq!(
                    res.in_flight_bytes, 0,
                    "{algo:?} p={p} layerwise={layerwise}: leaked bytes"
                );
            }
        }
    }
}
