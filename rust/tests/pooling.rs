//! Buffer-pool integration: the pooled hot path must be a pure
//! allocation optimization (docs/perf.md).
//!
//! Three guarantees pinned here:
//!
//! 1. **A/B parity** — `--no-pool` (fresh allocation per message, the
//!    pre-pool behaviour) and the default pooled path produce the same
//!    `param_hash` for gossip/AGD/PS × layerwise over the in-process
//!    link and the loopback-TCP mesh.  Pooling recycles capacity, never
//!    bits: `copy_f32` fills exactly like `to_vec`, `decode_pooled`
//!    like `decode`.
//! 2. **Zero-allocation steady state** — on a single-threaded 2-rank
//!    fabric (so the pool counters are exact) the send → recv → return
//!    cycle and the p = 2 engine all-reduce stop allocating entirely
//!    after warm-up.  This is the same invariant the CI bench gate pins
//!    (`BENCH_hotpath.json` / `BENCH_collectives.json` `allocs` = 0).
//! 3. **Sublinear allocations on real runs** — tripling the step count
//!    of a multi-threaded training run must far less than triple
//!    `PoolStats::allocs`: misses are a warm-up phenomenon, not a
//!    per-step cost.

use gossipgrad::codec::Codec;
use gossipgrad::collectives::{Algorithm, IAllreduce};
use gossipgrad::config::{Algo, RunConfig, Transport};
use gossipgrad::coordinator::trainer::run_with_backend;
use gossipgrad::nativenet::NativeMlp;
use gossipgrad::transport::{CostModel, Fabric, Tag};
use std::sync::Arc;

fn tiny_backend() -> gossipgrad::coordinator::worker::Backend {
    Arc::new(NativeMlp::new(vec![784, 16, 10], 16, 0))
}

fn base(algo: Algo) -> RunConfig {
    RunConfig {
        model: "mlp".into(),
        algo,
        ranks: 4,
        steps: 4,
        rows_per_rank: 32,
        use_artifacts: false,
        eval_every: 0,
        seed: 11,
        codec: Codec::F32,
        ..Default::default()
    }
}

/// Pooled vs `--no-pool` bit parity for every payload-bearing schedule,
/// over both transports.  The pool recycles buffers through sender,
/// wire and receiver — none of that may change a single bit.
#[test]
fn pooled_and_unpooled_runs_are_bit_identical() {
    for algo in [Algo::Gossip, Algo::Agd, Algo::ParamServer] {
        for layerwise in [false, true] {
            for transport in [Transport::Inproc, Transport::Tcp] {
                let mut pooled = base(algo);
                pooled.layerwise = layerwise;
                pooled.transport = transport;
                let mut bare = pooled.clone();
                bare.pool = false;
                let a = run_with_backend(&pooled, tiny_backend())
                    .unwrap_or_else(|e| panic!("{algo:?} {transport:?} pooled: {e}"));
                let b = run_with_backend(&bare, tiny_backend())
                    .unwrap_or_else(|e| panic!("{algo:?} {transport:?} no-pool: {e}"));
                assert_eq!(
                    a.param_hash(),
                    b.param_hash(),
                    "{algo:?} layerwise={layerwise} {transport:?}: \
                     pooling changed numerics"
                );
                // drain invariant: recycling must not strand payloads
                assert_eq!(a.in_flight_msgs, 0);
                assert_eq!(a.in_flight_bytes, 0);
                // disabled pool = pre-pool behaviour: every get misses
                assert_eq!(
                    b.pool_stats.allocs, b.pool_stats.gets,
                    "{algo:?} {transport:?}: disabled pool must not recycle"
                );
            }
        }
    }
}

/// Steady-state transport cycle on a single-threaded 2-rank fabric:
/// after warm-up, `copy_f32 → isend → recv → put_f32` must be
/// allocation-free — the counters are exact here because no other
/// thread touches the pool.
#[test]
fn steady_state_send_recv_cycle_is_allocation_free() {
    let fabric = Fabric::new(2, CostModel::zero());
    let e0 = fabric.endpoint(0);
    let e1 = fabric.endpoint(1);
    let pool = e0.pool();
    let payload = vec![1.25f32; 4096];
    for _ in 0..3 {
        e0.isend(1, Tag::MODEL, pool.copy_f32(&payload));
        pool.put_f32(e1.recv(0, Tag::MODEL));
    }
    let warm = pool.stats();
    assert!(warm.allocs > 0, "cold pool must have allocated");
    for _ in 0..100 {
        e0.isend(1, Tag::MODEL, pool.copy_f32(&payload));
        let got = e1.recv(0, Tag::MODEL);
        assert_eq!(got, payload, "recycled buffer corrupted the payload");
        pool.put_f32(got);
    }
    let after = pool.stats();
    assert_eq!(
        after.allocs, warm.allocs,
        "steady-state transport must not allocate"
    );
    assert_eq!(after.gets, warm.gets + 100);
    assert_eq!(fabric.in_flight(), 0);
}

/// The engine all-reduce's internal round payloads recycle too: a p = 2
/// collective pumped from one thread allocates only during warm-up.
#[test]
fn steady_state_engine_allreduce_is_allocation_free() {
    let fabric = Fabric::new(2, CostModel::zero());
    let e0 = fabric.endpoint(0);
    let e1 = fabric.endpoint(1);
    let pool = e0.pool();
    let src0 = vec![1.0f32; 2048];
    let src1 = vec![3.0f32; 2048];
    let cycle = |it: usize| {
        let mut a =
            IAllreduce::post(&e0, Algorithm::RecursiveDoubling, pool.copy_f32(&src0), it);
        let mut b =
            IAllreduce::post(&e1, Algorithm::RecursiveDoubling, pool.copy_f32(&src1), it);
        while !(a.progress(&e0) && b.progress(&e1)) {}
        let ra = a.wait(&e0);
        let rb = b.wait(&e1);
        assert!(ra.iter().all(|&x| x == 2.0), "bad reduction: {:?}", &ra[..4]);
        assert!(rb.iter().all(|&x| x == 2.0), "bad reduction: {:?}", &rb[..4]);
        pool.put_f32(ra);
        pool.put_f32(rb);
    };
    for it in 0..3 {
        cycle(it);
    }
    let warm = pool.stats().allocs;
    for it in 0..50 {
        cycle(3 + it);
    }
    assert_eq!(
        pool.stats().allocs,
        warm,
        "steady-state engine all-reduce must not allocate"
    );
    assert_eq!(fabric.in_flight(), 0);
}

/// On a real multi-threaded training run, allocations are a warm-up
/// cost: tripling the step count must far less than triple the miss
/// count, and recycling must actually happen (hits and returns > 0).
#[test]
fn training_run_allocations_are_sublinear_in_steps() {
    let run = |steps: usize| {
        let mut c = base(Algo::Gossip);
        c.layerwise = true;
        c.steps = steps;
        run_with_backend(&c, tiny_backend()).unwrap().pool_stats
    };
    let short = run(4);
    let long = run(12);
    assert!(
        short.gets > short.allocs,
        "pooled gossip run never hit the shelves: {short:?}"
    );
    assert!(short.returns > 0, "no buffer ever returned: {short:?}");
    assert!(
        long.allocs < 3 * short.allocs,
        "allocations scaled with steps (no steady state): \
         {} steps -> {} allocs, {} steps -> {} allocs",
        4,
        short.allocs,
        12,
        long.allocs
    );
}
