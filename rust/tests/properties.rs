//! Randomized property tests over the coordinator's invariants
//! (DESIGN.md "Key invariants"), using the util::prop harness.
//! Replay a failure with GG_PROP_SEED=<seed> cargo test --test properties.

use gossipgrad::collectives::Algorithm;
use gossipgrad::nativenet::ops;
use gossipgrad::topology::{
    check_balanced, diffusion_time, Dissemination, Hypercube, Ring, Rotation,
    Topology,
};
use gossipgrad::transport::{CostModel, Fabric, Tag};
use gossipgrad::util::prop::{f32_vec, forall, usize_in};
use gossipgrad::util::{ceil_log2, Rng};

// ---- invariant 1: balanced matching at every step ------------------------

#[test]
fn prop_dissemination_balanced() {
    forall(
        96,
        |r| (usize_in(r, 1, 200), usize_in(r, 0, 1000)),
        |&(p, step)| {
            check_balanced(&Dissemination::new(p), step)
        },
    );
}

#[test]
fn prop_rotation_balanced_and_bijective() {
    forall(
        64,
        |r| (usize_in(r, 2, 64), r.next_u64(), usize_in(r, 0, 500)),
        |&(p, seed, step)| {
            let t = Rotation::new(Dissemination::new(p), seed);
            check_balanced(&t, step)?;
            // recv must be inverse of send across the whole permutation
            let mut seen = vec![false; p];
            for rank in 0..p {
                let e = t.exchange(rank, step);
                if seen[e.send_to] {
                    return Err(format!("rank {} target hit twice", rank));
                }
                seen[e.send_to] = true;
            }
            Ok(())
        },
    );
}

// ---- invariant 2: diffusion completes within ceil(log2 p) ----------------

#[test]
fn prop_dissemination_diffusion_bound() {
    forall(
        48,
        |r| (usize_in(r, 2, 150), usize_in(r, 0, 149)),
        |&(p, origin)| {
            let origin = origin % p;
            let t = Dissemination::new(p);
            match diffusion_time(&t, origin, 4 * p) {
                Some(steps) if steps <= ceil_log2(p) => Ok(()),
                Some(steps) => Err(format!(
                    "diffused in {steps} > ceil_log2({p}) = {}",
                    ceil_log2(p)
                )),
                None => Err("never diffused".into()),
            }
        },
    );
}

#[test]
fn prop_rotation_preserves_diffusion_bound() {
    forall(
        32,
        |r| (1usize << usize_in(r, 1, 6), r.next_u64()),
        |&(p, seed)| {
            let t = Rotation::new(Dissemination::new(p), seed);
            match diffusion_time(&t, 0, 4 * p) {
                // rotation epochs switch mid-diffusion; allow one extra
                // epoch of slack but it must stay O(log p)
                Some(steps) if steps <= 2 * ceil_log2(p).max(1) => Ok(()),
                other => Err(format!("diffusion {other:?} for p={p}")),
            }
        },
    );
}

// ---- invariant 4: mixing conserves the global mean and contracts ---------

#[test]
fn prop_mixing_preserves_global_sum() {
    forall(
        48,
        |r| {
            let p = usize_in(r, 2, 16);
            let n = usize_in(r, 1, 300);
            let models: Vec<Vec<f32>> =
                (0..p).map(|_| f32_vec(r, n, 1.0)).collect();
            (models, r.next_u64())
        },
        |(models, seed)| {
            let p = models.len();
            let n = models[0].len();
            let topo = Dissemination::new(p);
            let sum_before: f64 = models
                .iter()
                .flat_map(|m| m.iter().map(|&v| v as f64))
                .sum();
            // run several synchronized gossip mixing rounds
            let mut ms = models.clone();
            let mut rng = Rng::new(*seed);
            for step in 0..usize_in(&mut rng, 1, 12) {
                let snapshot = ms.clone();
                for rank in 0..p {
                    let e = topo.exchange(rank, step);
                    ops::mix_to(&mut ms[rank], &snapshot[rank], &snapshot[e.recv_from]);
                }
            }
            let sum_after: f64 = ms
                .iter()
                .flat_map(|m| m.iter().map(|&v| v as f64))
                .sum();
            let tol = 1e-3 * (p * n) as f64;
            if (sum_before - sum_after).abs() > tol {
                return Err(format!(
                    "global sum drifted: {sum_before} -> {sum_after}"
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_mixing_contracts_disagreement() {
    forall(
        32,
        |r| {
            let p = 1usize << usize_in(r, 1, 4);
            let n = usize_in(r, 4, 128);
            ((0..p).map(|_| f32_vec(r, n, 1.0)).collect::<Vec<_>>(),)
        },
        |(models,)| {
            let p = models.len();
            let spread = |ms: &Vec<Vec<f32>>| -> f64 {
                let n = ms[0].len();
                let mut worst = 0.0f64;
                for j in 0..n {
                    let mut lo = f64::MAX;
                    let mut hi = f64::MIN;
                    for m in ms {
                        lo = lo.min(m[j] as f64);
                        hi = hi.max(m[j] as f64);
                    }
                    worst = worst.max(hi - lo);
                }
                worst
            };
            let before = spread(models);
            let topo = Hypercube::new(p);
            let mut ms = models.clone();
            for step in 0..ceil_log2(p) {
                let snapshot = ms.clone();
                for rank in 0..p {
                    let e = topo.exchange(rank, step);
                    ops::mix_to(&mut ms[rank], &snapshot[rank], &snapshot[e.recv_from]);
                }
            }
            let after = spread(&ms);
            // after a full hypercube sweep every rank holds the exact
            // global average -> spread collapses
            if after > 1e-3 * before.max(1.0) && after > 1e-4 {
                return Err(format!("spread {before} -> {after}"));
            }
            Ok(())
        },
    );
}

// ---- invariant 5: ring shuffle fairness ----------------------------------

#[test]
fn prop_ring_revisit_after_full_circulation() {
    forall(
        48,
        |r| (usize_in(r, 2, 40), usize_in(r, 0, 39)),
        |&(p, start)| {
            let start = start % p;
            let ring = Ring::new(p);
            let mut at = start;
            for hop in 1..=p {
                at = ring.exchange(at, hop - 1).send_to;
                if at == start && hop != p {
                    return Err(format!("returned after {hop} < p = {p}"));
                }
            }
            if at != start {
                return Err("did not return after p hops".into());
            }
            Ok(())
        },
    );
}

// ---- invariant 6: collectives equal the naive average --------------------

#[test]
fn prop_allreduce_equals_naive() {
    forall(
        24,
        |r| {
            let p = usize_in(r, 1, 9);
            let n = usize_in(r, 1, 200);
            let alg = match usize_in(r, 0, 2) {
                0 => Algorithm::RecursiveDoubling,
                1 => Algorithm::BinomialTree,
                _ => Algorithm::Ring,
            };
            let inputs: Vec<Vec<f32>> =
                (0..p).map(|_| f32_vec(r, n, 2.0)).collect();
            (alg, inputs)
        },
        |(alg, inputs)| {
            let p = inputs.len();
            let n = inputs[0].len();
            let mut want = vec![0.0f64; n];
            for v in inputs {
                for (w, &x) in want.iter_mut().zip(v) {
                    *w += x as f64;
                }
            }
            for w in want.iter_mut() {
                *w /= p as f64;
            }
            let fabric = Fabric::new(p, CostModel::zero());
            let alg = *alg;
            let handles: Vec<_> = inputs
                .iter()
                .cloned()
                .enumerate()
                .map(|(rank, mut buf)| {
                    let ep = fabric.endpoint(rank);
                    std::thread::spawn(move || {
                        alg.run(&ep, &mut buf, 0);
                        buf
                    })
                })
                .collect();
            for h in handles {
                let got = h.join().unwrap();
                for (g, w) in got.iter().zip(&want) {
                    if (*g as f64 - w).abs() > 1e-3 * (1.0 + w.abs()) {
                        return Err(format!("{} vs {}", g, w));
                    }
                }
            }
            Ok(())
        },
    );
}

// ---- invariant 7: transport FIFO + exactly-once ---------------------------

#[test]
fn prop_transport_fifo_exactly_once() {
    forall(
        32,
        |r| (usize_in(r, 1, 50), r.next_u64()),
        |&(n_msgs, seed)| {
            let fabric = Fabric::new(2, CostModel::zero());
            let a = fabric.endpoint(0);
            let b = fabric.endpoint(1);
            let mut rng = Rng::new(seed);
            let payloads: Vec<Vec<f32>> = (0..n_msgs)
                .map(|i| vec![i as f32, rng.f32()])
                .collect();
            for p in &payloads {
                a.isend(1, Tag::CTRL, p.clone());
            }
            for want in &payloads {
                let got = b.recv(0, Tag::CTRL);
                if &got != want {
                    return Err(format!("got {got:?} want {want:?}"));
                }
            }
            // nothing left
            let mut extra = b.irecv(0, Tag::CTRL);
            if extra.test() {
                return Err("message delivered twice".into());
            }
            Ok(())
        },
    );
}

// ---- fused update equals two-step reference -------------------------------

#[test]
fn prop_fused_sgd_matches_reference() {
    forall(
        48,
        |r| {
            let n = usize_in(r, 1, 500);
            (
                f32_vec(r, n, 1.0),
                f32_vec(r, n, 1.0),
                f32_vec(r, n, 1.0),
                r.f32() * 0.5,
                r.f32(),
            )
        },
        |(p, v, g, lr, mu)| {
            let mut p1 = p.clone();
            let mut v1 = v.clone();
            ops::sgd_momentum(&mut p1, &mut v1, g, *lr, *mu);
            for i in 0..p.len() {
                let nv = mu * v[i] + g[i];
                let np = p[i] - lr * nv;
                if (v1[i] - nv).abs() > 1e-5 || (p1[i] - np).abs() > 1e-5 {
                    return Err(format!("coord {i}"));
                }
            }
            Ok(())
        },
    );
}
