//! Hierarchical-fabric invariants (docs/topology.md):
//!
//! * **Flat identity** — `group_size = 1` and `group_size = p` are the
//!   degenerate corners of the two-level schedule, and both must
//!   reproduce the flat §4.5.1 rotation *bit for bit* (`param_hash`),
//!   across gossip and the AGD collective baseline, over both the
//!   in-proc fabric and the hybrid loopback-TCP link.  The hierarchy is
//!   a routing/cost overlay, never a numerics change.
//! * **Hybrid-link transparency** — on the collective baselines a
//!   `group_size > 1` hybrid link only swaps the wire under the same
//!   message schedule, so its parameter bits must equal the plain
//!   socket mesh's.
//! * **Membership interplay** — killing a rank *inside* a group leaves
//!   the survivors' collapsed exchange deadlock-free, drained, and
//!   bit-reproducible, on both transports.

use gossipgrad::config::{Algo, RunConfig, Transport};
use gossipgrad::coordinator::trainer::run_with_backend;
use gossipgrad::nativenet::NativeMlp;
use std::sync::Arc;

fn backend() -> gossipgrad::coordinator::worker::Backend {
    Arc::new(NativeMlp::new(vec![784, 16, 10], 16, 0))
}

fn cfg(algo: Algo, ranks: usize, steps: usize) -> RunConfig {
    RunConfig {
        model: "mlp".into(),
        algo,
        ranks,
        steps,
        rows_per_rank: 32,
        use_artifacts: false,
        ..Default::default()
    }
}

#[test]
fn flat_identity_inproc_group_size_one_and_p() {
    for algo in [Algo::Gossip, Algo::Agd] {
        let base = run_with_backend(&cfg(algo, 8, 10), backend()).unwrap();
        for group_size in [1usize, 8] {
            let mut c = cfg(algo, 8, 10);
            c.group_size = group_size;
            let res = run_with_backend(&c, backend())
                .unwrap_or_else(|e| panic!("{algo:?} g={group_size}: {e}"));
            assert_eq!(
                res.param_hash(),
                base.param_hash(),
                "{algo:?} group_size={group_size} must be bit-identical \
                 to the flat fabric"
            );
        }
    }
}

#[test]
fn flat_identity_hybrid_loopback_group_size_p() {
    // group_size = p mounts EVERY pair on the in-proc mailboxes (the
    // TCP mesh idles); the numerics must still match both the plain
    // socket mesh and the in-proc fabric
    for algo in [Algo::Gossip, Algo::Agd] {
        let inproc = run_with_backend(&cfg(algo, 4, 6), backend()).unwrap();
        let mut t = cfg(algo, 4, 6);
        t.transport = Transport::Tcp;
        let tcp = run_with_backend(&t, backend()).unwrap();
        let mut h = t.clone();
        h.group_size = 4;
        let hybrid = run_with_backend(&h, backend())
            .unwrap_or_else(|e| panic!("{algo:?} hybrid g=p: {e}"));
        assert_eq!(tcp.param_hash(), inproc.param_hash(), "{algo:?}");
        assert_eq!(
            hybrid.param_hash(),
            tcp.param_hash(),
            "{algo:?}: all-mailbox hybrid link diverged from the socket mesh"
        );
        assert_eq!(hybrid.in_flight_msgs, 0);
        assert_eq!(hybrid.in_flight_bytes, 0);
    }
}

#[test]
fn hybrid_link_is_numerically_transparent_on_collectives() {
    // a true two-group hybrid link (mailboxes inside, sockets between):
    // AGD's all-reduce schedule is group-oblivious, so the bits must
    // equal the plain TCP run's
    let mut t = cfg(Algo::Agd, 4, 6);
    t.transport = Transport::Tcp;
    let tcp = run_with_backend(&t, backend()).unwrap();
    let mut h = t.clone();
    h.group_size = 2;
    let hybrid = run_with_backend(&h, backend()).unwrap();
    assert_eq!(
        hybrid.param_hash(),
        tcp.param_hash(),
        "hybrid transport changed collective numerics"
    );
}

#[test]
fn two_level_schedule_actually_reroutes_gossip() {
    // 1 < group_size < p is the one region where routing may (and must)
    // differ from flat rotation — otherwise the locality win of
    // docs/topology.md would be a no-op
    let flat = run_with_backend(&cfg(Algo::Gossip, 8, 10), backend()).unwrap();
    let mut c = cfg(Algo::Gossip, 8, 10);
    c.group_size = 4;
    c.inter_period = 2;
    let two_level = run_with_backend(&c, backend()).unwrap();
    assert_ne!(
        two_level.param_hash(),
        flat.param_hash(),
        "two-level schedule routed identically to flat rotation"
    );
    assert!(
        two_level.max_disagreement() < 1.0,
        "two-level mixing failed to keep replicas coupled"
    );
}

#[test]
fn killed_rank_inside_a_group_survivors_reproduce() {
    // rank 3 dies at step 6 inside group 0 of a p = 8, group_size = 4
    // two-level run: the collapsed exchange must terminate, drain, and
    // be a pure function of the plan
    let mut c = cfg(Algo::Gossip, 8, 16);
    c.group_size = 4;
    c.inter_period = 2;
    c.fault_plan.kills = vec![(3, 6)];
    let a = run_with_backend(&c, backend()).unwrap();
    let b = run_with_backend(&c, backend()).unwrap();
    assert_eq!(a.survivors(), vec![0, 1, 2, 4, 5, 6, 7]);
    assert_eq!(a.per_rank[3].death_step, Some(6));
    assert_eq!(
        a.param_hash(),
        b.param_hash(),
        "a planned in-group kill must be bit-reproducible"
    );
    assert_eq!(a.in_flight_msgs, 0, "kill run leaked in-flight frames");
    assert_eq!(a.in_flight_bytes, 0, "kill run leaked in-flight bytes");
}

#[test]
fn killed_rank_over_hybrid_loopback_matches_inproc() {
    // the same in-group kill over the hybrid link: fault verdicts are a
    // pure function of the plan, so the socket/mailbox run reproduces
    // the in-proc run bit for bit
    let mut c = cfg(Algo::Gossip, 4, 10);
    c.group_size = 2;
    c.inter_period = 2;
    c.fault_plan.kills = vec![(1, 4)];
    let inproc = run_with_backend(&c, backend()).unwrap();
    let mut t = c.clone();
    t.transport = Transport::Tcp;
    let hybrid = run_with_backend(&t, backend()).unwrap();
    assert_eq!(
        hybrid.param_hash(),
        inproc.param_hash(),
        "in-group kill diverged between hybrid tcp and in-proc"
    );
    assert_eq!(hybrid.survivors(), vec![0, 2, 3]);
    assert_eq!(hybrid.in_flight_msgs, 0);
    assert_eq!(hybrid.in_flight_bytes, 0);
}
