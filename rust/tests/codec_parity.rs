//! Wire-codec integration: numerics parity, determinism, and the
//! measured efficiency win (docs/wire-codecs.md).
//!
//! Three guarantees pinned here:
//!
//! 1. **Identity parity** — `--codec f32` is a pure refactor of the
//!    old dense payload path: gossip/AGD/PS × layerwise produce the
//!    same `param_hash` over the in-process link and the loopback-TCP
//!    mesh (the wire must not reorder, truncate or re-encode frames).
//! 2. **Lossy determinism** — bf16/int8/top-k runs are run-to-run
//!    deterministic and transport-invariant: encode/decode are pure
//!    functions, so compressing the wire must not introduce timing-
//!    dependent numerics.
//! 3. **Measured win** — under the virtual clock a comm-bound schedule
//!    (parameter server) gets strictly faster steps from a smaller
//!    wire, because the fabric charges *compressed* bytes.

use gossipgrad::codec::Codec;
use gossipgrad::config::{Algo, RunConfig, Transport};
use gossipgrad::coordinator::trainer::run_with_backend;
use gossipgrad::nativenet::NativeMlp;
use gossipgrad::sim::Workload;
use std::sync::Arc;

fn tiny_backend() -> gossipgrad::coordinator::worker::Backend {
    Arc::new(NativeMlp::new(vec![784, 16, 10], 16, 0))
}

fn base(algo: Algo, codec: Codec) -> RunConfig {
    RunConfig {
        model: "mlp".into(),
        algo,
        ranks: 4,
        steps: 4,
        rows_per_rank: 32,
        use_artifacts: false,
        eval_every: 0,
        seed: 11,
        codec,
        ..Default::default()
    }
}

/// `--codec f32` must be bit-identical between the in-process link and
/// the loopback-TCP mesh for every payload-bearing schedule.
#[test]
fn identity_codec_is_bit_parity_across_transports() {
    for algo in [Algo::Gossip, Algo::Agd, Algo::ParamServer] {
        for layerwise in [false, true] {
            let mut c = base(algo, Codec::F32);
            c.layerwise = layerwise;
            let inproc = run_with_backend(&c, tiny_backend())
                .unwrap_or_else(|e| panic!("{algo:?} inproc: {e}"));
            let mut t = c.clone();
            t.transport = Transport::Tcp;
            let tcp = run_with_backend(&t, tiny_backend())
                .unwrap_or_else(|e| panic!("{algo:?} tcp: {e}"));
            assert_eq!(
                tcp.param_hash(),
                inproc.param_hash(),
                "{algo:?} layerwise={layerwise}: f32 codec numerics \
                 diverged across transports"
            );
            assert_eq!(tcp.in_flight_msgs, 0);
            assert_eq!(tcp.in_flight_bytes, 0);
        }
    }
}

/// bf16 and int8 gossip runs: run-to-run deterministic, and the same
/// bits over TCP as in-process (encode/decode are pure functions).
#[test]
fn lossy_codecs_are_deterministic_and_transport_invariant() {
    for codec in [Codec::Bf16, Codec::Int8] {
        let mut c = base(Algo::Gossip, codec);
        c.layerwise = true;
        let a = run_with_backend(&c, tiny_backend()).unwrap();
        let b = run_with_backend(&c, tiny_backend()).unwrap();
        assert_eq!(
            a.param_hash(),
            b.param_hash(),
            "{codec:?}: two identical runs disagreed"
        );
        let mut t = c.clone();
        t.transport = Transport::Tcp;
        let tcp = run_with_backend(&t, tiny_backend()).unwrap();
        assert_eq!(
            tcp.param_hash(),
            a.param_hash(),
            "{codec:?}: tcp numerics diverged from in-proc"
        );
        assert_eq!(tcp.in_flight_msgs, 0);
        assert_eq!(tcp.in_flight_bytes, 0);
    }
}

/// Top-k with error feedback: the sparse path must drain the fabric,
/// stay deterministic, and keep every parameter finite (the residual
/// accumulator must not blow up).
#[test]
fn topk_error_feedback_drains_and_stays_finite() {
    for layerwise in [false, true] {
        let mut c = base(Algo::Gossip, Codec::TopK);
        c.layerwise = layerwise;
        c.steps = 6;
        let a = run_with_backend(&c, tiny_backend()).unwrap();
        let b = run_with_backend(&c, tiny_backend()).unwrap();
        assert_eq!(a.param_hash(), b.param_hash());
        assert_eq!(a.in_flight_msgs, 0, "layerwise={layerwise}");
        assert_eq!(a.in_flight_bytes, 0, "layerwise={layerwise}");
        for (r, params) in a.final_params.iter().enumerate() {
            assert!(
                params.iter().all(|x| x.is_finite()),
                "layerwise={layerwise}: rank {r} has non-finite params"
            );
        }
    }
}

/// The byte half of the accounting seam: under the deterministic
/// virtual clock, a comm-bound schedule's step time shrinks when the
/// wire carries bf16 instead of f32 — the fabric charges compressed
/// bytes, so the efficiency win is visible on the measured path, not
/// just the closed-form curves.
#[test]
fn bf16_shrinks_virtual_clock_ps_steps() {
    let vcfg = |codec: Codec| {
        let mut c = base(Algo::ParamServer, codec);
        c.virtualize(&Workload::lenet3(4.0), 200e-6, 1.0 / 0.5e9);
        c
    };
    let dense = run_with_backend(&vcfg(Codec::F32), tiny_backend()).unwrap();
    let half = run_with_backend(&vcfg(Codec::Bf16), tiny_backend()).unwrap();
    assert!(
        half.mean_step_secs() < dense.mean_step_secs(),
        "bf16 step {:.6}s not faster than f32 {:.6}s",
        half.mean_step_secs(),
        dense.mean_step_secs()
    );
    assert!(half.mean_efficiency_pct() > dense.mean_efficiency_pct());
    assert_eq!(half.in_flight_msgs, 0);
    assert_eq!(half.in_flight_bytes, 0);
}
