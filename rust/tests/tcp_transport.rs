//! TCP link integration: numerics parity with the in-process link and
//! handshake failure modes.
//!
//! Every algorithm's message consumption is fully keyed — blocking
//! receives name their `(src, tag)` channel, never a wildcard — so the
//! final model bits are a pure function of the config, independent of
//! wire timing.  A p = 4 loopback-TCP run must therefore reproduce the
//! zero-cost in-process run's `param_hash` **bit for bit**; anything
//! else means the wire reordered, dropped or corrupted a frame.
//!
//! The handshake tests pin the failure modes documented in
//! docs/transport.md: wrong world size and wrong wire version must
//! error out on *both* sides of the connection, not hang.

use gossipgrad::config::{Algo, RunConfig, Transport};
use gossipgrad::coordinator::trainer::run_with_backend;
use gossipgrad::nativenet::NativeMlp;
use gossipgrad::transport::tcp::{HS_BAD_VERSION, HS_OK, WIRE_MAGIC};
use gossipgrad::transport::{CostModel, TcpLinkBuilder};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::thread;
use std::time::Duration;

fn tiny_backend() -> gossipgrad::coordinator::worker::Backend {
    Arc::new(NativeMlp::new(vec![784, 16, 10], 16, 0))
}

fn base(algo: Algo) -> RunConfig {
    RunConfig {
        model: "mlp".into(),
        algo,
        ranks: 4,
        steps: 4,
        rows_per_rank: 32,
        use_artifacts: false,
        eval_every: 0,
        seed: 11,
        ..Default::default()
    }
}

/// Gossip, AGD, PS — each with the layer-wise pipeline on and off — over
/// loopback TCP must match the in-proc zero-cost run bit for bit.
#[test]
fn tcp_numerics_match_inproc_bit_for_bit() {
    for algo in [Algo::Gossip, Algo::Agd, Algo::ParamServer] {
        for layerwise in [false, true] {
            let mut c = base(algo);
            c.layerwise = layerwise;
            let inproc = run_with_backend(&c, tiny_backend())
                .unwrap_or_else(|e| panic!("{algo:?} inproc: {e}"));
            let mut t = c.clone();
            t.transport = Transport::Tcp;
            let tcp = run_with_backend(&t, tiny_backend())
                .unwrap_or_else(|e| panic!("{algo:?} tcp: {e}"));
            assert_eq!(
                tcp.param_hash(),
                inproc.param_hash(),
                "{algo:?} layerwise={layerwise}: tcp numerics diverged from in-proc"
            );
            assert_eq!(
                tcp.in_flight_msgs, 0,
                "{algo:?} layerwise={layerwise}: leaked frames on the tcp mesh"
            );
            assert_eq!(tcp.per_rank.len(), c.ranks);
        }
    }
}

/// The non-blocking collective engine's wall-clock path over a real
/// socket mesh: comm-thread AGD numerics are identical to in-proc.
#[test]
fn tcp_comm_thread_agd_matches_inproc() {
    let mut c = base(Algo::Agd);
    c.layerwise = true;
    c.comm_thread = true;
    let inproc = run_with_backend(&c, tiny_backend()).unwrap();
    let mut t = c.clone();
    t.transport = Transport::Tcp;
    let tcp = run_with_backend(&t, tiny_backend()).unwrap();
    assert_eq!(tcp.param_hash(), inproc.param_hash());
    assert_eq!(tcp.in_flight_msgs, 0);
}

/// Sync-mix gossip blocks for the current step's partner model — the
/// schedule with the most exposed wire traffic — and must still match.
#[test]
fn tcp_sync_mix_gossip_matches_inproc() {
    let mut c = base(Algo::Gossip);
    c.sync_mix = true;
    let inproc = run_with_backend(&c, tiny_backend()).unwrap();
    let mut t = c.clone();
    t.transport = Transport::Tcp;
    let tcp = run_with_backend(&t, tiny_backend()).unwrap();
    assert_eq!(tcp.param_hash(), inproc.param_hash());
}

/// A peers-list (world size) mismatch must fail both establishes — the
/// rejected dialer and the rejecting acceptor — before their deadlines.
#[test]
fn handshake_rejects_wrong_world_size_instead_of_hanging() {
    let a = TcpLinkBuilder::bind("127.0.0.1:0").unwrap();
    let b = TcpLinkBuilder::bind("127.0.0.1:0").unwrap();
    let a_addr = a.local_addr().to_string();
    let b_addr = b.local_addr().to_string();
    let peers2 = vec![a_addr.clone(), b_addr.clone()];
    // rank 1 believes the world has three ranks (third addr never
    // answers — its handshake to rank 0 announces p=3 and is rejected
    // before that matters)
    let peers3 = vec![a_addr, b_addr, "127.0.0.1:1".into()];
    let ha = thread::spawn(move || {
        a.establish(0, &peers2, CostModel::zero(), Duration::from_secs(15))
    });
    let hb = thread::spawn(move || {
        b.establish(1, &peers3, CostModel::zero(), Duration::from_secs(15))
    });
    let ra = ha.join().unwrap();
    let rb = hb.join().unwrap();
    assert!(ra.is_err(), "p=2 side accepted a p=3 handshake");
    assert!(rb.is_err(), "p=3 side should have been rejected");
    let msg = format!("{:#}", rb.err().unwrap());
    assert!(
        msg.contains("world-size") || msg.contains("rejected"),
        "error should name the mismatch: {msg}"
    );
}

/// A wire-version mismatch is acked with `HS_BAD_VERSION` and errors
/// the acceptor out (mixed binary versions must not hang a launch).
#[test]
fn handshake_rejects_wrong_version_instead_of_hanging() {
    let a = TcpLinkBuilder::bind("127.0.0.1:0").unwrap();
    let addr = a.local_addr();
    let peers = vec![addr.to_string(), "127.0.0.1:1".into()];
    let h = thread::spawn(move || {
        a.establish(0, &peers, CostModel::zero(), Duration::from_secs(15))
    });
    // raw peer speaking a future wire version
    let mut s = TcpStream::connect(addr).unwrap();
    let mut hs = [0u8; 16];
    hs[0..4].copy_from_slice(&WIRE_MAGIC.to_le_bytes());
    hs[4..8].copy_from_slice(&999u32.to_le_bytes()); // bad version
    hs[8..12].copy_from_slice(&2u32.to_le_bytes());
    hs[12..16].copy_from_slice(&1u32.to_le_bytes());
    s.write_all(&hs).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut ack = [0u8; 4];
    s.read_exact(&mut ack).unwrap();
    let code = u32::from_le_bytes(ack);
    assert_ne!(code, HS_OK, "bad version must not be acked OK");
    assert_eq!(code, HS_BAD_VERSION);
    // the acceptor error aborts the whole establish (dial side included)
    let r = h.join().unwrap();
    assert!(r.is_err(), "establish must fail after a version rejection");
}
