//! Differential tests for the cooperative rank scheduler (docs/perf.md,
//! "rank scheduler"): scheduled runs must be **bit-identical** to the
//! legacy thread-per-rank oracle (`--legacy-ranks`) across algorithms,
//! schedules and fault plans; results must not depend on the worker
//! count (`--sim-threads`); and every scheduled run must drain the
//! fabric clean.
//!
//! "Bit-identical" is asserted on the canonical sweep-artifact string —
//! [`ScenarioReport::to_json`] — which covers `param_hash`, every
//! virtual-time metric (step time, efficiency, overlap), and the
//! ledger/drain gauges.

use gossipgrad::config::{Algo, CostModelKind, RunConfig};
use gossipgrad::coordinator;
use gossipgrad::exp::ScenarioReport;
use gossipgrad::sim::Workload;

/// Small virtual-clock scenario: p = 8, layer table from LeNet3, slow
/// wire so communication (and therefore scheduling) actually matters.
fn base(algo: Algo) -> RunConfig {
    let mut cfg = RunConfig {
        model: "mlp-small".into(),
        algo,
        ranks: 8,
        steps: 6,
        use_artifacts: false,
        rows_per_rank: 32,
        ..Default::default()
    };
    cfg.virtualize(&Workload::lenet3(4.0), 200e-6, 1.0 / 0.5e9);
    cfg
}

/// Canonical deterministic serialization of a run (the same string the
/// sweep artifacts are built from).
fn canon(cfg: &RunConfig) -> String {
    let res = coordinator::run(cfg).expect("run");
    ScenarioReport::from_run(cfg, &res).to_json().to_string()
}

/// Scheduled (bounded pool, 4 workers) vs legacy (thread-per-rank) —
/// the full reports must be byte-equal.
fn assert_parity(mut cfg: RunConfig) {
    cfg.legacy_ranks = true;
    let legacy = canon(&cfg);
    cfg.legacy_ranks = false;
    cfg.sim_threads = 4;
    let sched = canon(&cfg);
    assert_eq!(sched, legacy, "scheduler diverged from thread-per-rank oracle");
}

#[test]
fn gossip_monolithic_matches_legacy() {
    assert_parity(base(Algo::Gossip));
}

#[test]
fn gossip_layerwise_sync_mix_matches_legacy() {
    let mut c = base(Algo::Gossip);
    c.layerwise = true;
    c.sync_mix = true;
    assert_parity(c);
}

#[test]
fn agd_layerwise_comm_thread_matches_legacy() {
    let mut c = base(Algo::Agd);
    c.layerwise = true;
    c.comm_thread = true;
    assert_parity(c);
}

#[test]
fn periodic_agd_matches_legacy() {
    assert_parity(base(Algo::PeriodicAgd));
}

#[test]
fn param_server_layerwise_matches_legacy() {
    let mut c = base(Algo::ParamServer);
    c.layerwise = true;
    assert_parity(c);
}

#[test]
fn gossip_kill_fault_plan_matches_legacy() {
    let mut c = base(Algo::Gossip);
    c.fault_plan.kills = vec![(1, 3)];
    assert_parity(c);
}

#[test]
fn gossip_drop_dup_chaos_matches_legacy() {
    let mut c = base(Algo::Gossip);
    c.fault_plan.drop_frac = 0.05;
    c.fault_plan.dup_frac = 0.05;
    c.fault_plan.seed = 11;
    assert_parity(c);
}

#[test]
fn gossip_hierarchical_fabric_matches_legacy() {
    let mut c = base(Algo::Gossip);
    c.group_size = 4;
    c.inter_period = 2;
    c.cost_model = CostModelKind::Hier;
    assert_parity(c);
}

#[test]
fn worker_count_does_not_change_results() {
    let mut c = base(Algo::Gossip);
    c.layerwise = true;
    c.sim_threads = 1;
    let one = canon(&c);
    c.sim_threads = 4;
    let four = canon(&c);
    c.sim_threads = 0; // default: available cores
    let cores = canon(&c);
    assert_eq!(one, four, "1-worker vs 4-worker runs diverged");
    assert_eq!(four, cores, "4-worker vs all-cores runs diverged");
}

#[test]
fn scheduled_runs_drain_the_fabric() {
    for algo in [Algo::Gossip, Algo::Agd, Algo::ParamServer] {
        let mut c = base(algo);
        c.sim_threads = 2;
        let res = coordinator::run(&c).expect("run");
        assert_eq!(res.in_flight_msgs, 0, "{}: leaked messages", algo.name());
        assert_eq!(res.in_flight_bytes, 0, "{}: leaked bytes", algo.name());
    }
}
