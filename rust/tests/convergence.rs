//! Integration tests for the paper's §6 convergence claims, run end to
//! end through trainer + transport + native backend (no artifacts
//! needed, so these run everywhere).

use gossipgrad::config::{Algo, RunConfig};
use gossipgrad::coordinator::trainer::run_with_backend;
use gossipgrad::nativenet::NativeMlp;
use std::sync::Arc;

fn base_cfg(algo: Algo, ranks: usize, steps: usize) -> RunConfig {
    RunConfig {
        model: "mlp".into(),
        algo,
        ranks,
        steps,
        lr: 0.05,
        rows_per_rank: 192,
        eval_every: steps,
        use_artifacts: false,
        seed: 42,
        ..Default::default()
    }
}

fn tiny_backend() -> gossipgrad::coordinator::worker::Backend {
    // 784-dim input (matches the MNIST-analog dataset) but a small net
    Arc::new(NativeMlp::new(vec![784, 64, 10], 32, 0))
}

#[test]
fn gossip_learns_and_models_agree() {
    let cfg = base_cfg(Algo::Gossip, 8, 120);
    let res = run_with_backend(&cfg, tiny_backend()).unwrap();
    let acc = res.final_accuracy.expect("accuracy recorded");
    assert!(acc > 0.9, "gossip accuracy {acc}");
    // Corollary 6.3: models converge toward a single model.  With
    // mixing every step, cross-rank disagreement stays tiny relative
    // to parameter scale.
    let dis = res.max_disagreement();
    assert!(dis < 0.1, "disagreement {dis}");
}

#[test]
fn agd_learns_and_models_identical() {
    let cfg = base_cfg(Algo::Agd, 4, 80);
    let res = run_with_backend(&cfg, tiny_backend()).unwrap();
    assert!(res.final_accuracy.unwrap() > 0.9);
    // synchronous all-reduce keeps replicas bit-identical
    assert_eq!(res.max_disagreement(), 0.0);
}

#[test]
fn sgd_sync_matches_agd_updates() {
    // AGD (layer-wise) and SGD (whole-model) average the same gradients
    // => identical final models given the same seed/batches.
    let a = run_with_backend(&base_cfg(Algo::Agd, 4, 30), tiny_backend()).unwrap();
    let b =
        run_with_backend(&base_cfg(Algo::SgdSync, 4, 30), tiny_backend()).unwrap();
    let max_diff = a.final_params[0]
        .iter()
        .zip(&b.final_params[0])
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-4, "AGD vs SGD diverged: {max_diff}");
}

#[test]
fn periodic_agd_learns() {
    let cfg = base_cfg(Algo::PeriodicAgd, 8, 120);
    let res = run_with_backend(&cfg, tiny_backend()).unwrap();
    assert!(res.final_accuracy.unwrap() > 0.85);
}

#[test]
fn param_server_learns_and_models_identical() {
    let cfg = base_cfg(Algo::ParamServer, 4, 80);
    let res = run_with_backend(&cfg, tiny_backend()).unwrap();
    assert!(res.final_accuracy.unwrap() > 0.9);
    assert_eq!(res.max_disagreement(), 0.0);
}

#[test]
fn random_gossip_learns_but_gossip_is_no_worse() {
    let r = run_with_backend(&base_cfg(Algo::GossipRandom, 8, 120), tiny_backend())
        .unwrap();
    let g =
        run_with_backend(&base_cfg(Algo::Gossip, 8, 120), tiny_backend()).unwrap();
    let (ra, ga) = (r.final_accuracy.unwrap(), g.final_accuracy.unwrap());
    assert!(ra > 0.5, "random gossip acc {ra}");
    assert!(ga + 0.05 >= ra, "dissemination {ga} much worse than random {ra}");
}

#[test]
fn gossip_hypercube_learns() {
    let cfg = base_cfg(Algo::GossipHypercube, 8, 100);
    let res = run_with_backend(&cfg, tiny_backend()).unwrap();
    assert!(res.final_accuracy.unwrap() > 0.85);
}

#[test]
fn gossip_without_rotation_or_shuffle_still_learns() {
    // ablation: the §4.5 heuristics improve diffusion, but the core
    // algorithm must converge without them
    let mut cfg = base_cfg(Algo::Gossip, 8, 120);
    cfg.rotation = false;
    cfg.sample_shuffle = false;
    let res = run_with_backend(&cfg, tiny_backend()).unwrap();
    assert!(res.final_accuracy.unwrap() > 0.85);
}

#[test]
fn gossip_message_complexity_is_o1() {
    // Table 1's central claim measured on the wire: gossip messages per
    // rank per step stay constant as p doubles, AGD's grow ~log p.
    let mut gossip_rates = Vec::new();
    let mut agd_rates = Vec::new();
    for ranks in [4usize, 8, 16] {
        let mut cfg = base_cfg(Algo::Gossip, ranks, 20);
        cfg.sample_shuffle = false; // isolate gradient traffic
        let res = run_with_backend(&cfg, tiny_backend()).unwrap();
        let per = res.per_rank.iter().map(|m| m.msgs_sent).sum::<u64>() as f64
            / (ranks * 20) as f64;
        gossip_rates.push(per);

        let mut cfg = base_cfg(Algo::SgdSync, ranks, 20);
        cfg.sample_shuffle = false;
        let res = run_with_backend(&cfg, tiny_backend()).unwrap();
        let per = res.per_rank.iter().map(|m| m.msgs_sent).sum::<u64>() as f64
            / (ranks * 20) as f64;
        agd_rates.push(per);
    }
    // gossip: constant (layers per step, independent of p)
    assert!(
        (gossip_rates[0] - gossip_rates[2]).abs() < 0.5,
        "gossip rates {gossip_rates:?}"
    );
    // allreduce: strictly growing with p
    assert!(
        agd_rates[2] > agd_rates[1] && agd_rates[1] > agd_rates[0],
        "agd rates {agd_rates:?}"
    );
}

#[test]
fn disagreement_shrinks_with_more_gossip() {
    // §6 mixing: continuing to gossip with lr -> 0 contracts the models
    // toward consensus.
    let mut cfg = base_cfg(Algo::Gossip, 8, 30);
    cfg.lr = 0.05;
    let short = run_with_backend(&cfg, tiny_backend()).unwrap();
    let mut cfg2 = base_cfg(Algo::Gossip, 8, 200);
    cfg2.lr_schedule = gossipgrad::config::LrSchedule::Step {
        every: 60,
        gamma: 0.1,
    };
    let long = run_with_backend(&cfg2, tiny_backend()).unwrap();
    assert!(
        long.max_disagreement() < short.max_disagreement(),
        "disagreement did not shrink: short {} vs long {}",
        short.max_disagreement(),
        long.max_disagreement()
    );
}

#[test]
fn krizhevsky_scaling_only_affects_allreduce_family() {
    let mut g = base_cfg(Algo::Gossip, 16, 1);
    g.krizhevsky_lr_scaling = true;
    assert_eq!(g.effective_lr(), g.lr);
    let mut a = base_cfg(Algo::Agd, 16, 1);
    a.krizhevsky_lr_scaling = true;
    assert!((a.effective_lr() - a.lr * 4.0).abs() < 1e-12);
}
