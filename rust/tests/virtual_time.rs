//! Virtual-clock fabric integration tests: determinism at scale
//! (p = 256), overlap/exposed-wait accounting, the step-0 gossip skip,
//! and the per-rank exposed-wait metric surface.
//!
//! All tests use the native backend (no artifacts needed) and small
//! models so real compute stays cheap; the *simulated* timing comes from
//! the calibrated workload model and is asserted bit-for-bit.

use gossipgrad::config::{Algo, RunConfig};
use gossipgrad::coordinator::trainer::{run_with_backend, RunResult};
use gossipgrad::nativenet::NativeMlp;
use gossipgrad::sim::Workload;
use std::sync::Arc;

fn tiny_backend() -> gossipgrad::coordinator::worker::Backend {
    Arc::new(NativeMlp::new(vec![784, 16, 10], 16, 0))
}

/// LeNet3-calibrated virtual-clock config on the slow fabric the wall
/// benches use (200 µs / 0.5 GB/s), so exchanges are visible but
/// hideable under the 6.25 ms compute window.
fn vcfg(algo: Algo, ranks: usize, steps: usize) -> RunConfig {
    let mut c = RunConfig {
        model: "mlp".into(),
        algo,
        ranks,
        steps,
        rows_per_rank: 32,
        use_artifacts: false,
        eval_every: 0,
        seed: 42,
        ..Default::default()
    };
    c.virtualize(&Workload::lenet3(4.0), 200e-6, 1.0 / 0.5e9);
    c
}

fn assert_identical(a: &RunResult, b: &RunResult) {
    assert_eq!(a.per_rank.len(), b.per_rank.len());
    for (ma, mb) in a.per_rank.iter().zip(&b.per_rank) {
        assert_eq!(ma.rank, mb.rank);
        // timing metrics are simulated seconds: bit-identical, not close
        assert_eq!(ma.step_secs, mb.step_secs, "rank {}", ma.rank);
        assert_eq!(ma.comm_wait_secs, mb.comm_wait_secs, "rank {}", ma.rank);
        assert_eq!(ma.recv_wait_secs, mb.recv_wait_secs, "rank {}", ma.rank);
        assert_eq!(ma.comm_hidden_secs, mb.comm_hidden_secs, "rank {}", ma.rank);
        assert_eq!(ma.loss, mb.loss, "rank {}", ma.rank);
        assert_eq!(ma.msgs_sent, mb.msgs_sent, "rank {}", ma.rank);
        assert_eq!(ma.bytes_sent, mb.bytes_sent, "rank {}", ma.rank);
    }
    assert_eq!(a.final_params, b.final_params, "model bits diverged");
}

#[test]
fn virtual_clock_p256_is_deterministic_and_fast() {
    // the Fig 10/11 acceptance point: a p = 256 virtual-clock run
    // finishes in seconds of wall time and two runs with the same seed
    // produce identical metrics
    let t0 = std::time::Instant::now();
    let a = run_with_backend(&vcfg(Algo::Gossip, 256, 6), tiny_backend()).unwrap();
    let b = run_with_backend(&vcfg(Algo::Gossip, 256, 6), tiny_backend()).unwrap();
    let wall = t0.elapsed().as_secs_f64();
    assert_identical(&a, &b);
    assert!(
        wall < 10.0,
        "two p=256 virtual runs took {wall:.1}s wall (budget 10s)"
    );
    // simulated step time is the compute window + exposed waits — it
    // must not be contaminated by real wall time
    let w = Workload::lenet3(4.0);
    for m in &a.per_rank {
        for &s in &m.step_secs {
            assert!(
                s >= w.t_compute() - 1e-12 && s < 1.0,
                "simulated step {s}s out of range"
            );
        }
    }
}

#[test]
fn virtual_determinism_covers_agd_and_random_gossip() {
    for algo in [Algo::Agd, Algo::GossipRandom] {
        let a = run_with_backend(&vcfg(algo, 16, 5), tiny_backend()).unwrap();
        let b = run_with_backend(&vcfg(algo, 16, 5), tiny_backend()).unwrap();
        assert_identical(&a, &b);
    }
}

#[test]
fn virtual_overlap_hides_gossip_exchange() {
    // 6.25 ms compute window >> ~700 µs of per-step messages: the async
    // exchange must be (almost) fully hidden
    let res = run_with_backend(&vcfg(Algo::Gossip, 8, 12), tiny_backend()).unwrap();
    assert!(
        res.mean_efficiency_pct() > 95.0,
        "gossip efficiency {:.1}% — overlap not working",
        res.mean_efficiency_pct()
    );
}

#[test]
fn virtual_exposed_wait_appears_when_compute_shrinks() {
    // shrink the compute window to 10 µs: the same exchange is now
    // exposed, shows up in efficiency AND in the per-rank recv_wait
    // metric surfaced from the transport counters
    let mut c = vcfg(Algo::Gossip, 8, 12);
    c.virt_compute_secs = 1e-5;
    let res = run_with_backend(&c, tiny_backend()).unwrap();
    assert!(
        res.mean_efficiency_pct() < 90.0,
        "expected exposed comm, got {:.1}%",
        res.mean_efficiency_pct()
    );
    assert!(
        res.per_rank.iter().all(|m| m.recv_wait_secs > 0.0),
        "per-rank exposed wait must be surfaced in RunMetrics"
    );
    // comm_wait (drain sections) is contained in recv_wait (all blocking)
    for m in &res.per_rank {
        let drained: f64 = m.comm_wait_secs.iter().sum();
        assert!(
            drained <= m.recv_wait_secs + 1e-9,
            "rank {}: drain wait {drained} > total recv wait {}",
            m.rank,
            m.recv_wait_secs
        );
    }
}

#[test]
fn gossip_skips_step_zero_exchange() {
    // all replicas hold the identical initial model at step 0 — the
    // exchange starts at step 1, so gradient traffic is layers*(steps-1)
    let backend = tiny_backend();
    let layers = backend.layers().len() as u64;
    let mut c = vcfg(Algo::Gossip, 4, 5);
    c.sample_shuffle = false; // isolate gradient traffic
    let res = run_with_backend(&c, backend).unwrap();
    for m in &res.per_rank {
        assert_eq!(
            m.msgs_sent,
            layers * 4,
            "rank {}: expected {} layer messages over steps 1..=4",
            m.rank,
            layers * 4
        );
    }
}

// ---- layer-wise asynchronous pipeline ---------------------------------

/// The pipelined schedule re-times the step (per-layer compute slices,
/// per-layer sends at grad-ready instants) but must not re-number it:
/// the same elementwise mix/update ops run in the same per-element
/// order, so the final model is bit-identical to the monolithic
/// exchange.  Straggler jitter is enabled to prove the numerics are
/// independent of the timing model entirely.
#[test]
fn layerwise_pipeline_is_bit_identical_to_monolithic() {
    for algo in [Algo::Gossip, Algo::GossipRandom, Algo::Agd, Algo::ParamServer]
    {
        let mut mono = vcfg(algo, 8, 6);
        mono.straggler_jitter = 0.2;
        let mut pipe = mono.clone();
        pipe.layerwise = true;
        let a = run_with_backend(&mono, tiny_backend()).unwrap();
        let b = run_with_backend(&pipe, tiny_backend()).unwrap();
        assert_eq!(
            a.final_params, b.final_params,
            "{algo:?}: layer-wise pipeline changed the numerics"
        );
        for (ma, mb) in a.per_rank.iter().zip(&b.per_rank) {
            assert_eq!(ma.loss, mb.loss, "{algo:?} rank {}", ma.rank);
        }
    }
}

/// The overlap metric is part of the deterministic surface: two p = 256
/// pipelined runs must agree bit-for-bit on overlap_frac (and the
/// hidden/exposed split behind it).
#[test]
fn layerwise_overlap_frac_deterministic_at_p256() {
    let mut c = vcfg(Algo::Gossip, 256, 5);
    c.layerwise = true;
    let a = run_with_backend(&c, tiny_backend()).unwrap();
    let b = run_with_backend(&c, tiny_backend()).unwrap();
    assert_identical(&a, &b);
    for (ma, mb) in a.per_rank.iter().zip(&b.per_rank) {
        assert_eq!(ma.comm_hidden_secs, mb.comm_hidden_secs, "rank {}", ma.rank);
        assert_eq!(
            ma.overlap_frac().to_bits(),
            mb.overlap_frac().to_bits(),
            "rank {}",
            ma.rank
        );
        let f = ma.overlap_frac();
        assert!((0.0..=1.0).contains(&f), "overlap_frac {f} out of range");
    }
    // the 6.25 ms compute window dwarfs the ~700 µs of per-step
    // messages: the pipelined exchange must be almost entirely hidden
    assert!(
        a.mean_overlap_frac() > 0.9,
        "pipelined overlap {:.3} — exchange not hidden",
        a.mean_overlap_frac()
    );
}

// ---- comm-thread AGD (non-blocking collective engine) -----------------

/// The comm-thread schedule must not change a single bit of the math:
/// the same reductions run in the same order, only the timing model
/// (who waits when) differs.
#[test]
fn comm_thread_agd_numerics_identical_to_blocking() {
    let mut blocking = vcfg(Algo::Agd, 8, 6);
    blocking.layerwise = true;
    blocking.straggler_jitter = 0.2;
    let mut ct = blocking.clone();
    ct.comm_thread = true;
    let a = run_with_backend(&blocking, tiny_backend()).unwrap();
    let b = run_with_backend(&ct, tiny_backend()).unwrap();
    assert_eq!(
        a.final_params, b.final_params,
        "comm-thread engine changed the numerics"
    );
    for (ma, mb) in a.per_rank.iter().zip(&b.per_rank) {
        assert_eq!(ma.loss, mb.loss, "rank {}", ma.rank);
        assert_eq!(ma.msgs_sent, mb.msgs_sent, "rank {}", ma.rank);
        assert_eq!(ma.bytes_sent, mb.bytes_sent, "rank {}", ma.rank);
    }
}

/// With the modeled comm-progress thread, collective rounds advance
/// under later backprop slices: overlap_frac must be strictly above the
/// blocking schedule's, and the measured step time must match the
/// closed-form overlapped-AGD curve.
#[test]
fn comm_thread_agd_overlaps_and_matches_closed_form() {
    let backend = tiny_backend();
    let mut blocking = vcfg(Algo::Agd, 16, 6);
    blocking.layerwise = true;
    blocking.sample_shuffle = false; // isolate collective traffic
    let mut ct = blocking.clone();
    ct.comm_thread = true;
    let a = run_with_backend(&blocking, tiny_backend()).unwrap();
    let b = run_with_backend(&ct, tiny_backend()).unwrap();
    assert!(
        b.mean_overlap_frac() > a.mean_overlap_frac(),
        "comm thread must hide wire time the blocking chain exposes: \
         {:.4} !> {:.4}",
        b.mean_overlap_frac(),
        a.mean_overlap_frac()
    );
    assert!(
        b.mean_step_secs() <= a.mean_step_secs() + 1e-12,
        "comm thread cannot be slower than the blocking chain"
    );
    // analytic twin: same layer table, same α–β, no overheads
    let wl = Workload::standin(
        ct.virt_fwd_secs,
        ct.virt_compute_secs - ct.virt_fwd_secs,
        backend.layers().iter().rev().map(|l| l.len * 4).collect(),
    );
    let want = gossipgrad::sim::efficiency::overlapped_agd_step_time(
        gossipgrad::collectives::Algorithm::RecursiveDoubling,
        &wl,
        16,
        &ct.cost_model(),
    );
    let got = b.mean_step_secs();
    assert!(
        (got - want).abs() / want < 0.05,
        "measured comm-thread AGD {got}s vs closed form {want}s"
    );
}

/// Determinism at scale: two p = 256 comm-thread AGD runs must agree
/// bit-for-bit on every metric (the CI smoke asserts the same through
/// the CLI).
#[test]
fn comm_thread_agd_deterministic_at_p256() {
    let mk = || {
        let mut c = vcfg(Algo::Agd, 256, 4);
        c.layerwise = true;
        c.comm_thread = true;
        c
    };
    let a = run_with_backend(&mk(), tiny_backend()).unwrap();
    let b = run_with_backend(&mk(), tiny_backend()).unwrap();
    assert_identical(&a, &b);
    for (ma, mb) in a.per_rank.iter().zip(&b.per_rank) {
        assert_eq!(
            ma.overlap_frac().to_bits(),
            mb.overlap_frac().to_bits(),
            "rank {}",
            ma.rank
        );
    }
    assert_eq!(a.in_flight_msgs, 0, "comm-thread run left messages queued");
    assert_eq!(a.in_flight_bytes, 0, "comm-thread run left bytes queued");
}

// ---- sample-shuffle starvation accounting -----------------------------

/// Regression (shuffle.rs take()): when the local batch buffer drains
/// faster than the slow ring link refills it, take() blocks on the
/// oldest in-flight receive; that stall must appear in the per-step
/// comm ledger (comm_wait_secs) and therefore in efficiency — it used
/// to be invisible, letting sample starvation masquerade as compute.
#[test]
fn shuffle_starvation_is_charged_as_comm_wait() {
    let mut c = vcfg(Algo::Gossip, 4, 6);
    c.gossip_period = 100; // no gradient traffic: isolate the sample ring
    // shrink the compute window below the ~300 µs batch wire time: the
    // two-batch local buffer drains faster than the ring refills it,
    // so take() starves every step once the buffer is gone
    c.virt_compute_secs = 1e-4;
    c.virt_fwd_secs = 0.0;
    let res = run_with_backend(&c, tiny_backend()).unwrap();
    for m in &res.per_rank {
        // the first two steps eat the local batches; later steps wait
        // for the ring refill
        let starved: f64 = m.comm_wait_secs[2..].iter().sum();
        assert!(
            starved > 0.0,
            "rank {}: sample starvation invisible in comm_wait",
            m.rank
        );
        // only shuffle traffic exists, so the drain-bracketed waits are
        // exactly the transport's total exposed wait
        let total: f64 = m.comm_wait_secs.iter().sum();
        assert!(
            (total - m.recv_wait_secs).abs() < 1e-9,
            "rank {}: comm_wait {total} != recv_wait {}",
            m.rank,
            m.recv_wait_secs
        );
    }
    assert!(
        res.mean_efficiency_pct() < 100.0,
        "starvation must dent efficiency"
    );
    assert_eq!(res.in_flight_msgs, 0);
    assert_eq!(res.in_flight_bytes, 0);
}

/// Deterministic per-(rank, step) jitter on the measured fabric
/// reproduces the sim/straggler.rs ablation: the all-reduce barrier
/// amplifies straggler noise; gossip, waiting on one partner, does not.
#[test]
fn measured_jitter_reproduces_straggler_ablation() {
    let mk = |algo: Algo| {
        let mut c = vcfg(algo, 16, 12);
        c.straggler_jitter = 0.3;
        c.layerwise = true;
        c
    };
    let gossip = run_with_backend(&mk(Algo::Gossip), tiny_backend()).unwrap();
    let gossip2 = run_with_backend(&mk(Algo::Gossip), tiny_backend()).unwrap();
    assert_identical(&gossip, &gossip2);
    let agd = run_with_backend(&mk(Algo::Agd), tiny_backend()).unwrap();
    assert!(
        agd.mean_step_secs() > gossip.mean_step_secs(),
        "barrier schedule must amplify jitter: agd {:.4}s vs gossip {:.4}s",
        agd.mean_step_secs(),
        gossip.mean_step_secs()
    );
    // jitter slows the mean step beyond the nominal compute window
    let w = Workload::lenet3(4.0);
    assert!(gossip.mean_step_secs() > w.t_compute());
}

/// Fig 2(a): with server-side aggregation + serialized broadcast
/// charged on the PS rank, the parameter-server bottleneck appears as
/// worker efficiency collapsing with scale.
#[test]
fn virtual_ps_bottleneck_grows_with_scale() {
    let eff = |ranks: usize| {
        let mut c = vcfg(Algo::ParamServer, ranks, 6);
        c.layerwise = true;
        run_with_backend(&c, tiny_backend())
            .unwrap()
            .mean_efficiency_pct()
    };
    let e4 = eff(4);
    let e16 = eff(16);
    assert!(
        e16 < e4 - 3.0,
        "PS bottleneck must grow with p: eff(4)={e4:.1}% eff(16)={e16:.1}%"
    );
}

#[test]
fn wall_mode_still_measures_real_time() {
    // regression guard: the default (wall) path still produces real,
    // positive step timings after the clock refactor
    let mut c = vcfg(Algo::Gossip, 4, 5);
    c.virtual_clock = false;
    c.virt_compute_secs = 0.0;
    c.net_alpha = 0.0;
    c.net_beta = 0.0;
    let res = run_with_backend(&c, tiny_backend()).unwrap();
    for m in &res.per_rank {
        assert_eq!(m.step_secs.len(), 5);
        assert!(m.step_secs.iter().all(|&s| s > 0.0));
    }
}
