//! Experiment-engine integration tests: sweep determinism across host
//! thread counts, cache-hit semantics, report fidelity vs direct runs,
//! and the gossip-period autotuner's gates.
//!
//! The engine's core contract: scenarios are independent deterministic
//! virtual-clock runs, so *how* the work-stealing pool schedules them
//! (1 thread, N threads, cache-warm, cache-cold) must never show up in
//! the serialized artifacts.

use gossipgrad::config::{Algo, RunConfig};
use gossipgrad::exp::{autotune, Engine, Grid};
use gossipgrad::sim::Workload;
use std::path::PathBuf;

/// A small virtual-clock gossip base: LeNet3 compute model on the
/// mlp-small native backend, measurably slow fabric.
fn small_base() -> RunConfig {
    let mut base = RunConfig {
        model: "mlp-small".into(),
        algo: Algo::Gossip,
        ranks: 4,
        steps: 6,
        use_artifacts: false,
        rows_per_rank: 32,
        layerwise: true,
        ..Default::default()
    };
    base.virtualize(&Workload::lenet3(4.0), 200e-6, 1.0 / 0.5e9);
    base
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("gg_exp_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn n_thread_sweep_is_byte_identical_to_single_thread() {
    let grid = Grid::new(small_base())
        .algos(&[Algo::Gossip, Algo::Agd])
        .ranks(&[2, 4])
        .jitters(&[0.0, 0.2]);
    let s1 = Engine::with_threads(1).run(&grid).expect("1-thread sweep");
    let s4 = Engine::with_threads(4).run(&grid).expect("4-thread sweep");
    assert_eq!(s1.reports.len(), 8);
    assert_eq!(
        s1.to_json().to_string(),
        s4.to_json().to_string(),
        "host parallelism leaked into the artifact"
    );
    assert_eq!(s1.to_csv(), s4.to_csv());
    assert_eq!(s1.runs_executed, 8);
    assert_eq!((s1.cache_hits, s4.cache_hits), (0, 0), "no cache attached");
    // reports come back in grid order no matter which worker ran what
    for (report, cfg) in s4.reports.iter().zip(grid.scenarios()) {
        assert_eq!(report.config, cfg);
        assert_eq!(report.key, cfg.content_hash());
        assert_eq!(report.in_flight_msgs, 0, "fabric must drain");
        assert_eq!(report.in_flight_bytes, 0, "fabric must drain bytes too");
    }
}

#[test]
fn cache_hit_returns_identical_artifact_without_rerunning() {
    let dir = tmp_dir("cache");
    let grid = Grid::new(small_base()).gossip_periods(&[1, 3]);
    let engine = Engine::with_threads(2).cached(&dir);
    let cold = engine.run(&grid).expect("cold sweep");
    assert_eq!(cold.runs_executed, 2, "cold cache runs everything");
    assert_eq!(cold.cache_hits, 0);
    // a *fresh* engine (empty in-memory memo) must be served entirely
    // from the on-disk cache
    let warm = Engine::with_threads(2)
        .cached(&dir)
        .run(&grid)
        .expect("warm sweep");
    assert_eq!(warm.runs_executed, 0, "warm cache must not re-run");
    assert_eq!(warm.cache_hits, 2);
    assert_eq!(
        cold.to_json().to_string(),
        warm.to_json().to_string(),
        "cache hits must reproduce the artifact byte-identically"
    );
    // ... and through write_artifacts on disk too
    let (j1, c1) = cold.write_artifacts(&dir.join("out1"), "sweep").unwrap();
    let (j2, c2) = warm.write_artifacts(&dir.join("out2"), "sweep").unwrap();
    assert_eq!(std::fs::read(&j1).unwrap(), std::fs::read(&j2).unwrap());
    assert_eq!(std::fs::read(&c1).unwrap(), std::fs::read(&c2).unwrap());
    assert!(j1.file_name().unwrap().to_str().unwrap() == "BENCH_sweep.json");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn engine_memoizes_repeated_scenarios_in_memory() {
    // no cache dir: the second run on the *same* engine value is served
    // from its in-memory memo — this is what lets `sweep
    // --autotune-period` reuse the sweep's own runs
    let grid = Grid::new(small_base()).gossip_periods(&[1, 2]);
    let engine = Engine::with_threads(2);
    let first = engine.run(&grid).expect("first run");
    assert_eq!((first.runs_executed, first.cache_hits), (2, 0));
    let again = engine.run(&grid).expect("memoized run");
    assert_eq!((again.runs_executed, again.cache_hits), (0, 2));
    assert_eq!(first.to_json().to_string(), again.to_json().to_string());
}

#[test]
fn engine_report_matches_a_direct_coordinator_run() {
    let base = small_base();
    let sweep = Engine::with_threads(2)
        .run(&Grid::new(base.clone()))
        .expect("singleton sweep");
    assert_eq!(sweep.reports.len(), 1);
    let r = &sweep.reports[0];
    let direct = gossipgrad::coordinator::run(&base).expect("direct run");
    assert_eq!(r.param_hash, format!("{:016x}", direct.param_hash()));
    assert_eq!(r.mean_step_secs, direct.mean_step_secs());
    assert_eq!(r.mean_efficiency_pct, direct.mean_efficiency_pct());
    assert_eq!(r.mean_overlap_frac, direct.mean_overlap_frac());
    assert_eq!(r.max_disagreement, direct.max_disagreement() as f64);
    assert_eq!(r.ranks.len(), base.ranks);
}

#[test]
fn autotune_picks_a_period_that_passes_both_gates() {
    // negligible wire cost ⇒ every period is within 2% of peak
    // throughput, so the choice is decided by the consensus gate alone
    let mut base = small_base();
    base.steps = 12;
    base.virtualize(&Workload::lenet3(4.0), 1e-6, 1e-12);
    let engine = Engine::with_threads(4);
    let tuned = autotune::autotune_gossip_period(
        &engine,
        &base,
        &[1, 2, 4],
        autotune::AutotuneParams::default(),
    )
    .expect("autotune");
    assert_eq!(tuned.candidates.len(), 3);
    assert!(
        tuned.no_mix_disagreement > 0.0,
        "independent SGD on distinct shards must drift"
    );
    assert!(
        tuned.candidates[0].consensus_shrinks,
        "every-step mixing must beat half the no-mix drift"
    );
    let chosen = tuned.chosen_period.expect("period 1 qualifies at minimum");
    let c = tuned
        .candidates
        .iter()
        .find(|c| c.period == chosen)
        .expect("chosen period is a candidate");
    assert!(c.fast_enough && c.consensus_shrinks);
    // no qualifying candidate is larger than the chosen one
    assert!(tuned
        .candidates
        .iter()
        .filter(|c| c.fast_enough && c.consensus_shrinks)
        .all(|c| c.period <= chosen));
    // reports: one per period + the no-mixing reference
    assert_eq!(tuned.reports.len(), 4);
    assert_eq!(tuned.reports[3].config.gossip_period, base.steps + 1);
}

#[test]
fn autotune_rejects_bad_inputs() {
    let engine = Engine::with_threads(1);
    let base = small_base();
    let params = autotune::AutotuneParams::default();
    let mut agd = base.clone();
    agd.algo = Algo::Agd;
    assert!(
        autotune::autotune_gossip_period(&engine, &agd, &[1], params).is_err(),
        "non-gossip algo has no gossip period to tune"
    );
    assert!(
        autotune::autotune_gossip_period(&engine, &base, &[], params).is_err(),
        "empty candidate list"
    );
    assert!(
        autotune::autotune_gossip_period(&engine, &base, &[base.steps + 5], params)
            .is_err(),
        "periods beyond the step count never mix"
    );
}
