//! Integration tests over the PJRT artifact path: the full L3→L2→L1
//! stack with real XLA execution.  Skipped (with a notice) when
//! `make artifacts` hasn't run.

use gossipgrad::config::{Algo, RunConfig};
use gossipgrad::coordinator;
use std::path::Path;

fn have_artifacts() -> bool {
    let ok = Path::new("artifacts/mlp.meta.json").exists();
    if !ok {
        eprintln!("skipping PJRT integration test: run `make artifacts`");
    }
    ok
}

fn cfg(algo: Algo, ranks: usize, steps: usize) -> RunConfig {
    RunConfig {
        model: "mlp".into(),
        algo,
        ranks,
        steps,
        lr: 0.05,
        rows_per_rank: 192,
        eval_every: steps,
        use_artifacts: true,
        seed: 21,
        ..Default::default()
    }
}

#[test]
fn pjrt_gossip_end_to_end_learns() {
    if !have_artifacts() {
        return;
    }
    let res = coordinator::run(&cfg(Algo::Gossip, 4, 40)).unwrap();
    let acc = res.final_accuracy.unwrap();
    assert!(acc > 0.9, "accuracy {acc}");
    assert!(res.max_disagreement() < 0.05);
}

#[test]
fn pjrt_agd_matches_gossip_accuracy() {
    // §7.2.2's claim at integration level: both algorithms reach the
    // same accuracy band on the same task
    if !have_artifacts() {
        return;
    }
    let g = coordinator::run(&cfg(Algo::Gossip, 4, 40)).unwrap();
    let a = coordinator::run(&cfg(Algo::Agd, 4, 40)).unwrap();
    let (ga, aa) = (g.final_accuracy.unwrap(), a.final_accuracy.unwrap());
    assert!((ga - aa).abs() < 0.08, "gossip {ga} vs agd {aa}");
}

#[test]
fn pjrt_and_native_backends_agree_in_distribution() {
    // same algorithm family, different compute backends — both must
    // solve the task (numerics differ: init streams differ)
    if !have_artifacts() {
        return;
    }
    let mut c = cfg(Algo::Gossip, 4, 50);
    let pjrt = coordinator::run(&c).unwrap();
    c.use_artifacts = false;
    let native = coordinator::run(&c).unwrap();
    assert!(pjrt.final_accuracy.unwrap() > 0.9);
    assert!(native.final_accuracy.unwrap() > 0.9);
}

#[test]
fn pjrt_gossip_overlap_hides_simulated_network() {
    // with a 5 ms/message simulated fabric, gossip's exposed comm must
    // stay well under the message cost (the §5.1 overlap, measured)
    if !have_artifacts() {
        return;
    }
    let mut c = cfg(Algo::Gossip, 4, 20);
    c.net_alpha = 5e-3;
    let res = coordinator::run(&c).unwrap();
    let exposed = res
        .per_rank
        .iter()
        .map(|m| m.mean_comm_wait())
        .fold(0.0f64, f64::max);
    // 4 messages (3 layers + shuffle) × 5ms = 20 ms of wire time per
    // step; overlap must hide the bulk of it under ~30ms of compute
    assert!(
        exposed < 8e-3,
        "exposed comm {exposed}s — overlap not working"
    );
    assert!(res.mean_efficiency_pct() > 75.0);
}
