//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no network access to crates.io, so this
//! vendored shim provides exactly the API surface the workspace uses:
//!
//! * [`Error`] — a context-chained error value (`{}` prints the
//!   outermost message, `{:#}` prints the whole chain joined by `: `).
//! * [`Result`] — `Result<T, Error>` alias.
//! * [`Context`] — `.context(..)` / `.with_context(|| ..)` on
//!   `Result<_, E: std::error::Error>`, `Result<_, Error>` and `Option`.
//! * [`anyhow!`], [`bail!`], [`ensure!`] macros.
//!
//! Semantics match real `anyhow` closely enough that swapping in the
//! upstream crate is a one-line Cargo.toml change.

use std::error::Error as StdError;
use std::fmt;

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-chained error.  `chain[0]` is the outermost (most recently
/// attached) message; deeper entries are causes.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message (the `anyhow`
    /// `Error::msg` constructor, used with `map_err`).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    fn from_std(e: &(dyn StdError + 'static)) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — full chain, matching anyhow's alternate format
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// Allow `?` on any std error inside an anyhow::Result function.  (Error
// itself deliberately does not implement std::error::Error, exactly as
// in upstream anyhow, so this blanket impl is coherent.)
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::from_std(&e)
    }
}

/// Context-attachment on fallible values.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from_std(&e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from_std(&e).context(f()))
    }
}

// Coherent alongside the impl above because `Error: !std::error::Error`
// (same negative-reasoning pattern std uses for Box<dyn Error> Froms).
impl<T> Context<T> for std::result::Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::Other, "disk on fire"))
    }

    #[test]
    fn context_chains_and_formats() {
        let e = io_fail().context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: disk on fire");
        let e = Err::<(), Error>(e).context("starting up").unwrap_err();
        assert_eq!(format!("{e:#}"), "starting up: reading config: disk on fire");
    }

    #[test]
    fn option_context_and_macros() {
        let e = None::<u32>.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(12).is_err());
        assert_eq!(format!("{}", f(5).unwrap_err()), "five is right out");
        let _: Error = anyhow!("coords {},{}", 1, 2);
    }

    #[test]
    fn question_mark_on_std_errors() {
        fn g() -> Result<()> {
            io_fail()?;
            Ok(())
        }
        assert_eq!(format!("{:#}", g().unwrap_err()), "disk on fire");
    }
}
