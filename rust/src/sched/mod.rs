//! Cooperative rank scheduler: run p-rank virtual-clock scenarios on a
//! bounded worker pool instead of p OS threads.
//!
//! A p = 1024 in-process scenario used to cost 1024 spawned threads —
//! almost all of them parked in mailbox condvars — multiplied again by
//! `--sweep-threads` under the experiment engine.  This module turns
//! each rank body into a stackful coroutine on a guard-paged 2 MiB
//! stack and multiplexes all of them over `--sim-threads` workers
//! (default: available cores): a rank that would block in `Link::park`
//! yields its worker to the next runnable rank and is re-queued when a
//! sender's `enqueue` wakes it (`transport::SchedLink` is the hook-up;
//! docs/perf.md has the yield/wake/determinism write-up).
//!
//! Results are bit-identical to the legacy thread-per-rank path —
//! retained behind `--legacy-ranks` as the differential-testing oracle
//! (tests/scheduler.rs) — because only the blocking primitive changes,
//! not the message flow.
//!
//! The real implementation (`coop` + `ctx`) needs glibc's ucontext
//! family and so is gated to Linux/gnu on x86_64/aarch64; elsewhere a
//! thread-per-task stub keeps the API compiling and [`supported`]
//! steers the trainer back to the legacy path.

#[cfg(all(
    target_os = "linux",
    target_env = "gnu",
    any(target_arch = "x86_64", target_arch = "aarch64")
))]
mod coop;
#[cfg(all(
    target_os = "linux",
    target_env = "gnu",
    any(target_arch = "x86_64", target_arch = "aarch64")
))]
mod ctx;
#[cfg(all(
    target_os = "linux",
    target_env = "gnu",
    any(target_arch = "x86_64", target_arch = "aarch64")
))]
pub use coop::{SchedHandle, Scheduler};

#[cfg(not(all(
    target_os = "linux",
    target_env = "gnu",
    any(target_arch = "x86_64", target_arch = "aarch64")
)))]
mod threads;
#[cfg(not(all(
    target_os = "linux",
    target_env = "gnu",
    any(target_arch = "x86_64", target_arch = "aarch64")
)))]
pub use threads::{SchedHandle, Scheduler};

/// Stack budget per rank: the coroutine stacks here, and the legacy
/// path's `thread::Builder::stack_size` (rank bodies keep model state
/// on the heap, so 2 MiB replaces the 8 MiB thread default that made
/// p = 1024 cost 8 GiB of stack address space).
pub const RANK_STACK_BYTES: usize = 2 * 1024 * 1024;

/// Whether the cooperative scheduler is available on this target.
/// When false, `Scheduler::run` still works (thread-per-task stub) but
/// offers no thread-count win, so the trainer uses the legacy path.
pub fn supported() -> bool {
    cfg!(all(
        target_os = "linux",
        target_env = "gnu",
        any(target_arch = "x86_64", target_arch = "aarch64")
    ))
}
