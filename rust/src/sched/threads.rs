//! Thread-per-task fallback for targets without the glibc ucontext
//! machinery (non-Linux, musl, uncommon arches).  The public scheduler
//! API compiles everywhere; [`super::supported`] reports `false`, so
//! the trainer keeps those targets on the legacy thread-per-rank path
//! and these stubs exist only so callers that ignore `supported()`
//! still execute correctly (one OS thread per task).

#[derive(Clone)]
pub struct SchedHandle;

impl SchedHandle {
    /// No cooperative tasks exist on this target; nothing to wake.
    pub fn wake(&self, _rank: usize) {}

    /// Never a scheduler task here — callers park on the inner link.
    pub fn yield_park(&self, _timed: bool) -> bool {
        false
    }
}

pub struct Scheduler {
    _threads: usize,
}

impl Scheduler {
    pub fn new(threads: usize) -> Scheduler {
        Scheduler { _threads: threads }
    }

    pub fn handle(&self) -> SchedHandle {
        SchedHandle
    }

    /// Degenerate execution: every body on its own thread, like the
    /// legacy path.
    pub fn run<R: Send + 'static>(
        &self,
        bodies: Vec<Box<dyn FnOnce() -> R + Send + 'static>>,
    ) -> Vec<R> {
        let handles: Vec<_> = bodies.into_iter().map(std::thread::spawn).collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("task panicked"))
            .collect()
    }
}
