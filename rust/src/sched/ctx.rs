//! Stackful coroutine contexts for the cooperative rank scheduler:
//! glibc `ucontext` (`getcontext`/`makecontext`/`swapcontext`) plus
//! guard-paged `mmap` stacks.  Linux/glibc on x86_64/aarch64 only —
//! `super::supported()` gates every caller, and other targets compile
//! the thread-per-task fallback (`super::threads`) instead.
//!
//! Why ucontext instead of hand-rolled assembly: the repo vendors no
//! crates, and glibc's context switchers are ABI-stable, cover the
//! FP/SIMD register state, and have carried coroutine runtimes for
//! decades.  The price is a `rt_sigprocmask` syscall pair per switch
//! (~100 ns), irrelevant next to the mailbox locking a park already
//! pays.

use std::ffi::c_void;
use std::os::raw::c_int;

// glibc's ucontext_t is ~968 bytes on x86_64 and ~4.5 KiB on aarch64;
// the blob is opaque to us except for the header fields written in
// `init`, whose offsets are identical on both ABIs: uc_flags u64 @ 0,
// uc_link ptr @ 8, then stack_t in glibc field order — ss_sp @ 16,
// ss_flags @ 24, ss_size @ 32.
const UCTX_BYTES: usize = 8192;
const UC_LINK: usize = 8;
const SS_SP: usize = 16;
const SS_FLAGS: usize = 24;
const SS_SIZE: usize = 32;

/// One saved execution context (an opaque, oversized `ucontext_t`).
#[repr(C, align(16))]
pub struct Context {
    bytes: [u8; UCTX_BYTES],
}

impl Context {
    /// Heap-allocated so its address stays stable across moves of the
    /// owning task struct (swapcontext keeps raw pointers into it).
    pub fn boxed() -> Box<Context> {
        Box::new(Context {
            bytes: [0; UCTX_BYTES],
        })
    }
}

extern "C" {
    fn getcontext(ucp: *mut c_void) -> c_int;
    fn swapcontext(oucp: *mut c_void, ucp: *const c_void) -> c_int;
    fn makecontext(ucp: *mut c_void, func: extern "C" fn(), argc: c_int, ...);
    fn mmap(
        addr: *mut c_void,
        len: usize,
        prot: c_int,
        flags: c_int,
        fd: c_int,
        offset: i64,
    ) -> *mut c_void;
    fn munmap(addr: *mut c_void, len: usize) -> c_int;
    fn mprotect(addr: *mut c_void, len: usize, prot: c_int) -> c_int;
    fn sysconf(name: c_int) -> i64;
}

const PROT_NONE: c_int = 0;
const PROT_READ: c_int = 1;
const PROT_WRITE: c_int = 2;
const MAP_PRIVATE: c_int = 0x02;
const MAP_ANONYMOUS: c_int = 0x20;
const SC_PAGESIZE: c_int = 30;

fn page_size() -> usize {
    // 4 KiB on x86_64, but aarch64 kernels ship 4/16/64 KiB — ask,
    // don't assume, or the guard page math below lands mid-page
    let n = unsafe { sysconf(SC_PAGESIZE) };
    if n > 0 {
        n as usize
    } else {
        4096
    }
}

/// A guard-paged coroutine stack: `size` usable bytes above one
/// `PROT_NONE` page, so overflow faults loudly instead of silently
/// corrupting the heap.  Pages are lazily committed by the kernel —
/// 1024 parked ranks cost virtual address space, not resident memory.
pub struct Stack {
    base: *mut u8,
    len: usize,
    guard: usize,
}

// The base pointer is uniquely owned by this struct (mmap'd here,
// munmap'd in Drop); tasks migrate between worker threads.
unsafe impl Send for Stack {}

impl Stack {
    pub fn new(size: usize) -> Stack {
        let guard = page_size();
        let size = size.div_ceil(guard) * guard;
        let len = guard + size;
        let p = unsafe {
            mmap(
                std::ptr::null_mut(),
                len,
                PROT_READ | PROT_WRITE,
                MAP_PRIVATE | MAP_ANONYMOUS,
                -1,
                0,
            )
        };
        // MAP_FAILED is -1, not null
        assert!(
            !p.is_null() && p as isize != -1,
            "mmap of a {len}-byte coroutine stack failed"
        );
        let rc = unsafe { mprotect(p, guard, PROT_NONE) };
        assert_eq!(rc, 0, "mprotect on the coroutine stack guard page failed");
        Stack {
            base: p as *mut u8,
            len,
            guard,
        }
    }

    fn sp(&self) -> *mut c_void {
        unsafe { self.base.add(self.guard) as *mut c_void }
    }

    fn usable(&self) -> usize {
        self.len - self.guard
    }
}

impl Drop for Stack {
    fn drop(&mut self) {
        unsafe { munmap(self.base as *mut c_void, self.len) };
    }
}

/// Prepare `ctx` so the first [`swap`] into it enters `entry` on
/// `stack`.  `entry` takes no arguments (makecontext's variadic args
/// are `int`-sized — not pointer-safe on LP64): it locates its task
/// through the scheduler's thread-local worker block instead.  It must
/// never return — `uc_link` is null, so returning would abort the
/// process; the scheduler's trampoline always swaps out with a
/// `Finished` reason instead.
pub fn init(ctx: &mut Context, stack: &Stack, entry: extern "C" fn()) {
    let p = ctx as *mut Context as *mut u8;
    unsafe {
        let rc = getcontext(p as *mut c_void);
        assert_eq!(rc, 0, "getcontext failed");
        *(p.add(UC_LINK) as *mut *mut c_void) = std::ptr::null_mut();
        *(p.add(SS_SP) as *mut *mut c_void) = stack.sp();
        *(p.add(SS_FLAGS) as *mut c_int) = 0;
        *(p.add(SS_SIZE) as *mut usize) = stack.usable();
        makecontext(p as *mut c_void, entry, 0);
    }
}

/// Save the current continuation into `from` and resume `to`.  Returns
/// when something later swaps back into `from` — possibly on a
/// *different OS thread*, so callers must not cache thread-local
/// addresses across this call (see the `#[inline(never)]` accessors in
/// `super::coop`).
///
/// # Safety
/// `from` and `to` must point to live, distinct contexts; `to` must
/// hold a continuation from [`init`] or a previous save; nothing else
/// may resume either context concurrently.
pub unsafe fn swap(from: *mut Context, to: *const Context) {
    let rc = swapcontext(from as *mut c_void, to as *const c_void);
    debug_assert_eq!(rc, 0, "swapcontext failed");
}
