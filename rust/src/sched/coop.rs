//! The cooperative rank scheduler (docs/perf.md, "rank scheduler"):
//! p virtual-clock rank bodies run as stackful coroutines on a bounded
//! pool of worker threads, so a p = 1024 scenario needs `--sim-threads`
//! runnable OS threads instead of 1024 mostly-parked ones.
//!
//! The integration seam is the transport's park/wake pair: when a rank
//! would block in `Link::park` on an empty mailbox, `SchedLink` calls
//! [`SchedHandle::yield_park`] and the coroutine hands its worker to
//! the next runnable rank; the sender-side `Link::enqueue` calls
//! [`SchedHandle::wake`] to re-queue the destination.  Results are
//! bit-identical to the legacy thread-per-rank path because nothing
//! about the *data* flow changes — the same per-(src, tag) FIFO
//! mailboxes carry the same virtually-stamped messages, only the
//! blocking primitive differs (see the determinism argument in
//! docs/perf.md).

use super::ctx::{self, Context, Stack};
use std::any::Any;
use std::cell::{Cell, UnsafeCell};
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};

/// Lifecycle of one rank task.  Transitions happen only under the
/// scheduler's shared lock.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum State {
    /// In the run queue, waiting for a worker.
    Runnable,
    /// Claimed by a worker: executing, or about to be.
    Running,
    /// Yielded on an empty mailbox; re-queued by the next `wake`.
    Parked,
    Finished,
}

/// Why a coroutine handed control back to its worker.
enum Reason {
    /// `yield_park`.  Timed parks are re-queued immediately — no
    /// guaranteed waker exists for a timeout, and an early return is a
    /// legal spurious wake (mailbox callers re-poll in a loop) while
    /// actually parking could sleep forever.
    Yielded { timed: bool },
    /// The body returned (payload = the panic it ended with, if any).
    Finished(Option<Box<dyn Any + Send>>),
}

struct Shared {
    state: Vec<State>,
    /// A `wake` arrived while the task was Running: it may already
    /// have passed its final mailbox poll of that slice, so re-queue
    /// it once instead of parking it.  This is the lost-wakeup guard —
    /// one spurious re-poll is legal, a missed message is a deadlock.
    notified: Vec<bool>,
    queue: VecDeque<usize>,
    /// Tasks currently claimed by workers (Running state count).
    running: usize,
    finished: usize,
    /// First panic payload out of any task; re-raised by `run`.
    panic: Option<Box<dyn Any + Send>>,
    /// Stop claiming new work; workers drain and exit.
    aborting: bool,
}

struct Inner {
    shared: Mutex<Shared>,
    cv: Condvar,
}

/// One coroutine: its saved context, its guard-paged stack, and (until
/// first entry) its body.
struct Task {
    ctx: Box<Context>,
    stack: Stack,
    body: Option<Box<dyn FnOnce() + Send>>,
    started: bool,
}

/// Interior-mutable task slot, shared by the worker threads.
///
/// Safety: the state machine in [`Shared`] guarantees at most one
/// thread touches a task's coroutine state at a time — a task is only
/// accessed by the worker that claimed it (claim and publish both
/// happen under the shared lock, and the context is fully saved by
/// `swapcontext` before the publish that lets another worker claim
/// it).
struct TaskSlot(UnsafeCell<Task>);

unsafe impl Sync for TaskSlot {}

/// Per-worker block: the worker thread's saved continuation plus what
/// a coroutine needs to find its way back.  A raw pointer to this is
/// published in `CURRENT` while a task runs on the thread.
struct WorkerCtx {
    /// Identity of the owning scheduler — `yield_park` must only
    /// capture parks of *this* scheduler's fabric (concurrent sweep
    /// scenarios each run their own scheduler over their own fabric).
    sched: *const Inner,
    worker: Box<Context>,
    tasks: *const TaskSlot,
    current: usize,
    reason: Option<Reason>,
}

thread_local! {
    static CURRENT: Cell<*mut WorkerCtx> = const { Cell::new(std::ptr::null_mut()) };
}

/// Read the calling thread's worker block.  `#[inline(never)]`: a
/// coroutine may be resumed on a different OS thread than the one it
/// parked on, so the TLS address must be re-derived on every call and
/// never cached across a `ctx::swap`.
#[inline(never)]
fn current_worker() -> *mut WorkerCtx {
    CURRENT.with(|c| c.get())
}

/// Cloneable wake/yield handle, held by `SchedLink` on the fabric.
#[derive(Clone)]
pub struct SchedHandle(Arc<Inner>);

impl SchedHandle {
    /// Sender-side hook: a message for `rank` is now visible — make
    /// the rank runnable.  Wake ordering is FIFO on the run queue;
    /// wakes for ranks that are not tasks of the current run (e.g. the
    /// idle extra PS-server fabric slots) are ignored.
    pub fn wake(&self, rank: usize) {
        let mut sh = self.0.shared.lock().unwrap();
        if rank >= sh.state.len() {
            return;
        }
        match sh.state[rank] {
            State::Parked => {
                sh.state[rank] = State::Runnable;
                sh.queue.push_back(rank);
                self.0.cv.notify_one();
            }
            // mid-slice (also covers a rank sending to itself): flag
            // for one spurious re-queue so the wake can't be lost in
            // the window before the park publishes
            State::Running => sh.notified[rank] = true,
            // already queued, or done: the message sits in its mailbox
            State::Runnable | State::Finished => {}
        }
    }

    /// Park-side hook: yield the calling coroutine back to its worker.
    /// Returns `false` when the calling thread is not executing a task
    /// of *this* scheduler — the caller should fall back to a blocking
    /// link park — and `true` after the coroutine has yielded and been
    /// resumed (the caller then re-polls its mailbox, exactly like a
    /// condvar wakeup).
    pub fn yield_park(&self, timed: bool) -> bool {
        let w = current_worker();
        if w.is_null() || !std::ptr::eq(unsafe { (*w).sched }, Arc::as_ptr(&self.0)) {
            return false;
        }
        unsafe {
            // Publish nothing yet: the Parked state only becomes
            // visible after the worker's swap returns, i.e. after
            // swapcontext has fully saved this continuation.  Flipping
            // state first would let another worker resume an unsaved
            // context.
            (*w).reason = Some(Reason::Yielded { timed });
            let task = (*(*w).tasks.add((*w).current)).0.get();
            let from: *mut Context = &mut *(*task).ctx;
            let to: *const Context = &*(*w).worker;
            ctx::swap(from, to);
        }
        // Resumed — possibly on a different worker thread; nothing
        // read before the swap (including `w`) may be touched again.
        true
    }
}

/// Bounded-pool coroutine scheduler for one in-process scenario.
pub struct Scheduler {
    inner: Arc<Inner>,
    threads: usize,
}

impl Scheduler {
    /// `threads == 0` means one worker per available core.
    pub fn new(threads: usize) -> Scheduler {
        let threads = if threads == 0 {
            std::thread::available_parallelism().map_or(2, |n| n.get())
        } else {
            threads
        };
        Scheduler {
            inner: Arc::new(Inner {
                shared: Mutex::new(Shared {
                    state: Vec::new(),
                    notified: Vec::new(),
                    queue: VecDeque::new(),
                    running: 0,
                    finished: 0,
                    panic: None,
                    aborting: false,
                }),
                cv: Condvar::new(),
            }),
            threads,
        }
    }

    pub fn handle(&self) -> SchedHandle {
        SchedHandle(Arc::clone(&self.inner))
    }

    /// Run every body to completion as a coroutine (task index == rank)
    /// and return their results in task order.  Panics in any body (or
    /// a detected deadlock) are re-raised here after the pool drains.
    pub fn run<R: Send + 'static>(
        &self,
        bodies: Vec<Box<dyn FnOnce() -> R + Send + 'static>>,
    ) -> Vec<R> {
        let n = bodies.len();
        if n == 0 {
            return Vec::new();
        }
        let slots: Arc<Vec<Mutex<Option<R>>>> =
            Arc::new((0..n).map(|_| Mutex::new(None)).collect());
        let tasks: Vec<TaskSlot> = bodies
            .into_iter()
            .enumerate()
            .map(|(i, body)| {
                let slots = Arc::clone(&slots);
                TaskSlot(UnsafeCell::new(Task {
                    ctx: Context::boxed(),
                    stack: Stack::new(super::RANK_STACK_BYTES),
                    body: Some(Box::new(move || {
                        *slots[i].lock().unwrap() = Some(body());
                    })),
                    started: false,
                }))
            })
            .collect();
        {
            let mut sh = self.inner.shared.lock().unwrap();
            sh.state = vec![State::Runnable; n];
            sh.notified = vec![false; n];
            sh.queue = (0..n).collect();
            sh.running = 0;
            sh.finished = 0;
            sh.panic = None;
            sh.aborting = false;
        }
        let workers = self.threads.clamp(1, n);
        std::thread::scope(|s| {
            for w in 0..workers {
                let inner = &self.inner;
                let tasks = &tasks;
                std::thread::Builder::new()
                    .name(format!("sim-{w}"))
                    .spawn_scoped(s, move || worker_loop(inner, tasks))
                    .expect("spawning scheduler worker");
            }
        });
        if let Some(p) = self.inner.shared.lock().unwrap().panic.take() {
            resume_unwind(p);
        }
        // A clean finish means every body ran and dropped its result
        // slot handle (the abort paths re-raise above, or panic out of
        // the scope join), so ours is the only Arc left.
        drop(tasks);
        let slots = match Arc::try_unwrap(slots) {
            Ok(v) => v,
            Err(_) => unreachable!("workers joined cleanly; no slot refs remain"),
        };
        slots
            .into_iter()
            .map(|m| {
                m.into_inner()
                    .unwrap()
                    .expect("task finished without a result")
            })
            .collect()
    }
}

fn worker_loop(inner: &Arc<Inner>, tasks: &[TaskSlot]) {
    let mut wctx = Box::new(WorkerCtx {
        sched: Arc::as_ptr(inner),
        worker: Context::boxed(),
        tasks: tasks.as_ptr(),
        current: 0,
        reason: None,
    });
    loop {
        // -- claim ---------------------------------------------------
        let claimed = {
            let mut sh = inner.shared.lock().unwrap();
            loop {
                if sh.aborting || sh.finished == sh.state.len() {
                    break None;
                }
                if let Some(i) = sh.queue.pop_front() {
                    sh.state[i] = State::Running;
                    sh.running += 1;
                    break Some(i);
                }
                sh = inner.cv.wait(sh).unwrap();
            }
        };
        let Some(i) = claimed else { return };
        // -- execute one slice ---------------------------------------
        // The global budget permit is taken with no locks held and
        // released before re-locking: a worker must never wait for a
        // permit while holding the shared lock (another worker may
        // hold the last permit and need the lock to publish/release).
        budget::acquire();
        wctx.current = i;
        CURRENT.with(|c| c.set(&mut *wctx as *mut WorkerCtx));
        unsafe {
            let task = tasks[i].0.get();
            if !(*task).started {
                (*task).started = true;
                ctx::init(&mut *(*task).ctx, &(*task).stack, trampoline);
            }
            let from: *mut Context = &mut *wctx.worker;
            let to: *const Context = &*(*task).ctx;
            ctx::swap(from, to);
        }
        CURRENT.with(|c| c.set(std::ptr::null_mut()));
        budget::release();
        let reason = wctx.reason.take().expect("coroutine yielded no reason");
        // -- publish -------------------------------------------------
        let mut sh = inner.shared.lock().unwrap();
        sh.running -= 1;
        match reason {
            Reason::Finished(payload) => {
                sh.state[i] = State::Finished;
                sh.finished += 1;
                if let Some(p) = payload {
                    if sh.panic.is_none() {
                        sh.panic = Some(p);
                    }
                    sh.aborting = true;
                }
                if sh.finished == sh.state.len() || sh.aborting {
                    inner.cv.notify_all();
                }
            }
            Reason::Yielded { timed } => {
                if timed || sh.notified[i] {
                    sh.notified[i] = false;
                    sh.state[i] = State::Runnable;
                    sh.queue.push_back(i);
                } else {
                    sh.state[i] = State::Parked;
                }
            }
        }
        if let Some(msg) = deadlock_msg(&mut sh) {
            inner.cv.notify_all();
            drop(sh);
            panic!("{msg}");
        }
    }
}

/// The virtual fabric is a closed system: every wake source is itself
/// a task (sends happen inside rank slices), so an empty run queue
/// with nothing running and tasks still unfinished means no progress
/// is possible — fail with a diagnostic instead of hanging the run the
/// way the legacy thread-per-rank path would.
fn deadlock_msg(sh: &mut Shared) -> Option<String> {
    if sh.aborting || sh.running > 0 || !sh.queue.is_empty() || sh.finished >= sh.state.len() {
        return None;
    }
    sh.aborting = true;
    let parked: Vec<usize> = sh
        .state
        .iter()
        .enumerate()
        .filter(|&(_, s)| *s == State::Parked)
        .map(|(i, _)| i)
        .take(16)
        .collect();
    Some(format!(
        "rank scheduler deadlock: {} of {} tasks finished, none runnable; \
         parked ranks (first 16): {:?}",
        sh.finished,
        sh.state.len(),
        parked
    ))
}

/// First instructions of every coroutine, on its own stack.  No
/// arguments — the task is found through the worker block the resuming
/// worker published in `CURRENT`.
extern "C" fn trampoline() {
    let body = unsafe {
        let w = current_worker();
        let task = (*(*w).tasks.add((*w).current)).0.get();
        (*task).body.take().expect("task entered twice")
    };
    let payload = catch_unwind(AssertUnwindSafe(body)).err();
    finish(payload)
}

/// Leave the coroutine for good: record the Finished reason and swap
/// back to the worker.  A separate `#[inline(never)]` fn so the worker
/// block is re-read *after* the body ran — the task may have parked
/// and been resumed on a different OS thread since `trampoline`'s
/// first read.
#[inline(never)]
fn finish(payload: Option<Box<dyn Any + Send>>) -> ! {
    unsafe {
        let w = current_worker();
        (*w).reason = Some(Reason::Finished(payload));
        let task = (*(*w).tasks.add((*w).current)).0.get();
        let from: *mut Context = &mut *(*task).ctx;
        let to: *const Context = &*(*w).worker;
        ctx::swap(from, to);
    }
    unreachable!("finished coroutine resumed")
}

/// Process-global rank-execution budget (the `exp::Engine`
/// oversubscription fix, docs/experiments.md).  Every worker holds a
/// permit only while actually executing a task slice, so the number of
/// rank bodies running at once across ALL concurrent scenarios —
/// `--sweep-threads` engine workers × their schedulers — is bounded by
/// the core count instead of `sweep_threads × sim_threads`.
///
/// Deadlock-free by construction: permits are never held while waiting
/// for scheduler work or the shared lock, and every slice ends in a
/// yield or finish that releases its permit.
mod budget {
    use std::sync::{Condvar, Mutex, OnceLock};

    struct Pool {
        free: Mutex<usize>,
        cv: Condvar,
    }

    static POOL: OnceLock<Pool> = OnceLock::new();

    fn pool() -> &'static Pool {
        POOL.get_or_init(|| {
            let cores = std::thread::available_parallelism().map_or(2, |n| n.get());
            Pool {
                free: Mutex::new(cores),
                cv: Condvar::new(),
            }
        })
    }

    pub fn acquire() {
        let p = pool();
        let mut free = p.free.lock().unwrap();
        while *free == 0 {
            free = p.cv.wait(free).unwrap();
        }
        *free -= 1;
    }

    pub fn release() {
        let p = pool();
        *p.free.lock().unwrap() += 1;
        p.cv.notify_one();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_tasks_and_returns_results_in_order() {
        let s = Scheduler::new(4);
        let bodies: Vec<Box<dyn FnOnce() -> usize + Send>> =
            (0..64).map(|i| Box::new(move || i * 2) as _).collect();
        assert_eq!(s.run(bodies), (0..64).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn yield_and_wake_round_trip() {
        let s = Scheduler::new(2);
        let h = s.handle();
        let slot = Arc::new(Mutex::new(None::<u64>));
        let (hp, hc) = (h.clone(), h);
        let (sp, sc) = (Arc::clone(&slot), slot);
        let bodies: Vec<Box<dyn FnOnce() -> u64 + Send>> = vec![
            Box::new(move || {
                *sp.lock().unwrap() = Some(41);
                hp.wake(1);
                0
            }),
            Box::new(move || loop {
                if let Some(v) = sc.lock().unwrap().take() {
                    return v + 1;
                }
                assert!(hc.yield_park(false));
            }),
        ];
        assert_eq!(s.run(bodies), vec![0, 42]);
    }

    #[test]
    fn timed_yield_is_requeued_without_a_waker() {
        let s = Scheduler::new(1);
        let h = s.handle();
        let bodies: Vec<Box<dyn FnOnce() -> u32 + Send>> = vec![Box::new(move || {
            for _ in 0..3 {
                assert!(h.yield_park(true));
            }
            7
        })];
        assert_eq!(s.run(bodies), vec![7]);
    }

    #[test]
    fn self_wake_before_park_is_not_lost() {
        let s = Scheduler::new(1);
        let h = s.handle();
        let bodies: Vec<Box<dyn FnOnce() -> u32 + Send>> = vec![Box::new(move || {
            // wake lands while Running: must convert the next untimed
            // yield into a re-queue instead of a forever-park
            h.wake(0);
            assert!(h.yield_park(false));
            1
        })];
        assert_eq!(s.run(bodies), vec![1]);
    }

    #[test]
    fn yield_outside_a_task_falls_through() {
        let s = Scheduler::new(1);
        assert!(!s.handle().yield_park(false));
    }

    #[test]
    #[should_panic(expected = "rank scheduler deadlock")]
    fn deadlock_is_detected_and_reported() {
        let s = Scheduler::new(2);
        let h = s.handle();
        let bodies: Vec<Box<dyn FnOnce() + Send>> = vec![Box::new(move || loop {
            h.yield_park(false);
        })];
        s.run(bodies);
    }

    #[test]
    fn task_panics_propagate_with_payload() {
        let s = Scheduler::new(2);
        let bodies: Vec<Box<dyn FnOnce() + Send>> =
            vec![Box::new(|| panic!("boom in task")), Box::new(|| {})];
        let err = catch_unwind(AssertUnwindSafe(|| s.run(bodies))).unwrap_err();
        let msg = err.downcast_ref::<&str>().copied().unwrap_or_default();
        assert!(msg.contains("boom in task"), "payload: {msg:?}");
    }
}
