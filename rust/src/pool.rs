//! Reusable payload buffers: the allocation side of the hot-path
//! throughput pass (docs/perf.md).
//!
//! Every message on the fabric used to allocate a fresh `Vec<f32>` (or
//! `Vec<u8>` for encoded payloads) at the sender and another at the
//! receiver.  GossipGraD's efficiency argument (paper §1, Fig 10/11)
//! needs the coordinator's per-step overhead to stay far below compute,
//! so the steady-state target is **zero payload allocations per step**:
//! buffers cycle sender → wire → receiver → back to a shared
//! [`BufferPool`].
//!
//! Design:
//!
//! * Two shelves (one per element type, `f32` and `u8`), each a
//!   capacity-keyed `BTreeMap` of free buffers.  [`BufferPool::get_f32`]
//!   takes the smallest free buffer whose capacity fits (best-fit, so a
//!   layer-wise run with mixed slice sizes reuses across layers without
//!   reallocating), or allocates on a miss.
//! * **Ownership rule**: a buffer drawn from the pool is owned by
//!   exactly one payload until its consumer returns it with
//!   [`BufferPool::put_f32`]/[`put_u8`](BufferPool::put_u8) (or
//!   [`recycle`](BufferPool::recycle)s the whole [`Payload`]).  Returning
//!   is optional for correctness — a dropped buffer is just a future
//!   miss — so error paths need no cleanup bookkeeping.
//! * Three atomic counters are the **allocation-counting test hook**
//!   (`tests/pooling.rs`, `benches/hotpath.rs`): `gets` (requests),
//!   `allocs` (misses — fresh heap allocations), `returns`.  After
//!   warm-up a steady-state training loop must hold `allocs` flat while
//!   `gets` keeps climbing.
//! * The pool can be disabled ([`BufferPool::set_enabled`]): every get
//!   then allocates fresh and every put drops, reproducing the pre-pool
//!   allocation behaviour for A/B `param_hash` parity runs.
//!
//! The pool is shared per fabric ([`crate::transport::Fabric`]) and
//! handed to the link via [`crate::transport::Link::attach_pool`] so
//! TCP reader/writer threads draw frame buffers from the same shelves.

use crate::codec::Payload;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

/// Free buffers kept per capacity bucket before further returns of that
/// capacity are dropped (bounds shelf growth under bursty in-flight).
const BUCKET_CAP: usize = 64;

/// Snapshot of the pool's counters — the allocation-counting hook.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Buffer requests served (hits + misses).
    pub gets: u64,
    /// Requests that missed the shelves and hit the allocator.  The
    /// steady-state zero-allocation property is "this stops moving".
    pub allocs: u64,
    /// Buffers returned to the shelves.
    pub returns: u64,
}

struct Shelf<T> {
    buckets: Mutex<BTreeMap<usize, Vec<Vec<T>>>>,
}

impl<T> Shelf<T> {
    fn new() -> Shelf<T> {
        Shelf {
            buckets: Mutex::new(BTreeMap::new()),
        }
    }

    /// Best-fit: smallest free buffer with `capacity >= min_cap`.
    fn take(&self, min_cap: usize) -> Option<Vec<T>> {
        let mut b = self.buckets.lock().unwrap();
        let (&cap, _) = b.range(min_cap..).next()?;
        let bucket = b.get_mut(&cap).unwrap();
        let v = bucket.pop().unwrap();
        if bucket.is_empty() {
            b.remove(&cap);
        }
        Some(v)
    }

    fn put(&self, v: Vec<T>) {
        let cap = v.capacity();
        if cap == 0 {
            return;
        }
        let mut b = self.buckets.lock().unwrap();
        let bucket = b.entry(cap).or_default();
        if bucket.len() < BUCKET_CAP {
            bucket.push(v);
        }
    }

    fn free_buffers(&self) -> usize {
        self.buckets.lock().unwrap().values().map(Vec::len).sum()
    }
}

/// Shared pool of reusable `Vec<f32>` / `Vec<u8>` payload buffers.  See
/// the module docs for the design and ownership rules.
pub struct BufferPool {
    f32s: Shelf<f32>,
    u8s: Shelf<u8>,
    enabled: AtomicBool,
    gets: AtomicU64,
    allocs: AtomicU64,
    returns: AtomicU64,
}

impl Default for BufferPool {
    fn default() -> Self {
        BufferPool::new()
    }
}

impl BufferPool {
    pub fn new() -> BufferPool {
        BufferPool {
            f32s: Shelf::new(),
            u8s: Shelf::new(),
            enabled: AtomicBool::new(true),
            gets: AtomicU64::new(0),
            allocs: AtomicU64::new(0),
            returns: AtomicU64::new(0),
        }
    }

    /// Turn pooling off (every get allocates fresh, every put drops) or
    /// back on.  The A/B switch behind `RunConfig::pool` — numerics
    /// must be bit-identical either way (`tests/pooling.rs`).
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// A zero-filled `f32` buffer of exactly `len` elements.
    pub fn get_f32(&self, len: usize) -> Vec<f32> {
        self.gets.fetch_add(1, Ordering::Relaxed);
        if let Some(mut v) = self.take_f32(len) {
            v.clear();
            v.resize(len, 0.0);
            return v;
        }
        self.allocs.fetch_add(1, Ordering::Relaxed);
        vec![0.0; len]
    }

    /// A pooled copy of `src` — the steady-state replacement for
    /// `src.to_vec()` on every send path.
    pub fn copy_f32(&self, src: &[f32]) -> Vec<f32> {
        self.gets.fetch_add(1, Ordering::Relaxed);
        if let Some(mut v) = self.take_f32(src.len()) {
            v.clear();
            v.extend_from_slice(src);
            return v;
        }
        self.allocs.fetch_add(1, Ordering::Relaxed);
        src.to_vec()
    }

    /// A zero-filled `u8` buffer of exactly `len` bytes (the TCP reader
    /// overwrites it with `read_exact`).
    pub fn get_u8(&self, len: usize) -> Vec<u8> {
        self.gets.fetch_add(1, Ordering::Relaxed);
        if let Some(mut v) = self.take_u8(len) {
            v.clear();
            v.resize(len, 0);
            return v;
        }
        self.allocs.fetch_add(1, Ordering::Relaxed);
        vec![0; len]
    }

    /// An *empty* `u8` buffer with `capacity >= cap` — for encoders
    /// that build their output with `extend`/`push`.
    pub fn get_u8_empty(&self, cap: usize) -> Vec<u8> {
        self.gets.fetch_add(1, Ordering::Relaxed);
        if let Some(mut v) = self.take_u8(cap) {
            v.clear();
            return v;
        }
        self.allocs.fetch_add(1, Ordering::Relaxed);
        Vec::with_capacity(cap)
    }

    pub fn put_f32(&self, v: Vec<f32>) {
        self.returns.fetch_add(1, Ordering::Relaxed);
        if self.enabled() {
            self.f32s.put(v);
        }
    }

    pub fn put_u8(&self, v: Vec<u8>) {
        self.returns.fetch_add(1, Ordering::Relaxed);
        if self.enabled() {
            self.u8s.put(v);
        }
    }

    /// Return a consumed payload's buffer to the matching shelf.
    pub fn recycle(&self, p: Payload) {
        match p {
            Payload::F32(v) => self.put_f32(v),
            Payload::Bytes { bytes, .. } => self.put_u8(bytes),
        }
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            gets: self.gets.load(Ordering::Relaxed),
            allocs: self.allocs.load(Ordering::Relaxed),
            returns: self.returns.load(Ordering::Relaxed),
        }
    }

    /// Free buffers currently shelved (both element types) — test hook.
    pub fn free_buffers(&self) -> usize {
        self.f32s.free_buffers() + self.u8s.free_buffers()
    }

    fn take_f32(&self, min_cap: usize) -> Option<Vec<f32>> {
        if self.enabled() {
            self.f32s.take(min_cap)
        } else {
            None
        }
    }

    fn take_u8(&self, min_cap: usize) -> Option<Vec<u8>> {
        if self.enabled() {
            self.u8s.take(min_cap)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::Encoding;

    #[test]
    fn miss_then_hit_and_counters_track() {
        let pool = BufferPool::new();
        let v = pool.get_f32(100);
        assert_eq!(v.len(), 100);
        assert!(v.iter().all(|&x| x == 0.0));
        assert_eq!(
            pool.stats(),
            PoolStats {
                gets: 1,
                allocs: 1,
                returns: 0
            }
        );
        pool.put_f32(v);
        let w = pool.get_f32(100);
        assert_eq!(w.len(), 100);
        assert_eq!(
            pool.stats(),
            PoolStats {
                gets: 2,
                allocs: 1,
                returns: 1
            },
            "second get of the same size must be a hit"
        );
    }

    #[test]
    fn buffers_are_reused_after_warm_up() {
        let pool = BufferPool::new();
        let v = pool.get_f32(64);
        let ptr = v.as_ptr();
        pool.put_f32(v);
        let w = pool.get_f32(64);
        assert_eq!(w.as_ptr(), ptr, "same buffer must come back (best-fit)");
    }

    #[test]
    fn outstanding_gets_never_alias() {
        let pool = BufferPool::new();
        let a = pool.get_f32(32);
        pool.put_f32(pool.copy_f32(&a)); // shelve one buffer
        let b = pool.get_f32(32); // the shelved one
        let c = pool.get_f32(32); // forced miss
        assert_ne!(a.as_ptr(), b.as_ptr());
        assert_ne!(a.as_ptr(), c.as_ptr());
        assert_ne!(b.as_ptr(), c.as_ptr());
    }

    #[test]
    fn best_fit_takes_smallest_adequate_buffer() {
        let pool = BufferPool::new();
        let small = pool.get_f32(10);
        let big = pool.get_f32(1000);
        let big_ptr = big.as_ptr();
        pool.put_f32(small);
        pool.put_f32(big);
        // asking for 500 must skip the 10-cap buffer and reuse the big one
        let v = pool.copy_f32(&[1.0; 500]);
        assert_eq!(v.as_ptr(), big_ptr);
        assert_eq!(v.len(), 500);
        assert_eq!(pool.stats().allocs, 2, "no new allocation for the 500-get");
    }

    #[test]
    fn copy_f32_matches_to_vec() {
        let pool = BufferPool::new();
        let src = vec![1.5f32, -2.25, 0.0, 3.0];
        let v = pool.copy_f32(&src);
        assert_eq!(v, src);
        pool.put_f32(v);
        let w = pool.copy_f32(&src[..2]);
        assert_eq!(w, &src[..2], "reused buffer must not leak old tail");
    }

    #[test]
    fn recycle_routes_payloads_to_matching_shelves() {
        let pool = BufferPool::new();
        pool.recycle(Payload::F32(vec![0.0; 8]));
        pool.recycle(Payload::Bytes {
            enc: Encoding::Bf16,
            n: 4,
            bytes: vec![0u8; 8],
        });
        assert_eq!(pool.free_buffers(), 2);
        assert_eq!(pool.stats().returns, 2);
        // and the f32 shelf serves f32 gets only
        let v = pool.get_f32(8);
        assert_eq!(pool.stats().allocs, 0, "f32 recycle must serve f32 get");
        let b = pool.get_u8(8);
        assert_eq!(pool.stats().allocs, 0, "u8 recycle must serve u8 get");
        drop((v, b));
    }

    #[test]
    fn disabled_pool_always_allocates_and_drops() {
        let pool = BufferPool::new();
        pool.set_enabled(false);
        let v = pool.get_f32(16);
        pool.put_f32(v);
        assert_eq!(pool.free_buffers(), 0, "disabled pool must not shelve");
        let w = pool.get_f32(16);
        assert_eq!(pool.stats().allocs, 2, "every disabled get is a miss");
        assert_eq!(pool.stats().gets, 2);
        assert_eq!(pool.stats().returns, 1);
        drop(w);
    }

    #[test]
    fn steady_state_loop_stops_allocating() {
        let pool = BufferPool::new();
        for _ in 0..3 {
            let v = pool.get_f32(4096);
            pool.put_f32(v);
        }
        let warm = pool.stats().allocs;
        for _ in 0..100 {
            let v = pool.copy_f32(&[0.5; 4096]);
            pool.put_f32(v);
        }
        assert_eq!(pool.stats().allocs, warm, "steady state must be alloc-free");
        assert_eq!(pool.stats().gets, 103);
    }

    #[test]
    fn bucket_cap_bounds_shelf_growth() {
        let pool = BufferPool::new();
        let bufs: Vec<_> = (0..2 * BUCKET_CAP).map(|_| pool.get_f32(8)).collect();
        for b in bufs {
            pool.put_f32(b);
        }
        assert!(pool.free_buffers() <= BUCKET_CAP);
    }
}
