//! First-class membership: the explicit alive-set every layer consults.
//!
//! The rest of the stack historically assumed "ranks `0..p`, all alive
//! forever" — `Topology::size`, the rotation permutations, the fabric's
//! mailbox array, `quiesce` as an all-ranks barrier.  This module makes
//! the rank set an explicit, *epoch-numbered* [`View`] derived
//! deterministically from a seeded [`FaultPlan`]:
//!
//! * the plan rides inside `RunConfig` (JSON + content-hash round-trip),
//!   so **every rank knows the same plan** — view transitions need no
//!   consensus protocol, no failure detector, and no timeouts on the
//!   deterministic path.  Every rank evaluates [`Membership::view_at`]
//!   at every step and gets the identical answer, which is what makes
//!   survivor routing (and therefore final model bits) reproducible
//!   run to run and across transports;
//! * wall/virtual *timeouts* remain the safety net for genuine
//!   (unplanned) failures: the bounded `Link::quiesce` surfaces a typed
//!   error naming the missing rank instead of hanging
//!   (docs/fault-tolerance.md).
//!
//! Frame-level faults (drop/duplicate) are pure functions of
//! `(plan seed, src, dst, tag)` — a stateless hash, mirroring
//! `sim::jitter_factor` — so the sending `FaultyLink` and the receiving
//! coordinator independently compute the *same* verdict for every
//! frame.  That is the whole determinism story: no shared mutable
//! fault state, no thread-schedule dependence, identical over the
//! in-process fabric and TCP.

use crate::util::json::{self, arr, num, obj, Json};

/// One seeded, declarative fault scenario.  Default = no faults; the
/// default plan is omitted from config JSON so every pre-existing
/// content hash is unchanged.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    /// `(rank, step)`: rank dies at the *start* of `step` — it never
    /// executes that step, but completed every earlier one.
    pub kills: Vec<(usize, usize)>,
    /// `(rank, step)`: rank is absent (idle) before `step`; at `step`
    /// it bootstraps from a donor's snapshot and enters the rotation.
    pub joins: Vec<(usize, usize)>,
    /// `(rank, step, factor)`: from message round `step` on, frames to
    /// or from `rank` take `factor`× their modeled wire time.
    pub slows: Vec<(usize, usize, f64)>,
    /// Fraction of gossip model frames silently dropped on the wire.
    pub drop_frac: f64,
    /// Fraction of gossip model frames delivered twice.
    pub dup_frac: f64,
    /// Seed for the per-frame drop/dup hash.
    pub seed: u64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            kills: Vec::new(),
            joins: Vec::new(),
            slows: Vec::new(),
            drop_frac: 0.0,
            dup_frac: 0.0,
            seed: 0,
        }
    }
}

/// splitmix64-style finalizer: avalanche `x` into a uniform u64.
fn mix64(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

impl FaultPlan {
    pub fn is_default(&self) -> bool {
        self == &FaultPlan::default()
    }

    pub fn has_faults(&self) -> bool {
        !self.is_default()
    }

    /// Uniform [0, 1) hash of one frame identity.  `salt` separates the
    /// drop and dup streams so they are independent.
    fn frame_unit(&self, src: usize, dst: usize, tag_bits: u64, salt: u64) -> f64 {
        let h = mix64(
            self.seed
                ^ salt
                ^ (src as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ (dst as u64).wrapping_mul(0xD1B5_4A32_D192_ED03)
                ^ tag_bits.wrapping_mul(0xA24B_AED4_963E_E407),
        );
        (h >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Is the frame `(src → dst, tag)` dropped on the wire?  Pure:
    /// sender and receiver evaluate this independently and agree.
    pub fn dropped(&self, src: usize, dst: usize, tag_bits: u64) -> bool {
        self.drop_frac > 0.0
            && self.frame_unit(src, dst, tag_bits, 0x11) < self.drop_frac
    }

    /// Is the frame delivered twice?  A dropped frame is never also
    /// duplicated (drop wins).
    pub fn duplicated(&self, src: usize, dst: usize, tag_bits: u64) -> bool {
        self.dup_frac > 0.0
            && !self.dropped(src, dst, tag_bits)
            && self.frame_unit(src, dst, tag_bits, 0x22) < self.dup_frac
    }

    /// Wire-time multiplier for a frame touching `src`/`dst` at message
    /// round `round` (≥ 1; 1.0 = no slowdown).
    pub fn slow_factor(&self, src: usize, dst: usize, round: usize) -> f64 {
        let mut f = 1.0;
        for &(r, s, factor) in &self.slows {
            if (r == src || r == dst) && round >= s && factor > f {
                f = factor;
            }
        }
        f
    }

    /// The step at which `rank` dies, if the plan kills it.
    pub fn kill_step(&self, rank: usize) -> Option<usize> {
        self.kills.iter().find(|&&(r, _)| r == rank).map(|&(_, s)| s)
    }

    /// The step at which `rank` bootstraps, if it is a late joiner.
    pub fn join_step(&self, rank: usize) -> Option<usize> {
        self.joins.iter().find(|&&(r, _)| r == rank).map(|&(_, s)| s)
    }

    pub fn to_json(&self) -> Json {
        let pair = |v: &[(usize, usize)]| {
            arr(v.iter()
                .map(|&(r, s)| arr(vec![num(r as f64), num(s as f64)]))
                .collect())
        };
        obj(vec![
            ("kills", pair(&self.kills)),
            ("joins", pair(&self.joins)),
            (
                "slows",
                arr(self
                    .slows
                    .iter()
                    .map(|&(r, s, f)| {
                        arr(vec![num(r as f64), num(s as f64), num(f)])
                    })
                    .collect()),
            ),
            ("drop_frac", num(self.drop_frac)),
            ("dup_frac", num(self.dup_frac)),
            // string, like RunConfig::seed: u64 must survive the f64
            // number path losslessly
            ("seed", json::s(&self.seed.to_string())),
        ])
    }

    pub fn from_json(j: &Json) -> Result<FaultPlan, String> {
        let pairs = |k: &str| -> Result<Vec<(usize, usize)>, String> {
            j.get(k)
                .and_then(Json::as_arr)
                .ok_or_else(|| format!("fault_plan: missing {k}"))?
                .iter()
                .map(|e| {
                    let r = e.idx(0).and_then(Json::as_usize);
                    let s = e.idx(1).and_then(Json::as_usize);
                    match (r, s) {
                        (Some(r), Some(s)) => Ok((r, s)),
                        _ => Err(format!("fault_plan: bad {k} entry")),
                    }
                })
                .collect()
        };
        let slows = j
            .get("slows")
            .and_then(Json::as_arr)
            .ok_or("fault_plan: missing slows")?
            .iter()
            .map(|e| {
                let r = e.idx(0).and_then(Json::as_usize);
                let s = e.idx(1).and_then(Json::as_usize);
                let f = e.idx(2).and_then(Json::as_f64);
                match (r, s, f) {
                    (Some(r), Some(s), Some(f)) => Ok((r, s, f)),
                    _ => Err("fault_plan: bad slows entry".to_string()),
                }
            })
            .collect::<Result<Vec<_>, _>>()?;
        let f = |k: &str| {
            j.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("fault_plan: missing {k}"))
        };
        let seed = j
            .get("seed")
            .and_then(Json::as_str)
            .ok_or("fault_plan: missing seed")?
            .parse::<u64>()
            .map_err(|e| format!("fault_plan: bad seed: {e}"))?;
        Ok(FaultPlan {
            kills: pairs("kills")?,
            joins: pairs("joins")?,
            slows,
            drop_frac: f("drop_frac")?,
            dup_frac: f("dup_frac")?,
            seed,
        })
    }
}

/// One epoch of the alive-set.  `epoch` increments at every membership
/// transition (a kill taking effect, a joiner entering), so two views
/// compare by epoch alone.
#[derive(Clone, Debug, PartialEq)]
pub struct View {
    pub epoch: usize,
    pub alive: Vec<bool>,
}

impl View {
    /// The epoch-0 view: everyone in `0..world` alive.
    pub fn full(world: usize) -> View {
        View { epoch: 0, alive: vec![true; world] }
    }

    pub fn is_full(&self) -> bool {
        self.alive.iter().all(|&a| a)
    }

    pub fn is_alive(&self, rank: usize) -> bool {
        self.alive[rank]
    }

    pub fn num_alive(&self) -> usize {
        self.alive.iter().filter(|&&a| a).count()
    }

    /// Alive ranks in ascending rank order — the canonical collapsed
    /// ordering every layer derives its degraded topology from.
    pub fn alive_ranks(&self) -> Vec<usize> {
        (0..self.alive.len()).filter(|&r| self.alive[r]).collect()
    }

    /// `rank`'s `(next, prev)` neighbours on the sample-shuffle ring
    /// over this view's alive ordering — how the ring *heals* around a
    /// dead rank (docs/fault-tolerance.md).  `rank` must be alive; a
    /// single survivor is its own neighbour (the shuffle then keeps
    /// batches local, like the disabled path).
    pub fn ring_neighbors(&self, rank: usize) -> (usize, usize) {
        let order = self.alive_ranks();
        let k = order.len();
        let q = order
            .iter()
            .position(|&r| r == rank)
            .expect("ring neighbour of a rank outside the view");
        (order[(q + 1) % k], order[(q + k - 1) % k])
    }
}

/// The deterministic membership oracle: world size + plan in, the view
/// at any step out.  Every rank holds an identical copy (the plan is
/// part of the shared config), so `view_at(step)` is a *consensus-free
/// agreement*: all survivors route through the same view at the same
/// step without exchanging a single membership message.
#[derive(Clone, Debug)]
pub struct Membership {
    world: usize,
    plan: FaultPlan,
}

impl Membership {
    pub fn new(world: usize, plan: FaultPlan) -> Membership {
        Membership { world, plan }
    }

    pub fn world(&self) -> usize {
        self.world
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// The view in force at `step`.  Kills at step `s` exclude the rank
    /// for every `step >= s`; joins at `s` include it from `s` on.  The
    /// epoch counts transitions whose trigger step is `<= step`.
    pub fn view_at(&self, step: usize) -> View {
        let mut alive = vec![true; self.world];
        let mut epoch = 0;
        for &(r, s) in &self.plan.joins {
            if r < self.world {
                if step < s {
                    alive[r] = false;
                } else {
                    epoch += 1;
                }
            }
        }
        for &(r, s) in &self.plan.kills {
            if r < self.world && step >= s {
                alive[r] = false;
                epoch += 1;
            }
        }
        View { epoch, alive }
    }

    /// The donor a joiner bootstraps from: the smallest rank alive at
    /// the join step that is not itself joining at that step.  Both
    /// sides evaluate this; `validate` guarantees it exists.
    pub fn donor_for(&self, joiner: usize, join_step: usize) -> Option<usize> {
        let view = self.view_at(join_step);
        (0..self.world).find(|&r| {
            r != joiner
                && view.is_alive(r)
                && self.plan.join_step(r) != Some(join_step)
        })
    }
}

/// Dissemination partner formula over an arbitrary ordered alive-list:
/// the degraded-view twin of `topology::Dissemination::exchange`.  At
/// full view with the identity ordering it reproduces that formula
/// bit for bit; with members excluded, the dead slots *collapse* (the
/// list shrinks) rather than leaving holes, so every survivor pairs
/// with a live partner every gossip step — no step ever stalls on a
/// dead rank.  Returns `(send_to, recv_from)`; the pairing is a
/// bijection on the list (`recv_from(send_to(r)) == r`).
pub fn collapsed_exchange(order: &[usize], rank: usize, step: usize) -> (usize, usize) {
    let k = order.len();
    if k <= 1 {
        return (rank, rank);
    }
    let q = order
        .iter()
        .position(|&r| r == rank)
        .expect("rank must be in the alive ordering");
    let rounds = crate::util::ceil_log2(k).max(1);
    let mut d = 1usize << (step % rounds);
    d %= k;
    if d == 0 {
        d = 1;
    }
    (order[(q + d) % k], order[(q + k - d) % k])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan() -> FaultPlan {
        FaultPlan {
            kills: vec![(3, 10)],
            joins: vec![(7, 14)],
            slows: vec![(2, 5, 3.0)],
            drop_frac: 0.25,
            dup_frac: 0.1,
            seed: 42,
        }
    }

    #[test]
    fn default_plan_is_default() {
        assert!(FaultPlan::default().is_default());
        assert!(!plan().is_default());
    }

    #[test]
    fn plan_roundtrips_through_json() {
        let p = plan();
        let j = p.to_json();
        let back = FaultPlan::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back, p);
        assert_eq!(back.to_json().to_string(), j.to_string());
    }

    #[test]
    fn view_transitions_are_deterministic_and_epoch_numbered() {
        let m = Membership::new(8, plan());
        let v0 = m.view_at(0);
        assert_eq!(v0.epoch, 0);
        assert!(!v0.is_alive(7), "joiner absent before its join step");
        assert!(v0.is_alive(3));
        assert_eq!(v0.num_alive(), 7);
        let v10 = m.view_at(10);
        assert_eq!(v10.epoch, 1, "kill at 10 is one transition");
        assert!(!v10.is_alive(3));
        assert_eq!(v10.num_alive(), 6);
        let v14 = m.view_at(14);
        assert_eq!(v14.epoch, 2, "join at 14 is the second transition");
        assert!(v14.is_alive(7));
        assert!(!v14.is_alive(3));
        assert_eq!(v14.alive_ranks(), vec![0, 1, 2, 4, 5, 6, 7]);
    }

    #[test]
    fn no_faults_means_full_view_forever() {
        let m = Membership::new(4, FaultPlan::default());
        for step in [0, 1, 100] {
            let v = m.view_at(step);
            assert!(v.is_full());
            assert_eq!(v.epoch, 0);
        }
    }

    #[test]
    fn donor_is_smallest_alive_non_joining_rank() {
        let m = Membership::new(8, plan());
        assert_eq!(m.donor_for(7, 14), Some(0));
        // kill rank 0 early: donor shifts to rank 1
        let mut p = plan();
        p.kills.push((0, 2));
        let m = Membership::new(8, p);
        assert_eq!(m.donor_for(7, 14), Some(1));
    }

    #[test]
    fn drop_dup_hash_is_pure_and_roughly_calibrated() {
        let p = plan();
        let mut drops = 0;
        let mut dups = 0;
        let n = 10_000;
        for i in 0..n {
            let tag = 0xDEAD_0000 + i as u64;
            // pure: same answer every time
            assert_eq!(p.dropped(1, 2, tag), p.dropped(1, 2, tag));
            assert_eq!(p.duplicated(1, 2, tag), p.duplicated(1, 2, tag));
            // drop wins: never both
            assert!(!(p.dropped(1, 2, tag) && p.duplicated(1, 2, tag)));
            drops += p.dropped(1, 2, tag) as usize;
            dups += p.duplicated(1, 2, tag) as usize;
        }
        let drop_rate = drops as f64 / n as f64;
        assert!((drop_rate - 0.25).abs() < 0.03, "drop rate {drop_rate}");
        assert!(dups > 0);
        // different seeds decorrelate
        let mut p2 = p.clone();
        p2.seed = 43;
        let same = (0..n)
            .filter(|&i| p.dropped(1, 2, i as u64) == p2.dropped(1, 2, i as u64))
            .count();
        assert!(same < n, "seed must matter");
    }

    #[test]
    fn slow_factor_gates_on_rank_and_round() {
        let p = plan(); // slow rank 2 from round 5, 3x
        assert_eq!(p.slow_factor(2, 1, 4), 1.0, "before the slow step");
        assert_eq!(p.slow_factor(2, 1, 5), 3.0, "src slowed");
        assert_eq!(p.slow_factor(1, 2, 9), 3.0, "dst slowed");
        assert_eq!(p.slow_factor(0, 1, 9), 1.0, "uninvolved pair");
    }

    #[test]
    fn collapsed_exchange_matches_dissemination_at_full_view() {
        use crate::topology::{Dissemination, Topology};
        for p in [2usize, 3, 5, 8] {
            let t = Dissemination::new(p);
            let order: Vec<usize> = (0..p).collect();
            for step in 0..12 {
                for r in 0..p {
                    let ex = t.exchange(r, step);
                    let (s, rx) = collapsed_exchange(&order, r, step);
                    assert_eq!((s, rx), (ex.send_to, ex.recv_from));
                }
            }
        }
    }

    #[test]
    fn collapsed_exchange_is_a_consistent_bijection() {
        // survivors of p=8 with ranks 3 and 6 dead
        let order = vec![0usize, 1, 2, 4, 5, 7];
        for step in 0..10 {
            let mut seen = std::collections::HashSet::new();
            for &r in &order {
                let (send, _) = collapsed_exchange(&order, r, step);
                assert!(order.contains(&send));
                assert_ne!(send, r, "k >= 2 never self-pairs");
                assert!(seen.insert(send), "send targets must be a bijection");
                // if r sends to send, send receives from r
                let (_, recv) = collapsed_exchange(&order, send, step);
                assert_eq!(recv, r, "recv_from must invert send_to");
            }
        }
    }

    #[test]
    fn single_survivor_self_loops() {
        assert_eq!(collapsed_exchange(&[5], 5, 3), (5, 5));
    }

    #[test]
    fn ring_heals_around_dead_ranks() {
        let m = Membership::new(4, FaultPlan {
            kills: vec![(2, 6)],
            ..Default::default()
        });
        let before = m.view_at(5);
        assert_eq!(before.ring_neighbors(1), (2, 0));
        let after = m.view_at(6);
        assert_eq!(after.ring_neighbors(1), (3, 0), "next skips the dead rank");
        assert_eq!(after.ring_neighbors(3), (0, 1), "prev skips the dead rank");
        // two survivors: a 2-cycle; one survivor: self-loop
        let m = Membership::new(3, FaultPlan {
            kills: vec![(0, 1), (1, 1)],
            ..Default::default()
        });
        assert_eq!(m.view_at(1).ring_neighbors(2), (2, 2));
    }
}
