//! Model checkpointing: save/restore the flat parameter + momentum
//! vectors with a JSON manifest.  Format:
//!
//!   <dir>/manifest.json   {"model": .., "param_count": .., "step": ..,
//!                          "files": {"params": "params.f32", ...}}
//!   <dir>/params.f32      raw little-endian f32
//!   <dir>/momentum.f32    raw little-endian f32
//!
//! Used by the CLI's `--save-every/--resume` and by the Fig-14-style
//! long runs so the step-LR schedule can be continued across restarts.

use crate::util::json::{num, obj, s, Json};
use std::io::Write;
use std::path::Path;

#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub model: String,
    pub step: usize,
    pub params: Vec<f32>,
    pub momentum: Vec<f32>,
}

fn write_f32(path: &Path, data: &[f32]) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    let mut buf = Vec::with_capacity(data.len() * 4);
    for v in data {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    f.write_all(&buf)
}

fn read_f32(path: &Path, expect: usize) -> Result<Vec<f32>, String> {
    let bytes =
        std::fs::read(path).map_err(|e| format!("{}: {e}", path.display()))?;
    if bytes.len() != expect * 4 {
        return Err(format!(
            "{}: {} bytes, expected {}",
            path.display(),
            bytes.len(),
            expect * 4
        ));
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

impl Checkpoint {
    pub fn save(&self, dir: &Path) -> Result<(), String> {
        std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
        write_f32(&dir.join("params.f32"), &self.params)
            .map_err(|e| e.to_string())?;
        write_f32(&dir.join("momentum.f32"), &self.momentum)
            .map_err(|e| e.to_string())?;
        let manifest = obj(vec![
            ("model", s(&self.model)),
            ("param_count", num(self.params.len() as f64)),
            ("step", num(self.step as f64)),
            (
                "files",
                obj(vec![
                    ("params", s("params.f32")),
                    ("momentum", s("momentum.f32")),
                ]),
            ),
        ]);
        std::fs::write(dir.join("manifest.json"), manifest.to_string())
            .map_err(|e| e.to_string())
    }

    pub fn load(dir: &Path) -> Result<Checkpoint, String> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| format!("manifest: {e}"))?;
        let j = Json::parse(&text)?;
        let n = j
            .get("param_count")
            .and_then(Json::as_usize)
            .ok_or("manifest missing param_count")?;
        let model = j
            .get("model")
            .and_then(Json::as_str)
            .ok_or("manifest missing model")?
            .to_string();
        let step = j.get("step").and_then(Json::as_usize).unwrap_or(0);
        Ok(Checkpoint {
            model,
            step,
            params: read_f32(&dir.join("params.f32"), n)?,
            momentum: read_f32(&dir.join("momentum.f32"), n)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("gg_ckpt_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(4);
        let ck = Checkpoint {
            model: "mlp".into(),
            step: 123,
            params: (0..1000).map(|_| rng.normal_f32()).collect(),
            momentum: (0..1000).map(|_| rng.normal_f32()).collect(),
        };
        let dir = tmpdir("roundtrip");
        ck.save(&dir).unwrap();
        let back = Checkpoint::load(&dir).unwrap();
        assert_eq!(back, ck);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_rejects_truncated() {
        let mut ck = Checkpoint {
            model: "mlp".into(),
            step: 1,
            params: vec![1.0; 10],
            momentum: vec![0.0; 10],
        };
        let dir = tmpdir("trunc");
        ck.save(&dir).unwrap();
        // corrupt: shrink params file
        std::fs::write(dir.join("params.f32"), [0u8; 8]).unwrap();
        assert!(Checkpoint::load(&dir).is_err());
        // manifest mismatch: param_count changed
        ck.params = vec![1.0; 10];
        ck.save(&dir).unwrap();
        assert!(Checkpoint::load(&dir).is_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_dir_is_error() {
        assert!(Checkpoint::load(Path::new("/nonexistent/gg")).is_err());
    }
}
