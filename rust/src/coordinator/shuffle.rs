//! Asynchronous distributed sample shuffle (paper §4.5.2).
//!
//! Ring topology, deliberately different from the gradient topology:
//! after a rank consumes a batch it forwards that batch to its right
//! neighbour and (asynchronously) receives one from its left.  Batches
//! therefore circulate the ring, giving the fairness property proved in
//! topology::ring's tests: a sample returns to a rank only after every
//! other rank has held it once.
//!
//! The exchange is fully overlapped: sends are non-blocking; the receive
//! posted at step k is only *required* by the time the local queue runs
//! dry, which takes `rows_per_rank / batch` further steps — by then the
//! message has long arrived.
//!
//! Token batches (transformer) ride the same path: token ids are carried
//! in the f32 payload (exact for vocab < 2^24).

use crate::transport::{Endpoint, RecvReq, Tag};

/// One circulating unit: a batch of samples (features or token ids).
#[derive(Clone, Debug, PartialEq)]
pub struct SampleBatch {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
}

impl SampleBatch {
    fn pack(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.x.len() + self.y.len());
        out.extend_from_slice(&self.x);
        out.extend(self.y.iter().map(|&v| v as f32));
        out
    }

    fn unpack(mut payload: Vec<f32>, rows: usize) -> SampleBatch {
        let y_start = payload.len() - rows;
        let y = payload[y_start..].iter().map(|&v| v as i32).collect();
        payload.truncate(y_start);
        SampleBatch { x: payload, y }
    }
}

/// Per-rank ring-shuffle state.
pub struct RingShuffle {
    queue: std::collections::VecDeque<SampleBatch>,
    pending: std::collections::VecDeque<RecvReq>,
    next: usize,
    prev: usize,
    rows_per_batch: usize,
    step: usize,
    /// disabled ranks pass batches straight through the queue
    enabled: bool,
    /// blocking-wait seconds accumulated by [`take`](Self::take) since
    /// the last [`take_stall_secs`](Self::take_stall_secs) — sample
    /// starvation the run loop folds into the step's comm ledger
    stall_secs: f64,
}

impl RingShuffle {
    /// `batches`: this rank's initial shard cut into batch-sized units.
    /// `p` is the number of *workers* in the ring — this may be smaller
    /// than the fabric size (the parameter-server fabric has extra
    /// server ranks that must not be in the sample ring).
    pub fn new(
        ep: &Endpoint,
        p: usize,
        batches: Vec<SampleBatch>,
        rows_per_batch: usize,
        enabled: bool,
    ) -> RingShuffle {
        let me = ep.rank();
        assert!(me < p, "rank {me} outside worker ring of size {p}");
        assert!(!batches.is_empty(), "rank {me}: empty shard");
        RingShuffle {
            queue: batches.into(),
            pending: Default::default(),
            next: (me + 1) % p,
            prev: (me + p - 1) % p,
            rows_per_batch,
            step: 0,
            enabled,
            stall_secs: 0.0,
        }
    }

    /// Number of batches currently held (queued locally).
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Take the next batch to train on.  Blocks on the oldest in-flight
    /// receive only if the local queue is empty; that stall is real
    /// exposed communication (sample starvation), so it is bracketed
    /// with the transport's wait ledger and surfaced through
    /// [`take_stall_secs`](Self::take_stall_secs) — an unattributed
    /// wait here would hide starvation from `comm_wait_secs` and let
    /// step time silently masquerade as compute.
    pub fn take(&mut self, ep: &Endpoint) -> SampleBatch {
        if let Some(b) = self.queue.pop_front() {
            return b;
        }
        let req = self
            .pending
            .pop_front()
            .expect("ring shuffle: queue empty with no in-flight batches");
        let m = ep.mark();
        let payload = req.wait();
        self.stall_secs += ep.comm_wait_since(&m);
        SampleBatch::unpack(payload, self.rows_per_batch)
    }

    /// Blocking-wait seconds accumulated by [`take`](Self::take) since
    /// the last call (returns and resets) — the share the run loops add
    /// to the step's `comm_wait_secs`.
    pub fn take_stall_secs(&mut self) -> f64 {
        std::mem::take(&mut self.stall_secs)
    }

    /// End-of-run cleanup: harvest every in-flight circulating batch
    /// back into the local queue so the fabric ends with no queued
    /// messages (the drain invariant checked by
    /// tests/fabric_drain.rs).  Uses the raw unaccounted harvest — the
    /// recorded steps are over, so these waits belong to no step and
    /// must not perturb the timing ledger.
    pub fn drain(&mut self, _ep: &Endpoint) {
        while let Some(req) = self.pending.pop_front() {
            let (payload, _, _) = req.wait_raw();
            self.queue
                .push_back(SampleBatch::unpack(payload, self.rows_per_batch));
        }
    }

    /// Re-point the ring around a membership change: from the next
    /// `give_back` on, forward to `next` and expect refills from
    /// `prev`.  In-flight receives already posted against the old
    /// neighbours stay pending — their senders committed those frames
    /// before the view transition, so they arrive and are harvested
    /// normally (batch payloads carry no origin the unpack cares
    /// about).  Every alive rank performs exactly one `give_back` per
    /// step, so the internal step counters — and therefore the
    /// [`Tag::SAMPLES`] rounds — stay rank-synchronized across the
    /// transition without any extra protocol (docs/fault-tolerance.md).
    pub fn reroute(&mut self, next: usize, prev: usize) {
        self.next = next;
        self.prev = prev;
    }

    /// Late-joiner bootstrap: align this ring's step counter with the
    /// cohort's, so the joiner's first `give_back` tags its frames with
    /// the round the rest of the ring expects.
    pub fn sync_step(&mut self, step: usize) {
        self.step = step;
    }

    /// Return a consumed batch: forward it around the ring (if enabled)
    /// and harvest any batches that have arrived meanwhile.
    pub fn give_back(&mut self, ep: &Endpoint, batch: SampleBatch) {
        if !self.enabled || self.next == ep.rank() {
            self.queue.push_back(batch);
            return;
        }
        let tag = Tag::SAMPLES.round(self.step);
        ep.isend(self.next, tag, batch.pack());
        self.pending.push_back(ep.irecv(self.prev, tag));
        self.step += 1;
        // opportunistically drain completed receives (non-blocking)
        while let Some(front) = self.pending.front_mut() {
            if front.test() {
                let req = self.pending.pop_front().unwrap();
                self.queue
                    .push_back(SampleBatch::unpack(req.wait(), self.rows_per_batch));
            } else {
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::{CostModel, Fabric};
    use std::thread;

    fn mk_batches(rank: usize, n: usize, rows: usize, dim: usize) -> Vec<SampleBatch> {
        (0..n)
            .map(|b| SampleBatch {
                x: vec![(rank * 100 + b) as f32; rows * dim],
                y: vec![(rank * 100 + b) as i32; rows],
            })
            .collect()
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let b = SampleBatch {
            x: vec![1.5, -2.0, 3.0, 0.0],
            y: vec![7, 123456],
        };
        let up = SampleBatch::unpack(b.pack(), 2);
        assert_eq!(up, b);
    }

    #[test]
    fn batches_circulate_the_ring() {
        let p = 4;
        let steps = 12;
        let f = Fabric::new(p, CostModel::zero());
        let handles: Vec<_> = (0..p)
            .map(|r| {
                let ep = f.endpoint(r);
                thread::spawn(move || {
                    let mut sh =
                        RingShuffle::new(&ep, p, mk_batches(r, 3, 2, 1), 2, true);
                    let mut seen_owners = std::collections::HashSet::new();
                    for _ in 0..steps {
                        let b = sh.take(&ep);
                        seen_owners.insert(b.y[0] / 100);
                        sh.give_back(&ep, b);
                    }
                    seen_owners
                })
            })
            .collect();
        for h in handles {
            let owners = h.join().unwrap();
            // over 12 steps every rank sees batches originating from
            // multiple other ranks — circulation is happening
            assert!(
                owners.len() >= 3,
                "saw only origins {owners:?}"
            );
        }
    }

    #[test]
    fn disabled_shuffle_keeps_local_data() {
        let f = Fabric::new(2, CostModel::zero());
        let ep = f.endpoint(0);
        let mut sh = RingShuffle::new(&ep, 2, mk_batches(0, 2, 2, 3), 2, false);
        for _ in 0..6 {
            let b = sh.take(&ep);
            assert_eq!(b.y[0] / 100, 0, "foreign batch with shuffle off");
            sh.give_back(&ep, b);
        }
        assert_eq!(f.total_msgs(), 0);
    }

    #[test]
    fn take_stall_is_attributed_on_slow_link() {
        // one batch per rank on a slow virtual link: every take() after
        // the first blocks on the in-flight refill, and that stall must
        // land in the wait ledger (regression: it used to be invisible
        // to the per-step comm accounting, inflating efficiency)
        let p = 2;
        let f = Fabric::new_virtual(p, CostModel::new(5e-3, 0.0, 0.0, 0));
        let handles: Vec<_> = (0..p)
            .map(|r| {
                let ep = f.endpoint(r);
                thread::spawn(move || {
                    let mut sh =
                        RingShuffle::new(&ep, p, mk_batches(r, 1, 1, 1), 1, true);
                    let mut stall = 0.0;
                    for _ in 0..4 {
                        let b = sh.take(&ep);
                        stall += sh.take_stall_secs();
                        sh.give_back(&ep, b);
                    }
                    sh.drain(&ep);
                    stall
                })
            })
            .collect();
        for h in handles {
            let stall = h.join().unwrap();
            // 3 starved refills x 5 ms wire each
            assert!(
                (stall - 3.0 * 5e-3).abs() < 1e-9,
                "stall {stall}s not attributed"
            );
        }
        assert_eq!(f.in_flight(), 0, "drain left batches on the fabric");
    }

    #[test]
    fn ring_reroutes_around_a_departing_rank() {
        // rank 1 leaves at the start of step 4 (cooperative death, as
        // the gossip loop does it); ranks 0 and 2 reroute their ring
        // pointers at that step and keep shuffling as a 2-ring.  All
        // batches are conserved and the fabric drains clean.
        let p = 3;
        let leave_at = 4;
        let f = Fabric::new(p, CostModel::zero());
        let handles: Vec<_> = (0..p)
            .map(|r| {
                let ep = f.endpoint(r);
                thread::spawn(move || {
                    let mut sh =
                        RingShuffle::new(&ep, p, mk_batches(r, 2, 1, 1), 1, true);
                    let steps = if r == 1 { leave_at } else { 10 };
                    for step in 0..steps {
                        if r != 1 && step == leave_at {
                            // the healed ring is the 2-cycle {0, 2}
                            let peer = if r == 0 { 2 } else { 0 };
                            sh.reroute(peer, peer);
                        }
                        let b = sh.take(&ep);
                        sh.give_back(&ep, b);
                    }
                    sh.drain(&ep);
                    assert!(sh.pending.is_empty());
                    sh.queue.len()
                })
            })
            .collect();
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, p * 2, "no batch lost across the transition");
        assert_eq!(f.in_flight(), 0, "ring healed without leaking frames");
    }

    #[test]
    fn conservation_no_batch_lost() {
        // total batches across ranks is conserved after many steps
        let p = 3;
        let per = 4;
        let f = Fabric::new(p, CostModel::zero());
        let handles: Vec<_> = (0..p)
            .map(|r| {
                let ep = f.endpoint(r);
                thread::spawn(move || {
                    let mut sh =
                        RingShuffle::new(&ep, p, mk_batches(r, per, 1, 1), 1, true);
                    for _ in 0..20 {
                        let b = sh.take(&ep);
                        sh.give_back(&ep, b);
                    }
                    sh.drain(&ep);
                    assert!(sh.pending.is_empty());
                    sh.queue.len()
                })
            })
            .collect();
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, p * per);
    }
}
