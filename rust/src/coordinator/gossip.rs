//! The GossipGraD engine (paper §4–§5).
//!
//! Per step, each rank:
//! 1. **Drains** its partner's model slices from the *previous* step —
//!    by now they have arrived under the compute of this step's gradient
//!    evaluation, so the wait is ≈ 0 (the §5.1 overlap, implemented with
//!    non-blocking irecv + test_all + wait_all exactly as the paper's
//!    MPI_TestAll design).
//! 2. Computes gradients on its current batch.
//! 3. **Mixes**: `params <- (params + partner_params) / 2` (§6's pairwise
//!    averaging; the supermartingale argument's w_{n+1} step).
//! 4. Applies the fused momentum-SGD update.
//! 5. **Sends** its updated model to this step's dissemination partner,
//!    one message per layer slice (layer-wise, so a real NIC would
//!    pipeline them; tags carry (layer, step)), each slice encoded under
//!    the configured wire codec ([`crate::codec`], docs/wire-codecs.md)
//!    so compressed bytes are what the fabric charges; under top-k the
//!    unsent mass stays in a per-(partner, layer) error-feedback
//!    residual and only transmitted coordinates are mixed.
//! 6. Forwards its consumed batch around the sample-shuffle ring.
//!
//! Partner selection is a rotated dissemination topology by default
//! (§4.3–4.5); hypercube and random (Jin/Blot) variants are selectable
//! for the ablations.  With `gossip_period > 1` mixing/sending happens
//! every k-th step only.  Step 0 never gossips: all ranks start from the
//! same initial model, so a step-0 exchange would swap identical
//! parameters and inflate the per-step message count for nothing.
//!
//! Timing goes through [`Endpoint::mark`]/[`Endpoint::elapsed`]/
//! [`Endpoint::comm_wait_since`], so the same code path produces wall
//! timings on the default fabric and deterministic simulated timings on
//! a virtual-clock fabric ([`crate::transport::Fabric::new_virtual`]).
//! In virtual mode the configured per-step compute cost is charged
//! either as one block after the gradient evaluation (monolithic) or,
//! with `cfg.layerwise`, as per-layer backprop slices with each layer's
//! exchange posted at its grad-ready instant — the §5 asynchronous
//! pipeline, measurable via the per-rank `overlap_frac` metric.
//!
//! ## Staleness note
//! Mixing consumes the partner model *sent after the partner's previous
//! update* — one step of staleness, which is precisely what makes the
//! exchange fully overlappable (the paper's asynchronous design).  The
//! synchronous variant (`sync_mix = true`, used by the convergence
//! property tests) blocks for the current step's model instead and pays
//! the exposed communication time.

use super::worker::Worker;
use crate::codec::{mix_payload_recycle, Encoder};
use crate::config::Algo;
use crate::topology::{
    Dissemination, Exchange, Hypercube, RandomGossip, Rotation, Topology,
};
use crate::transport::{Endpoint, RecvReq, Tag};

/// Which virtual topology drives partner selection.
pub enum GossipTopology {
    Rotated(Rotation<Dissemination>),
    Plain(Dissemination),
    Hyper(Hypercube),
    Random(RandomGossip),
}

impl GossipTopology {
    pub fn build(algo: Algo, p: usize, rotation: bool, seed: u64) -> GossipTopology {
        match algo {
            // Hypercube requires power-of-two p (panics otherwise, §4.4.1)
            Algo::GossipHypercube => GossipTopology::Hyper(Hypercube::new(p)),
            Algo::GossipRandom => GossipTopology::Random(RandomGossip::new(p, seed)),
            _ if rotation => {
                GossipTopology::Rotated(Rotation::new(Dissemination::new(p), seed))
            }
            _ => GossipTopology::Plain(Dissemination::new(p)),
        }
    }

    pub fn exchange(&self, rank: usize, step: usize) -> Exchange {
        match self {
            GossipTopology::Rotated(t) => t.exchange(rank, step),
            GossipTopology::Plain(t) => t.exchange(rank, step),
            GossipTopology::Hyper(t) => t.exchange(rank, step),
            GossipTopology::Random(t) => t.exchange(rank, step),
        }
    }

    /// For the random baseline: every rank whose message must be drained.
    pub fn senders_to(&self, rank: usize, step: usize) -> Option<Vec<usize>> {
        match self {
            GossipTopology::Random(t) => Some(t.senders_to(rank, step)),
            _ => None,
        }
    }
}

/// In-flight model receive: the layer-sliced irecvs posted for one
/// exchange, indexed by backend layer-table position so the pipelined
/// schedule can drain exactly the layer whose backprop slice just
/// completed (`None` once consumed).
struct PendingModel {
    reqs: Vec<Option<(usize, RecvReq)>>, // [layer] -> (offset, request)
}

/// Run GossipGraD on one rank for `cfg.steps` steps.
///
/// Two step schedules share all numerics — with an elementwise update
/// kernel (native backend) the final models are bit-identical, since
/// the same elementwise mix/update ops run in the same per-element
/// order (see
/// `tests/virtual_time.rs::layerwise_pipeline_is_bit_identical_to_monolithic`):
///
/// * **Monolithic** (`cfg.layerwise = false`): charge the whole
///   backward pass, drain + mix the whole partner model, update, send
///   every layer at once.
/// * **Layer-wise pipeline** (`cfg.layerwise = true`, paper §5): charge
///   the forward pass, then per layer in backprop-completion order
///   (output layer first) charge that layer's compute slice, drain the
///   partner's matching slice from the previous exchange, mix, update,
///   and post the layer's async send immediately — while later layers'
///   backprop continues.  Each message's logical send instant is its
///   layer's grad-ready instant, so the measured overlap matches the
///   closed-form `Workload::grad_ready_times` model.
pub fn run_gossip(w: &mut Worker, ep: &Endpoint, topo: &GossipTopology, sync_mix: bool) {
    let steps = w.cfg.steps;
    let period = w.cfg.gossip_period.max(1);
    let layers: Vec<(usize, usize)> = w
        .backend
        .layers()
        .iter()
        .map(|l| (l.offset, l.len))
        .collect();
    let layerwise = w.cfg.layerwise;
    let sched = w.bwd_schedule(); // (layer, offset, len, slice secs), output first
    let mut pending: Option<PendingModel> = None;
    // wire codec: every outgoing model slice goes through this encoder
    // (per-destination/per-layer error-feedback residuals under top-k),
    // with scratch drawn from the fabric's buffer pool; incoming slices
    // mix via `mix_payload_recycle`, which for dense payloads is
    // bit-identical to `ops::mix_into` on the decoded vector and hands
    // the spent buffer back to the pool — `--codec f32` keeps the
    // historical param_hash exactly
    let mut enc = Encoder::new(w.cfg.codec);

    for step in 0..steps {
        let t0 = ep.mark();
        let lr = w.lr_at(step);
        let batch = w.shuffle.take(ep);
        // sample starvation is exposed communication, not compute
        let mut comm_wait = w.shuffle.take_stall_secs();
        let (x, y) = w.to_batch_data(&batch);

        // ---- compute (overlaps the in-flight partner model) ----------
        let (grads, loss) = w.backend.grad(&w.params, &x, &y);

        // gossip exchange runs every `period` steps; never at step 0,
        // where all replicas still hold the identical initial model
        let gossip_now = step > 0 && step % period == 0;
        let gossip_step = step / period;
        let random_senders = if gossip_now {
            topo.senders_to(w.rank, gossip_step)
        } else {
            None
        };
        let exchange = if gossip_now {
            Some(topo.exchange(w.rank, gossip_step))
        } else {
            None
        };

        if layerwise {
            // ---- layer-wise pipeline --------------------------------
            w.charge_compute(ep, step, w.cfg.virt_fwd_secs);
            let mut new_reqs: Vec<Option<(usize, RecvReq)>> =
                (0..layers.len()).map(|_| None).collect();
            for &(li, off, len, secs) in &sched {
                w.charge_compute(ep, step, secs);
                // drain the previous exchange's slice for this layer the
                // moment the local slice completes (mix before update,
                // as in the monolithic schedule)
                if let Some(pm) = pending.as_mut() {
                    if let Some((o2, req)) = pm.reqs[li].take() {
                        let tw = ep.mark();
                        let data = req.wait_payload();
                        comm_wait += ep.comm_wait_since(&tw);
                        mix_payload_recycle(
                            &mut w.params[o2..o2 + data.len()],
                            data,
                            ep.pool(),
                        );
                    }
                }
                w.backend.apply_update_slice(
                    &mut w.params[off..off + len],
                    &mut w.mom[off..off + len],
                    &grads[off..off + len],
                    lr,
                );
                // post this layer's async exchange at its grad-ready
                // instant — later layers' backprop continues past it
                if let Some(ex) = &exchange {
                    if ex.send_to != w.rank {
                        ep.isend_payload(
                            ex.send_to,
                            Tag::layer(li).round(step),
                            enc.encode_pooled(
                                ex.send_to,
                                li,
                                &w.params[off..off + len],
                                ep.pool(),
                            ),
                        );
                        if random_senders.is_none() && !sync_mix {
                            new_reqs[li] = Some((
                                off,
                                ep.irecv(ex.recv_from, Tag::layer(li).round(step)),
                            ));
                        }
                    }
                }
            }
            pending = None;
            if new_reqs.iter().any(Option::is_some) {
                pending = Some(PendingModel { reqs: new_reqs });
            }
        } else {
            // ---- monolithic schedule --------------------------------
            // virtual clock: charge the whole modeled compute cost
            w.charge_compute(ep, step, w.cfg.virt_compute_secs);

            // drain previous step's partner model & mix (§6) — slice by
            // slice; the layer slices are disjoint, so per-slice mixing
            // is elementwise-identical to buffering the whole partner
            // model first
            if let Some(pm) = pending.take() {
                let tw = ep.mark();
                for (off, req) in pm.reqs.into_iter().flatten() {
                    let data = req.wait_payload();
                    mix_payload_recycle(
                        &mut w.params[off..off + data.len()],
                        data,
                        ep.pool(),
                    );
                }
                comm_wait += ep.comm_wait_since(&tw);
            }

            // local update
            w.backend.apply_update(&mut w.params, &mut w.mom, &grads, lr);

            if let Some(ex) = &exchange {
                if random_senders.is_none() && ex.send_to != w.rank {
                    send_model(ep, ex.send_to, step, &w.params, &layers, &mut enc);
                    let pm = post_recvs(ep, ex.recv_from, step, &layers);
                    if sync_mix {
                        let tw = ep.mark();
                        for (off, req) in pm.reqs.into_iter().flatten() {
                            let data = req.wait_payload();
                            mix_payload_recycle(
                                &mut w.params[off..off + data.len()],
                                data,
                                ep.pool(),
                            );
                        }
                        comm_wait += ep.comm_wait_since(&tw);
                    } else {
                        pending = Some(pm);
                    }
                } else if random_senders.is_some() {
                    send_model(ep, ex.send_to, step, &w.params, &layers, &mut enc);
                }
            }
        }

        // random-gossip baseline: blocking, possibly unbalanced drain of
        // every sender targeting this rank (both schedules)
        if let Some(senders) = random_senders {
            let tw = ep.mark();
            for src in senders {
                let pm = post_recvs(ep, src, step, &layers);
                for (off, req) in pm.reqs.into_iter().flatten() {
                    let data = req.wait_payload();
                    mix_payload_recycle(
                        &mut w.params[off..off + data.len()],
                        data,
                        ep.pool(),
                    );
                }
            }
            comm_wait += ep.comm_wait_since(&tw);
        } else if layerwise && sync_mix {
            // synchronous mixing under the pipeline: block for the
            // current exchange once all layers are updated and sent
            if let Some(ex) = &exchange {
                if ex.send_to != w.rank {
                    let pm = post_recvs(ep, ex.recv_from, step, &layers);
                    let tw = ep.mark();
                    for (off, req) in pm.reqs.into_iter().flatten() {
                        let data = req.wait_payload();
                        mix_payload_recycle(
                            &mut w.params[off..off + data.len()],
                            data,
                            ep.pool(),
                        );
                    }
                    comm_wait += ep.comm_wait_since(&tw);
                }
            }
        }

        // ---- sample shuffle (§4.5.2, overlapped) ----------------------
        w.shuffle.give_back(ep, batch);

        w.record_step(step, loss, ep.elapsed(&t0), comm_wait);

        if w.cfg.eval_every > 0
            && (step % w.cfg.eval_every == 0 || step + 1 == steps)
        {
            let (_, acc) = w.evaluate();
            w.metrics.accuracy.push((step, acc));
        }
    }

    // drain any final in-flight model so the fabric is clean; raw
    // harvest — the recorded steps are over, so this communication
    // belongs to no step and must not perturb the overlap ledger
    // (the mix itself still runs: numerics are unchanged)
    if let Some(pm) = pending.take() {
        for (off, req) in pm.reqs.into_iter().flatten() {
            let (data, _, _) = req.wait_raw_payload();
            mix_payload_recycle(&mut w.params[off..off + data.len()], data, ep.pool());
        }
    }
    // ... and any in-flight sample batches, so the fabric ends clean
    w.shuffle.drain(ep);

    w.snapshot_counters(ep);
}

/// Send the model to `dst`, one message per layer slice (§5 layer-wise),
/// each slice encoded under the configured wire codec (the encoder's
/// residual stream for a slice is its layer index).
fn send_model(
    ep: &Endpoint,
    dst: usize,
    step: usize,
    params: &[f32],
    layers: &[(usize, usize)],
    enc: &mut Encoder,
) {
    for (li, &(off, len)) in layers.iter().enumerate() {
        ep.isend_payload(
            dst,
            Tag::layer(li).round(step),
            enc.encode_pooled(dst, li, &params[off..off + len], ep.pool()),
        );
    }
}

/// Post per-layer irecvs for the model sent by `src` at `step`.
fn post_recvs(
    ep: &Endpoint,
    src: usize,
    step: usize,
    layers: &[(usize, usize)],
) -> PendingModel {
    PendingModel {
        reqs: layers
            .iter()
            .enumerate()
            .map(|(li, &(off, _))| {
                Some((off, ep.irecv(src, Tag::layer(li).round(step))))
            })
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topology_builder_variants() {
        let t = GossipTopology::build(crate::config::Algo::Gossip, 8, true, 1);
        assert!(matches!(t, GossipTopology::Rotated(_)));
        let t = GossipTopology::build(crate::config::Algo::Gossip, 8, false, 1);
        assert!(matches!(t, GossipTopology::Plain(_)));
        let t =
            GossipTopology::build(crate::config::Algo::GossipRandom, 8, true, 1);
        assert!(matches!(t, GossipTopology::Random(_)));
        assert!(t.senders_to(0, 0).is_some());
    }
}
