//! The GossipGraD engine (paper §4–§5).
//!
//! Per step, each rank:
//! 1. **Drains** its partner's model slices from the *previous* step —
//!    by now they have arrived under the compute of this step's gradient
//!    evaluation, so the wait is ≈ 0 (the §5.1 overlap, implemented with
//!    non-blocking irecv + test_all + wait_all exactly as the paper's
//!    MPI_TestAll design).
//! 2. Computes gradients on its current batch.
//! 3. **Mixes**: `params <- (params + partner_params) / 2` (§6's pairwise
//!    averaging; the supermartingale argument's w_{n+1} step).
//! 4. Applies the fused momentum-SGD update.
//! 5. **Sends** its updated model to this step's dissemination partner,
//!    one message per layer slice (layer-wise, so a real NIC would
//!    pipeline them; tags carry (layer, step)), each slice encoded under
//!    the configured wire codec ([`crate::codec`], docs/wire-codecs.md)
//!    so compressed bytes are what the fabric charges; under top-k the
//!    unsent mass stays in a per-(partner, layer) error-feedback
//!    residual and only transmitted coordinates are mixed.
//! 6. Forwards its consumed batch around the sample-shuffle ring.
//!
//! Partner selection is a rotated dissemination topology by default
//! (§4.3–4.5); hypercube and random (Jin/Blot) variants are selectable
//! for the ablations.  With `gossip_period > 1` mixing/sending happens
//! every k-th step only.  Step 0 never gossips: all ranks start from the
//! same initial model, so a step-0 exchange would swap identical
//! parameters and inflate the per-step message count for nothing.
//!
//! Timing goes through [`Endpoint::mark`]/[`Endpoint::elapsed`]/
//! [`Endpoint::comm_wait_since`], so the same code path produces wall
//! timings on the default fabric and deterministic simulated timings on
//! a virtual-clock fabric ([`crate::transport::Fabric::new_virtual`]).
//! In virtual mode the configured per-step compute cost is charged
//! either as one block after the gradient evaluation (monolithic) or,
//! with `cfg.layerwise`, as per-layer backprop slices with each layer's
//! exchange posted at its grad-ready instant — the §5 asynchronous
//! pipeline, measurable via the per-rank `overlap_frac` metric.
//!
//! ## Staleness note
//! Mixing consumes the partner model *sent after the partner's previous
//! update* — one step of staleness, which is precisely what makes the
//! exchange fully overlappable (the paper's asynchronous design).  The
//! synchronous variant (`sync_mix = true`, used by the convergence
//! property tests) blocks for the current step's model instead and pays
//! the exposed communication time.
//!
//! ## Execution note
//! The engine never blocks except through [`Link::park`] (via the
//! endpoint wait/drain helpers), which is what lets the *same* rank body
//! run unmodified either on its own OS thread (legacy) or as a coroutine
//! on the bounded rank scheduler (docs/perf.md): under a
//! [`SchedLink`](crate::transport::SchedLink) each park becomes a
//! cooperative yield.
//!
//! [`Link::park`]: crate::transport::Link::park

use super::worker::Worker;
use crate::codec::{mix_payload_recycle, Encoder};
use crate::config::Algo;
use crate::membership::{collapsed_exchange, FaultPlan, Membership, View};
use crate::topology::{
    Dissemination, Exchange, Hypercube, RandomGossip, Rotation, Topology, TwoLevel,
};
use crate::transport::{Endpoint, RecvReq, Tag};

/// Which virtual topology drives partner selection.
pub enum GossipTopology {
    Rotated(Rotation<Dissemination>),
    Plain(Dissemination),
    Hyper(Hypercube),
    Random(RandomGossip),
    /// Hierarchical schedule (docs/topology.md): dense intra-group
    /// mixing, sparse inter-group partners every `inter_period` steps.
    TwoLevel(TwoLevel),
}

impl GossipTopology {
    pub fn build(algo: Algo, p: usize, rotation: bool, seed: u64) -> GossipTopology {
        match algo {
            // Hypercube requires power-of-two p (panics otherwise, §4.4.1)
            Algo::GossipHypercube => GossipTopology::Hyper(Hypercube::new(p)),
            Algo::GossipRandom => GossipTopology::Random(RandomGossip::new(p, seed)),
            _ if rotation => {
                GossipTopology::Rotated(Rotation::new(Dissemination::new(p), seed))
            }
            _ => GossipTopology::Plain(Dissemination::new(p)),
        }
    }

    /// [`build`](Self::build) with host-group awareness.  A non-trivial
    /// `group_size` (1 < g < p, plain gossip only — `validate` rejects
    /// the rest) selects the two-level schedule; every degenerate case
    /// routes through the flat builder, so `group_size` 1 and p are
    /// bit-identical to the historical routing by construction.
    pub fn build_grouped(
        algo: Algo,
        p: usize,
        rotation: bool,
        seed: u64,
        group_size: usize,
        inter_period: usize,
    ) -> GossipTopology {
        if matches!(algo, Algo::Gossip) && group_size > 1 && group_size < p {
            GossipTopology::TwoLevel(TwoLevel::new(
                p,
                group_size,
                inter_period,
                rotation,
                seed,
            ))
        } else {
            GossipTopology::build(algo, p, rotation, seed)
        }
    }

    pub fn exchange(&self, rank: usize, step: usize) -> Exchange {
        match self {
            GossipTopology::Rotated(t) => t.exchange(rank, step),
            GossipTopology::Plain(t) => t.exchange(rank, step),
            GossipTopology::Hyper(t) => t.exchange(rank, step),
            GossipTopology::Random(t) => t.exchange(rank, step),
            GossipTopology::TwoLevel(t) => t.exchange(rank, step),
        }
    }

    /// For the random baseline: every rank whose message must be drained.
    pub fn senders_to(&self, rank: usize, step: usize) -> Option<Vec<usize>> {
        match self {
            GossipTopology::Random(t) => Some(t.senders_to(rank, step)),
            _ => None,
        }
    }
}

/// In-flight model receive: the layer-sliced irecvs posted for one
/// exchange, indexed by backend layer-table position so the pipelined
/// schedule can drain exactly the layer whose backprop slice just
/// completed (`None` once consumed — or never posted, when the fault
/// plan drops that slice's frame on the wire).  `src`/`step` let the
/// harvest sites recompute each slice's tag, which is what the
/// duplicate-discard check keys on.
struct PendingModel {
    src: usize,
    step: usize,
    reqs: Vec<Option<(usize, RecvReq)>>, // [layer] -> (offset, request)
}

/// Does the fault plan drop the `(src → dst, tag)` frame?  The exact
/// predicate `FaultyLink::enqueue` evaluates on the sender, so the
/// receiver can decline to post an irecv for a frame that will never
/// arrive instead of blocking on it.  `None` (fault-free run) is a
/// constant `false` — the historical path is untouched.
fn frame_dropped(fp: Option<&FaultPlan>, src: usize, dst: usize, tag: Tag) -> bool {
    fp.map_or(false, |p| src != dst && p.dropped(src, dst, tag.0))
}

/// After harvesting a frame the plan delivered twice, pop and recycle
/// the second copy so the mailbox (and the `in_flight` gauges) drain to
/// zero.  Mixing the duplicate again would double-count the partner
/// model; discarding it makes "delivered twice" numerically identical
/// to "delivered once", which the determinism tests rely on.
fn discard_dup(ep: &Endpoint, fp: Option<&FaultPlan>, src: usize, tag: Tag) {
    let me = ep.rank();
    if fp.map_or(false, |p| src != me && p.duplicated(src, me, tag.0)) {
        let (dup, _, _) = ep.irecv(src, tag).wait_raw_payload();
        ep.pool().recycle(dup);
    }
}

/// Partner selection through the membership view.  At full view (or in
/// a fault-free run, where `view` is `None`) this is exactly
/// `topo.exchange` — bit-identical routing to every pre-membership run.
/// Under a degraded view the dead slots *collapse*: the rotation
/// epoch's permutation (or the plain alive ordering) is filtered to
/// survivors and the dissemination formula reruns over the shorter
/// list, so every survivor pairs with a live partner at every gossip
/// step and no exchange ever stalls on a dead rank.
fn exchange_for(
    topo: &GossipTopology,
    view: Option<&View>,
    rank: usize,
    gossip_step: usize,
) -> Exchange {
    match view {
        Some(v) if !v.is_full() => {
            let order: Vec<usize> = match topo {
                GossipTopology::Rotated(t) => t
                    .perm(t.epoch(gossip_step))
                    .iter()
                    .copied()
                    .filter(|&r| v.is_alive(r))
                    .collect(),
                // under a degraded view the two-level schedule falls
                // back to its flat rotation's ordering: locality is
                // best-effort during faults, live pairing is not
                GossipTopology::TwoLevel(t) if t.rotates() => t
                    .flat_order(gossip_step)
                    .iter()
                    .copied()
                    .filter(|&r| v.is_alive(r))
                    .collect(),
                _ => v.alive_ranks(),
            };
            let (send_to, recv_from) = collapsed_exchange(&order, rank, gossip_step);
            Exchange { send_to, recv_from }
        }
        _ => topo.exchange(rank, gossip_step),
    }
}

/// FNV-1a over the raw parameter bits — the same digest
/// `RunResult::param_hash` uses, computed per rank at the bootstrap
/// handoff so the join-parity test can compare donor and joiner.
fn params_hash(params: &[f32]) -> u64 {
    let mut bytes = Vec::with_capacity(params.len() * 4);
    for x in params {
        bytes.extend_from_slice(&x.to_bits().to_le_bytes());
    }
    crate::util::fnv1a64(&bytes)
}

/// Run GossipGraD on one rank for `cfg.steps` steps.
///
/// Two step schedules share all numerics — with an elementwise update
/// kernel (native backend) the final models are bit-identical, since
/// the same elementwise mix/update ops run in the same per-element
/// order (see
/// `tests/virtual_time.rs::layerwise_pipeline_is_bit_identical_to_monolithic`):
///
/// * **Monolithic** (`cfg.layerwise = false`): charge the whole
///   backward pass, drain + mix the whole partner model, update, send
///   every layer at once.
/// * **Layer-wise pipeline** (`cfg.layerwise = true`, paper §5): charge
///   the forward pass, then per layer in backprop-completion order
///   (output layer first) charge that layer's compute slice, drain the
///   partner's matching slice from the previous exchange, mix, update,
///   and post the layer's async send immediately — while later layers'
///   backprop continues.  Each message's logical send instant is its
///   layer's grad-ready instant, so the measured overlap matches the
///   closed-form `Workload::grad_ready_times` model.
pub fn run_gossip(w: &mut Worker, ep: &Endpoint, topo: &GossipTopology, sync_mix: bool) {
    let steps = w.cfg.steps;
    let period = w.cfg.gossip_period.max(1);
    let layers: Vec<(usize, usize)> = w
        .backend
        .layers()
        .iter()
        .map(|l| (l.offset, l.len))
        .collect();
    let layerwise = w.cfg.layerwise;
    let sched = w.bwd_schedule(); // (layer, offset, len, slice secs), output first
    let mut pending: Option<PendingModel> = None;
    // wire codec: every outgoing model slice goes through this encoder
    // (per-destination/per-layer error-feedback residuals under top-k),
    // with scratch drawn from the fabric's buffer pool; incoming slices
    // mix via `mix_payload_recycle`, which for dense payloads is
    // bit-identical to `ops::mix_into` on the decoded vector and hands
    // the spent buffer back to the pool — `--codec f32` keeps the
    // historical param_hash exactly
    let mut enc = Encoder::new(w.cfg.codec);

    // ---- membership (docs/fault-tolerance.md) ------------------------
    // Every rank holds the same fault plan (it rides in the config), so
    // view transitions are consensus-free: each rank evaluates
    // `view_at(step)` locally and they all agree.  Fault-free runs keep
    // `fp = None` and every fault hook below compiles to the historical
    // behaviour.
    let me = w.rank;
    let member = Membership::new(w.cfg.ranks, w.cfg.fault_plan.clone());
    let plan = member.plan().clone();
    let faulty = plan.has_faults();
    let fp: Option<&FaultPlan> = if faulty { Some(&plan) } else { None };
    let kill_at = plan.kill_step(me);
    let join_at = plan.join_step(me);
    // reroute the sample-shuffle ring whenever the view's epoch changes;
    // `None` forces a reroute at the first iterated step, which is what
    // hands a late joiner its real neighbours before its first exchange
    let mut cur_epoch: Option<usize> = None;

    // ---- late-rank bootstrap ----------------------------------------
    // A joiner idles until its join step, then blocks for the donor's
    // parameter snapshot (CTRL rides dense f32 and is exempt from
    // drop/dup, so the handoff is lossless).  Momentum restarts at zero
    // — the joiner re-warms it, exactly like a fresh rank.  Both sides
    // record the snapshot's hash for the join-parity test.
    let start = if let Some(js) = join_at {
        let donor = member
            .donor_for(me, js)
            .expect("validate guarantees every joiner a donor");
        w.params = ep.irecv(donor, Tag::CTRL.round(js)).wait();
        w.metrics.joined_step = Some(js);
        w.metrics.join_hash = Some(params_hash(&w.params));
        // align the ring-shuffle step counter so the joiner's first
        // give_back tags round `js`, matching what its rerouted
        // neighbours send and expect at that step
        w.shuffle.sync_step(js);
        js
    } else {
        0
    };

    for step in start..steps {
        // a killed rank stops at the *start* of its kill step: it
        // completed every earlier step (including the sends), so its
        // partners' already-posted receives all arrive, and the normal
        // end-of-run drain below leaves the fabric clean
        if kill_at == Some(step) {
            w.metrics.death_step = Some(step);
            break;
        }
        let mut view: Option<View> = None;
        if faulty {
            let v = member.view_at(step);
            if cur_epoch != Some(v.epoch) {
                cur_epoch = Some(v.epoch);
                let (next, prev) = v.ring_neighbors(me);
                w.shuffle.reroute(next, prev);
            }
            // donor duty: ship the bootstrap snapshot to any rank that
            // joins at this step (params as of the start of the step —
            // the joiner proceeds from exactly this state)
            for &(j, js) in &plan.joins {
                if step == js && j != me && member.donor_for(j, js) == Some(me) {
                    ep.isend(j, Tag::CTRL.round(js), w.params.clone());
                    w.metrics.join_hash = Some(params_hash(&w.params));
                }
            }
            view = Some(v);
        }

        let t0 = ep.mark();
        let lr = w.lr_at(step);
        let batch = w.shuffle.take(ep);
        // sample starvation is exposed communication, not compute
        let mut comm_wait = w.shuffle.take_stall_secs();
        let (x, y) = w.to_batch_data(&batch);

        // ---- compute (overlaps the in-flight partner model) ----------
        let (grads, loss) = w.backend.grad(&w.params, &x, &y);

        // gossip exchange runs every `period` steps; never at step 0,
        // where all replicas still hold the identical initial model
        let gossip_now = step > 0 && step % period == 0;
        let gossip_step = step / period;
        let random_senders = if gossip_now {
            topo.senders_to(w.rank, gossip_step)
        } else {
            None
        };
        let exchange = if gossip_now {
            Some(exchange_for(topo, view.as_ref(), w.rank, gossip_step))
        } else {
            None
        };

        if layerwise {
            // ---- layer-wise pipeline --------------------------------
            w.charge_compute(ep, step, w.cfg.virt_fwd_secs);
            let mut new_reqs: Vec<Option<(usize, RecvReq)>> =
                (0..layers.len()).map(|_| None).collect();
            for &(li, off, len, secs) in &sched {
                w.charge_compute(ep, step, secs);
                // drain the previous exchange's slice for this layer the
                // moment the local slice completes (mix before update,
                // as in the monolithic schedule)
                if let Some(pm) = pending.as_mut() {
                    if let Some((o2, req)) = pm.reqs[li].take() {
                        let tw = ep.mark();
                        let data = req.wait_payload();
                        comm_wait += ep.comm_wait_since(&tw);
                        mix_payload_recycle(
                            &mut w.params[o2..o2 + data.len()],
                            data,
                            ep.pool(),
                        );
                        discard_dup(ep, fp, pm.src, Tag::layer(li).round(pm.step));
                    }
                }
                w.backend.apply_update_slice(
                    &mut w.params[off..off + len],
                    &mut w.mom[off..off + len],
                    &grads[off..off + len],
                    lr,
                );
                // post this layer's async exchange at its grad-ready
                // instant — later layers' backprop continues past it
                if let Some(ex) = &exchange {
                    if ex.send_to != w.rank {
                        ep.isend_payload(
                            ex.send_to,
                            Tag::layer(li).round(step),
                            enc.encode_pooled(
                                ex.send_to,
                                li,
                                &w.params[off..off + len],
                                ep.pool(),
                            ),
                        );
                        if random_senders.is_none() && !sync_mix {
                            let tag = Tag::layer(li).round(step);
                            // a frame the plan drops never arrives — the
                            // receiver skips the irecv instead of
                            // blocking on it (same predicate the sender
                            // evaluates; see `frame_dropped`)
                            if !frame_dropped(fp, ex.recv_from, w.rank, tag) {
                                new_reqs[li] =
                                    Some((off, ep.irecv(ex.recv_from, tag)));
                            }
                        }
                    }
                }
            }
            pending = None;
            if new_reqs.iter().any(Option::is_some) {
                pending = Some(PendingModel {
                    src: exchange.as_ref().map_or(w.rank, |e| e.recv_from),
                    step,
                    reqs: new_reqs,
                });
            }
        } else {
            // ---- monolithic schedule --------------------------------
            // virtual clock: charge the whole modeled compute cost
            w.charge_compute(ep, step, w.cfg.virt_compute_secs);

            // drain previous step's partner model & mix (§6) — slice by
            // slice; the layer slices are disjoint, so per-slice mixing
            // is elementwise-identical to buffering the whole partner
            // model first
            if let Some(pm) = pending.take() {
                let PendingModel { src, step: sent_step, reqs } = pm;
                let tw = ep.mark();
                for (li, slot) in reqs.into_iter().enumerate() {
                    if let Some((off, req)) = slot {
                        let data = req.wait_payload();
                        mix_payload_recycle(
                            &mut w.params[off..off + data.len()],
                            data,
                            ep.pool(),
                        );
                        discard_dup(ep, fp, src, Tag::layer(li).round(sent_step));
                    }
                }
                comm_wait += ep.comm_wait_since(&tw);
            }

            // local update
            w.backend.apply_update(&mut w.params, &mut w.mom, &grads, lr);

            if let Some(ex) = &exchange {
                if random_senders.is_none() && ex.send_to != w.rank {
                    send_model(ep, ex.send_to, step, &w.params, &layers, &mut enc);
                    let pm = post_recvs(ep, ex.recv_from, step, &layers, fp);
                    if sync_mix {
                        let PendingModel { src, step: sent_step, reqs } = pm;
                        let tw = ep.mark();
                        for (li, slot) in reqs.into_iter().enumerate() {
                            if let Some((off, req)) = slot {
                                let data = req.wait_payload();
                                mix_payload_recycle(
                                    &mut w.params[off..off + data.len()],
                                    data,
                                    ep.pool(),
                                );
                                discard_dup(
                                    ep,
                                    fp,
                                    src,
                                    Tag::layer(li).round(sent_step),
                                );
                            }
                        }
                        comm_wait += ep.comm_wait_since(&tw);
                    } else {
                        pending = Some(pm);
                    }
                } else if random_senders.is_some() {
                    send_model(ep, ex.send_to, step, &w.params, &layers, &mut enc);
                }
            }
        }

        // random-gossip baseline: blocking, possibly unbalanced drain of
        // every sender targeting this rank (both schedules)
        if let Some(senders) = random_senders {
            let tw = ep.mark();
            for src in senders {
                let pm = post_recvs(ep, src, step, &layers, fp);
                for (li, slot) in pm.reqs.into_iter().enumerate() {
                    if let Some((off, req)) = slot {
                        let data = req.wait_payload();
                        mix_payload_recycle(
                            &mut w.params[off..off + data.len()],
                            data,
                            ep.pool(),
                        );
                        discard_dup(ep, fp, src, Tag::layer(li).round(step));
                    }
                }
            }
            comm_wait += ep.comm_wait_since(&tw);
        } else if layerwise && sync_mix {
            // synchronous mixing under the pipeline: block for the
            // current exchange once all layers are updated and sent
            if let Some(ex) = &exchange {
                if ex.send_to != w.rank {
                    let pm = post_recvs(ep, ex.recv_from, step, &layers, fp);
                    let tw = ep.mark();
                    for (li, slot) in pm.reqs.into_iter().enumerate() {
                        if let Some((off, req)) = slot {
                            let data = req.wait_payload();
                            mix_payload_recycle(
                                &mut w.params[off..off + data.len()],
                                data,
                                ep.pool(),
                            );
                            discard_dup(
                                ep,
                                fp,
                                ex.recv_from,
                                Tag::layer(li).round(step),
                            );
                        }
                    }
                    comm_wait += ep.comm_wait_since(&tw);
                }
            }
        }

        // ---- sample shuffle (§4.5.2, overlapped) ----------------------
        w.shuffle.give_back(ep, batch);

        w.record_step(step, loss, ep.elapsed(&t0), comm_wait);

        if w.cfg.eval_every > 0
            && (step % w.cfg.eval_every == 0 || step + 1 == steps)
        {
            let (_, acc) = w.evaluate();
            w.metrics.accuracy.push((step, acc));
        }
    }

    // drain any final in-flight model so the fabric is clean; raw
    // harvest — the recorded steps are over, so this communication
    // belongs to no step and must not perturb the overlap ledger
    // (the mix itself still runs: numerics are unchanged)
    if let Some(pm) = pending.take() {
        let PendingModel { src, step: sent_step, reqs } = pm;
        for (li, slot) in reqs.into_iter().enumerate() {
            if let Some((off, req)) = slot {
                let (data, _, _) = req.wait_raw_payload();
                mix_payload_recycle(
                    &mut w.params[off..off + data.len()],
                    data,
                    ep.pool(),
                );
                discard_dup(ep, fp, src, Tag::layer(li).round(sent_step));
            }
        }
    }
    // ... and any in-flight sample batches, so the fabric ends clean
    w.shuffle.drain(ep);

    w.snapshot_counters(ep);
}

/// Send the model to `dst`, one message per layer slice (§5 layer-wise),
/// each slice encoded under the configured wire codec (the encoder's
/// residual stream for a slice is its layer index).
fn send_model(
    ep: &Endpoint,
    dst: usize,
    step: usize,
    params: &[f32],
    layers: &[(usize, usize)],
    enc: &mut Encoder,
) {
    for (li, &(off, len)) in layers.iter().enumerate() {
        ep.isend_payload(
            dst,
            Tag::layer(li).round(step),
            enc.encode_pooled(dst, li, &params[off..off + len], ep.pool()),
        );
    }
}

/// Post per-layer irecvs for the model sent by `src` at `step`,
/// skipping any slice the fault plan drops on the wire (that frame was
/// never enqueued on the sender, so an irecv for it would block
/// forever).
fn post_recvs(
    ep: &Endpoint,
    src: usize,
    step: usize,
    layers: &[(usize, usize)],
    fp: Option<&FaultPlan>,
) -> PendingModel {
    let me = ep.rank();
    PendingModel {
        src,
        step,
        reqs: layers
            .iter()
            .enumerate()
            .map(|(li, &(off, _))| {
                let tag = Tag::layer(li).round(step);
                if frame_dropped(fp, src, me, tag) {
                    None
                } else {
                    Some((off, ep.irecv(src, tag)))
                }
            })
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exchange_for_full_view_is_bit_identical_to_topology() {
        let topo = GossipTopology::build(crate::config::Algo::Gossip, 8, true, 7);
        let full = View::full(8);
        for step in 0..40 {
            for r in 0..8 {
                assert_eq!(
                    exchange_for(&topo, Some(&full), r, step),
                    topo.exchange(r, step)
                );
                assert_eq!(exchange_for(&topo, None, r, step), topo.exchange(r, step));
            }
        }
    }

    #[test]
    fn exchange_for_degraded_view_pairs_survivors_bijectively() {
        use crate::membership::{FaultPlan, Membership};
        let topo = GossipTopology::build(crate::config::Algo::Gossip, 8, true, 7);
        let m = Membership::new(
            8,
            FaultPlan { kills: vec![(3, 10)], ..Default::default() },
        );
        let v = m.view_at(10);
        for step in 0..30 {
            let mut targets = std::collections::HashSet::new();
            for r in v.alive_ranks() {
                let ex = exchange_for(&topo, Some(&v), r, step);
                assert!(v.is_alive(ex.send_to), "never routed to a dead rank");
                assert!(v.is_alive(ex.recv_from));
                assert_ne!(ex.send_to, r);
                assert!(targets.insert(ex.send_to), "send targets form a bijection");
                let back = exchange_for(&topo, Some(&v), ex.send_to, step);
                assert_eq!(back.recv_from, r, "recv_from inverts send_to");
            }
        }
    }

    #[test]
    fn topology_builder_variants() {
        let t = GossipTopology::build(crate::config::Algo::Gossip, 8, true, 1);
        assert!(matches!(t, GossipTopology::Rotated(_)));
        let t = GossipTopology::build(crate::config::Algo::Gossip, 8, false, 1);
        assert!(matches!(t, GossipTopology::Plain(_)));
        let t =
            GossipTopology::build(crate::config::Algo::GossipRandom, 8, true, 1);
        assert!(matches!(t, GossipTopology::Random(_)));
        assert!(t.senders_to(0, 0).is_some());
    }

    #[test]
    fn grouped_builder_dispatch() {
        use crate::config::Algo;
        // non-trivial group: the two-level schedule
        let t = GossipTopology::build_grouped(Algo::Gossip, 8, true, 1, 2, 4);
        assert!(matches!(t, GossipTopology::TwoLevel(_)));
        // degenerate groups route through the flat builder — the
        // flat-identity guarantee holds by construction
        for g in [1usize, 8] {
            let t = GossipTopology::build_grouped(Algo::Gossip, 8, true, 1, g, 4);
            assert!(matches!(t, GossipTopology::Rotated(_)), "g={g}");
            let t = GossipTopology::build_grouped(Algo::Gossip, 8, false, 1, g, 4);
            assert!(matches!(t, GossipTopology::Plain(_)), "g={g}");
        }
        // group-aware flat routing is bit-identical to build()
        let flat = GossipTopology::build(Algo::Gossip, 8, true, 7);
        let g1 = GossipTopology::build_grouped(Algo::Gossip, 8, true, 7, 1, 4);
        for step in 0..40 {
            for r in 0..8 {
                assert_eq!(g1.exchange(r, step), flat.exchange(r, step));
            }
        }
    }

    #[test]
    fn two_level_degraded_view_pairs_survivors() {
        use crate::membership::{FaultPlan, Membership};
        // kill one rank inside a group: the collapsed exchange must
        // still pair every survivor with a live partner, bijectively
        let topo = GossipTopology::build_grouped(
            crate::config::Algo::Gossip,
            8,
            true,
            7,
            4,
            2,
        );
        let m = Membership::new(
            8,
            FaultPlan { kills: vec![(2, 10)], ..Default::default() },
        );
        let v = m.view_at(10);
        for step in 0..30 {
            let mut targets = std::collections::HashSet::new();
            for r in v.alive_ranks() {
                let ex = exchange_for(&topo, Some(&v), r, step);
                assert!(v.is_alive(ex.send_to));
                assert!(v.is_alive(ex.recv_from));
                assert_ne!(ex.send_to, r);
                assert!(targets.insert(ex.send_to));
                let back = exchange_for(&topo, Some(&v), ex.send_to, step);
                assert_eq!(back.recv_from, r);
            }
        }
    }
}
