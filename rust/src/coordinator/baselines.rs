//! The baselines the paper evaluates GossipGraD against.
//!
//! * [`run_allreduce`] — synchronous SGD (whole-model all-reduce after
//!   backprop) and AGD (layer-wise all-reduce; §3.2/S-Caffe/PowerAI
//!   style).  AGD's *gradient averaging* is mathematically identical to
//!   SGD — the paper treats AGD as "theoretically equivalent" (§7.1) —
//!   the difference is the communication schedule.
//! * [`run_periodic`] — AGD communicating every ⌈log₂ p⌉ steps (Fig 17).
//! * [`run_param_server`] — Fig 2(a): workers push gradients to server
//!   rank(s), pull fresh weights.  Servers occupy the top ranks of the
//!   fabric (fabric size = workers + servers).

use super::worker::Worker;
use crate::codec::Encoder;
use crate::collectives::{Algorithm, IAllreduce};
use crate::config::RunConfig;
use crate::nativenet::ops;
use crate::transport::{Endpoint, Tag};
use crate::util::ceil_log2;

/// Synchronous all-reduce training.  `layerwise = true` → AGD (one
/// all-reduce per layer slice, the overlappable schedule); `false` →
/// plain SGD (single whole-model all-reduce).
///
/// With `cfg.layerwise` the AGD variant additionally runs the per-layer
/// *pipelined* compute schedule: each layer's backprop slice is charged
/// right before that layer's all-reduce, so the collective for layer ℓ
/// starts at ℓ's grad-ready instant (the §3.2 S-Caffe/PowerAI schedule)
/// instead of after the whole backward pass.  Two collective schedules
/// exist on top of that pipeline:
///
/// * **Blocking** (`cfg.comm_thread = false`): each layer's all-reduce
///   is dependency-chained on the caller, so its Θ(log p) rounds stay
///   exposed between compute slices — the pessimistic bound.
/// * **Comm-thread** (`cfg.comm_thread = true`): each layer's
///   [`IAllreduce`] is *posted* at its grad-ready instant and its rounds
///   advance at message-arrival instants on the modeled comm-progress
///   thread while later layers' backprop is still being charged; all
///   results are harvested at the update point.  This is the
///   S-Caffe/PowerAI/Jin-et-al. overlapped AGD the closed-form
///   simulator's `overlapped_agd_step_time` curve describes.  Numerics
///   are identical either way (same reductions in the same order).
pub fn run_allreduce(w: &mut Worker, ep: &Endpoint, alg: Algorithm, layerwise: bool) {
    let steps = w.cfg.steps;
    let layers: Vec<(usize, usize)> = w
        .backend
        .layers()
        .iter()
        .map(|l| (l.offset, l.len))
        .collect();
    let pipelined = layerwise && w.cfg.layerwise;
    let comm_thread = pipelined && w.cfg.comm_thread;
    let sched = w.bwd_schedule(); // (layer, offset, len, slice secs), output first
    for step in 0..steps {
        let t0 = ep.mark();
        let lr = w.lr_at(step);
        let batch = w.shuffle.take(ep);
        // sample starvation is communication time: fold the refill
        // stall into the step's exposed-comm ledger
        let mut comm_wait = w.shuffle.take_stall_secs();
        let (x, y) = w.to_batch_data(&batch);
        let (mut grads, loss) = w.backend.grad(&w.params, &x, &y);

        comm_wait += if comm_thread {
            // comm-thread AGD: post each layer's non-blocking all-reduce
            // at its grad-ready instant; rounds progress at arrival
            // instants while later slices are charged; harvest at the
            // update point
            w.charge_compute(ep, step, w.cfg.virt_fwd_secs);
            let tw = ep.mark();
            let mut posted: Vec<(usize, usize, IAllreduce)> =
                Vec::with_capacity(sched.len());
            for &(li, off, len, secs) in &sched {
                w.charge_compute(ep, step, secs);
                // pump in-flight collectives (wall-clock liveness only;
                // the virtual timeline is fixed by arrival stamps)
                for (_, _, h) in posted.iter_mut() {
                    h.progress(ep);
                }
                posted.push((
                    off,
                    len,
                    IAllreduce::post(
                        ep,
                        alg,
                        ep.pool().copy_f32(&grads[off..off + len]),
                        step * layers.len() + li,
                    ),
                ));
            }
            for (off, len, h) in posted {
                let out = h.wait(ep);
                grads[off..off + len].copy_from_slice(&out);
                ep.pool().put_f32(out);
            }
            ep.comm_wait_since(&tw)
        } else if pipelined {
            // per-layer pipeline: slice compute, then that layer's
            // all-reduce at its grad-ready instant (output layer first)
            w.charge_compute(ep, step, w.cfg.virt_fwd_secs);
            let tw = ep.mark();
            for &(li, off, len, secs) in &sched {
                w.charge_compute(ep, step, secs);
                alg.run(ep, &mut grads[off..off + len], step * layers.len() + li);
            }
            ep.comm_wait_since(&tw)
        } else {
            w.charge_compute(ep, step, w.cfg.virt_compute_secs);
            let tw = ep.mark();
            if layerwise {
                for (li, &(off, len)) in layers.iter().enumerate() {
                    alg.run(ep, &mut grads[off..off + len], step * layers.len() + li);
                }
            } else {
                alg.run(ep, &mut grads, step);
            }
            ep.comm_wait_since(&tw)
        };

        w.backend.apply_update(&mut w.params, &mut w.mom, &grads, lr);
        w.shuffle.give_back(ep, batch);
        w.record_step(step, loss, ep.elapsed(&t0), comm_wait);
        if w.cfg.eval_every > 0 && (step % w.cfg.eval_every == 0 || step + 1 == steps)
        {
            let (_, acc) = w.evaluate();
            w.metrics.accuracy.push((step, acc));
        }
    }
    w.shuffle.drain(ep);
    w.snapshot_counters(ep);
}

/// AGD every ⌈log₂ p⌉ steps (Fig 17's "computing AGD every log(p)
/// iterations"): local updates in between, model (not gradient)
/// averaging at the boundary so updates are not lost.
pub fn run_periodic(w: &mut Worker, ep: &Endpoint, alg: Algorithm) {
    let steps = w.cfg.steps;
    let period = ceil_log2(w.cfg.ranks).max(1);
    for step in 0..steps {
        let t0 = ep.mark();
        let lr = w.lr_at(step);
        let batch = w.shuffle.take(ep);
        let mut comm_wait = w.shuffle.take_stall_secs();
        let (x, y) = w.to_batch_data(&batch);
        let (grads, loss) = w.backend.grad(&w.params, &x, &y);
        w.charge_compute(ep, step, w.cfg.virt_compute_secs);
        w.backend.apply_update(&mut w.params, &mut w.mom, &grads, lr);

        if step % period == period - 1 {
            let tw = ep.mark();
            alg.run(ep, &mut w.params, step);
            comm_wait += ep.comm_wait_since(&tw);
        }
        w.shuffle.give_back(ep, batch);
        w.record_step(step, loss, ep.elapsed(&t0), comm_wait);
        if w.cfg.eval_every > 0 && (step % w.cfg.eval_every == 0 || step + 1 == steps)
        {
            let (_, acc) = w.evaluate();
            w.metrics.accuracy.push((step, acc));
        }
    }
    w.shuffle.drain(ep);
    w.snapshot_counters(ep);
}

/// Parameter-server worker loop: push grads, pull weights, every step.
///
/// With `cfg.layerwise` the push is pipelined: each layer's gradient is
/// sent the instant its backprop slice completes (one message per layer,
/// tagged with the layer channel), so the push overlaps the remaining
/// backward pass; only the weight pull stays exposed — which is exactly
/// the Fig 2(a) bottleneck once the server serializes its broadcast.
pub fn run_ps_worker(w: &mut Worker, ep: &Endpoint, server: usize) {
    let steps = w.cfg.steps;
    let sched = w.bwd_schedule();
    // gradient pushes go through the wire codec; under top-k the unsent
    // gradient mass stays in a per-layer residual toward the server
    // (zero-filled decode is exact for the server's *summation*), while
    // the model pull rides the transport's stateless auto path
    let mut enc = Encoder::new(w.cfg.codec);
    for step in 0..steps {
        let t0 = ep.mark();
        let batch = w.shuffle.take(ep);
        let shuffle_stall = w.shuffle.take_stall_secs();
        let (x, y) = w.to_batch_data(&batch);
        let (grads, loss) = w.backend.grad(&w.params, &x, &y);

        let pull_wait = if w.cfg.layerwise {
            w.charge_compute(ep, step, w.cfg.virt_fwd_secs);
            for &(li, off, len, secs) in &sched {
                w.charge_compute(ep, step, secs);
                ep.isend_payload(
                    server,
                    Tag::layer(li).round(step),
                    enc.encode_pooled(server, li, &grads[off..off + len], ep.pool()),
                );
            }
            let tw = ep.mark();
            let fresh = ep.recv(server, Tag::MODEL.round(step));
            w.params.copy_from_slice(&fresh);
            ep.pool().put_f32(fresh);
            ep.comm_wait_since(&tw)
        } else {
            w.charge_compute(ep, step, w.cfg.virt_compute_secs);
            let tw = ep.mark();
            ep.isend_payload(
                server,
                Tag::REDUCE.round(step),
                enc.encode_pooled(server, 0, &grads, ep.pool()),
            );
            let fresh = ep.recv(server, Tag::MODEL.round(step));
            w.params.copy_from_slice(&fresh);
            ep.pool().put_f32(fresh);
            ep.comm_wait_since(&tw)
        };

        w.shuffle.give_back(ep, batch);
        w.record_step(step, loss, ep.elapsed(&t0), shuffle_stall + pull_wait);
        if w.cfg.eval_every > 0 && (step % w.cfg.eval_every == 0 || step + 1 == steps)
        {
            let (_, acc) = w.evaluate();
            w.metrics.accuracy.push((step, acc));
        }
    }
    w.shuffle.drain(ep);
    w.snapshot_counters(ep);
}

/// Parameter-server loop (runs on fabric rank `workers`..): aggregates
/// the workers' gradients each step, applies the update centrally, and
/// broadcasts fresh weights.
///
/// Virtual-clock cost model (Fig 2(a)): the server charges
/// `cfg.virt_ps_agg_secs` of aggregation compute per worker per step
/// (one host-memory reduction pass over the model), and its broadcast is
/// serialized on the server's single NIC — `M·β` of link occupancy is
/// charged between consecutive sends, so the k-th worker's fresh model
/// leaves k transfers late.  Both charges are no-ops on a wall fabric.
/// Workers may push monolithically (one `REDUCE` message) or layer-wise
/// (one message per layer, `cfg.layerwise`); aggregation order is
/// src-major in both cases, so the reduced model is bit-identical.
pub fn run_ps_server(
    ep: &Endpoint,
    backend: &super::worker::Backend,
    workers: usize,
    cfg: &RunConfig,
) {
    let mut params = backend.init_params();
    let mut mom = vec![0.0f32; params.len()];
    let mut acc = vec![0.0f32; params.len()];
    let layers: Vec<(usize, usize)> = backend
        .layers()
        .iter()
        .map(|l| (l.offset, l.len))
        .collect();
    let beta = ep.fabric().cost.beta;
    for step in 0..cfg.steps {
        acc.iter_mut().for_each(|v| *v = 0.0);
        for src in 0..workers {
            if cfg.layerwise {
                for (li, &(off, len)) in layers.iter().enumerate() {
                    let g = ep.recv(src, Tag::layer(li).round(step));
                    ops::add_into(&mut acc[off..off + len], &g);
                    ep.pool().put_f32(g);
                }
            } else {
                let g = ep.recv(src, Tag::REDUCE.round(step));
                ops::add_into(&mut acc, &g);
                ep.pool().put_f32(g);
            }
        }
        // server-side aggregation + update compute (virtual clock only)
        ep.advance(cfg.virt_ps_agg_secs * workers as f64);
        ops::scale(&mut acc, 1.0 / workers as f32);
        let lr = cfg.lr_schedule.lr_at(cfg.effective_lr(), step) as f32;
        backend.apply_update(&mut params, &mut mom, &acc, lr);
        // serialized-broadcast occupancy matches what each send actually
        // charges: the model rides the stateless auto path, so its wire
        // bytes are codec-compressed (top-k falls back to dense there)
        let wire = cfg.codec.stateless_wire_bytes_for(params.len()) as f64 * beta;
        for dst in 0..workers {
            if dst > 0 {
                // transfer k cannot start until transfer k-1 clears the
                // server's NIC: the broadcast serialization of Fig 2(a).
                // Only *inter-send* gaps serialize — the final transfer
                // drains while the server is already receiving step
                // k+1's pushes (full-duplex link), so charging after
                // the last send would delay the next step's first recv
                // by a whole transfer the server can in fact overlap.
                ep.advance(wire);
            }
            ep.isend(dst, Tag::MODEL.round(step), ep.pool().copy_f32(&params));
        }
    }
}
