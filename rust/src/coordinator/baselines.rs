//! The baselines the paper evaluates GossipGraD against.
//!
//! * [`run_allreduce`] — synchronous SGD (whole-model all-reduce after
//!   backprop) and AGD (layer-wise all-reduce; §3.2/S-Caffe/PowerAI
//!   style).  AGD's *gradient averaging* is mathematically identical to
//!   SGD — the paper treats AGD as "theoretically equivalent" (§7.1) —
//!   the difference is the communication schedule.
//! * [`run_periodic`] — AGD communicating every ⌈log₂ p⌉ steps (Fig 17).
//! * [`run_param_server`] — Fig 2(a): workers push gradients to server
//!   rank(s), pull fresh weights.  Servers occupy the top ranks of the
//!   fabric (fabric size = workers + servers).

use super::worker::Worker;
use crate::collectives::Algorithm;
use crate::nativenet::ops;
use crate::transport::{Endpoint, Tag};
use crate::util::ceil_log2;

/// Synchronous all-reduce training.  `layerwise = true` → AGD (one
/// all-reduce per layer slice, the overlappable schedule); `false` →
/// plain SGD (single whole-model all-reduce).
pub fn run_allreduce(w: &mut Worker, ep: &Endpoint, alg: Algorithm, layerwise: bool) {
    let steps = w.cfg.steps;
    let layers: Vec<(usize, usize)> = w
        .backend
        .layers()
        .iter()
        .map(|l| (l.offset, l.len))
        .collect();
    for step in 0..steps {
        let t0 = ep.mark();
        let lr = w.lr_at(step);
        let batch = w.shuffle.take(ep);
        let (x, y) = w.to_batch_data(&batch);
        let (mut grads, loss) = w.backend.grad(&w.params, &x, &y);
        ep.advance(w.cfg.virt_compute_secs);

        let tw = ep.mark();
        if layerwise {
            for (li, &(off, len)) in layers.iter().enumerate() {
                alg.run(ep, &mut grads[off..off + len], step * layers.len() + li);
            }
        } else {
            alg.run(ep, &mut grads, step);
        }
        let comm_wait = ep.comm_wait_since(&tw);

        w.backend.apply_update(&mut w.params, &mut w.mom, &grads, lr);
        w.shuffle.give_back(ep, batch);
        w.record_step(step, loss, ep.elapsed(&t0), comm_wait);
        if w.cfg.eval_every > 0 && (step % w.cfg.eval_every == 0 || step + 1 == steps)
        {
            let (_, acc) = w.evaluate();
            w.metrics.accuracy.push((step, acc));
        }
    }
    w.snapshot_counters(ep);
}

/// AGD every ⌈log₂ p⌉ steps (Fig 17's "computing AGD every log(p)
/// iterations"): local updates in between, model (not gradient)
/// averaging at the boundary so updates are not lost.
pub fn run_periodic(w: &mut Worker, ep: &Endpoint, alg: Algorithm) {
    let steps = w.cfg.steps;
    let period = ceil_log2(w.cfg.ranks).max(1);
    for step in 0..steps {
        let t0 = ep.mark();
        let lr = w.lr_at(step);
        let batch = w.shuffle.take(ep);
        let (x, y) = w.to_batch_data(&batch);
        let (grads, loss) = w.backend.grad(&w.params, &x, &y);
        ep.advance(w.cfg.virt_compute_secs);
        w.backend.apply_update(&mut w.params, &mut w.mom, &grads, lr);

        let mut comm_wait = 0.0;
        if step % period == period - 1 {
            let tw = ep.mark();
            alg.run(ep, &mut w.params, step);
            comm_wait = ep.comm_wait_since(&tw);
        }
        w.shuffle.give_back(ep, batch);
        w.record_step(step, loss, ep.elapsed(&t0), comm_wait);
        if w.cfg.eval_every > 0 && (step % w.cfg.eval_every == 0 || step + 1 == steps)
        {
            let (_, acc) = w.evaluate();
            w.metrics.accuracy.push((step, acc));
        }
    }
    w.snapshot_counters(ep);
}

/// Parameter-server worker loop: push grads, pull weights, every step.
pub fn run_ps_worker(w: &mut Worker, ep: &Endpoint, server: usize) {
    let steps = w.cfg.steps;
    for step in 0..steps {
        let t0 = ep.mark();
        let batch = w.shuffle.take(ep);
        let (x, y) = w.to_batch_data(&batch);
        let (grads, loss) = w.backend.grad(&w.params, &x, &y);
        ep.advance(w.cfg.virt_compute_secs);

        let tw = ep.mark();
        ep.isend(server, Tag::REDUCE.round(step), grads);
        let fresh = ep.recv(server, Tag::MODEL.round(step));
        let comm_wait = ep.comm_wait_since(&tw);
        w.params.copy_from_slice(&fresh);

        w.shuffle.give_back(ep, batch);
        w.record_step(step, loss, ep.elapsed(&t0), comm_wait);
        if w.cfg.eval_every > 0 && (step % w.cfg.eval_every == 0 || step + 1 == steps)
        {
            let (_, acc) = w.evaluate();
            w.metrics.accuracy.push((step, acc));
        }
    }
    w.snapshot_counters(ep);
}

/// Parameter-server loop (runs on fabric rank `workers`..): aggregates
/// the workers' gradients each step, applies the update centrally, and
/// broadcasts fresh weights.  `lr_of(step)` mirrors the workers'
/// schedule.
pub fn run_ps_server(
    ep: &Endpoint,
    backend: &super::worker::Backend,
    workers: usize,
    steps: usize,
    lr_of: impl Fn(usize) -> f32,
) {
    let mut params = backend.init_params();
    let mut mom = vec![0.0f32; params.len()];
    let mut acc = vec![0.0f32; params.len()];
    for step in 0..steps {
        acc.iter_mut().for_each(|v| *v = 0.0);
        for src in 0..workers {
            let g = ep.recv(src, Tag::REDUCE.round(step));
            ops::add_into(&mut acc, &g);
        }
        ops::scale(&mut acc, 1.0 / workers as f32);
        backend.apply_update(&mut params, &mut mom, &acc, lr_of(step));
        for dst in 0..workers {
            ep.isend(dst, Tag::MODEL.round(step), params.clone());
        }
    }
}
