//! Per-rank training state shared by GossipGraD and every baseline.

use super::shuffle::{RingShuffle, SampleBatch};
use crate::config::RunConfig;
use crate::data::synthetic::Dataset;
use crate::data::Shard;
use crate::metrics::RunMetrics;
use crate::runtime::{BatchData, ModelBackend};
use crate::transport::Endpoint;
use std::sync::Arc;

pub type Backend = Arc<dyn ModelBackend + Send + Sync>;

/// Initial (params, momentum) for a rank: the backend's common init, or
/// a checkpoint when `cfg.resume_from` is set (all ranks resume from the
/// same state, as they started from the same init).
pub fn initial_state(backend: &Backend, cfg: &RunConfig) -> (Vec<f32>, Vec<f32>) {
    if let Some(dir) = &cfg.resume_from {
        let ck = super::checkpoint::Checkpoint::load(std::path::Path::new(dir))
            .unwrap_or_else(|e| panic!("resume_from {dir}: {e}"));
        assert_eq!(
            ck.params.len(),
            backend.param_count(),
            "checkpoint size mismatch"
        );
        (ck.params, ck.momentum)
    } else {
        let params = backend.init_params();
        let n = params.len();
        (params, vec![0.0; n])
    }
}

/// One rank's model replica + data + metrics.
pub struct Worker {
    pub rank: usize,
    pub backend: Backend,
    pub params: Vec<f32>,
    pub mom: Vec<f32>,
    pub shuffle: RingShuffle,
    pub metrics: RunMetrics,
    pub cfg: RunConfig,
    /// validation set shared by all ranks (read-only)
    pub val: Arc<Dataset>,
}

impl Worker {
    pub fn new(
        rank: usize,
        ep: &Endpoint,
        backend: Backend,
        train: &Dataset,
        val: Arc<Dataset>,
        cfg: &RunConfig,
    ) -> Worker {
        let p = cfg.ranks;
        let shard = Shard::partition(train, rank, p);
        let batch = backend.batch();
        // cut the shard into batch-sized circulating units
        let n_batches = (shard.rows / batch).max(1);
        let mut batches = Vec::with_capacity(n_batches);
        for b in 0..n_batches {
            let lo = b * batch;
            let hi = ((b + 1) * batch).min(shard.rows);
            let mut x = Vec::with_capacity(batch * shard.dim);
            let mut y = Vec::with_capacity(batch);
            for i in lo..hi {
                x.extend_from_slice(shard.row(i));
                y.push(shard.y[i]);
            }
            // pad the tail batch by wrapping (static shapes)
            let mut i = lo;
            while y.len() < batch {
                x.extend_from_slice(shard.row(i % shard.rows));
                y.push(shard.y[i % shard.rows]);
                i += 1;
            }
            batches.push(SampleBatch { x, y });
        }
        let shuffle = RingShuffle::new(
            ep,
            p,
            batches,
            backend.labels_len(),
            cfg.sample_shuffle,
        );
        let (params, mom) = initial_state(&backend, cfg);
        Worker {
            rank,
            backend,
            params,
            mom,
            shuffle,
            metrics: RunMetrics::new(rank),
            cfg: cfg.clone(),
            val,
        }
    }

    /// Learning rate at `step` (schedule over the *effective* base lr).
    pub fn lr_at(&self, step: usize) -> f32 {
        self.cfg
            .lr_schedule
            .lr_at(self.cfg.effective_lr(), step) as f32
    }

    /// Convert a circulating batch into backend input form.
    pub fn to_batch_data(&self, b: &SampleBatch) -> (BatchData, Vec<i32>) {
        if self.backend.x_is_int() {
            let toks: Vec<i32> = b.x.iter().map(|&v| v as i32).collect();
            (BatchData::I32(toks), b.y.clone())
        } else {
            (BatchData::F32(b.x.clone()), b.y.clone())
        }
    }

    /// Evaluate on the shared validation set; returns (loss, accuracy).
    /// For the LM, "accuracy" is next-token accuracy (labels per row =
    /// sequence length); for image tasks it is top-1 classification.
    pub fn evaluate(&self) -> (f64, f64) {
        let batch = self.backend.batch();
        let dim = self.val.dim;
        let labels_per_row = self.backend.labels_len() / batch;
        let mut total_loss = 0.0f64;
        let mut total_correct = 0.0f64;
        let mut label_rows = 0usize;
        let n_batches = (self.val.rows / batch).clamp(1, 64);
        for b in 0..n_batches {
            let lo = (b * batch) % self.val.rows.max(1);
            let mut x = Vec::with_capacity(batch * dim);
            let mut y = Vec::with_capacity(batch * labels_per_row);
            for i in 0..batch {
                let r = (lo + i) % self.val.rows;
                x.extend_from_slice(self.val.row(r));
                y.extend_from_slice(
                    &self.val.y[r * labels_per_row..(r + 1) * labels_per_row],
                );
            }
            let xb = if self.backend.x_is_int() {
                BatchData::I32(x.iter().map(|&v| v as i32).collect())
            } else {
                BatchData::F32(x)
            };
            let (loss, correct) = self.backend.eval(&self.params, &xb, &y);
            total_loss += loss as f64;
            total_correct += correct as f64;
            label_rows += batch * labels_per_row;
        }
        (
            total_loss / n_batches as f64,
            total_correct / label_rows.max(1) as f64,
        )
    }

    /// Snapshot the transport's traffic + overlap-ledger counters into
    /// this rank's metrics at the end of a run.
    pub fn snapshot_counters(&mut self, ep: &Endpoint) {
        use std::sync::atomic::Ordering;
        let c = ep.fabric().counters(self.rank);
        self.metrics.msgs_sent = c.msgs_sent.load(Ordering::Relaxed);
        self.metrics.bytes_sent = c.bytes_sent.load(Ordering::Relaxed);
        self.metrics.recv_wait_secs =
            c.recv_wait_ns.load(Ordering::Relaxed) as f64 * 1e-9;
        self.metrics.comm_hidden_secs =
            c.comm_hidden_ns.load(Ordering::Relaxed) as f64 * 1e-9;
    }

    /// Charge modeled compute to this rank's virtual clock, scaled by
    /// the deterministic per-(rank, step) straggler factor (no-op on a
    /// wall fabric, where compute takes real time).
    pub fn charge_compute(&self, ep: &Endpoint, step: usize, secs: f64) {
        if secs > 0.0 {
            ep.advance(
                secs * crate::sim::jitter_factor(
                    self.cfg.seed,
                    self.rank,
                    step,
                    self.cfg.straggler_jitter,
                ),
            );
        }
    }

    /// The layer-wise pipeline's backprop schedule: per-layer
    /// `(table index, offset, len, compute-slice seconds)` in backprop
    /// *completion* order — the output layer (last table entry) first,
    /// mirroring `Workload::layer_compute_slices`.  The backward budget
    /// (`virt_compute_secs − virt_fwd_secs`) is split across layers
    /// proportionally to their parameter bytes.
    pub fn bwd_schedule(&self) -> Vec<(usize, usize, usize, f64)> {
        let layers = self.backend.layers();
        let bytes: Vec<usize> = layers.iter().rev().map(|l| l.len * 4).collect();
        let bwd = (self.cfg.virt_compute_secs - self.cfg.virt_fwd_secs).max(0.0);
        let slices = crate::sim::split_compute(bwd, &bytes);
        layers
            .iter()
            .enumerate()
            .rev()
            .zip(slices)
            .map(|((li, l), secs)| (li, l.offset, l.len, secs))
            .collect()
    }

    /// Record one step's timings into the metrics.  `step_secs` and
    /// `comm_wait` are seconds on the rank's active clock (wall seconds,
    /// or simulated seconds in virtual-clock mode — see
    /// [`Endpoint::mark`]/[`Endpoint::elapsed`]).
    pub fn record_step(&mut self, step: usize, loss: f32, step_secs: f64, comm_wait: f64) {
        self.metrics.step_secs.push(step_secs);
        self.metrics.comm_wait_secs.push(comm_wait);
        if step % 10 == 0 || step + 1 == self.cfg.steps {
            self.metrics.loss.push((step, loss as f64));
        }
    }
}
