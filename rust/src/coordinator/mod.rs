//! The GossipGraD coordinator — the paper's contribution (L3).
//!
//! * [`gossip`]      — the GossipGraD engine: dissemination/hypercube
//!   partner selection, §4.5.1 partner rotation, pairwise model mixing,
//!   §5.1 asynchronous (overlapped) exchange, §4.5.2 ring sample shuffle.
//! * [`baselines`]   — everything the paper compares against: synchronous
//!   all-reduce SGD, AGD (layer-wise all-reduce), AGD-every-log(p) steps
//!   (Fig 17), random gossip (Jin/Blot), parameter server (Fig 2a).
//! * [`shuffle`]     — the asynchronous distributed sample shuffle.
//! * [`worker`]      — per-rank training state shared by all algorithms.
//! * [`trainer`]     — multi-threaded launcher: one thread per rank over
//!   the in-process fabric, metrics collection, validation evaluation.
//!
//! ## Execution model
//! Each rank is a thread owning its model replica (flat `f32[N]`),
//! momentum buffer, and data shard.  Compute runs through a shared
//! [`ModelBackend`](crate::runtime::ModelBackend) (PJRT artifacts or the
//! native backend).  All communication flows through the MPI-like
//! transport, so message counts/bytes and blocked time are measured, not
//! estimated.

pub mod baselines;
pub mod checkpoint;
pub mod gossip;
pub mod shuffle;
pub mod trainer;
pub mod worker;

pub use trainer::{run, RunResult};
