//! Training launchers: build the fabric (over either link), dataset and
//! backend, run the selected algorithm on every rank and collect
//! per-rank metrics.
//!
//! Three entry shapes share one per-rank body ([`drive_worker`]):
//!
//! * [`run`] / [`run_with_backend`] — in-process ranks over the
//!   in-process link: cooperative coroutines on `--sim-threads`
//!   workers for virtual-clock runs (crate::sched, docs/perf.md), or
//!   the historical thread-per-rank launcher (wall clock, or
//!   `--legacy-ranks` as the parity oracle).
//! * [`run_rank_with_link`] — ONE rank over a caller-supplied
//!   [`Link`]; the unit the `rank` subcommand executes, one process
//!   per rank over [`TcpLink`](crate::transport::TcpLink).
//! * [`run_tcp_loopback`] — all ranks as threads, but each over its own
//!   TCP link on loopback ephemeral ports: the full socket wire path
//!   inside one process, powering the numerics-parity and drain tests
//!   (`tests/tcp_transport.rs`) and `run_with_backend`'s dispatch for
//!   `RunConfig::transport == Tcp`.

use super::baselines;
use super::gossip::{run_gossip, GossipTopology};
use super::worker::{Backend, Worker};
use crate::config::{Algo, CostModelKind, RunConfig, Transport};
use crate::data::synthetic::{self, Dataset};
use crate::membership::Membership;
use crate::metrics::RunMetrics;
use crate::nativenet::NativeMlp;
use crate::pool::PoolStats;
use crate::runtime::PjrtModel;
use crate::transport::{
    hybrid, ClockMode, Endpoint, Fabric, FaultyLink, GroupMap, HybridLink, InprocLink,
    Link, SchedLink, TcpLinkBuilder,
};

use anyhow::{Context, Result};
use std::sync::Arc;
use std::time::Duration;

/// How long a rank waits at end-of-run quiesce before declaring the
/// missing peers dead-or-hung (docs/fault-tolerance.md).
const QUIESCE_TIMEOUT: Duration = Duration::from_secs(120);

/// Outcome of one distributed run.
pub struct RunResult {
    pub per_rank: Vec<RunMetrics>,
    /// Final parameter vectors (rank-major) — used by convergence tests
    /// to measure cross-rank disagreement.
    pub final_params: Vec<Vec<f32>>,
    /// rank-0 validation accuracy at the end (if eval was enabled).
    pub final_accuracy: Option<f64>,
    pub wall_secs: f64,
    /// Messages still queued on the fabric after every rank finished —
    /// must be 0 (leaked `isend`/`irecv` pairs; see
    /// tests/fabric_drain.rs).
    pub in_flight_msgs: usize,
    /// Wire bytes those leaked messages occupy — the byte half of the
    /// drain invariant, also 0 on a clean run.
    pub in_flight_bytes: usize,
    /// Buffer-pool counters summed over the run's fabric(s): `allocs`
    /// is the allocation-count hook `tests/pooling.rs` and the bench
    /// gate assert on — in steady state (after warm-up) it stops
    /// growing because every payload draw hits a recycled buffer.
    pub pool_stats: PoolStats,
}

impl RunResult {
    /// Max pairwise L∞ distance between rank models (consensus metric;
    /// Corollary 6.3 says this shrinks under gossip).
    ///
    /// For L∞ the pairwise max equals the max over coordinates of
    /// (max − min) across ranks, so one coordinate-wise min/max pass —
    /// O(p·params) — replaces the O(p²·params) all-pairs scan (at
    /// p = 1024 that was ~1M vector comparisons per run).
    pub fn max_disagreement(&self) -> f32 {
        let Some(first) = self.final_params.first() else {
            return 0.0;
        };
        let n = first.len();
        let mut lo = first.clone();
        let mut hi = first.clone();
        for params in &self.final_params[1..] {
            debug_assert_eq!(params.len(), n);
            for ((l, h), &x) in lo.iter_mut().zip(hi.iter_mut()).zip(params) {
                *l = l.min(x);
                *h = h.max(x);
            }
        }
        lo.iter()
            .zip(&hi)
            .map(|(&l, &h)| h - l)
            .fold(0.0f32, f32::max)
    }

    /// FNV-1a checksum of every rank's final model bits (rank-major).
    /// Two runs with equal hashes produced bit-identical models — the
    /// cheap, serializable stand-in for comparing `final_params`
    /// directly (which the experiment engine's cached reports cannot
    /// carry).
    pub fn param_hash(&self) -> u64 {
        let mut bytes =
            Vec::with_capacity(self.final_params.iter().map(|p| p.len() * 4).sum());
        for params in &self.final_params {
            for x in params {
                bytes.extend_from_slice(&x.to_bits().to_le_bytes());
            }
        }
        crate::util::fnv1a64(&bytes)
    }

    pub fn mean_efficiency_pct(&self) -> f64 {
        crate::util::mean(
            &self
                .per_rank
                .iter()
                .map(|m| m.efficiency_pct())
                .collect::<Vec<_>>(),
        )
    }

    pub fn mean_step_secs(&self) -> f64 {
        crate::util::mean(
            &self
                .per_rank
                .iter()
                .map(|m| m.mean_step_secs())
                .collect::<Vec<_>>(),
        )
    }

    /// Ranks that finished the run alive — everyone whose metrics carry
    /// no `death_step`.  On a fault-free run this is simply `0..ranks`.
    pub fn survivors(&self) -> Vec<usize> {
        self.per_rank
            .iter()
            .filter(|m| m.death_step.is_none())
            .map(|m| m.rank)
            .collect()
    }

    /// Mean fraction of received wire time hidden under compute (§5.1
    /// overlap) across ranks — the measured-overlap column of the
    /// Fig 10/11 and Table 7 benches.
    pub fn mean_overlap_frac(&self) -> f64 {
        crate::util::mean(
            &self
                .per_rank
                .iter()
                .map(|m| m.overlap_frac())
                .collect::<Vec<_>>(),
        )
    }
}

/// Build the training/validation datasets for `cfg.model`.
pub fn build_datasets(
    cfg: &RunConfig,
    batch: usize,
    x_len: usize,
    classes: usize,
) -> (Dataset, Dataset) {
    let rows = cfg.rows_per_rank.max(batch * 2) * cfg.ranks;
    match cfg.model.as_str() {
        m if m.starts_with("mlp") => (
            synthetic::mnist_analog_split(rows, cfg.seed, 0),
            synthetic::mnist_analog_split(cfg.val_rows, cfg.seed, 1),
        ),
        "cnn" => (
            synthetic::cifar_analog_split(rows, cfg.seed, 0),
            synthetic::cifar_analog_split(cfg.val_rows, cfg.seed, 1),
        ),
        m if m.starts_with("transformer") => {
            let seq = x_len / batch;
            let mk = |n_rows: usize, stream: u64| {
                let toks = synthetic::token_corpus_split(
                    (n_rows + 1) * seq + 1,
                    classes,
                    4,
                    cfg.seed,
                    stream,
                );
                let (xs, ys) = crate::data::shard::lm_windows(&toks, seq);
                let rows = xs.len();
                Dataset {
                    x: xs.iter()
                        .flat_map(|w| w.iter().map(|&t| t as f32))
                        .collect(),
                    // labels: next tokens, flattened (seq per row) — the
                    // Dataset.y field holds row labels for image tasks;
                    // for LM we store targets separately per row below.
                    y: ys.iter().flat_map(|w| w.iter().cloned()).collect(),
                    dim: seq,
                    rows,
                    classes,
                }
            };
            (mk(rows, 0), mk(cfg.val_rows.max(4), 1))
        }
        other => panic!("unknown model {other:?}"),
    }
}

/// Load the configured backend (PJRT artifacts or native).
pub fn build_backend(cfg: &RunConfig) -> Result<Backend> {
    if cfg.use_artifacts {
        let dir = std::path::Path::new(&cfg.artifacts_dir);
        let m = PjrtModel::load(dir, &cfg.model)
            .with_context(|| format!("loading {} artifacts", cfg.model))?;
        Ok(Arc::new(m))
    } else {
        match cfg.model.as_str() {
            "mlp" => Ok(Arc::new(NativeMlp::mnist(64))),
            // tiny deterministic stand-in (same dims/batch/seed the
            // figure benches use) — lets p = 1024 sweep scenarios fit
            // in memory with one thread per rank
            "mlp-small" => Ok(Arc::new(NativeMlp::new(vec![784, 32, 10], 16, 0))),
            other => anyhow::bail!(
                "native backend only implements the mlp family (mlp, \
                 mlp-small), not {other:?}"
            ),
        }
    }
}

/// Ranks the fabric must address for `cfg`: the workers, plus the
/// parameter-server rank(s) occupying the top of the fabric for the PS
/// algorithm.  A multi-process launch spawns exactly this many
/// processes.
pub fn fabric_size(cfg: &RunConfig) -> usize {
    if cfg.algo == Algo::ParamServer {
        cfg.ranks + cfg.ps_servers.max(1)
    } else {
        cfg.ranks
    }
}

/// The per-rank training body shared by every launcher: build the
/// worker, run the configured algorithm, hand back its metrics and
/// final parameters.
fn drive_worker(
    rank: usize,
    ep: &Endpoint,
    backend: Backend,
    train: &Dataset,
    val: Arc<Dataset>,
    cfg: &RunConfig,
) -> (RunMetrics, Vec<f32>) {
    let p = cfg.ranks;
    let mut w = build_worker(rank, ep, backend, train, val, cfg);
    match cfg.algo {
        Algo::Gossip | Algo::GossipHypercube | Algo::GossipRandom => {
            let topo = GossipTopology::build_grouped(
                cfg.algo,
                p,
                cfg.rotation,
                cfg.seed,
                cfg.group_size,
                cfg.inter_period,
            );
            run_gossip(&mut w, ep, &topo, cfg.sync_mix);
        }
        Algo::SgdSync => baselines::run_allreduce(&mut w, ep, cfg.allreduce, false),
        Algo::Agd => baselines::run_allreduce(&mut w, ep, cfg.allreduce, true),
        Algo::PeriodicAgd => baselines::run_periodic(&mut w, ep, cfg.allreduce),
        Algo::ParamServer => baselines::run_ps_worker(&mut w, ep, p),
    }
    (w.metrics, w.params)
}

fn validate(cfg: &RunConfig) -> Result<()> {
    anyhow::ensure!(cfg.ranks >= 1, "need at least one rank");
    // a comm thread only overlaps collectives posted mid-backprop;
    // without the layer-wise pipeline it would silently measure the
    // blocking schedule while claiming otherwise
    anyhow::ensure!(
        !cfg.comm_thread || cfg.layerwise,
        "comm_thread requires layerwise (per-layer pipelined AGD)"
    );
    anyhow::ensure!(
        !(cfg.transport == Transport::Tcp && cfg.virtual_clock),
        "the TCP link runs on the wall clock only (docs/transport.md)"
    );
    // ---- hierarchical fabric (docs/topology.md) ----------------------
    anyhow::ensure!(cfg.group_size >= 1, "group_size must be at least 1");
    anyhow::ensure!(cfg.inter_period >= 1, "inter_period must be at least 1");
    anyhow::ensure!(
        cfg.ranks % cfg.group_size == 0,
        "group_size {} must divide ranks {}",
        cfg.group_size,
        cfg.ranks
    );
    if cfg.group_size > 1 {
        anyhow::ensure!(
            !matches!(
                cfg.algo,
                Algo::GossipHypercube | Algo::GossipRandom | Algo::ParamServer
            ),
            "--group-size > 1 needs a grouped schedule: only dissemination \
             gossip (--algo gossip) defines one, and the collective/PS \
             baselines ignore the topology entirely (docs/topology.md)"
        );
    }
    anyhow::ensure!(
        !(cfg.cost_model == CostModelKind::Hier && cfg.transport == Transport::Tcp),
        "--cost-model hier charges simulated two-tier costs on the \
         in-process fabric only; the TCP link pays real wire time (use \
         --group-size for the hybrid mailbox/socket split instead)"
    );
    let plan = &cfg.fault_plan;
    if plan.has_faults() {
        anyhow::ensure!(
            matches!(
                cfg.algo,
                Algo::Gossip | Algo::GossipHypercube | Algo::GossipRandom
            ),
            "fault plans only apply to the gossip family — collectives \
             and the parameter server block forever on a lost frame \
             (docs/fault-tolerance.md)"
        );
        anyhow::ensure!(
            (plan.kills.is_empty() && plan.joins.is_empty())
                || cfg.algo == Algo::Gossip,
            "kills/joins need --algo gossip: only the dissemination \
             topology has the collapsed-view survivor routing"
        );
        anyhow::ensure!(
            (0.0..1.0).contains(&plan.drop_frac),
            "drop_frac must be in [0, 1)"
        );
        anyhow::ensure!(
            (0.0..1.0).contains(&plan.dup_frac),
            "dup_frac must be in [0, 1)"
        );
        for &(r, s) in &plan.kills {
            anyhow::ensure!(r < cfg.ranks, "kill rank {r} outside 0..{}", cfg.ranks);
            anyhow::ensure!(
                s >= 1,
                "kill step for rank {r} must be >= 1 (a rank dead at \
                 step 0 should just not be launched)"
            );
            anyhow::ensure!(
                plan.join_step(r).is_none(),
                "rank {r} cannot both join late and be killed"
            );
        }
        let member = Membership::new(cfg.ranks, plan.clone());
        for &(r, s) in &plan.joins {
            anyhow::ensure!(r < cfg.ranks, "join rank {r} outside 0..{}", cfg.ranks);
            anyhow::ensure!(
                s >= 1 && s < cfg.steps,
                "join step for rank {r} must be in 1..steps ({}) — the \
                 joiner blocks on a donor snapshot that is only sent at \
                 a step the donor actually runs",
                cfg.steps
            );
            anyhow::ensure!(
                member.donor_for(r, s).is_some(),
                "joiner {r} has no alive donor at step {s}"
            );
        }
        anyhow::ensure!(
            member.view_at(cfg.steps).num_alive() >= 1,
            "the fault plan kills every rank before the run ends"
        );
    }
    Ok(())
}

/// Run a full distributed training job per `cfg`; blocks until done.
pub fn run(cfg: &RunConfig) -> Result<RunResult> {
    let backend = build_backend(cfg)?;
    run_with_backend(cfg, backend)
}

/// Like [`run`] but with a caller-provided backend (tests inject the
/// native backend or tiny models here).  Dispatches on
/// `cfg.transport`: in-process ranks (cooperative scheduler on the
/// virtual clock, thread-per-rank on the wall clock or with
/// `--legacy-ranks`), or one TCP link per rank on loopback
/// ([`run_tcp_loopback`]).
pub fn run_with_backend(cfg: &RunConfig, backend: Backend) -> Result<RunResult> {
    validate(cfg)?;
    if cfg.transport == Transport::Tcp {
        return run_tcp_loopback(cfg, backend);
    }
    let p = cfg.ranks;
    // Virtual-clock fabric makes all timing metrics deterministic
    // discrete-event simulated seconds (docs/virtual-time.md).  The
    // configured wire codec rides on the fabric so the transport's
    // stateless auto path compresses payload-kind messages.
    let mode = if cfg.virtual_clock {
        ClockMode::Virtual
    } else {
        ClockMode::Wall
    };
    let link: Arc<dyn Link> = {
        let base: Arc<dyn Link> = Arc::new(InprocLink::new(fabric_size(cfg)));
        if cfg.fault_plan.has_faults() {
            // interpose the fault layer between the ranks and the
            // in-proc link: drop/dup/slow verdicts are pure functions of
            // the shared plan, so the run stays deterministic
            // (docs/fault-tolerance.md)
            FaultyLink::new(base, cfg.fault_plan.clone())
        } else {
            base
        }
    };
    // Cooperative rank scheduler (docs/perf.md, "rank scheduler"):
    // virtual-clock rank bodies become coroutines on `--sim-threads`
    // workers, with `SchedLink` as the outermost wrapper turning parks
    // into yields and enqueues into wakes.  `--legacy-ranks` keeps the
    // historical thread-per-rank launcher as the differential-testing
    // oracle (tests/scheduler.rs pins bit parity).  Wall-clock runs
    // always use the legacy path: their waits are real `thread::sleep`s
    // that must not hold a scheduler worker hostage.
    let sched = (cfg.virtual_clock && !cfg.legacy_ranks && crate::sched::supported())
        .then(|| crate::sched::Scheduler::new(cfg.sim_threads));
    let link: Arc<dyn Link> = match &sched {
        Some(s) => Arc::new(SchedLink::new(link, s.handle())),
        None => link,
    };
    // --cost-model hier swaps the flat α–β charge for the two-tier
    // (intra/inter host-group) model; None keeps the historical charges
    let fabric =
        Fabric::with_link_codec_hier(link, cfg.cost_model(), mode, cfg.codec, cfg.hier_cost_model());
    fabric.pool().set_enabled(cfg.pool);

    let batch = backend.batch();
    let x_len = backend.x_len();
    let (train, val) = build_datasets(cfg, batch, x_len, backend.classes());
    // For the LM, labels live row-wise in train.y with `dim` targets per
    // row; the Worker's SampleBatch carries (x row, y row) pairs — image
    // tasks have 1 label per row, LM tasks have seq labels per row.
    let train = Arc::new(train);
    let val = Arc::new(val);

    let t0 = std::time::Instant::now();
    let outcomes: Vec<Option<(RunMetrics, Vec<f32>)>> = if let Some(sched) = &sched {
        // scheduled path: every rank body (and the PS server) is a
        // coroutine task; task index == fabric rank
        let mut bodies: Vec<Box<dyn FnOnce() -> Option<(RunMetrics, Vec<f32>)> + Send>> =
            Vec::with_capacity(p + 1);
        for rank in 0..p {
            let ep = fabric.endpoint(rank);
            let backend = Arc::clone(&backend);
            let train = Arc::clone(&train);
            let val = Arc::clone(&val);
            let cfg = cfg.clone();
            bodies.push(Box::new(move || {
                Some(drive_worker(rank, &ep, backend, &train, val, &cfg))
            }));
        }
        if cfg.algo == Algo::ParamServer {
            // the server is just one more cooperative task, on fabric
            // rank p (extra server slots stay idle, as on the legacy
            // path)
            let ep = fabric.endpoint(p);
            let sb = Arc::clone(&backend);
            let scfg = cfg.clone();
            bodies.push(Box::new(move || {
                baselines::run_ps_server(&ep, &sb, p, &scfg);
                None
            }));
        }
        // surface panics/deadlocks the way the legacy join path does,
        // keeping the scheduler's diagnostic message
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| sched.run(bodies)))
            .map_err(|e| {
                let msg = e
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "worker panicked".to_string());
                anyhow::anyhow!("{msg}")
            })?
    } else {
        // legacy thread-per-rank oracle: named, small-stack threads —
        // rank bodies keep model state on the heap, so
        // `sched::RANK_STACK_BYTES` replaces the 8 MiB default that
        // made p = 1024 cost 8 GiB of stack address space
        let mut handles = Vec::new();
        for rank in 0..p {
            let ep = fabric.endpoint(rank);
            let backend = Arc::clone(&backend);
            let train = Arc::clone(&train);
            let val = Arc::clone(&val);
            let cfg = cfg.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("rank-{rank}"))
                    .stack_size(crate::sched::RANK_STACK_BYTES)
                    .spawn(move || drive_worker(rank, &ep, backend, &train, val, &cfg))
                    .expect("spawning rank thread"),
            );
        }
        if cfg.algo == Algo::ParamServer {
            // dedicate this thread to the (first) server; extra servers
            // are future work — the paper's critique targets the
            // 1-server case
            let ep = fabric.endpoint(p);
            let sb = Arc::clone(&backend);
            baselines::run_ps_server(&ep, &sb, p, cfg);
        }
        handles
            .into_iter()
            .map(|h| {
                h.join()
                    .map(Some)
                    .map_err(|_| anyhow::anyhow!("worker panicked"))
            })
            .collect::<Result<Vec<_>>>()?
    };

    let mut per_rank = Vec::new();
    let mut final_params = Vec::new();
    for (m, params) in outcomes.into_iter().flatten() {
        per_rank.push(m);
        final_params.push(params);
    }
    per_rank.sort_by_key(|m| m.rank);
    let final_accuracy = per_rank
        .first()
        .and_then(|m| m.accuracy.last())
        .map(|&(_, a)| a);
    Ok(RunResult {
        per_rank,
        final_params,
        final_accuracy,
        wall_secs: t0.elapsed().as_secs_f64(),
        in_flight_msgs: fabric.in_flight(),
        in_flight_bytes: fabric.in_flight_bytes(),
        pool_stats: fabric.pool().stats(),
    })
}

/// What one rank of a multi-process run produces.  Worker ranks carry
/// metrics + final parameters; parameter-server ranks (fabric ranks ≥
/// `cfg.ranks`) carry neither.  `in_flight` is this rank's post-quiesce
/// link count — the launcher sums them for the global drain invariant.
pub struct RankOutcome {
    pub rank: usize,
    pub metrics: Option<RunMetrics>,
    pub params: Option<Vec<f32>>,
    pub in_flight: usize,
    /// Wire bytes of the leaked messages `in_flight` counts.
    pub in_flight_bytes: usize,
    /// This rank's fabric buffer-pool counters.
    pub pool_stats: PoolStats,
}

/// Run exactly ONE fabric rank over a caller-supplied link — the unit
/// of multi-process execution (`gossipgrad rank`).  Every process
/// derives the same datasets/backend deterministically from `cfg`, so
/// the numerics match the threads-as-ranks run bit for bit.
pub fn run_rank_with_link(
    cfg: &RunConfig,
    backend: Backend,
    rank: usize,
    link: Arc<dyn Link>,
) -> Result<RankOutcome> {
    validate(cfg)?;
    anyhow::ensure!(!cfg.virtual_clock, "multi-process links are wall-clock only");
    let n = fabric_size(cfg);
    anyhow::ensure!(
        link.size() == n,
        "link addresses {} ranks but the config needs {n}",
        link.size()
    );
    anyhow::ensure!(rank < n, "rank {rank} outside fabric of {n}");
    // interpose the fault layer over whatever link the caller built
    // (in-proc or TCP) — the same plan produces the same drop/dup
    // verdicts on both, which is what makes fault runs
    // transport-invariant (tests/failure_injection.rs)
    let link: Arc<dyn Link> = if cfg.fault_plan.has_faults() {
        FaultyLink::new(link, cfg.fault_plan.clone())
    } else {
        link
    };
    let fabric =
        Fabric::with_link_codec(link, cfg.cost_model(), ClockMode::Wall, cfg.codec);
    fabric.pool().set_enabled(cfg.pool);
    let ep = fabric.endpoint(rank);
    let p = cfg.ranks;
    let (metrics, params) = if rank < p {
        let batch = backend.batch();
        let x_len = backend.x_len();
        let (train, val) = build_datasets(cfg, batch, x_len, backend.classes());
        let (m, params) = drive_worker(rank, &ep, backend, &train, Arc::new(val), cfg);
        (Some(m), Some(params))
    } else {
        if rank == p {
            baselines::run_ps_server(&ep, &backend, p, cfg);
        }
        // extra server ranks (ps_servers > 1) idle, as in-proc
        (None, None)
    };
    // flush our sends, ingest peer streams to EOF, then count leaks —
    // bounded so a peer that died *unplanned* (no fault plan) surfaces
    // as a named error instead of hanging this rank forever.  Generous:
    // a planned-dead rank quiesces early and legitimately waits here
    // until the survivors finish their run.
    if let Err(e) = fabric.quiesce(rank, Some(QUIESCE_TIMEOUT)) {
        eprintln!("warning: {e}; counting undrained frames as leaks");
    }
    Ok(RankOutcome {
        rank,
        metrics,
        params,
        in_flight: fabric.in_flight(),
        in_flight_bytes: fabric.in_flight_bytes(),
        pool_stats: fabric.pool().stats(),
    })
}

/// All ranks as threads, each over its **own TCP link** on loopback
/// ephemeral ports — the full socket wire path (frames, handshakes,
/// reader/writer threads) without spawning processes.  Used by
/// `run_with_backend` when `cfg.transport == Tcp` and by the parity and
/// drain tests.
///
/// With `cfg.group_size > 1` each rank's link becomes a
/// [`HybridLink`]: same-group traffic moves through mailboxes shared by
/// the group's rank threads, only cross-group traffic touches the
/// sockets — the in-process analog of `launch --group-size`
/// (docs/topology.md).
pub fn run_tcp_loopback(cfg: &RunConfig, backend: Backend) -> Result<RunResult> {
    validate(cfg)?;
    let n = fabric_size(cfg);
    // bind every rank first so the full peer table is known before any
    // rank dials (ephemeral ports: no collisions, parallel-test safe)
    let builders = (0..n)
        .map(|_| TcpLinkBuilder::bind("127.0.0.1:0"))
        .collect::<std::io::Result<Vec<_>>>()
        .context("binding loopback listeners")?;
    let peers: Vec<String> =
        builders.iter().map(|b| b.local_addr().to_string()).collect();
    let groups = (cfg.group_size > 1).then(|| GroupMap::new(n, cfg.group_size));
    let shared: Vec<_> = groups
        .map(|g| {
            (0..g.num_groups())
                .map(|_| hybrid::group_mailboxes(g.group_size()))
                .collect::<Vec<_>>()
        })
        .unwrap_or_default();
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for (rank, b) in builders.into_iter().enumerate() {
        let peers = peers.clone();
        let cfg = cfg.clone();
        let backend = Arc::clone(&backend);
        let boxes = groups.map(|g| Arc::clone(&shared[g.group_of(rank)]));
        handles.push(
            std::thread::Builder::new()
                .name(format!("rank-{rank}"))
                .stack_size(crate::sched::RANK_STACK_BYTES)
                .spawn(move || -> Result<RankOutcome> {
                    let tcp = b
                        .establish(rank, &peers, cfg.cost_model(), Duration::from_secs(60))
                        .with_context(|| format!("rank {rank}: establishing tcp mesh"))?;
                    let link: Arc<dyn Link> = match (groups, boxes) {
                        (Some(g), Some(boxes)) => {
                            Arc::new(HybridLink::new(rank, g, boxes, tcp))
                        }
                        _ => tcp,
                    };
                    run_rank_with_link(&cfg, backend, rank, link)
                })
                .expect("spawning rank thread"),
        );
    }
    // join EVERY rank before surfacing an error: returning on the first
    // failure would leak still-running rank threads (sockets, io
    // threads) into the caller's process
    let joined: Vec<Result<RankOutcome>> = handles
        .into_iter()
        .map(|h| {
            h.join()
                .map_err(|_| anyhow::anyhow!("rank panicked"))
                .and_then(|r| r)
        })
        .collect();
    let mut outcomes = Vec::with_capacity(joined.len());
    for r in joined {
        outcomes.push(r?);
    }
    outcomes.sort_by_key(|o| o.rank);
    let in_flight_msgs = outcomes.iter().map(|o| o.in_flight).sum();
    let in_flight_bytes = outcomes.iter().map(|o| o.in_flight_bytes).sum();
    // each rank has its own fabric (and pool) here: sum the counters
    let pool_stats = outcomes.iter().fold(PoolStats::default(), |a, o| PoolStats {
        gets: a.gets + o.pool_stats.gets,
        allocs: a.allocs + o.pool_stats.allocs,
        returns: a.returns + o.pool_stats.returns,
    });
    let mut per_rank = Vec::new();
    let mut final_params = Vec::new();
    for o in outcomes {
        if let (Some(m), Some(p)) = (o.metrics, o.params) {
            per_rank.push(m);
            final_params.push(p);
        }
    }
    let final_accuracy = per_rank
        .first()
        .and_then(|m| m.accuracy.last())
        .map(|&(_, a)| a);
    Ok(RunResult {
        per_rank,
        final_params,
        final_accuracy,
        wall_secs: t0.elapsed().as_secs_f64(),
        in_flight_msgs,
        in_flight_bytes,
        pool_stats,
    })
}

/// Construct a Worker, handling the LM's row-wise multi-label layout.
fn build_worker(
    rank: usize,
    ep: &crate::transport::Endpoint,
    backend: Backend,
    train: &Dataset,
    val: Arc<Dataset>,
    cfg: &RunConfig,
) -> Worker {
    if backend.x_is_int() {
        // LM: each dataset row is one sequence; labels are seq targets.
        // Re-pack rows so Worker's batch cutter sees (x=seq toks, y=seq
        // targets) with batch = backend.batch() rows per batch.
        let seq = train.dim;
        let labels_per_row = backend.labels_len() / backend.batch();
        assert_eq!(labels_per_row, seq);
        let mut d = Dataset {
            x: train.x.clone(),
            y: train.y.clone(),
            dim: seq,
            rows: train.rows,
            classes: train.classes,
        };
        // Worker::new uses Shard { y per row = 1 }, so for the LM we
        // inline a custom cutter here instead.
        let p = cfg.ranks;
        let base = d.rows / p;
        let extra = d.rows % p;
        let my_rows = base + usize::from(rank < extra);
        let start = rank * base + rank.min(extra);
        let batch = backend.batch();
        let n_batches = (my_rows / batch).max(1);
        let mut batches = Vec::new();
        for b in 0..n_batches {
            let mut x = Vec::with_capacity(batch * seq);
            let mut y = Vec::with_capacity(batch * seq);
            for i in 0..batch {
                let r = start + (b * batch + i) % my_rows.max(1);
                x.extend_from_slice(&d.x[r * seq..(r + 1) * seq]);
                y.extend_from_slice(&d.y[r * seq..(r + 1) * seq]);
            }
            batches.push(super::shuffle::SampleBatch { x, y });
        }
        let shuffle = super::shuffle::RingShuffle::new(
            ep,
            p,
            batches,
            backend.labels_len(),
            cfg.sample_shuffle,
        );
        let (params, mom) = super::worker::initial_state(&backend, cfg);
        d.rows = my_rows;
        Worker {
            rank,
            backend,
            params,
            mom,
            shuffle,
            metrics: RunMetrics::new(rank),
            cfg: cfg.clone(),
            val,
        }
    } else {
        Worker::new(rank, ep, backend, train, val, cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result_with(params: Vec<Vec<f32>>) -> RunResult {
        RunResult {
            per_rank: Vec::new(),
            final_params: params,
            final_accuracy: None,
            wall_secs: 0.0,
            in_flight_msgs: 0,
            in_flight_bytes: 0,
            pool_stats: PoolStats::default(),
        }
    }

    /// The reference O(p²·params) all-pairs scan the min/max pass replaced.
    fn pairwise_linf(params: &[Vec<f32>]) -> f32 {
        let mut worst = 0.0f32;
        for a in params {
            for b in params {
                for (x, y) in a.iter().zip(b) {
                    worst = worst.max((x - y).abs());
                }
            }
        }
        worst
    }

    #[test]
    fn max_disagreement_matches_pairwise_scan() {
        let mut rng = crate::util::Rng::new(7);
        for p in [1usize, 2, 3, 8, 17] {
            let params: Vec<Vec<f32>> = (0..p)
                .map(|_| (0..33).map(|_| rng.f32() * 4.0 - 2.0).collect())
                .collect();
            let r = result_with(params);
            let fast = r.max_disagreement();
            let slow = pairwise_linf(&r.final_params);
            assert_eq!(fast, slow, "p={p}");
        }
        // empty + single-rank degenerate cases
        assert_eq!(result_with(Vec::new()).max_disagreement(), 0.0);
        assert_eq!(result_with(vec![vec![1.0, -3.0]]).max_disagreement(), 0.0);
    }

    #[test]
    fn param_hash_distinguishes_model_bits() {
        let a = result_with(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = result_with(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(a.param_hash(), b.param_hash());
        let c = result_with(vec![vec![1.0, 2.0], vec![3.0, 4.0000005]]);
        assert_ne!(a.param_hash(), c.param_hash());
        // rank-major: swapping ranks changes the hash
        let d = result_with(vec![vec![3.0, 4.0], vec![1.0, 2.0]]);
        assert_ne!(a.param_hash(), d.param_hash());
    }
}
