//! Declarative experiment engine: scenario grids → parallel execution
//! on the virtual fabric → cached, serializable reports.
//!
//! The paper's headline results are *sweeps* — efficiency vs p (Figs
//! 10/11, Table 7), gossip-period trade-offs (Fig 17), straggler
//! ablations — so the run-entry layer is grid-shaped, not point-shaped:
//!
//! 1. declare a [`Grid`] (cartesian product over `algo × p ×
//!    gossip_period × straggler_jitter × layerwise × comm_thread ×
//!    sync_mix × allreduce × codec × seed`) over a base [`RunConfig`];
//! 2. an [`Engine`] executes the scenarios on a work-stealing pool of
//!    host threads — each scenario is an independent deterministic
//!    virtual-clock run, so an N-thread sweep is **byte-identical** to
//!    a 1-thread sweep (asserted in `tests/experiment.rs`);
//! 3. results land as [`ScenarioReport`]s, cached on disk under the
//!    config's content hash ([`RunConfig::content_hash`]) and emitted
//!    as JSON + CSV artifacts (the `BENCH_*.json` trajectory).
//!
//! The `gossipgrad sweep` subcommand, the Fig 10/11 / Table 7 / Fig 17
//! benches, and the [`autotune`] pass are all thin layers over this
//! module.  See `docs/experiments.md`.

pub mod autotune;
pub mod cache;
pub mod grid;
pub mod report;

pub use autotune::{autotune_gossip_period, AutotuneReport};
pub use cache::DiskCache;
pub use grid::Grid;
pub use report::ScenarioReport;

use crate::config::RunConfig;
use crate::util::json::{arr, obj, Json};

use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Scenario executor: a work-stealing pool of host threads over a
/// [`Grid`]'s scenarios, with optional on-disk result caching.
pub struct Engine {
    /// Host worker threads (`--sweep-threads`): how many *scenarios*
    /// execute concurrently.  Rank-level parallelism inside each
    /// scenario is governed separately: virtual-clock scenarios run
    /// their rank bodies as coroutines on a bounded rank scheduler
    /// (`--sim-threads`, [`crate::sched`]), and all schedulers in the
    /// process draw their workers from **one global execution budget**
    /// of `available_parallelism` permits — so `sweep_threads ×
    /// sim_threads` (let alone `sweep_threads × p`) can never
    /// oversubscribe the host.  Engine threads holding no permit simply
    /// wait; the budget model is documented in `docs/perf.md`.
    pub threads: usize,
    /// Cache directory (`None` disables on-disk caching).
    pub cache_dir: Option<PathBuf>,
    /// In-memory memo (config hash → report): scenarios already run by
    /// *this* engine value are never re-executed, so e.g. `sweep
    /// --autotune-period` reuses the sweep's own runs for the period
    /// scenarios the autotuner revisits.  Deterministic runs make this
    /// transparent.
    memo: Mutex<HashMap<String, ScenarioReport>>,
}

impl Default for Engine {
    fn default() -> Engine {
        Engine::with_threads(default_threads())
    }
}

/// Default engine parallelism: the host's logical CPUs, capped at 8 —
/// scenarios are themselves parallel (their rank schedulers compete for
/// the shared execution budget), so more engine threads than this adds
/// queueing without speedup.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(2)
        .clamp(1, 8)
}

impl Engine {
    /// Engine with `threads` workers and no on-disk cache.
    pub fn with_threads(threads: usize) -> Engine {
        Engine {
            threads,
            cache_dir: None,
            memo: Mutex::new(HashMap::new()),
        }
    }

    /// Attach an on-disk cache directory.
    pub fn cached(mut self, dir: &Path) -> Engine {
        self.cache_dir = Some(dir.to_path_buf());
        self
    }

    /// Execute every scenario of `grid` (cache-aware), returning the
    /// reports in grid order regardless of which worker finished which
    /// scenario when.
    pub fn run(&self, grid: &Grid) -> Result<Sweep> {
        self.run_scenarios(&grid.scenarios())
    }

    /// Execute an explicit scenario list (the engine primitive `run`
    /// and the autotuner share).
    pub fn run_scenarios(&self, scenarios: &[RunConfig]) -> Result<Sweep> {
        let cache = match &self.cache_dir {
            Some(dir) => Some(DiskCache::open(dir)?),
            None => None,
        };
        let n = scenarios.len();
        let next = AtomicUsize::new(0);
        let executed = AtomicUsize::new(0);
        let hits = AtomicUsize::new(0);
        let done: Mutex<Vec<(usize, Result<ScenarioReport, String>)>> =
            Mutex::new(Vec::with_capacity(n));
        let workers = self.threads.clamp(1, n.max(1));
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let r =
                        self.run_one(&scenarios[i], cache.as_ref(), &executed, &hits);
                    done.lock().unwrap().push((i, r));
                });
            }
        });
        let mut slots = done.into_inner().unwrap();
        slots.sort_by_key(|(i, _)| *i);
        let mut reports = Vec::with_capacity(n);
        for (i, r) in slots {
            reports.push(r.map_err(|e| anyhow!("scenario {i}: {e}"))?);
        }
        Ok(Sweep {
            reports,
            runs_executed: executed.load(Ordering::Relaxed),
            cache_hits: hits.load(Ordering::Relaxed),
        })
    }

    fn run_one(
        &self,
        cfg: &RunConfig,
        cache: Option<&DiskCache>,
        executed: &AtomicUsize,
        hits: &AtomicUsize,
    ) -> Result<ScenarioReport, String> {
        let key = cfg.content_hash();
        if let Some(report) = self.memo.lock().unwrap().get(&key) {
            hits.fetch_add(1, Ordering::Relaxed);
            return Ok(report.clone());
        }
        if let Some(c) = cache {
            if let Some(report) = c.load(&key) {
                hits.fetch_add(1, Ordering::Relaxed);
                self.memo.lock().unwrap().insert(key, report.clone());
                return Ok(report);
            }
        }
        let res =
            crate::coordinator::run(cfg).map_err(|e| format!("{key}: {e:#}"))?;
        executed.fetch_add(1, Ordering::Relaxed);
        let report = ScenarioReport::from_run(cfg, &res);
        if let Some(c) = cache {
            c.store(&report)
                .map_err(|e| format!("{key}: cache store: {e}"))?;
        }
        self.memo.lock().unwrap().insert(key, report.clone());
        Ok(report)
    }
}

/// Outcome of an [`Engine::run`]: reports in grid order plus execution
/// accounting (how many scenarios actually ran vs were served from the
/// engine's in-memory memo or the on-disk cache — the determinism
/// tests assert on these).
pub struct Sweep {
    pub reports: Vec<ScenarioReport>,
    pub runs_executed: usize,
    pub cache_hits: usize,
}

impl Sweep {
    /// First report whose config matches `pred` (benches use this to
    /// pull named corners out of a grid).
    pub fn find<F: Fn(&RunConfig) -> bool>(&self, pred: F) -> Option<&ScenarioReport> {
        self.reports.iter().find(|r| pred(&r.config))
    }

    /// Like [`find`](Self::find) but panics with `what` — for benches
    /// whose grid provably contains the corner.
    pub fn get<F: Fn(&RunConfig) -> bool>(&self, what: &str, pred: F) -> &ScenarioReport {
        self.find(pred)
            .unwrap_or_else(|| panic!("sweep has no scenario matching {what}"))
    }

    /// Canonical JSON artifact: the reports, in grid order.  Contains
    /// *only* deterministic content (no wall times, no cache
    /// accounting), so two sweeps of the same grid — any thread count,
    /// warm or cold cache — serialize byte-identically.
    pub fn to_json(&self) -> Json {
        obj(vec![(
            "scenarios",
            arr(self.reports.iter().map(ScenarioReport::to_json).collect()),
        )])
    }

    /// Flat CSV companion (one row per scenario, grid order) for
    /// spreadsheet/plot ingestion.
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "key,algo,model,ranks,steps,gossip_period,straggler_jitter,\
             layerwise,comm_thread,sync_mix,allreduce,codec,seed,transport,\
             step_ms,efficiency_pct,overlap_frac,max_disagreement,\
             msgs_per_rank_step,in_flight_msgs,in_flight_bytes,param_hash\n",
        );
        for r in &self.reports {
            let c = &r.config;
            out.push_str(&format!(
                "{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{}\n",
                r.key,
                c.algo.name(),
                c.model,
                c.ranks,
                c.steps,
                c.gossip_period,
                c.straggler_jitter,
                c.layerwise,
                c.comm_thread,
                c.sync_mix,
                c.allreduce.name(),
                c.codec.name(),
                c.seed,
                c.transport.name(),
                1e3 * r.mean_step_secs,
                r.mean_efficiency_pct,
                r.mean_overlap_frac,
                r.max_disagreement,
                r.msgs_per_rank_step(),
                r.in_flight_msgs,
                r.in_flight_bytes,
                r.param_hash,
            ));
        }
        out
    }

    /// Write `<dir>/BENCH_<name>.json` + `<dir>/BENCH_<name>.csv`;
    /// returns both paths.
    pub fn write_artifacts(
        &self,
        dir: &Path,
        name: &str,
    ) -> std::io::Result<(PathBuf, PathBuf)> {
        std::fs::create_dir_all(dir)?;
        let json_path = dir.join(format!("BENCH_{name}.json"));
        let csv_path = dir.join(format!("BENCH_{name}.csv"));
        std::fs::write(&json_path, self.to_json().to_string() + "\n")?;
        std::fs::write(&csv_path, self.to_csv())?;
        Ok((json_path, csv_path))
    }
}
