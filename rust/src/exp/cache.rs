//! On-disk scenario-result cache, keyed by `RunConfig::content_hash`.
//!
//! One JSON file per scenario (`<dir>/<key>.json`, the canonical
//! `ScenarioReport` serialization).  Because reports round-trip
//! byte-identically, a cache hit reproduces the artifact a fresh run
//! would have written — sweeps resume for free after an interrupt, and
//! re-running a sweep with a warm cache is a pure artifact re-emission.
//!
//! Corrupt or unreadable entries are treated as misses (the scenario
//! re-runs and overwrites them), never as errors: a cache must not be
//! able to wedge a sweep.  Writes go through a temp file + rename so a
//! killed sweep can't leave a truncated entry that later parses as
//! garbage.

use super::report::ScenarioReport;
use crate::util::json::Json;
use std::path::{Path, PathBuf};

pub struct DiskCache {
    dir: PathBuf,
}

impl DiskCache {
    /// Open (creating if needed) a cache directory.
    pub fn open(dir: &Path) -> std::io::Result<DiskCache> {
        std::fs::create_dir_all(dir)?;
        Ok(DiskCache {
            dir: dir.to_path_buf(),
        })
    }

    pub fn entry_path(&self, key: &str) -> PathBuf {
        self.dir.join(format!("{key}.json"))
    }

    /// Load a cached report; `None` on miss *or* unparseable entry.
    /// The stored config must actually hash to the requested key (not
    /// just carry a matching `key` string) — a renamed, hand-edited,
    /// or stale-format entry is a miss, not a silent wrong answer.
    pub fn load(&self, key: &str) -> Option<ScenarioReport> {
        let text = std::fs::read_to_string(self.entry_path(key)).ok()?;
        let report = Json::parse(&text)
            .ok()
            .and_then(|j| ScenarioReport::from_json(&j).ok())?;
        (report.key == key && report.config.content_hash() == key)
            .then_some(report)
    }

    /// Persist a report under its key (temp file + atomic rename).
    pub fn store(&self, report: &ScenarioReport) -> std::io::Result<PathBuf> {
        let path = self.entry_path(&report.key);
        let tmp = self.dir.join(format!(".{}.tmp", report.key));
        std::fs::write(&tmp, report.to_json().to_string() + "\n")?;
        std::fs::rename(&tmp, &path)?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;

    fn tiny_report() -> ScenarioReport {
        let mut cfg = RunConfig::default();
        cfg.use_artifacts = false;
        cfg.ranks = 1;
        ScenarioReport {
            key: cfg.content_hash(),
            config: cfg,
            ranks: Vec::new(),
            mean_step_secs: 0.25,
            mean_efficiency_pct: 99.0,
            mean_overlap_frac: 0.5,
            max_disagreement: 0.0,
            param_hash: "00deadbeef00cafe".into(),
            in_flight_msgs: 0,
            in_flight_bytes: 0,
            final_accuracy: None,
        }
    }

    #[test]
    fn store_then_load_roundtrips() {
        let dir = std::env::temp_dir().join("gg_exp_cache_test");
        let _ = std::fs::remove_dir_all(&dir);
        let cache = DiskCache::open(&dir).unwrap();
        let r = tiny_report();
        assert!(cache.load(&r.key).is_none(), "cold cache misses");
        let path = cache.store(&r).unwrap();
        assert!(path.ends_with(format!("{}.json", r.key)));
        assert_eq!(cache.load(&r.key).as_ref(), Some(&r));
        // corrupt entry degrades to a miss
        std::fs::write(&path, "{not json").unwrap();
        assert!(cache.load(&r.key).is_none());
        // an entry stored under the wrong key is rejected
        let other = "0000000000000000";
        std::fs::write(
            cache.entry_path(other),
            r.to_json().to_string(),
        )
        .unwrap();
        assert!(cache.load(other).is_none());
        // an entry whose embedded config was edited (key string left
        // intact) no longer hashes to its key — also a miss
        cache.store(&r).unwrap();
        let tampered = std::fs::read_to_string(cache.entry_path(&r.key))
            .unwrap()
            .replace("\"ranks\":1", "\"ranks\":3");
        std::fs::write(cache.entry_path(&r.key), tampered).unwrap();
        assert!(cache.load(&r.key).is_none());
    }
}
