//! Declarative scenario grids: declare the sweep once, get the
//! cartesian product of concrete [`RunConfig`]s in a fixed, documented
//! order.
//!
//! Every axis left empty pins that field at the base config's value, so
//! a `Grid` is "the base scenario, varied along these axes".  Axis
//! nesting order (outer → inner) is `algo → ranks → gossip_period →
//! straggler_jitter → layerwise → comm_thread → sync_mix → allreduce →
//! codec → drop_frac → group_size → inter_period → seed`; scenario
//! index order — and therefore artifact row order — is a pure function
//! of the declaration, never of execution timing.
//!
//! Invalid combinations are skipped, not errored: `comm_thread` without
//! `layerwise` measures nothing (the collective engine has no backprop
//! slices to hide rounds under), so the product silently drops those
//! points — a `comm_thread × layerwise` grid yields the three runnable
//! corners.

use crate::codec::Codec;
use crate::collectives::Algorithm;
use crate::config::{Algo, CostModelKind, RunConfig};
use crate::sim::Workload;
use crate::util::args::Args;

use anyhow::{bail, Context, Result};

/// Cartesian scenario grid over a base [`RunConfig`].
#[derive(Clone, Debug)]
pub struct Grid {
    pub base: RunConfig,
    algos: Vec<Algo>,
    ranks: Vec<usize>,
    gossip_periods: Vec<usize>,
    jitters: Vec<f64>,
    layerwise: Vec<bool>,
    comm_threads: Vec<bool>,
    sync_mixes: Vec<bool>,
    allreduces: Vec<Algorithm>,
    codecs: Vec<Codec>,
    /// Frame-drop fractions for the fault axis (the base fault plan's
    /// other fields — kills, joins, seed — are inherited unchanged).
    drop_fracs: Vec<f64>,
    /// Host-group sizes for the hierarchical fabric axis
    /// (docs/topology.md).  `1` is the flat fabric; larger values carve
    /// the ranks into contiguous groups and (on gossip) switch to the
    /// two-level schedule.
    group_sizes: Vec<usize>,
    /// Inter-group exchange cadences for the two-level schedule — only
    /// meaningful alongside `group_size > 1`, so the product skips the
    /// redundant `group_size == 1 × inter_period > 1` corners.
    inter_periods: Vec<usize>,
    seeds: Vec<u64>,
}

impl Grid {
    pub fn new(base: RunConfig) -> Grid {
        Grid {
            base,
            algos: Vec::new(),
            ranks: Vec::new(),
            gossip_periods: Vec::new(),
            jitters: Vec::new(),
            layerwise: Vec::new(),
            comm_threads: Vec::new(),
            sync_mixes: Vec::new(),
            allreduces: Vec::new(),
            codecs: Vec::new(),
            drop_fracs: Vec::new(),
            group_sizes: Vec::new(),
            inter_periods: Vec::new(),
            seeds: Vec::new(),
        }
    }

    pub fn algos(mut self, v: &[Algo]) -> Self {
        self.algos = v.to_vec();
        self
    }
    pub fn ranks(mut self, v: &[usize]) -> Self {
        self.ranks = v.to_vec();
        self
    }
    pub fn gossip_periods(mut self, v: &[usize]) -> Self {
        self.gossip_periods = v.to_vec();
        self
    }
    pub fn jitters(mut self, v: &[f64]) -> Self {
        self.jitters = v.to_vec();
        self
    }
    pub fn layerwise(mut self, v: &[bool]) -> Self {
        self.layerwise = v.to_vec();
        self
    }
    pub fn comm_threads(mut self, v: &[bool]) -> Self {
        self.comm_threads = v.to_vec();
        self
    }
    pub fn sync_mixes(mut self, v: &[bool]) -> Self {
        self.sync_mixes = v.to_vec();
        self
    }
    pub fn allreduces(mut self, v: &[Algorithm]) -> Self {
        self.allreduces = v.to_vec();
        self
    }
    pub fn codecs(mut self, v: &[Codec]) -> Self {
        self.codecs = v.to_vec();
        self
    }
    pub fn drop_fracs(mut self, v: &[f64]) -> Self {
        self.drop_fracs = v.to_vec();
        self
    }
    pub fn group_sizes(mut self, v: &[usize]) -> Self {
        self.group_sizes = v.to_vec();
        self
    }
    pub fn inter_periods(mut self, v: &[usize]) -> Self {
        self.inter_periods = v.to_vec();
        self
    }
    pub fn seeds(mut self, v: &[u64]) -> Self {
        self.seeds = v.to_vec();
        self
    }

    /// The declared gossip-period axis (empty when pinned at the base
    /// value) — the `sweep --autotune-period` CLI reuses a grid's axis
    /// as the autotuner's candidate list.
    pub fn period_axis(&self) -> &[usize] {
        &self.gossip_periods
    }

    /// Materialize the product as concrete configs, in declaration
    /// order, with unrunnable `comm_thread && !layerwise` points
    /// dropped.
    pub fn scenarios(&self) -> Vec<RunConfig> {
        fn axis<T: Copy>(v: &[T], base: T) -> Vec<T> {
            if v.is_empty() {
                vec![base]
            } else {
                v.to_vec()
            }
        }
        let algos = axis(&self.algos, self.base.algo);
        let ranks = axis(&self.ranks, self.base.ranks);
        let periods = axis(&self.gossip_periods, self.base.gossip_period);
        let jitters = axis(&self.jitters, self.base.straggler_jitter);
        let layerwise = axis(&self.layerwise, self.base.layerwise);
        let comm_threads = axis(&self.comm_threads, self.base.comm_thread);
        let sync_mixes = axis(&self.sync_mixes, self.base.sync_mix);
        let allreduces = axis(&self.allreduces, self.base.allreduce);
        let codecs = axis(&self.codecs, self.base.codec);
        let drop_fracs = axis(&self.drop_fracs, self.base.fault_plan.drop_frac);
        let group_sizes = axis(&self.group_sizes, self.base.group_size);
        let inter_periods = axis(&self.inter_periods, self.base.inter_period);
        let seeds = axis(&self.seeds, self.base.seed);
        let mut out = Vec::new();
        for &algo in &algos {
            for &p in &ranks {
                for &period in &periods {
                    for &jitter in &jitters {
                        for &lw in &layerwise {
                            for &ct in &comm_threads {
                                for &sm in &sync_mixes {
                                    for &ar in &allreduces {
                                        for &codec in &codecs {
                                            for &drop in &drop_fracs {
                                                for &gs in &group_sizes {
                                                    for &ip in &inter_periods {
                                                        for &seed in &seeds {
                                                            if ct && !lw {
                                                                continue;
                                                            }
                                                            // lost frames are only
                                                            // survivable on the gossip
                                                            // family (collectives
                                                            // block forever on them)
                                                            if drop > 0.0
                                                                && !matches!(
                                                                    algo,
                                                                    Algo::Gossip
                                                                        | Algo::GossipHypercube
                                                                        | Algo::GossipRandom
                                                                )
                                                            {
                                                                continue;
                                                            }
                                                            // groups must tile the
                                                            // ranks, and only the §4.5.1
                                                            // rotation schedule (plus the
                                                            // collective baselines, where
                                                            // grouping is cost-only) has
                                                            // a two-level form — mirror
                                                            // of trainer validate()
                                                            if gs > 1
                                                                && (p % gs != 0
                                                                    || matches!(
                                                                        algo,
                                                                        Algo::GossipHypercube
                                                                            | Algo::GossipRandom
                                                                            | Algo::ParamServer
                                                                    ))
                                                            {
                                                                continue;
                                                            }
                                                            // inter_period is inert on
                                                            // the flat fabric — the
                                                            // crossing would duplicate
                                                            // runs under distinct keys
                                                            if gs == 1 && ip > 1 {
                                                                continue;
                                                            }
                                                            let mut c = self.base.clone();
                                                            c.algo = algo;
                                                            c.ranks = p;
                                                            c.gossip_period = period;
                                                            c.straggler_jitter = jitter;
                                                            c.layerwise = lw;
                                                            c.comm_thread = ct;
                                                            c.sync_mix = sm;
                                                            c.allreduce = ar;
                                                            c.codec = codec;
                                                            c.fault_plan.drop_frac = drop;
                                                            c.group_size = gs;
                                                            c.inter_period = ip;
                                                            c.seed = seed;
                                                            out.push(c);
                                                        }
                                                    }
                                                }
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// Number of runnable scenarios in the product.
    pub fn len(&self) -> usize {
        self.scenarios().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Read `--*-list` axes from CLI args onto a base config:
    /// `--algo-list`, `--ranks-list`, `--gossip-period-list`,
    /// `--jitter-list`, `--layerwise-list`, `--comm-thread-list`,
    /// `--sync-mix-list`, `--allreduce-list`, `--codec-list`,
    /// `--drop-frac-list`, `--group-size-list`, `--inter-period-list`,
    /// `--seed-list` — all comma-separated.
    pub fn from_args(base: RunConfig, args: &Args) -> Result<Grid> {
        let mut g = Grid::new(base);
        if let Some(v) = args.get("algo-list") {
            g.algos = split(v)
                .map(|t| Algo::parse(t).map_err(anyhow::Error::msg))
                .collect::<Result<_>>()?;
        }
        if let Some(v) = args.get("ranks-list") {
            g.ranks = parse_list(v, "--ranks-list")?;
        }
        if let Some(v) = args.get("gossip-period-list") {
            g.gossip_periods = parse_list(v, "--gossip-period-list")?;
        }
        if let Some(v) = args.get("jitter-list") {
            g.jitters = parse_list(v, "--jitter-list")?;
        }
        if let Some(v) = args.get("layerwise-list") {
            g.layerwise = parse_bools(v, "--layerwise-list")?;
        }
        if let Some(v) = args.get("comm-thread-list") {
            g.comm_threads = parse_bools(v, "--comm-thread-list")?;
        }
        if let Some(v) = args.get("sync-mix-list") {
            g.sync_mixes = parse_bools(v, "--sync-mix-list")?;
        }
        if let Some(v) = args.get("allreduce-list") {
            g.allreduces = split(v)
                .map(|t| Algorithm::parse(t).map_err(anyhow::Error::msg))
                .collect::<Result<_>>()?;
        }
        if let Some(v) = args.get("codec-list") {
            g.codecs = split(v)
                .map(|t| Codec::parse(t).map_err(anyhow::Error::msg))
                .collect::<Result<_>>()?;
        }
        if let Some(v) = args.get("drop-frac-list") {
            g.drop_fracs = parse_list(v, "--drop-frac-list")?;
        }
        if let Some(v) = args.get("group-size-list") {
            g.group_sizes = parse_list(v, "--group-size-list")?;
        }
        if let Some(v) = args.get("inter-period-list") {
            g.inter_periods = parse_list(v, "--inter-period-list")?;
        }
        if let Some(v) = args.get("seed-list") {
            g.seeds = parse_list(v, "--seed-list")?;
        }
        Ok(g)
    }

    /// Named grids for the ROADMAP sweeps: `period-jitter-<p>` is the
    /// layer-wise `gossip_period × straggler_jitter` product on the
    /// virtual LeNet3 fabric at `p` ranks (the Fig 17-style trade-off
    /// crossed with the straggler ablation — where does `overlap_frac`
    /// stop compensating?); `codec-frontier-<p>` is the wire-codec ×
    /// `gossip_period` product at `p` ranks (the bandwidth/fidelity
    /// frontier: how much wire compression buys once mixing is already
    /// overlapped, and what it costs in convergence); `hier-frontier-<p>`
    /// is the flat-vs-hierarchical gossip comparison under the two-tier
    /// cost model at `p` ranks (does the locality-aware schedule beat
    /// flat rotation once intra-host hops are ~free? — the measured-arm
    /// counterpart of `sim::avg_gossip_efficiency_with_topology`).
    pub fn preset(name: &str) -> Result<Grid> {
        if let Some(p) = name.strip_prefix("period-jitter-") {
            let p: usize = p.parse().with_context(|| {
                format!("preset {name:?}: rank count suffix")
            })?;
            return Ok(Grid::period_jitter(p));
        }
        if let Some(p) = name.strip_prefix("codec-frontier-") {
            let p: usize = p.parse().with_context(|| {
                format!("preset {name:?}: rank count suffix")
            })?;
            return Ok(Grid::codec_frontier(p));
        }
        if let Some(p) = name.strip_prefix("hier-frontier-") {
            let p: usize = p.parse().with_context(|| {
                format!("preset {name:?}: rank count suffix")
            })?;
            return Ok(Grid::hier_frontier(p));
        }
        bail!(
            "unknown preset {name:?} (try period-jitter-1024, \
             codec-frontier-1024 or hier-frontier-1024)"
        )
    }

    /// The ROADMAP `gossip_period × jitter` grid at `p` ranks: gossip
    /// with the layer-wise pipeline on the virtual-clock LeNet3 fabric
    /// (same α–β and device speed as the Fig 10/11 benches), periods
    /// 1–16 crossed with jitter amplitudes 0–0.5.  24 steps so even the
    /// period-16 row actually mixes (a period above the step count
    /// would silently measure the no-mixing schedule) and the whole
    /// axis stays eligible for `--autotune-period`.
    pub fn period_jitter(p: usize) -> Grid {
        let mut base = RunConfig {
            model: "mlp-small".into(),
            algo: Algo::Gossip,
            ranks: p,
            steps: 24,
            use_artifacts: false,
            rows_per_rank: 32,
            layerwise: true,
            ..Default::default()
        };
        base.virtualize(&Workload::lenet3(4.0), 200e-6, 1.0 / 0.5e9);
        Grid::new(base)
            .gossip_periods(&[1, 2, 4, 8, 16])
            .jitters(&[0.0, 0.1, 0.3, 0.5])
    }

    /// The wire-codec frontier at `p` ranks: every codec × gossip
    /// periods 1–4, layer-wise gossip on the same virtual LeNet3 fabric
    /// as [`period_jitter`](Self::period_jitter).  `eval_every` is on so
    /// each cell reports end-of-run accuracy next to its efficiency —
    /// the convergence column of the BENCH_codec_frontier artifact.
    pub fn codec_frontier(p: usize) -> Grid {
        let mut base = RunConfig {
            model: "mlp-small".into(),
            algo: Algo::Gossip,
            ranks: p,
            steps: 24,
            use_artifacts: false,
            rows_per_rank: 32,
            layerwise: true,
            eval_every: 8,
            ..Default::default()
        };
        base.virtualize(&Workload::lenet3(4.0), 200e-6, 1.0 / 0.5e9);
        Grid::new(base)
            .codecs(&[Codec::F32, Codec::Bf16, Codec::Int8, Codec::TopK])
            .gossip_periods(&[1, 2, 4])
    }

    /// The hierarchical-fabric frontier at `p` ranks: gossip on the
    /// virtual-clock fabric with the two-tier [`HierCostModel`]
    /// (NVLink-class links inside each 8-rank host group, a slow
    /// α = 200 µs / 0.5 GB/s tier between groups), swept over
    /// `group_size × inter_period`.  Three runnable rows:
    ///
    /// * `group_size = 1` — flat §4.5.1 rotation, every hop charged at
    ///   the inter-group tier (the uniform-scatter baseline);
    /// * `group_size = 8, inter_period = 1` — hierarchical *costs* but
    ///   a topology-blind cadence (every exchange still crosses hosts);
    /// * `group_size = 8, inter_period = 4` — the locality-aware
    ///   two-level schedule (dense intra-group mixing, one inter-group
    ///   exchange in four).
    ///
    /// The BENCH_hier_frontier gate asserts the last row's step time
    /// beats the first by ≥ 1.5× — and the middle row shows the win
    /// comes from the *schedule*, not merely from faster local links.
    /// Device speed 100 keeps compute (0.25 ms) well under the
    /// inter-tier wire time (~0.6 ms for the ~100 KB mlp-small model)
    /// so the comparison measures the fabric, not the backprop.
    ///
    /// [`HierCostModel`]: crate::transport::HierCostModel
    pub fn hier_frontier(p: usize) -> Grid {
        let mut base = RunConfig {
            model: "mlp-small".into(),
            algo: Algo::Gossip,
            ranks: p,
            steps: 24,
            use_artifacts: false,
            rows_per_rank: 32,
            layerwise: true,
            cost_model: CostModelKind::Hier,
            ..Default::default()
        };
        base.virtualize(&Workload::lenet3(100.0), 200e-6, 1.0 / 0.5e9);
        Grid::new(base)
            .group_sizes(&[1, 8])
            .inter_periods(&[1, 4])
    }
}

fn split(v: &str) -> impl Iterator<Item = &str> {
    v.split(',').map(str::trim).filter(|t| !t.is_empty())
}

fn parse_list<T: std::str::FromStr>(v: &str, what: &str) -> Result<Vec<T>>
where
    T::Err: std::error::Error + Send + Sync + 'static,
{
    split(v)
        .map(|t| t.parse::<T>().with_context(|| format!("{what}: {t:?}")))
        .collect()
}

fn parse_bools(v: &str, what: &str) -> Result<Vec<bool>> {
    split(v)
        .map(|t| match t {
            "true" | "1" | "yes" | "on" => Ok(true),
            "false" | "0" | "no" | "off" => Ok(false),
            other => bail!("{what}: expected bool, got {other:?}"),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_axes_yield_the_base_scenario() {
        let g = Grid::new(RunConfig::default());
        let s = g.scenarios();
        assert_eq!(s.len(), 1);
        assert_eq!(s[0], RunConfig::default());
    }

    #[test]
    fn product_order_is_declaration_order() {
        let g = Grid::new(RunConfig::default())
            .algos(&[Algo::Gossip, Algo::Agd])
            .ranks(&[2, 4])
            .gossip_periods(&[1, 3]);
        let s = g.scenarios();
        assert_eq!(s.len(), 8);
        // algo outermost, period innermost
        assert_eq!((s[0].algo, s[0].ranks, s[0].gossip_period), (Algo::Gossip, 2, 1));
        assert_eq!((s[1].algo, s[1].ranks, s[1].gossip_period), (Algo::Gossip, 2, 3));
        assert_eq!((s[2].algo, s[2].ranks, s[2].gossip_period), (Algo::Gossip, 4, 1));
        assert_eq!((s[4].algo, s[4].ranks, s[4].gossip_period), (Algo::Agd, 2, 1));
        // every scenario gets a distinct content hash
        let mut keys: Vec<String> =
            s.iter().map(RunConfig::content_hash).collect();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), 8);
    }

    #[test]
    fn comm_thread_without_layerwise_is_dropped() {
        let g = Grid::new(RunConfig::default())
            .layerwise(&[false, true])
            .comm_threads(&[false, true]);
        let s = g.scenarios();
        assert_eq!(s.len(), 3, "the ct ∧ ¬lw corner must be skipped");
        assert!(s.iter().all(|c| !c.comm_thread || c.layerwise));
    }

    #[test]
    fn from_args_reads_every_axis() {
        let args = Args::parse(
            "sweep --algo-list gossip,agd --ranks-list 2,4,8 \
             --gossip-period-list 1,2 --jitter-list 0,0.25 \
             --layerwise-list true --comm-thread-list false,true \
             --sync-mix-list false --allreduce-list rd,ring \
             --codec-list f32,bf16 --seed-list 1,2,3"
                .split_whitespace()
                .map(|t| t.to_string()),
            &[],
        )
        .unwrap();
        let g = Grid::from_args(RunConfig::default(), &args).unwrap();
        // 2 × 3 × 2 × 2 × 1 × 2 × 1 × 2 × 2 × 3
        assert_eq!(g.len(), 2 * 3 * 2 * 2 * 2 * 2 * 2 * 3);
        assert!(Grid::from_args(
            RunConfig::default(),
            &Args::parse(
                ["--algo-list".to_string(), "nope".to_string()].into_iter(),
                &[]
            )
            .unwrap()
        )
        .is_err());
    }

    #[test]
    fn preset_parses_rank_suffix() {
        let g = Grid::preset("period-jitter-64").unwrap();
        assert_eq!(g.base.ranks, 64);
        assert_eq!(g.len(), 20, "5 periods × 4 jitters");
        assert!(g.base.virtual_clock && g.base.layerwise);
        // every period row must mix at least once within the run (and
        // stay eligible for --autotune-period, which rejects periods
        // beyond the step count)
        assert!(g.period_axis().iter().all(|&p| p <= g.base.steps));
        assert!(Grid::preset("nope").is_err());
    }

    #[test]
    fn codec_axis_multiplies_the_product() {
        let g = Grid::new(RunConfig::default())
            .codecs(&[Codec::F32, Codec::Bf16, Codec::TopK])
            .gossip_periods(&[1, 2]);
        let s = g.scenarios();
        assert_eq!(s.len(), 6);
        // period outer, codec inner
        assert_eq!((s[0].gossip_period, s[0].codec), (1, Codec::F32));
        assert_eq!((s[1].gossip_period, s[1].codec), (1, Codec::Bf16));
        assert_eq!((s[3].gossip_period, s[3].codec), (2, Codec::F32));
        let mut keys: Vec<String> = s.iter().map(RunConfig::content_hash).collect();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), 6, "codec must reshape every scenario key");
    }

    #[test]
    fn drop_frac_axis_multiplies_and_skips_non_gossip() {
        let g = Grid::new(RunConfig::default())
            .algos(&[Algo::Gossip, Algo::Agd])
            .drop_fracs(&[0.0, 0.05]);
        let s = g.scenarios();
        // gossip gets both corners; AGD only the lossless one
        assert_eq!(s.len(), 3, "drop > 0 on a collective algo must be skipped");
        assert!(s
            .iter()
            .all(|c| c.fault_plan.drop_frac == 0.0 || c.algo == Algo::Gossip));
        // the axis reshapes the scenario key
        let mut keys: Vec<String> = s.iter().map(RunConfig::content_hash).collect();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), 3);
        // CLI axis parses
        let args = Args::parse(
            "sweep --drop-frac-list 0,0.02"
                .split_whitespace()
                .map(|t| t.to_string()),
            &[],
        )
        .unwrap();
        let g = Grid::from_args(RunConfig::default(), &args).unwrap();
        assert_eq!(g.len(), 2);
    }

    #[test]
    fn group_size_axis_skips_unrunnable_corners() {
        let mut base = RunConfig::default();
        base.ranks = 8;
        let g = Grid::new(base.clone())
            .algos(&[Algo::Gossip, Algo::GossipHypercube])
            .group_sizes(&[1, 2, 3])
            .inter_periods(&[1, 4]);
        let s = g.scenarios();
        // gossip: (1,1), (2,1), (2,4) — the (1,4) crossing is inert and
        // 3 doesn't divide 8; hypercube: flat row only
        assert_eq!(s.len(), 4);
        assert!(s.iter().all(|c| c.ranks % c.group_size == 0));
        assert!(s.iter().all(|c| c.group_size == 1 || c.algo == Algo::Gossip));
        assert!(s.iter().all(|c| c.group_size > 1 || c.inter_period == 1));
        // the axes reshape the scenario key
        let mut keys: Vec<String> = s.iter().map(RunConfig::content_hash).collect();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), 4);
        // CLI axes parse
        let args = Args::parse(
            "sweep --group-size-list 1,2 --inter-period-list 1,4"
                .split_whitespace()
                .map(|t| t.to_string()),
            &[],
        )
        .unwrap();
        let g = Grid::from_args(base, &args).unwrap();
        assert_eq!(g.len(), 3, "(1,1), (2,1), (2,4)");
    }

    #[test]
    fn hier_frontier_preset_has_the_three_gate_rows() {
        let g = Grid::preset("hier-frontier-1024").unwrap();
        assert_eq!(g.base.ranks, 1024);
        assert!(g.base.virtual_clock && g.base.layerwise);
        assert_eq!(g.base.cost_model, CostModelKind::Hier);
        let s = g.scenarios();
        let rows: Vec<(usize, usize)> =
            s.iter().map(|c| (c.group_size, c.inter_period)).collect();
        assert_eq!(rows, vec![(1, 1), (8, 1), (8, 4)]);
        // every row passes trainer validation (divisibility, algo, transport)
        for c in &s {
            assert_eq!(c.ranks % c.group_size, 0);
            assert_eq!(c.algo, Algo::Gossip);
        }
    }

    #[test]
    fn codec_frontier_preset_covers_every_codec() {
        let g = Grid::preset("codec-frontier-64").unwrap();
        assert_eq!(g.base.ranks, 64);
        assert_eq!(g.len(), 12, "4 codecs × 3 periods");
        assert!(g.base.virtual_clock && g.base.layerwise);
        assert!(g.base.eval_every > 0, "frontier rows carry accuracy");
        let s = g.scenarios();
        for codec in [Codec::F32, Codec::Bf16, Codec::Int8, Codec::TopK] {
            assert!(s.iter().any(|c| c.codec == codec), "{codec:?} missing");
        }
    }
}
