//! Scenario reports: the serializable outcome of one grid point.
//!
//! A [`ScenarioReport`] pairs the exact [`RunConfig`] that produced it
//! (so an artifact is self-describing and re-runnable) with per-rank
//! [`RankSummary`] digests and the run-level aggregates the paper's
//! tables plot — efficiency, overlap, consensus disagreement, in-flight
//! leak count.  Reports round-trip losslessly through `util::json`:
//! parsing a cached report and re-serializing it is byte-identical,
//! which is what lets the engine's disk cache return artifacts that
//! diff clean against a fresh run.
//!
//! Deliberately absent: wall-clock time and full parameter vectors.
//! Wall time is nondeterministic (it would break the byte-identical
//! sweep guarantee); model bits are summarized by `param_hash`, an
//! FNV-1a checksum strong enough for the benches' "same numerics"
//! assertions.

use crate::config::RunConfig;
use crate::coordinator::RunResult;
use crate::metrics::RankSummary;
use crate::util::json::{self, arr, num, obj, Json};

/// Outcome of one scenario (one grid point), keyed by the config's
/// content hash.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioReport {
    /// `config.content_hash()` — the cache / artifact key.
    pub key: String,
    pub config: RunConfig,
    /// Per-rank metric digests, rank order.
    pub ranks: Vec<RankSummary>,
    pub mean_step_secs: f64,
    pub mean_efficiency_pct: f64,
    pub mean_overlap_frac: f64,
    /// Max pairwise L∞ distance between rank models (consensus).
    pub max_disagreement: f64,
    /// FNV-1a checksum of every rank's final model bits (16 hex chars).
    pub param_hash: String,
    /// Messages still queued on the fabric after the run — must be 0.
    pub in_flight_msgs: usize,
    /// Encoded payload bytes still queued on the fabric after the run —
    /// the byte half of the drain invariant, also must be 0.
    pub in_flight_bytes: usize,
    /// rank-0 final validation accuracy, when eval was enabled.
    pub final_accuracy: Option<f64>,
}

impl ScenarioReport {
    pub fn from_run(cfg: &RunConfig, res: &RunResult) -> ScenarioReport {
        ScenarioReport {
            key: cfg.content_hash(),
            config: cfg.clone(),
            ranks: res.per_rank.iter().map(RankSummary::from_metrics).collect(),
            mean_step_secs: res.mean_step_secs(),
            mean_efficiency_pct: res.mean_efficiency_pct(),
            mean_overlap_frac: res.mean_overlap_frac(),
            max_disagreement: res.max_disagreement() as f64,
            param_hash: format!("{:016x}", res.param_hash()),
            in_flight_msgs: res.in_flight_msgs,
            in_flight_bytes: res.in_flight_bytes,
            final_accuracy: res.final_accuracy,
        }
    }

    /// Scenario throughput in steps (batch updates) per simulated
    /// second — the autotuner's objective.
    pub fn steps_per_sec(&self) -> f64 {
        if self.mean_step_secs > 0.0 {
            1.0 / self.mean_step_secs
        } else {
            0.0
        }
    }

    /// Messages per rank per step, the sweep table's traffic column.
    pub fn msgs_per_rank_step(&self) -> f64 {
        let total: u64 = self.ranks.iter().map(|r| r.msgs_sent).sum();
        let denom = (self.config.ranks * self.config.steps) as f64;
        if denom > 0.0 {
            total as f64 / denom
        } else {
            0.0
        }
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("key", json::s(&self.key)),
            ("config", self.config.to_json()),
            (
                "ranks",
                arr(self.ranks.iter().map(RankSummary::to_json).collect()),
            ),
            ("mean_step_secs", num(self.mean_step_secs)),
            ("mean_efficiency_pct", num(self.mean_efficiency_pct)),
            ("mean_overlap_frac", num(self.mean_overlap_frac)),
            ("max_disagreement", num(self.max_disagreement)),
            ("param_hash", json::s(&self.param_hash)),
            ("in_flight_msgs", num(self.in_flight_msgs as f64)),
            ("in_flight_bytes", num(self.in_flight_bytes as f64)),
            (
                "final_accuracy",
                self.final_accuracy.map(num).unwrap_or(Json::Null),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ScenarioReport, String> {
        let key = j
            .get("key")
            .and_then(Json::as_str)
            .ok_or("report: missing key")?
            .to_string();
        let config =
            RunConfig::from_json(j.get("config").ok_or("report: missing config")?)?;
        let ranks = j
            .get("ranks")
            .and_then(Json::as_arr)
            .ok_or("report: missing ranks")?
            .iter()
            .map(RankSummary::from_json)
            .collect::<Result<Vec<_>, _>>()?;
        let f = |k: &str| {
            j.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("report: missing {k}"))
        };
        Ok(ScenarioReport {
            key,
            config,
            ranks,
            mean_step_secs: f("mean_step_secs")?,
            mean_efficiency_pct: f("mean_efficiency_pct")?,
            mean_overlap_frac: f("mean_overlap_frac")?,
            max_disagreement: f("max_disagreement")?,
            param_hash: j
                .get("param_hash")
                .and_then(Json::as_str)
                .ok_or("report: missing param_hash")?
                .to_string(),
            in_flight_msgs: f("in_flight_msgs")? as usize,
            in_flight_bytes: f("in_flight_bytes")? as usize,
            final_accuracy: j.get("final_accuracy").and_then(Json::as_f64),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::RunMetrics;

    fn sample_report() -> ScenarioReport {
        let mut cfg = RunConfig::default();
        cfg.model = "mlp-small".into();
        cfg.ranks = 2;
        cfg.steps = 3;
        cfg.use_artifacts = false;
        let mut m0 = RunMetrics::new(0);
        m0.step_secs = vec![0.01, 0.02, 0.03];
        m0.comm_wait_secs = vec![0.001, 0.001, 0.001];
        m0.loss = vec![(0, 2.0), (2, 1.0)];
        m0.msgs_sent = 6;
        let mut m1 = RunMetrics::new(1);
        m1.step_secs = vec![0.015, 0.02, 0.025];
        m1.recv_wait_secs = 0.004;
        m1.comm_hidden_secs = 0.012;
        let res = RunResult {
            per_rank: vec![m0, m1],
            final_params: vec![vec![1.0, 2.5], vec![1.5, 2.0]],
            final_accuracy: Some(0.5),
            wall_secs: 123.0, // must NOT appear in the report
            in_flight_msgs: 0,
            in_flight_bytes: 0,
            pool_stats: Default::default(),
        };
        ScenarioReport::from_run(&cfg, &res)
    }

    #[test]
    fn report_roundtrips_byte_identically() {
        let r = sample_report();
        assert_eq!(r.key, r.config.content_hash());
        assert_eq!(r.param_hash.len(), 16);
        assert!((r.max_disagreement - 0.5).abs() < 1e-12);
        let j = r.to_json();
        let text = j.to_string();
        assert!(
            !text.contains("wall"),
            "wall time is nondeterministic and must stay out of artifacts"
        );
        let back = ScenarioReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, r);
        assert_eq!(back.to_json().to_string(), text);
    }

    #[test]
    fn throughput_and_traffic_helpers() {
        let r = sample_report();
        assert!((r.steps_per_sec() - 1.0 / r.mean_step_secs).abs() < 1e-9);
        // 6 msgs over 2 ranks × 3 steps
        assert!((r.msgs_per_rank_step() - 1.0).abs() < 1e-12);
    }
}
