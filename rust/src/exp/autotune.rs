//! Gossip-period autotuning (the Fig 17 trade-off, mechanized).
//!
//! Raising `gossip_period` amortizes exchange cost over more steps —
//! throughput rises toward the no-comm ceiling — but mixing becomes
//! rarer, so cross-rank consensus (max pairwise L∞ disagreement,
//! Corollary 6.3) decays toward the no-mixing drift of independent
//! SGD.  The autotuner walks a period grid on the engine and picks
//! **the largest period within `throughput_slack` (default 2%) of peak
//! throughput whose consensus still shrinks** — "still shrinks"
//! measured against an explicit no-mixing reference run (same config,
//! `gossip_period > steps`, so no exchange ever fires): a period
//! qualifies only if its final disagreement stays below
//! `consensus_frac` (default ½) of the reference drift.

use super::{Engine, Grid, ScenarioReport, Sweep};
use crate::config::{Algo, RunConfig};

use anyhow::{ensure, Result};

/// One period's measurements + verdicts.
#[derive(Clone, Debug)]
pub struct Candidate {
    pub period: usize,
    pub steps_per_sec: f64,
    pub disagreement: f64,
    /// Within `throughput_slack` of the grid's peak throughput.
    pub fast_enough: bool,
    /// Disagreement below `consensus_frac ×` the no-mixing drift.
    pub consensus_shrinks: bool,
}

/// Autotune outcome: the chosen period plus everything needed to audit
/// the choice.
#[derive(Clone, Debug)]
pub struct AutotuneReport {
    /// Largest period that is both fast enough and still mixing;
    /// `None` when no candidate passes both gates (pathological grids —
    /// e.g. every period's consensus already matches no-mixing drift).
    pub chosen_period: Option<usize>,
    pub peak_steps_per_sec: f64,
    /// Final disagreement of the no-mixing reference run.
    pub no_mix_disagreement: f64,
    pub candidates: Vec<Candidate>,
    /// The full scenario reports (periods in grid order, then the
    /// no-mixing reference last) for artifact emission.
    pub reports: Vec<ScenarioReport>,
}

/// Gate parameters; [`Default`] gives the paper-motivated 2% / ½.
#[derive(Clone, Copy, Debug)]
pub struct AutotuneParams {
    /// Throughput may trail the peak by at most this fraction.
    pub throughput_slack: f64,
    /// Disagreement must stay below this fraction of no-mixing drift.
    pub consensus_frac: f64,
}

impl Default for AutotuneParams {
    fn default() -> AutotuneParams {
        AutotuneParams {
            throughput_slack: 0.02,
            consensus_frac: 0.5,
        }
    }
}

/// Run the period grid + no-mixing reference on `engine` and pick the
/// period per the rule above.  `base` must be a gossip-family config
/// (the knob being tuned is gossip's); every other field is honored
/// as-is, so the caller controls scale, fabric and pipeline mode.
pub fn autotune_gossip_period(
    engine: &Engine,
    base: &RunConfig,
    periods: &[usize],
    params: AutotuneParams,
) -> Result<AutotuneReport> {
    ensure!(
        matches!(
            base.algo,
            Algo::Gossip | Algo::GossipHypercube | Algo::GossipRandom
        ),
        "gossip-period autotuning needs a gossip-family algo, got {}",
        base.algo.name()
    );
    ensure!(!periods.is_empty(), "need at least one candidate period");
    ensure!(
        periods.iter().all(|&p| (1..=base.steps).contains(&p)),
        "candidate periods must be in 1..=steps ({}) — larger ones never mix",
        base.steps
    );
    // the period grid, plus the no-mixing reference as a final scenario
    // (gossip_period > steps ⇒ the exchange never fires)
    let mut scenarios = Grid::new(base.clone()).gossip_periods(periods).scenarios();
    let mut no_mix = base.clone();
    no_mix.gossip_period = base.steps + 1;
    scenarios.push(no_mix);
    let Sweep { reports, .. } = engine.run_scenarios(&scenarios)?;
    let (no_mix_report, period_reports) =
        reports.split_last().expect("grid is non-empty");
    let no_mix_disagreement = no_mix_report.max_disagreement;

    let peak = period_reports
        .iter()
        .map(ScenarioReport::steps_per_sec)
        .fold(0.0f64, f64::max);
    let candidates: Vec<Candidate> = period_reports
        .iter()
        .map(|r| {
            let tput = r.steps_per_sec();
            Candidate {
                period: r.config.gossip_period,
                steps_per_sec: tput,
                disagreement: r.max_disagreement,
                fast_enough: tput >= peak * (1.0 - params.throughput_slack),
                consensus_shrinks: r.max_disagreement
                    < params.consensus_frac * no_mix_disagreement,
            }
        })
        .collect();
    let chosen_period = candidates
        .iter()
        .filter(|c| c.fast_enough && c.consensus_shrinks)
        .map(|c| c.period)
        .max();
    Ok(AutotuneReport {
        chosen_period,
        peak_steps_per_sec: peak,
        no_mix_disagreement,
        candidates,
        reports,
    })
}
