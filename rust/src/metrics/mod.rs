//! Experiment metrics: per-step records, loss/accuracy curves, CSV and
//! JSON emission for the figures in EXPERIMENTS.md.

use crate::util::json::{arr, num, obj, Json};
use std::io::Write;
use std::path::Path;

/// One rank's record of a training run.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub rank: usize,
    /// (step, training loss) samples.
    pub loss: Vec<(usize, f64)>,
    /// (step, validation accuracy) samples.
    pub accuracy: Vec<(usize, f64)>,
    /// Wall-clock seconds per step.
    pub step_secs: Vec<f64>,
    /// Seconds spent blocked on communication (exposed comm).
    pub comm_wait_secs: Vec<f64>,
    pub msgs_sent: u64,
    pub bytes_sent: u64,
    /// Total exposed receive wait over the whole run, snapshotted from
    /// the transport's `Counters::recv_wait_ns` (wall-blocked seconds,
    /// or deterministic simulated seconds in virtual-clock mode).
    /// Unlike `comm_wait_secs` this also covers waits outside the
    /// explicitly-marked drain sections (e.g. sample-shuffle refills).
    pub recv_wait_secs: f64,
    /// Wire time of received messages that elapsed *under* this rank's
    /// compute instead of being exposed as blocking wait, snapshotted
    /// from `Counters::comm_hidden_ns`.  `recv_wait_secs +
    /// comm_hidden_secs` is the rank's total received wire time; the
    /// hidden share is the overlap the layer-wise pipeline wins.
    pub comm_hidden_secs: f64,
    /// Step at which this rank died under the run's fault plan (the
    /// rank stopped training at the *start* of this step).  `None` for
    /// survivors and fault-free runs.
    pub death_step: Option<usize>,
    /// Step at which this rank bootstrap-joined a running communicator
    /// (`None` for founding ranks).
    pub joined_step: Option<usize>,
    /// FNV-1a hash of the parameter vector at the join handoff: the
    /// donor records its hash when it ships the snapshot, the joiner
    /// records the hash of what it decoded.  Matching values prove a
    /// lossless bootstrap (tests/failure_injection.rs).
    pub join_hash: Option<u64>,
}

impl RunMetrics {
    pub fn new(rank: usize) -> Self {
        RunMetrics {
            rank,
            ..Default::default()
        }
    }

    pub fn mean_step_secs(&self) -> f64 {
        crate::util::mean(&self.step_secs)
    }

    pub fn mean_comm_wait(&self) -> f64 {
        crate::util::mean(&self.comm_wait_secs)
    }

    /// Compute efficiency as the paper defines it: fraction of step time
    /// not blocked on communication.
    pub fn efficiency_pct(&self) -> f64 {
        let step = self.mean_step_secs();
        if step <= 0.0 {
            return 100.0;
        }
        100.0 * (1.0 - self.mean_comm_wait() / step).clamp(0.0, 1.0)
    }

    pub fn final_loss(&self) -> Option<f64> {
        self.loss.last().map(|&(_, l)| l)
    }

    /// Fraction of this rank's received wire time it never paid for as
    /// blocking wait (§5.1 overlap): `hidden / (hidden + exposed)`.
    /// "Hidden" wire time elapsed under compute *or* under a wait on
    /// another message (concurrent waits cost the rank only once);
    /// `recv_wait_secs` is exactly the blocking time paid.  1.0 when the
    /// rank received no timed communication at all — nothing was
    /// exposed.  Collective-internal messages are in the ledger too
    /// (settled when the collective is harvested), so this metric is
    /// meaningful for AGD: under `--comm-thread` the chain rounds that
    /// advanced beneath later backprop slices show up as hidden wire
    /// time instead of vanishing.
    pub fn overlap_frac(&self) -> f64 {
        let total = self.comm_hidden_secs + self.recv_wait_secs;
        if total <= 0.0 {
            return 1.0;
        }
        self.comm_hidden_secs / total
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("rank", num(self.rank as f64)),
            (
                "loss",
                arr(self
                    .loss
                    .iter()
                    .map(|&(st, l)| arr(vec![num(st as f64), num(l)]))
                    .collect()),
            ),
            (
                "accuracy",
                arr(self
                    .accuracy
                    .iter()
                    .map(|&(st, a)| arr(vec![num(st as f64), num(a)]))
                    .collect()),
            ),
            ("mean_step_secs", num(self.mean_step_secs())),
            ("mean_comm_wait_secs", num(self.mean_comm_wait())),
            ("recv_wait_secs", num(self.recv_wait_secs)),
            ("comm_hidden_secs", num(self.comm_hidden_secs)),
            ("overlap_frac", num(self.overlap_frac())),
            ("efficiency_pct", num(self.efficiency_pct())),
            ("msgs_sent", num(self.msgs_sent as f64)),
            ("bytes_sent", num(self.bytes_sent as f64)),
        ];
        // Fault-plan fields only appear on runs that used them, so
        // fault-free artifacts stay byte-identical to older versions
        // (`obj` sorts keys, so push order is irrelevant).
        if let Some(d) = self.death_step {
            fields.push(("death_step", num(d as f64)));
        }
        if let Some(js) = self.joined_step {
            fields.push(("joined_step", num(js as f64)));
        }
        if let Some(h) = self.join_hash {
            fields.push(("join_hash", crate::util::json::s(&h.to_string())));
        }
        obj(fields)
    }
}

/// Compact, serializable digest of one rank's [`RunMetrics`] — the
/// per-rank payload of an experiment-engine `ScenarioReport`
/// (`crate::exp::ScenarioReport`).  Unlike [`RunMetrics::to_json`] it
/// round-trips: [`from_json`](Self::from_json) restores exactly what
/// [`to_json`](Self::to_json) emitted (derived means are stored, not
/// recomputed, so a cached report re-serializes byte-identically).
#[derive(Clone, Debug, PartialEq)]
pub struct RankSummary {
    pub rank: usize,
    pub mean_step_secs: f64,
    pub mean_comm_wait_secs: f64,
    pub recv_wait_secs: f64,
    pub comm_hidden_secs: f64,
    pub overlap_frac: f64,
    pub efficiency_pct: f64,
    pub msgs_sent: u64,
    pub bytes_sent: u64,
    pub final_loss: Option<f64>,
    /// See [`RunMetrics::death_step`] / [`RunMetrics::joined_step`] /
    /// [`RunMetrics::join_hash`].  All three are omitted from the JSON
    /// when `None` so fault-free reports keep their historical shape.
    pub death_step: Option<usize>,
    pub joined_step: Option<usize>,
    pub join_hash: Option<u64>,
}

impl RankSummary {
    pub fn from_metrics(m: &RunMetrics) -> RankSummary {
        RankSummary {
            rank: m.rank,
            mean_step_secs: m.mean_step_secs(),
            mean_comm_wait_secs: m.mean_comm_wait(),
            recv_wait_secs: m.recv_wait_secs,
            comm_hidden_secs: m.comm_hidden_secs,
            overlap_frac: m.overlap_frac(),
            efficiency_pct: m.efficiency_pct(),
            msgs_sent: m.msgs_sent,
            bytes_sent: m.bytes_sent,
            final_loss: m.final_loss(),
            death_step: m.death_step,
            joined_step: m.joined_step,
            join_hash: m.join_hash,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("rank", num(self.rank as f64)),
            ("mean_step_secs", num(self.mean_step_secs)),
            ("mean_comm_wait_secs", num(self.mean_comm_wait_secs)),
            ("recv_wait_secs", num(self.recv_wait_secs)),
            ("comm_hidden_secs", num(self.comm_hidden_secs)),
            ("overlap_frac", num(self.overlap_frac)),
            ("efficiency_pct", num(self.efficiency_pct)),
            ("msgs_sent", num(self.msgs_sent as f64)),
            ("bytes_sent", num(self.bytes_sent as f64)),
            (
                "final_loss",
                self.final_loss.map(num).unwrap_or(Json::Null),
            ),
        ];
        if let Some(d) = self.death_step {
            fields.push(("death_step", num(d as f64)));
        }
        if let Some(js) = self.joined_step {
            fields.push(("joined_step", num(js as f64)));
        }
        if let Some(h) = self.join_hash {
            // stringified: f64 can't hold all u64 hashes losslessly
            fields.push(("join_hash", crate::util::json::s(&h.to_string())));
        }
        obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<RankSummary, String> {
        let f = |k: &str| {
            j.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("rank summary: missing {k}"))
        };
        Ok(RankSummary {
            rank: f("rank")? as usize,
            mean_step_secs: f("mean_step_secs")?,
            mean_comm_wait_secs: f("mean_comm_wait_secs")?,
            recv_wait_secs: f("recv_wait_secs")?,
            comm_hidden_secs: f("comm_hidden_secs")?,
            overlap_frac: f("overlap_frac")?,
            efficiency_pct: f("efficiency_pct")?,
            msgs_sent: f("msgs_sent")? as u64,
            bytes_sent: f("bytes_sent")? as u64,
            final_loss: j.get("final_loss").and_then(Json::as_f64),
            death_step: j.get("death_step").and_then(Json::as_usize),
            joined_step: j.get("joined_step").and_then(Json::as_usize),
            join_hash: j
                .get("join_hash")
                .and_then(Json::as_str)
                .and_then(|s| s.parse::<u64>().ok()),
        })
    }
}

/// Aggregate across ranks for a run summary line.
pub fn summarize(runs: &[RunMetrics]) -> Json {
    let losses: Vec<f64> = runs.iter().filter_map(|r| r.final_loss()).collect();
    let eff: Vec<f64> = runs.iter().map(|r| r.efficiency_pct()).collect();
    let steps: Vec<f64> = runs.iter().map(|r| r.mean_step_secs()).collect();
    let overlap: Vec<f64> = runs.iter().map(|r| r.overlap_frac()).collect();
    obj(vec![
        ("ranks", num(runs.len() as f64)),
        ("mean_final_loss", num(crate::util::mean(&losses))),
        ("mean_efficiency_pct", num(crate::util::mean(&eff))),
        ("mean_step_secs", num(crate::util::mean(&steps))),
        ("mean_overlap_frac", num(crate::util::mean(&overlap))),
        (
            "total_msgs",
            num(runs.iter().map(|r| r.msgs_sent).sum::<u64>() as f64),
        ),
    ])
}

/// Write (step, value) series as CSV.  Column 0 is the x value.
pub fn write_csv(
    path: &Path,
    header: &[&str],
    rows: &[Vec<f64>],
) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{}", header.join(","))?;
    for r in rows {
        let cells: Vec<String> = r.iter().map(|v| format!("{v}")).collect();
        writeln!(f, "{}", cells.join(","))?;
    }
    Ok(())
}

/// Render a (x, y) series as a coarse ASCII sparkline for run logs.
pub fn sparkline(ys: &[f64], width: usize) -> String {
    if ys.is_empty() {
        return String::new();
    }
    const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let lo = ys.iter().cloned().fold(f64::MAX, f64::min);
    let hi = ys.iter().cloned().fold(f64::MIN, f64::max);
    let span = (hi - lo).max(1e-12);
    let step = (ys.len() as f64 / width as f64).max(1.0);
    let mut out = String::new();
    let mut i = 0.0;
    while (i as usize) < ys.len() && out.chars().count() < width {
        let v = ys[i as usize];
        let g = (((v - lo) / span) * 7.0).round() as usize;
        out.push(GLYPHS[g.min(7)]);
        i += step;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn efficiency_computation() {
        let mut m = RunMetrics::new(0);
        m.step_secs = vec![0.1, 0.1];
        m.comm_wait_secs = vec![0.01, 0.01];
        assert!((m.efficiency_pct() - 90.0).abs() < 1e-9);
    }

    #[test]
    fn efficiency_empty_is_100() {
        assert_eq!(RunMetrics::new(0).efficiency_pct(), 100.0);
    }

    #[test]
    fn overlap_frac_splits_hidden_vs_exposed() {
        let mut m = RunMetrics::new(0);
        assert_eq!(m.overlap_frac(), 1.0, "no comm ⇒ vacuously all hidden");
        m.comm_hidden_secs = 0.03;
        m.recv_wait_secs = 0.01;
        assert!((m.overlap_frac() - 0.75).abs() < 1e-12);
        let j = m.to_json();
        assert_eq!(j.get("overlap_frac").and_then(|v| v.as_f64()), Some(0.75));
        assert_eq!(
            j.get("comm_hidden_secs").and_then(|v| v.as_f64()),
            Some(0.03)
        );
    }

    #[test]
    fn json_roundtrip() {
        let mut m = RunMetrics::new(2);
        m.loss = vec![(0, 2.3), (10, 1.1)];
        m.accuracy = vec![(10, 0.55)];
        m.step_secs = vec![0.01];
        m.recv_wait_secs = 0.25;
        let j = m.to_json();
        assert_eq!(
            j.get("recv_wait_secs").and_then(|v| v.as_f64()),
            Some(0.25),
            "per-rank exposed wait must be surfaced"
        );
        let parsed =
            crate::util::json::Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("rank").unwrap().as_usize(), Some(2));
        assert_eq!(
            parsed.get("loss").unwrap().idx(1).unwrap().idx(1).unwrap().as_f64(),
            Some(1.1)
        );
    }

    #[test]
    fn rank_summary_roundtrips() {
        let mut m = RunMetrics::new(3);
        m.loss = vec![(0, 2.3), (9, 0.7)];
        m.step_secs = vec![0.01, 0.03];
        m.comm_wait_secs = vec![0.001, 0.002];
        m.recv_wait_secs = 0.004;
        m.comm_hidden_secs = 0.012;
        m.msgs_sent = 42;
        m.bytes_sent = 4096;
        let s = RankSummary::from_metrics(&m);
        assert_eq!(s.rank, 3);
        assert_eq!(s.final_loss, Some(0.7));
        assert!((s.overlap_frac - 0.75).abs() < 1e-12);
        let j = s.to_json();
        let back = RankSummary::from_json(&j).unwrap();
        assert_eq!(back, s);
        // text round-trip re-serializes byte-identically (caching needs this)
        let reparsed =
            Json::parse(&j.to_string()).expect("valid summary json");
        assert_eq!(
            RankSummary::from_json(&reparsed).unwrap().to_json().to_string(),
            j.to_string()
        );
        // absent final_loss survives as None
        let mut empty = RunMetrics::new(0);
        empty.step_secs = vec![0.01];
        let s2 = RankSummary::from_metrics(&empty);
        assert_eq!(s2.final_loss, None);
        let back2 = RankSummary::from_json(&s2.to_json()).unwrap();
        assert_eq!(back2, s2);
        // fault-free summaries never emit the fault-plan keys …
        assert!(s2.to_json().get("death_step").is_none());
        assert!(s2.to_json().get("join_hash").is_none());
        // … and fault-run fields round-trip losslessly (join_hash is a
        // full-width u64, beyond f64's 53-bit mantissa).
        let mut f = RunMetrics::new(1);
        f.step_secs = vec![0.01];
        f.death_step = Some(10);
        f.joined_step = Some(4);
        f.join_hash = Some(u64::MAX - 1);
        let s3 = RankSummary::from_metrics(&f);
        let j3 = s3.to_json();
        assert_eq!(
            j3.get("join_hash").and_then(Json::as_str),
            Some("18446744073709551614")
        );
        let back3 = RankSummary::from_json(&j3).unwrap();
        assert_eq!(back3, s3);
        assert_eq!(back3.to_json().to_string(), j3.to_string());
    }

    #[test]
    fn csv_writes(){
        let dir = std::env::temp_dir().join("gg_metrics_test");
        let p = dir.join("x.csv");
        write_csv(&p, &["step", "loss"], &[vec![0.0, 2.3], vec![1.0, 1.9]])
            .unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.starts_with("step,loss\n0,2.3\n"));
    }

    #[test]
    fn sparkline_monotone() {
        let ys: Vec<f64> = (0..64).map(|i| i as f64).collect();
        let sl = sparkline(&ys, 16);
        assert_eq!(sl.chars().count(), 16);
        assert!(sl.starts_with('▁'));
        assert!(sl.ends_with('█'));
    }

    #[test]
    fn summarize_aggregates() {
        let mut a = RunMetrics::new(0);
        a.loss = vec![(0, 2.0)];
        a.step_secs = vec![0.2];
        a.msgs_sent = 5;
        let mut b = RunMetrics::new(1);
        b.loss = vec![(0, 4.0)];
        b.step_secs = vec![0.4];
        b.msgs_sent = 7;
        let j = summarize(&[a, b]);
        assert_eq!(j.get("mean_final_loss").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.get("total_msgs").unwrap().as_f64(), Some(12.0));
    }
}
