//! Synthetic dataset generators.
//!
//! `blob_classification` builds a c-class Gaussian-mixture task: each
//! class has `modes` prototype vectors; a sample is prototype + σ·noise.
//! With σ below the prototype separation the task is cleanly learnable,
//! so validation-accuracy curves (paper Figs 12/13) behave like the real
//! datasets': rapid rise then saturation — while generation stays fast
//! and deterministic.
//!
//! `token_corpus` emits a first-order Markov chain over the vocabulary
//! with a sparse, seeded transition structure: the LM's achievable loss
//! is the chain's conditional entropy, so loss curves have a meaningful
//! floor (EXPERIMENTS.md records it per seed).

use crate::util::Rng;

/// A dense in-memory dataset (row-major features + integer labels).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub dim: usize,
    pub rows: usize,
    pub classes: usize,
}

impl Dataset {
    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.dim..(i + 1) * self.dim]
    }
}

/// Gaussian-blob classification dataset.
///
/// `seed` controls BOTH the class prototypes and the sample noise;
/// use [`blob_split`] to draw train/validation sets from the *same*
/// prototypes with independent noise.
pub fn blob_classification(
    rows: usize,
    dim: usize,
    classes: usize,
    modes: usize,
    sigma: f32,
    seed: u64,
) -> Dataset {
    blob_split(rows, dim, classes, modes, sigma, seed, 0)
}

/// Like [`blob_classification`] but with an explicit sample stream, so
/// train (stream 0) and validation (stream 1) share the task definition.
pub fn blob_split(
    rows: usize,
    dim: usize,
    classes: usize,
    modes: usize,
    sigma: f32,
    seed: u64,
    sample_stream: u64,
) -> Dataset {
    let mut rng = Rng::new(seed);
    // class prototypes: unit-ish vectors with disjoint-ish support
    let mut protos = vec![0.0f32; classes * modes * dim];
    for p in protos.iter_mut() {
        *p = rng.normal_f32() * 0.9;
    }
    let mut x = vec![0.0f32; rows * dim];
    let mut y = vec![0i32; rows];
    let mut srng = rng.split(1 + sample_stream);
    for r in 0..rows {
        let c = srng.below(classes);
        let m = srng.below(modes);
        y[r] = c as i32;
        let proto = &protos[(c * modes + m) * dim..(c * modes + m + 1) * dim];
        let dst = &mut x[r * dim..(r + 1) * dim];
        for (d, p) in dst.iter_mut().zip(proto) {
            *d = p + sigma * srng.normal_f32();
        }
    }
    Dataset {
        x,
        y,
        dim,
        rows,
        classes,
    }
}

/// MNIST-analog: 784-dim, 10 classes (paper §7.2, LeNet3).
/// `stream` 0 = train, 1 = validation (same prototypes, fresh noise).
pub fn mnist_analog_split(rows: usize, seed: u64, stream: u64) -> Dataset {
    blob_split(rows, 784, 10, 3, 0.35, seed, stream)
}

pub fn mnist_analog(rows: usize, seed: u64) -> Dataset {
    mnist_analog_split(rows, seed, 0)
}

/// CIFAR-analog: 3072-dim, 10 classes (paper §7.2, CIFARNet).
pub fn cifar_analog_split(rows: usize, seed: u64, stream: u64) -> Dataset {
    blob_split(rows, 3072, 10, 4, 0.45, seed, stream)
}

pub fn cifar_analog(rows: usize, seed: u64) -> Dataset {
    cifar_analog_split(rows, seed, 0)
}

/// Markov token corpus for the transformer LM.  Returns flat token ids;
/// the shard layer cuts it into (seq+1)-length windows (input/target).
pub fn token_corpus(tokens: usize, vocab: usize, branching: usize, seed: u64) -> Vec<i32> {
    token_corpus_split(tokens, vocab, branching, seed, 0)
}

/// Like [`token_corpus`] with an explicit walk stream: train (0) and
/// validation (1) share the transition table but walk independently.
pub fn token_corpus_split(
    tokens: usize,
    vocab: usize,
    branching: usize,
    seed: u64,
    stream: u64,
) -> Vec<i32> {
    let mut rng = Rng::new(seed);
    // sparse transition table: each symbol can be followed by `branching`
    // successors with geometric-ish weights
    let succ: Vec<Vec<usize>> = (0..vocab)
        .map(|_| (0..branching).map(|_| rng.below(vocab)).collect())
        .collect();
    let mut out = Vec::with_capacity(tokens);
    let mut srng = rng.split(2 + stream);
    let mut cur = srng.below(vocab);
    for _ in 0..tokens {
        out.push(cur as i32);
        // pick successor: heavily skewed so the chain is predictable
        let r = srng.f64();
        let idx = if r < 0.6 {
            0
        } else if r < 0.85 {
            1 % branching
        } else {
            srng.below(branching)
        };
        cur = succ[cur][idx];
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_shaped() {
        let a = mnist_analog(100, 7);
        let b = mnist_analog(100, 7);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        assert_eq!(a.dim, 784);
        assert_eq!(a.rows, 100);
        let c = mnist_analog(100, 8);
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn labels_in_range_all_classes_present() {
        let d = blob_classification(2000, 16, 10, 2, 0.3, 3);
        let mut seen = [false; 10];
        for &y in &d.y {
            assert!((0..10).contains(&(y as usize)));
            seen[y as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn blobs_are_linearly_separable_ish() {
        // nearest-prototype classification on held-out samples should
        // beat chance by a wide margin — i.e. the task is learnable
        let d = blob_classification(500, 32, 4, 1, 0.2, 11);
        // estimate class means from first half, test on second half
        let mut means = vec![0.0f64; 4 * 32];
        let mut counts = [0usize; 4];
        for i in 0..250 {
            let c = d.y[i] as usize;
            counts[c] += 1;
            for (j, &v) in d.row(i).iter().enumerate() {
                means[c * 32 + j] += v as f64;
            }
        }
        for c in 0..4 {
            for j in 0..32 {
                means[c * 32 + j] /= counts[c].max(1) as f64;
            }
        }
        let mut correct = 0;
        for i in 250..500 {
            let mut best = (f64::MAX, 0usize);
            for c in 0..4 {
                let dist: f64 = d
                    .row(i)
                    .iter()
                    .enumerate()
                    .map(|(j, &v)| {
                        let e = v as f64 - means[c * 32 + j];
                        e * e
                    })
                    .sum();
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            if best.1 == d.y[i] as usize {
                correct += 1;
            }
        }
        assert!(correct > 200, "only {correct}/250 correct");
    }

    #[test]
    fn corpus_tokens_in_vocab_and_predictable() {
        let toks = token_corpus(5000, 64, 4, 9);
        assert_eq!(toks.len(), 5000);
        assert!(toks.iter().all(|&t| (0..64).contains(&t)));
        // bigram predictability: most-frequent successor should cover
        // >40% of transitions (we skew 60% to the first successor)
        use std::collections::HashMap;
        let mut best: HashMap<i32, HashMap<i32, usize>> = HashMap::new();
        for w in toks.windows(2) {
            *best.entry(w[0]).or_default().entry(w[1]).or_default() += 1;
        }
        let (hit, tot): (usize, usize) = best
            .values()
            .map(|m| {
                let t: usize = m.values().sum();
                (*m.values().max().unwrap(), t)
            })
            .fold((0, 0), |(a, b), (h, t)| (a + h, b + t));
        assert!(
            hit as f64 / tot as f64 > 0.4,
            "predictability {}",
            hit as f64 / tot as f64
        );
    }
}
