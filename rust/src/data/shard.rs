//! Dataset sharding + batch iteration.
//!
//! Mirrors the paper's setup: the dataset is read once, partitioned
//! contiguously across ranks (the parallel-netCDF reader in the paper's
//! artifact), and each rank iterates batches locally.  The GossipGraD
//! ring *sample shuffle* (coordinator::shuffle) then migrates batches
//! between ranks during training.

use super::synthetic::Dataset;
use crate::util::Rng;

/// One rank's partition of a dataset (owning copies — ranks are threads
/// but we keep shards disjoint as real distributed memory would be).
#[derive(Clone, Debug)]
pub struct Shard {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub dim: usize,
    pub rows: usize,
}

impl Shard {
    /// Contiguous partition `rank` of `p` (remainder spread to the first
    /// ranks, like MPI_Scatterv).
    pub fn partition(d: &Dataset, rank: usize, p: usize) -> Shard {
        let base = d.rows / p;
        let extra = d.rows % p;
        let my_rows = base + usize::from(rank < extra);
        let start = rank * base + rank.min(extra);
        Shard {
            x: d.x[start * d.dim..(start + my_rows) * d.dim].to_vec(),
            y: d.y[start..start + my_rows].to_vec(),
            dim: d.dim,
            rows: my_rows,
        }
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.dim..(i + 1) * self.dim]
    }

    /// Replace this shard's contents (ring shuffle delivery).
    pub fn replace(&mut self, x: Vec<f32>, y: Vec<i32>) {
        assert_eq!(x.len(), y.len() * self.dim);
        self.rows = y.len();
        self.x = x;
        self.y = y;
    }
}

/// Epoch-wise batch iterator with in-shard permutation reshuffled each
/// epoch (the standard local shuffle every implementation does; the
/// *distributed* shuffle is layered on top by the coordinator).
pub struct BatchIter {
    order: Vec<usize>,
    cursor: usize,
    batch: usize,
    rng: Rng,
    pub epoch: usize,
}

impl BatchIter {
    pub fn new(rows: usize, batch: usize, seed: u64) -> BatchIter {
        assert!(batch > 0);
        let mut rng = Rng::new(seed);
        let mut order: Vec<usize> = (0..rows).collect();
        rng.shuffle(&mut order);
        BatchIter {
            order,
            cursor: 0,
            batch,
            rng,
            epoch: 0,
        }
    }

    /// Next batch of row indices; wraps (and reshuffles) at epoch end so
    /// every batch is full-sized (static shapes for the AOT executables).
    pub fn next_indices(&mut self, rows: usize) -> Vec<usize> {
        if self.order.len() != rows {
            // shard contents changed size (ring shuffle) — rebuild
            self.order = (0..rows).collect();
            self.rng.shuffle(&mut self.order);
            self.cursor = 0;
        }
        let mut out = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            if self.cursor >= self.order.len() {
                self.rng.shuffle(&mut self.order);
                self.cursor = 0;
                self.epoch += 1;
            }
            out.push(self.order[self.cursor]);
            self.cursor += 1;
        }
        out
    }

    /// Materialize a batch as (x, y) buffers from a shard.
    pub fn next_batch(&mut self, shard: &Shard) -> (Vec<f32>, Vec<i32>) {
        let idx = self.next_indices(shard.rows);
        let mut x = Vec::with_capacity(idx.len() * shard.dim);
        let mut y = Vec::with_capacity(idx.len());
        for &i in &idx {
            x.extend_from_slice(shard.row(i));
            y.push(shard.y[i]);
        }
        (x, y)
    }
}

/// Cut a token stream into (input, target) LM windows of length `seq`.
pub fn lm_windows(tokens: &[i32], seq: usize) -> (Vec<Vec<i32>>, Vec<Vec<i32>>) {
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    let mut i = 0;
    while i + seq + 1 <= tokens.len() {
        xs.push(tokens[i..i + seq].to_vec());
        ys.push(tokens[i + 1..i + seq + 1].to_vec());
        i += seq;
    }
    (xs, ys)
}

#[cfg(test)]
mod tests {
    use super::super::synthetic::mnist_analog;
    use super::*;

    #[test]
    fn partition_covers_dataset_disjointly() {
        let d = mnist_analog(103, 1);
        let p = 4;
        let shards: Vec<_> = (0..p).map(|r| Shard::partition(&d, r, p)).collect();
        let total: usize = shards.iter().map(|s| s.rows).sum();
        assert_eq!(total, 103);
        // sizes differ by at most 1
        let min = shards.iter().map(|s| s.rows).min().unwrap();
        let max = shards.iter().map(|s| s.rows).max().unwrap();
        assert!(max - min <= 1);
        // concatenation reproduces the dataset
        let mut y = Vec::new();
        for s in &shards {
            y.extend_from_slice(&s.y);
        }
        assert_eq!(y, d.y);
    }

    #[test]
    fn batches_are_full_and_cover_epoch() {
        let d = mnist_analog(50, 2);
        let s = Shard::partition(&d, 0, 1);
        let mut it = BatchIter::new(s.rows, 16, 3);
        let mut seen = vec![0usize; 50];
        for _ in 0..3 {
            for &i in &it.next_indices(s.rows) {
                seen[i] += 1;
            }
        }
        // 48 of 50 seen exactly once in the first epoch-ish pass
        assert!(seen.iter().filter(|&&c| c >= 1).count() >= 48);
        assert_eq!(it.epoch, 0);
        it.next_indices(s.rows);
        assert_eq!(it.epoch, 1);
    }

    #[test]
    fn batch_materializes_rows() {
        let d = mnist_analog(20, 4);
        let s = Shard::partition(&d, 0, 1);
        let mut it = BatchIter::new(s.rows, 5, 0);
        let (x, y) = it.next_batch(&s);
        assert_eq!(x.len(), 5 * 784);
        assert_eq!(y.len(), 5);
    }

    #[test]
    fn lm_windows_shift_by_one() {
        let toks: Vec<i32> = (0..100).collect();
        let (xs, ys) = lm_windows(&toks, 10);
        assert_eq!(xs.len(), 9);
        assert_eq!(xs[0], (0..10).collect::<Vec<i32>>());
        assert_eq!(ys[0], (1..11).collect::<Vec<i32>>());
    }

    #[test]
    fn shard_replace_resizes_iterator() {
        let d = mnist_analog(30, 5);
        let mut s = Shard::partition(&d, 0, 2); // 15 rows
        let mut it = BatchIter::new(s.rows, 4, 1);
        let _ = it.next_batch(&s);
        // ring shuffle delivers a differently-sized shard
        let d2 = mnist_analog(8, 6);
        s.replace(d2.x.clone(), d2.y.clone());
        let (x, y) = it.next_batch(&s);
        assert_eq!(y.len(), 4);
        assert_eq!(x.len(), 4 * 784);
    }
}
