//! Synthetic datasets + sharding — the laptop-scale stand-ins for
//! MNIST / CIFAR10 / ImageNet-1K (DESIGN.md "substitutions" table).
//!
//! * [`synthetic`] — Gaussian-blob classification generators with
//!   per-class structure (learnable, so accuracy curves are meaningful)
//!   and a Markov token corpus for the transformer LM.
//! * [`shard`]     — contiguous sharding across ranks + batch iterators,
//!   mirroring how the paper's netCDF reader partitions ImageNet.

pub mod shard;
pub mod synthetic;

pub use shard::{BatchIter, Shard};
pub use synthetic::{blob_classification, token_corpus, Dataset};
