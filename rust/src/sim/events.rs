//! Generic discrete-event engine used by the multi-rank straggler
//! simulation (sim::straggler) — the machinery behind the noise
//! ablation in EXPERIMENTS.md.
//!
//! Minimal but real: a time-ordered event queue with stable FIFO
//! ordering for simultaneous events, driving opaque event payloads.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

#[derive(Debug)]
struct Scheduled<E> {
    at: f64,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: invert so the earliest time pops first,
        // ties broken by insertion order (stable FIFO)
        other
            .at
            .partial_cmp(&self.at)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

/// Time-ordered event queue.
pub struct EventQueue<E> {
    heap: BinaryHeap<Scheduled<E>>,
    seq: u64,
    now: f64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
            now: 0.0,
        }
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Current simulation time (time of the last popped event).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Schedule `event` at absolute time `at` (must not be in the past).
    pub fn schedule(&mut self, at: f64, event: E) {
        debug_assert!(at >= self.now, "scheduling into the past");
        self.heap.push(Scheduled {
            at,
            seq: self.seq,
            event,
        });
        self.seq += 1;
    }

    /// Schedule `event` `delay` after now.
    pub fn schedule_in(&mut self, delay: f64, event: E) {
        let at = self.now + delay;
        self.schedule(at, event);
    }

    /// Pop the next event, advancing simulation time.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        self.heap.pop().map(|s| {
            self.now = s.at;
            (s.at, s.event)
        })
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(3.0, "c");
        q.schedule(1.0, "a");
        q.schedule(2.0, "b");
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn simultaneous_events_fifo() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.schedule(1.0, i);
        }
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn now_advances() {
        let mut q = EventQueue::new();
        q.schedule(5.0, ());
        assert_eq!(q.now(), 0.0);
        q.pop();
        assert_eq!(q.now(), 5.0);
        q.schedule_in(2.0, ());
        assert_eq!(q.pop().unwrap().0, 7.0);
    }
}
