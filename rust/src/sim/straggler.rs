//! Multi-rank straggler simulation — the noise ablation.
//!
//! The closed-form model in [`super::efficiency`] charges an *expected*
//! straggler factor per collective round.  This module simulates the
//! actual rank-level dynamics with a per-(rank, step) recurrence:
//!
//! ```text
//!   start[r][k] = max(end[r][k-1], dependency(r, k-1))
//!   end[r][k]   = start[r][k] + compute · (1 + jitter)
//! ```
//!
//! where the dependency is the global max (barrier schedules: all-reduce
//! SGD/AGD wait for the slowest rank each step) or a single gossip
//! partner (dissemination).  The paper cites exactly this effect
//! (Hoefler et al. [14], Bhatele et al. [15]) as why "actual
//! communication time deviates from Θ(log p)".
//!
//! Output: mean step time per schedule as noise and p grow — gossip's
//! advantage widens with both, which the efficiency table alone cannot
//! show.  (The generic [`super::events`] queue is the DES substrate for
//! schedules with irregular dependency graphs; the three below have
//! regular per-step dependencies, so the recurrence is exact.)

use super::workload::Workload;
use crate::util::{ceil_log2, Rng};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SyncKind {
    /// Barrier each step (all-reduce SGD/AGD): wait for the global max.
    Global,
    /// Gossip: wait only for this step's dissemination partner.
    Partner,
    /// No waiting at all (infinite-staleness bound, for reference).
    None,
}

/// One rank's compute time for a step: nominal × (1 + jitter), jitter
/// drawn from an exponential tail with mean `noise`.
fn jittered(nominal: f64, noise: f64, rng: &mut Rng) -> f64 {
    let u = rng.f64().max(1e-12);
    nominal * (1.0 + noise * (-u.ln()))
}

/// Deterministic per-(rank, step) straggler factor for the *measured*
/// virtual-clock fabric: the same exponential tail as [`jittered`], but
/// a pure hash of `(seed, rank, step)` instead of a sequential RNG
/// stream.  Hash-based (not shared-RNG) on purpose: the coordinator's
/// ranks charge compute concurrently from many threads, so a shared
/// stream would be drawn in scheduling-dependent order and break the
/// fabric's bit-reproducibility.  With this, the noise ablation this
/// module runs in closed form reproduces on the measured fabric at
/// p = 1024 (set `RunConfig::straggler_jitter`).
pub fn jitter_factor(seed: u64, rank: usize, step: usize, noise: f64) -> f64 {
    if noise <= 0.0 {
        return 1.0;
    }
    // splitmix64 over the three coordinates, mixed pairwise so nearby
    // (rank, step) pairs land in unrelated places
    let mut z = seed
        .wrapping_add((rank as u64).wrapping_mul(0x9E3779B97F4A7C15))
        .wrapping_add((step as u64).wrapping_mul(0xBF58476D1CE4E5B9));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^= z >> 31;
    let u = ((z >> 11) as f64 / (1u64 << 53) as f64).max(1e-12);
    1.0 + noise * (-u.ln())
}

/// Simulate `steps` training steps on `p` ranks; returns the mean
/// wall-clock time per step (completion of the slowest rank / steps).
pub fn mean_step_time(
    w: &Workload,
    p: usize,
    kind: SyncKind,
    noise: f64,
    steps: usize,
    seed: u64,
) -> f64 {
    assert!(p >= 1 && steps >= 1);
    let nominal = w.t_compute();
    let mut rngs: Vec<Rng> = (0..p)
        .map(|r| Rng::new(seed ^ (r as u64).wrapping_mul(0x9E3779B97F4A7C15)))
        .collect();
    let rounds = ceil_log2(p).max(1);
    let mut end = vec![0.0f64; p]; // end[r] after the previous step
    for k in 0..steps {
        let prev = end.clone();
        let prev_max = prev.iter().cloned().fold(0.0, f64::max);
        for r in 0..p {
            let dep = match kind {
                SyncKind::Global => prev_max,
                SyncKind::Partner => {
                    // rank r mixes with the model sent by its
                    // dissemination recv partner after step k-1
                    let d = (1usize << (k % rounds)) % p.max(1);
                    let d = d.max(1) % p.max(1);
                    if p == 1 {
                        prev[r]
                    } else {
                        let from = (r + p - d.max(1)) % p;
                        prev[from]
                    }
                }
                SyncKind::None => prev[r],
            };
            let start = prev[r].max(dep);
            end[r] = start + jittered(nominal, noise, &mut rngs[r]);
        }
    }
    end.iter().cloned().fold(0.0, f64::max) / steps as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_noise_all_kinds_equal_nominal() {
        let w = Workload::lenet3(1.0);
        for kind in [SyncKind::Global, SyncKind::Partner, SyncKind::None] {
            let t = mean_step_time(&w, 8, kind, 0.0, 50, 1);
            assert!(
                (t - w.t_compute()).abs() < 1e-9,
                "{kind:?}: {t} vs {}",
                w.t_compute()
            );
        }
    }

    #[test]
    fn global_sync_amplifies_noise_more_than_gossip() {
        let w = Workload::lenet3(1.0);
        let noise = 0.2;
        let g = mean_step_time(&w, 32, SyncKind::Global, noise, 200, 7);
        let p = mean_step_time(&w, 32, SyncKind::Partner, noise, 200, 7);
        let n = mean_step_time(&w, 32, SyncKind::None, noise, 200, 7);
        assert!(g > p, "global {g} should exceed partner {p}");
        assert!(p >= n * 0.999, "partner {p} can't beat no-sync {n}");
        // E[max of 32 exp] ≈ H_32 ≈ 4.06 × mean jitter: the barrier cost
        let amplification = (g / w.t_compute() - 1.0) / noise;
        assert!(
            amplification > 2.0,
            "straggler amplification {amplification} too small"
        );
    }

    #[test]
    fn gossip_advantage_grows_with_p() {
        let w = Workload::lenet3(1.0);
        let adv = |p: usize| {
            let g = mean_step_time(&w, p, SyncKind::Global, 0.15, 200, 3);
            let pt = mean_step_time(&w, p, SyncKind::Partner, 0.15, 200, 3);
            g / pt
        };
        let a4 = adv(4);
        let a64 = adv(64);
        assert!(
            a64 > a4,
            "advantage should grow with p: {a4:.3} -> {a64:.3}"
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let w = Workload::cifarnet(1.0);
        let a = mean_step_time(&w, 16, SyncKind::Partner, 0.3, 100, 42);
        let b = mean_step_time(&w, 16, SyncKind::Partner, 0.3, 100, 42);
        assert_eq!(a, b);
    }

    #[test]
    fn jitter_factor_is_pure_and_tail_shaped() {
        // pure function: same coordinates, same factor — regardless of
        // evaluation order (the property shared-RNG draws cannot give)
        assert_eq!(jitter_factor(7, 3, 100, 0.2), jitter_factor(7, 3, 100, 0.2));
        assert_ne!(jitter_factor(7, 3, 100, 0.2), jitter_factor(7, 4, 100, 0.2));
        assert_ne!(jitter_factor(7, 3, 100, 0.2), jitter_factor(7, 3, 101, 0.2));
        assert_eq!(jitter_factor(7, 3, 100, 0.0), 1.0, "no noise, no jitter");
        // factors are ≥ 1 (one-sided slowdown) with mean ≈ 1 + noise
        let noise = 0.3;
        let n = 20_000usize;
        let mut sum = 0.0;
        for i in 0..n {
            let f = jitter_factor(42, i % 64, i / 64, noise);
            assert!(f >= 1.0);
            sum += f;
        }
        let mean = sum / n as f64;
        assert!(
            (mean - (1.0 + noise)).abs() < 0.02,
            "exponential tail mean off: {mean}"
        );
    }

    #[test]
    fn single_rank_all_kinds_identical() {
        let w = Workload::lenet3(1.0);
        let a = mean_step_time(&w, 1, SyncKind::Global, 0.3, 100, 5);
        let b = mean_step_time(&w, 1, SyncKind::Partner, 0.3, 100, 5);
        let c = mean_step_time(&w, 1, SyncKind::None, 0.3, 100, 5);
        assert_eq!(a, b);
        assert_eq!(b, c);
    }
}
