//! Calibrated workload descriptions for the paper's networks.
//!
//! Numbers from the paper + public model cards:
//! * ResNet50  — 25M params (100 MB fp32); §7.3: fwd+bwd 96 ms at batch
//!   32/device on P100, point-to-point exchange 27 ms.
//! * GoogLeNet — 5M params (20 MB); computationally cheaper per byte,
//!   batch 16 (§7.4) → comm:compute ratio is *higher*, which is why its
//!   AGD speedup curve (Fig 15) rises faster.
//! * LeNet3 / CIFARNet — tiny nets on MNIST/CIFAR10 (§7.2): per-batch
//!   compute derived from the paper's per-epoch numbers (1.2 s/epoch for
//!   MNIST at batch 64/device on 32 GPUs; 0.75 s/epoch CIFAR10 at 100).
//!
//! Per-layer gradient sizes follow each network's actual parameter
//! distribution shape (a few large FC/final blocks + many small conv
//! layers), which is what makes layer-wise overlap interesting.

#[derive(Clone, Debug)]
pub struct Workload {
    pub name: &'static str,
    /// Forward time per batch per device, seconds.
    pub t_fwd: f64,
    /// Backward time per batch per device, seconds.
    pub t_bwd: f64,
    /// Gradient bytes per layer, in *backprop completion order*
    /// (output layer first — ready for comm earliest).
    pub layer_bytes: Vec<usize>,
    /// Fixed per-collective-call overhead (host staging, kernel launch,
    /// enqueue/sync) of the software stack the paper ran this workload
    /// on.  PowerAI DDL (ResNet50, Table 7) is highly optimized
    /// (~10 µs); the paper's own Caffe+MPI path (LeNet3/CIFARNet/
    /// GoogLeNet, Figs 10/11/15/16) stages GPU buffers through the host
    /// — back-solving their "1.2 s/epoch MNIST on 32 GPUs" and the
    /// ~1.9x AGD gap gives ~2 ms per call.
    pub call_overhead: f64,
}

impl Workload {
    pub fn model_bytes(&self) -> usize {
        self.layer_bytes.iter().sum()
    }

    pub fn t_compute(&self) -> f64 {
        self.t_fwd + self.t_bwd
    }

    /// Per-layer backward compute slices, in backprop-completion order
    /// (same order as `layer_bytes`: output layer first).  The backward
    /// time is split across layers proportionally to their parameter
    /// bytes — heavier layers take longer — so `t_fwd + Σ slices =
    /// t_compute()`.  This is the compute model behind both the
    /// closed-form simulator ([`grad_ready_times`](Self::grad_ready_times))
    /// and the measured virtual-clock pipeline (the coordinator charges
    /// one slice per layer and posts that layer's send the instant its
    /// slice completes).
    pub fn layer_compute_slices(&self) -> Vec<f64> {
        split_compute(self.t_bwd, &self.layer_bytes)
    }

    /// Instant (from step start) at which each layer's gradient is ready
    /// for communication: the forward pass plus the prefix sums of the
    /// per-layer backward slices.  Output layer first.
    pub fn grad_ready_times(&self) -> Vec<f64> {
        let mut t = self.t_fwd;
        self.layer_compute_slices()
            .into_iter()
            .map(|s| {
                t += s;
                t
            })
            .collect()
    }

    /// ResNet50 on P100, batch 32/device (paper §7.3.1).
    pub fn resnet50_p100() -> Workload {
        // 100 MB over a ResNet-ish distribution: fc + 53 conv blocks,
        // sizes dominated by the late stages.
        let mut layers = vec![8 << 20]; // fc + last conv block
        for i in 0..16 {
            layers.push(((4 << 20) as f64 * (1.0 - i as f64 / 24.0)) as usize);
        }
        for _ in 0..36 {
            layers.push(1 << 20);
        }
        let total: usize = layers.iter().sum();
        let scale = (100u64 << 20) as f64 / total as f64;
        for l in layers.iter_mut() {
            *l = (*l as f64 * scale) as usize;
        }
        Workload {
            name: "resnet50",
            t_fwd: 0.032,
            t_bwd: 0.064, // fwd:bwd ≈ 1:2
            layer_bytes: layers,
            call_overhead: 10e-6, // PowerAI DDL: optimized collectives
        }
    }

    /// GoogLeNet on P100, batch 16/device (paper §7.4).
    pub fn googlenet_p100() -> Workload {
        let mut layers = vec![4 << 20]; // classifier head
        for _ in 0..9 {
            layers.push((16 << 20) / 10); // 9 inception blocks
        }
        Workload {
            name: "googlenet",
            t_fwd: 0.0065,
            t_bwd: 0.013,
            layer_bytes: layers,
            call_overhead: 1.5e-3, // paper's NVCaffe+MPI path
        }
    }

    /// LeNet3 on MNIST, batch 64/device; 1.2 s/epoch on 32 devices
    /// (§7.2.1) → 60000/(32·64) ≈ 29 batches → ~41 ms/batch... but that
    /// epoch time already includes comm; we attribute 60% to compute.
    pub fn lenet3(device_speed: f64) -> Workload {
        let t = 0.025 / device_speed;
        Workload {
            name: "lenet3",
            t_fwd: t / 3.0,
            t_bwd: 2.0 * t / 3.0,
            layer_bytes: vec![120_000, 1_600_000, 400_000],
            call_overhead: 4.0e-3, // vanilla Caffe+MPI host staging (backsolved from 1.2 s/epoch)
        }
    }

    /// Ad-hoc workload over an explicit layer table (backprop order,
    /// output layer first) with zero software-stack overhead — the
    /// analytic twin of a *measured* virtual-clock run, whose backend
    /// layer table generally differs from the paper networks'.  The
    /// benches build one from `RunConfig::{virt_fwd_secs,
    /// virt_compute_secs}` and the backend's reversed layer table to
    /// assert measured comm-thread AGD against
    /// [`overlapped_agd_step_time`](crate::sim::efficiency::overlapped_agd_step_time).
    pub fn standin(t_fwd: f64, t_bwd: f64, layer_bytes: Vec<usize>) -> Workload {
        Workload {
            name: "standin",
            t_fwd,
            t_bwd,
            layer_bytes,
            call_overhead: 0.0,
        }
    }

    /// [`standin`](Self::standin) for an MLP layer stack: per-layer
    /// gradient bytes `(d_i·d_{i+1} + d_{i+1})·4` in backprop order
    /// (output layer first) — the same table
    /// [`NativeMlp::new`](crate::nativenet::NativeMlp::new) builds, so
    /// benches and tests construct the analytic twin of a measured
    /// stand-in run from one place.
    pub fn standin_mlp(t_fwd: f64, t_bwd: f64, dims: &[usize]) -> Workload {
        let layer_bytes = (0..dims.len() - 1)
            .rev()
            .map(|i| (dims[i] * dims[i + 1] + dims[i + 1]) * 4)
            .collect();
        Workload::standin(t_fwd, t_bwd, layer_bytes)
    }

    /// CLI name → calibrated workload.  `device_speed` scales the
    /// tiny-net compute models (LeNet3/CIFARNet); the P100-calibrated
    /// networks ignore it.
    pub fn by_name(name: &str, device_speed: f64) -> Option<Workload> {
        Some(match name {
            "resnet50" => Workload::resnet50_p100(),
            "googlenet" => Workload::googlenet_p100(),
            "lenet3" => Workload::lenet3(device_speed),
            "cifarnet" => Workload::cifarnet(device_speed),
            _ => return None,
        })
    }

    /// CIFARNet, batch 100/device; 0.75 s/epoch at 32 devices (§7.2.1).
    pub fn cifarnet(device_speed: f64) -> Workload {
        let t = 0.040 / device_speed;
        Workload {
            name: "cifarnet",
            t_fwd: t / 3.0,
            t_bwd: 2.0 * t / 3.0,
            layer_bytes: vec![250_000, 1_100_000, 210_000, 90_000],
            call_overhead: 4.0e-3, // vanilla Caffe+MPI host staging (backsolved from 1.2 s/epoch)
        }
    }
}

/// Split `total` seconds across layers proportionally to their byte
/// sizes (the shared per-layer compute model; also used by the
/// coordinator to split a configured compute budget across a backend's
/// actual layer table).
pub fn split_compute(total: f64, layer_bytes: &[usize]) -> Vec<f64> {
    let sum: usize = layer_bytes.iter().sum();
    if sum == 0 {
        return vec![0.0; layer_bytes.len()];
    }
    layer_bytes
        .iter()
        .map(|&b| total * b as f64 / sum as f64)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet50_calibration() {
        let w = Workload::resnet50_p100();
        let mb = w.model_bytes() as f64 / (1 << 20) as f64;
        assert!((95.0..=105.0).contains(&mb), "model {mb} MB");
        assert!((w.t_compute() - 0.096).abs() < 1e-9);
    }

    #[test]
    fn googlenet_smaller_but_chattier() {
        let g = Workload::googlenet_p100();
        let r = Workload::resnet50_p100();
        assert!(g.model_bytes() < r.model_bytes() / 3);
        // comm:compute ratio higher for googlenet (the Fig 15 driver)
        let ratio = |w: &Workload| w.model_bytes() as f64 / w.t_compute();
        assert!(ratio(&g) > ratio(&r) * 0.9);
    }

    #[test]
    fn layer_order_output_first() {
        let w = Workload::resnet50_p100();
        assert!(w.layer_bytes[0] > *w.layer_bytes.last().unwrap());
    }

    #[test]
    fn compute_slices_partition_the_backward_pass() {
        let w = Workload::resnet50_p100();
        let slices = w.layer_compute_slices();
        assert_eq!(slices.len(), w.layer_bytes.len());
        let total: f64 = slices.iter().sum();
        assert!((total - w.t_bwd).abs() < 1e-12, "Σ slices {total}");
        // heavier layers get longer slices
        assert!(slices[0] > *slices.last().unwrap());
    }

    #[test]
    fn grad_ready_times_monotone_and_end_at_t_compute() {
        for w in [Workload::resnet50_p100(), Workload::lenet3(1.0)] {
            let ready = w.grad_ready_times();
            assert!(ready[0] > w.t_fwd);
            assert!(ready.windows(2).all(|p| p[0] < p[1]));
            assert!((ready.last().unwrap() - w.t_compute()).abs() < 1e-12);
        }
    }

    #[test]
    fn standin_mlp_reverses_layer_table() {
        let w = Workload::standin_mlp(0.0, 0.0, &[4, 3, 2]);
        // fc0 = 4*3+3 = 15 params, fc1 = 3*2+2 = 8; output layer first
        assert_eq!(w.layer_bytes, vec![8 * 4, 15 * 4]);
        assert_eq!(w.model_bytes(), (15 + 8) * 4);
    }

    #[test]
    fn split_compute_handles_degenerate_inputs() {
        assert_eq!(split_compute(1.0, &[]), Vec::<f64>::new());
        assert_eq!(split_compute(1.0, &[0, 0]), vec![0.0, 0.0]);
        let s = split_compute(2.0, &[1, 3]);
        assert!((s[0] - 0.5).abs() < 1e-12 && (s[1] - 1.5).abs() < 1e-12);
    }
}
