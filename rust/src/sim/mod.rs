//! Discrete-event scale simulator — regenerates the paper's large-scale
//! efficiency results (Table 7, Figs 10/11/15/17 performance panels) at
//! device counts unavailable on this testbed (up to 128 P100s and
//! beyond).
//!
//! The simulator charges exactly the communication schedules the real
//! coordinator emits (same per-layer message sizes, same per-step
//! partner patterns, same all-reduce round structures) against the α–β
//! cost model, with a per-layer compute timeline that exposes the
//! paper's central mechanism: *gradients of layer ℓ are ready for
//! communication while back-propagation continues on layers < ℓ* (§5).
//!
//! Efficiency := t_compute / t_step — "compute efficiency" as reported
//! in Table 7 (100% ⇔ all communication hidden under compute).
//!
//! Two simulation paths share the calibrated [`Workload`] costs:
//! * this module's *closed-form* per-step models (fast sweeps to
//!   arbitrary p, no coordinator in the loop), and
//! * the transport's *virtual clock*
//!   ([`Fabric::new_virtual`](crate::transport::Fabric::new_virtual) +
//!   [`RunConfig::virtualize`](crate::config::RunConfig::virtualize)),
//!   which runs the real coordinator/transport code against
//!   `Workload::t_compute()` charges — measured schedules, deterministic
//!   discrete-event timing (docs/virtual-time.md).

pub mod efficiency;
pub mod events;
pub mod straggler;
pub mod workload;

pub use efficiency::{
    avg_gossip_efficiency_with_topology, gossip_step_time_with_topology, step_time,
    Efficiency, Schedule,
};
pub use straggler::jitter_factor;
pub use workload::{split_compute, Workload};
