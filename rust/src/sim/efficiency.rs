//! Step-time simulation for each communication schedule.
//!
//! Timeline model (one training step, one device; all devices are
//! symmetric under weak scaling):
//!
//! ```text
//!   fwd ──────▶ bwd layer L ▶ layer L-1 ▶ ... ▶ layer 1 ──▶ [drain] ─▶ next fwd
//!                    │gradient ready       │
//!                    ▼                     ▼
//!               NIC queue (one link, serialised sends)
//! ```
//!
//! Layer ℓ's gradient message is *enqueued* when its backprop slice
//! finishes; the NIC transmits queued messages serially at α + M·β each
//! (per partner/round).  The step ends when both compute and the
//! schedule's completion condition are met; `exposed = t_step − t_compute`.
//!
//! Schedules:
//! * `Gossip`      — one send + one recv of each layer (dissemination
//!   partner), O(1) per step.  §5.1 non-blocking + TestAll.
//! * `Allreduce`   — per-layer all-reduce, `rounds(p)` serialized rounds
//!   each (AGD: overlapped with remaining backprop; SGD: after backprop).
//! * `PeriodicAllreduce` — AGD every ⌈log₂p⌉ steps (Fig 17 baseline).
//! * `ParamServer` — all ranks push/pull to `servers` servers; server
//!   NIC is the contended resource (the §1 bottleneck).

use super::workload::Workload;
use crate::codec::Codec;
use crate::collectives::Algorithm;
use crate::transport::{CostModel, HierCostModel};
use crate::util::ceil_log2;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Schedule {
    /// GossipGraD: O(1) point-to-point per step, layer-wise async.
    Gossip,
    /// Synchronous SGD: all-reduce after backprop, nothing overlapped.
    SgdSync(Algorithm),
    /// AGD: layer-wise all-reduce overlapped with backprop (S-Caffe /
    /// PowerAI / Caffe2 style).
    Agd(Algorithm),
    /// AGD but communicating only every ⌈log₂ p⌉ steps (Fig 17).
    PeriodicAgd(Algorithm),
    /// Parameter server with `n` servers (Fig 2a baseline).
    ParamServer { servers: usize },
}

impl Schedule {
    pub fn name(self) -> String {
        match self {
            Schedule::Gossip => "gossipgrad".into(),
            Schedule::SgdSync(a) => format!("sgd-sync/{}", a.name()),
            Schedule::Agd(a) => format!("agd/{}", a.name()),
            Schedule::PeriodicAgd(a) => format!("periodic-agd/{}", a.name()),
            Schedule::ParamServer { servers } => format!("ps/{servers}"),
        }
    }
}

#[derive(Clone, Debug)]
pub struct Efficiency {
    pub p: usize,
    pub t_compute: f64,
    pub t_step: f64,
    pub exposed_comm: f64,
}

impl Efficiency {
    /// "Compute efficiency" as in Table 7 (percent).
    pub fn percent(&self) -> f64 {
        100.0 * self.t_compute / self.t_step
    }

    /// Throughput in batch updates per second per device (§7.3.1 quotes
    /// 10.4 for ResNet50).
    pub fn updates_per_sec(&self) -> f64 {
        1.0 / self.t_step
    }
}

/// Per-layer backprop finish times (output layer first) — the shared
/// compute model in [`Workload::grad_ready_times`]; the measured
/// virtual-clock pipeline charges the same slices.
fn grad_ready_times(w: &Workload) -> Vec<f64> {
    w.grad_ready_times()
}

/// Per-round progress/synchronisation overhead of collective rounds
/// (kernel launch + MPI progress engine; ~10 µs in practice — the paper
/// cites Sur et al. [46] on rendezvous-protocol progress costs).
const ROUND_OVERHEAD: f64 = 10e-6;

/// OS-noise straggler amplification (Hoefler et al. [14]): every
/// synchronising round waits for the slowest of p ranks; with a
/// heavy-tailed per-rank delay the expected max grows ~ln(p).
fn straggler(p: usize, noise_frac: f64) -> f64 {
    1.0 + noise_frac * (p.max(1) as f64).ln()
}

/// Completion time of one all-reduce *chain* started at `ready`:
/// `rounds` dependent rounds, each paying latency + sync overhead, plus
/// a per-call fixed cost (the workload's software stack: host staging /
/// launch / enqueue — see Workload::call_overhead) and the total wire
/// time for this algorithm's traffic pattern.
fn chain_time(
    alg: Algorithm,
    p: usize,
    bytes: usize,
    cost: &CostModel,
    call_overhead: f64,
) -> f64 {
    let rounds = alg.rounds(p).max(1) as f64;
    let per_round_bytes = match alg {
        Algorithm::Ring => bytes / p.max(1),
        _ => bytes,
    };
    let wire = rounds * (per_round_bytes as f64 * cost.beta);
    call_overhead * straggler(p, cost.noise_frac)
        + rounds * (cost.alpha + ROUND_OVERHEAD * straggler(p, cost.noise_frac))
        + wire
}

/// Serialise a set of (enqueue_time, wire_time) messages on one NIC;
/// returns the time the last message completes.
fn nic_drain(mut msgs: Vec<(f64, f64)>) -> f64 {
    msgs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut nic_free = 0.0f64;
    for (ready, wire) in msgs {
        let start = nic_free.max(ready);
        nic_free = start + wire;
    }
    nic_free
}

/// Wire bytes of a dense-f32 buffer of `bytes` bytes under `codec` on
/// the rank-side [`crate::codec::Encoder`] path (gossip model
/// exchanges, PS gradient pushes) — top-k genuinely sparsifies here.
fn coded(codec: Codec, bytes: usize) -> usize {
    codec.wire_bytes_for(bytes / 4)
}

/// Same, on the stateless auto-encode path (collective rounds, PS model
/// broadcast), where top-k rides dense f32.
fn coded_stateless(codec: Codec, bytes: usize) -> usize {
    codec.stateless_wire_bytes_for(bytes / 4)
}

/// Simulate one step with the default dense-f32 codec.
pub fn step_time(
    sched: Schedule,
    w: &Workload,
    p: usize,
    cost: &CostModel,
    step_idx: usize,
) -> Efficiency {
    step_time_with_codec(sched, w, p, cost, step_idx, Codec::F32)
}

/// Simulate one step; returns the efficiency record.  Payload byte
/// counts are scaled by `codec` exactly where the measured coordinator
/// compresses: gossip exchanges and PS pushes on the Encoder path,
/// collective rounds and PS broadcasts on the stateless path.
/// `Codec::F32` reproduces the uncoded curve bit-for-bit.
pub fn step_time_with_codec(
    sched: Schedule,
    w: &Workload,
    p: usize,
    cost: &CostModel,
    step_idx: usize,
    codec: Codec,
) -> Efficiency {
    let t_compute = w.t_compute();
    let ready = grad_ready_times(w);
    let t_step = match sched {
        Schedule::Gossip => {
            // one partner: each layer sent once as it becomes ready;
            // receives happen concurrently (full-duplex link assumed,
            // as in the paper's NVLink/IB fabrics)
            let msgs: Vec<(f64, f64)> = ready
                .iter()
                .zip(&w.layer_bytes)
                .map(|(&r, &b)| (r, cost.nominal(coded(codec, b))))
                .collect();
            let comm_done = nic_drain(msgs);
            // mixing cost: one streaming pass over the model in device
            // memory (P100 HBM2 ~500 GB/s effective for 2R+1W) — the
            // mix runs on *decoded* f32s, so it does not shrink with
            // the codec
            let mix = 3.0 * w.model_bytes() as f64 / 500.0e9;
            t_compute.max(comm_done) + mix
        }
        Schedule::SgdSync(alg) => {
            // blocking all-reduce of the whole model after backprop
            t_compute
                + chain_time(
                    alg,
                    p,
                    coded_stateless(codec, w.model_bytes()),
                    cost,
                    w.call_overhead,
                )
        }
        Schedule::Agd(alg) => {
            // per-layer all-reduce, overlapped: layer ℓ's chain starts
            // when its gradient is ready; chains run concurrently but
            // their wire traffic shares the NIC
            let mut comm_done = 0.0f64;
            let mut msgs = Vec::new();
            for (&r, &b) in ready.iter().zip(&w.layer_bytes) {
                let cb = coded_stateless(codec, b);
                comm_done =
                    comm_done.max(r + chain_time(alg, p, cb, cost, w.call_overhead));
                let rounds = alg.rounds(p).max(1);
                let per_round_bytes = match alg {
                    Algorithm::Ring => cb / p.max(1),
                    _ => cb,
                };
                for _ in 0..rounds {
                    msgs.push((r, per_round_bytes as f64 * cost.beta));
                }
            }
            comm_done = comm_done.max(nic_drain(msgs));
            t_compute.max(comm_done)
        }
        Schedule::PeriodicAgd(alg) => {
            let period = ceil_log2(p).max(1);
            if step_idx % period == period - 1 {
                // communication step: same as Agd
                return step_time_with_codec(
                    Schedule::Agd(alg),
                    w,
                    p,
                    cost,
                    0,
                    codec,
                );
            }
            t_compute
        }
        Schedule::ParamServer { servers } => {
            // each device pushes grads + pulls weights; each server link
            // carries 2·p/servers model-sized transfers serially.  The
            // push is the compressing Encoder path; the pull (model
            // broadcast) is the stateless path.
            let per_server = (p as f64 / servers.max(1) as f64).ceil();
            let push = cost.nominal(coded(codec, w.model_bytes()));
            let pull = cost.nominal(coded_stateless(codec, w.model_bytes()));
            t_compute + per_server * (push + pull)
        }
    };
    Efficiency {
        p,
        t_compute,
        t_step,
        exposed_comm: (t_step - t_compute).max(0.0),
    }
}

/// Closed-form step time of one **two-level gossip** step under the
/// hierarchical cost model — the analytic twin of the measured
/// `--group-size G --inter-period k --cost-model hier` run
/// (docs/topology.md).
///
/// The two-level schedule sends each layer to exactly one partner per
/// step, like flat gossip — what changes is *which tier* the message
/// crosses: every `inter_period`-th step the partner sits in another
/// host group (the `hier.inter` α–β pair), every other step it is a
/// group co-resident (`hier.intra`, NVLink-class).  The degenerate maps
/// fall out naturally: `group_size = 1` makes every pair inter-group
/// (the flat curve under the inter tier — the baseline arm of the
/// hier-frontier gate), `group_size = p` makes every pair intra-group.
pub fn gossip_step_time_with_topology(
    w: &Workload,
    hier: &HierCostModel,
    inter_period: usize,
    step_idx: usize,
    codec: Codec,
) -> Efficiency {
    let g = hier.groups.group_size();
    let p = hier.groups.p();
    let two_level = g > 1 && g < p;
    // flat schedules exchange across groups every step (g = 1: every
    // peer is foreign; g = p: every peer is local)
    let inter_step = if two_level {
        step_idx % inter_period.max(1) == 0
    } else {
        g == 1
    };
    let tier = if inter_step { &hier.inter } else { &hier.intra };
    let t_compute = w.t_compute();
    let msgs: Vec<(f64, f64)> = grad_ready_times(w)
        .iter()
        .zip(&w.layer_bytes)
        .map(|(&r, &b)| (r, tier.nominal(coded(codec, b))))
        .collect();
    let comm_done = nic_drain(msgs);
    // same device-memory mixing pass as Schedule::Gossip: decoded f32s,
    // tier-independent
    let mix = 3.0 * w.model_bytes() as f64 / 500.0e9;
    let t_step = t_compute.max(comm_done) + mix;
    Efficiency {
        p,
        t_compute,
        t_step,
        exposed_comm: (t_step - t_compute).max(0.0),
    }
}

/// [`gossip_step_time_with_topology`] averaged over a window of steps —
/// the window must cover the inter/intra cadence, so it is rounded up
/// to a multiple of `inter_period`.
pub fn avg_gossip_efficiency_with_topology(
    w: &Workload,
    hier: &HierCostModel,
    inter_period: usize,
    steps: usize,
    codec: Codec,
) -> Efficiency {
    let k = inter_period.max(1);
    let steps = steps.max(1).div_ceil(k) * k;
    let mut tot_step = 0.0;
    let mut tot_comp = 0.0;
    for s in 0..steps {
        let e = gossip_step_time_with_topology(w, hier, k, s, codec);
        tot_step += e.t_step;
        tot_comp += e.t_compute;
    }
    Efficiency {
        p: hier.groups.p(),
        t_compute: tot_comp / steps as f64,
        t_step: tot_step / steps as f64,
        exposed_comm: ((tot_step - tot_comp) / steps as f64).max(0.0),
    }
}

/// Closed-form step time of **comm-thread AGD** (the measured
/// `--comm-thread` schedule) on the pure α–β fabric: layer ℓ's
/// collective is posted at its grad-ready instant r_ℓ and its
/// `rounds(p)` dependency-chained rounds advance at message-arrival
/// instants on a dedicated progress thread, concurrent with the
/// remaining backprop; the harvest point is when both the compute and
/// the slowest chain have finished:
///
/// ```text
///   t_step = max( t_compute, max_ℓ ( r_ℓ + rounds(p) · (α + M_ℓ·β) ) )
/// ```
///
/// Unlike [`Schedule::Agd`]'s curve this carries no software-stack
/// overheads (`call_overhead`, `ROUND_OVERHEAD`, straggler
/// amplification) and no NIC serialization, because the virtual fabric
/// charges pure nominal wire costs — it is the analytic twin the
/// measured comm-thread path is asserted against (within 5%) in the
/// Fig 10/11 and Table 7 benches.
pub fn overlapped_agd_step_time(
    alg: Algorithm,
    w: &Workload,
    p: usize,
    cost: &CostModel,
) -> f64 {
    overlapped_agd_step_time_with_codec(alg, w, p, cost, Codec::F32)
}

/// [`overlapped_agd_step_time`] with collective payloads scaled by the
/// codec's stateless path (comm-thread collectives auto-encode at the
/// endpoint, so top-k rides dense f32 here too).
pub fn overlapped_agd_step_time_with_codec(
    alg: Algorithm,
    w: &Workload,
    p: usize,
    cost: &CostModel,
    codec: Codec,
) -> f64 {
    let rounds = alg.rounds(p).max(1) as f64;
    let mut t = w.t_compute();
    for (&r, &b) in w.grad_ready_times().iter().zip(&w.layer_bytes) {
        let cb = coded_stateless(codec, b);
        let per_round_bytes = match alg {
            Algorithm::Ring => cb / p.max(1),
            _ => cb,
        };
        t = t.max(r + rounds * cost.nominal(per_round_bytes));
    }
    t
}

/// [`overlapped_agd_step_time`] as an efficiency record.
pub fn overlapped_agd_efficiency(
    alg: Algorithm,
    w: &Workload,
    p: usize,
    cost: &CostModel,
) -> Efficiency {
    let t_step = overlapped_agd_step_time(alg, w, p, cost);
    Efficiency {
        p,
        t_compute: w.t_compute(),
        t_step,
        exposed_comm: (t_step - w.t_compute()).max(0.0),
    }
}

/// Average efficiency over a window of steps (relevant for periodic
/// schedules whose per-step time alternates).
pub fn avg_efficiency(
    sched: Schedule,
    w: &Workload,
    p: usize,
    cost: &CostModel,
    steps: usize,
) -> Efficiency {
    avg_efficiency_with_codec(sched, w, p, cost, steps, Codec::F32)
}

/// [`avg_efficiency`] under a wire codec.
pub fn avg_efficiency_with_codec(
    sched: Schedule,
    w: &Workload,
    p: usize,
    cost: &CostModel,
    steps: usize,
    codec: Codec,
) -> Efficiency {
    let mut tot_step = 0.0;
    let mut tot_comp = 0.0;
    for s in 0..steps {
        let e = step_time_with_codec(sched, w, p, cost, s, codec);
        tot_step += e.t_step;
        tot_comp += e.t_compute;
    }
    Efficiency {
        p,
        t_compute: tot_comp / steps as f64,
        t_step: tot_step / steps as f64,
        exposed_comm: ((tot_step - tot_comp) / steps as f64).max(0.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ib() -> CostModel {
        CostModel::new(1.0e-6, 1.0 / 12.0e9, 0.0, 0)
    }

    #[test]
    fn gossip_resnet50_hits_full_efficiency() {
        // the paper's headline: ≈100% at 128 GPUs (Table 7)
        let w = Workload::resnet50_p100();
        for p in [4usize, 8, 16, 32, 64, 128] {
            let e = step_time(Schedule::Gossip, &w, p, &ib(), 0);
            assert!(
                e.percent() > 98.5,
                "p={p}: gossip eff {:.1}%",
                e.percent()
            );
        }
    }

    #[test]
    fn gossip_comm_fits_the_27ms_budget() {
        // §7.3.1: 27 ms point-to-point comm, hidden under 96 ms compute
        let w = Workload::resnet50_p100();
        let comm: f64 = w
            .layer_bytes
            .iter()
            .map(|&b| ib().nominal(b))
            .sum();
        assert!(comm < 0.030, "p2p comm {comm}s");
        assert!(comm < w.t_compute());
    }

    #[test]
    fn allreduce_efficiency_decays_with_p() {
        let w = Workload::resnet50_p100();
        let c = ib();
        let e8 = step_time(Schedule::Agd(Algorithm::Ring), &w, 8, &c, 0);
        let e128 = step_time(Schedule::Agd(Algorithm::Ring), &w, 128, &c, 0);
        assert!(e128.percent() < e8.percent(), "agd should decay with p");
        // shape check vs Table 7's PowerAI column: still >90% at 128
        assert!(e128.percent() > 85.0, "{:.1}", e128.percent());
        assert!(e8.percent() > 97.0, "{:.1}", e8.percent());
    }

    #[test]
    fn sgd_sync_worse_than_agd() {
        let w = Workload::resnet50_p100();
        let c = ib();
        for p in [16usize, 64] {
            let sgd = step_time(
                Schedule::SgdSync(Algorithm::RecursiveDoubling),
                &w,
                p,
                &c,
                0,
            );
            let agd =
                step_time(Schedule::Agd(Algorithm::RecursiveDoubling), &w, p, &c, 0);
            assert!(sgd.t_step > agd.t_step, "p={p}");
        }
    }

    #[test]
    fn param_server_collapses_at_scale() {
        let w = Workload::resnet50_p100();
        let c = ib();
        let e = step_time(Schedule::ParamServer { servers: 1 }, &w, 64, &c, 0);
        assert!(e.percent() < 15.0, "ps eff {:.1}%", e.percent());
    }

    #[test]
    fn periodic_agd_amortizes() {
        let w = Workload::lenet3(4.0);
        let c = ib();
        let per = avg_efficiency(
            Schedule::PeriodicAgd(Algorithm::RecursiveDoubling),
            &w,
            32,
            &c,
            100,
        );
        let agd = avg_efficiency(
            Schedule::Agd(Algorithm::RecursiveDoubling),
            &w,
            32,
            &c,
            100,
        );
        assert!(per.percent() >= agd.percent());
    }

    #[test]
    fn overlapped_agd_bounds_and_shape() {
        let w = Workload::resnet50_p100();
        let c = ib();
        for p in [8usize, 128, 1024] {
            let ov = overlapped_agd_step_time(Algorithm::RecursiveDoubling, &w, p, &c);
            // never faster than compute, never slower than the
            // overhead-laden Schedule::Agd curve
            assert!(ov >= w.t_compute(), "p={p}");
            let agd = step_time(
                Schedule::Agd(Algorithm::RecursiveDoubling),
                &w,
                p,
                &c,
                0,
            );
            assert!(
                ov <= agd.t_step + 1e-12,
                "p={p}: pure-fabric overlapped AGD ({ov}) slower than \
                 overheaded AGD ({})",
                agd.t_step
            );
        }
        // the exposed chain grows with p once log p rounds dominate
        let e128 =
            overlapped_agd_efficiency(Algorithm::RecursiveDoubling, &w, 128, &c);
        let e1024 =
            overlapped_agd_efficiency(Algorithm::RecursiveDoubling, &w, 1024, &c);
        assert!(e1024.percent() <= e128.percent());
        assert!(e1024.exposed_comm >= 0.0);
    }

    #[test]
    fn standin_workload_matches_explicit_table() {
        let w = Workload::standin(0.002, 0.004, vec![1000, 3000]);
        assert_eq!(w.model_bytes(), 4000);
        assert!((w.t_compute() - 0.006).abs() < 1e-12);
        let ready = w.grad_ready_times();
        // bwd split 1:3 over the table
        assert!((ready[0] - 0.003).abs() < 1e-12);
        assert!((ready[1] - 0.006).abs() < 1e-12);
        assert_eq!(w.call_overhead, 0.0);
    }

    #[test]
    fn f32_codec_is_the_identity_curve() {
        let w = Workload::resnet50_p100();
        let c = ib();
        for sched in [
            Schedule::Gossip,
            Schedule::SgdSync(Algorithm::RecursiveDoubling),
            Schedule::Agd(Algorithm::Ring),
            Schedule::ParamServer { servers: 1 },
        ] {
            let plain = step_time(sched, &w, 64, &c, 0);
            let coded = step_time_with_codec(sched, &w, 64, &c, 0, Codec::F32);
            assert_eq!(
                plain.t_step.to_bits(),
                coded.t_step.to_bits(),
                "{}: f32 codec must be bit-identical",
                sched.name()
            );
        }
    }

    #[test]
    fn bf16_lifts_comm_bound_schedules() {
        let w = Workload::resnet50_p100();
        let c = ib();
        // PS at p=64 is comm-bound: halving the bytes must lift
        // efficiency substantially
        let f32e =
            step_time_with_codec(Schedule::ParamServer { servers: 1 }, &w, 64, &c, 0, Codec::F32);
        let bf16 =
            step_time_with_codec(Schedule::ParamServer { servers: 1 }, &w, 64, &c, 0, Codec::Bf16);
        assert!(
            bf16.percent() > 1.5 * f32e.percent(),
            "bf16 ps eff {:.1}% vs f32 {:.1}%",
            bf16.percent(),
            f32e.percent()
        );
        // blocking sgd-sync also sees a strictly faster step
        let s32 = step_time_with_codec(
            Schedule::SgdSync(Algorithm::RecursiveDoubling),
            &w,
            64,
            &c,
            0,
            Codec::F32,
        );
        let s16 = step_time_with_codec(
            Schedule::SgdSync(Algorithm::RecursiveDoubling),
            &w,
            64,
            &c,
            0,
            Codec::Bf16,
        );
        assert!(s16.t_step < s32.t_step);
    }

    #[test]
    fn topk_is_sparse_on_gossip_but_dense_on_collectives() {
        // comm-bound standin so gossip's exposed comm is visible
        let w = Workload::standin(0.0001, 0.0001, vec![4_000_000]);
        let c = ib();
        let g32 = step_time_with_codec(Schedule::Gossip, &w, 64, &c, 0, Codec::F32);
        let gtk = step_time_with_codec(Schedule::Gossip, &w, 64, &c, 0, Codec::TopK);
        assert!(
            gtk.t_step < g32.t_step,
            "top-k gossip {:.6}s vs f32 {:.6}s",
            gtk.t_step,
            g32.t_step
        );
        // collectives ride the stateless path: top-k is dense there
        let a32 = step_time_with_codec(
            Schedule::SgdSync(Algorithm::RecursiveDoubling),
            &w,
            64,
            &c,
            0,
            Codec::F32,
        );
        let atk = step_time_with_codec(
            Schedule::SgdSync(Algorithm::RecursiveDoubling),
            &w,
            64,
            &c,
            0,
            Codec::TopK,
        );
        assert_eq!(a32.t_step.to_bits(), atk.t_step.to_bits());
    }

    #[test]
    fn topology_twin_degenerates_to_flat_curves() {
        use crate::transport::GroupMap;
        let w = Workload::resnet50_p100();
        let c = ib();
        let p = 64;
        // group_size = 1: every pair is inter-group — bit-identical to
        // the historical flat gossip curve under the inter tier
        let flat = step_time(Schedule::Gossip, &w, p, &c, 0);
        let g1 = gossip_step_time_with_topology(
            &w,
            &HierCostModel::with_inter(c.clone(), GroupMap::new(p, 1)),
            4,
            0,
            Codec::F32,
        );
        assert_eq!(flat.t_step.to_bits(), g1.t_step.to_bits());
        // group_size = p: every pair is intra-group — the NVLink curve
        let gp = gossip_step_time_with_topology(
            &w,
            &HierCostModel::with_inter(c.clone(), GroupMap::new(p, p)),
            4,
            0,
            Codec::F32,
        );
        let nv = step_time(Schedule::Gossip, &w, p, &CostModel::nvlink(), 0);
        assert_eq!(nv.t_step.to_bits(), gp.t_step.to_bits());
    }

    #[test]
    fn two_level_alternates_tiers_on_the_inter_cadence() {
        use crate::transport::GroupMap;
        let w = Workload::lenet3(40.0);
        let inter = CostModel::new(200e-6, 1.0 / 0.5e9, 0.0, 0);
        let hier = HierCostModel::with_inter(inter, GroupMap::new(64, 8));
        let k = 4;
        let at = |s| gossip_step_time_with_topology(&w, &hier, k, s, Codec::F32).t_step;
        assert!(at(0) > at(1), "step 0 crosses hosts, step 1 stays inside");
        assert_eq!(at(1).to_bits(), at(2).to_bits());
        assert_eq!(at(0).to_bits(), at(4).to_bits(), "cadence repeats every k");
    }

    #[test]
    fn hier_frontier_two_level_beats_flat_at_1024() {
        // the closed-form arm of the CI hier-frontier gate
        // (tools/hier_frontier_closed_form.py mirrors this setup):
        // p = 1024 over 128 modeled hosts (group_size 8), LeNet3 analog
        // at device speed 40, 200 µs / 0.5 GB/s across hosts,
        // inter-group exchange every 4th step
        use crate::transport::GroupMap;
        let w = Workload::lenet3(40.0);
        let inter = CostModel::new(200e-6, 1.0 / 0.5e9, 0.0, 0);
        let p = 1024;
        let hier = HierCostModel::with_inter(inter.clone(), GroupMap::new(p, 8));
        let flat = HierCostModel::with_inter(inter, GroupMap::new(p, 1));
        let h = avg_gossip_efficiency_with_topology(&w, &hier, 4, 64, Codec::F32);
        let f = avg_gossip_efficiency_with_topology(&w, &flat, 4, 64, Codec::F32);
        let ratio = f.t_step / h.t_step;
        assert!(
            ratio >= 1.5,
            "two-level speedup {ratio:.2}× misses the 1.5× gate \
             (flat {:.6}s vs hier {:.6}s)",
            f.t_step,
            h.t_step
        );
        assert!(h.percent() > f.percent());
    }

    #[test]
    fn updates_per_sec_matches_paper_order() {
        // §7.3.1: 10.4 batch updates/sec for ResNet50 under gossip
        let w = Workload::resnet50_p100();
        let e = step_time(Schedule::Gossip, &w, 128, &ib(), 0);
        let ups = e.updates_per_sec();
        assert!((9.0..=11.0).contains(&ups), "ups={ups}");
    }
}
