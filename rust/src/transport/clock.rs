//! Clock abstraction for the fabric: wall time vs. virtual time.
//!
//! * [`ClockMode::Wall`] — the default.  Message arrival instants are
//!   real [`Instant`]s; blocking waits sleep; timings are measured with
//!   the OS clock.  Non-deterministic (thread scheduling, machine load)
//!   but physically real — the mode the cross-thread overlap tests use.
//! * [`ClockMode::Virtual`] — discrete-event simulated time.  Each rank
//!   owns a logical clock (u64 nanoseconds) advanced by (a) explicit
//!   compute charges ([`Endpoint::advance`](super::Endpoint::advance),
//!   driven by the calibrated [`Workload`](crate::sim::Workload) model)
//!   and (b) message arrival instants on blocking receives.  Nothing
//!   sleeps and no condvar timeout is involved in the time accounting,
//!   so a run's timing metrics are **bit-reproducible** across
//!   executions and independent of host speed — this is what lets the
//!   Fig 10/11/17 and Table 7 benches sweep p = 128/256/1024 in seconds
//!   of wall time.
//!
//! ## Determinism argument (virtual mode)
//! A message's arrival instant is `sender_clock_at_send + nominal cost`
//! (the α–β model with the noise term disabled — see
//! [`CostModel::nominal`](super::CostModel::nominal)).  Sender clocks
//! advance only through deterministic charges, channels are FIFO, and a
//! receiver's exposed wait is computed arithmetically as
//! `max(0, arrival − receiver_now)` — never measured.  OS scheduling can
//! reorder *wall-clock* interleavings, but every virtual-time quantity
//! (step seconds, exposed wait, message counts, delivered payload order
//! per channel) is a pure function of the run configuration and seed.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ClockMode {
    /// Real time: arrival instants are `Instant`s, waits sleep.
    Wall,
    /// Deterministic discrete-event time: per-rank logical clocks.
    Virtual,
}

/// Per-rank logical clocks (nanosecond ticks) for [`ClockMode::Virtual`].
///
/// Only the owning rank advances its own clock, and only the owning rank
/// reads it on its hot paths, so `Relaxed` ordering suffices; the store
/// is atomic only so `Fabric` can stay `Sync` without a lock.
pub struct Clock {
    mode: ClockMode,
    vnow: Vec<AtomicU64>,
}

impl Clock {
    pub fn new(mode: ClockMode, ranks: usize) -> Clock {
        Clock {
            mode,
            vnow: (0..ranks).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    pub fn mode(&self) -> ClockMode {
        self.mode
    }

    pub fn is_virtual(&self) -> bool {
        self.mode == ClockMode::Virtual
    }

    /// This rank's current virtual time in nanoseconds (0 in wall mode).
    pub fn now_ns(&self, rank: usize) -> u64 {
        self.vnow[rank].load(Ordering::Relaxed)
    }

    /// Charge `delta_ns` of simulated time to `rank`.
    pub fn advance_ns(&self, rank: usize, delta_ns: u64) {
        self.vnow[rank].fetch_add(delta_ns, Ordering::Relaxed);
    }

    /// Move `rank`'s clock forward to at least `t_ns` (monotonic).
    pub fn advance_to_ns(&self, rank: usize, t_ns: u64) {
        self.vnow[rank].fetch_max(t_ns, Ordering::Relaxed);
    }

    pub fn secs_to_ns(secs: f64) -> u64 {
        (secs * 1e9).round() as u64
    }

    pub fn ns_to_secs(ns: u64) -> f64 {
        ns as f64 * 1e-9
    }
}

/// Opaque timestamp for step/exposed-wait accounting under either clock
/// mode; produced by [`Endpoint::mark`](super::Endpoint::mark) and
/// consumed by `Endpoint::elapsed` / `Endpoint::comm_wait_since` /
/// `Endpoint::comm_hidden_since`.
#[derive(Clone, Copy, Debug)]
pub struct TimeMark {
    pub(crate) wall: Instant,
    pub(crate) virt_ns: u64,
    pub(crate) wait_ns: u64,
    /// Snapshot of the rank's hidden-communication counter (wire time
    /// that elapsed under compute rather than being exposed as wait) —
    /// the other half of the overlap ledger.
    pub(crate) hidden_ns: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_clock_advances_monotonically() {
        let c = Clock::new(ClockMode::Virtual, 2);
        assert_eq!(c.now_ns(0), 0);
        c.advance_ns(0, 500);
        c.advance_ns(0, 250);
        assert_eq!(c.now_ns(0), 750);
        assert_eq!(c.now_ns(1), 0, "clocks are per-rank");
        c.advance_to_ns(0, 600); // already past: no-op
        assert_eq!(c.now_ns(0), 750);
        c.advance_to_ns(0, 1_000);
        assert_eq!(c.now_ns(0), 1_000);
    }

    #[test]
    fn seconds_nanos_roundtrip() {
        assert_eq!(Clock::secs_to_ns(1.5e-3), 1_500_000);
        assert_eq!(Clock::ns_to_secs(2_000_000_000), 2.0);
        assert_eq!(Clock::secs_to_ns(0.0), 0);
    }
}
