//! Accounting layer: MPI-like request handles, clocks and the overlap
//! ledger over a pluggable [`Link`].
//!
//! Historically this module *was* the in-process fabric; the delivery
//! mechanics now live in the link layer ([`super::link`]) and this
//! module keeps everything about **time and measurement**: which clock
//! the fabric runs under, how a message's wire time is split into
//! hidden vs exposed communication, and the per-rank traffic counters.
//! The public API (`Fabric`/`Endpoint`/`SendReq`/`RecvReq`) is
//! unchanged, so collectives and coordinator code is untouched by the
//! split, and the default construction paths ([`Fabric::new`],
//! [`Fabric::new_virtual`]) still build the in-process link with
//! bit-identical timing behaviour.
//!
//! Visibility time: a message sent at time t with simulated cost c
//! becomes matchable at `t + c` (see [`super::simnet`]).  `RecvReq::test`
//! returns false before that instant; `wait` blocks out the remainder.
//! This makes *overlap* physically real: a rank that computes past the
//! delivery instant observes zero exposed communication time.
//!
//! The fabric runs under one of two clocks (see [`super::clock`]):
//!
//! * **Wall** (default, [`Fabric::new`]) — arrival instants are real
//!   [`Instant`]s; `wait` sleeps out the simulated wire time; exposed
//!   wait is measured with the OS clock.  The only mode a real-network
//!   link ([`super::tcp::TcpLink`]) supports.
//! * **Virtual** ([`Fabric::new_virtual`]) — arrival instants are
//!   logical nanoseconds on the sender's per-rank clock; `test` compares
//!   logical instants; `wait` never sleeps on simulated time — it blocks
//!   only until the payload is *queued* (an atomic link park, no
//!   timeout), then jumps the receiver's clock to the arrival instant
//!   and records `max(0, arrival − now)` as exposed wait.  All timing
//!   quantities are deterministic (see the determinism argument in
//!   [`super::clock`]).

use super::clock::{Clock, ClockMode, TimeMark};
use super::link::{InprocLink, Key, Link, Stamp};
use super::simnet::{CostModel, HierCostModel};
use super::Tag;
use crate::codec::{Codec, Payload};
use crate::pool::BufferPool;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Per-rank traffic counters — the data behind the Table-1
/// communication-complexity assertions and the EXPERIMENTS.md imbalance
/// histograms.  `recv_wait_ns` is the rank's *exposed* communication
/// time: wall-clock blocked time in wall mode, simulated
/// `arrival − now` in virtual mode.
#[derive(Default)]
pub struct Counters {
    pub msgs_sent: AtomicU64,
    pub bytes_sent: AtomicU64,
    pub msgs_recv: AtomicU64,
    pub recv_wait_ns: AtomicU64,
    /// Wire time this rank never paid for as blocking wait — per
    /// received message, `(arrival − send) − exposed`, clamped at 0.
    /// Together with `recv_wait_ns` this splits every received message's
    /// wire time into hidden vs exposed, giving the per-rank
    /// `overlap_frac` metric (the §5.1 overlap the layer-wise pipeline
    /// exists to win).  "Hidden" counts wire time overlapped with
    /// anything that wasn't *this* message's wait — compute, or a
    /// blocking wait on another message (two waits overlapping each
    /// other cost the rank only once, so the second message's covered
    /// wire time is genuinely free); `recv_wait_ns` remains exactly the
    /// total blocking time the rank paid.
    pub comm_hidden_ns: AtomicU64,
}

/// The interconnect a run sees: a [`Link`] (delivery) + a cost model +
/// a clock + per-rank counters (accounting).  On a multi-process link
/// only the local rank's counters and clock are meaningful; each
/// process reports its own and the launcher merges them.
pub struct Fabric {
    link: Arc<dyn Link>,
    pub cost: CostModel,
    /// Optional two-tier topology-aware cost model.  When set, message
    /// stamps are charged by (src, dst) group locality instead of the
    /// flat `cost` model (docs/topology.md); `cost` still covers any
    /// path that has no destination in scope.
    hier: Option<HierCostModel>,
    counters: Vec<Counters>,
    clock: Clock,
    /// Wire codec for payload-kind tags on the auto-encode path
    /// ([`Endpoint::isend`]); the traffic counters and the α–β stamps
    /// always charge *compressed* bytes ([`Payload::wire_bytes`]).
    codec: Codec,
    /// Shared payload-buffer pool: every send/receive hot path draws
    /// from (and recycles into) these shelves, so steady-state training
    /// performs zero payload allocations per step (docs/perf.md).  Also
    /// handed to the link ([`Link::attach_pool`]) so TCP I/O threads
    /// cycle frame buffers through the same shelves.
    pool: Arc<BufferPool>,
}

impl Fabric {
    /// Wall-clock in-process fabric (the default; real sleeps, measured
    /// waits).
    pub fn new(p: usize, cost: CostModel) -> Arc<Fabric> {
        Fabric::with_clock(p, cost, ClockMode::Wall)
    }

    /// Virtual-clock in-process fabric: deterministic discrete-event
    /// time.  Message costs use [`CostModel::nominal`] (the noise term
    /// is skipped — its RNG draw order would depend on thread
    /// scheduling).
    pub fn new_virtual(p: usize, cost: CostModel) -> Arc<Fabric> {
        Fabric::with_clock(p, cost, ClockMode::Virtual)
    }

    pub fn with_clock(p: usize, cost: CostModel, mode: ClockMode) -> Arc<Fabric> {
        Fabric::with_link(Arc::new(InprocLink::new(p)), cost, mode)
    }

    /// In-process fabric with an explicit wire codec (`with_clock`
    /// defaults to the bit-parity [`Codec::F32`]).
    pub fn with_clock_codec(
        p: usize,
        cost: CostModel,
        mode: ClockMode,
        codec: Codec,
    ) -> Arc<Fabric> {
        Fabric::with_link_codec(Arc::new(InprocLink::new(p)), cost, mode, codec)
    }

    /// Accounting layer over an arbitrary link — the factory the TCP
    /// runner uses.  Panics if the link cannot carry the requested
    /// clock mode (real-network links are wall-clock only: their
    /// arrival stamps are made of receiver-side `Instant`s).
    pub fn with_link(link: Arc<dyn Link>, cost: CostModel, mode: ClockMode) -> Arc<Fabric> {
        Fabric::with_link_codec(link, cost, mode, Codec::F32)
    }

    /// [`with_link`](Self::with_link) with an explicit wire codec.
    pub fn with_link_codec(
        link: Arc<dyn Link>,
        cost: CostModel,
        mode: ClockMode,
        codec: Codec,
    ) -> Arc<Fabric> {
        Fabric::with_link_codec_hier(link, cost, mode, codec, None)
    }

    /// The fully general factory: [`with_link_codec`](Self::with_link_codec)
    /// plus an optional two-tier [`HierCostModel`] charging messages by
    /// (src, dst) host-group locality.
    pub fn with_link_codec_hier(
        link: Arc<dyn Link>,
        cost: CostModel,
        mode: ClockMode,
        codec: Codec,
        hier: Option<HierCostModel>,
    ) -> Arc<Fabric> {
        assert!(
            mode == ClockMode::Wall || link.supports_virtual(),
            "this link is wall-clock only (virtual stamps cannot cross it)"
        );
        let p = link.size();
        let pool = Arc::new(BufferPool::new());
        link.attach_pool(&pool);
        Arc::new(Fabric {
            link,
            cost,
            hier,
            counters: (0..p).map(|_| Counters::default()).collect(),
            clock: Clock::new(mode, p),
            codec,
            pool,
        })
    }

    /// The fabric's wire codec.
    pub fn codec(&self) -> Codec {
        self.codec
    }

    /// The fabric's shared payload-buffer pool (allocation-counting
    /// hook included — [`BufferPool::stats`]).
    pub fn pool(&self) -> &Arc<BufferPool> {
        &self.pool
    }

    pub fn size(&self) -> usize {
        self.link.size()
    }

    pub fn clock(&self) -> &Clock {
        &self.clock
    }

    pub fn endpoint(self: &Arc<Self>, rank: usize) -> Endpoint {
        assert!(rank < self.size());
        Endpoint {
            fabric: Arc::clone(self),
            rank,
        }
    }

    pub fn counters(&self, rank: usize) -> &Counters {
        &self.counters[rank]
    }

    /// Total messages sent across all ranks (for complexity assertions).
    /// On a multi-process link this covers the local ranks only.
    pub fn total_msgs(&self) -> u64 {
        self.counters
            .iter()
            .map(|c| c.msgs_sent.load(Ordering::Relaxed))
            .sum()
    }

    pub fn reset_counters(&self) {
        for c in &self.counters {
            c.msgs_sent.store(0, Ordering::Relaxed);
            c.bytes_sent.store(0, Ordering::Relaxed);
            c.msgs_recv.store(0, Ordering::Relaxed);
            c.recv_wait_ns.store(0, Ordering::Relaxed);
            c.comm_hidden_ns.store(0, Ordering::Relaxed);
        }
    }

    /// Messages accepted by the link but never harvested — the
    /// fabric-drain invariant: a finished run must leave this at zero,
    /// or leaked `isend`/`irecv` pairs would silently accumulate
    /// payloads (and skew a reused fabric's accounting).  A
    /// real-network link also counts frames still in its writer queues
    /// (call [`quiesce`](Self::quiesce) first so only true leaks
    /// remain).
    pub fn in_flight(&self) -> usize {
        self.link.in_flight()
    }

    /// Wire bytes accepted by the link but never harvested — the byte
    /// half of the drain invariant (see [`in_flight`](Self::in_flight)).
    pub fn in_flight_bytes(&self) -> usize {
        self.link.in_flight_bytes()
    }

    /// End-of-run link barrier for `rank` (flush sends, ingest peer
    /// streams to EOF), bounded by `timeout`: a peer that never closes
    /// its stream surfaces a typed [`QuiesceError`](super::QuiesceError)
    /// naming it instead of hanging the barrier forever.  No-op
    /// (`Ok`) on the in-process link.  See [`Link::quiesce`].
    pub fn quiesce(
        &self,
        rank: usize,
        timeout: Option<std::time::Duration>,
    ) -> Result<(), super::QuiesceError> {
        self.link.quiesce(rank, timeout)
    }
}

/// One rank's handle onto the fabric.
#[derive(Clone)]
pub struct Endpoint {
    fabric: Arc<Fabric>,
    rank: usize,
}

/// Non-blocking send handle.  Sends are buffered-eager (as in MPI eager
/// protocol for our message sizes relative to the simulated rendezvous
/// threshold): completion is immediate once enqueued.
pub struct SendReq {
    done: bool,
}

impl SendReq {
    pub fn test(&mut self) -> bool {
        self.done = true;
        true
    }
    pub fn wait(mut self) {
        self.test();
    }
}

/// Non-blocking receive handle.  Harvest methods come in pairs: the
/// historical `Vec<f32>` forms decode at harvest (so existing callers
/// — collectives, PS aggregation, the shuffle ring — are untouched by
/// the codec seam), and the `_payload` forms hand back the encoded
/// [`Payload`] for receivers that decode sparsely (gossip mixing).
pub struct RecvReq {
    fabric: Arc<Fabric>,
    rank: usize,
    key: Key,
    data: Option<Payload>,
}

impl RecvReq {
    /// Non-blocking poll (MPI_Test): true once the message is delivered
    /// *and* its arrival instant has passed on this rank's clock.  A
    /// message harvested by `test` exposed no wait, so its entire wire
    /// time is credited as hidden communication.
    pub fn test(&mut self) -> bool {
        if self.data.is_some() {
            return true;
        }
        let link = &self.fabric.link;
        let Some(stamp) = link.peek(self.rank, self.key) else {
            return false;
        };
        let wire_ns = match stamp {
            Stamp::Wall { sent, at } => {
                if Instant::now() < at {
                    return false;
                }
                (at - sent).as_nanos() as u64
            }
            Stamp::Virt { sent_ns, at_ns } => {
                if self.fabric.clock.now_ns(self.rank) < at_ns {
                    return false;
                }
                at_ns - sent_ns
            }
        };
        // single consumer per rank: the peeked front is still the front
        let (_, data) = link.pop(self.rank, self.key).expect("front vanished");
        self.data = Some(data);
        let c = &self.fabric.counters[self.rank];
        c.msgs_recv.fetch_add(1, Ordering::Relaxed);
        c.comm_hidden_ns.fetch_add(wire_ns, Ordering::Relaxed);
        true
    }

    /// Raw non-blocking harvest: pop the message as soon as it is
    /// *queued* — even if its arrival instant lies in this rank's
    /// logical future — returning `(payload, sent_ns, arrival_ns)` and
    /// counting it in `msgs_recv`, but leaving the rank clock and the
    /// exposed/hidden wire-time ledger untouched.  This is the hook for
    /// the collective engine's modeled comm-progress thread
    /// ([`crate::collectives::IAllreduce`]), which advances its own comm
    /// clock from the stamps and settles the ledger only when the main
    /// thread harvests the whole collective.  On a wall fabric the
    /// stamps degenerate to `(0, wire_ns)`.
    pub fn test_raw(&mut self) -> Option<(Vec<f32>, u64, u64)> {
        let pool = Arc::clone(&self.fabric.pool);
        self.test_raw_payload()
            .map(|(p, sent_ns, at_ns)| (p.decode_pooled(&pool), sent_ns, at_ns))
    }

    /// [`test_raw`](Self::test_raw) without the decode: the payload
    /// comes back still encoded.
    pub fn test_raw_payload(&mut self) -> Option<(Payload, u64, u64)> {
        if let Some(d) = self.data.take() {
            // already harvested by a normal test(): ledger settled
            // there, but the real stamps are gone — a virtual-mode
            // caller mixing accounted and raw harvests on one request
            // would feed zeros into a comm clock, which no caller does
            debug_assert!(
                !self.fabric.clock.is_virtual(),
                "raw harvest after an accounted test() on a virtual fabric"
            );
            return Some((d, 0, 0));
        }
        let (stamp, data) = self.fabric.link.pop(self.rank, self.key)?;
        self.fabric.counters[self.rank]
            .msgs_recv
            .fetch_add(1, Ordering::Relaxed);
        Some(match stamp {
            Stamp::Virt { sent_ns, at_ns } => (data, sent_ns, at_ns),
            Stamp::Wall { sent, at } => (data, 0, (at - sent).as_nanos() as u64),
        })
    }

    /// Blocking counterpart of [`test_raw`](Self::test_raw): parks on
    /// the link until the payload is queued, then pops it without any
    /// clock or ledger accounting.  Also used for end-of-run cleanup
    /// drains (e.g. the sample-shuffle ring) that happen after the last
    /// recorded step and must not perturb the timing metrics.  The park
    /// is atomic with respect to enqueue (no lost wake-ups), so no
    /// timeout poll is needed in either clock mode.
    pub fn wait_raw(self) -> (Vec<f32>, u64, u64) {
        let pool = Arc::clone(&self.fabric.pool);
        let (p, sent_ns, at_ns) = self.wait_raw_payload();
        (p.decode_pooled(&pool), sent_ns, at_ns)
    }

    /// [`wait_raw`](Self::wait_raw) without the decode.  The untimed
    /// `park` doubles as the rank scheduler's yield point when the link
    /// is a [`SchedLink`](super::SchedLink).
    pub fn wait_raw_payload(mut self) -> (Payload, u64, u64) {
        loop {
            if let Some(hit) = self.test_raw_payload() {
                return hit;
            }
            self.fabric.link.park(self.rank, self.key, None);
        }
    }

    /// Blocking wait (MPI_Wait); returns the decoded payload and
    /// records the exposed communication time in
    /// `Counters::recv_wait_ns`.  The decode is pooled (bit-identical
    /// values; encoded frame bytes recycle to the fabric pool).
    pub fn wait(self) -> Vec<f32> {
        let pool = Arc::clone(&self.fabric.pool);
        self.wait_payload().decode_pooled(&pool)
    }

    /// [`wait`](Self::wait) without the decode: full clock/ledger
    /// accounting, payload handed back still encoded (the gossip mixer
    /// applies TopK payloads sparsely instead of densifying them).
    pub fn wait_payload(mut self) -> Payload {
        if let Some(d) = self.data.take() {
            return d;
        }
        match self.fabric.clock.mode() {
            ClockMode::Wall => self.wait_wall(),
            ClockMode::Virtual => self.wait_virtual(),
        }
    }

    /// Wall mode: sleep out the simulated wire time; measure the blocked
    /// interval with the OS clock.
    fn wait_wall(self) -> Payload {
        let t0 = Instant::now();
        let link = &self.fabric.link;
        loop {
            match link.peek(self.rank, self.key) {
                Some(Stamp::Wall { sent, at }) => {
                    let now = Instant::now();
                    if now < at {
                        // queued but not yet "arrived": sleep out the
                        // simulated wire time
                        std::thread::sleep(at - now);
                        continue;
                    }
                    let (_, data) =
                        link.pop(self.rank, self.key).expect("front vanished");
                    let c = &self.fabric.counters[self.rank];
                    c.msgs_recv.fetch_add(1, Ordering::Relaxed);
                    let exposed = t0.elapsed().as_nanos() as u64;
                    let wire = (at - sent).as_nanos() as u64;
                    c.recv_wait_ns.fetch_add(exposed, Ordering::Relaxed);
                    c.comm_hidden_ns
                        .fetch_add(wire.saturating_sub(exposed), Ordering::Relaxed);
                    return data;
                }
                Some(Stamp::Virt { .. }) => {
                    unreachable!("virtual stamp on wall fabric")
                }
                None => link.park(self.rank, self.key, None),
            }
        }
    }

    /// Virtual mode: block (atomic park, no timeout) only until the
    /// payload is queued, then jump this rank's clock to the arrival
    /// instant; the exposed wait is computed, never measured.
    ///
    /// The `park` below is the cooperative yield seam: when the fabric
    /// link is wrapped in a [`SchedLink`](super::SchedLink), parking
    /// suspends this rank's coroutine and releases its worker thread
    /// instead of blocking on the condvar (see `docs/perf.md`, "rank
    /// scheduler").  The loop shape is unchanged either way — a wake
    /// re-polls `pop`, so spurious wakes are harmless.
    fn wait_virtual(self) -> Payload {
        let link = &self.fabric.link;
        loop {
            if let Some((stamp, data)) = link.pop(self.rank, self.key) {
                let (sent_ns, at_ns) = match stamp {
                    Stamp::Virt { sent_ns, at_ns } => (sent_ns, at_ns),
                    Stamp::Wall { .. } => {
                        unreachable!("wall stamp on virtual fabric")
                    }
                };
                let clock = &self.fabric.clock;
                let exposed = at_ns.saturating_sub(clock.now_ns(self.rank));
                clock.advance_to_ns(self.rank, at_ns);
                let c = &self.fabric.counters[self.rank];
                c.msgs_recv.fetch_add(1, Ordering::Relaxed);
                c.recv_wait_ns.fetch_add(exposed, Ordering::Relaxed);
                c.comm_hidden_ns.fetch_add(
                    (at_ns - sent_ns).saturating_sub(exposed),
                    Ordering::Relaxed,
                );
                return data;
            }
            link.park(self.rank, self.key, None);
        }
    }
}

impl Endpoint {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn size(&self) -> usize {
        self.fabric.size()
    }

    pub fn fabric(&self) -> &Arc<Fabric> {
        &self.fabric
    }

    /// The fabric's shared payload-buffer pool — the hot send paths
    /// draw their copies here ([`BufferPool::copy_f32`]) and consumers
    /// return spent buffers.
    pub fn pool(&self) -> &Arc<BufferPool> {
        self.fabric.pool()
    }

    /// Charge `secs` of modeled compute time to this rank's virtual
    /// clock.  No-op on a wall-clock fabric, where compute takes real
    /// time.  The coordinator calls this once per step with the
    /// calibrated [`Workload`](crate::sim::Workload) compute cost — this
    /// is the window asynchronous exchange overlaps with.
    pub fn advance(&self, secs: f64) {
        if self.fabric.clock.is_virtual() {
            self.fabric
                .clock
                .advance_ns(self.rank, Clock::secs_to_ns(secs));
        }
    }

    /// Opaque timestamp for step / exposed-wait accounting that works
    /// under either clock mode.
    pub fn mark(&self) -> TimeMark {
        let c = &self.fabric.counters[self.rank];
        TimeMark {
            wall: Instant::now(),
            virt_ns: self.fabric.clock.now_ns(self.rank),
            wait_ns: c.recv_wait_ns.load(Ordering::Relaxed),
            hidden_ns: c.comm_hidden_ns.load(Ordering::Relaxed),
        }
    }

    /// Seconds elapsed since `m` on this rank's active clock (wall
    /// seconds, or simulated seconds in virtual mode).
    pub fn elapsed(&self, m: &TimeMark) -> f64 {
        match self.fabric.clock.mode() {
            ClockMode::Wall => m.wall.elapsed().as_secs_f64(),
            ClockMode::Virtual => {
                Clock::ns_to_secs(self.fabric.clock.now_ns(self.rank) - m.virt_ns)
            }
        }
    }

    /// Exposed communication wait since `m`.  Wall mode measures the
    /// real elapsed interval (call it tightly around a blocking drain);
    /// virtual mode reads the transport's deterministic exposed-wait
    /// counter delta, so unrelated work between the marks is excluded.
    pub fn comm_wait_since(&self, m: &TimeMark) -> f64 {
        match self.fabric.clock.mode() {
            ClockMode::Wall => m.wall.elapsed().as_secs_f64(),
            ClockMode::Virtual => {
                let now = self.fabric.counters[self.rank]
                    .recv_wait_ns
                    .load(Ordering::Relaxed);
                Clock::ns_to_secs(now - m.wait_ns)
            }
        }
    }

    /// Hidden communication accumulated since `m`: wire time of received
    /// messages that elapsed under this rank's compute instead of being
    /// exposed as blocking wait.  `comm_hidden / (comm_hidden +
    /// comm_wait)` over a run is the rank's overlap fraction.
    pub fn comm_hidden_since(&self, m: &TimeMark) -> f64 {
        let now = self.fabric.counters[self.rank]
            .comm_hidden_ns
            .load(Ordering::Relaxed);
        Clock::ns_to_secs(now - m.hidden_ns)
    }

    /// Non-blocking send (MPI_Isend).  The payload is moved into the
    /// destination mailbox with its send + simulated arrival instants —
    /// under the layer-wise pipeline the sender's clock sits at the
    /// layer's grad-ready instant, so the arrival stamp is
    /// `grad_ready + α + M·β` exactly as in the closed-form simulator.
    pub fn isend(&self, dst: usize, tag: Tag, data: Vec<f32>) -> SendReq {
        let send_ns = self.fabric.clock.now_ns(self.rank);
        self.isend_at(dst, tag, data, send_ns)
    }

    /// Non-blocking send stamped at an explicit logical instant
    /// (virtual mode).  The collective engine's modeled comm-progress
    /// thread posts round k+1's send at round k's *arrival* instant,
    /// which may lie ahead of this rank's main clock while later
    /// compute slices are still being charged — `isend` would stamp the
    /// main clock and break that timeline.  Wall mode ignores `send_ns`
    /// and stamps the real now.
    pub fn isend_at(&self, dst: usize, tag: Tag, data: Vec<f32>, send_ns: u64) -> SendReq {
        // codec auto path: payload-kind tags (model/reduce/layer/bcast)
        // are encoded with the fabric's stateless codec; bookkeeping
        // channels (samples/labels/ctrl) always ride dense f32 — class
        // labels and shuffled sample rows must cross bit-exact.
        let payload = if tag.is_payload_kind() {
            self.fabric
                .codec
                .encode_stateless_pooled(data, &self.fabric.pool)
        } else {
            Payload::F32(data)
        };
        self.isend_payload_at(dst, tag, payload, send_ns)
    }

    /// Send an already-encoded payload (the coordinator's [`Encoder`]
    /// (crate::codec::Encoder) sites — TopK with error feedback).  The
    /// payload is never re-encoded; the stamp and the traffic counters
    /// charge its *compressed* wire bytes.
    pub fn isend_payload(&self, dst: usize, tag: Tag, payload: Payload) -> SendReq {
        let send_ns = self.fabric.clock.now_ns(self.rank);
        self.isend_payload_at(dst, tag, payload, send_ns)
    }

    /// [`isend_payload`](Self::isend_payload) stamped at an explicit
    /// logical instant — see [`isend_at`](Self::isend_at).
    pub fn isend_payload_at(
        &self,
        dst: usize,
        tag: Tag,
        payload: Payload,
        send_ns: u64,
    ) -> SendReq {
        let bytes = payload.wire_bytes();
        let stamp = match self.fabric.clock.mode() {
            ClockMode::Wall => {
                let delay = match &self.fabric.hier {
                    Some(h) => h.message_time(self.rank, dst, bytes),
                    None => self.fabric.cost.message_time(bytes),
                };
                let sent = Instant::now();
                Stamp::Wall {
                    sent,
                    at: sent + Duration::from_secs_f64(delay),
                }
            }
            ClockMode::Virtual => {
                let secs = match &self.fabric.hier {
                    Some(h) => h.nominal(self.rank, dst, bytes),
                    None => self.fabric.cost.nominal(bytes),
                };
                let cost = Clock::secs_to_ns(secs);
                Stamp::Virt {
                    sent_ns: send_ns,
                    at_ns: send_ns + cost,
                }
            }
        };
        let c = &self.fabric.counters[self.rank];
        c.msgs_sent.fetch_add(1, Ordering::Relaxed);
        c.bytes_sent.fetch_add(bytes as u64, Ordering::Relaxed);
        self.fabric.link.enqueue(self.rank, dst, tag, stamp, payload);
        SendReq { done: false }
    }

    /// Non-blocking receive (MPI_Irecv) for a message from `src` on `tag`.
    pub fn irecv(&self, src: usize, tag: Tag) -> RecvReq {
        RecvReq {
            fabric: Arc::clone(&self.fabric),
            rank: self.rank,
            key: (src, tag),
            data: None,
        }
    }

    /// Blocking convenience: send and forget.
    pub fn send(&self, dst: usize, tag: Tag, data: Vec<f32>) {
        self.isend(dst, tag, data).wait();
    }

    /// Blocking convenience: receive.
    pub fn recv(&self, src: usize, tag: Tag) -> Vec<f32> {
        self.irecv(src, tag).wait()
    }

    /// MPI_Testall over receive handles: one progress pass, true if all
    /// completed.
    pub fn test_all(reqs: &mut [RecvReq]) -> bool {
        reqs.iter_mut().all(|r| r.test())
    }

    /// MPI_Waitall: drain all receives, returning payloads in order.
    pub fn wait_all(reqs: Vec<RecvReq>) -> Vec<Vec<f32>> {
        reqs.into_iter().map(|r| r.wait()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::deadline_poll;
    use std::thread;

    #[test]
    fn send_recv_roundtrip() {
        let f = Fabric::new(2, CostModel::zero());
        let a = f.endpoint(0);
        let b = f.endpoint(1);
        a.send(1, Tag::MODEL, vec![1.0, 2.0, 3.0]);
        assert_eq!(b.recv(0, Tag::MODEL), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn fifo_per_channel() {
        let f = Fabric::new(2, CostModel::zero());
        let a = f.endpoint(0);
        let b = f.endpoint(1);
        for i in 0..10 {
            a.send(1, Tag::MODEL, vec![i as f32]);
        }
        for i in 0..10 {
            assert_eq!(b.recv(0, Tag::MODEL)[0], i as f32);
        }
    }

    #[test]
    fn tags_do_not_cross() {
        let f = Fabric::new(2, CostModel::zero());
        let a = f.endpoint(0);
        let b = f.endpoint(1);
        a.send(1, Tag::layer(1), vec![1.0]);
        a.send(1, Tag::layer(0), vec![0.0]);
        assert_eq!(b.recv(0, Tag::layer(0))[0], 0.0);
        assert_eq!(b.recv(0, Tag::layer(1))[0], 1.0);
    }

    #[test]
    fn irecv_test_is_nonblocking() {
        let f = Fabric::new(2, CostModel::zero());
        let b = f.endpoint(1);
        let mut r = b.irecv(0, Tag::MODEL);
        assert!(!r.test()); // nothing sent yet
        f.endpoint(0).send(1, Tag::MODEL, vec![9.0]);
        // with zero cost the message is visible as soon as it is
        // enqueued; poll with a deadline, not a fixed spin count
        deadline_poll("message visible to test()", || r.test().then_some(()));
    }

    #[test]
    fn simulated_latency_delays_visibility() {
        let f = Fabric::new(2, CostModel::new(20e-3, 0.0, 0.0, 0));
        let a = f.endpoint(0);
        let b = f.endpoint(1);
        a.isend(1, Tag::MODEL, vec![1.0]);
        let mut r = b.irecv(0, Tag::MODEL);
        assert!(!r.test(), "visible before alpha elapsed");
        let t0 = Instant::now();
        let _ = r.wait();
        assert!(
            t0.elapsed() >= Duration::from_millis(15),
            "wait returned too early: {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn overlap_hides_latency() {
        // compute longer than the wire time => exposed wait ~ 0
        let f = Fabric::new(2, CostModel::new(10e-3, 0.0, 0.0, 0));
        let a = f.endpoint(0);
        let b = f.endpoint(1);
        a.isend(1, Tag::MODEL, vec![1.0]);
        std::thread::sleep(Duration::from_millis(15)); // "compute"
        let t0 = Instant::now();
        let _ = b.recv(0, Tag::MODEL);
        assert!(
            t0.elapsed() < Duration::from_millis(5),
            "exposed {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn cross_thread_ring() {
        let p = 8;
        let f = Fabric::new(p, CostModel::zero());
        let mut handles = Vec::new();
        for r in 0..p {
            let ep = f.endpoint(r);
            handles.push(thread::spawn(move || {
                let next = (r + 1) % p;
                let prev = (r + p - 1) % p;
                ep.isend(next, Tag::SAMPLES, vec![r as f32]);
                let got = ep.recv(prev, Tag::SAMPLES);
                assert_eq!(got[0], prev as f32);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn counters_track_traffic() {
        let f = Fabric::new(2, CostModel::zero());
        let a = f.endpoint(0);
        a.send(1, Tag::MODEL, vec![0.0; 256]);
        assert_eq!(f.counters(0).msgs_sent.load(Ordering::Relaxed), 1);
        assert_eq!(f.counters(0).bytes_sent.load(Ordering::Relaxed), 1024);
        let _ = f.endpoint(1).recv(0, Tag::MODEL);
        assert_eq!(f.counters(1).msgs_recv.load(Ordering::Relaxed), 1);
        f.reset_counters();
        assert_eq!(f.total_msgs(), 0);
    }

    #[test]
    fn wait_all_orders_payloads() {
        let f = Fabric::new(3, CostModel::zero());
        let c = f.endpoint(2);
        f.endpoint(0).send(2, Tag::REDUCE, vec![10.0]);
        f.endpoint(1).send(2, Tag::REDUCE, vec![20.0]);
        let reqs = vec![c.irecv(0, Tag::REDUCE), c.irecv(1, Tag::REDUCE)];
        let got = Endpoint::wait_all(reqs);
        assert_eq!(got[0][0], 10.0);
        assert_eq!(got[1][0], 20.0);
    }

    // ---- virtual-clock semantics ---------------------------------------

    #[test]
    fn virtual_visibility_follows_logical_time() {
        let f = Fabric::new_virtual(2, CostModel::new(10e-3, 0.0, 0.0, 0));
        let a = f.endpoint(0);
        let b = f.endpoint(1);
        a.isend(1, Tag::MODEL, vec![1.0]);
        let mut r = b.irecv(0, Tag::MODEL);
        assert!(!r.test(), "receiver clock at 0 < arrival at 10ms");
        b.advance(5e-3);
        assert!(!r.test(), "5ms < 10ms arrival");
        b.advance(5e-3);
        assert!(r.test(), "arrival instant reached on the logical clock");
    }

    #[test]
    fn virtual_wait_jumps_clock_and_accounts_exposed_time() {
        // noise_frac > 0 must be ignored (nominal cost) for determinism
        let f = Fabric::new_virtual(2, CostModel::new(10e-3, 0.0, 0.5, 7));
        f.endpoint(0).isend(1, Tag::MODEL, vec![1.0]);
        let b = f.endpoint(1);
        let m = b.mark();
        let _ = b.recv(0, Tag::MODEL);
        assert_eq!(f.clock().now_ns(1), 10_000_000, "clock jumped to arrival");
        assert_eq!(
            f.counters(1).recv_wait_ns.load(Ordering::Relaxed),
            10_000_000,
            "exposed wait is exactly the nominal wire time"
        );
        assert!((b.comm_wait_since(&m) - 10e-3).abs() < 1e-12);
        assert!((b.elapsed(&m) - 10e-3).abs() < 1e-12);
        // fully exposed wait: nothing was hidden under compute
        assert_eq!(f.counters(1).comm_hidden_ns.load(Ordering::Relaxed), 0);
        assert_eq!(b.comm_hidden_since(&m), 0.0);
    }

    #[test]
    fn virtual_overlap_hides_wire_time() {
        let f = Fabric::new_virtual(2, CostModel::new(10e-3, 0.0, 0.0, 0));
        f.endpoint(0).isend(1, Tag::MODEL, vec![1.0]);
        let b = f.endpoint(1);
        let m = b.mark();
        b.advance(20e-3); // "compute" past the arrival instant
        let _ = b.recv(0, Tag::MODEL);
        assert_eq!(f.counters(1).recv_wait_ns.load(Ordering::Relaxed), 0);
        assert_eq!(f.clock().now_ns(1), 20_000_000, "clock not rewound");
        // the whole 10 ms wire time was hidden under the compute charge
        assert_eq!(
            f.counters(1).comm_hidden_ns.load(Ordering::Relaxed),
            10_000_000
        );
        assert!((b.comm_hidden_since(&m) - 10e-3).abs() < 1e-12);
    }

    #[test]
    fn virtual_partial_overlap_splits_wire_time() {
        // 10 ms wire, 4 ms of compute: 4 ms hidden + 6 ms exposed
        let f = Fabric::new_virtual(2, CostModel::new(10e-3, 0.0, 0.0, 0));
        f.endpoint(0).isend(1, Tag::MODEL, vec![1.0]);
        let b = f.endpoint(1);
        b.advance(4e-3);
        let _ = b.recv(0, Tag::MODEL);
        let c = f.counters(1);
        assert_eq!(c.recv_wait_ns.load(Ordering::Relaxed), 6_000_000);
        assert_eq!(c.comm_hidden_ns.load(Ordering::Relaxed), 4_000_000);
    }

    #[test]
    fn test_harvest_credits_full_wire_as_hidden() {
        let f = Fabric::new_virtual(2, CostModel::new(5e-3, 0.0, 0.0, 0));
        f.endpoint(0).isend(1, Tag::MODEL, vec![1.0]);
        let b = f.endpoint(1);
        let mut r = b.irecv(0, Tag::MODEL);
        b.advance(8e-3);
        assert!(r.test());
        let c = f.counters(1);
        assert_eq!(c.recv_wait_ns.load(Ordering::Relaxed), 0);
        assert_eq!(c.comm_hidden_ns.load(Ordering::Relaxed), 5_000_000);
    }

    #[test]
    fn virtual_wait_blocks_until_queued_cross_thread() {
        // no condvar timeout: the virtual wait must still wake when the
        // sender (another thread) enqueues the payload
        let f = Fabric::new_virtual(2, CostModel::new(1e-3, 0.0, 0.0, 0));
        let b = f.endpoint(1);
        let a = f.endpoint(0);
        let h = thread::spawn(move || b.recv(0, Tag::MODEL));
        thread::sleep(Duration::from_millis(20));
        a.advance(3e-3);
        a.isend(1, Tag::MODEL, vec![7.0]);
        let got = h.join().unwrap();
        assert_eq!(got, vec![7.0]);
        // arrival = sender now (3ms) + alpha (1ms)
        assert_eq!(f.clock().now_ns(1), 4_000_000);
    }

    #[test]
    fn raw_harvest_skips_clock_and_ledger() {
        // test_raw pops a message whose arrival lies in the logical
        // future, returns its stamps, and leaves clock + ledger alone
        let f = Fabric::new_virtual(2, CostModel::new(10e-3, 0.0, 0.0, 0));
        let a = f.endpoint(0);
        a.advance(2e-3);
        a.isend(1, Tag::MODEL, vec![1.0]);
        let b = f.endpoint(1);
        let mut r = b.irecv(0, Tag::MODEL);
        // queued-not-arrived: a normal test() would refuse it
        let (data, sent_ns, at_ns) = deadline_poll("raw harvest", || r.test_raw());
        assert_eq!(data, vec![1.0]);
        assert_eq!(sent_ns, 2_000_000);
        assert_eq!(at_ns, 12_000_000);
        assert_eq!(f.clock().now_ns(1), 0, "receiver clock untouched");
        let c = f.counters(1);
        assert_eq!(c.recv_wait_ns.load(Ordering::Relaxed), 0);
        assert_eq!(c.comm_hidden_ns.load(Ordering::Relaxed), 0);
        assert_eq!(c.msgs_recv.load(Ordering::Relaxed), 1);
        assert_eq!(f.in_flight(), 0);
    }

    #[test]
    fn wait_raw_blocks_until_queued_only() {
        let f = Fabric::new_virtual(2, CostModel::new(5e-3, 0.0, 0.0, 0));
        let b = f.endpoint(1);
        let h = thread::spawn(move || b.irecv(0, Tag::MODEL).wait_raw());
        thread::sleep(Duration::from_millis(10));
        f.endpoint(0).isend(1, Tag::MODEL, vec![3.0]);
        let (data, sent_ns, at_ns) = h.join().unwrap();
        assert_eq!(data, vec![3.0]);
        assert_eq!((sent_ns, at_ns), (0, 5_000_000));
        assert_eq!(f.clock().now_ns(1), 0, "no clock jump on raw wait");
        assert_eq!(f.counters(1).recv_wait_ns.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn isend_at_stamps_explicit_instant() {
        let f = Fabric::new_virtual(2, CostModel::new(1e-3, 0.0, 0.0, 0));
        let a = f.endpoint(0);
        // sender main clock is 0, but the comm thread posts at 7 ms
        a.isend_at(1, Tag::MODEL, vec![9.0], 7_000_000);
        let mut r = f.endpoint(1).irecv(0, Tag::MODEL);
        let (_, sent_ns, at_ns) = deadline_poll("raw harvest", || r.test_raw());
        assert_eq!((sent_ns, at_ns), (7_000_000, 8_000_000));
    }

    #[test]
    fn in_flight_counts_queued_messages() {
        let f = Fabric::new(3, CostModel::zero());
        f.endpoint(0).isend(1, Tag::MODEL, vec![0.0]);
        f.endpoint(0).isend(2, Tag::MODEL, vec![0.0]);
        assert_eq!(f.in_flight(), 2);
        let _ = f.endpoint(1).recv(0, Tag::MODEL);
        assert_eq!(f.in_flight(), 1);
        let _ = f.endpoint(2).recv(0, Tag::MODEL);
        assert_eq!(f.in_flight(), 0);
    }

    #[test]
    fn virtual_send_stamps_use_sender_clock() {
        let f = Fabric::new_virtual(3, CostModel::new(2e-3, 0.0, 0.0, 0));
        let a = f.endpoint(0);
        a.advance(10e-3);
        a.isend(2, Tag::MODEL, vec![0.5]);
        f.endpoint(1).isend(2, Tag::SAMPLES, vec![1.5]); // sender clock 0
        let c = f.endpoint(2);
        let _ = c.recv(1, Tag::SAMPLES);
        assert_eq!(f.clock().now_ns(2), 2_000_000);
        let _ = c.recv(0, Tag::MODEL);
        assert_eq!(f.clock().now_ns(2), 12_000_000);
        let w = f.counters(2).recv_wait_ns.load(Ordering::Relaxed);
        assert_eq!(w, 12_000_000, "2ms + 10ms exposed across the two recvs");
    }

    #[test]
    fn with_link_refuses_virtual_on_wall_only_links() {
        struct WallOnly;
        impl Link for WallOnly {
            fn size(&self) -> usize {
                1
            }
            fn enqueue(&self, _: usize, _: usize, _: Tag, _: Stamp, _: Payload) {}
            fn peek(&self, _: usize, _: Key) -> Option<Stamp> {
                None
            }
            fn pop(&self, _: usize, _: Key) -> Option<(Stamp, Payload)> {
                None
            }
            fn park(&self, _: usize, _: Key, _: Option<Duration>) {}
            fn in_flight(&self) -> usize {
                0
            }
            fn in_flight_bytes(&self) -> usize {
                0
            }
            fn supports_virtual(&self) -> bool {
                false
            }
        }
        let r = std::panic::catch_unwind(|| {
            Fabric::with_link(Arc::new(WallOnly), CostModel::zero(), ClockMode::Virtual)
        });
        assert!(r.is_err(), "virtual clock over a wall-only link must panic");
        let f = Fabric::with_link(Arc::new(WallOnly), CostModel::zero(), ClockMode::Wall);
        assert_eq!(f.size(), 1);
    }

    // ---- wire-codec charging ------------------------------------------

    #[test]
    fn compressed_payloads_charge_compressed_bytes_and_time() {
        // beta-only cost: arrival instant is proportional to wire bytes,
        // so bf16 halves both the counter and the stamped wire time
        let cost = CostModel::new(0.0, 1e-3 / 4.0, 0.0, 0); // 1 ms per f32
        let f = Fabric::with_clock_codec(2, cost, ClockMode::Virtual, Codec::Bf16);
        f.endpoint(0).isend(1, Tag::MODEL, vec![1.0; 4]);
        assert_eq!(
            f.counters(0).bytes_sent.load(Ordering::Relaxed),
            8,
            "4 elements x 2 bytes on the wire"
        );
        let b = f.endpoint(1);
        let got = b.recv(0, Tag::MODEL);
        assert_eq!(got, vec![1.0; 4], "1.0 is bf16-exact");
        assert_eq!(
            f.clock().now_ns(1),
            2_000_000,
            "wire time halved vs the 4 ms an f32 payload would cost"
        );
    }

    #[test]
    fn bookkeeping_tags_stay_dense_under_compression() {
        let f = Fabric::with_clock_codec(
            2,
            CostModel::zero(),
            ClockMode::Wall,
            Codec::Int8,
        );
        let odd = vec![0.1234567_f32, -9.87654e-3];
        f.endpoint(0).send(1, Tag::SAMPLES, odd.clone());
        assert_eq!(
            f.counters(0).bytes_sent.load(Ordering::Relaxed),
            8,
            "samples ride dense f32"
        );
        assert_eq!(f.endpoint(1).recv(0, Tag::SAMPLES), odd, "bit-exact");
    }

    #[test]
    fn isend_payload_charges_wire_bytes_without_reencoding() {
        let f = Fabric::new(2, CostModel::zero());
        let p = Payload::Bytes {
            enc: crate::codec::Encoding::TopK,
            n: 32,
            bytes: {
                let mut b = 5u32.to_le_bytes().to_vec();
                b.extend_from_slice(&2.5f32.to_le_bytes());
                b
            },
        };
        assert_eq!(f.in_flight_bytes(), 0);
        f.endpoint(0).isend_payload(1, Tag::layer(0), p);
        assert_eq!(f.counters(0).bytes_sent.load(Ordering::Relaxed), 8);
        assert_eq!(f.in_flight(), 1);
        assert_eq!(f.in_flight_bytes(), 8, "compressed bytes on the gauge");
        let (got, _, _) = f.endpoint(1).irecv(0, Tag::layer(0)).wait_raw_payload();
        assert_eq!(got.wire_bytes(), 8);
        let dense = got.decode();
        assert_eq!(dense.len(), 32);
        assert_eq!(dense[5], 2.5);
        assert_eq!(f.in_flight_bytes(), 0);
    }

    #[test]
    fn hier_cost_charges_by_group_locality() {
        use super::super::simnet::{GroupMap, HierCostModel};
        // 4 ranks, 2 hosts of 2: intra 1 ms, inter 100 ms (alpha-only)
        let hier = HierCostModel::new(
            CostModel::new(1e-3, 0.0, 0.0, 0),
            CostModel::new(100e-3, 0.0, 0.0, 0),
            GroupMap::new(4, 2),
        );
        let f = Fabric::with_link_codec_hier(
            Arc::new(InprocLink::new(4)),
            CostModel::zero(),
            ClockMode::Virtual,
            Codec::F32,
            Some(hier),
        );
        f.endpoint(0).isend(1, Tag::MODEL, vec![1.0]); // same host
        f.endpoint(0).isend(2, Tag::MODEL, vec![1.0]); // cross host
        let _ = f.endpoint(1).recv(0, Tag::MODEL);
        let _ = f.endpoint(2).recv(0, Tag::MODEL);
        assert_eq!(f.clock().now_ns(1), 1_000_000, "intra tier");
        assert_eq!(f.clock().now_ns(2), 100_000_000, "inter tier");
    }

    #[test]
    fn default_codec_is_bit_parity_f32() {
        let f = Fabric::new_virtual(2, CostModel::zero());
        assert_eq!(f.codec(), Codec::F32);
        let data = vec![0.1, -0.2, 0.3];
        f.endpoint(0).isend(1, Tag::MODEL, data.clone());
        let got = f.endpoint(1).irecv(0, Tag::MODEL).wait_payload();
        match got {
            Payload::F32(v) => assert_eq!(v, data, "no encode round-trip"),
            other => panic!("f32 codec produced {other:?}"),
        }
    }
}
