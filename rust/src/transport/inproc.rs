//! In-process fabric: one mailbox per rank, real buffers, MPI-like
//! non-blocking request handles.
//!
//! Visibility time: a message sent at wall-time t with simulated cost c
//! becomes matchable at `t + c` (see [`super::simnet`]).  `RecvReq::test`
//! returns false before that instant; `wait` sleeps out the remainder.
//! This makes *overlap* physically real: a rank that computes past the
//! delivery instant observes zero exposed communication time.

use super::simnet::CostModel;
use super::Tag;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

type Key = (usize, Tag); // (src, tag)

struct Mailbox {
    queues: HashMap<Key, VecDeque<(Instant, Vec<f32>)>>,
}

struct RankSlot {
    mbox: Mutex<Mailbox>,
    cv: Condvar,
}

/// Per-rank traffic counters — the data behind the Table-1
/// communication-complexity assertions and the EXPERIMENTS.md imbalance
/// histograms.
#[derive(Default)]
pub struct Counters {
    pub msgs_sent: AtomicU64,
    pub bytes_sent: AtomicU64,
    pub msgs_recv: AtomicU64,
    pub recv_wait_ns: AtomicU64,
}

/// The shared interconnect: `p` mailboxes + a cost model.
pub struct Fabric {
    slots: Vec<RankSlot>,
    pub cost: CostModel,
    counters: Vec<Counters>,
    #[allow(dead_code)]
    epoch: Instant,
}

impl Fabric {
    pub fn new(p: usize, cost: CostModel) -> Arc<Fabric> {
        Arc::new(Fabric {
            slots: (0..p)
                .map(|_| RankSlot {
                    mbox: Mutex::new(Mailbox {
                        queues: HashMap::new(),
                    }),
                    cv: Condvar::new(),
                })
                .collect(),
            cost,
            counters: (0..p).map(|_| Counters::default()).collect(),
            epoch: Instant::now(),
        })
    }

    pub fn size(&self) -> usize {
        self.slots.len()
    }

    pub fn endpoint(self: &Arc<Self>, rank: usize) -> Endpoint {
        assert!(rank < self.size());
        Endpoint {
            fabric: Arc::clone(self),
            rank,
        }
    }

    pub fn counters(&self, rank: usize) -> &Counters {
        &self.counters[rank]
    }

    /// Total messages sent across all ranks (for complexity assertions).
    pub fn total_msgs(&self) -> u64 {
        self.counters
            .iter()
            .map(|c| c.msgs_sent.load(Ordering::Relaxed))
            .sum()
    }

    pub fn reset_counters(&self) {
        for c in &self.counters {
            c.msgs_sent.store(0, Ordering::Relaxed);
            c.bytes_sent.store(0, Ordering::Relaxed);
            c.msgs_recv.store(0, Ordering::Relaxed);
            c.recv_wait_ns.store(0, Ordering::Relaxed);
        }
    }
}

/// One rank's handle onto the fabric.
#[derive(Clone)]
pub struct Endpoint {
    fabric: Arc<Fabric>,
    rank: usize,
}

/// Non-blocking send handle.  Sends are buffered-eager (as in MPI eager
/// protocol for our message sizes relative to the simulated rendezvous
/// threshold): completion is immediate once enqueued.
pub struct SendReq {
    done: bool,
}

impl SendReq {
    pub fn test(&mut self) -> bool {
        self.done = true;
        true
    }
    pub fn wait(mut self) {
        self.test();
    }
}

/// Non-blocking receive handle.
pub struct RecvReq {
    fabric: Arc<Fabric>,
    rank: usize,
    key: Key,
    data: Option<Vec<f32>>,
}

impl RecvReq {
    /// Non-blocking poll (MPI_Test): true once the message is delivered
    /// *and* its simulated arrival instant has passed.
    pub fn test(&mut self) -> bool {
        if self.data.is_some() {
            return true;
        }
        let slot = &self.fabric.slots[self.rank];
        let mut mb = slot.mbox.lock().unwrap();
        if let Some(q) = mb.queues.get_mut(&self.key) {
            if let Some((at, _)) = q.front() {
                if Instant::now() >= *at {
                    let (_, data) = q.pop_front().unwrap();
                    self.data = Some(data);
                    self.fabric.counters[self.rank]
                        .msgs_recv
                        .fetch_add(1, Ordering::Relaxed);
                    return true;
                }
            }
        }
        false
    }

    /// Blocking wait (MPI_Wait); returns the payload.  Records the time
    /// spent blocked as *exposed communication time*.
    pub fn wait(mut self) -> Vec<f32> {
        if let Some(d) = self.data.take() {
            return d;
        }
        let t0 = Instant::now();
        let slot = &self.fabric.slots[self.rank];
        let mut mb = slot.mbox.lock().unwrap();
        loop {
            let now = Instant::now();
            let deliver_at = mb
                .queues
                .get(&self.key)
                .and_then(|q| q.front())
                .map(|(at, _)| *at);
            match deliver_at {
                Some(at) if now >= at => {
                    let (_, data) = mb
                        .queues
                        .get_mut(&self.key)
                        .unwrap()
                        .pop_front()
                        .unwrap();
                    let c = &self.fabric.counters[self.rank];
                    c.msgs_recv.fetch_add(1, Ordering::Relaxed);
                    c.recv_wait_ns.fetch_add(
                        t0.elapsed().as_nanos() as u64,
                        Ordering::Relaxed,
                    );
                    return data;
                }
                Some(at) => {
                    // message queued but not yet "arrived": sleep out the
                    // simulated wire time without holding the lock
                    drop(mb);
                    std::thread::sleep(at - now);
                    mb = slot.mbox.lock().unwrap();
                }
                None => {
                    let (g, _) = slot
                        .cv
                        .wait_timeout(mb, Duration::from_millis(50))
                        .unwrap();
                    mb = g;
                }
            }
        }
    }
}

impl Endpoint {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn size(&self) -> usize {
        self.fabric.size()
    }

    pub fn fabric(&self) -> &Arc<Fabric> {
        &self.fabric
    }

    /// Non-blocking send (MPI_Isend).  The payload is moved into the
    /// destination mailbox with its simulated arrival instant.
    pub fn isend(&self, dst: usize, tag: Tag, data: Vec<f32>) -> SendReq {
        let bytes = data.len() * 4;
        let delay = self.fabric.cost.message_time(bytes);
        let at = Instant::now() + Duration::from_secs_f64(delay);
        let c = &self.fabric.counters[self.rank];
        c.msgs_sent.fetch_add(1, Ordering::Relaxed);
        c.bytes_sent.fetch_add(bytes as u64, Ordering::Relaxed);
        let slot = &self.fabric.slots[dst];
        {
            let mut mb = slot.mbox.lock().unwrap();
            mb.queues
                .entry((self.rank, tag))
                .or_default()
                .push_back((at, data));
        }
        slot.cv.notify_all();
        SendReq { done: false }
    }

    /// Non-blocking receive (MPI_Irecv) for a message from `src` on `tag`.
    pub fn irecv(&self, src: usize, tag: Tag) -> RecvReq {
        RecvReq {
            fabric: Arc::clone(&self.fabric),
            rank: self.rank,
            key: (src, tag),
            data: None,
        }
    }

    /// Blocking convenience: send and forget.
    pub fn send(&self, dst: usize, tag: Tag, data: Vec<f32>) {
        self.isend(dst, tag, data).wait();
    }

    /// Blocking convenience: receive.
    pub fn recv(&self, src: usize, tag: Tag) -> Vec<f32> {
        self.irecv(src, tag).wait()
    }

    /// MPI_Testall over receive handles: one progress pass, true if all
    /// completed.
    pub fn test_all(reqs: &mut [RecvReq]) -> bool {
        reqs.iter_mut().all(|r| r.test())
    }

    /// MPI_Waitall: drain all receives, returning payloads in order.
    pub fn wait_all(reqs: Vec<RecvReq>) -> Vec<Vec<f32>> {
        reqs.into_iter().map(|r| r.wait()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn send_recv_roundtrip() {
        let f = Fabric::new(2, CostModel::zero());
        let a = f.endpoint(0);
        let b = f.endpoint(1);
        a.send(1, Tag::MODEL, vec![1.0, 2.0, 3.0]);
        assert_eq!(b.recv(0, Tag::MODEL), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn fifo_per_channel() {
        let f = Fabric::new(2, CostModel::zero());
        let a = f.endpoint(0);
        let b = f.endpoint(1);
        for i in 0..10 {
            a.send(1, Tag::MODEL, vec![i as f32]);
        }
        for i in 0..10 {
            assert_eq!(b.recv(0, Tag::MODEL)[0], i as f32);
        }
    }

    #[test]
    fn tags_do_not_cross() {
        let f = Fabric::new(2, CostModel::zero());
        let a = f.endpoint(0);
        let b = f.endpoint(1);
        a.send(1, Tag::layer(1), vec![1.0]);
        a.send(1, Tag::layer(0), vec![0.0]);
        assert_eq!(b.recv(0, Tag::layer(0))[0], 0.0);
        assert_eq!(b.recv(0, Tag::layer(1))[0], 1.0);
    }

    #[test]
    fn irecv_test_is_nonblocking() {
        let f = Fabric::new(2, CostModel::zero());
        let b = f.endpoint(1);
        let mut r = b.irecv(0, Tag::MODEL);
        assert!(!r.test()); // nothing sent yet
        f.endpoint(0).send(1, Tag::MODEL, vec![9.0]);
        // spin-poll (eventual completion)
        let mut ok = false;
        for _ in 0..1000 {
            if r.test() {
                ok = true;
                break;
            }
        }
        assert!(ok);
    }

    #[test]
    fn simulated_latency_delays_visibility() {
        let f = Fabric::new(2, CostModel::new(20e-3, 0.0, 0.0, 0));
        let a = f.endpoint(0);
        let b = f.endpoint(1);
        a.isend(1, Tag::MODEL, vec![1.0]);
        let mut r = b.irecv(0, Tag::MODEL);
        assert!(!r.test(), "visible before alpha elapsed");
        let t0 = Instant::now();
        let _ = r.wait();
        assert!(
            t0.elapsed() >= Duration::from_millis(15),
            "wait returned too early: {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn overlap_hides_latency() {
        // compute longer than the wire time => exposed wait ~ 0
        let f = Fabric::new(2, CostModel::new(10e-3, 0.0, 0.0, 0));
        let a = f.endpoint(0);
        let b = f.endpoint(1);
        a.isend(1, Tag::MODEL, vec![1.0]);
        std::thread::sleep(Duration::from_millis(15)); // "compute"
        let t0 = Instant::now();
        let _ = b.recv(0, Tag::MODEL);
        assert!(
            t0.elapsed() < Duration::from_millis(5),
            "exposed {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn cross_thread_ring() {
        let p = 8;
        let f = Fabric::new(p, CostModel::zero());
        let mut handles = Vec::new();
        for r in 0..p {
            let ep = f.endpoint(r);
            handles.push(thread::spawn(move || {
                let next = (r + 1) % p;
                let prev = (r + p - 1) % p;
                ep.isend(next, Tag::SAMPLES, vec![r as f32]);
                let got = ep.recv(prev, Tag::SAMPLES);
                assert_eq!(got[0], prev as f32);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn counters_track_traffic() {
        let f = Fabric::new(2, CostModel::zero());
        let a = f.endpoint(0);
        a.send(1, Tag::MODEL, vec![0.0; 256]);
        assert_eq!(f.counters(0).msgs_sent.load(Ordering::Relaxed), 1);
        assert_eq!(f.counters(0).bytes_sent.load(Ordering::Relaxed), 1024);
        let _ = f.endpoint(1).recv(0, Tag::MODEL);
        assert_eq!(f.counters(1).msgs_recv.load(Ordering::Relaxed), 1);
        f.reset_counters();
        assert_eq!(f.total_msgs(), 0);
    }

    #[test]
    fn wait_all_orders_payloads() {
        let f = Fabric::new(3, CostModel::zero());
        let c = f.endpoint(2);
        f.endpoint(0).send(2, Tag::REDUCE, vec![10.0]);
        f.endpoint(1).send(2, Tag::REDUCE, vec![20.0]);
        let reqs = vec![c.irecv(0, Tag::REDUCE), c.irecv(1, Tag::REDUCE)];
        let got = Endpoint::wait_all(reqs);
        assert_eq!(got[0][0], 10.0);
        assert_eq!(got[1][0], 20.0);
    }
}
