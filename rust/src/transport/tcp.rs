//! TCP link: one OS process per rank, std-only sockets.
//!
//! This is the real-network implementation of the [`Link`] seam: the
//! same gossip/AGD/PS code that runs threads-as-ranks over
//! [`InprocLink`](super::link::InprocLink) runs as `p` processes
//! exchanging length-prefixed frames over loopback or a LAN.  Wall
//! clock only — arrival stamps are receiver-side [`Instant`]s, which
//! cannot cross a process boundary, so `--virtual-clock` is rejected up
//! front (see `docs/transport.md` for the full wire format and failure
//! modes).
//!
//! ## Topology
//!
//! Full mesh, two sockets per pair, each used in one direction: rank R
//! listens on `peers[R]` and dials every other rank, using the dialed
//! stream exclusively for R→S frames.  Accepted streams are read-only.
//! This needs no pair-ordering protocol and keeps every stream
//! single-writer/single-reader.
//!
//! ## Handshake
//!
//! The dialer opens with 16 bytes, all little-endian u32:
//! `[magic][version][p][src_rank]`.  The listener validates each field
//! and answers one u32 status ([`HS_OK`] or a rejection code), then
//! closes on rejection.  Both sides turn a rejection into an
//! `establish` error — a misconfigured launch (wrong `p`, mixed binary
//! versions) fails loudly instead of hanging (regression-tested in
//! `tests/tcp_transport.rs`).
//!
//! ## Frames
//!
//! `[payload_bytes: u32 LE][tag: u64 LE][enc: u8][n: u32 LE][payload]`
//! — wire version 2 (docs/wire-codecs.md).  `enc` is the payload's
//! [`Encoding`] byte, `n` its decoded element count, `payload_bytes`
//! the *encoded* (possibly compressed) byte length.  Dense f32
//! payloads are written as raw LE f32s; the source rank is implied by
//! the stream (learned at handshake).
//!
//! ## Delivery & accounting
//!
//! Per peer, a writer thread drains an unbounded channel (so `enqueue`
//! is buffered-eager, like the in-process link) and a reader thread
//! ingests frames into the local [`Mailbox`], stamping arrival as
//! `recv_instant + cost.message_time(bytes)` — the α–β model charges
//! *encoded* bytes on the receiving side, on top of whatever time the
//! real wire took.  Frame payloads are kept as raw bytes in the
//! mailbox (one bulk `read_exact`, no reader-thread conversion) and
//! decoded once, at harvest, by the accounting layer.
//! [`Link::in_flight`] counts local mailbox messages plus frames handed
//! to writers but not yet flushed to the socket (with
//! [`Link::in_flight_bytes`] as its wire-byte companion); after
//! [`Link::quiesce`] (flush + close writers, drain readers to EOF) only
//! genuinely leaked messages remain, which is what lets the
//! fabric-drain invariant extend across processes: the launcher sums
//! each rank's post-quiesce count.
//!
//! ## Reconnect & peer death
//!
//! A writer whose socket breaks mid-run does not take the rank down
//! with it.  It redials the peer with capped exponential backoff
//! ([`reconnect_delay`]: 10 ms doubling to a 320 ms cap, at most
//! [`RECONNECT_MAX_RETRIES`] attempts, each dial bounded by a short
//! deadline) and resends the frame it was carrying on the fresh
//! connection.  The listener side keeps accepting after `establish` —
//! a background acceptor validates re-handshakes and spawns a
//! replacement reader for the new stream.  Two caveats, both tolerable
//! to gossip by construction and documented in
//! docs/fault-tolerance.md: delivery across a reconnect is
//! *at-least-once* (a frame flushed into a dying socket may be resent),
//! and frames may *reorder* across the break (the old reader drains its
//! socket concurrently with the new one).
//!
//! When every redial is exhausted the peer is declared **dead**: the
//! writer marks it in the link's dead-set and then discards everything
//! else queued for it (decrementing the in-flight gauges, so the drain
//! invariant still closes), and later `enqueue`s to that peer are
//! dropped at the door.  Death is an accounting event, not a panic —
//! the membership layer (`membership::Membership`) is what reroutes the
//! survivors.
//!
//! ## Bounded quiesce
//!
//! [`Link::quiesce`] is a cross-rank barrier (every peer must close its
//! write side before our readers see EOF).  With a `timeout` it waits
//! on an io-thread registry instead of blind joins: if the deadline
//! passes it returns a [`QuiesceError`] naming exactly which peer ranks
//! still have a live writer or reader — "rank 3 is dead or hung"
//! instead of a forever-hang.  A timed-out quiesce leaves the threads
//! registered; a later unbounded call can still finish the join.

use super::link::{Key, Link, Mailbox, QuiesceError, Stamp};
use super::simnet::CostModel;
use super::Tag;
use crate::codec::{Encoding, Payload, INT8_CHUNK};
use crate::pool::BufferPool;
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

/// First handshake word — rejects strangers speaking other protocols.
pub const WIRE_MAGIC: u32 = 0x4747_5244; // "GGRD"
/// Wire-format version; bumped on any frame/handshake change.
/// v2: frames carry an encoding byte + element count (wire codecs).
pub const WIRE_VERSION: u32 = 2;

/// Handshake accepted.
pub const HS_OK: u32 = 1;
/// Rejection codes (the dialer surfaces them in its error message).
pub const HS_BAD_MAGIC: u32 = 2;
pub const HS_BAD_VERSION: u32 = 3;
pub const HS_BAD_P: u32 = 4;
pub const HS_BAD_RANK: u32 = 5;

fn hs_explain(code: u32) -> &'static str {
    match code {
        HS_BAD_MAGIC => "bad magic (not a gossipgrad peer?)",
        HS_BAD_VERSION => "wire version mismatch (mixed binaries?)",
        HS_BAD_P => "world-size mismatch (peers lists disagree)",
        HS_BAD_RANK => "bad or duplicate source rank",
        _ => "unknown rejection code",
    }
}

/// Redial attempts before a broken peer is declared dead.
pub const RECONNECT_MAX_RETRIES: usize = 6;
/// Per-attempt dial deadline during a redial (the initial `establish`
/// uses the caller's much larger timeout instead).
const RECONNECT_DIAL_TIMEOUT: Duration = Duration::from_millis(250);

/// Backoff before redial `attempt` (0-based): 10 ms doubling per
/// attempt, capped at 320 ms.
pub fn reconnect_delay(attempt: usize) -> Duration {
    Duration::from_millis((10u64 << attempt.min(5)).min(320))
}

/// What a writer thread's channel carries.
enum Frame {
    Data(Tag, Payload),
    /// Test hook: sever the live connection so the next data frame
    /// exercises the redial path.
    #[cfg(test)]
    Break,
}

type FrameSender = mpsc::Sender<Frame>;
type IoThread = JoinHandle<io::Result<()>>;

fn err(msg: String) -> io::Error {
    io::Error::other(msg)
}

/// What an io thread does, for the quiesce-timeout diagnostic.
#[derive(Clone, Debug)]
enum IoLabel {
    Writer(usize),
    Reader(usize),
    Acceptor,
}

/// Registry of live io threads: every writer/reader/acceptor registers
/// a slot at spawn and marks it done on exit, so a bounded quiesce can
/// wait on "all done" with a deadline and name the stragglers instead
/// of block-joining each handle in turn.
struct IoRegistry {
    slots: Mutex<Vec<IoSlot>>,
    cv: Condvar,
}

struct IoSlot {
    label: IoLabel,
    done: bool,
    handle: Option<IoThread>,
}

impl IoRegistry {
    fn new() -> Arc<IoRegistry> {
        Arc::new(IoRegistry { slots: Mutex::new(Vec::new()), cv: Condvar::new() })
    }

    /// Register a slot and spawn the thread that fills it.  Errors are
    /// reported at failure time (the training thread only sees a closed
    /// channel, so the root cause must not wait to be joined).
    fn spawn<F>(self: &Arc<Self>, label: IoLabel, rank: usize, f: F)
    where
        F: FnOnce() -> io::Result<()> + Send + 'static,
    {
        let idx = {
            let mut slots = self.slots.lock().unwrap();
            slots.push(IoSlot { label: label.clone(), done: false, handle: None });
            slots.len() - 1
        };
        let reg = Arc::clone(self);
        let h = thread::spawn(move || {
            let r = f();
            if let Err(e) = &r {
                eprintln!("tcp link rank {rank}: {label:?} failed: {e}");
            }
            let mut slots = reg.slots.lock().unwrap();
            slots[idx].done = true;
            reg.cv.notify_all();
            r
        });
        // if the thread already finished, the handle lands in a done
        // slot and is simply never joined — it has nothing left to do
        self.slots.lock().unwrap()[idx].handle = Some(h);
    }

    /// Wait until every registered thread (including ones registered
    /// *while waiting*, e.g. readers the acceptor respawns) is done.
    /// `None` waits forever; a passed deadline returns the labels of
    /// the unfinished threads, leaving their handles registered so a
    /// later unbounded wait can still collect them.
    fn wait_all(&self, deadline: Option<Instant>) -> Result<Vec<IoThread>, Vec<IoLabel>> {
        let mut slots = self.slots.lock().unwrap();
        loop {
            if slots.iter().all(|s| s.done) {
                return Ok(slots.iter_mut().filter_map(|s| s.handle.take()).collect());
            }
            match deadline {
                None => slots = self.cv.wait(slots).unwrap(),
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        return Err(slots
                            .iter()
                            .filter(|s| !s.done)
                            .map(|s| s.label.clone())
                            .collect());
                    }
                    slots = self.cv.wait_timeout(slots, d - now).unwrap().0;
                }
            }
        }
    }
}

/// Half-constructed [`TcpLink`]: the listener is bound (so the local
/// port is known — bind to port 0 to let the OS pick one) but no peer
/// connections exist yet.  Two-phase construction lets a launcher or
/// test collect every rank's actual address before any rank dials.
pub struct TcpLinkBuilder {
    listener: TcpListener,
    addr: SocketAddr,
}

impl TcpLinkBuilder {
    pub fn bind(addr: &str) -> io::Result<TcpLinkBuilder> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        Ok(TcpLinkBuilder { listener, addr })
    }

    /// The bound address (with the OS-assigned port resolved).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Connect the full mesh: accept a handshake from every other rank
    /// and dial every other rank, retrying dials until `timeout`.
    /// `peers[rank]` must be this builder's own address; `peers.len()`
    /// is the world size announced in (and checked against) every
    /// handshake.  Errors instead of hanging on any handshake
    /// rejection, duplicate rank, or deadline overrun.  The listener
    /// stays alive afterwards to accept peer *re*-connections (see the
    /// module docs on reconnect).
    pub fn establish(
        self,
        rank: usize,
        peers: &[String],
        cost: CostModel,
        timeout: Duration,
    ) -> io::Result<Arc<TcpLink>> {
        let p = peers.len();
        if rank >= p {
            return Err(err(format!("rank {rank} outside peer list of {p}")));
        }
        let deadline = Instant::now() + timeout;
        // a failed acceptor flips this so the dial-retry loop can abort
        // early instead of spinning to the deadline
        let accept_failed = Arc::new(AtomicBool::new(false));

        let listener = self.listener;
        listener.set_nonblocking(true)?;
        let fail_flag = Arc::clone(&accept_failed);
        let acceptor = thread::spawn(move || {
            let r = accept_peers(&listener, rank, p, deadline);
            if r.is_err() {
                fail_flag.store(true, Ordering::Relaxed);
            }
            // hand the listener back: it outlives establish so the
            // link's background acceptor can serve reconnects
            (r, listener)
        });

        // dial every peer; hold the streams until the acceptor confirms
        let mut outbound: Vec<Option<TcpStream>> = (0..p).map(|_| None).collect();
        let mut dial_err = None;
        'dialing: for (peer, addr) in peers.iter().enumerate() {
            if peer == rank {
                continue;
            }
            match dial_peer(rank, p, peer, addr, deadline, &accept_failed) {
                Ok(s) => outbound[peer] = Some(s),
                Err(e) => {
                    dial_err = Some(e);
                    break 'dialing;
                }
            }
        }
        // always join the acceptor (it exits on success, failure or
        // deadline) so its error — usually the root cause — wins
        let (inbound, listener) = match acceptor.join() {
            Ok((r, l)) => (r, Some(l)),
            Err(_) => (Err(err("acceptor thread panicked".into())), None),
        };
        if let Some(e) = dial_err {
            return match inbound {
                // the peer that rejected our dial also failed our
                // acceptor side; report whichever carries more detail
                Err(ae) => Err(err(format!("{e}; accept side: {ae}"))),
                Ok(_) => Err(e),
            };
        }
        let inbound = inbound?;
        let listener = listener.expect("listener survives a successful accept");

        TcpLink::over_streams(rank, peers.to_vec(), outbound, inbound, cost, listener)
    }
}

/// Accept `p - 1` valid peer handshakes (one per rank) before
/// `deadline`.
///
/// Strangers are tolerated, misconfigured peers are not: a connection
/// that sends nothing (within a capped per-handshake timeout), closes
/// early, or opens with the wrong magic is a **stray** (port scanner,
/// health probe) — it is dropped and accepting continues.  A correct
/// magic with a wrong version / world size / rank is a gossipgrad peer
/// from a broken launch — that errors out the whole establish so the
/// job fails instead of hanging.
fn accept_peers(
    listener: &TcpListener,
    rank: usize,
    p: usize,
    deadline: Instant,
) -> io::Result<Vec<(usize, TcpStream)>> {
    let mut got: Vec<(usize, TcpStream)> = Vec::with_capacity(p - 1);
    let mut seen = vec![false; p];
    while got.len() < p - 1 {
        match listener.accept() {
            Ok((mut s, _)) => {
                s.set_nonblocking(false)?;
                // cap the per-handshake read tightly: a real peer's 16
                // handshake bytes are written right after its connect()
                // returns, so they are already buffered by the time the
                // connection leaves the backlog — while each *silent*
                // stray serializes the accept loop for the full cap, so
                // a generous cap would let a few idle probes exhaust
                // the whole establish deadline
                s.set_read_timeout(Some(
                    remaining(deadline).min(Duration::from_secs(1)),
                ))?;
                let mut hdr = [0u8; 16];
                if s.read_exact(&mut hdr).is_err() {
                    // unreadable handshake: stray connection, drop it
                    continue;
                }
                let (magic, version, their_p, src) = parse_handshake(&hdr);
                if magic != WIRE_MAGIC {
                    // not a gossipgrad peer: answer and keep accepting
                    s.write_all(&HS_BAD_MAGIC.to_le_bytes()).ok();
                    continue;
                }
                let status = if version != WIRE_VERSION {
                    HS_BAD_VERSION
                } else if their_p as usize != p {
                    HS_BAD_P
                } else if src >= p || src == rank || seen[src] {
                    HS_BAD_RANK
                } else {
                    HS_OK
                };
                s.write_all(&status.to_le_bytes())?;
                if status != HS_OK {
                    return Err(err(format!(
                        "rank {rank}: rejected inbound handshake \
                         (version {version} p {their_p} src {src}): {}",
                        hs_explain(status)
                    )));
                }
                s.set_read_timeout(None)?;
                s.set_nodelay(true).ok();
                seen[src] = true;
                got.push((src, s));
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    return Err(err(format!(
                        "rank {rank}: accept timeout — {}/{} peers connected",
                        got.len(),
                        p - 1
                    )));
                }
                thread::sleep(Duration::from_millis(5));
            }
            Err(e) => return Err(e),
        }
    }
    Ok(got)
}

/// Split the 16 handshake bytes into `(magic, version, p, src_rank)`.
fn parse_handshake(hdr: &[u8; 16]) -> (u32, u32, u32, usize) {
    let word = |i: usize| u32::from_le_bytes([hdr[i], hdr[i + 1], hdr[i + 2], hdr[i + 3]]);
    (word(0), word(4), word(8), word(12) as usize)
}

/// Dial one peer with connect-retry until `deadline`, send our
/// handshake and check the ack.
fn dial_peer(
    rank: usize,
    p: usize,
    peer: usize,
    addr: &str,
    deadline: Instant,
    accept_failed: &AtomicBool,
) -> io::Result<TcpStream> {
    let mut stream = loop {
        if accept_failed.load(Ordering::Relaxed) {
            return Err(err(format!(
                "rank {rank}: aborting dial to peer {peer} — accept side failed"
            )));
        }
        match TcpStream::connect(addr) {
            Ok(s) => break s,
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(err(format!(
                        "rank {rank}: dial timeout to peer {peer} at {addr}: {e}"
                    )));
                }
                thread::sleep(Duration::from_millis(25));
            }
        }
    };
    stream.set_nodelay(true).ok();
    let mut hs = [0u8; 16];
    hs[0..4].copy_from_slice(&WIRE_MAGIC.to_le_bytes());
    hs[4..8].copy_from_slice(&WIRE_VERSION.to_le_bytes());
    hs[8..12].copy_from_slice(&(p as u32).to_le_bytes());
    hs[12..16].copy_from_slice(&(rank as u32).to_le_bytes());
    stream.write_all(&hs)?;
    stream.set_read_timeout(Some(remaining(deadline)))?;
    let mut ack = [0u8; 4];
    stream.read_exact(&mut ack).map_err(|e| {
        err(format!(
            "rank {rank}: no handshake ack from peer {peer} at {addr}: {e}"
        ))
    })?;
    let code = u32::from_le_bytes(ack);
    if code != HS_OK {
        return Err(err(format!(
            "rank {rank}: peer {peer} rejected handshake (code {code}): {}",
            hs_explain(code)
        )));
    }
    stream.set_read_timeout(None)?;
    Ok(stream)
}

/// Time left until `deadline`, floored at 1 ms (socket timeouts reject
/// zero durations).
fn remaining(deadline: Instant) -> Duration {
    deadline
        .saturating_duration_since(Instant::now())
        .max(Duration::from_millis(1))
}

/// The established TCP link for one rank: local mailbox + per-peer
/// writer/reader threads + a background reconnect acceptor.  See the
/// module docs for the delivery, reconnect and in-flight accounting
/// model.
pub struct TcpLink {
    rank: usize,
    p: usize,
    mbox: Arc<Mailbox>,
    /// `writers[dst]` feeds dst's writer thread; `None` for self and
    /// after [`quiesce`](Link::quiesce) closed them.
    writers: Mutex<Vec<Option<FrameSender>>>,
    /// Frames handed to writer threads and not yet flushed to a socket.
    unsent: Arc<AtomicUsize>,
    /// Wire bytes of those frames — the byte gauge's writer-queue half.
    unsent_bytes: Arc<AtomicUsize>,
    /// Live io threads (writers, readers, the reconnect acceptor),
    /// waited on by the bounded quiesce.
    io: Arc<IoRegistry>,
    /// Peers whose redial budget is exhausted: enqueues to them are
    /// dropped at the door (see module docs on peer death).
    dead_peers: Arc<Mutex<Vec<bool>>>,
    /// Tells the background acceptor to exit (set by quiesce).
    accept_stop: Arc<AtomicBool>,
    /// The owning fabric's buffer pool, filled in by
    /// [`Link::attach_pool`] after the io threads are already running
    /// (the fabric is built around an established link).  Writers
    /// recycle flushed payload buffers here; readers draw frame buffers
    /// from it.  `None` until attached — threads fall back to fresh
    /// allocations.
    pool: Arc<Mutex<Option<Arc<BufferPool>>>>,
}

/// Everything a writer thread needs to run — and to *redial* when its
/// socket breaks.
struct WriterCtx {
    rank: usize,
    p: usize,
    dst: usize,
    addr: String,
    unsent: Arc<AtomicUsize>,
    unsent_bytes: Arc<AtomicUsize>,
    pool: Arc<Mutex<Option<Arc<BufferPool>>>>,
    dead: Arc<Mutex<Vec<bool>>>,
}

impl TcpLink {
    fn over_streams(
        rank: usize,
        peers: Vec<String>,
        outbound: Vec<Option<TcpStream>>,
        inbound: Vec<(usize, TcpStream)>,
        cost: CostModel,
        listener: TcpListener,
    ) -> io::Result<Arc<TcpLink>> {
        let p = peers.len();
        let mbox = Arc::new(Mailbox::new());
        let unsent = Arc::new(AtomicUsize::new(0));
        let unsent_bytes = Arc::new(AtomicUsize::new(0));
        let pool: Arc<Mutex<Option<Arc<BufferPool>>>> = Arc::new(Mutex::new(None));
        let dead_peers = Arc::new(Mutex::new(vec![false; p]));
        let accept_stop = Arc::new(AtomicBool::new(false));
        let io = IoRegistry::new();
        let mut writers: Vec<Option<FrameSender>> = (0..p).map(|_| None).collect();
        for (dst, stream) in outbound.into_iter().enumerate() {
            let Some(stream) = stream else { continue };
            let (tx, rx) = mpsc::channel::<Frame>();
            let ctx = WriterCtx {
                rank,
                p,
                dst,
                addr: peers[dst].clone(),
                unsent: Arc::clone(&unsent),
                unsent_bytes: Arc::clone(&unsent_bytes),
                pool: Arc::clone(&pool),
                dead: Arc::clone(&dead_peers),
            };
            io.spawn(IoLabel::Writer(dst), rank, move || run_writer(ctx, stream, rx));
            writers[dst] = Some(tx);
        }
        for (src, stream) in inbound {
            let mbox = Arc::clone(&mbox);
            let cost = cost.clone();
            let pool = Arc::clone(&pool);
            io.spawn(IoLabel::Reader(src), rank, move || {
                read_frames(stream, src, &mbox, &cost, &pool)
            });
        }
        {
            let mbox = Arc::clone(&mbox);
            let pool = Arc::clone(&pool);
            let io2 = Arc::clone(&io);
            let stop = Arc::clone(&accept_stop);
            let cost = cost.clone();
            io.spawn(IoLabel::Acceptor, rank, move || {
                run_acceptor(listener, rank, p, mbox, cost, pool, io2, stop)
            });
        }
        Ok(Arc::new(TcpLink {
            rank,
            p,
            mbox,
            writers: Mutex::new(writers),
            unsent,
            unsent_bytes,
            io,
            dead_peers,
            accept_stop,
            pool,
        }))
    }

    /// The local rank this link serves.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Peers declared dead by exhausted redial (ascending ranks).
    pub fn dead_peers(&self) -> Vec<usize> {
        self.dead_peers
            .lock()
            .unwrap()
            .iter()
            .enumerate()
            .filter_map(|(r, &d)| d.then_some(r))
            .collect()
    }

    #[cfg(test)]
    fn stop_acceptor(&self) {
        self.accept_stop.store(true, Ordering::Relaxed);
    }

    /// Test hook: sever the live connection to `dst` (the writer drops
    /// its socket and redials on the next data frame).
    #[cfg(test)]
    fn inject_writer_break(&self, dst: usize) {
        let writers = self.writers.lock().unwrap();
        writers[dst]
            .as_ref()
            .expect("break target still has a live writer")
            .send(Frame::Break)
            .expect("writer channel open");
    }
}

/// Largest frame a reader will accept.  Far above any model this
/// fabric moves (whole ResNet50 ≈ 100 MB), far below a garbage length
/// field's 4 GiB — a desynced stream fails as a protocol error instead
/// of an allocation attempt.
pub const MAX_FRAME_BYTES: usize = 1 << 30;

/// Serialize one frame onto the socket and flush it.
///
/// Per-writer `scratch`, reused across every frame this thread ever
/// sends: a dense payload is bulk-converted to LE bytes here and hits
/// the socket as ONE write_all.  `to_le_bytes` is a move on
/// little-endian targets, so the conversion loop flattens to a copy
/// there and stays correct (byte-swapping) on big-endian ones.
fn write_one(
    w: &mut io::BufWriter<TcpStream>,
    tag: Tag,
    payload: &Payload,
    scratch: &mut Vec<u8>,
) -> io::Result<()> {
    let bytes = payload.wire_bytes();
    w.write_all(&(bytes as u32).to_le_bytes())?;
    w.write_all(&tag.0.to_le_bytes())?;
    w.write_all(&[payload.encoding() as u8])?;
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    match payload {
        Payload::F32(data) => {
            scratch.clear();
            scratch.reserve(4 * data.len());
            for x in data {
                scratch.extend_from_slice(&x.to_le_bytes());
            }
            w.write_all(scratch)?;
        }
        Payload::Bytes { bytes: b, .. } => w.write_all(b)?,
    }
    w.flush()
}

/// Writer thread: serialize frames from the channel onto the socket,
/// redialing the peer on a broken connection (module docs: reconnect).
/// Exits when the sender half is dropped at quiesce.  If the redial
/// budget runs out it marks the peer dead and keeps *discarding*
/// queued frames (decrementing the gauges) until quiesce — so enqueue
/// never races a vanished channel and in-flight still drains to zero.
fn run_writer(
    ctx: WriterCtx,
    first: TcpStream,
    rx: mpsc::Receiver<Frame>,
) -> io::Result<()> {
    let mut w = Some(io::BufWriter::new(first));
    let mut scratch: Vec<u8> = Vec::new();
    loop {
        let frame = match rx.recv() {
            Ok(f) => f,
            Err(_) => break, // all senders dropped: normal quiesce
        };
        let (tag, payload) = match frame {
            Frame::Data(tag, payload) => (tag, payload),
            #[cfg(test)]
            Frame::Break => {
                w = None; // sever: next data frame redials
                continue;
            }
        };
        let bytes = payload.wire_bytes();
        loop {
            if w.is_none() {
                match redial(&ctx) {
                    Some(s) => w = Some(io::BufWriter::new(s)),
                    None => {
                        // redial exhausted: the peer is dead.  Account
                        // for this frame, then discard the rest of the
                        // queue as it arrives.
                        ctx.dead.lock().unwrap()[ctx.dst] = true;
                        eprintln!(
                            "tcp link rank {}: peer {} declared dead after \
                             {RECONNECT_MAX_RETRIES} failed redials",
                            ctx.rank, ctx.dst
                        );
                        discard(&ctx, bytes, payload);
                        discard_until_quiesce(&ctx, &rx);
                        return Ok(());
                    }
                }
            }
            match write_one(w.as_mut().expect("connected"), tag, &payload, &mut scratch) {
                Ok(()) => {
                    // decrement only once the frame is on the socket:
                    // between enqueue and here the message is "in
                    // flight" and must be visible to the drain invariant
                    discard(&ctx, bytes, payload);
                    break;
                }
                Err(e) => {
                    eprintln!(
                        "tcp link rank {}: write to rank {} broke ({e}) — redialing",
                        ctx.rank, ctx.dst
                    );
                    // resend this frame on the fresh connection
                    // (at-least-once across a reconnect; module docs)
                    w = None;
                }
            }
        }
    }
    if let Some(w) = w.as_mut() {
        w.flush()?;
    }
    Ok(())
}

/// Settle one frame's accounting: off the gauges, buffer to the pool.
fn discard(ctx: &WriterCtx, bytes: usize, payload: Payload) {
    ctx.unsent.fetch_sub(1, Ordering::Relaxed);
    ctx.unsent_bytes.fetch_sub(bytes, Ordering::Relaxed);
    if let Some(p) = ctx.pool.lock().unwrap().as_ref() {
        p.recycle(payload);
    }
}

/// Dead-peer tail: drain the channel, discarding every frame, until
/// the senders drop at quiesce.
fn discard_until_quiesce(ctx: &WriterCtx, rx: &mpsc::Receiver<Frame>) {
    while let Ok(f) = rx.recv() {
        match f {
            Frame::Data(_, payload) => {
                let bytes = payload.wire_bytes();
                discard(ctx, bytes, payload);
            }
            #[cfg(test)]
            Frame::Break => {}
        }
    }
}

/// Redial a broken peer: capped exponential backoff, bounded attempts,
/// short per-dial deadline.  `None` means the budget is exhausted and
/// the peer should be declared dead.
fn redial(ctx: &WriterCtx) -> Option<TcpStream> {
    for attempt in 0..RECONNECT_MAX_RETRIES {
        thread::sleep(reconnect_delay(attempt));
        let deadline = Instant::now() + RECONNECT_DIAL_TIMEOUT;
        let never_failed = AtomicBool::new(false);
        match dial_peer(ctx.rank, ctx.p, ctx.dst, &ctx.addr, deadline, &never_failed) {
            Ok(s) => {
                eprintln!(
                    "tcp link rank {}: reconnected to rank {} (attempt {})",
                    ctx.rank,
                    ctx.dst,
                    attempt + 1
                );
                return Some(s);
            }
            Err(_) => continue,
        }
    }
    None
}

/// Background acceptor: after `establish`, keep the listener alive and
/// serve peer *re*-handshakes, spawning a replacement reader for each
/// accepted stream.  Exits when quiesce sets the stop flag.  Unlike
/// `accept_peers`, duplicate ranks are expected (that is the point),
/// and a bad handshake is answered and dropped rather than fatal — the
/// mesh is already up.
#[allow(clippy::too_many_arguments)]
fn run_acceptor(
    listener: TcpListener,
    rank: usize,
    p: usize,
    mbox: Arc<Mailbox>,
    cost: CostModel,
    pool: Arc<Mutex<Option<Arc<BufferPool>>>>,
    io: Arc<IoRegistry>,
    stop: Arc<AtomicBool>,
) -> io::Result<()> {
    // (nonblocking was set by establish; re-assert for safety)
    listener.set_nonblocking(true)?;
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((mut s, _)) => {
                if s.set_nonblocking(false).is_err() {
                    continue;
                }
                s.set_read_timeout(Some(Duration::from_secs(1))).ok();
                let mut hdr = [0u8; 16];
                if s.read_exact(&mut hdr).is_err() {
                    continue; // stray
                }
                let (magic, version, their_p, src) = parse_handshake(&hdr);
                if magic != WIRE_MAGIC {
                    s.write_all(&HS_BAD_MAGIC.to_le_bytes()).ok();
                    continue;
                }
                let status = if version != WIRE_VERSION {
                    HS_BAD_VERSION
                } else if their_p as usize != p {
                    HS_BAD_P
                } else if src >= p || src == rank {
                    HS_BAD_RANK
                } else {
                    HS_OK
                };
                if s.write_all(&status.to_le_bytes()).is_err() || status != HS_OK {
                    continue;
                }
                s.set_read_timeout(None).ok();
                s.set_nodelay(true).ok();
                eprintln!("tcp link rank {rank}: accepted reconnect from rank {src}");
                let mbox = Arc::clone(&mbox);
                let cost = cost.clone();
                let pool = Arc::clone(&pool);
                io.spawn(IoLabel::Reader(src), rank, move || {
                    read_frames(s, src, &mbox, &cost, &pool)
                });
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(10));
            }
            Err(_) => break,
        }
    }
    Ok(())
}

/// Exact encoded length a well-formed frame must carry, or `None` for
/// TopK, whose pair count varies (validated separately: whole 8-byte
/// pairs, at most n of them).
fn expected_frame_bytes(enc: Encoding, n: usize) -> Option<usize> {
    match enc {
        Encoding::F32 => Some(4 * n),
        Encoding::Bf16 => Some(2 * n),
        Encoding::Int8 => Some(n + 4 * n.div_ceil(INT8_CHUNK)),
        Encoding::TopK => None,
    }
}

/// Reader thread: ingest frames from one peer into the local mailbox
/// until the peer closes its write side (EOF).  Arrival is stamped
/// receiver-side: `now + cost.message_time(bytes)` — the simulated α–β
/// cost rides on top of the real socket latency already paid.
fn read_frames(
    stream: TcpStream,
    src: usize,
    mbox: &Mailbox,
    cost: &CostModel,
    pool: &Mutex<Option<Arc<BufferPool>>>,
) -> io::Result<()> {
    let mut r = io::BufReader::new(stream);
    loop {
        let mut len = [0u8; 4];
        match r.read_exact(&mut len) {
            Ok(()) => {}
            Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => return Ok(()),
            // a severed connection (peer's writer broke/redialed) ends
            // this reader like an EOF: a replacement reader owns the
            // new stream, and a mid-frame cut is discarded with the
            // socket (the peer resends the whole frame)
            Err(e)
                if matches!(
                    e.kind(),
                    io::ErrorKind::ConnectionReset | io::ErrorKind::ConnectionAborted
                ) =>
            {
                return Ok(())
            }
            Err(e) => return Err(e),
        }
        let bytes = u32::from_le_bytes(len) as usize;
        // validate before trusting the length with an allocation: a
        // desynced or corrupt stream must be a protocol error, not a
        // silently-truncated payload or a 4 GiB alloc
        if bytes > MAX_FRAME_BYTES {
            return Err(err(format!(
                "frame from rank {src}: payload length {bytes} over {MAX_FRAME_BYTES}"
            )));
        }
        let mut tag = [0u8; 8];
        r.read_exact(&mut tag)?;
        let tag = Tag(u64::from_le_bytes(tag));
        let mut hdr = [0u8; 5];
        r.read_exact(&mut hdr)?;
        let Some(enc) = Encoding::from_u8(hdr[0]) else {
            return Err(err(format!(
                "frame from rank {src}: unknown encoding byte {}",
                hdr[0]
            )));
        };
        let n = u32::from_le_bytes([hdr[1], hdr[2], hdr[3], hdr[4]]);
        let consistent = match expected_frame_bytes(enc, n as usize) {
            Some(want) => bytes == want,
            // TopK: whole (idx u32, val f32) pairs, at most n of them
            None => bytes % 8 == 0 && bytes / 8 <= n as usize,
        };
        if !consistent {
            return Err(err(format!(
                "frame from rank {src}: {bytes} payload bytes inconsistent \
                 with encoding {enc:?} × {n} elements"
            )));
        }
        // one bulk read straight into the buffer the mailbox keeps —
        // decoding happens once, at harvest, in the accounting layer
        // (the old path round-tripped every frame through a second
        // per-chunk f32 conversion here in the reader thread).  The
        // buffer comes from the fabric pool when attached, so harvest's
        // decode-in-place recycles it instead of freeing it.
        let mut payload = match pool.lock().unwrap().as_ref() {
            Some(p) => p.get_u8(bytes),
            None => vec![0u8; bytes],
        };
        r.read_exact(&mut payload)?;
        let now = Instant::now();
        let at = now + Duration::from_secs_f64(cost.message_time(bytes));
        mbox.push(
            (src, tag),
            Stamp::Wall { sent: now, at },
            Payload::Bytes { enc, n, bytes: payload },
        );
    }
}

impl Link for TcpLink {
    fn size(&self) -> usize {
        self.p
    }

    fn enqueue(&self, src: usize, dst: usize, tag: Tag, stamp: Stamp, data: Payload) {
        assert_eq!(
            src, self.rank,
            "tcp link sends only from its local rank"
        );
        if dst == self.rank {
            // loopback: deliver locally with the caller's stamp, exactly
            // like the in-process link
            self.mbox.push((src, tag), stamp, data);
            return;
        }
        if self.dead_peers.lock().unwrap()[dst] {
            // peer declared dead after exhausted redial: drop at the
            // door — survivors route around it through the view
            return;
        }
        // count before handing off so in_flight never under-reports
        self.unsent.fetch_add(1, Ordering::Relaxed);
        self.unsent_bytes.fetch_add(data.wire_bytes(), Ordering::Relaxed);
        let writers = self.writers.lock().unwrap();
        let tx = writers[dst]
            .as_ref()
            .unwrap_or_else(|| panic!("send to rank {dst} after quiesce"));
        tx.send(Frame::Data(tag, data))
            .expect("writer thread terminated early");
    }

    fn peek(&self, rank: usize, key: Key) -> Option<Stamp> {
        debug_assert_eq!(rank, self.rank, "tcp link serves its local rank only");
        self.mbox.peek(key)
    }

    fn pop(&self, rank: usize, key: Key) -> Option<(Stamp, Payload)> {
        debug_assert_eq!(rank, self.rank, "tcp link serves its local rank only");
        self.mbox.pop(key)
    }

    fn park(&self, rank: usize, key: Key, timeout: Option<Duration>) {
        debug_assert_eq!(rank, self.rank, "tcp link serves its local rank only");
        self.mbox.park(key, timeout)
    }

    fn in_flight(&self) -> usize {
        self.mbox.queued() + self.unsent.load(Ordering::Relaxed)
    }

    fn in_flight_bytes(&self) -> usize {
        self.mbox.queued_bytes() + self.unsent_bytes.load(Ordering::Relaxed)
    }

    fn supports_virtual(&self) -> bool {
        false
    }

    fn attach_pool(&self, pool: &Arc<BufferPool>) {
        *self.pool.lock().unwrap() = Some(Arc::clone(pool));
    }

    /// Close this rank's write side (writer threads flush their queues
    /// and drop their sockets, which EOFs the peers' readers), stop the
    /// reconnect acceptor, and wait for every io thread — readers
    /// return once each peer has quiesced in turn.  Afterwards every
    /// frame this process sent is delivered (or charged off against a
    /// dead peer) and every frame peers sent sits in the local mailbox,
    /// so [`in_flight`](Link::in_flight) counts only true leaks.
    ///
    /// This is a **cross-rank barrier**: it blocks until every peer has
    /// also closed its write side, so each rank must call it from its
    /// own thread/process (as the trainer does).  Quiescing several
    /// ranks' links sequentially on one thread would deadlock.
    ///
    /// With a `timeout`, a peer that never closes its side (crashed
    /// hard, hung) surfaces as a [`QuiesceError`] naming the ranks
    /// whose io threads are still live, instead of hanging forever.
    /// The threads stay registered — a later call can finish the wait.
    fn quiesce(&self, rank: usize, timeout: Option<Duration>) -> Result<(), QuiesceError> {
        debug_assert_eq!(rank, self.rank, "tcp link serves its local rank only");
        self.accept_stop.store(true, Ordering::Relaxed);
        for w in self.writers.lock().unwrap().iter_mut() {
            w.take();
        }
        let deadline = timeout.map(|t| Instant::now() + t);
        match self.io.wait_all(deadline) {
            Ok(handles) => {
                for h in handles {
                    // io errors were already reported by the failing
                    // thread itself, at failure time
                    if h.join().is_err() {
                        eprintln!("tcp link rank {}: io thread panicked", self.rank);
                    }
                }
                Ok(())
            }
            Err(labels) => {
                let mut missing: Vec<usize> = labels
                    .iter()
                    .filter_map(|l| match l {
                        IoLabel::Writer(d) => Some(*d),
                        IoLabel::Reader(s) => Some(*s),
                        IoLabel::Acceptor => None,
                    })
                    .collect();
                missing.sort_unstable();
                missing.dedup();
                Err(QuiesceError { rank: self.rank, missing })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build an established p-rank mesh on loopback ephemeral ports.
    fn mesh(p: usize, cost: CostModel) -> Vec<Arc<TcpLink>> {
        let builders: Vec<TcpLinkBuilder> = (0..p)
            .map(|_| TcpLinkBuilder::bind("127.0.0.1:0").unwrap())
            .collect();
        let peers: Vec<String> =
            builders.iter().map(|b| b.local_addr().to_string()).collect();
        let handles: Vec<_> = builders
            .into_iter()
            .enumerate()
            .map(|(rank, b)| {
                let peers = peers.clone();
                let cost = cost.clone();
                thread::spawn(move || {
                    b.establish(rank, &peers, cost, Duration::from_secs(20))
                        .unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    /// Quiesce every link concurrently (it's a cross-rank barrier —
    /// sequential quiesce on one thread would deadlock on reader join).
    fn quiesce_all(links: &[Arc<TcpLink>]) {
        let handles: Vec<_> = links
            .iter()
            .enumerate()
            .map(|(rank, l)| {
                let l = Arc::clone(l);
                thread::spawn(move || l.quiesce(rank, None).unwrap())
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn frames_cross_the_mesh_fifo_per_channel() {
        let links = mesh(3, CostModel::zero());
        for i in 0..5 {
            let t = Instant::now();
            links[0].enqueue(
                0,
                2,
                Tag::MODEL,
                Stamp::Wall { sent: t, at: t },
                Payload::F32(vec![i as f32, 0.5]),
            );
        }
        let key = (0usize, Tag::MODEL);
        for i in 0..5 {
            let (_, data) = crate::util::deadline_poll("tcp frame", || {
                links[2].pop(2, key)
            });
            assert_eq!(data.decode(), vec![i as f32, 0.5], "fifo order per channel");
        }
        quiesce_all(&links);
        for l in &links {
            assert_eq!(l.in_flight(), 0);
            assert_eq!(l.in_flight_bytes(), 0);
        }
    }

    #[test]
    fn compressed_frames_cross_the_wire_intact() {
        let links = mesh(2, CostModel::zero());
        let t = Instant::now();
        // hand-built top-k frame: one pair (idx 3, 2.5) out of n = 8
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&3u32.to_le_bytes());
        bytes.extend_from_slice(&2.5f32.to_le_bytes());
        links[0].enqueue(
            0,
            1,
            Tag::MODEL,
            Stamp::Wall { sent: t, at: t },
            Payload::Bytes { enc: Encoding::TopK, n: 8, bytes },
        );
        let (_, p) = crate::util::deadline_poll("tcp frame", || {
            links[1].pop(1, (0, Tag::MODEL))
        });
        assert_eq!(p.encoding(), Encoding::TopK);
        assert_eq!(p.wire_bytes(), 8, "compressed size survives the wire");
        let mut want = vec![0.0f32; 8];
        want[3] = 2.5;
        assert_eq!(p.decode(), want);
        quiesce_all(&links);
    }

    #[test]
    fn quiesce_surfaces_leaked_messages() {
        let links = mesh(2, CostModel::zero());
        let t = Instant::now();
        links[0].enqueue(
            0,
            1,
            Tag::CTRL,
            Stamp::Wall { sent: t, at: t },
            Payload::F32(vec![1.0]),
        );
        quiesce_all(&links);
        assert_eq!(links[0].in_flight(), 0, "sender side fully flushed");
        assert_eq!(links[0].in_flight_bytes(), 0, "no bytes stuck in writer queues");
        assert_eq!(
            links[1].in_flight(),
            1,
            "unharvested frame must count as in flight after quiesce"
        );
        assert_eq!(
            links[1].in_flight_bytes(),
            4,
            "leaked frame's wire bytes must show in the byte gauge"
        );
    }

    #[test]
    fn loopback_send_delivers_locally() {
        let links = mesh(2, CostModel::zero());
        let t = Instant::now();
        links[0].enqueue(
            0,
            0,
            Tag::MODEL,
            Stamp::Wall { sent: t, at: t },
            Payload::F32(vec![9.0]),
        );
        let (_, data) = links[0].pop(0, (0, Tag::MODEL)).unwrap();
        assert_eq!(data.decode(), vec![9.0]);
        quiesce_all(&links);
    }

    #[test]
    fn reconnect_backoff_schedule_is_capped() {
        let ms: Vec<u64> = (0..8)
            .map(|a| reconnect_delay(a).as_millis() as u64)
            .collect();
        assert_eq!(ms, vec![10, 20, 40, 80, 160, 320, 320, 320]);
    }

    #[test]
    fn quiesce_timeout_names_the_missing_peer() {
        let links = mesh(2, CostModel::zero());
        // rank 1 never quiesces in time: rank 0's reader from 1 stays
        // live, so the bounded wait must name rank 1 instead of hanging
        let e = links[0]
            .quiesce(0, Some(Duration::from_millis(300)))
            .unwrap_err();
        assert_eq!(e.rank, 0);
        assert_eq!(e.missing, vec![1], "the hung peer is named");
        assert!(e.to_string().contains("rank(s) [1]"), "{e}");
        // a later unbounded quiesce (both sides this time) still closes
        quiesce_all(&links);
        for l in &links {
            assert_eq!(l.in_flight(), 0);
        }
    }

    #[test]
    fn writer_reconnects_after_transient_break() {
        let links = mesh(2, CostModel::zero());
        let t = Instant::now();
        let stamp = Stamp::Wall { sent: t, at: t };
        links[0].enqueue(0, 1, Tag::MODEL.round(1), stamp, Payload::F32(vec![1.0]));
        let (_, a) = crate::util::deadline_poll("pre-break frame", || {
            links[1].pop(1, (0, Tag::MODEL.round(1)))
        });
        assert_eq!(a.decode(), vec![1.0]);
        // sever the 0→1 socket, then keep sending: the writer must
        // redial rank 1's live acceptor and deliver on the new stream
        links[0].inject_writer_break(1);
        links[0].enqueue(0, 1, Tag::MODEL.round(2), stamp, Payload::F32(vec![2.0]));
        let (_, b) = crate::util::deadline_poll("post-break frame", || {
            links[1].pop(1, (0, Tag::MODEL.round(2)))
        });
        assert_eq!(b.decode(), vec![2.0], "frame survives the reconnect");
        quiesce_all(&links);
        for l in &links {
            assert_eq!(l.in_flight(), 0);
            assert_eq!(l.in_flight_bytes(), 0);
        }
    }

    #[test]
    fn exhausted_redial_marks_peer_dead_instead_of_panicking() {
        let links = mesh(2, CostModel::zero());
        // kill rank 1's acceptor so every redial is refused, then sever
        // the live 0→1 socket: the writer must burn its retry budget,
        // declare rank 1 dead, and settle the gauges — not panic
        links[1].stop_acceptor();
        thread::sleep(Duration::from_millis(50)); // listener drops
        links[0].inject_writer_break(1);
        let t = Instant::now();
        let stamp = Stamp::Wall { sent: t, at: t };
        links[0].enqueue(0, 1, Tag::MODEL.round(1), stamp, Payload::F32(vec![3.0]));
        crate::util::deadline_poll("dead-peer drain", || {
            (links[0].in_flight() == 0 && links[0].dead_peers() == vec![1]).then_some(())
        });
        assert_eq!(links[0].in_flight_bytes(), 0, "discards settle the byte gauge");
        // post-death sends are dropped at the door, no panic
        links[0].enqueue(0, 1, Tag::MODEL.round(2), stamp, Payload::F32(vec![4.0]));
        assert_eq!(links[0].in_flight(), 0);
        quiesce_all(&links);
    }
}
