//! Fault-injecting link wrapper: deterministic wire chaos behind the
//! same [`Link`] trait everything else speaks.
//!
//! [`FaultyLink`] wraps any inner link (the in-process mailbox array or
//! a [`TcpLink`](super::tcp::TcpLink)) and applies a seeded
//! [`FaultPlan`] at the `enqueue` boundary:
//!
//! * **drop** — a gossip model frame whose `(seed, src, dst, tag)`
//!   hash falls under `drop_frac` never enters the link.  The receiver
//!   evaluates the *same pure hash* before harvesting and skips the
//!   wait (`coordinator::gossip`), so nothing blocks and nothing leaks;
//! * **duplicate** — the frame is enqueued twice with identical
//!   stamps; the receiver pops and discards the extra copy after the
//!   accounted harvest of the first;
//! * **slow** — frames touching a slowed rank (from the plan's trigger
//!   round on) have their modeled wire time scaled, stretching the
//!   stamp's send→arrival interval under either clock.
//!
//! Only gossip model kinds ([`Tag::is_gossip_model_kind`]) are ever
//! dropped or duplicated: collective rounds and the sample-shuffle ring
//! block forever on a missing frame, while gossip mixing tolerates a
//! lost exchange by construction.  Rank *death* needs no interception
//! at all — a killed rank exits its step loop deterministically (it
//! knows the shared plan) and simply stops sending, while survivors
//! route around it through the same plan-derived view
//! (`membership::Membership::view_at`).  See docs/fault-tolerance.md.

use super::link::{Key, Link, QuiesceError, Stamp};
use super::Tag;
use crate::codec::Payload;
use crate::membership::FaultPlan;
use crate::pool::BufferPool;
use std::sync::Arc;
use std::time::Duration;

/// A [`Link`] that perturbs traffic per a seeded [`FaultPlan`] and
/// delegates everything else to the wrapped link.
pub struct FaultyLink {
    inner: Arc<dyn Link>,
    plan: FaultPlan,
}

impl FaultyLink {
    pub fn new(inner: Arc<dyn Link>, plan: FaultPlan) -> Arc<FaultyLink> {
        Arc::new(FaultyLink { inner, plan })
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Stretch a stamp's send→arrival interval by `factor` (> 1 slows
    /// the frame down; the send instant is untouched so the overlap
    /// ledger still sees the true wire span).
    fn slow_stamp(stamp: Stamp, factor: f64) -> Stamp {
        if factor <= 1.0 {
            return stamp;
        }
        match stamp {
            Stamp::Wall { sent, at } => {
                let wire = at.saturating_duration_since(sent);
                Stamp::Wall {
                    sent,
                    at: sent + Duration::from_secs_f64(wire.as_secs_f64() * factor),
                }
            }
            Stamp::Virt { sent_ns, at_ns } => {
                let wire = at_ns.saturating_sub(sent_ns) as f64;
                Stamp::Virt {
                    sent_ns,
                    at_ns: sent_ns + (wire * factor).round() as u64,
                }
            }
        }
    }
}

impl Link for FaultyLink {
    fn size(&self) -> usize {
        self.inner.size()
    }

    fn enqueue(&self, src: usize, dst: usize, tag: Tag, stamp: Stamp, data: Payload) {
        let chaos_eligible = tag.is_gossip_model_kind() && src != dst;
        if chaos_eligible && self.plan.dropped(src, dst, tag.0) {
            // never enters the link: in_flight stays balanced and the
            // receiver skips the harvest via the same hash
            return;
        }
        let stamp = Self::slow_stamp(stamp, self.plan.slow_factor(src, dst, tag.round_of()));
        if chaos_eligible && self.plan.duplicated(src, dst, tag.0) {
            // original first (FIFO: the accounted harvest gets it),
            // identical-stamp copy second for the receiver to discard
            self.inner.enqueue(src, dst, tag, stamp, data.clone());
        }
        self.inner.enqueue(src, dst, tag, stamp, data);
    }

    fn peek(&self, rank: usize, key: Key) -> Option<Stamp> {
        self.inner.peek(rank, key)
    }

    fn pop(&self, rank: usize, key: Key) -> Option<(Stamp, Payload)> {
        self.inner.pop(rank, key)
    }

    fn park(&self, rank: usize, key: Key, timeout: Option<Duration>) {
        self.inner.park(rank, key, timeout)
    }

    fn in_flight(&self) -> usize {
        self.inner.in_flight()
    }

    fn in_flight_bytes(&self) -> usize {
        self.inner.in_flight_bytes()
    }

    fn supports_virtual(&self) -> bool {
        self.inner.supports_virtual()
    }

    fn quiesce(&self, rank: usize, timeout: Option<Duration>) -> Result<(), QuiesceError> {
        self.inner.quiesce(rank, timeout)
    }

    fn attach_pool(&self, pool: &Arc<BufferPool>) {
        self.inner.attach_pool(pool)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::link::InprocLink;
    use std::time::Instant;

    fn plan(drop: f64, dup: f64) -> FaultPlan {
        FaultPlan { drop_frac: drop, dup_frac: dup, seed: 9, ..Default::default() }
    }

    fn wall(ms: u64) -> Stamp {
        let t = Instant::now();
        Stamp::Wall { sent: t, at: t + Duration::from_millis(ms) }
    }

    #[test]
    fn drops_match_the_plan_hash_exactly() {
        let p = plan(0.5, 0.0);
        let l = FaultyLink::new(Arc::new(InprocLink::new(2)), p.clone());
        let mut delivered = 0;
        let mut expected = 0;
        for r in 0..200usize {
            let tag = Tag::MODEL.round(r);
            l.enqueue(0, 1, tag, wall(0), Payload::F32(vec![1.0]));
            expected += !p.dropped(0, 1, tag.0) as usize;
            delivered += l.pop(1, (0, tag)).is_some() as usize;
        }
        assert_eq!(delivered, expected);
        assert!(delivered > 0 && delivered < 200, "0.5 drop must bite");
        assert_eq!(l.in_flight(), 0, "dropped frames never enter the link");
    }

    #[test]
    fn duplicates_enqueue_two_identical_copies() {
        let p = plan(0.0, 1.0); // every eligible frame duplicated
        let l = FaultyLink::new(Arc::new(InprocLink::new(2)), p);
        let tag = Tag::layer(1).round(4);
        l.enqueue(0, 1, tag, wall(0), Payload::F32(vec![2.0, 3.0]));
        let a = l.pop(1, (0, tag)).unwrap();
        let b = l.pop(1, (0, tag)).unwrap();
        assert_eq!(a.1.decode(), b.1.decode());
        assert!(l.pop(1, (0, tag)).is_none());
    }

    #[test]
    fn bookkeeping_and_collective_kinds_are_exempt() {
        let l = FaultyLink::new(Arc::new(InprocLink::new(2)), plan(1.0, 1.0));
        for tag in [
            Tag::SAMPLES.round(3),
            Tag::CTRL.round(3),
            Tag::REDUCE.round(3),
            Tag::BCAST.round(3),
        ] {
            l.enqueue(0, 1, tag, wall(0), Payload::F32(vec![1.0]));
            assert!(l.pop(1, (0, tag)).is_some(), "{tag:?} must pass");
            assert!(l.pop(1, (0, tag)).is_none(), "{tag:?} must not duplicate");
        }
        // self-loops are never perturbed either
        l.enqueue(0, 0, Tag::MODEL.round(1), wall(0), Payload::F32(vec![1.0]));
        assert!(l.pop(0, (0, Tag::MODEL.round(1))).is_some());
    }

    #[test]
    fn slow_stretches_the_wire_interval() {
        let mut p = FaultPlan::default();
        p.slows = vec![(1, 2, 4.0)];
        let l = FaultyLink::new(Arc::new(InprocLink::new(2)), p);
        // round 1: before the trigger — untouched
        l.enqueue(0, 1, Tag::MODEL.round(1), wall(10), Payload::F32(vec![0.0]));
        // round 2: dst slowed 4x
        l.enqueue(0, 1, Tag::MODEL.round(2), wall(10), Payload::F32(vec![0.0]));
        let span = |s: Stamp| match s {
            Stamp::Wall { sent, at } => at.saturating_duration_since(sent),
            _ => unreachable!(),
        };
        let fast = span(l.pop(1, (0, Tag::MODEL.round(1))).unwrap().0);
        let slow = span(l.pop(1, (0, Tag::MODEL.round(2))).unwrap().0);
        assert!(
            slow >= fast * 3 && slow <= fast * 5,
            "expected ~4x stretch, got {fast:?} vs {slow:?}"
        );
    }

    #[test]
    fn virtual_stamps_stretch_deterministically() {
        let mut p = FaultPlan::default();
        p.slows = vec![(0, 0, 2.0)];
        let l = FaultyLink::new(Arc::new(InprocLink::new(2)), p);
        let s = Stamp::Virt { sent_ns: 1_000, at_ns: 1_500 };
        l.enqueue(0, 1, Tag::MODEL.round(1), s, Payload::F32(vec![0.0]));
        match l.pop(1, (0, Tag::MODEL.round(1))).unwrap().0 {
            Stamp::Virt { sent_ns, at_ns } => {
                assert_eq!(sent_ns, 1_000);
                assert_eq!(at_ns, 2_000, "500ns wire doubled");
            }
            _ => unreachable!(),
        }
    }
}
