//! Hybrid link: in-process mailboxes inside a host group, TCP across
//! groups.
//!
//! GossipGraD's deployment unit is a *node* hosting several workers:
//! ranks on the same host exchange over shared memory while only the
//! inter-host partners touch the NIC.  `--group-size G` reproduces that
//! shape — `launch` spawns one OS process per group of G consecutive
//! ranks, and inside each process every rank's link is a [`HybridLink`]:
//!
//! * **same-group traffic** (`dst` in `[base, base + G)`) is pushed
//!   straight into a [`Mailbox`] shared by the co-resident rank threads,
//!   exactly like [`InprocLink`](super::link::InprocLink) — synchronous,
//!   caller stamp preserved, no serialization;
//! * **cross-group traffic** rides the rank's own
//!   [`TcpLink`](super::tcp::TcpLink), with all of its framing,
//!   reconnect and quiesce machinery unchanged.
//!
//! The TCP mesh is still established over the full `p`-rank peer list
//! (same-group sockets simply stay idle), so handshake validation,
//! launch plumbing and `tcp.rs` itself need no group awareness.  Wall
//! clock only, like any real-network link — hierarchical *virtual*-time
//! runs use the in-process fabric with a
//! [`HierCostModel`](super::simnet::HierCostModel) instead
//! (docs/topology.md).  Being wall-clock, hybrid runs always execute on
//! the legacy thread-per-rank path — the cooperative rank scheduler
//! (docs/perf.md) only takes over virtual-clock fabrics, where parks
//! never sleep out real time.
//!
//! ## Accounting
//!
//! Each rank's `in_flight` counts its *own* mailbox plus its own TCP
//! gauges — co-residents share the mailbox `Vec` but each consumes only
//! its slot, so summing every rank's gauge (what the launcher's drain
//! check does) counts each message exactly once.

use super::link::{Key, Link, Mailbox, QuiesceError, Stamp};
use super::simnet::GroupMap;
use super::tcp::TcpLink;
use super::Tag;
use crate::codec::Payload;
use crate::pool::BufferPool;
use std::sync::Arc;
use std::time::Duration;

/// Build the mailbox array one group's rank threads share: slot `i`
/// serves group-local rank `base + i`.
pub fn group_mailboxes(group_size: usize) -> Arc<Vec<Mailbox>> {
    Arc::new((0..group_size).map(|_| Mailbox::new()).collect())
}

/// One rank's hybrid link — see the module docs.
pub struct HybridLink {
    rank: usize,
    groups: GroupMap,
    /// First rank of this rank's group.
    base: usize,
    /// This rank's slot in `boxes` (`rank - base`).
    local_idx: usize,
    /// Shared with every co-resident rank in the group.
    boxes: Arc<Vec<Mailbox>>,
    /// This rank's own full-mesh TCP link, used for cross-group peers.
    tcp: Arc<TcpLink>,
}

impl HybridLink {
    /// Wrap `rank`'s established TCP link, mounting `boxes` (from
    /// [`group_mailboxes`], shared across the group's rank threads) for
    /// same-group delivery.
    pub fn new(
        rank: usize,
        groups: GroupMap,
        boxes: Arc<Vec<Mailbox>>,
        tcp: Arc<TcpLink>,
    ) -> HybridLink {
        assert_eq!(
            boxes.len(),
            groups.group_size(),
            "one mailbox per group-local rank"
        );
        assert_eq!(tcp.size(), groups.p(), "tcp mesh spans the full world");
        assert_eq!(tcp.rank(), rank, "tcp link belongs to this rank");
        let base = groups.group_base(groups.group_of(rank));
        HybridLink {
            rank,
            groups,
            base,
            local_idx: rank - base,
            boxes,
            tcp,
        }
    }

    fn local(&self, r: usize) -> bool {
        self.groups.same_group(self.rank, r)
    }
}

impl Link for HybridLink {
    fn size(&self) -> usize {
        self.groups.p()
    }

    fn enqueue(&self, src: usize, dst: usize, tag: Tag, stamp: Stamp, data: Payload) {
        assert_eq!(src, self.rank, "hybrid link sends only from its local rank");
        if self.local(dst) {
            // co-resident peer (or self): straight into its mailbox,
            // caller stamp preserved — identical to the in-process link
            self.boxes[dst - self.base].push((src, tag), stamp, data);
        } else {
            self.tcp.enqueue(src, dst, tag, stamp, data);
        }
    }

    fn peek(&self, rank: usize, key: Key) -> Option<Stamp> {
        debug_assert_eq!(rank, self.rank, "hybrid link serves its local rank only");
        if self.local(key.0) {
            self.boxes[self.local_idx].peek(key)
        } else {
            self.tcp.peek(rank, key)
        }
    }

    fn pop(&self, rank: usize, key: Key) -> Option<(Stamp, Payload)> {
        debug_assert_eq!(rank, self.rank, "hybrid link serves its local rank only");
        if self.local(key.0) {
            self.boxes[self.local_idx].pop(key)
        } else {
            self.tcp.pop(rank, key)
        }
    }

    fn park(&self, rank: usize, key: Key, timeout: Option<Duration>) {
        debug_assert_eq!(rank, self.rank, "hybrid link serves its local rank only");
        if self.local(key.0) {
            self.boxes[self.local_idx].park(key, timeout)
        } else {
            self.tcp.park(rank, key, timeout)
        }
    }

    fn in_flight(&self) -> usize {
        // own mailbox slot + own tcp gauges only: co-residents share the
        // mailbox Vec but each rank counts just its slot, so the
        // launcher's per-rank sum counts every message exactly once
        self.boxes[self.local_idx].queued() + self.tcp.in_flight()
    }

    fn in_flight_bytes(&self) -> usize {
        self.boxes[self.local_idx].queued_bytes() + self.tcp.in_flight_bytes()
    }

    fn supports_virtual(&self) -> bool {
        false
    }

    fn quiesce(&self, rank: usize, timeout: Option<Duration>) -> Result<(), QuiesceError> {
        // mailbox pushes are synchronous (no drain needed, like the
        // in-process link); only the TCP half has a barrier to run
        self.tcp.quiesce(rank, timeout)
    }

    fn attach_pool(&self, pool: &Arc<BufferPool>) {
        self.tcp.attach_pool(pool)
    }
}

#[cfg(test)]
mod tests {
    use super::super::tcp::TcpLinkBuilder;
    use super::super::simnet::CostModel;
    use super::*;
    use std::thread;
    use std::time::Instant;

    /// Full hybrid world on loopback: p ranks, groups of `g`, each rank
    /// wrapped in a HybridLink sharing its group's mailboxes.
    fn hybrid_world(p: usize, g: usize) -> Vec<Arc<HybridLink>> {
        let builders: Vec<TcpLinkBuilder> = (0..p)
            .map(|_| TcpLinkBuilder::bind("127.0.0.1:0").unwrap())
            .collect();
        let peers: Vec<String> =
            builders.iter().map(|b| b.local_addr().to_string()).collect();
        let handles: Vec<_> = builders
            .into_iter()
            .enumerate()
            .map(|(rank, b)| {
                let peers = peers.clone();
                thread::spawn(move || {
                    b.establish(rank, &peers, CostModel::zero(), Duration::from_secs(20))
                        .unwrap()
                })
            })
            .collect();
        let tcps: Vec<Arc<TcpLink>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let groups = GroupMap::new(p, g);
        let shared: Vec<Arc<Vec<Mailbox>>> =
            (0..groups.num_groups()).map(|_| group_mailboxes(g)).collect();
        tcps.into_iter()
            .enumerate()
            .map(|(rank, tcp)| {
                let boxes = Arc::clone(&shared[groups.group_of(rank)]);
                Arc::new(HybridLink::new(rank, groups, boxes, tcp))
            })
            .collect()
    }

    fn quiesce_all(links: &[Arc<HybridLink>]) {
        let handles: Vec<_> = links
            .iter()
            .enumerate()
            .map(|(rank, l)| {
                let l = Arc::clone(l);
                thread::spawn(move || l.quiesce(rank, None).unwrap())
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn intra_group_delivery_preserves_caller_stamp() {
        // ranks 0,1 share a group: the push is synchronous and the
        // stamp must come back bit-identical (no receiver restamping)
        let links = hybrid_world(4, 2);
        let sent = Instant::now();
        let at = sent + Duration::from_millis(250);
        links[0].enqueue(0, 1, Tag::MODEL, Stamp::Wall { sent, at }, Payload::F32(vec![7.0]));
        // synchronous: visible immediately, no polling needed
        let (stamp, data) = links[1].pop(1, (0, Tag::MODEL)).unwrap();
        assert_eq!(data.decode(), vec![7.0]);
        match stamp {
            Stamp::Wall { sent: s, at: a } => {
                assert_eq!(s, sent);
                assert_eq!(a, at, "caller stamp preserved across the mailbox");
            }
            Stamp::Virt { .. } => panic!("wall stamp expected"),
        }
        quiesce_all(&links);
    }

    #[test]
    fn cross_group_delivery_rides_tcp() {
        let links = hybrid_world(4, 2);
        let t = Instant::now();
        links[0].enqueue(
            0,
            2,
            Tag::MODEL,
            Stamp::Wall { sent: t, at: t },
            Payload::F32(vec![1.0, 2.0]),
        );
        let (_, data) = crate::util::deadline_poll("cross-group frame", || {
            links[2].pop(2, (0, Tag::MODEL))
        });
        assert_eq!(data.decode(), vec![1.0, 2.0]);
        quiesce_all(&links);
        for l in &links {
            assert_eq!(l.in_flight(), 0);
            assert_eq!(l.in_flight_bytes(), 0);
        }
    }

    #[test]
    fn self_send_is_local() {
        let links = hybrid_world(2, 1);
        let t = Instant::now();
        links[0].enqueue(0, 0, Tag::CTRL, Stamp::Wall { sent: t, at: t }, Payload::F32(vec![3.0]));
        let (_, data) = links[0].pop(0, (0, Tag::CTRL)).unwrap();
        assert_eq!(data.decode(), vec![3.0]);
        quiesce_all(&links);
    }

    #[test]
    fn gauges_count_own_slot_only_and_drain_to_zero() {
        let links = hybrid_world(4, 2);
        let t = Instant::now();
        let stamp = Stamp::Wall { sent: t, at: t };
        // 0 → 1 (intra): shows in rank 1's gauge, not rank 0's
        links[0].enqueue(0, 1, Tag::MODEL, stamp, Payload::F32(vec![1.0]));
        assert_eq!(links[0].in_flight(), 0, "producer's own slot untouched");
        assert_eq!(links[1].in_flight(), 1);
        assert_eq!(links[1].in_flight_bytes(), 4);
        links[1].pop(1, (0, Tag::MODEL)).unwrap();
        // 1 → 3 (inter): charged on rank 1's tcp gauges until flushed,
        // then on rank 3's mailbox until popped — per-rank sums stay
        // double-count-free either way
        links[1].enqueue(1, 3, Tag::MODEL, stamp, Payload::F32(vec![2.0]));
        crate::util::deadline_poll("inter frame", || links[3].pop(3, (1, Tag::MODEL)));
        quiesce_all(&links);
        for l in &links {
            assert_eq!(l.in_flight(), 0);
            assert_eq!(l.in_flight_bytes(), 0);
        }
    }

    #[test]
    fn park_covers_both_halves() {
        let links = hybrid_world(4, 2);
        let t0 = Instant::now();
        let t = Instant::now();
        let stamp = Stamp::Wall { sent: t, at: t };
        // queued intra message: park returns immediately
        links[0].enqueue(0, 1, Tag::MODEL, stamp, Payload::F32(vec![1.0]));
        links[1].park(1, (0, Tag::MODEL), None);
        assert!(t0.elapsed() < Duration::from_secs(5));
        links[1].pop(1, (0, Tag::MODEL)).unwrap();
        // silent inter channel: timed park comes back without traffic
        links[1].park(1, (2, Tag::MODEL), Some(Duration::from_millis(20)));
        quiesce_all(&links);
    }
}
