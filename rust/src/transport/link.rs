//! Link layer: message *delivery* only — enqueue, poll, drain, in-flight
//! accounting.  Everything about time (clocks, wire-cost stamping
//! policy, the hidden/exposed overlap ledger, traffic counters) lives
//! one layer up in the accounting layer ([`super::inproc`]), which is
//! generic over this trait.  The split mirrors the `SimCommunicator`
//! seam in distributed simulators: the same collectives/coordinator
//! code runs over an in-process mailbox array or a real network.
//!
//! Two links ship:
//!
//! * [`InprocLink`] — one mailbox per rank inside one process (threads
//!   as ranks).  This is the historical transport, bit-identical in
//!   behaviour and timing to the pre-split `inproc` fabric.
//! * [`TcpLink`](super::tcp::TcpLink) — one OS process per rank,
//!   length-prefixed frames over `std::net::TcpStream` (wall clock
//!   only; see `docs/transport.md`).
//!
//! ## Contract
//!
//! * Channels are FIFO per [`Key`] = `(src, tag)`: [`Link::pop`]
//!   returns messages from one key in the order they were enqueued.
//! * Each rank has exactly **one consumer thread**: only the owning
//!   rank calls `peek`/`pop`/`park` for its own slot, so a
//!   peek-then-pop sequence is race-free (producers only append).
//! * [`Link::park`] atomically checks "is anything queued on this key?"
//!   under the same lock the producers publish under, so a message
//!   enqueued concurrently with a park can never be missed (no lost
//!   wake-up) — this is what lets the accounting layer block without
//!   busy-wait polls or timeout loops.

use super::Tag;
use crate::codec::Payload;
use crate::pool::BufferPool;
use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Channel key: `(source rank, tag)` — mirrors MPI's (source, tag)
/// matching, without wildcards.
pub type Key = (usize, Tag);

/// Send/arrival instants carried with every queued message — the
/// variant always matches the owning fabric's clock mode.  The send
/// instant rides along so the receiver can split the wire time into its
/// *hidden* part (elapsed under the receiver's compute) and its
/// *exposed* part (blocked wait) — the two halves of the overlap ledger
/// behind `overlap_frac`.
#[derive(Clone, Copy, Debug)]
pub enum Stamp {
    Wall { sent: Instant, at: Instant },
    Virt { sent_ns: u64, at_ns: u64 },
}

type Queue = VecDeque<(Stamp, Payload)>;

/// One rank's delivery queue set: per-[`Key`] FIFO queues plus the
/// condvar producers notify.  Shared by both link implementations (the
/// in-process link owns `p` of these; the TCP link owns one, for the
/// local rank).
pub struct Mailbox {
    queues: Mutex<HashMap<Key, Queue>>,
    cv: Condvar,
}

impl Default for Mailbox {
    fn default() -> Self {
        Mailbox::new()
    }
}

impl Mailbox {
    pub fn new() -> Mailbox {
        Mailbox {
            queues: Mutex::new(HashMap::new()),
            cv: Condvar::new(),
        }
    }

    /// Producer side: append and wake any parked consumer.
    pub fn push(&self, key: Key, stamp: Stamp, data: Payload) {
        {
            let mut q = self.queues.lock().unwrap();
            q.entry(key).or_default().push_back((stamp, data));
        }
        self.cv.notify_all();
    }

    /// Stamp of the front message on `key`, without removing it.
    pub fn peek(&self, key: Key) -> Option<Stamp> {
        let q = self.queues.lock().unwrap();
        q.get(&key).and_then(|d| d.front()).map(|(s, _)| *s)
    }

    /// Remove and return the front message on `key`.  Empty per-key
    /// queues are dropped from the map so long runs (whose tags carry
    /// ever-growing round numbers) don't accumulate dead entries.
    pub fn pop(&self, key: Key) -> Option<(Stamp, Payload)> {
        let mut q = self.queues.lock().unwrap();
        let deque = q.get_mut(&key)?;
        let hit = deque.pop_front();
        if deque.is_empty() {
            q.remove(&key);
        }
        hit
    }

    /// Block the calling consumer until a message is queued on `key`
    /// (returns immediately if one already is) or `timeout` elapses.
    /// The queued-check and the wait happen under one lock acquisition,
    /// so a concurrent [`push`](Self::push) cannot slip between them —
    /// spurious wake-ups are possible and callers re-poll in a loop.
    pub fn park(&self, key: Key, timeout: Option<Duration>) {
        let guard = self.queues.lock().unwrap();
        if guard.get(&key).map_or(false, |d| !d.is_empty()) {
            return;
        }
        match timeout {
            Some(d) => drop(self.cv.wait_timeout(guard, d).unwrap()),
            None => drop(self.cv.wait(guard).unwrap()),
        }
    }

    /// Messages queued and not yet popped.
    pub fn queued(&self) -> usize {
        let q = self.queues.lock().unwrap();
        q.values().map(|d| d.len()).sum()
    }

    /// Wire bytes queued and not yet popped — the byte companion of
    /// [`queued`](Self::queued) for the fabric-drain invariant (a leak
    /// of one tiny frame and a leak of a whole model both show up in
    /// frame counts, but only the byte gauge sizes the damage).
    pub fn queued_bytes(&self) -> usize {
        let q = self.queues.lock().unwrap();
        q.values()
            .flat_map(|d| d.iter())
            .map(|(_, p)| p.wire_bytes())
            .sum()
    }
}

/// Typed quiesce failure: the end-of-run barrier timed out because one
/// or more peers never closed their side of the wire.  Naming the
/// missing ranks (instead of hanging forever, the historical behaviour
/// when a peer died mid-run) mirrors the handshake policy of erroring
/// on both sides of a misconfiguration.
#[derive(Clone, Debug, PartialEq)]
pub struct QuiesceError {
    /// The rank whose quiesce timed out.
    pub rank: usize,
    /// Peer ranks whose streams were still open at the deadline.
    pub missing: Vec<usize>,
}

impl std::fmt::Display for QuiesceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "rank {}: quiesce timed out waiting on rank(s) {:?} — peer dead or hung?",
            self.rank, self.missing
        )
    }
}

impl std::error::Error for QuiesceError {}

/// The wire: message delivery between `size()` ranks.  Implementations
/// must uphold the FIFO-per-key and single-consumer-per-rank contract
/// documented at module level.
pub trait Link: Send + Sync {
    /// Number of ranks addressable on this link.
    fn size(&self) -> usize;

    /// Deliver `data` from `src` to `dst` on `tag`, carrying `stamp`.
    /// Must not block on the consumer (buffered-eager semantics).  A
    /// real-network link may replace the stamp on the receiving side
    /// (the sender's `Instant`s are meaningless in another process) and
    /// may re-materialize the payload from frame bytes, but must
    /// preserve its encoding and wire size.
    fn enqueue(&self, src: usize, dst: usize, tag: Tag, stamp: Stamp, data: Payload);

    /// Stamp of the front message queued for `rank` on `key`.
    fn peek(&self, rank: usize, key: Key) -> Option<Stamp>;

    /// Pop the front message queued for `rank` on `key`.
    fn pop(&self, rank: usize, key: Key) -> Option<(Stamp, Payload)>;

    /// Park `rank`'s consumer thread until a message is queued on `key`
    /// or `timeout` elapses; atomic with respect to `enqueue` (no lost
    /// wake-ups, see [`Mailbox::park`]).
    fn park(&self, rank: usize, key: Key, timeout: Option<Duration>);

    /// Messages accepted by the link and not yet popped by a consumer.
    /// For a real-network link this also counts frames still sitting in
    /// writer queues / being serialized — the end-of-run drain
    /// invariant (`tests/fabric_drain.rs`) needs every sent-but-never-
    /// harvested payload to be visible here.
    fn in_flight(&self) -> usize;

    /// Wire bytes accepted by the link and not yet popped — the byte
    /// gauge next to [`in_flight`](Self::in_flight)'s frame count.  The
    /// drain invariant asserts both hit zero: a run that leaks must be
    /// caught even if a future refactor made empty frames possible.
    fn in_flight_bytes(&self) -> usize;

    /// Whether this link can carry [`Stamp::Virt`] stamps (deterministic
    /// virtual-clock runs).  Real-network links run on the wall clock
    /// only.
    fn supports_virtual(&self) -> bool {
        true
    }

    /// End-of-run barrier for `rank`'s side of the link: flush
    /// everything this rank sent and ingest everything peers sent until
    /// their streams close.  After it returns `Ok`, [`in_flight`]
    /// (Self::in_flight) counts only genuinely leaked messages.
    /// `timeout` bounds the barrier: when a peer never closes its
    /// stream (a dead or hung rank), the implementation must return a
    /// [`QuiesceError`] naming the missing peer(s) instead of hanging
    /// forever.  `None` waits unbounded.  No-op for the in-process
    /// link, whose enqueues are synchronous.
    fn quiesce(&self, _rank: usize, _timeout: Option<Duration>) -> Result<(), QuiesceError> {
        Ok(())
    }

    /// Hand the owning fabric's [`BufferPool`] to the link so transport
    /// threads can draw receive buffers from — and recycle flushed send
    /// payloads into — the same shelves the coordinator uses.  Default:
    /// no-op; the in-process link moves payloads by pointer and owns no
    /// private buffers.
    fn attach_pool(&self, _pool: &Arc<BufferPool>) {}
}

/// The in-process link: one [`Mailbox`] per rank, producers push
/// directly into the consumer's mailbox.  Behaviour (and therefore
/// every virtual-clock timing) is identical to the pre-split fabric.
pub struct InprocLink {
    boxes: Vec<Mailbox>,
}

impl InprocLink {
    pub fn new(p: usize) -> InprocLink {
        InprocLink {
            boxes: (0..p).map(|_| Mailbox::new()).collect(),
        }
    }
}

impl Link for InprocLink {
    fn size(&self) -> usize {
        self.boxes.len()
    }

    fn enqueue(&self, src: usize, dst: usize, tag: Tag, stamp: Stamp, data: Payload) {
        self.boxes[dst].push((src, tag), stamp, data);
    }

    fn peek(&self, rank: usize, key: Key) -> Option<Stamp> {
        self.boxes[rank].peek(key)
    }

    fn pop(&self, rank: usize, key: Key) -> Option<(Stamp, Payload)> {
        self.boxes[rank].pop(key)
    }

    fn park(&self, rank: usize, key: Key, timeout: Option<Duration>) {
        self.boxes[rank].park(key, timeout)
    }

    fn in_flight(&self) -> usize {
        self.boxes.iter().map(Mailbox::queued).sum()
    }

    fn in_flight_bytes(&self) -> usize {
        self.boxes.iter().map(Mailbox::queued_bytes).sum()
    }
}

/// Scheduler-integrated link: the outermost wrapper on a cooperative
/// virtual-clock fabric (docs/perf.md, "rank scheduler").  Two hooks:
///
/// * [`enqueue`](Link::enqueue) delivers on the inner link, then tells
///   the scheduler the destination rank may be runnable — the
///   sender-side wake that replaces "p threads parked in mailbox
///   condvars".
/// * [`park`](Link::park) yields the calling rank's coroutine back to
///   its worker instead of blocking the OS thread.  Callers that are
///   not tasks of this scheduler (the legacy path, another scenario's
///   fabric, a raw test thread) fall through to the inner link's
///   blocking park, so mixed use stays correct.
///
/// Wrapping order matters: `SchedLink` sits *outside*
/// [`FaultyLink`](super::FaultyLink), so a frame the fault plan drops
/// still wakes its destination — a harmless spurious wake (parked
/// consumers always re-poll) — and the no-lost-wakeup argument only
/// has to cover messages the inner link really delivers.
pub struct SchedLink {
    inner: Arc<dyn Link>,
    sched: crate::sched::SchedHandle,
}

impl SchedLink {
    pub fn new(inner: Arc<dyn Link>, sched: crate::sched::SchedHandle) -> SchedLink {
        SchedLink { inner, sched }
    }
}

impl Link for SchedLink {
    fn size(&self) -> usize {
        self.inner.size()
    }

    fn enqueue(&self, src: usize, dst: usize, tag: Tag, stamp: Stamp, data: Payload) {
        self.inner.enqueue(src, dst, tag, stamp, data);
        // wake strictly after the message is visible: waking first
        // would let the rank poll, miss, and park again pre-delivery
        self.sched.wake(dst);
    }

    fn peek(&self, rank: usize, key: Key) -> Option<Stamp> {
        self.inner.peek(rank, key)
    }

    fn pop(&self, rank: usize, key: Key) -> Option<(Stamp, Payload)> {
        self.inner.pop(rank, key)
    }

    fn park(&self, rank: usize, key: Key, timeout: Option<Duration>) {
        // a timed park becomes a yield-once (re-queued without a
        // waker); an untimed park stays parked until a wake
        if !self.sched.yield_park(timeout.is_some()) {
            self.inner.park(rank, key, timeout);
        }
    }

    fn in_flight(&self) -> usize {
        self.inner.in_flight()
    }

    fn in_flight_bytes(&self) -> usize {
        self.inner.in_flight_bytes()
    }

    fn supports_virtual(&self) -> bool {
        self.inner.supports_virtual()
    }

    fn quiesce(&self, rank: usize, timeout: Option<Duration>) -> Result<(), QuiesceError> {
        self.inner.quiesce(rank, timeout)
    }

    fn attach_pool(&self, pool: &Arc<BufferPool>) {
        self.inner.attach_pool(pool)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread;

    fn wall_now() -> Stamp {
        let t = Instant::now();
        Stamp::Wall { sent: t, at: t }
    }

    #[test]
    fn fifo_per_key_and_empty_queue_cleanup() {
        let l = InprocLink::new(2);
        for i in 0..4 {
            l.enqueue(0, 1, Tag::MODEL, wall_now(), Payload::F32(vec![i as f32]));
        }
        assert_eq!(l.in_flight(), 4);
        assert_eq!(l.in_flight_bytes(), 16, "4 one-float payloads");
        for i in 0..4 {
            let (_, d) = l.pop(1, (0, Tag::MODEL)).unwrap();
            assert_eq!(d.decode()[0], i as f32);
        }
        assert!(l.pop(1, (0, Tag::MODEL)).is_none());
        assert_eq!(l.in_flight(), 0);
        assert_eq!(l.in_flight_bytes(), 0);
    }

    #[test]
    fn peek_does_not_consume() {
        let l = InprocLink::new(2);
        l.enqueue(0, 1, Tag::CTRL, wall_now(), Payload::F32(vec![7.0]));
        assert!(l.peek(1, (0, Tag::CTRL)).is_some());
        assert!(l.peek(1, (0, Tag::CTRL)).is_some());
        assert_eq!(l.in_flight(), 1);
        assert_eq!(l.in_flight_bytes(), 4);
        assert!(l.peek(1, (0, Tag::MODEL)).is_none());
    }

    #[test]
    fn byte_gauge_charges_encoded_sizes() {
        use crate::codec::Encoding;
        let l = InprocLink::new(2);
        l.enqueue(0, 1, Tag::MODEL, wall_now(), Payload::F32(vec![0.0; 10]));
        l.enqueue(
            0,
            1,
            Tag::layer(0),
            wall_now(),
            Payload::Bytes {
                enc: Encoding::Bf16,
                n: 10,
                bytes: vec![0u8; 20],
            },
        );
        assert_eq!(l.in_flight(), 2);
        assert_eq!(l.in_flight_bytes(), 60, "40 dense + 20 compressed");
        l.pop(1, (0, Tag::layer(0))).unwrap();
        assert_eq!(l.in_flight_bytes(), 40);
    }

    #[test]
    fn park_returns_immediately_when_queued() {
        let l = InprocLink::new(2);
        l.enqueue(0, 1, Tag::MODEL, wall_now(), Payload::F32(vec![1.0]));
        let t0 = Instant::now();
        l.park(1, (0, Tag::MODEL), None);
        assert!(t0.elapsed() < Duration::from_secs(1));
    }

    #[test]
    fn park_wakes_on_cross_thread_enqueue() {
        // no timeout: the park must still wake when a producer thread
        // enqueues — the lost-wakeup regression the atomic
        // check-then-wait prevents
        let l = Arc::new(InprocLink::new(2));
        let l2 = Arc::clone(&l);
        let h = thread::spawn(move || {
            loop {
                if l2.pop(1, (0, Tag::MODEL)).is_some() {
                    return;
                }
                l2.park(1, (0, Tag::MODEL), None);
            }
        });
        thread::sleep(Duration::from_millis(20));
        l.enqueue(0, 1, Tag::MODEL, wall_now(), Payload::F32(vec![3.0]));
        h.join().unwrap();
        assert_eq!(l.in_flight(), 0);
    }

    #[test]
    fn park_timeout_returns_without_traffic() {
        // a timed park on a silent channel must come back (spurious
        // wake-ups may return it early — callers always re-poll — so
        // only the "does not hang" property is asserted)
        let l = InprocLink::new(1);
        l.park(0, (0, Tag::MODEL), Some(Duration::from_millis(20)));
        assert_eq!(l.in_flight(), 0);
    }
}
