//! α–β network cost model (+ optional OS-noise jitter) — the simulated
//! interconnect standing in for InfiniBand-EDR / Cray Aries.
//!
//! Message cost: `t = α + M·β`, the model the paper's complexity claims
//! are phrased in (Θ(log p) all-reduce vs O(1) gossip).  `noise_frac`
//! injects multiplicative jitter reproducing the "system issues" the
//! paper cites (Hoefler et al. [14], Bhatele et al. [15]).
//!
//! Presets are calibrated to the paper's testbeds (Table 4): IB-EDR
//! (~1 µs latency, ~12 GB/s effective) and Aries (~1.2 µs, ~10 GB/s).
//! `scaled` presets shrink message *time* proportionally for laptop-scale
//! real runs while preserving the compute:comm ratio.

use crate::util::Rng;
use std::sync::Mutex;

#[derive(Debug)]
pub struct CostModel {
    /// Per-message latency, seconds.
    pub alpha: f64,
    /// Per-byte transfer time, seconds (1 / bandwidth).
    pub beta: f64,
    /// Multiplicative noise amplitude (0.0 = deterministic).
    pub noise_frac: f64,
    rng: Mutex<Rng>,
}

impl Clone for CostModel {
    fn clone(&self) -> Self {
        CostModel {
            alpha: self.alpha,
            beta: self.beta,
            noise_frac: self.noise_frac,
            rng: Mutex::new(self.rng.lock().unwrap().clone()),
        }
    }
}

impl CostModel {
    pub fn new(alpha: f64, beta: f64, noise_frac: f64, seed: u64) -> Self {
        CostModel {
            alpha,
            beta,
            noise_frac,
            rng: Mutex::new(Rng::new(seed)),
        }
    }

    /// No simulated cost: messages are visible immediately (correctness
    /// runs, unit tests).
    pub fn zero() -> Self {
        CostModel::new(0.0, 0.0, 0.0, 0)
    }

    /// InfiniBand EDR preset (paper's P100 cluster fabric).
    pub fn ib_edr(seed: u64) -> Self {
        CostModel::new(1.0e-6, 1.0 / 12.0e9, 0.05, seed)
    }

    /// Cray Aries preset (paper's KNL cluster fabric).
    pub fn aries(seed: u64) -> Self {
        CostModel::new(1.2e-6, 1.0 / 10.0e9, 0.08, seed)
    }

    /// The cost in seconds of one message of `bytes` bytes.
    pub fn message_time(&self, bytes: usize) -> f64 {
        let base = self.alpha + bytes as f64 * self.beta;
        if self.noise_frac > 0.0 {
            let u = self.rng.lock().unwrap().f64();
            // one-sided jitter: networks are slower than nominal, not faster
            base * (1.0 + self.noise_frac * u)
        } else {
            base
        }
    }

    /// Analytic (noise-free) cost — used by the discrete-event simulator
    /// where determinism across sweeps matters.
    pub fn nominal(&self, bytes: usize) -> f64 {
        self.alpha + bytes as f64 * self.beta
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_model_is_free() {
        let m = CostModel::zero();
        assert_eq!(m.message_time(1 << 20), 0.0);
    }

    #[test]
    fn alpha_beta_additive() {
        let m = CostModel::new(1e-6, 1e-9, 0.0, 0);
        assert!((m.message_time(0) - 1e-6).abs() < 1e-12);
        assert!((m.message_time(1000) - (1e-6 + 1e-6)).abs() < 1e-12);
    }

    #[test]
    fn noise_is_one_sided_and_bounded() {
        let m = CostModel::new(1e-6, 0.0, 0.5, 7);
        for _ in 0..100 {
            let t = m.message_time(0);
            assert!(t >= 1e-6 && t <= 1.5e-6 + 1e-12, "t={t}");
        }
    }

    #[test]
    fn presets_sane() {
        // 100 MB model (ResNet50) on IB-EDR: ~8ms — the paper's 27 ms
        // includes protocol overheads; order of magnitude is right
        let m = CostModel::ib_edr(0);
        let t = m.nominal(100 << 20);
        assert!(t > 5e-3 && t < 20e-3, "t={t}");
    }
}
