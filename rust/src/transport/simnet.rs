//! α–β network cost model (+ optional OS-noise jitter) — the simulated
//! interconnect standing in for InfiniBand-EDR / Cray Aries.
//!
//! Message cost: `t = α + M·β`, the model the paper's complexity claims
//! are phrased in (Θ(log p) all-reduce vs O(1) gossip).  `noise_frac`
//! injects multiplicative jitter reproducing the "system issues" the
//! paper cites (Hoefler et al. [14], Bhatele et al. [15]).
//!
//! Presets are calibrated to the paper's testbeds (Table 4): IB-EDR
//! (~1 µs latency, ~12 GB/s effective) and Aries (~1.2 µs, ~10 GB/s).
//! `scaled` presets shrink message *time* proportionally for laptop-scale
//! real runs while preserving the compute:comm ratio.
//!
//! Real clusters are not flat: the paper's P100 nodes hold multiple GPUs
//! behind NVLink/PCIe while nodes talk over IB.  [`HierCostModel`] models
//! that shape: a [`GroupMap`] partitions ranks into host groups and each
//! message is charged the intra- or inter-group tier by (src, dst)
//! (docs/topology.md).

use crate::util::Rng;
use std::sync::Mutex;

#[derive(Debug)]
pub struct CostModel {
    /// Per-message latency, seconds.
    pub alpha: f64,
    /// Per-byte transfer time, seconds (1 / bandwidth).
    pub beta: f64,
    /// Multiplicative noise amplitude (0.0 = deterministic).
    pub noise_frac: f64,
    /// Jitter stream; `None` iff `noise_frac == 0.0`, so the
    /// deterministic path provably never touches a lock (this sits
    /// inside every virtual-clock message send).
    rng: Option<Mutex<Rng>>,
}

impl Clone for CostModel {
    fn clone(&self) -> Self {
        CostModel {
            alpha: self.alpha,
            beta: self.beta,
            noise_frac: self.noise_frac,
            rng: self
                .rng
                .as_ref()
                .map(|m| Mutex::new(m.lock().unwrap().clone())),
        }
    }
}

impl CostModel {
    pub fn new(alpha: f64, beta: f64, noise_frac: f64, seed: u64) -> Self {
        CostModel {
            alpha,
            beta,
            noise_frac,
            rng: if noise_frac > 0.0 {
                Some(Mutex::new(Rng::new(seed)))
            } else {
                None
            },
        }
    }

    /// No simulated cost: messages are visible immediately (correctness
    /// runs, unit tests).
    pub fn zero() -> Self {
        CostModel::new(0.0, 0.0, 0.0, 0)
    }

    /// InfiniBand EDR preset (paper's P100 cluster fabric).
    pub fn ib_edr(seed: u64) -> Self {
        CostModel::new(1.0e-6, 1.0 / 12.0e9, 0.05, seed)
    }

    /// Cray Aries preset (paper's KNL cluster fabric).
    pub fn aries(seed: u64) -> Self {
        CostModel::new(1.2e-6, 1.0 / 10.0e9, 0.08, seed)
    }

    /// Intra-host preset: NVLink/PCIe-class links between ranks that
    /// share a host group (~0.5 µs, ~100 GB/s), deterministic.
    pub fn nvlink() -> Self {
        CostModel::new(0.5e-6, 1.0 / 100.0e9, 0.0, 0)
    }

    /// The cost in seconds of one message of `bytes` bytes.
    pub fn message_time(&self, bytes: usize) -> f64 {
        let base = self.alpha + bytes as f64 * self.beta;
        match &self.rng {
            // one-sided jitter: networks are slower than nominal, not faster
            Some(rng) => base * (1.0 + self.noise_frac * rng.lock().unwrap().f64()),
            None => base,
        }
    }

    /// Analytic (noise-free) cost — used by the discrete-event simulator
    /// where determinism across sweeps matters.
    pub fn nominal(&self, bytes: usize) -> f64 {
        self.alpha + bytes as f64 * self.beta
    }
}

/// Partition of `p` ranks into contiguous host groups of `group_size`.
///
/// Group `g` owns ranks `[g·group_size, (g+1)·group_size)`.  The map is
/// pure arithmetic — cheap to copy into every link/cost-model that needs
/// locality decisions.  `group_size == 1` degenerates to a flat network
/// (every pair is inter-group); `group_size == p` is a single host
/// (every pair is intra-group).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GroupMap {
    p: usize,
    group_size: usize,
}

impl GroupMap {
    /// Panics unless `group_size >= 1` and `group_size` divides `p`
    /// (callers validate user input before construction).
    pub fn new(p: usize, group_size: usize) -> Self {
        assert!(p >= 1, "GroupMap needs at least one rank");
        assert!(group_size >= 1, "group_size must be >= 1");
        assert!(
            p % group_size == 0,
            "group_size {group_size} must divide p {p}"
        );
        GroupMap { p, group_size }
    }

    pub fn p(&self) -> usize {
        self.p
    }

    pub fn group_size(&self) -> usize {
        self.group_size
    }

    pub fn num_groups(&self) -> usize {
        self.p / self.group_size
    }

    /// Host group of `rank` (ranks beyond `p` — e.g. PS server ranks —
    /// extend the same arithmetic rather than panicking).
    pub fn group_of(&self, rank: usize) -> usize {
        rank / self.group_size
    }

    /// First rank of group `g`.
    pub fn group_base(&self, g: usize) -> usize {
        g * self.group_size
    }

    pub fn same_group(&self, a: usize, b: usize) -> bool {
        self.group_of(a) == self.group_of(b)
    }
}

/// Two-tier α–β cost model: messages between ranks in the same host
/// group pay the fast `intra` tier, everything else pays `inter`.
#[derive(Clone, Debug)]
pub struct HierCostModel {
    pub intra: CostModel,
    pub inter: CostModel,
    pub groups: GroupMap,
}

impl HierCostModel {
    pub fn new(intra: CostModel, inter: CostModel, groups: GroupMap) -> Self {
        HierCostModel {
            intra,
            inter,
            groups,
        }
    }

    /// Default two-tier preset: NVLink-class within a group, the given
    /// inter-group model across groups.
    pub fn with_inter(inter: CostModel, groups: GroupMap) -> Self {
        HierCostModel::new(CostModel::nvlink(), inter, groups)
    }

    /// The tier a (src, dst) pair is charged on.
    pub fn tier(&self, src: usize, dst: usize) -> &CostModel {
        if self.groups.same_group(src, dst) {
            &self.intra
        } else {
            &self.inter
        }
    }

    /// Wall-clock cost (includes the tier's jitter, if any).
    pub fn message_time(&self, src: usize, dst: usize, bytes: usize) -> f64 {
        self.tier(src, dst).message_time(bytes)
    }

    /// Analytic cost — the virtual-clock charge.
    pub fn nominal(&self, src: usize, dst: usize, bytes: usize) -> f64 {
        self.tier(src, dst).nominal(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_model_is_free() {
        let m = CostModel::zero();
        assert_eq!(m.message_time(1 << 20), 0.0);
    }

    #[test]
    fn alpha_beta_additive() {
        let m = CostModel::new(1e-6, 1e-9, 0.0, 0);
        assert!((m.message_time(0) - 1e-6).abs() < 1e-12);
        assert!((m.message_time(1000) - (1e-6 + 1e-6)).abs() < 1e-12);
    }

    #[test]
    fn noise_is_one_sided_and_bounded() {
        let m = CostModel::new(1e-6, 0.0, 0.5, 7);
        for _ in 0..100 {
            let t = m.message_time(0);
            assert!(t >= 1e-6 && t <= 1.5e-6 + 1e-12, "t={t}");
        }
    }

    #[test]
    fn presets_sane() {
        // 100 MB model (ResNet50) on IB-EDR: ~8ms — the paper's 27 ms
        // includes protocol overheads; order of magnitude is right
        let m = CostModel::ib_edr(0);
        let t = m.nominal(100 << 20);
        assert!(t > 5e-3 && t < 20e-3, "t={t}");
    }

    #[test]
    fn deterministic_model_has_no_rng() {
        // the lock-free invariant: noise_frac == 0 means no Mutex exists
        let m = CostModel::new(1e-6, 1e-9, 0.0, 42);
        assert!(m.rng.is_none());
        let c = m.clone();
        assert!(c.rng.is_none());
        assert_eq!(m.message_time(4096), c.message_time(4096));
        // and a noisy model still carries (and clones) its stream
        let n = CostModel::new(1e-6, 0.0, 0.1, 42);
        assert!(n.rng.is_some());
        assert!(n.clone().rng.is_some());
    }

    #[test]
    fn noisy_clone_replays_same_jitter() {
        let a = CostModel::new(1e-6, 0.0, 0.3, 9);
        let b = a.clone();
        for _ in 0..32 {
            assert_eq!(a.message_time(128), b.message_time(128));
        }
    }

    #[test]
    fn group_map_partitions() {
        let g = GroupMap::new(8, 4);
        assert_eq!(g.num_groups(), 2);
        assert_eq!(g.group_of(0), 0);
        assert_eq!(g.group_of(3), 0);
        assert_eq!(g.group_of(4), 1);
        assert!(g.same_group(1, 2));
        assert!(!g.same_group(3, 4));
        assert_eq!(g.group_base(1), 4);
        // degenerate maps
        assert_eq!(GroupMap::new(4, 1).num_groups(), 4);
        assert_eq!(GroupMap::new(4, 4).num_groups(), 1);
        assert!(GroupMap::new(4, 4).same_group(0, 3));
        assert!(!GroupMap::new(4, 1).same_group(0, 1));
    }

    #[test]
    #[should_panic(expected = "must divide")]
    fn group_map_rejects_ragged() {
        GroupMap::new(10, 4);
    }

    #[test]
    fn hier_model_selects_tier() {
        let h =
            HierCostModel::with_inter(CostModel::new(200e-6, 2e-9, 0.0, 0), GroupMap::new(8, 4));
        let m = 1 << 20;
        // intra: ~0.5 µs + 1 MiB / 100 GB/s ≈ 11 µs
        let intra = h.nominal(0, 3, m);
        // inter: 200 µs + 1 MiB / 0.5 GB/s ≈ 2.3 ms
        let inter = h.nominal(0, 4, m);
        assert!(intra < 2e-5, "intra={intra}");
        assert!(inter > 1e-3, "inter={inter}");
        assert_eq!(h.nominal(3, 0, m), intra);
        assert_eq!(h.message_time(0, 3, m), intra);
        assert!(h.nominal(5, 6, m) < h.nominal(5, 2, m));
    }
}
