//! MPI-like transport substrate, split into two layers:
//!
//! * **Link layer** ([`link`]) — message *delivery* only: enqueue,
//!   poll, park, drain, in-flight accounting, behind the [`Link`]
//!   trait.  Two implementations: [`link::InprocLink`] (threads as
//!   ranks, the historical in-process fabric, bit-identical timings)
//!   and [`tcp::TcpLink`] (one OS process per rank, length-prefixed
//!   frames over `TcpStream`, wall clock only — docs/transport.md).
//! * **Accounting layer** ([`inproc`]) — clocks, the α–β cost stamps,
//!   the hidden/exposed overlap ledger and per-rank traffic counters,
//!   link-agnostic.  Its public API (`Fabric`/`Endpoint`/request
//!   handles) predates the split and is unchanged, so collectives and
//!   coordinator code never see which wire they run over.
//!
//! The paper runs on MPI over InfiniBand/Aries; by default each rank is
//! a thread and messages are real buffers moved through per-rank
//! mailboxes.  Non-blocking semantics mirror the MPI primitives the
//! paper uses (§5.1): `isend` / `irecv` return request handles;
//! `test` is a non-blocking progress poll (MPI_Test/MPI_TestAll);
//! `wait` blocks (MPI_Wait/MPI_WaitAll).
//!
//! Timing is charged by the α–β cost model in [`simnet`]: a message of
//! M bytes becomes *visible* to the receiver `α + M·β (+ noise)` after
//! the send — so a receiver that arrives later than that observes zero
//! exposed communication time, exactly the overlap behaviour the paper
//! exploits.  With [`simnet::CostModel::zero`] the transport is a plain
//! (correctness-only) message layer.
//!
//! ## Clock modes
//!
//! The fabric runs under one of two clocks ([`clock`]):
//!
//! * **Wall** ([`Fabric::new`], the default) — arrival instants are real
//!   [`std::time::Instant`]s, blocking waits sleep out the simulated
//!   wire time, and exposed waits are measured with the OS clock.
//!   Physically real overlap, but timings vary run to run and the
//!   wall-clock cost of a simulated second is a real second.
//! * **Virtual** ([`Fabric::new_virtual`]) — deterministic discrete-event
//!   time.  Each rank owns a logical clock advanced by explicit compute
//!   charges ([`Endpoint::advance`]) and by message arrival instants on
//!   blocking receives; `RecvReq::test`/`wait` compare logical arrival
//!   instants instead of sleeping, and the exposed wait is *computed*
//!   (`max(0, arrival − now)`), never measured.  Timing metrics are
//!   bit-reproducible given the same configuration and seed, and a run
//!   at p = 1024 costs only the real compute the backend performs.
//!   See `docs/virtual-time.md` for the full determinism argument.
//!
//! Step and wait accounting that works under either mode goes through
//! [`Endpoint::mark`] / [`Endpoint::elapsed`] /
//! [`Endpoint::comm_wait_since`], which the coordinator uses in place of
//! raw `Instant::now()` arithmetic.
//!
//! The non-blocking collective engine
//! ([`crate::collectives::IAllreduce`]) additionally uses the *raw*
//! primitives — [`Endpoint::isend_at`] (send stamped at an explicit
//! logical instant) and [`RecvReq::test_raw`] / [`RecvReq::wait_raw`]
//! (harvest as soon as queued, bypassing clock and ledger) — to model a
//! dedicated communication-progress thread whose rounds advance at
//! message-arrival instants independent of the caller's clock; the
//! hidden/exposed ledger is settled when the collective is harvested.

pub mod clock;
pub mod fault;
pub mod hybrid;
pub mod inproc;
pub mod link;
pub mod simnet;
pub mod tcp;

pub use clock::{Clock, ClockMode, TimeMark};
pub use fault::FaultyLink;
pub use hybrid::HybridLink;
pub use inproc::{Counters, Endpoint, Fabric, RecvReq, SendReq};
pub use link::{InprocLink, Link, QuiesceError, SchedLink, Stamp};
pub use simnet::{CostModel, GroupMap, HierCostModel};
pub use tcp::{TcpLink, TcpLinkBuilder};

/// Message tags name the logical channel, mirroring MPI tags.
/// Layer-wise gradient exchange uses `Tag::layer(i)`.
///
/// Bit layout of the `u64` (fields are disjoint, so `kind`, `chan`,
/// `round` and `sub` can never collide with each other):
///
/// ```text
///   63      60 59              44 43                      16 15       0
///   +--------+------------------+--------------------------+---------+
///   |  kind  |  chan (layer i)  |  round (call separator)  |   sub   |
///   | 4 bits |     16 bits      |         28 bits          | 16 bits |
///   +--------+------------------+--------------------------+---------+
/// ```
///
/// `round` is 28 bits wide so per-step tags do not wrap until ~268M
/// steps (the old 16-bit field silently collided after 65,536 steps),
/// and the layer index lives in its own dedicated field instead of the
/// low bits (where i ≥ 256 used to bleed into `sub`).  Overflowing any
/// field is a programming error and panics rather than aliasing a
/// channel.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Tag(pub u64);

const KIND_SHIFT: u32 = 60;
const CHAN_SHIFT: u32 = 44;
const CHAN_BITS: u32 = 16;
const ROUND_SHIFT: u32 = 16;
const ROUND_BITS: u32 = 28;
const SUB_BITS: u32 = 16;

impl Tag {
    pub const MODEL: Tag = Tag(1u64 << KIND_SHIFT);
    pub const SAMPLES: Tag = Tag(2u64 << KIND_SHIFT);
    pub const LABELS: Tag = Tag(3u64 << KIND_SHIFT);
    pub const REDUCE: Tag = Tag(4u64 << KIND_SHIFT);
    pub const CTRL: Tag = Tag(5u64 << KIND_SHIFT);

    pub const BCAST: Tag = Tag(7u64 << KIND_SHIFT);

    /// Per-layer gradient channel (paper §5: layer-wise async exchange).
    /// The index occupies the dedicated 16-bit `chan` field.
    pub fn layer(i: usize) -> Tag {
        assert!(
            i < (1usize << CHAN_BITS),
            "layer index {i} overflows the {CHAN_BITS}-bit chan field"
        );
        Tag((6u64 << KIND_SHIFT) | ((i as u64) << CHAN_SHIFT))
    }

    /// Collective-call separator (one per allreduce invocation / step).
    /// Uses a dedicated 28-bit field so it cannot collide with `sub`,
    /// `layer` or the tag kind, and does not wrap at 65,536 steps.
    pub fn round(self, r: usize) -> Tag {
        assert!(
            (r as u64) < (1u64 << ROUND_BITS),
            "round {r} overflows the {ROUND_BITS}-bit round field"
        );
        let mask = ((1u64 << ROUND_BITS) - 1) << ROUND_SHIFT;
        Tag((self.0 & !mask) | ((r as u64) << ROUND_SHIFT))
    }

    /// The tag's kind field.  The transport's codec auto-path encodes
    /// only *payload* kinds (model/reduce/layer/bcast); bookkeeping
    /// channels (samples/labels/ctrl) always ride dense f32.
    pub fn kind(self) -> u64 {
        self.0 >> KIND_SHIFT
    }

    /// Whether messages on this tag carry model/gradient payloads that
    /// the wire codec may compress.
    pub fn is_payload_kind(self) -> bool {
        matches!(self.kind(), 1 | 4 | 6 | 7)
    }

    /// Whether messages on this tag are *gossip model* traffic — the
    /// only kinds the fault layer may drop or duplicate.  Collective
    /// rounds (`REDUCE`/`BCAST`) and bookkeeping channels block forever
    /// on a missing frame, so they are exempt; gossip mixing tolerates
    /// a lost exchange by construction (paper §4.5: no global barrier).
    pub fn is_gossip_model_kind(self) -> bool {
        matches!(self.kind(), 1 | 6)
    }

    /// The tag's round field (the call/step separator set by
    /// [`round`](Self::round)) — the fault layer keys kill/slow gating
    /// on it.
    pub fn round_of(self) -> usize {
        ((self.0 >> ROUND_SHIFT) & ((1u64 << ROUND_BITS) - 1)) as usize
    }

    /// Intra-collective step separator (ring steps, tree phases).
    pub fn sub(self, s: usize) -> Tag {
        assert!(
            (s as u64) < (1u64 << SUB_BITS),
            "sub-step {s} overflows the {SUB_BITS}-bit sub field"
        );
        let mask = (1u64 << SUB_BITS) - 1;
        Tag((self.0 & !mask) | s as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tags_distinct() {
        assert_ne!(Tag::MODEL, Tag::SAMPLES);
        assert_ne!(Tag::layer(0), Tag::layer(1));
        assert_ne!(Tag::layer(3), Tag::MODEL);
        assert_ne!(Tag::REDUCE.round(0), Tag::REDUCE.round(1));
        assert_ne!(Tag::REDUCE.round(7), Tag::CTRL.round(7));
        // round and sub live in disjoint bit fields
        assert_ne!(Tag::REDUCE.round(1).sub(0), Tag::REDUCE.round(0).sub(1));
        assert_eq!(Tag::REDUCE.round(1).round(2), Tag::REDUCE.round(2));
        assert_ne!(Tag::BCAST.round(3), Tag::REDUCE.round(3));
    }

    #[test]
    fn round_survives_16bit_overflow() {
        // regression: the old layout masked rounds to 16 bits, so step
        // 65_536 aliased step 0 and long runs crossed messages
        assert_ne!(Tag::REDUCE.round(65_536), Tag::REDUCE.round(0));
        assert_ne!(Tag::REDUCE.round(65_537), Tag::REDUCE.round(1));
        assert_ne!(Tag::layer(2).round(100_000), Tag::layer(2).round(100_001));
        assert_eq!(Tag::CTRL.round(1 << 27).round(3), Tag::CTRL.round(3));
    }

    #[test]
    fn layer_index_has_its_own_field() {
        // regression: the old layout put the layer index in the low bits,
        // so layer(256) == layer(0).sub(1)
        assert_ne!(Tag::layer(256), Tag::layer(0).sub(1));
        assert_ne!(Tag::layer(512).round(9), Tag::layer(0).round(9).sub(2));
        // deep layer indices never perturb round/sub
        for i in [0usize, 1, 255, 256, 257, 4095, 65_535] {
            assert_eq!(Tag::layer(i).round(5).sub(9), Tag::layer(i).sub(9).round(5));
            assert_ne!(Tag::layer(i).round(5), Tag::layer(i).round(6));
        }
        assert_ne!(Tag::layer(256).round(1).sub(2), Tag::layer(257).round(1).sub(2));
    }

    #[test]
    fn payload_kinds_are_compressible_bookkeeping_is_not() {
        for t in [Tag::MODEL, Tag::REDUCE, Tag::layer(3), Tag::BCAST] {
            assert!(t.round(9).sub(1).is_payload_kind(), "{t:?}");
        }
        for t in [Tag::SAMPLES, Tag::LABELS, Tag::CTRL] {
            assert!(!t.round(9).is_payload_kind(), "{t:?}");
        }
    }

    #[test]
    fn round_of_reads_back_the_round_field() {
        assert_eq!(Tag::MODEL.round(12_345).round_of(), 12_345);
        assert_eq!(Tag::layer(7).round(99).sub(3).round_of(), 99);
        assert_eq!(Tag::CTRL.round_of(), 0);
    }

    #[test]
    fn gossip_model_kinds_exclude_collectives_and_bookkeeping() {
        assert!(Tag::MODEL.round(3).is_gossip_model_kind());
        assert!(Tag::layer(2).round(3).is_gossip_model_kind());
        for t in [Tag::REDUCE, Tag::BCAST, Tag::SAMPLES, Tag::LABELS, Tag::CTRL] {
            assert!(!t.round(3).is_gossip_model_kind(), "{t:?}");
        }
    }

    #[test]
    #[should_panic(expected = "overflows")]
    fn round_overflow_panics_instead_of_aliasing() {
        let _ = Tag::REDUCE.round(1 << ROUND_BITS);
    }

    #[test]
    #[should_panic(expected = "overflows")]
    fn layer_overflow_panics_instead_of_aliasing() {
        let _ = Tag::layer(1 << CHAN_BITS);
    }
}
