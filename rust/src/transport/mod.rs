//! MPI-like in-process transport substrate.
//!
//! The paper runs on MPI over InfiniBand/Aries; here each rank is a
//! thread and messages are real buffers moved through per-rank mailboxes
//! ([`inproc`]).  Non-blocking semantics mirror the MPI primitives the
//! paper uses (§5.1): `isend` / `irecv` return request handles;
//! `test` is a non-blocking progress poll (MPI_Test/MPI_TestAll);
//! `wait` blocks (MPI_Wait/MPI_WaitAll).
//!
//! Timing is charged by the α–β cost model in [`simnet`]: a message of
//! M bytes becomes *visible* to the receiver `α + M·β (+ noise)` after
//! the send — so a receiver that arrives later than that observes zero
//! exposed communication time, exactly the overlap behaviour the paper
//! exploits.  With [`simnet::CostModel::zero`] the transport is a plain
//! (correctness-only) message layer.

pub mod inproc;
pub mod simnet;

pub use inproc::{Endpoint, Fabric, RecvReq, SendReq};
pub use simnet::CostModel;

/// Message tags name the logical channel, mirroring MPI tags.
/// Layer-wise gradient exchange uses `Tag::layer(i)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Tag(pub u64);

impl Tag {
    pub const MODEL: Tag = Tag(1 << 40);
    pub const SAMPLES: Tag = Tag(2 << 40);
    pub const LABELS: Tag = Tag(3 << 40);
    pub const REDUCE: Tag = Tag(4 << 40);
    pub const CTRL: Tag = Tag(5 << 40);

    pub const BCAST: Tag = Tag(7 << 40);

    /// Per-layer gradient channel (paper §5: layer-wise async exchange).
    pub fn layer(i: usize) -> Tag {
        Tag((6u64 << 40) | i as u64)
    }

    /// Collective-call separator (one per allreduce invocation).
    /// Uses a dedicated 16-bit field so it cannot collide with `sub`.
    pub fn round(self, r: usize) -> Tag {
        Tag((self.0 & !(0xFFFFu64 << 24)) | ((r as u64 & 0xFFFF) << 24))
    }

    /// Intra-collective step separator (ring steps, tree phases).
    pub fn sub(self, s: usize) -> Tag {
        Tag((self.0 & !(0xFFFFu64 << 8)) | ((s as u64 & 0xFFFF) << 8))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tags_distinct() {
        assert_ne!(Tag::MODEL, Tag::SAMPLES);
        assert_ne!(Tag::layer(0), Tag::layer(1));
        assert_ne!(Tag::layer(3), Tag::MODEL);
        assert_ne!(Tag::REDUCE.round(0), Tag::REDUCE.round(1));
        assert_ne!(Tag::REDUCE.round(7), Tag::CTRL.round(7));
        // round and sub live in disjoint bit fields
        assert_ne!(Tag::REDUCE.round(1).sub(0), Tag::REDUCE.round(0).sub(1));
        assert_eq!(Tag::REDUCE.round(1).round(2), Tag::REDUCE.round(2));
        assert_ne!(Tag::BCAST.round(3), Tag::REDUCE.round(3));
    }
}
