//! Wire codecs: the typed payload every transport layer carries, plus
//! the encoders that trade exchange fidelity for wire bytes.
//!
//! GossipGraD's premise is that the wire is the bottleneck at scale
//! (paper §1, Fig 2(a)); the related gossip-SGD work (GoSGD, Elastic
//! Gossip — PAPERS.md) competes on exactly the bandwidth axis this
//! module opens.  Every message on the fabric is a [`Payload`]: either
//! a dense `f32` vector (the historical wire format, bit-identical
//! default) or an encoded byte buffer tagged with its [`Encoding`].
//! The accounting layer charges [`Payload::wire_bytes`] — *compressed*
//! bytes — to the α–β cost model, so both the measured fabric and the
//! closed-form efficiency curves ([`crate::sim::efficiency`]) see the
//! bandwidth win.  See `docs/wire-codecs.md`.
//!
//! Four codecs ship:
//!
//! * [`Codec::F32`] — identity.  4 bytes/element; payloads stay
//!   `Payload::F32` end to end, so runs are bit-identical
//!   (`param_hash`) to the pre-codec stack.
//! * [`Codec::Bf16`] — bfloat16 truncation with round-to-nearest-even.
//!   2 bytes/element, relative error ≤ 2⁻⁸.
//! * [`Codec::Int8`] — linear 8-bit quantization with one `f32` scale
//!   per [`INT8_CHUNK`]-element chunk (scale = chunk max-abs / 127).
//!   ~1 byte/element; absolute error ≤ scale/2 per chunk.
//! * [`Codec::TopK`] — magnitude sparsification: the k = max(1, n/16)
//!   largest-|v| coordinates as `(u32 index, f32 value)` pairs, with
//!   **error feedback**: unsent mass is held rank-side in a
//!   per-(destination, stream) residual ([`Encoder`]) and added to the
//!   next message on that stream, so no gradient/model mass is ever
//!   dropped — only delayed (encoded + residual == input exactly; the
//!   selected values cross the wire unquantized).
//!
//! Stateless codecs (F32/Bf16/Int8) can be applied anywhere — the
//! transport auto-encodes payload-kind tags via
//! [`Codec::encode_stateless`].  TopK is stateful (residuals) and
//! sparse (a dense decode zero-fills unsent coordinates), so it is only
//! applied at coordinator sites that own an [`Encoder`] and mix
//! sparsely ([`mix_payload_into`]) or sum densely (PS aggregation,
//! where zero-filling is exact); the stateless fallback for TopK is
//! dense f32.

use crate::nativenet::ops;
use crate::pool::BufferPool;
use std::collections::HashMap;

/// Elements per int8 quantization chunk (one f32 scale each).
pub const INT8_CHUNK: usize = 256;

/// Coordinates kept by top-k sparsification: max(1, n/16).
pub fn top_k(n: usize) -> usize {
    if n == 0 {
        0
    } else {
        (n / 16).max(1)
    }
}

/// On-wire encoding id, carried in TCP frames as one byte.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Encoding {
    F32 = 0,
    Bf16 = 1,
    Int8 = 2,
    TopK = 3,
}

impl Encoding {
    pub fn from_u8(b: u8) -> Option<Encoding> {
        match b {
            0 => Some(Encoding::F32),
            1 => Some(Encoding::Bf16),
            2 => Some(Encoding::Int8),
            3 => Some(Encoding::TopK),
            _ => None,
        }
    }
}

/// A message body as it crosses the wire.  `F32` is the dense fast
/// path (no serialization on the in-process link — the vector moves by
/// pointer); `Bytes` is an encoded buffer plus the element count `n`
/// needed to decode it.  The accounting layer charges
/// [`wire_bytes`](Self::wire_bytes), so compressed payloads cost
/// compressed bytes on the simulated wire.
#[derive(Clone, Debug)]
pub enum Payload {
    F32(Vec<f32>),
    Bytes {
        enc: Encoding,
        n: u32,
        bytes: Vec<u8>,
    },
}

impl Payload {
    /// Element count of the decoded vector.
    pub fn len(&self) -> usize {
        match self {
            Payload::F32(v) => v.len(),
            Payload::Bytes { n, .. } => *n as usize,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn encoding(&self) -> Encoding {
        match self {
            Payload::F32(_) => Encoding::F32,
            Payload::Bytes { enc, .. } => *enc,
        }
    }

    /// Bytes this payload occupies on the wire — what the α–β cost
    /// model and the traffic counters charge.
    pub fn wire_bytes(&self) -> usize {
        match self {
            Payload::F32(v) => v.len() * 4,
            Payload::Bytes { bytes, .. } => bytes.len(),
        }
    }

    /// Decode to a dense `f32` vector.  TopK zero-fills unsent
    /// coordinates (exact for summation — PS aggregation — but *not*
    /// for averaging; mixing uses [`mix_payload_into`] instead).
    pub fn decode(self) -> Vec<f32> {
        match self {
            Payload::F32(v) => v,
            Payload::Bytes { enc, n, bytes } => match enc {
                Encoding::F32 => f32_decode(&bytes),
                Encoding::Bf16 => bf16_decode(&bytes),
                Encoding::Int8 => int8_decode(n as usize, &bytes),
                Encoding::TopK => topk_decode(n as usize, &bytes),
            },
        }
    }

    /// Pool-aware [`decode`](Self::decode): bit-identical values, but
    /// the dense output is drawn from `pool` and the spent byte buffer
    /// is recycled into it — the decode-in-place harvest path (a TCP
    /// frame's bytes land in a pooled buffer, decode into a pooled f32
    /// buffer, and both keep cycling).
    pub fn decode_pooled(self, pool: &BufferPool) -> Vec<f32> {
        match self {
            Payload::F32(v) => v,
            Payload::Bytes { enc, n, bytes } => {
                let mut out = pool.get_f32(n as usize);
                match enc {
                    Encoding::F32 => f32_decode_into(&bytes, &mut out),
                    Encoding::Bf16 => bf16_decode_into(&bytes, &mut out),
                    Encoding::Int8 => int8_decode_into(n as usize, &bytes, &mut out),
                    // `out` arrives zero-filled; scatter the sent coords
                    Encoding::TopK => topk_scatter_into(&bytes, &mut out),
                }
                pool.put_u8(bytes);
                out
            }
        }
    }
}

/// The configured wire codec (a `RunConfig` axis, `--codec`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Codec {
    #[default]
    F32,
    Bf16,
    Int8,
    TopK,
}

impl Codec {
    pub fn parse(s: &str) -> Result<Codec, String> {
        match s {
            "f32" => Ok(Codec::F32),
            "bf16" => Ok(Codec::Bf16),
            "int8" => Ok(Codec::Int8),
            "topk" => Ok(Codec::TopK),
            other => Err(format!(
                "unknown codec '{other}' (expected f32|bf16|int8|topk)"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Codec::F32 => "f32",
            Codec::Bf16 => "bf16",
            Codec::Int8 => "int8",
            Codec::TopK => "topk",
        }
    }

    /// Stateless encode — the transport's auto path for payload-kind
    /// tags.  TopK needs rank-side residual state and a sparse-aware
    /// receiver, so here it falls back to dense f32 (compression for
    /// TopK happens only at coordinator sites owning an [`Encoder`]).
    pub fn encode_stateless(&self, data: Vec<f32>) -> Payload {
        match self {
            Codec::F32 | Codec::TopK => Payload::F32(data),
            Codec::Bf16 => Payload::Bytes {
                enc: Encoding::Bf16,
                n: data.len() as u32,
                bytes: bf16_encode(&data),
            },
            Codec::Int8 => Payload::Bytes {
                enc: Encoding::Int8,
                n: data.len() as u32,
                bytes: int8_encode(&data),
            },
        }
    }

    /// Pool-aware [`encode_stateless`](Self::encode_stateless): the
    /// dense arms still move the owned input straight into the payload;
    /// compressing arms draw their byte output from `pool` and recycle
    /// the consumed input into it.  Byte-identical output.
    pub fn encode_stateless_pooled(&self, data: Vec<f32>, pool: &BufferPool) -> Payload {
        match self {
            Codec::F32 | Codec::TopK => Payload::F32(data),
            Codec::Bf16 => {
                let mut bytes = pool.get_u8_empty(2 * data.len());
                bf16_encode_into(&data, &mut bytes);
                let n = data.len() as u32;
                pool.put_f32(data);
                Payload::Bytes {
                    enc: Encoding::Bf16,
                    n,
                    bytes,
                }
            }
            Codec::Int8 => {
                let mut bytes = pool.get_u8_empty(Codec::Int8.wire_bytes_for(data.len()));
                int8_encode_into(&data, &mut bytes);
                let n = data.len() as u32;
                pool.put_f32(data);
                Payload::Bytes {
                    enc: Encoding::Int8,
                    n,
                    bytes,
                }
            }
        }
    }

    /// Closed-form wire bytes for an `n`-element message under this
    /// codec's *compressing* path (the [`Encoder`] path) — what the
    /// closed-form efficiency curves scale message sizes by.
    pub fn wire_bytes_for(&self, n: usize) -> usize {
        match self {
            Codec::F32 => 4 * n,
            Codec::Bf16 => 2 * n,
            Codec::Int8 => n + 4 * n.div_ceil(INT8_CHUNK),
            Codec::TopK => 8 * top_k(n),
        }
    }

    /// Closed-form wire bytes under the *stateless* path
    /// ([`encode_stateless`](Self::encode_stateless)): TopK rides dense
    /// f32 there (collective rounds, PS model broadcast).
    pub fn stateless_wire_bytes_for(&self, n: usize) -> usize {
        match self {
            Codec::TopK => 4 * n,
            _ => self.wire_bytes_for(n),
        }
    }
}

/// Stateful encoder: one per sending rank, holding the per-destination
/// per-stream error-feedback residuals that make TopK lossless over
/// time.  `stream` is the logical channel (layer index for layer-wise
/// exchange; 0 for monolithic) — residuals never mix across layers or
/// destinations.  For stateless codecs this is a thin wrapper.
pub struct Encoder {
    codec: Codec,
    residuals: HashMap<(usize, usize), Vec<f32>>,
}

impl Encoder {
    pub fn new(codec: Codec) -> Encoder {
        Encoder {
            codec,
            residuals: HashMap::new(),
        }
    }

    pub fn codec(&self) -> Codec {
        self.codec
    }

    /// Encode `data` for transmission to `dst` on `stream`.  TopK adds
    /// the stream's residual first (acc = data + residual), sends the
    /// top-k coordinates of acc exactly, and keeps the rest as the new
    /// residual — so `decode(payload) + residual == data + old_residual`
    /// bit-for-bit (values are partitioned, never quantized).
    pub fn encode(&mut self, dst: usize, stream: usize, data: &[f32]) -> Payload {
        match self.codec {
            Codec::F32 => Payload::F32(data.to_vec()),
            Codec::Bf16 => Payload::Bytes {
                enc: Encoding::Bf16,
                n: data.len() as u32,
                bytes: bf16_encode(data),
            },
            Codec::Int8 => Payload::Bytes {
                enc: Encoding::Int8,
                n: data.len() as u32,
                bytes: int8_encode(data),
            },
            Codec::TopK => {
                let res = self
                    .residuals
                    .entry((dst, stream))
                    .or_insert_with(|| vec![0.0; data.len()]);
                assert_eq!(res.len(), data.len(), "stream {stream} length changed");
                let mut acc: Vec<f32> =
                    data.iter().zip(res.iter()).map(|(&d, &r)| d + r).collect();
                let bytes = topk_extract(&mut acc);
                res.copy_from_slice(&acc);
                Payload::Bytes {
                    enc: Encoding::TopK,
                    n: data.len() as u32,
                    bytes,
                }
            }
        }
    }

    /// Owned-input [`encode`](Self::encode): the f32 arm **moves** the
    /// caller's buffer into the payload instead of copying it (the
    /// historical `data.to_vec()` double-copy); compressing arms
    /// delegate to the borrowing path.  Identical output.
    pub fn encode_owned(&mut self, dst: usize, stream: usize, data: Vec<f32>) -> Payload {
        match self.codec {
            Codec::F32 => Payload::F32(data),
            _ => self.encode(dst, stream, &data),
        }
    }

    /// Pool-aware [`encode`](Self::encode): identical payload bytes and
    /// residual updates, but the dense copy and the encoded byte output
    /// are drawn from `pool` instead of freshly allocated — the
    /// steady-state zero-allocation send path.
    pub fn encode_pooled(
        &mut self,
        dst: usize,
        stream: usize,
        data: &[f32],
        pool: &BufferPool,
    ) -> Payload {
        match self.codec {
            Codec::F32 => Payload::F32(pool.copy_f32(data)),
            Codec::Bf16 => {
                let mut bytes = pool.get_u8_empty(2 * data.len());
                bf16_encode_into(data, &mut bytes);
                Payload::Bytes {
                    enc: Encoding::Bf16,
                    n: data.len() as u32,
                    bytes,
                }
            }
            Codec::Int8 => {
                let mut bytes = pool.get_u8_empty(Codec::Int8.wire_bytes_for(data.len()));
                int8_encode_into(data, &mut bytes);
                Payload::Bytes {
                    enc: Encoding::Int8,
                    n: data.len() as u32,
                    bytes,
                }
            }
            Codec::TopK => {
                let res = self
                    .residuals
                    .entry((dst, stream))
                    .or_insert_with(|| vec![0.0; data.len()]);
                assert_eq!(res.len(), data.len(), "stream {stream} length changed");
                // acc[i] = data[i] + res[i], computed in a pooled buffer
                // (same f32 add as the collecting path in `encode`)
                let mut acc = pool.copy_f32(data);
                for (a, &r) in acc.iter_mut().zip(res.iter()) {
                    *a += r;
                }
                let bytes = topk_extract(&mut acc);
                res.copy_from_slice(&acc);
                pool.put_f32(acc);
                Payload::Bytes {
                    enc: Encoding::TopK,
                    n: data.len() as u32,
                    bytes,
                }
            }
        }
    }

    /// The current residual for `(dst, stream)` (empty if none) — test
    /// and introspection hook for the conservation property.
    pub fn residual(&self, dst: usize, stream: usize) -> &[f32] {
        self.residuals
            .get(&(dst, stream))
            .map_or(&[], |v| v.as_slice())
    }
}

/// GossipGraD pairwise mixing against an encoded partner payload:
/// `dst[i] <- (dst[i] + v[i]) / 2`.  Dense payloads mix every
/// coordinate (bit-identical to `ops::mix_into` on the decoded
/// vector); TopK payloads mix **only the transmitted coordinates**
/// (partial/elastic averaging — zero-filled coords would otherwise
/// halve untouched parameters).
pub fn mix_payload_into(dst: &mut [f32], p: Payload) {
    match p {
        Payload::Bytes {
            enc: Encoding::TopK,
            n,
            bytes,
        } => {
            assert_eq!(n as usize, dst.len(), "mix length mismatch");
            for c in bytes.chunks_exact(8) {
                let i = u32::from_le_bytes([c[0], c[1], c[2], c[3]]) as usize;
                let v = f32::from_le_bytes([c[4], c[5], c[6], c[7]]);
                dst[i] = (dst[i] + v) * 0.5;
            }
        }
        other => {
            let v = other.decode();
            assert_eq!(v.len(), dst.len(), "mix length mismatch");
            // chunked kernel — bit-identical to the plain zip loop
            ops::mix_into(dst, &v);
        }
    }
}

/// Pool-aware [`mix_payload_into`]: same numerics, but every consumed
/// buffer (the payload itself and any dense-decode scratch) returns to
/// `pool` — the steady-state zero-allocation harvest path.
pub fn mix_payload_recycle(dst: &mut [f32], p: Payload, pool: &BufferPool) {
    match p {
        Payload::Bytes {
            enc: Encoding::TopK,
            n,
            bytes,
        } => {
            assert_eq!(n as usize, dst.len(), "mix length mismatch");
            for c in bytes.chunks_exact(8) {
                let i = u32::from_le_bytes([c[0], c[1], c[2], c[3]]) as usize;
                let v = f32::from_le_bytes([c[4], c[5], c[6], c[7]]);
                dst[i] = (dst[i] + v) * 0.5;
            }
            pool.put_u8(bytes);
        }
        other => {
            let v = other.decode_pooled(pool);
            assert_eq!(v.len(), dst.len(), "mix length mismatch");
            ops::mix_into(dst, &v);
            pool.put_f32(v);
        }
    }
}

// ---- encode/decode kernels ---------------------------------------------

/// Bulk LE-bytes → f32 decode into one pre-sized buffer (the TCP
/// reader's frame payload lands here exactly once, at harvest).
pub fn f32_decode(bytes: &[u8]) -> Vec<f32> {
    let mut out = vec![0.0f32; bytes.len() / 4];
    f32_decode_into(bytes, &mut out);
    out
}

/// Decode-in-place form: LE bytes → the caller's (pooled) buffer.
pub fn f32_decode_into(bytes: &[u8], out: &mut [f32]) {
    debug_assert_eq!(bytes.len() % 4, 0);
    assert_eq!(out.len(), bytes.len() / 4, "decode length mismatch");
    for (o, c) in out.iter_mut().zip(bytes.chunks_exact(4)) {
        *o = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
    }
}

/// f32 → bfloat16 with round-to-nearest-even on the dropped 16
/// mantissa bits.
fn bf16_bits(x: f32) -> u16 {
    let b = x.to_bits();
    let r = 0x7fff + ((b >> 16) & 1);
    (b.wrapping_add(r) >> 16) as u16
}

fn bf16_encode(data: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(2 * data.len());
    bf16_encode_into(data, &mut out);
    out
}

/// Encode into a caller-provided (pooled) byte buffer; `out` is
/// cleared first.
fn bf16_encode_into(data: &[f32], out: &mut Vec<u8>) {
    out.clear();
    out.reserve(2 * data.len());
    for &x in data {
        out.extend_from_slice(&bf16_bits(x).to_le_bytes());
    }
}

fn bf16_decode(bytes: &[u8]) -> Vec<f32> {
    let mut out = vec![0.0f32; bytes.len() / 2];
    bf16_decode_into(bytes, &mut out);
    out
}

fn bf16_decode_into(bytes: &[u8], out: &mut [f32]) {
    debug_assert_eq!(bytes.len() % 2, 0);
    assert_eq!(out.len(), bytes.len() / 2, "decode length mismatch");
    for (o, c) in out.iter_mut().zip(bytes.chunks_exact(2)) {
        *o = f32::from_bits((u16::from_le_bytes([c[0], c[1]]) as u32) << 16);
    }
}

/// Layout: `[scale f32 LE × ceil(n/INT8_CHUNK)][q i8 × n]`.
fn int8_encode(data: &[f32]) -> Vec<u8> {
    let mut out = Vec::new();
    int8_encode_into(data, &mut out);
    out
}

/// Encode into a caller-provided (pooled) byte buffer; `out` is
/// cleared first.  Scales are written up front and read back during
/// quantization, so no scale scratch vector is allocated.
fn int8_encode_into(data: &[f32], out: &mut Vec<u8>) {
    let n = data.len();
    let nchunks = n.div_ceil(INT8_CHUNK);
    out.clear();
    out.reserve(4 * nchunks + n);
    for chunk in data.chunks(INT8_CHUNK) {
        let max = chunk.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let s = if max > 0.0 { max / 127.0 } else { 0.0 };
        out.extend_from_slice(&s.to_le_bytes());
    }
    for (ci, chunk) in data.chunks(INT8_CHUNK).enumerate() {
        let s = f32::from_le_bytes([out[4 * ci], out[4 * ci + 1], out[4 * ci + 2], out[4 * ci + 3]]);
        for &x in chunk {
            let q = if s > 0.0 {
                (x / s).round().clamp(-127.0, 127.0) as i8
            } else {
                0
            };
            out.push(q as u8);
        }
    }
}

fn int8_decode(n: usize, bytes: &[u8]) -> Vec<f32> {
    let mut out = vec![0.0f32; n];
    int8_decode_into(n, bytes, &mut out);
    out
}

/// Decode-in-place form: one scale read per chunk, no scale scratch.
fn int8_decode_into(n: usize, bytes: &[u8], out: &mut [f32]) {
    let nchunks = n.div_ceil(INT8_CHUNK);
    debug_assert_eq!(bytes.len(), 4 * nchunks + n);
    assert_eq!(out.len(), n, "decode length mismatch");
    let (sb, qb) = bytes.split_at(4 * nchunks);
    for (ci, (qchunk, ochunk)) in qb
        .chunks(INT8_CHUNK)
        .zip(out.chunks_mut(INT8_CHUNK))
        .enumerate()
    {
        let s = f32::from_le_bytes([sb[4 * ci], sb[4 * ci + 1], sb[4 * ci + 2], sb[4 * ci + 3]]);
        for (o, &q) in ochunk.iter_mut().zip(qchunk) {
            *o = (q as i8) as f32 * s;
        }
    }
}

/// Select the top-k coordinates of `acc` by |v| (ties broken by lower
/// index), serialize them as `(u32 idx LE, f32 val LE)` pairs in index
/// order, and zero them in `acc` (which becomes the new residual).
fn topk_extract(acc: &mut [f32]) -> Vec<u8> {
    let n = acc.len();
    let k = top_k(n);
    let mut idx: Vec<u32> = (0..n as u32).collect();
    idx.sort_unstable_by(|&a, &b| {
        let (xa, xb) = (acc[a as usize].abs(), acc[b as usize].abs());
        xb.partial_cmp(&xa)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let mut sel = idx[..k].to_vec();
    sel.sort_unstable();
    let mut bytes = Vec::with_capacity(8 * k);
    for &i in &sel {
        bytes.extend_from_slice(&i.to_le_bytes());
        bytes.extend_from_slice(&acc[i as usize].to_le_bytes());
        acc[i as usize] = 0.0;
    }
    bytes
}

/// Dense decode: zeros everywhere but the transmitted coordinates.
fn topk_decode(n: usize, bytes: &[u8]) -> Vec<f32> {
    let mut out = vec![0.0f32; n];
    topk_scatter_into(bytes, &mut out);
    out
}

/// Scatter the `(u32 idx, f32 val)` pairs into `out`, which the caller
/// must have zero-filled.
fn topk_scatter_into(bytes: &[u8], out: &mut [f32]) {
    for c in bytes.chunks_exact(8) {
        let i = u32::from_le_bytes([c[0], c[1], c[2], c[3]]) as usize;
        out[i] = f32::from_le_bytes([c[4], c[5], c[6], c[7]]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wave(n: usize) -> Vec<f32> {
        // deterministic, sign-varying, multi-scale values
        (0..n)
            .map(|i| ((i as f32 * 0.7).sin() + 0.001 * i as f32) * if i % 3 == 0 { -2.0 } else { 1.0 })
            .collect()
    }

    #[test]
    fn f32_payload_is_identity_and_charges_4_bytes_per_elem() {
        let data = wave(100);
        let p = Codec::F32.encode_stateless(data.clone());
        assert_eq!(p.wire_bytes(), 400);
        assert_eq!(p.encoding(), Encoding::F32);
        assert_eq!(p.decode(), data, "identity codec must be bit-exact");
    }

    #[test]
    fn bf16_roundtrip_within_relative_error_bound() {
        let data = wave(1000);
        let p = Codec::Bf16.encode_stateless(data.clone());
        assert_eq!(p.wire_bytes(), 2000, "2 bytes per element");
        let dec = p.decode();
        for (&x, &y) in data.iter().zip(&dec) {
            // 7 explicit mantissa bits + RNE: rel err <= 2^-8
            assert!(
                (x - y).abs() <= x.abs() / 256.0 + f32::MIN_POSITIVE,
                "bf16 error too large: {x} -> {y}"
            );
        }
    }

    #[test]
    fn bf16_exactly_representable_values_survive() {
        let data = vec![0.0, 1.0, -2.5, 0.5, -0.25, 104.0];
        let p = Codec::Bf16.encode_stateless(data.clone());
        assert_eq!(p.decode(), data);
    }

    #[test]
    fn int8_roundtrip_within_half_scale_per_chunk() {
        let data = wave(600); // 3 chunks, last one partial
        let p = Codec::Int8.encode_stateless(data.clone());
        assert_eq!(p.wire_bytes(), 600 + 4 * 3);
        let dec = p.decode();
        for (ci, chunk) in data.chunks(INT8_CHUNK).enumerate() {
            let max = chunk.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            let half_scale = max / 127.0 / 2.0 + 1e-7;
            for (j, &x) in chunk.iter().enumerate() {
                let y = dec[ci * INT8_CHUNK + j];
                assert!(
                    (x - y).abs() <= half_scale,
                    "int8 chunk {ci} error: {x} -> {y}"
                );
            }
        }
    }

    #[test]
    fn int8_chunks_isolate_scales() {
        // a huge value in chunk 0 must not destroy chunk 1's precision
        let mut data = vec![0.01f32; 2 * INT8_CHUNK];
        data[0] = 1000.0;
        let dec = Codec::Int8.encode_stateless(data.clone()).decode();
        assert!((dec[INT8_CHUNK] - 0.01).abs() <= 0.01 / 127.0 / 2.0 + 1e-7);
    }

    #[test]
    fn int8_all_zero_chunk_decodes_to_zero() {
        let dec = Codec::Int8.encode_stateless(vec![0.0; 300]).decode();
        assert!(dec.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn topk_error_feedback_conserves_mass_exactly() {
        let data = wave(256);
        let mut enc = Encoder::new(Codec::TopK);
        let p = enc.encode(3, 1, &data);
        assert_eq!(p.wire_bytes(), 8 * 16, "k = 256/16 pairs of 8 bytes");
        let dec = p.decode();
        let res = enc.residual(3, 1);
        // partition, not quantization: decoded + residual == input, bitwise
        for i in 0..data.len() {
            assert_eq!(
                (dec[i] + res[i]).to_bits(),
                data[i].to_bits(),
                "coordinate {i} not conserved"
            );
            assert!(
                dec[i] == 0.0 || res[i] == 0.0,
                "coordinate {i} split across wire and residual"
            );
        }
    }

    #[test]
    fn topk_residual_feeds_into_next_message() {
        // round 1 sends the single largest coord; round 2's selection
        // sees data + residual, so a coord starved in round 1 wins
        let mut enc = Encoder::new(Codec::TopK);
        let p1 = enc.encode(0, 0, &[1.0, 0.9, 0.0, 0.0]).decode();
        assert_eq!(p1, vec![1.0, 0.0, 0.0, 0.0]);
        // acc = [0.1 + 0, 0.1 + 0.9, 0, 0] -> coord 1 now largest
        let p2 = enc.encode(0, 0, &[0.1, 0.1, 0.0, 0.0]).decode();
        assert_eq!(p2, vec![0.0, 1.0, 0.0, 0.0]);
        assert_eq!(enc.residual(0, 0), &[0.1, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn topk_selection_is_deterministic_under_ties() {
        let mut e1 = Encoder::new(Codec::TopK);
        let mut e2 = Encoder::new(Codec::TopK);
        let data = vec![0.5f32; 64]; // all tied: lowest indices win
        let p1 = e1.encode(0, 0, &data);
        let p2 = e2.encode(0, 0, &data);
        match (&p1, &p2) {
            (
                Payload::Bytes { bytes: b1, .. },
                Payload::Bytes { bytes: b2, .. },
            ) => assert_eq!(b1, b2),
            _ => panic!("topk must produce byte payloads"),
        }
        let dec = p1.decode();
        for (i, &v) in dec.iter().enumerate() {
            assert_eq!(v, if i < 4 { 0.5 } else { 0.0 }, "ties break low-index");
        }
    }

    #[test]
    fn residuals_are_per_destination_and_stream() {
        let mut enc = Encoder::new(Codec::TopK);
        enc.encode(1, 0, &[1.0, 0.5]);
        enc.encode(2, 0, &[1.0, 0.25]);
        enc.encode(1, 7, &[1.0, 0.125]);
        assert_eq!(enc.residual(1, 0), &[0.0, 0.5]);
        assert_eq!(enc.residual(2, 0), &[0.0, 0.25]);
        assert_eq!(enc.residual(1, 7), &[0.0, 0.125]);
        assert_eq!(enc.residual(9, 9), &[] as &[f32]);
    }

    #[test]
    fn mix_payload_dense_matches_elementwise_average() {
        let mut a = wave(50);
        let want: Vec<f32> = a.iter().map(|&x| (x + 1.0) * 0.5).collect();
        mix_payload_into(&mut a, Payload::F32(vec![1.0; 50]));
        assert_eq!(a, want);
    }

    #[test]
    fn mix_payload_topk_touches_only_sent_coords() {
        let mut enc = Encoder::new(Codec::TopK);
        let mut theirs = vec![0.0f32; 32];
        theirs[5] = 8.0; // the one coord that crosses the wire (k = 2)
        theirs[9] = 4.0;
        let p = enc.encode(0, 0, &theirs);
        let mut mine = vec![1.0f32; 32];
        mix_payload_into(&mut mine, p);
        for (i, &v) in mine.iter().enumerate() {
            match i {
                5 => assert_eq!(v, 4.5),
                9 => assert_eq!(v, 2.5),
                _ => assert_eq!(v, 1.0, "untouched coord {i} perturbed"),
            }
        }
    }

    #[test]
    fn wire_bytes_for_matches_actual_encoded_size() {
        for n in [1usize, 15, 16, 100, 256, 257, 1000] {
            let data = wave(n);
            for codec in [Codec::F32, Codec::Bf16, Codec::Int8] {
                let p = codec.encode_stateless(data.clone());
                assert_eq!(
                    p.wire_bytes(),
                    codec.wire_bytes_for(n),
                    "{codec:?} n={n}"
                );
            }
            let mut enc = Encoder::new(Codec::TopK);
            let p = enc.encode(0, 0, &data);
            assert_eq!(p.wire_bytes(), Codec::TopK.wire_bytes_for(n), "topk n={n}");
            // stateless TopK rides dense
            assert_eq!(Codec::TopK.stateless_wire_bytes_for(n), 4 * n);
            assert_eq!(
                Codec::TopK.encode_stateless(data.clone()).wire_bytes(),
                4 * n
            );
        }
    }

    #[test]
    fn codec_names_parse_back() {
        for codec in [Codec::F32, Codec::Bf16, Codec::Int8, Codec::TopK] {
            assert_eq!(Codec::parse(codec.name()), Ok(codec));
        }
        assert!(Codec::parse("fp8").is_err());
        assert_eq!(Codec::default(), Codec::F32);
    }

    #[test]
    fn encoding_byte_roundtrip() {
        for enc in [Encoding::F32, Encoding::Bf16, Encoding::Int8, Encoding::TopK] {
            assert_eq!(Encoding::from_u8(enc as u8), Some(enc));
        }
        assert_eq!(Encoding::from_u8(9), None);
    }

    fn assert_payload_bits_eq(a: &Payload, b: &Payload, ctx: &str) {
        match (a, b) {
            (Payload::F32(x), Payload::F32(y)) => {
                assert_eq!(x.len(), y.len(), "{ctx}: length");
                for (i, (u, v)) in x.iter().zip(y).enumerate() {
                    assert_eq!(u.to_bits(), v.to_bits(), "{ctx}: coord {i}");
                }
            }
            (
                Payload::Bytes {
                    enc: e1,
                    n: n1,
                    bytes: b1,
                },
                Payload::Bytes {
                    enc: e2,
                    n: n2,
                    bytes: b2,
                },
            ) => {
                assert_eq!(e1, e2, "{ctx}: encoding");
                assert_eq!(n1, n2, "{ctx}: n");
                assert_eq!(b1, b2, "{ctx}: bytes");
            }
            _ => panic!("{ctx}: payload variants differ"),
        }
    }

    #[test]
    fn pooled_encode_and_decode_match_fresh_paths_bitwise() {
        use crate::pool::BufferPool;
        let pool = BufferPool::new();
        let data = wave(600);
        for codec in [Codec::F32, Codec::Bf16, Codec::Int8, Codec::TopK] {
            let mut fresh = Encoder::new(codec);
            let mut pooled = Encoder::new(codec);
            // multiple rounds so TopK residuals evolve and the pool
            // serves warm buffers
            for round in 0..3 {
                let ctx = format!("{codec:?} round {round}");
                let a = fresh.encode(1, 0, &data);
                let b = pooled.encode_pooled(1, 0, &data, &pool);
                assert_payload_bits_eq(&a, &b, &ctx);
                let da = a.decode();
                let db = b.decode_pooled(&pool);
                for (i, (u, v)) in da.iter().zip(&db).enumerate() {
                    assert_eq!(u.to_bits(), v.to_bits(), "{ctx}: decode coord {i}");
                }
                pool.put_f32(db);
            }
            assert_eq!(fresh.residual(1, 0), pooled.residual(1, 0), "{codec:?}");
        }
    }

    #[test]
    fn encode_stateless_pooled_matches_fresh() {
        use crate::pool::BufferPool;
        let pool = BufferPool::new();
        let data = wave(300);
        for codec in [Codec::F32, Codec::Bf16, Codec::Int8, Codec::TopK] {
            let a = codec.encode_stateless(data.clone());
            let b = codec.encode_stateless_pooled(data.clone(), &pool);
            assert_payload_bits_eq(&a, &b, codec.name());
        }
    }

    #[test]
    fn encode_owned_moves_f32_without_copy() {
        let mut enc = Encoder::new(Codec::F32);
        let data = wave(64);
        let ptr = data.as_ptr();
        match enc.encode_owned(0, 0, data) {
            Payload::F32(v) => assert_eq!(v.as_ptr(), ptr, "owned f32 must move"),
            _ => panic!("f32 codec must keep dense payloads"),
        }
        // lossy codecs take the borrowing path and stay byte-identical,
        // residuals included
        let data = wave(128);
        let mut e1 = Encoder::new(Codec::TopK);
        let mut e2 = Encoder::new(Codec::TopK);
        for round in 0..3 {
            let a = e1.encode(2, 5, &data);
            let b = e2.encode_owned(2, 5, data.clone());
            assert_payload_bits_eq(&a, &b, &format!("topk owned round {round}"));
        }
        assert_eq!(e1.residual(2, 5), e2.residual(2, 5));
    }

    #[test]
    fn mix_payload_recycle_matches_mix_and_returns_buffers() {
        use crate::pool::BufferPool;
        let pool = BufferPool::new();
        let data = wave(256);
        // stateless codecs: the same encoder emits identical payloads
        // for identical inputs, so the two mixes must agree bitwise
        for codec in [Codec::F32, Codec::Bf16, Codec::Int8] {
            let mut enc = Encoder::new(codec);
            let mut a = wave(256);
            let mut b = a.clone();
            mix_payload_into(&mut a, enc.encode(0, 0, &data));
            mix_payload_recycle(&mut b, enc.encode(0, 0, &data), &pool);
            for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "{codec:?} coord {i}");
            }
        }
        // TopK advances its residual per encode, so compare two fresh
        // encoders fed the same input (identical payloads by the
        // determinism test above)
        let mut e1 = Encoder::new(Codec::TopK);
        let mut e2 = Encoder::new(Codec::TopK);
        let mut a = wave(256);
        let mut b = a.clone();
        mix_payload_into(&mut a, e1.encode(0, 0, &data));
        mix_payload_recycle(&mut b, e2.encode(0, 0, &data), &pool);
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "topk coord {i}");
        }
        assert!(pool.free_buffers() > 0, "spent payloads must be shelved");
    }

    #[test]
    fn raw_f32_bytes_decode_bulk() {
        // the TCP reader path: frame bytes held raw, decoded at harvest
        let data = wave(33);
        let mut bytes = Vec::new();
        for &x in &data {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        let p = Payload::Bytes {
            enc: Encoding::F32,
            n: 33,
            bytes,
        };
        assert_eq!(p.wire_bytes(), 132);
        assert_eq!(p.decode(), data);
    }
}
