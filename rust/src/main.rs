//! `gossipgrad` — CLI launcher for the GossipGraD reproduction.
//!
//! Subcommands:
//!   train      run a distributed training job (threads-as-ranks)
//!   sweep      efficiency sweep over rank counts (real runs)
//!   sim        scale simulation (Table 7-style, up to 1024 devices)
//!   inspect    print artifact metadata
//!
//! Examples:
//!   gossipgrad train --model mlp --algo gossip --ranks 8 --steps 200
//!   gossipgrad train --config configs/mnist_gossip_32.json
//!   gossipgrad sim --workload resnet50 --algos gossip,agd-ring
//!   gossipgrad inspect --model transformer

use anyhow::{bail, Context, Result};
use gossipgrad::collectives::Algorithm;
use gossipgrad::config::{Algo, RunConfig};
use gossipgrad::coordinator;
use gossipgrad::metrics::sparkline;
use gossipgrad::runtime::artifacts::{default_dir, ArtifactSet};
use gossipgrad::sim::{self, Schedule, Workload};
use gossipgrad::transport::CostModel;
use gossipgrad::util::args::Args;
use gossipgrad::util::bench::Table;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args = Args::from_env(&[
        "no-rotation",
        "no-shuffle",
        "native",
        "lr-scaling",
        "virtual-clock",
        "layerwise",
        "comm-thread",
        "sync-mix",
    ])
    .map_err(anyhow::Error::msg)?;
    match args.positional.first().map(|s| s.as_str()) {
        Some("train") => cmd_train(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("sim") => cmd_sim(&args),
        Some("inspect") => cmd_inspect(&args),
        other => {
            if let Some(o) = other {
                eprintln!("unknown subcommand {o:?}\n");
            }
            print_usage();
            Ok(())
        }
    }
}

fn print_usage() {
    println!(
        "gossipgrad — GossipGraD (Daily et al. 2018) reproduction\n\n\
         USAGE: gossipgrad <train|sweep|sim|inspect> [--key value ...]\n\n\
         train:   --model mlp|cnn|transformer  --algo gossip|gossip-hypercube|\n\
                  gossip-random|sgd|agd|periodic-agd|ps  --ranks N --steps N\n\
                  --lr F --eval-every N --config file.json --seed N\n\
                  --alpha S --beta-gbps G --noise F\n\
                  [--no-rotation] [--no-shuffle] [--native] [--lr-scaling]\n\
                  [--virtual-clock] [--compute-ms MS]   deterministic\n\
                  discrete-event timing (docs/virtual-time.md)\n\
                  [--layerwise]  per-layer async pipeline: charge backprop\n\
                  in layer slices, post each layer's exchange at its\n\
                  grad-ready instant (measured overlap; bit-identical\n\
                  numerics on the native backend)   [--fwd-ms MS]\n\
                  forward-pass share of --compute-ms   [--jitter F]\n\
                  deterministic per-(rank,step) straggler noise on the\n\
                  virtual fabric   [--comm-thread]  non-blocking AGD\n\
                  collectives on a modeled comm-progress thread (rounds\n\
                  advance at arrival instants under later backprop;\n\
                  needs --layerwise)   [--sync-mix]  gossip blocks for\n\
                  the current step's partner model\n\
         sweep:   train across --ranks-list 2,4,8 (other train flags apply)\n\
         sim:     --workload resnet50|googlenet|lenet3|cifarnet\n\
                  --p-list 4,8,...  --algos gossip,agd-ring,sgd-rd,ps1\n\
         inspect: --model NAME [--dir artifacts]"
    );
}

/// Build a RunConfig from `--config` (optional) + CLI overrides.
pub fn config_from(args: &Args) -> Result<RunConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::load(path).map_err(anyhow::Error::msg)?,
        None => RunConfig::default(),
    };
    if let Some(m) = args.get("model") {
        cfg.model = m.to_string();
    }
    if let Some(a) = args.get("algo") {
        cfg.algo = Algo::parse(a).map_err(anyhow::Error::msg)?;
    }
    if let Some(a) = args.get("allreduce") {
        cfg.allreduce = match a {
            "recursive-doubling" | "rd" => Algorithm::RecursiveDoubling,
            "binomial-tree" | "tree" => Algorithm::BinomialTree,
            "ring" => Algorithm::Ring,
            other => bail!("unknown allreduce {other:?}"),
        };
    }
    cfg.ranks = args.usize_or("ranks", cfg.ranks);
    cfg.steps = args.usize_or("steps", cfg.steps);
    cfg.lr = args.f64_or("lr", cfg.lr);
    cfg.seed = args.usize_or("seed", cfg.seed as usize) as u64;
    cfg.eval_every = args.usize_or("eval-every", cfg.eval_every);
    cfg.rows_per_rank = args.usize_or("rows-per-rank", cfg.rows_per_rank);
    cfg.gossip_period = args.usize_or("gossip-period", cfg.gossip_period);
    cfg.net_alpha = args.f64_or("alpha", cfg.net_alpha);
    if let Some(g) = args.get("beta-gbps") {
        let gbps: f64 = g.parse().context("--beta-gbps")?;
        cfg.net_beta = 1.0 / (gbps * 1e9);
    }
    cfg.net_noise = args.f64_or("noise", cfg.net_noise);
    if args.flag("no-rotation") {
        cfg.rotation = false;
    }
    if args.flag("no-shuffle") {
        cfg.sample_shuffle = false;
    }
    if args.flag("native") {
        cfg.use_artifacts = false;
    }
    if args.flag("lr-scaling") {
        cfg.krizhevsky_lr_scaling = true;
    }
    if args.flag("virtual-clock") {
        cfg.virtual_clock = true;
    }
    if args.flag("layerwise") {
        cfg.layerwise = true;
    }
    if args.flag("comm-thread") {
        cfg.comm_thread = true;
    }
    if args.flag("sync-mix") {
        cfg.sync_mix = true;
    }
    // a comm thread only overlaps collectives posted mid-backprop; the
    // monolithic schedule has nothing left to hide them under
    if cfg.comm_thread && !cfg.layerwise {
        bail!("--comm-thread requires --layerwise (per-layer pipelined AGD)");
    }
    cfg.straggler_jitter = args.f64_or("jitter", cfg.straggler_jitter);
    cfg.virt_compute_secs =
        args.f64_or("compute-ms", cfg.virt_compute_secs * 1e3) * 1e-3;
    cfg.virt_fwd_secs = args.f64_or("fwd-ms", cfg.virt_fwd_secs * 1e3) * 1e-3;
    // A virtual run with no compute charge degenerates to pure exposed
    // wait (0% efficiency, meaningless step times) — refuse it loudly.
    if cfg.virtual_clock && cfg.virt_compute_secs <= 0.0 {
        bail!(
            "--virtual-clock needs a per-step compute cost: pass \
             --compute-ms MS (e.g. 6.25 for LeNet3@P100) or set \
             virt_compute_secs in the config"
        );
    }
    // A forward share exceeding the whole compute budget would silently
    // clamp every backward slice to zero and overcharge the step.
    if cfg.virtual_clock && cfg.virt_fwd_secs > cfg.virt_compute_secs {
        bail!(
            "--fwd-ms ({} ms) must not exceed --compute-ms ({} ms)",
            cfg.virt_fwd_secs * 1e3,
            cfg.virt_compute_secs * 1e3
        );
    }
    if let Some(d) = args.get("artifacts-dir") {
        cfg.artifacts_dir = d.to_string();
    }
    if let Some(d) = args.get("resume") {
        cfg.resume_from = Some(d.to_string());
    }
    Ok(cfg)
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    println!(
        "train: model={} algo={} ranks={} steps={} lr={} (effective {:.4})",
        cfg.model,
        cfg.algo.name(),
        cfg.ranks,
        cfg.steps,
        cfg.lr,
        cfg.effective_lr()
    );
    let res = coordinator::run(&cfg)?;
    report(&res);
    if let Some(dir) = args.get("save") {
        let ck = gossipgrad::coordinator::checkpoint::Checkpoint {
            model: cfg.model.clone(),
            step: cfg.steps,
            params: res.final_params[0].clone(),
            // momentum is per-rank transient state; a resumed run
            // restarts it (standard practice for step-LR restarts)
            momentum: vec![0.0; res.final_params[0].len()],
        };
        ck.save(std::path::Path::new(dir)).map_err(anyhow::Error::msg)?;
        println!("saved checkpoint to {dir}");
    }
    Ok(())
}

fn report(res: &coordinator::RunResult) {
    let m0 = &res.per_rank[0];
    let losses: Vec<f64> = m0.loss.iter().map(|&(_, l)| l).collect();
    println!(
        "rank0 loss  {}  {:.4} -> {:.4}",
        sparkline(&losses, 40),
        losses.first().unwrap_or(&f64::NAN),
        losses.last().unwrap_or(&f64::NAN)
    );
    if let Some(acc) = res.final_accuracy {
        println!("final validation accuracy: {:.2}%", 100.0 * acc);
    }
    // metrics line is deterministic under --virtual-clock (the CI smoke
    // diffs two runs); wall time goes on its own line so it can be
    // filtered out
    println!(
        "mean step {:.2} ms | efficiency {:.1}% | overlap {:.0}% | disagreement {:.3e} | {} msgs",
        1e3 * res.mean_step_secs(),
        res.mean_efficiency_pct(),
        100.0 * res.mean_overlap_frac(),
        res.max_disagreement(),
        res.per_rank.iter().map(|m| m.msgs_sent).sum::<u64>(),
    );
    println!("wall {:.1}s", res.wall_secs);
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let base = config_from(args)?;
    let list = args.get_or("ranks-list", "2,4,8");
    let mut table = Table::new(&["ranks", "step_ms", "eff_%", "msgs/rank/step"]);
    for tok in list.split(',') {
        let ranks: usize = tok.trim().parse().context("--ranks-list")?;
        let mut cfg = base.clone();
        cfg.ranks = ranks;
        let res = coordinator::run(&cfg)?;
        let msgs = res.per_rank.iter().map(|m| m.msgs_sent).sum::<u64>() as f64
            / (ranks * cfg.steps) as f64;
        table.row(&[
            ranks.to_string(),
            format!("{:.2}", 1e3 * res.mean_step_secs()),
            format!("{:.1}", res.mean_efficiency_pct()),
            format!("{msgs:.1}"),
        ]);
    }
    table.print(&format!("sweep: {} / {}", base.model, base.algo.name()));
    Ok(())
}

fn parse_sched(tok: &str) -> Result<Schedule> {
    Ok(match tok {
        "gossip" => Schedule::Gossip,
        "agd-rd" => Schedule::Agd(Algorithm::RecursiveDoubling),
        "agd-ring" => Schedule::Agd(Algorithm::Ring),
        "agd-tree" => Schedule::Agd(Algorithm::BinomialTree),
        "sgd-rd" => Schedule::SgdSync(Algorithm::RecursiveDoubling),
        "sgd-ring" => Schedule::SgdSync(Algorithm::Ring),
        "periodic-rd" => Schedule::PeriodicAgd(Algorithm::RecursiveDoubling),
        s if s.starts_with("ps") => Schedule::ParamServer {
            servers: s[2..].parse().unwrap_or(1),
        },
        other => bail!("unknown schedule {other:?}"),
    })
}

fn cmd_sim(args: &Args) -> Result<()> {
    let w = match args.get_or("workload", "resnet50").as_str() {
        "resnet50" => Workload::resnet50_p100(),
        "googlenet" => Workload::googlenet_p100(),
        "lenet3" => Workload::lenet3(args.f64_or("device-speed", 1.0)),
        "cifarnet" => Workload::cifarnet(args.f64_or("device-speed", 1.0)),
        other => bail!("unknown workload {other:?}"),
    };
    let cost = CostModel::ib_edr(0);
    let p_list = args.get_or("p-list", "4,8,16,32,64,128");
    let algos = args.get_or("algos", "gossip,agd-ring,agd-rd,sgd-rd,ps1");
    let scheds: Vec<Schedule> = algos
        .split(',')
        .map(|t| parse_sched(t.trim()))
        .collect::<Result<_>>()?;
    let mut header = vec!["p".to_string()];
    header.extend(scheds.iter().map(|s| s.name()));
    let mut table =
        Table::new(&header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    for tok in p_list.split(',') {
        let p: usize = tok.trim().parse().context("--p-list")?;
        let mut row = vec![p.to_string()];
        for &s in &scheds {
            let e = sim::efficiency::avg_efficiency(s, &w, p, &cost, 64);
            row.push(format!("{:.1}", e.percent()));
        }
        table.row(&row);
    }
    table.print(&format!("simulated compute efficiency (%) — {}", w.name));
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let dir = args
        .get("dir")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(default_dir);
    let model = args.get_or("model", "mlp");
    let set = ArtifactSet::load(&dir, &model).map_err(anyhow::Error::msg)?;
    let m = &set.meta;
    println!("model {}: {} params, batch {}", m.model, m.param_count, m.batch);
    println!(
        "x{:?} ({}) | {} label rows | {} classes | momentum {}",
        m.x_shape,
        if m.x_is_int { "i32" } else { "f32" },
        m.labels_rows,
        m.classes,
        m.momentum
    );
    let mut t = Table::new(&["layer", "offset", "len", "KiB"]);
    for l in &m.layers {
        t.row(&[
            l.name.clone(),
            l.offset.to_string(),
            l.len.to_string(),
            format!("{:.1}", l.len as f64 * 4.0 / 1024.0),
        ]);
    }
    t.print("layer table (layer-wise comm granularity)");
    Ok(())
}
