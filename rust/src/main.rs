//! `gossipgrad` — CLI launcher for the GossipGraD reproduction.
//!
//! Subcommands:
//!   train      run a distributed training job (threads-as-ranks)
//!   launch     spawn one OS process per rank over TCP on localhost
//!   rank       run a single rank of a multi-process TCP job
//!   sweep      declarative scenario grid on the experiment engine
//!   sim        scale simulation (Table 7-style, up to 1024 devices)
//!   inspect    print artifact metadata
//!
//! Examples:
//!   gossipgrad train --model mlp --algo gossip --ranks 8 --steps 200
//!   gossipgrad train --config configs/mnist_gossip_32.json
//!   gossipgrad launch --transport tcp --native --model mlp-small \
//!       --algo gossip --ranks 4 --steps 50
//!   gossipgrad rank --transport tcp --rank 0 \
//!       --peers host0:29500,host1:29500 --native --algo agd --ranks 2
//!   gossipgrad sweep --native --model mlp-small --workload lenet3 \
//!       --device-speed 4 --alpha 0.0002 --beta-gbps 0.5 --layerwise \
//!       --ranks 1024 --gossip-period-list 1,2,4,8 --jitter-list 0,0.3
//!   gossipgrad sweep --preset period-jitter-1024
//!   gossipgrad sim --workload resnet50 --algos gossip,agd-ring
//!   gossipgrad inspect --model transformer

use anyhow::{bail, Context, Result};
use gossipgrad::collectives::Algorithm;
use gossipgrad::config::{cli, Transport};
use gossipgrad::coordinator;
use gossipgrad::config::RunConfig;
use gossipgrad::coordinator::trainer::{
    build_backend, fabric_size, run_rank_with_link, RankOutcome,
};
use gossipgrad::exp::{autotune, Engine, Grid, Sweep};
use gossipgrad::metrics::{sparkline, RankSummary};
use gossipgrad::runtime::artifacts::{default_dir, ArtifactSet};
use gossipgrad::sim::{self, Schedule, Workload};
use gossipgrad::transport::{
    hybrid, CostModel, GroupMap, HybridLink, Link, TcpLinkBuilder,
};
use gossipgrad::util::args::Args;
use gossipgrad::util::bench::Table;
use gossipgrad::util::json::{self, num, obj, Json};

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args = Args::from_env(cli::FLAGS).map_err(anyhow::Error::msg)?;
    match args.positional.first().map(|s| s.as_str()) {
        Some("train") => cmd_train(&args),
        Some("launch") => cmd_launch(&args),
        Some("rank") => cmd_rank(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("sim") => cmd_sim(&args),
        Some("inspect") => cmd_inspect(&args),
        other => {
            if let Some(o) = other {
                eprintln!("unknown subcommand {o:?}\n");
            }
            print_usage();
            Ok(())
        }
    }
}

fn print_usage() {
    println!(
        "gossipgrad — GossipGraD (Daily et al. 2018) reproduction\n\n\
         USAGE: gossipgrad <train|launch|rank|sweep|sim|inspect> \
         [--key value ...]\n\n\
         train:   --model mlp|mlp-small|cnn|transformer  --algo gossip|\n\
                  gossip-hypercube|gossip-random|sgd|agd|periodic-agd|ps\n\
                  --ranks N --steps N --lr F --eval-every N\n\
                  --config file.json --seed N --alpha S --beta-gbps G\n\
                  --noise F --ps-servers N --val-rows N\n\
                  --lr-step-every N --lr-step-gamma F --ps-agg-ms MS\n\
                  [--no-rotation] [--no-shuffle] [--native] [--lr-scaling]\n\
                  [--virtual-clock] [--compute-ms MS]   deterministic\n\
                  discrete-event timing (docs/virtual-time.md)\n\
                  [--workload lenet3|cifarnet|resnet50|googlenet\n\
                  [--device-speed F]]  virtualize onto a calibrated\n\
                  compute model   [--layerwise]  per-layer async\n\
                  pipeline   [--fwd-ms MS]   [--jitter F]  deterministic\n\
                  straggler noise   [--comm-thread]  non-blocking AGD\n\
                  collectives (needs --layerwise)   [--sync-mix]\n\
                  [--transport inproc|tcp]  wire layer (tcp = one\n\
                  loopback socket mesh, wall clock; docs/transport.md)\n\
                  [--codec f32|bf16|int8|topk]  wire codec for model/\n\
                  gradient payloads, charged in compressed bytes\n\
                  (docs/wire-codecs.md)   fault injection (gossip\n\
                  only; docs/fault-tolerance.md): [--kill-rank R@S,..]\n\
                  [--join-at-step R@S,..] [--slow-rank R@S:F,..]\n\
                  [--drop-frac F] [--dup-frac F] [--fault-seed N]\n\
                  hierarchical fabric (docs/topology.md):\n\
                  [--group-size G]  carve ranks into contiguous\n\
                  host groups (two-level gossip schedule)\n\
                  [--inter-period K]  inter-group exchange cadence\n\
                  [--cost-model flat|hier]  two-tier virtual costs\n\
                  [--sim-threads N]  rank-scheduler workers for\n\
                  virtual-clock runs (0 = cores; docs/perf.md)\n\
                  [--legacy-ranks]  thread-per-rank oracle path\n\
         launch:  spawn one OS process per host group (default: per\n\
                  rank) on localhost over TCP and merge their metrics.\n\
                  Takes every train flag, plus --port-base P (default\n\
                  29500) [--keep-dir] (requires --transport tcp);\n\
                  --group-size G mounts in-proc mailboxes inside each\n\
                  group and the TCP mesh between groups\n\
         rank:    run ONE rank of a multi-process TCP job:\n\
                  --rank R --peers host:port,...  (one entry per\n\
                  fabric rank, in rank order; entry R is this rank's\n\
                  listen address)  [--result-dir DIR]  write\n\
                  rank_R.json for the launcher  [--handshake-timeout-\n\
                  secs N]  plus every train flag (requires\n\
                  --transport tcp)\n\
         sweep:   declarative grid on the experiment engine\n\
                  (docs/experiments.md).  Takes every train flag as the\n\
                  base scenario, plus axes --algo-list --ranks-list\n\
                  --gossip-period-list --jitter-list --layerwise-list\n\
                  --comm-thread-list --sync-mix-list --allreduce-list\n\
                  --codec-list --drop-frac-list --group-size-list\n\
                  --inter-period-list --seed-list\n\
                  (comma-separated; omitted\n\
                  axes pin at the base value), or --preset\n\
                  period-jitter-1024 | codec-frontier-1024 |\n\
                  hier-frontier-1024.\n\
                  --sweep-threads N  host worker threads (N-thread and\n\
                  1-thread sweeps are byte-identical; rank bodies\n\
                  inside scenarios share one global core budget with\n\
                  --sim-threads — docs/perf.md)   --cache-dir DIR\n\
                  content-hash result cache   --out-dir DIR --out-name S\n\
                  BENCH_<name>.json/.csv artifacts (default bench_out/\n\
                  sweep)   [--autotune-period]  pick the largest gossip\n\
                  period within 2% of peak throughput whose consensus\n\
                  still shrinks (Fig 17 trade-off)\n\
         sim:     --workload resnet50|googlenet|lenet3|cifarnet\n\
                  --p-list 4,8,...  --algos gossip,agd-ring,sgd-rd,ps1\n\
         inspect: --model NAME [--dir artifacts]"
    );
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = cli::from_args(args)?;
    println!(
        "train: model={} algo={} ranks={} steps={} lr={} (effective {:.4})",
        cfg.model,
        cfg.algo.name(),
        cfg.ranks,
        cfg.steps,
        cfg.lr,
        cfg.effective_lr()
    );
    let res = coordinator::run(&cfg)?;
    report(&res);
    if let Some(dir) = args.get("save") {
        let ck = gossipgrad::coordinator::checkpoint::Checkpoint {
            model: cfg.model.clone(),
            step: cfg.steps,
            params: res.final_params[0].clone(),
            // momentum is per-rank transient state; a resumed run
            // restarts it (standard practice for step-LR restarts)
            momentum: vec![0.0; res.final_params[0].len()],
        };
        ck.save(std::path::Path::new(dir)).map_err(anyhow::Error::msg)?;
        println!("saved checkpoint to {dir}");
    }
    Ok(())
}

fn report(res: &coordinator::RunResult) {
    let m0 = &res.per_rank[0];
    let losses: Vec<f64> = m0.loss.iter().map(|&(_, l)| l).collect();
    println!(
        "rank0 loss  {}  {:.4} -> {:.4}",
        sparkline(&losses, 40),
        losses.first().unwrap_or(&f64::NAN),
        losses.last().unwrap_or(&f64::NAN)
    );
    if let Some(acc) = res.final_accuracy {
        println!("final validation accuracy: {:.2}%", 100.0 * acc);
    }
    // metrics line is deterministic under --virtual-clock (the CI smoke
    // diffs two runs); wall time goes on its own line so it can be
    // filtered out
    println!(
        "mean step {:.2} ms | efficiency {:.1}% | overlap {:.0}% | disagreement {:.3e} | {} msgs",
        1e3 * res.mean_step_secs(),
        res.mean_efficiency_pct(),
        100.0 * res.mean_overlap_frac(),
        res.max_disagreement(),
        res.per_rank.iter().map(|m| m.msgs_sent).sum::<u64>(),
    );
    let deaths: Vec<usize> = res
        .per_rank
        .iter()
        .filter(|m| m.death_step.is_some())
        .map(|m| m.rank)
        .collect();
    if !deaths.is_empty() {
        println!("deaths {:?} | survivors {:?}", deaths, res.survivors());
    }
    // numerics fingerprint on its own line so CI can diff a TCP
    // multi-process run against the equivalent threads-as-ranks run
    println!("param_hash {:016x}", res.param_hash());
    println!("wall {:.1}s", res.wall_secs);
}

/// One rank of a multi-process TCP job: bind `peers[rank]`, handshake
/// the full mesh, run the rank, optionally write `rank_<R>.json` (the
/// launcher's merge input).
fn cmd_rank(args: &Args) -> Result<()> {
    let cfg = cli::from_args(args)?;
    if cfg.transport != Transport::Tcp {
        bail!("the rank subcommand needs --transport tcp");
    }
    let rank: usize = args
        .get("rank")
        .context("rank: --rank R is required")?
        .parse()
        .context("--rank")?;
    let peers: Vec<String> = args
        .get("peers")
        .context("rank: --peers host:port,... is required")?
        .split(',')
        .map(|t| t.trim().to_string())
        .collect();
    let n = fabric_size(&cfg);
    if peers.len() != n {
        bail!(
            "--peers lists {} addresses but the config needs {n} fabric \
             ranks ({} workers{})",
            peers.len(),
            cfg.ranks,
            if n > cfg.ranks {
                format!(" + {} server(s)", n - cfg.ranks)
            } else {
                String::new()
            }
        );
    }
    if rank >= n {
        bail!("--rank {rank} outside fabric of {n}");
    }
    let timeout = std::time::Duration::from_secs(
        args.usize_or("handshake-timeout-secs", 30) as u64,
    );
    if cfg.group_size > 1 {
        // group mode: this process hosts the whole host-group
        // [rank, rank + group_size) behind a hybrid link
        return cmd_rank_group(args, &cfg, rank, &peers, timeout);
    }
    let backend = build_backend(&cfg)?;
    let builder = TcpLinkBuilder::bind(&peers[rank])
        .with_context(|| format!("binding {}", peers[rank]))?;
    let link: std::sync::Arc<dyn Link> = builder
        .establish(rank, &peers, cfg.cost_model(), timeout)
        .context("establishing the tcp mesh")?;
    let out = run_rank_with_link(&cfg, backend, rank, link)?;
    finish_rank(args, &out)
}

/// One host-group of a `--group-size G` multi-process job: this process
/// owns fabric ranks `[base, base + G)` — one thread each — with
/// in-proc mailboxes between them and the TCP mesh to every other
/// group (docs/topology.md).  Writes the same `rank_<R>.json` files as
/// G single-rank processes would, so the launcher's merge loop is
/// oblivious to grouping.
fn cmd_rank_group(
    args: &Args,
    cfg: &RunConfig,
    base: usize,
    peers: &[String],
    timeout: std::time::Duration,
) -> Result<()> {
    let n = fabric_size(cfg);
    let gsize = cfg.group_size;
    if base % gsize != 0 {
        bail!(
            "--rank {base} must be a group base (a multiple of \
             --group-size {gsize}) when launching grouped ranks"
        );
    }
    let groups = GroupMap::new(n, gsize);
    // bind every hosted listener before any establish: the mesh
    // handshake is a global barrier over all n listen addresses, so a
    // late bind inside the establish loop would deadlock the job
    let builders = (base..base + gsize)
        .map(|r| {
            TcpLinkBuilder::bind(&peers[r])
                .with_context(|| format!("binding {}", peers[r]))
        })
        .collect::<Result<Vec<_>>>()?;
    let boxes = hybrid::group_mailboxes(gsize);
    let backend = build_backend(cfg)?;
    let joined: Vec<Result<RankOutcome>> = std::thread::scope(|s| {
        let handles: Vec<_> = builders
            .into_iter()
            .enumerate()
            .map(|(i, b)| {
                let r = base + i;
                let boxes = std::sync::Arc::clone(&boxes);
                let backend = std::sync::Arc::clone(&backend);
                // each rank establishes in its own thread: the
                // handshake is a cross-rank barrier, serial
                // establishment would deadlock
                s.spawn(move || -> Result<RankOutcome> {
                    let tcp = b
                        .establish(r, peers, cfg.cost_model(), timeout)
                        .with_context(|| {
                            format!("rank {r}: establishing the tcp mesh")
                        })?;
                    let link: std::sync::Arc<dyn Link> =
                        std::sync::Arc::new(HybridLink::new(r, groups, boxes, tcp));
                    run_rank_with_link(cfg, backend, r, link)
                })
            })
            .collect();
        // join EVERY hosted rank before surfacing an error, so no rank
        // thread (with its sockets) outlives the scope
        handles
            .into_iter()
            .map(|h| {
                h.join()
                    .map_err(|_| anyhow::anyhow!("rank thread panicked"))
                    .and_then(|r| r)
            })
            .collect()
    });
    let mut outs = Vec::with_capacity(gsize);
    for r in joined {
        outs.push(r?);
    }
    outs.sort_by_key(|o| o.rank);
    for out in &outs {
        finish_rank(args, out)?;
    }
    Ok(())
}

/// Shared tail of the `rank` subcommand: persist the outcome for the
/// launcher, report it, and enforce the per-rank drain invariant.
fn finish_rank(args: &Args, out: &RankOutcome) -> Result<()> {
    let rank = out.rank;
    if let Some(dir) = args.get("result-dir") {
        let dir = std::path::Path::new(dir);
        std::fs::create_dir_all(dir)?;
        std::fs::write(
            dir.join(format!("rank_{rank}.json")),
            rank_result_json(out).to_string() + "\n",
        )?;
    }
    match &out.metrics {
        Some(m) => println!(
            "rank {rank}: mean step {:.2} ms | efficiency {:.1}% | {} msgs \
             | in-flight {} ({} B)",
            1e3 * m.mean_step_secs(),
            m.efficiency_pct(),
            m.msgs_sent,
            out.in_flight,
            out.in_flight_bytes
        ),
        None => println!(
            "rank {rank}: server role done | in-flight {} ({} B)",
            out.in_flight, out.in_flight_bytes
        ),
    }
    if out.in_flight != 0 {
        bail!("rank {rank} left {} messages in flight", out.in_flight);
    }
    if out.in_flight_bytes != 0 {
        bail!("rank {rank} left {} bytes in flight", out.in_flight_bytes);
    }
    Ok(())
}

/// Serialize one rank's outcome for the launcher: metric digest +
/// parameter bits (hex of each f32's bit pattern, so the merge can
/// recompute the exact rank-major `param_hash`).
fn rank_result_json(out: &coordinator::trainer::RankOutcome) -> Json {
    let mut pairs = vec![
        ("rank", num(out.rank as f64)),
        ("in_flight", num(out.in_flight as f64)),
        ("in_flight_bytes", num(out.in_flight_bytes as f64)),
    ];
    if let Some(m) = &out.metrics {
        pairs.push(("summary", RankSummary::from_metrics(m).to_json()));
        if let Some(&(_, acc)) = m.accuracy.last() {
            pairs.push(("final_accuracy", num(acc)));
        }
    }
    if let Some(params) = &out.params {
        use std::fmt::Write as _;
        let mut hex = String::with_capacity(params.len() * 8);
        for x in params {
            let _ = write!(hex, "{:08x}", x.to_bits());
        }
        pairs.push(("params_hex", json::s(&hex)));
    }
    obj(pairs)
}

/// Spawn one `rank` process per fabric rank on localhost and merge
/// their results: metrics table, global drain invariant, rank-major
/// `param_hash` (bit-comparable with a `train` run of the same config).
fn cmd_launch(args: &Args) -> Result<()> {
    let cfg = cli::from_args(args)?;
    if cfg.transport != Transport::Tcp {
        bail!("launch currently supports --transport tcp only");
    }
    let n = fabric_size(&cfg);
    if n == 0 {
        bail!("need at least one rank");
    }
    // one process per host-group (docs/topology.md); group_size = 1 is
    // the historical one-process-per-rank launch
    let gsize = cfg.group_size.max(1);
    if n % gsize != 0 {
        bail!("--group-size {gsize} must divide the fabric size {n}");
    }
    let port_base = args.usize_or("port-base", 29500);
    let peers: Vec<String> =
        (0..n).map(|i| format!("127.0.0.1:{}", port_base + i)).collect();
    let dir = std::env::temp_dir()
        .join(format!("gossipgrad_launch_{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    let cfg_path = dir.join("config.json");
    std::fs::write(&cfg_path, cfg.to_json().to_string() + "\n")?;
    let exe = std::env::current_exe()?;
    println!(
        "launch: transport=tcp algo={} workers={} processes={} group-size={gsize} ports {}..{}",
        cfg.algo.name(),
        cfg.ranks,
        n / gsize,
        port_base,
        port_base + n - 1
    );
    let t0 = std::time::Instant::now();
    let mut children = Vec::with_capacity(n / gsize);
    for base in (0..n).step_by(gsize) {
        let child = std::process::Command::new(&exe)
            .arg("rank")
            .arg("--transport")
            .arg("tcp")
            .arg("--config")
            .arg(&cfg_path)
            .arg("--rank")
            .arg(base.to_string())
            .arg("--peers")
            .arg(peers.join(","))
            .arg("--result-dir")
            .arg(&dir)
            .stdout(std::process::Stdio::null())
            .spawn()
            .with_context(|| format!("spawning group process at rank {base}"))?;
        children.push((base, child));
    }
    let mut failed = Vec::new();
    for (base, mut child) in children {
        let status = child.wait()?;
        if !status.success() {
            failed.push(base);
        }
    }
    if !failed.is_empty() {
        bail!("rank processes {failed:?} exited with failure (see stderr above)");
    }

    // ---- merge the per-rank result files -----------------------------
    let mut summaries: Vec<RankSummary> = Vec::new();
    let mut param_bytes: Vec<u8> = Vec::new();
    let mut total_in_flight = 0usize;
    let mut total_in_flight_bytes = 0usize;
    for rank in 0..n {
        let path = dir.join(format!("rank_{rank}.json"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).map_err(anyhow::Error::msg)?;
        total_in_flight += j
            .get("in_flight")
            .and_then(Json::as_usize)
            .with_context(|| format!("rank {rank}: missing in_flight"))?;
        total_in_flight_bytes += j
            .get("in_flight_bytes")
            .and_then(Json::as_usize)
            .with_context(|| format!("rank {rank}: missing in_flight_bytes"))?;
        if let Some(s) = j.get("summary") {
            summaries.push(RankSummary::from_json(s).map_err(anyhow::Error::msg)?);
        }
        if rank < cfg.ranks {
            let hex = j
                .get("params_hex")
                .and_then(Json::as_str)
                .with_context(|| format!("rank {rank}: missing params_hex"))?;
            append_param_bits(&mut param_bytes, hex)
                .with_context(|| format!("rank {rank}: params_hex"))?;
        }
    }
    if !args.flag("keep-dir") {
        std::fs::remove_dir_all(&dir).ok();
    }

    let mut t = Table::new(&["rank", "step ms", "eff %", "overlap %", "msgs"]);
    for s in &summaries {
        t.row(&[
            s.rank.to_string(),
            format!("{:.2}", 1e3 * s.mean_step_secs),
            format!("{:.1}", s.efficiency_pct),
            format!("{:.1}", 100.0 * s.overlap_frac),
            s.msgs_sent.to_string(),
        ]);
    }
    t.print("merged per-rank metrics (tcp multi-process)");
    if total_in_flight != 0 {
        bail!("{total_in_flight} messages left in flight across the mesh");
    }
    if total_in_flight_bytes != 0 {
        bail!("{total_in_flight_bytes} bytes left in flight across the mesh");
    }
    println!(
        "mean step {:.2} ms | efficiency {:.1}% | in-flight 0",
        1e3 * gossipgrad::util::mean(
            &summaries.iter().map(|s| s.mean_step_secs).collect::<Vec<_>>()
        ),
        gossipgrad::util::mean(
            &summaries.iter().map(|s| s.efficiency_pct).collect::<Vec<_>>()
        ),
    );
    println!(
        "param_hash {:016x}",
        gossipgrad::util::fnv1a64(&param_bytes)
    );
    println!("wall {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}

/// Decode a `params_hex` string (8 hex chars per f32 bit pattern) into
/// the same little-endian byte stream `RunResult::param_hash` hashes.
fn append_param_bits(out: &mut Vec<u8>, hex: &str) -> Result<()> {
    if hex.len() % 8 != 0 {
        bail!("length {} is not a multiple of 8", hex.len());
    }
    for chunk in hex.as_bytes().chunks_exact(8) {
        let s = std::str::from_utf8(chunk).context("non-utf8 hex")?;
        let bits = u32::from_str_radix(s, 16).context("bad hex digit")?;
        out.extend_from_slice(&bits.to_le_bytes());
    }
    Ok(())
}

/// Axis options that turn a base config into a grid; with none present
/// (and no preset) `sweep` falls back to the historical rank sweep.
const AXIS_KEYS: &[&str] = &[
    "algo-list",
    "ranks-list",
    "gossip-period-list",
    "jitter-list",
    "layerwise-list",
    "comm-thread-list",
    "sync-mix-list",
    "allreduce-list",
    "codec-list",
    "drop-frac-list",
    "group-size-list",
    "inter-period-list",
    "seed-list",
];

fn cmd_sweep(args: &Args) -> Result<()> {
    let grid = match args.get("preset") {
        Some(name) => Grid::preset(name)?,
        None => {
            let base = cli::from_args(args)?;
            let mut grid = Grid::from_args(base, args)?;
            if !AXIS_KEYS.iter().any(|k| args.get(k).is_some()) {
                // historical default: a rank sweep
                grid = grid.ranks(&[2, 4, 8]);
            }
            grid
        }
    };
    let mut engine = Engine::with_threads(
        args.usize_or("sweep-threads", gossipgrad::exp::default_threads()),
    );
    if let Some(d) = args.get("cache-dir") {
        engine = engine.cached(std::path::Path::new(d));
    }
    let n = grid.len();
    println!(
        "sweep: {n} scenarios on {} host threads (cache: {})",
        engine.threads,
        engine
            .cache_dir
            .as_deref()
            .map(|p| p.display().to_string())
            .unwrap_or_else(|| "off".into()),
    );
    let t0 = std::time::Instant::now();
    let sweep = engine.run(&grid)?;
    print_sweep_table(&sweep);
    for r in &sweep.reports {
        if r.in_flight_msgs != 0 {
            bail!("scenario {} leaked {} in-flight messages", r.key, r.in_flight_msgs);
        }
        if r.in_flight_bytes != 0 {
            bail!("scenario {} leaked {} in-flight bytes", r.key, r.in_flight_bytes);
        }
    }
    let out_dir = std::path::PathBuf::from(args.get_or("out-dir", "bench_out"));
    let name = args.get_or("out-name", "sweep");
    let (json_path, csv_path) = sweep
        .write_artifacts(&out_dir, &name)
        .with_context(|| format!("writing artifacts under {}", out_dir.display()))?;
    // wall line is nondeterministic: keep it separate from the table so
    // CI can diff sweep output (grep -v '^wall ')
    println!(
        "{} executed, {} cache hits | artifacts: {} {}",
        sweep.runs_executed,
        sweep.cache_hits,
        json_path.display(),
        csv_path.display()
    );
    println!("wall {:.1}s", t0.elapsed().as_secs_f64());

    if args.flag("autotune-period") {
        let base = grid.base.clone();
        let periods: Vec<usize> = match args.get("gossip-period-list") {
            Some(v) => v
                .split(',')
                .map(|t| t.trim().parse().context("--gossip-period-list"))
                .collect::<Result<_>>()?,
            None => grid.period_axis().to_vec(),
        };
        if periods.is_empty() {
            bail!("--autotune-period needs --gossip-period-list (or a preset with a period axis)");
        }
        let tuned = autotune::autotune_gossip_period(
            &engine,
            &base,
            &periods,
            autotune::AutotuneParams::default(),
        )?;
        print_autotune_table(&tuned);
    }
    Ok(())
}

fn print_sweep_table(sweep: &Sweep) {
    let mut t = Table::new(&[
        "algo",
        "p",
        "period",
        "jitter",
        "lw",
        "ct",
        "step ms",
        "eff %",
        "overlap %",
        "disagreement",
        "msgs/rank/step",
    ]);
    for r in &sweep.reports {
        let c = &r.config;
        t.row(&[
            c.algo.name().to_string(),
            c.ranks.to_string(),
            c.gossip_period.to_string(),
            format!("{}", c.straggler_jitter),
            (if c.layerwise { "y" } else { "n" }).to_string(),
            (if c.comm_thread { "y" } else { "n" }).to_string(),
            format!("{:.2}", 1e3 * r.mean_step_secs),
            format!("{:.1}", r.mean_efficiency_pct),
            format!("{:.1}", 100.0 * r.mean_overlap_frac),
            format!("{:.3e}", r.max_disagreement),
            format!("{:.1}", r.msgs_per_rank_step()),
        ]);
    }
    t.print("sweep (experiment engine, grid order)");
}

fn print_autotune_table(tuned: &autotune::AutotuneReport) {
    let mut t = Table::new(&[
        "period",
        "steps/s",
        "disagreement",
        "fast enough",
        "consensus shrinks",
    ]);
    for c in &tuned.candidates {
        t.row(&[
            c.period.to_string(),
            format!("{:.2}", c.steps_per_sec),
            format!("{:.3e}", c.disagreement),
            (if c.fast_enough { "y" } else { "n" }).to_string(),
            (if c.consensus_shrinks { "y" } else { "n" }).to_string(),
        ]);
    }
    t.print(&format!(
        "gossip-period autotune (peak {:.2} steps/s, no-mix drift {:.3e})",
        tuned.peak_steps_per_sec, tuned.no_mix_disagreement
    ));
    match tuned.chosen_period {
        Some(p) => println!(
            "chosen gossip_period = {p} (largest within 2% of peak whose \
             consensus still shrinks)"
        ),
        None => println!(
            "no period passed both gates — keep gossip_period = 1 and \
             inspect the candidates above"
        ),
    }
}

fn parse_sched(tok: &str) -> Result<Schedule> {
    Ok(match tok {
        "gossip" => Schedule::Gossip,
        "agd-rd" => Schedule::Agd(Algorithm::RecursiveDoubling),
        "agd-ring" => Schedule::Agd(Algorithm::Ring),
        "agd-tree" => Schedule::Agd(Algorithm::BinomialTree),
        "sgd-rd" => Schedule::SgdSync(Algorithm::RecursiveDoubling),
        "sgd-ring" => Schedule::SgdSync(Algorithm::Ring),
        "periodic-rd" => Schedule::PeriodicAgd(Algorithm::RecursiveDoubling),
        s if s.starts_with("ps") => Schedule::ParamServer {
            servers: s[2..].parse().unwrap_or(1),
        },
        other => bail!("unknown schedule {other:?}"),
    })
}

fn cmd_sim(args: &Args) -> Result<()> {
    let name = args.get_or("workload", "resnet50");
    let w = Workload::by_name(&name, args.f64_or("device-speed", 1.0))
        .ok_or_else(|| anyhow::anyhow!("unknown workload {name:?}"))?;
    let cost = CostModel::ib_edr(0);
    let p_list = args.get_or("p-list", "4,8,16,32,64,128");
    let algos = args.get_or("algos", "gossip,agd-ring,agd-rd,sgd-rd,ps1");
    let scheds: Vec<Schedule> = algos
        .split(',')
        .map(|t| parse_sched(t.trim()))
        .collect::<Result<_>>()?;
    let mut header = vec!["p".to_string()];
    header.extend(scheds.iter().map(|s| s.name()));
    let mut table =
        Table::new(&header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    for tok in p_list.split(',') {
        let p: usize = tok.trim().parse().context("--p-list")?;
        let mut row = vec![p.to_string()];
        for &s in &scheds {
            let e = sim::efficiency::avg_efficiency(s, &w, p, &cost, 64);
            row.push(format!("{:.1}", e.percent()));
        }
        table.row(&row);
    }
    table.print(&format!("simulated compute efficiency (%) — {}", w.name));
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let dir = args
        .get("dir")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(default_dir);
    let model = args.get_or("model", "mlp");
    let set = ArtifactSet::load(&dir, &model).map_err(anyhow::Error::msg)?;
    let m = &set.meta;
    println!("model {}: {} params, batch {}", m.model, m.param_count, m.batch);
    println!(
        "x{:?} ({}) | {} label rows | {} classes | momentum {}",
        m.x_shape,
        if m.x_is_int { "i32" } else { "f32" },
        m.labels_rows,
        m.classes,
        m.momentum
    );
    let mut t = Table::new(&["layer", "offset", "len", "KiB"]);
    for l in &m.layers {
        t.row(&[
            l.name.clone(),
            l.offset.to_string(),
            l.len.to_string(),
            format!("{:.1}", l.len as f64 * 4.0 / 1024.0),
        ]);
    }
    t.print("layer table (layer-wise comm granularity)");
    Ok(())
}
