//! Native MLP backend: the same model family as the `mlp` AOT artifacts
//! (relu hidden layers, softmax-xent head, flat-parameter layout in
//! `fc{i}.w, fc{i}.b` order) with hand-written backprop.
//!
//! The numerics intentionally mirror python/compile/model.py::mlp_logits
//! so integration tests can train either backend interchangeably.

use super::ops;
use crate::runtime::{BatchData, LayerSlice, ModelBackend};
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct NativeMlp {
    pub dims: Vec<usize>, // [din, h1, ..., classes]
    pub batch: usize,
    layers: Vec<LayerSlice>,
    param_count: usize,
    momentum: f32,
    init_seed: u64,
}

impl NativeMlp {
    pub fn new(dims: Vec<usize>, batch: usize, init_seed: u64) -> NativeMlp {
        assert!(dims.len() >= 2);
        let mut layers = Vec::new();
        let mut off = 0usize;
        for i in 0..dims.len() - 1 {
            let len = dims[i] * dims[i + 1] + dims[i + 1];
            layers.push(LayerSlice {
                name: format!("fc{i}"),
                offset: off,
                len,
            });
            off += len;
        }
        NativeMlp {
            dims,
            batch,
            layers,
            param_count: off,
            momentum: 0.9,
            init_seed,
        }
    }

    /// The MNIST-analog configuration (mirrors build_model("mlp")).
    pub fn mnist(batch: usize) -> NativeMlp {
        NativeMlp::new(vec![784, 512, 256, 10], batch, 0)
    }

    /// Small configuration for fast tests.
    pub fn tiny(batch: usize) -> NativeMlp {
        NativeMlp::new(vec![16, 24, 4], batch, 0)
    }

    fn wb<'a>(&self, params: &'a [f32], i: usize) -> (&'a [f32], &'a [f32]) {
        let l = &self.layers[i];
        let w_len = self.dims[i] * self.dims[i + 1];
        let s = &params[l.offset..l.offset + l.len];
        (&s[..w_len], &s[w_len..])
    }

    /// Forward pass; returns activations per layer (a[0] = input copy).
    fn forward(&self, params: &[f32], x: &[f32], rows: usize) -> Vec<Vec<f32>> {
        let mut acts = vec![x.to_vec()];
        let n_layers = self.dims.len() - 1;
        for i in 0..n_layers {
            let (w, b) = self.wb(params, i);
            let (din, dout) = (self.dims[i], self.dims[i + 1]);
            let mut out = vec![0.0f32; rows * dout];
            // bias
            for r in 0..rows {
                out[r * dout..(r + 1) * dout].copy_from_slice(b);
            }
            ops::matmul_acc(&mut out, &acts[i], w, rows, din, dout);
            if i < n_layers - 1 {
                for v in out.iter_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
            acts.push(out);
        }
        acts
    }

    /// Full backprop; returns (grads, loss).
    fn backprop(&self, params: &[f32], x: &[f32], y: &[i32], rows: usize) -> (Vec<f32>, f32) {
        let n_layers = self.dims.len() - 1;
        let acts = self.forward(params, x, rows);
        let classes = self.classes();
        let mut grads = vec![0.0f32; self.param_count];
        let mut delta = vec![0.0f32; rows * classes];
        let loss = ops::softmax_xent(&acts[n_layers], y, rows, classes, &mut delta);

        for i in (0..n_layers).rev() {
            let (din, dout) = (self.dims[i], self.dims[i + 1]);
            let l = &self.layers[i];
            let w_len = din * dout;
            // dW = a[i]ᵀ · delta   (a[i] stored [rows, din])
            {
                let (gw, gb) = grads[l.offset..l.offset + l.len].split_at_mut(w_len);
                ops::matmul_at_acc(gw, &acts[i], &delta, din, rows, dout);
                // db = column sums of delta
                for r in 0..rows {
                    ops::add_into(gb, &delta[r * dout..(r + 1) * dout]);
                }
            }
            if i > 0 {
                // dx = delta · Wᵀ, masked by relu'(a[i])
                let (w, _) = self.wb(params, i);
                let mut dx = vec![0.0f32; rows * din];
                // w stored [din, dout]; need delta[rows,dout] · wᵀ[dout,din]
                // = matmul_bt with B stored [din? ] — use plain loops via
                // matmul_acc on transposed w
                // Build wt [dout, din] once per layer (din*dout floats).
                let mut wt = vec![0.0f32; w_len];
                for a_ in 0..din {
                    for b_ in 0..dout {
                        wt[b_ * din + a_] = w[a_ * dout + b_];
                    }
                }
                ops::matmul_acc(&mut dx, &delta, &wt, rows, dout, din);
                // relu mask from a[i] (post-activation: zero where act==0)
                for (d, &a_) in dx.iter_mut().zip(&acts[i]) {
                    if a_ <= 0.0 {
                        *d = 0.0;
                    }
                }
                delta = dx;
            }
        }
        (grads, loss)
    }
}

impl ModelBackend for NativeMlp {
    fn param_count(&self) -> usize {
        self.param_count
    }

    fn layers(&self) -> &[LayerSlice] {
        &self.layers
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn x_len(&self) -> usize {
        self.batch * self.dims[0]
    }

    fn labels_len(&self) -> usize {
        self.batch
    }

    fn classes(&self) -> usize {
        *self.dims.last().unwrap()
    }

    fn x_is_int(&self) -> bool {
        false
    }

    fn init_params(&self) -> Vec<f32> {
        // He init, zero biases — same scheme as ParamSpec.init
        let mut rng = Rng::new(self.init_seed);
        let mut out = vec![0.0f32; self.param_count];
        for (i, l) in self.layers.iter().enumerate() {
            let (din, dout) = (self.dims[i], self.dims[i + 1]);
            let scale = (2.0 / din as f64).sqrt() as f32;
            let w = &mut out[l.offset..l.offset + din * dout];
            for v in w.iter_mut() {
                *v = rng.normal_f32() * scale;
            }
        }
        out
    }

    fn grad(&self, params: &[f32], x: &BatchData, y: &[i32]) -> (Vec<f32>, f32) {
        self.backprop(params, x.as_f32(), y, self.batch)
    }

    fn train_step(
        &self,
        params: &mut [f32],
        mom: &mut [f32],
        x: &BatchData,
        y: &[i32],
        lr: f32,
    ) -> f32 {
        let (grads, loss) = self.backprop(params, x.as_f32(), y, self.batch);
        ops::sgd_momentum(params, mom, &grads, lr, self.momentum);
        loss
    }

    fn apply_update(&self, params: &mut [f32], mom: &mut [f32], grads: &[f32], lr: f32) {
        ops::sgd_momentum(params, mom, grads, lr, self.momentum);
    }

    fn eval(&self, params: &[f32], x: &BatchData, y: &[i32]) -> (f32, f32) {
        let rows = self.batch;
        let acts = self.forward(params, x.as_f32(), rows);
        let classes = self.classes();
        let logits = acts.last().unwrap();
        let mut d = vec![0.0f32; rows * classes];
        let loss = ops::softmax_xent(logits, y, rows, classes, &mut d);
        let mut correct = 0.0f32;
        for r in 0..rows {
            let row = &logits[r * classes..(r + 1) * classes];
            let arg = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if arg as i32 == y[r] {
                correct += 1.0;
            }
        }
        (loss, correct)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batch(m: &NativeMlp, seed: u64) -> (BatchData, Vec<i32>) {
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..m.x_len()).map(|_| rng.normal_f32() * 0.5).collect();
        let y: Vec<i32> = (0..m.batch()).map(|_| rng.below(m.classes()) as i32).collect();
        (BatchData::F32(x), y)
    }

    #[test]
    fn layer_table_contiguous() {
        let m = NativeMlp::mnist(8);
        let mut off = 0;
        for l in m.layers() {
            assert_eq!(l.offset, off);
            off += l.len;
        }
        assert_eq!(off, m.param_count());
        assert_eq!(m.param_count(), 784 * 512 + 512 + 512 * 256 + 256 + 256 * 10 + 10);
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let m = NativeMlp::tiny(4);
        let params = m.init_params();
        let (x, y) = batch(&m, 1);
        let (grads, loss0) = m.grad(&params, &x, &y);
        assert!(loss0.is_finite());
        // check a scatter of coordinates with central differences
        let mut rng = Rng::new(7);
        let eps = 1e-3f32;
        for _ in 0..20 {
            let i = rng.below(m.param_count());
            let mut pp = params.clone();
            pp[i] += eps;
            let (_, lp) = m.grad(&pp, &x, &y);
            pp[i] -= 2.0 * eps;
            let (_, lm) = m.grad(&pp, &x, &y);
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - grads[i]).abs() < 2e-2 * (1.0 + fd.abs()),
                "coord {i}: fd {fd} vs analytic {}",
                grads[i]
            );
        }
    }

    #[test]
    fn training_learns_fixed_batch() {
        let m = NativeMlp::tiny(8);
        let mut params = m.init_params();
        let mut mom = vec![0.0; m.param_count()];
        let (x, y) = batch(&m, 3);
        let first = m.train_step(&mut params, &mut mom, &x, &y, 0.1);
        let mut last = first;
        for _ in 0..60 {
            last = m.train_step(&mut params, &mut mom, &x, &y, 0.1);
        }
        assert!(
            last < 0.3 * first,
            "failed to memorize batch: {first} -> {last}"
        );
        let (_, correct) = m.eval(&params, &x, &y);
        assert!(correct >= 7.0, "correct={correct}");
    }

    #[test]
    fn grad_plus_update_equals_train_step() {
        let m = NativeMlp::tiny(4);
        let (x, y) = batch(&m, 9);
        let mut p1 = m.init_params();
        let mut v1 = vec![0.0; m.param_count()];
        let mut p2 = p1.clone();
        let mut v2 = v1.clone();
        m.train_step(&mut p1, &mut v1, &x, &y, 0.05);
        let (g, _) = m.grad(&p2, &x, &y);
        m.apply_update(&mut p2, &mut v2, &g, 0.05);
        assert_eq!(p1, p2);
        assert_eq!(v1, v2);
    }

    #[test]
    fn deterministic_init() {
        let a = NativeMlp::mnist(4).init_params();
        let b = NativeMlp::mnist(4).init_params();
        assert_eq!(a, b);
    }
}
