//! Hot-path numerics.  Written as straight slices + chunked loops so the
//! autovectorizer emits AVX on this target (verified in EXPERIMENTS.md
//! §Perf via the hotpath bench); no unsafe, no hand intrinsics.

/// GossipGraD pairwise mixing: `a <- (a + b) / 2`, in place.
/// The L3 hot path (runs every gossip step over the full flat model).
pub fn mix_into(a: &mut [f32], b: &[f32]) {
    assert_eq!(a.len(), b.len());
    for (x, &y) in a.iter_mut().zip(b) {
        *x = (*x + y) * 0.5;
    }
}

/// Out-of-place mixing into a caller-provided buffer (steady-state
/// allocation-free form).
pub fn mix_to(out: &mut [f32], a: &[f32], b: &[f32]) {
    assert!(out.len() == a.len() && a.len() == b.len());
    for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
        *o = (x + y) * 0.5;
    }
}

/// `acc += x`.
pub fn add_into(acc: &mut [f32], x: &[f32]) {
    assert_eq!(acc.len(), x.len());
    for (a, &b) in acc.iter_mut().zip(x) {
        *a += b;
    }
}

/// `buf *= k`.
pub fn scale(buf: &mut [f32], k: f32) {
    for v in buf.iter_mut() {
        *v *= k;
    }
}

/// Fused momentum-SGD (the native mirror of the Pallas update kernel):
/// `v = mu*v + g; p -= lr*v` in one pass.
pub fn sgd_momentum(params: &mut [f32], mom: &mut [f32], grads: &[f32], lr: f32, mu: f32) {
    assert!(params.len() == mom.len() && mom.len() == grads.len());
    for ((p, v), &g) in params.iter_mut().zip(mom.iter_mut()).zip(grads) {
        let nv = mu * *v + g;
        *v = nv;
        *p -= lr * nv;
    }
}

/// C[m,n] += A[m,k] · B[k,n]  (row-major, i-k-j loop order so the inner
/// loop is a contiguous axpy the vectorizer likes).
pub fn matmul_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue; // relu sparsity shortcut
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// C[m,n] += Aᵀ[m,k] · B[k,n] where A is stored [k,m] (for dW = xᵀ·g).
pub fn matmul_at_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), k * m);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    for kk in 0..k {
        let arow = &a[kk * m..(kk + 1) * m];
        let brow = &b[kk * n..(kk + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// C[m,n] += A[m,k] · Bᵀ[k,n] where B is stored [n,k] (for dx = g·Wᵀ).
pub fn matmul_bt_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (j, cv) in crow.iter_mut().enumerate() {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for (&av, &bv) in arow.iter().zip(brow) {
                acc += av * bv;
            }
            *cv += acc;
        }
    }
}

/// Row-wise softmax cross-entropy.  Returns mean NLL; writes
/// `(softmax - onehot) / rows` into `dlogits`.
pub fn softmax_xent(
    logits: &[f32],
    labels: &[i32],
    rows: usize,
    classes: usize,
    dlogits: &mut [f32],
) -> f32 {
    assert_eq!(logits.len(), rows * classes);
    assert_eq!(dlogits.len(), logits.len());
    let mut loss = 0.0f64;
    let inv = 1.0 / rows as f32;
    for r in 0..rows {
        let row = &logits[r * classes..(r + 1) * classes];
        let drow = &mut dlogits[r * classes..(r + 1) * classes];
        let mx = row.iter().cloned().fold(f32::MIN, f32::max);
        let mut z = 0.0f32;
        for (d, &v) in drow.iter_mut().zip(row) {
            let e = (v - mx).exp();
            *d = e;
            z += e;
        }
        let label = labels[r] as usize;
        loss += -(((row[label] - mx) - z.ln()) as f64);
        for d in drow.iter_mut() {
            *d = *d / z * inv;
        }
        drow[label] -= inv;
    }
    (loss / rows as f64) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn mix_into_averages() {
        let mut a = vec![1.0, 2.0, 3.0];
        mix_into(&mut a, &[3.0, 2.0, 1.0]);
        assert_eq!(a, vec![2.0, 2.0, 2.0]);
    }

    #[test]
    fn sgd_momentum_matches_formula() {
        let mut p = vec![1.0f32, 2.0];
        let mut v = vec![0.5f32, -0.5];
        sgd_momentum(&mut p, &mut v, &[0.1, 0.2], 0.1, 0.9);
        // v' = 0.9*0.5 + 0.1 = 0.55 ; p' = 1 - 0.055 = 0.945
        assert!((v[0] - 0.55).abs() < 1e-6);
        assert!((p[0] - 0.945).abs() < 1e-6);
    }

    fn naive_matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                for kk in 0..k {
                    c[i * n + j] += a[i * k + kk] * b[kk * n + j];
                }
            }
        }
        c
    }

    #[test]
    fn matmul_variants_agree_with_naive() {
        let mut rng = Rng::new(1);
        let (m, k, n) = (7, 11, 5);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal_f32()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let want = naive_matmul(&a, &b, m, k, n);

        let mut c = vec![0.0; m * n];
        matmul_acc(&mut c, &a, &b, m, k, n);
        for (x, y) in c.iter().zip(&want) {
            assert!((x - y).abs() < 1e-4);
        }

        // Aᵀ form: store a as [k,m]
        let mut at = vec![0.0; k * m];
        for i in 0..m {
            for kk in 0..k {
                at[kk * m + i] = a[i * k + kk];
            }
        }
        let mut c2 = vec![0.0; m * n];
        matmul_at_acc(&mut c2, &at, &b, m, k, n);
        for (x, y) in c2.iter().zip(&want) {
            assert!((x - y).abs() < 1e-4);
        }

        // Bᵀ form: store b as [n,k]
        let mut bt = vec![0.0; n * k];
        for kk in 0..k {
            for j in 0..n {
                bt[j * k + kk] = b[kk * n + j];
            }
        }
        let mut c3 = vec![0.0; m * n];
        matmul_bt_acc(&mut c3, &a, &bt, m, k, n);
        for (x, y) in c3.iter().zip(&want) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn xent_matches_hand_case() {
        // logits [[0,0]] label 0 -> loss ln(2), grad [(0.5-1)/1, 0.5]
        let mut d = vec![0.0; 2];
        let loss = softmax_xent(&[0.0, 0.0], &[0], 1, 2, &mut d);
        assert!((loss - (2.0f32).ln()).abs() < 1e-6);
        assert!((d[0] + 0.5).abs() < 1e-6);
        assert!((d[1] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn xent_grad_sums_to_zero_per_row() {
        let mut rng = Rng::new(2);
        let (rows, classes) = (6, 10);
        let logits: Vec<f32> =
            (0..rows * classes).map(|_| 3.0 * rng.normal_f32()).collect();
        let labels: Vec<i32> = (0..rows).map(|r| (r % classes) as i32).collect();
        let mut d = vec![0.0; rows * classes];
        let loss = softmax_xent(&logits, &labels, rows, classes, &mut d);
        assert!(loss.is_finite());
        for r in 0..rows {
            let s: f32 = d[r * classes..(r + 1) * classes].iter().sum();
            assert!(s.abs() < 1e-5, "row {r} grad sum {s}");
        }
    }
}
