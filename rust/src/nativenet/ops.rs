//! Hot-path numerics.  The streaming kernels (`mix_into`, `mix_to`,
//! `add_into`, `sgd_momentum`) walk fixed-width [`LANES`]-element
//! chunks via `chunks_exact`, with each chunk converted to a
//! fixed-size array reference — the compiler sees a constant trip
//! count, unrolls the body, and the autovectorizer emits AVX on this
//! target (measured as effective GB/s by `benches/hotpath.rs`,
//! regression-gated against `BENCH_hotpath.json` — docs/perf.md); no
//! unsafe, no hand intrinsics.  Per-element arithmetic is identical to
//! the plain zip loop (elementwise-independent ops), so `param_hash`
//! stays bit-identical.

/// Chunk width for the streaming kernels: 8 f32 lanes = one AVX2
/// register.  Wider chunks would just spill; narrower ones leave the
/// unroller less to work with.
const LANES: usize = 8;

/// GossipGraD pairwise mixing: `a <- (a + b) / 2`, in place.
/// The L3 hot path (runs every gossip step over the full flat model).
pub fn mix_into(a: &mut [f32], b: &[f32]) {
    assert_eq!(a.len(), b.len());
    let mut ac = a.chunks_exact_mut(LANES);
    let mut bc = b.chunks_exact(LANES);
    for (xs, ys) in ac.by_ref().zip(bc.by_ref()) {
        let xs: &mut [f32; LANES] = xs.try_into().unwrap();
        let ys: &[f32; LANES] = ys.try_into().unwrap();
        for (x, &y) in xs.iter_mut().zip(ys) {
            *x = (*x + y) * 0.5;
        }
    }
    for (x, &y) in ac.into_remainder().iter_mut().zip(bc.remainder()) {
        *x = (*x + y) * 0.5;
    }
}

/// Out-of-place mixing into a caller-provided buffer (steady-state
/// allocation-free form).
pub fn mix_to(out: &mut [f32], a: &[f32], b: &[f32]) {
    assert!(out.len() == a.len() && a.len() == b.len());
    let mut oc = out.chunks_exact_mut(LANES);
    let mut ac = a.chunks_exact(LANES);
    let mut bc = b.chunks_exact(LANES);
    for ((os, xs), ys) in oc.by_ref().zip(ac.by_ref()).zip(bc.by_ref()) {
        let os: &mut [f32; LANES] = os.try_into().unwrap();
        let xs: &[f32; LANES] = xs.try_into().unwrap();
        let ys: &[f32; LANES] = ys.try_into().unwrap();
        for ((o, &x), &y) in os.iter_mut().zip(xs).zip(ys) {
            *o = (x + y) * 0.5;
        }
    }
    for ((o, &x), &y) in oc
        .into_remainder()
        .iter_mut()
        .zip(ac.remainder())
        .zip(bc.remainder())
    {
        *o = (x + y) * 0.5;
    }
}

/// `acc += x`.
pub fn add_into(acc: &mut [f32], x: &[f32]) {
    assert_eq!(acc.len(), x.len());
    let mut ac = acc.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (accs, xs) in ac.by_ref().zip(xc.by_ref()) {
        let accs: &mut [f32; LANES] = accs.try_into().unwrap();
        let xs: &[f32; LANES] = xs.try_into().unwrap();
        for (a, &b) in accs.iter_mut().zip(xs) {
            *a += b;
        }
    }
    for (a, &b) in ac.into_remainder().iter_mut().zip(xc.remainder()) {
        *a += b;
    }
}

/// `buf *= k`.
pub fn scale(buf: &mut [f32], k: f32) {
    for v in buf.iter_mut() {
        *v *= k;
    }
}

/// Fused momentum-SGD (the native mirror of the Pallas update kernel):
/// `v = mu*v + g; p -= lr*v` in one pass.
pub fn sgd_momentum(params: &mut [f32], mom: &mut [f32], grads: &[f32], lr: f32, mu: f32) {
    assert!(params.len() == mom.len() && mom.len() == grads.len());
    let mut pc = params.chunks_exact_mut(LANES);
    let mut mc = mom.chunks_exact_mut(LANES);
    let mut gc = grads.chunks_exact(LANES);
    for ((ps, vs), gs) in pc.by_ref().zip(mc.by_ref()).zip(gc.by_ref()) {
        let ps: &mut [f32; LANES] = ps.try_into().unwrap();
        let vs: &mut [f32; LANES] = vs.try_into().unwrap();
        let gs: &[f32; LANES] = gs.try_into().unwrap();
        for ((p, v), &g) in ps.iter_mut().zip(vs.iter_mut()).zip(gs) {
            let nv = mu * *v + g;
            *v = nv;
            *p -= lr * nv;
        }
    }
    for ((p, v), &g) in pc
        .into_remainder()
        .iter_mut()
        .zip(mc.into_remainder().iter_mut())
        .zip(gc.remainder())
    {
        let nv = mu * *v + g;
        *v = nv;
        *p -= lr * nv;
    }
}

/// C[m,n] += A[m,k] · B[k,n]  (row-major, i-k-j loop order so the inner
/// loop is a contiguous axpy the vectorizer likes).
pub fn matmul_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue; // relu sparsity shortcut
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// C[m,n] += Aᵀ[m,k] · B[k,n] where A is stored [k,m] (for dW = xᵀ·g).
pub fn matmul_at_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), k * m);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    for kk in 0..k {
        let arow = &a[kk * m..(kk + 1) * m];
        let brow = &b[kk * n..(kk + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// C[m,n] += A[m,k] · Bᵀ[k,n] where B is stored [n,k] (for dx = g·Wᵀ).
pub fn matmul_bt_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (j, cv) in crow.iter_mut().enumerate() {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for (&av, &bv) in arow.iter().zip(brow) {
                acc += av * bv;
            }
            *cv += acc;
        }
    }
}

/// Row-wise softmax cross-entropy.  Returns mean NLL; writes
/// `(softmax - onehot) / rows` into `dlogits`.
pub fn softmax_xent(
    logits: &[f32],
    labels: &[i32],
    rows: usize,
    classes: usize,
    dlogits: &mut [f32],
) -> f32 {
    assert_eq!(logits.len(), rows * classes);
    assert_eq!(dlogits.len(), logits.len());
    let mut loss = 0.0f64;
    let inv = 1.0 / rows as f32;
    for r in 0..rows {
        let row = &logits[r * classes..(r + 1) * classes];
        let drow = &mut dlogits[r * classes..(r + 1) * classes];
        let mx = row.iter().cloned().fold(f32::MIN, f32::max);
        let mut z = 0.0f32;
        for (d, &v) in drow.iter_mut().zip(row) {
            let e = (v - mx).exp();
            *d = e;
            z += e;
        }
        let label = labels[r] as usize;
        loss += -(((row[label] - mx) - z.ln()) as f64);
        for d in drow.iter_mut() {
            *d = *d / z * inv;
        }
        drow[label] -= inv;
    }
    (loss / rows as f64) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn mix_into_averages() {
        let mut a = vec![1.0, 2.0, 3.0];
        mix_into(&mut a, &[3.0, 2.0, 1.0]);
        assert_eq!(a, vec![2.0, 2.0, 2.0]);
    }

    #[test]
    fn chunked_kernels_match_scalar_reference_bitwise() {
        // the LANES-chunked bodies must compute exactly what the plain
        // zip loop computed, at every length class (empty, sub-chunk,
        // exact multiple, chunk + remainder)
        let mut rng = Rng::new(3);
        for n in [0usize, 1, 7, 8, 9, 63, 64, 100] {
            let a: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();

            let mut got = a.clone();
            mix_into(&mut got, &b);
            for (i, (g, (&x, &y))) in got.iter().zip(a.iter().zip(&b)).enumerate() {
                assert_eq!(g.to_bits(), ((x + y) * 0.5).to_bits(), "mix_into n={n} i={i}");
            }

            let mut out = vec![0.0; n];
            mix_to(&mut out, &a, &b);
            assert_eq!(out, got, "mix_to must match mix_into");

            let mut acc = a.clone();
            add_into(&mut acc, &b);
            for (i, (g, (&x, &y))) in acc.iter().zip(a.iter().zip(&b)).enumerate() {
                assert_eq!(g.to_bits(), (x + y).to_bits(), "add_into n={n} i={i}");
            }

            let (lr, mu) = (0.05f32, 0.9f32);
            let mut p = a.clone();
            let mut v = b.clone();
            sgd_momentum(&mut p, &mut v, &acc, lr, mu);
            for i in 0..n {
                let nv = mu * b[i] + acc[i];
                assert_eq!(v[i].to_bits(), nv.to_bits(), "sgd mom n={n} i={i}");
                assert_eq!(p[i].to_bits(), (a[i] - lr * nv).to_bits(), "sgd n={n} i={i}");
            }
        }
    }

    #[test]
    fn sgd_momentum_matches_formula() {
        let mut p = vec![1.0f32, 2.0];
        let mut v = vec![0.5f32, -0.5];
        sgd_momentum(&mut p, &mut v, &[0.1, 0.2], 0.1, 0.9);
        // v' = 0.9*0.5 + 0.1 = 0.55 ; p' = 1 - 0.055 = 0.945
        assert!((v[0] - 0.55).abs() < 1e-6);
        assert!((p[0] - 0.945).abs() < 1e-6);
    }

    fn naive_matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                for kk in 0..k {
                    c[i * n + j] += a[i * k + kk] * b[kk * n + j];
                }
            }
        }
        c
    }

    #[test]
    fn matmul_variants_agree_with_naive() {
        let mut rng = Rng::new(1);
        let (m, k, n) = (7, 11, 5);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal_f32()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let want = naive_matmul(&a, &b, m, k, n);

        let mut c = vec![0.0; m * n];
        matmul_acc(&mut c, &a, &b, m, k, n);
        for (x, y) in c.iter().zip(&want) {
            assert!((x - y).abs() < 1e-4);
        }

        // Aᵀ form: store a as [k,m]
        let mut at = vec![0.0; k * m];
        for i in 0..m {
            for kk in 0..k {
                at[kk * m + i] = a[i * k + kk];
            }
        }
        let mut c2 = vec![0.0; m * n];
        matmul_at_acc(&mut c2, &at, &b, m, k, n);
        for (x, y) in c2.iter().zip(&want) {
            assert!((x - y).abs() < 1e-4);
        }

        // Bᵀ form: store b as [n,k]
        let mut bt = vec![0.0; n * k];
        for kk in 0..k {
            for j in 0..n {
                bt[j * k + kk] = b[kk * n + j];
            }
        }
        let mut c3 = vec![0.0; m * n];
        matmul_bt_acc(&mut c3, &a, &bt, m, k, n);
        for (x, y) in c3.iter().zip(&want) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn xent_matches_hand_case() {
        // logits [[0,0]] label 0 -> loss ln(2), grad [(0.5-1)/1, 0.5]
        let mut d = vec![0.0; 2];
        let loss = softmax_xent(&[0.0, 0.0], &[0], 1, 2, &mut d);
        assert!((loss - (2.0f32).ln()).abs() < 1e-6);
        assert!((d[0] + 0.5).abs() < 1e-6);
        assert!((d[1] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn xent_grad_sums_to_zero_per_row() {
        let mut rng = Rng::new(2);
        let (rows, classes) = (6, 10);
        let logits: Vec<f32> =
            (0..rows * classes).map(|_| 3.0 * rng.normal_f32()).collect();
        let labels: Vec<i32> = (0..rows).map(|r| (r % classes) as i32).collect();
        let mut d = vec![0.0; rows * classes];
        let loss = softmax_xent(&logits, &labels, rows, classes, &mut d);
        assert!(loss.is_finite());
        for r in 0..rows {
            let s: f32 = d[r * classes..(r + 1) * classes].iter().sum();
            assert!(s.abs() < 1e-5, "row {r} grad sum {s}");
        }
    }
}
