//! Pure-Rust compute backend.
//!
//! Two roles:
//! 1. [`ops`] — the coordinator's own hot-path numerics (gossip mixing,
//!    fused momentum update, blocked matmul).  The mixer here is the
//!    "native" side of the mixing ablation against the Pallas AOT
//!    artifact (benches/hotpath.rs).
//! 2. [`mlp`] — a complete MLP model (same family as the AOT `mlp`
//!    artifacts, same flat-parameter layout) with hand-written backprop.
//!    Used for artifact-independent tests and large-p experiments where
//!    compiling/sharing XLA executables is not the point.

pub mod mlp;
pub mod ops;

pub use mlp::NativeMlp;
