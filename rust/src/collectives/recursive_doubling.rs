//! Recursive-doubling all-reduce: ⌈log₂ p⌉ pairwise full-vector
//! exchanges.  For non-power-of-two p, the standard fold: extra ranks
//! first send their vector to a partner in the power-of-two core, the
//! core runs recursive doubling, and the result is sent back.
//!
//! Expressed as a per-round state machine ([`RecursiveDoublingMachine`])
//! so the engine can progress it non-blockingly; the arithmetic order
//! (fold-add, core adds in doubling order, scale, unfold) is identical
//! to the historical blocking implementation, so results are
//! bit-identical.

use super::engine::{RoundMachine, SendCtx, Step};
use super::{add_into, scale, Algorithm};
use crate::transport::{Endpoint, Tag};

/// Blocking convenience wrapper (post + wait through the engine).
pub fn recursive_doubling_allreduce(ep: &Endpoint, buf: &mut [f32], round: usize) {
    Algorithm::RecursiveDoubling.run(ep, buf, round);
}

enum RdState {
    /// me >= core: folded our vector in, awaiting the reduced result.
    FoldedOut,
    /// me < rem: awaiting the extra rank's fold-in.
    AwaitExtra,
    /// In the power-of-two core, awaiting the partner at `dist`.
    Core,
}

pub(crate) struct RecursiveDoublingMachine {
    p: usize,
    me: usize,
    core: usize,
    rem: usize,
    tag: Tag,
    dist: usize,
    state: RdState,
}

impl RecursiveDoublingMachine {
    pub(crate) fn new(p: usize, me: usize, round: usize) -> Self {
        let core = 1usize << crate::util::ceil_log2(p + 1).saturating_sub(1).min(63);
        let core = if core > p { core >> 1 } else { core }; // largest pow2 <= p
        RecursiveDoublingMachine {
            p,
            me,
            core,
            rem: p - core,
            tag: Tag::REDUCE.round(round),
            dist: 1,
            state: RdState::Core,
        }
    }

    /// First core round: send to the dist-1 partner, await its vector.
    fn enter_core(&mut self, buf: &mut [f32], ctx: &SendCtx) -> Step {
        self.dist = 1;
        self.state = RdState::Core;
        let partner = self.me ^ 1;
        ctx.send(partner, self.tag, buf);
        Step::Pending(partner, self.tag)
    }
}

impl RoundMachine for RecursiveDoublingMachine {
    fn start(&mut self, buf: &mut [f32], ctx: &SendCtx) -> Step {
        if self.me >= self.core {
            // fold phase: park our vector in the core, await the result
            ctx.send(self.me - self.core, self.tag, buf);
            self.state = RdState::FoldedOut;
            return Step::Pending(self.me - self.core, self.tag);
        }
        if self.me < self.rem {
            self.state = RdState::AwaitExtra;
            return Step::Pending(self.me + self.core, self.tag);
        }
        self.enter_core(buf, ctx)
    }

    fn deliver(&mut self, buf: &mut [f32], data: &[f32], ctx: &SendCtx) -> Step {
        match self.state {
            RdState::FoldedOut => {
                buf.copy_from_slice(data);
                Step::Finished
            }
            RdState::AwaitExtra => {
                add_into(buf, data);
                self.enter_core(buf, ctx)
            }
            RdState::Core => {
                add_into(buf, data);
                self.dist <<= 1;
                if self.dist < self.core {
                    let partner = self.me ^ self.dist;
                    ctx.send(partner, self.tag, buf);
                    return Step::Pending(partner, self.tag);
                }
                scale(buf, 1.0 / self.p as f32);
                // unfold phase: hand the result back to the folded rank
                if self.me < self.rem {
                    ctx.send(self.me + self.core, self.tag, buf);
                }
                Step::Finished
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::{CostModel, Fabric};
    use std::thread;

    #[test]
    fn two_ranks_average() {
        let f = Fabric::new(2, CostModel::zero());
        let h: Vec<_> = (0..2)
            .map(|r| {
                let ep = f.endpoint(r);
                thread::spawn(move || {
                    let mut b = vec![r as f32 * 2.0; 8];
                    recursive_doubling_allreduce(&ep, &mut b, 0);
                    b
                })
            })
            .collect();
        for t in h {
            assert_eq!(t.join().unwrap(), vec![1.0; 8]);
        }
    }

    #[test]
    fn three_ranks_fold_unfold() {
        let f = Fabric::new(3, CostModel::zero());
        let h: Vec<_> = (0..3)
            .map(|r| {
                let ep = f.endpoint(r);
                thread::spawn(move || {
                    let mut b = vec![(r + 1) as f32; 4];
                    recursive_doubling_allreduce(&ep, &mut b, 0);
                    b
                })
            })
            .collect();
        for t in h {
            let got = t.join().unwrap();
            assert!((got[0] - 2.0).abs() < 1e-6, "{got:?}");
        }
    }
}
