//! Recursive-doubling all-reduce: ⌈log₂ p⌉ pairwise full-vector
//! exchanges.  For non-power-of-two p, the standard fold: extra ranks
//! first send their vector to a partner in the power-of-two core, the
//! core runs recursive doubling, and the result is sent back.

use super::{add_into, scale};
use crate::transport::{Endpoint, Tag};

pub fn recursive_doubling_allreduce(ep: &Endpoint, buf: &mut [f32], round: usize) {
    let p = ep.size();
    let me = ep.rank();
    if p == 1 {
        return;
    }
    let tag = Tag::REDUCE.round(round);
    let core = 1usize << crate::util::ceil_log2(p + 1).saturating_sub(1).min(63);
    let core = if core > p { core >> 1 } else { core }; // largest pow2 <= p
    let rem = p - core;

    // fold phase: ranks >= core send to (rank - core)
    if me >= core {
        ep.send(me - core, tag, buf.to_vec());
        // idle during the core exchange; wait for the result broadcast
        let out = ep.recv(me - core, tag);
        buf.copy_from_slice(&out);
        return;
    }
    if me < rem {
        let extra = ep.recv(me + core, tag);
        add_into(buf, &extra);
    }

    // core recursive doubling over `core` ranks
    let mut dist = 1usize;
    while dist < core {
        let partner = me ^ dist;
        ep.isend(partner, tag, buf.to_vec());
        let theirs = ep.recv(partner, tag);
        add_into(buf, &theirs);
        dist <<= 1;
    }

    scale(buf, 1.0 / p as f32);

    // unfold phase
    if me < rem {
        ep.send(me + core, tag, buf.to_vec());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::{CostModel, Fabric};
    use std::thread;

    #[test]
    fn two_ranks_average() {
        let f = Fabric::new(2, CostModel::zero());
        let h: Vec<_> = (0..2)
            .map(|r| {
                let ep = f.endpoint(r);
                thread::spawn(move || {
                    let mut b = vec![r as f32 * 2.0; 8];
                    recursive_doubling_allreduce(&ep, &mut b, 0);
                    b
                })
            })
            .collect();
        for t in h {
            assert_eq!(t.join().unwrap(), vec![1.0; 8]);
        }
    }

    #[test]
    fn three_ranks_fold_unfold() {
        let f = Fabric::new(3, CostModel::zero());
        let h: Vec<_> = (0..3)
            .map(|r| {
                let ep = f.endpoint(r);
                thread::spawn(move || {
                    let mut b = vec![(r + 1) as f32; 4];
                    recursive_doubling_allreduce(&ep, &mut b, 0);
                    b
                })
            })
            .collect();
        for t in h {
            let got = t.join().unwrap();
            assert!((got[0] - 2.0).abs() < 1e-6, "{got:?}");
        }
    }
}
