//! All-to-all reduction algorithms over the transport — the substrate of
//! the paper's SGD/AGD baselines (§3) and of the PowerAI-style
//! comparison in Table 7.
//!
//! All algorithms compute the elementwise **average** across ranks (the
//! gradient all-reduce of data-parallel SGD) and are SPMD: every rank
//! posts the same collective with its own endpoint and buffer.  Each is
//! a per-round state machine run by the non-blocking [`engine`]
//! ([`IAllreduce`]: post / progress / test / wait); the blocking
//! [`Algorithm::run`] is post-plus-immediate-wait with the historical
//! dependency-chained accounting.
//!
//! * [`recursive_doubling`] — ⌈log₂ p⌉ rounds of pairwise exchange of the
//!   full vector (the binomial/k-nomial tree cost the paper's Θ(log p)
//!   bound refers to).  General p via the standard fold-to-power-of-two
//!   pre/post phase.
//! * [`binomial_tree`] — reduce-to-root + broadcast, 2⌈log₂ p⌉ rounds,
//!   half the bandwidth of recursive doubling at the root bottleneck.
//! * [`ring_allreduce`] — 2(p−1) rounds on 1/p-sized chunks; the
//!   bandwidth-optimal "hierarchical ring" PowerAI uses (Table 7 note).

pub mod binomial_tree;
pub mod engine;
pub mod recursive_doubling;
pub mod ring_allreduce;

pub use binomial_tree::binomial_tree_allreduce;
pub use engine::IAllreduce;
pub use recursive_doubling::recursive_doubling_allreduce;
pub use ring_allreduce::ring_allreduce;

use crate::transport::Endpoint;

/// Which all-reduce algorithm a baseline uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    RecursiveDoubling,
    BinomialTree,
    Ring,
}

impl Algorithm {
    /// Blocking all-reduce: post the state machine and harvest it
    /// immediately, with the dependency-chained (pre-engine) ledger —
    /// rounds stay exposed on the caller's clock, exactly the schedule
    /// the paper's Θ(log p) critique targets.
    pub fn run(self, ep: &Endpoint, buf: &mut [f32], round: usize) {
        if ep.size() == 1 {
            return; // average of one rank is itself — no traffic, no copies
        }
        let work = ep.pool().copy_f32(buf);
        let out = IAllreduce::post_blocking(ep, self, work, round).wait(ep);
        buf.copy_from_slice(&out);
        ep.pool().put_f32(out);
    }

    pub fn name(self) -> &'static str {
        match self {
            Algorithm::RecursiveDoubling => "recursive-doubling",
            Algorithm::BinomialTree => "binomial-tree",
            Algorithm::Ring => "ring",
        }
    }

    /// Inverse of [`name`](Self::name), plus the short CLI aliases.
    pub fn parse(s: &str) -> Result<Algorithm, String> {
        Ok(match s {
            "recursive-doubling" | "rd" => Algorithm::RecursiveDoubling,
            "binomial-tree" | "tree" => Algorithm::BinomialTree,
            "ring" => Algorithm::Ring,
            other => return Err(format!("unknown allreduce {other:?}")),
        })
    }

    /// Number of communication rounds on the critical path for `p` ranks
    /// — the Θ(log p) (or 2(p−1)) terms of Table 1 / §3.1.
    pub fn rounds(self, p: usize) -> usize {
        let lg = crate::util::ceil_log2(p);
        match self {
            Algorithm::RecursiveDoubling => lg,
            Algorithm::BinomialTree => 2 * lg,
            Algorithm::Ring => 2 * p.saturating_sub(1),
        }
    }
}

/// Elementwise `acc += x` (the reduction op).
pub(crate) fn add_into(acc: &mut [f32], x: &[f32]) {
    debug_assert_eq!(acc.len(), x.len());
    for (a, b) in acc.iter_mut().zip(x) {
        *a += b;
    }
}

/// Divide by p to turn the sum into the data-parallel average.
pub(crate) fn scale(buf: &mut [f32], k: f32) {
    for v in buf.iter_mut() {
        *v *= k;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::{CostModel, Fabric};
    use crate::util::Rng;
    use std::thread;

    /// Run `alg` on `p` ranks with seeded random vectors; check every
    /// rank ends with the exact average (within fp tolerance).
    fn check(alg: Algorithm, p: usize, n: usize) {
        let fabric = Fabric::new(p, CostModel::zero());
        let inputs: Vec<Vec<f32>> = (0..p)
            .map(|r| {
                let mut rng = Rng::new(100 + r as u64);
                (0..n).map(|_| rng.normal_f32()).collect()
            })
            .collect();
        let mut want = vec![0.0f32; n];
        for v in &inputs {
            add_into(&mut want, v);
        }
        scale(&mut want, 1.0 / p as f32);

        let handles: Vec<_> = (0..p)
            .map(|r| {
                let ep = fabric.endpoint(r);
                let mut buf = inputs[r].clone();
                thread::spawn(move || {
                    alg.run(&ep, &mut buf, 0);
                    buf
                })
            })
            .collect();
        for h in handles {
            let got = h.join().unwrap();
            for (g, w) in got.iter().zip(&want) {
                assert!(
                    (g - w).abs() <= 1e-4 * w.abs().max(1.0),
                    "{} p={p} n={n}: {g} vs {w}",
                    alg.name()
                );
            }
        }
    }

    #[test]
    fn all_algorithms_all_sizes() {
        for alg in [
            Algorithm::RecursiveDoubling,
            Algorithm::BinomialTree,
            Algorithm::Ring,
        ] {
            for p in [1usize, 2, 3, 4, 5, 7, 8, 12, 16] {
                check(alg, p, 257);
            }
        }
    }

    #[test]
    fn consecutive_rounds_do_not_cross() {
        // two back-to-back allreduces must not mix messages
        let p = 4;
        let fabric = Fabric::new(p, CostModel::zero());
        let handles: Vec<_> = (0..p)
            .map(|r| {
                let ep = fabric.endpoint(r);
                thread::spawn(move || {
                    let mut a = vec![r as f32; 64];
                    let mut b = vec![(r * 10) as f32; 64];
                    recursive_doubling_allreduce(&ep, &mut a, 0);
                    recursive_doubling_allreduce(&ep, &mut b, 1);
                    (a, b)
                })
            })
            .collect();
        let avg_a = (0..p).map(|r| r as f32).sum::<f32>() / p as f32;
        let avg_b = (0..p).map(|r| (r * 10) as f32).sum::<f32>() / p as f32;
        for h in handles {
            let (a, b) = h.join().unwrap();
            assert!((a[0] - avg_a).abs() < 1e-5);
            assert!((b[0] - avg_b).abs() < 1e-5);
        }
    }

    #[test]
    fn round_counts_match_complexity_table() {
        // Table 1: Θ(log p) for tree-based, 2(p-1) for ring
        assert_eq!(Algorithm::RecursiveDoubling.rounds(128), 7);
        assert_eq!(Algorithm::BinomialTree.rounds(128), 14);
        assert_eq!(Algorithm::Ring.rounds(128), 254);
    }

    #[test]
    fn message_count_scales_log_p_for_recursive_doubling() {
        // the comm-complexity assertion behind Table 1
        for p in [4usize, 8, 16] {
            let fabric = Fabric::new(p, CostModel::zero());
            let handles: Vec<_> = (0..p)
                .map(|r| {
                    let ep = fabric.endpoint(r);
                    thread::spawn(move || {
                        let mut buf = vec![1.0f32; 32];
                        recursive_doubling_allreduce(&ep, &mut buf, 0);
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            let per_rank = fabric.total_msgs() as usize / p;
            assert_eq!(
                per_rank,
                crate::util::ceil_log2(p),
                "p={p}: {per_rank} msgs/rank"
            );
        }
    }
}
