//! Ring all-reduce: reduce-scatter + all-gather over a ring, 2(p−1)
//! rounds on n/p-sized chunks — bandwidth-optimal, the building block of
//! PowerAI's "hierarchical rings" that Table 7 compares against.
//!
//! Expressed as a per-round state machine ([`RingMachine`]) for the
//! non-blocking engine; chunk walk and accumulation order are identical
//! to the historical blocking implementation.

use super::engine::{RoundMachine, SendCtx, Step};
use super::{scale, Algorithm};
use crate::transport::{Endpoint, Tag};

/// Blocking convenience wrapper (post + wait through the engine).
pub fn ring_allreduce(ep: &Endpoint, buf: &mut [f32], round: usize) {
    Algorithm::Ring.run(ep, buf, round);
}

enum RingPhase {
    ReduceScatter,
    AllGather,
}

pub(crate) struct RingMachine {
    p: usize,
    me: usize,
    tag: Tag,
    /// chunk c covers [starts[c], starts[c+1]) — set once n is known.
    starts: Vec<usize>,
    next: usize,
    prev: usize,
    s: usize,
    phase: RingPhase,
}

impl RingMachine {
    pub(crate) fn new(p: usize, me: usize, round: usize) -> Self {
        RingMachine {
            p,
            me,
            tag: Tag::REDUCE.round(round),
            starts: Vec::new(),
            next: (me + 1) % p,
            prev: (me + p - 1) % p,
            s: 0,
            phase: RingPhase::ReduceScatter,
        }
    }

    fn chunk<'a>(&self, buf: &'a [f32], c: usize) -> &'a [f32] {
        &buf[self.starts[c]..self.starts[c + 1]]
    }

    /// Send the reduce-scatter chunk for step `s` and name its matching
    /// receive.
    fn rs_round(&mut self, buf: &[f32], ctx: &SendCtx) -> Step {
        let send_c = (self.me + self.p - self.s) % self.p;
        ctx.send(self.next, self.tag.sub(self.s), self.chunk(buf, send_c));
        Step::Pending(self.prev, self.tag.sub(self.s))
    }

    /// Send the all-gather chunk for step `s` and name its receive.
    fn ag_round(&mut self, buf: &[f32], ctx: &SendCtx) -> Step {
        let send_c = (self.me + 1 + self.p - self.s) % self.p;
        let t = self.tag.sub(self.p + self.s);
        ctx.send(self.next, t, self.chunk(buf, send_c));
        Step::Pending(self.prev, t)
    }
}

impl RoundMachine for RingMachine {
    fn start(&mut self, buf: &mut [f32], ctx: &SendCtx) -> Step {
        let n = buf.len();
        self.starts = (0..=self.p).map(|c| c * n / self.p).collect();
        self.rs_round(buf, ctx)
    }

    fn deliver(&mut self, buf: &mut [f32], data: &[f32], ctx: &SendCtx) -> Step {
        match self.phase {
            RingPhase::ReduceScatter => {
                let recv_c = (self.me + self.p - self.s - 1) % self.p;
                let dst = &mut buf[self.starts[recv_c]..self.starts[recv_c + 1]];
                for (a, b) in dst.iter_mut().zip(data) {
                    *a += b;
                }
                self.s += 1;
                if self.s < self.p - 1 {
                    return self.rs_round(buf, ctx);
                }
                // each rank now owns the fully reduced chunk (me + 1) % p
                let owned = (self.me + 1) % self.p;
                scale(
                    &mut buf[self.starts[owned]..self.starts[owned + 1]],
                    1.0 / self.p as f32,
                );
                self.phase = RingPhase::AllGather;
                self.s = 0;
                self.ag_round(buf, ctx)
            }
            RingPhase::AllGather => {
                let recv_c = (self.me + self.p - self.s) % self.p;
                buf[self.starts[recv_c]..self.starts[recv_c + 1]]
                    .copy_from_slice(data);
                self.s += 1;
                if self.s < self.p - 1 {
                    return self.ag_round(buf, ctx);
                }
                Step::Finished
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::{CostModel, Fabric};
    use std::thread;

    #[test]
    fn averages_with_ragged_chunks() {
        // n not divisible by p exercises the uneven chunk boundaries
        for (p, n) in [(2usize, 7usize), (3, 10), (5, 23), (8, 64), (4, 3)] {
            let f = Fabric::new(p, CostModel::zero());
            let h: Vec<_> = (0..p)
                .map(|r| {
                    let ep = f.endpoint(r);
                    thread::spawn(move || {
                        let mut b: Vec<f32> =
                            (0..n).map(|i| (r * n + i) as f32).collect();
                        ring_allreduce(&ep, &mut b, 0);
                        b
                    })
                })
                .collect();
            let want: Vec<f32> = (0..n)
                .map(|i| {
                    (0..p).map(|r| (r * n + i) as f32).sum::<f32>() / p as f32
                })
                .collect();
            for t in h {
                let got = t.join().unwrap();
                for (g, w) in got.iter().zip(&want) {
                    assert!((g - w).abs() < 1e-4, "p={p} n={n}: {got:?}");
                }
            }
        }
    }
}
