//! Ring all-reduce: reduce-scatter + all-gather over a ring, 2(p−1)
//! rounds on n/p-sized chunks — bandwidth-optimal, the building block of
//! PowerAI's "hierarchical rings" that Table 7 compares against.

use super::scale;
use crate::transport::{Endpoint, Tag};

pub fn ring_allreduce(ep: &Endpoint, buf: &mut [f32], round: usize) {
    let p = ep.size();
    let me = ep.rank();
    if p == 1 {
        return;
    }
    let tag = Tag::REDUCE.round(round);
    let n = buf.len();
    // chunk c covers [starts[c], starts[c+1])
    let starts: Vec<usize> = (0..=p).map(|c| c * n / p).collect();
    let next = (me + 1) % p;
    let prev = (me + p - 1) % p;

    // reduce-scatter: at step s, send chunk (me - s) and accumulate
    // chunk (me - s - 1) from the left neighbour
    for s in 0..p - 1 {
        let send_c = (me + p - s) % p;
        let recv_c = (me + p - s - 1) % p;
        let chunk = buf[starts[send_c]..starts[send_c + 1]].to_vec();
        ep.isend(next, tag.sub(s), chunk);
        let theirs = ep.recv(prev, tag.sub(s));
        let dst = &mut buf[starts[recv_c]..starts[recv_c + 1]];
        for (a, b) in dst.iter_mut().zip(&theirs) {
            *a += b;
        }
    }
    // each rank now owns the fully reduced chunk (me + 1) % p
    let owned = (me + 1) % p;
    scale(&mut buf[starts[owned]..starts[owned + 1]], 1.0 / p as f32);

    // all-gather: circulate the reduced chunks p-1 more steps
    for s in 0..p - 1 {
        let send_c = (me + 1 + p - s) % p;
        let recv_c = (me + p - s) % p;
        let chunk = buf[starts[send_c]..starts[send_c + 1]].to_vec();
        ep.isend(next, tag.sub(p + s), chunk);
        let theirs = ep.recv(prev, tag.sub(p + s));
        buf[starts[recv_c]..starts[recv_c + 1]].copy_from_slice(&theirs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::{CostModel, Fabric};
    use std::thread;

    #[test]
    fn averages_with_ragged_chunks() {
        // n not divisible by p exercises the uneven chunk boundaries
        for (p, n) in [(2usize, 7usize), (3, 10), (5, 23), (8, 64), (4, 3)] {
            let f = Fabric::new(p, CostModel::zero());
            let h: Vec<_> = (0..p)
                .map(|r| {
                    let ep = f.endpoint(r);
                    thread::spawn(move || {
                        let mut b: Vec<f32> =
                            (0..n).map(|i| (r * n + i) as f32).collect();
                        ring_allreduce(&ep, &mut b, 0);
                        b
                    })
                })
                .collect();
            let want: Vec<f32> = (0..n)
                .map(|i| {
                    (0..p).map(|r| (r * n + i) as f32).sum::<f32>() / p as f32
                })
                .collect();
            for t in h {
                let got = t.join().unwrap();
                for (g, w) in got.iter().zip(&want) {
                    assert!((g - w).abs() < 1e-4, "p={p} n={n}: {got:?}");
                }
            }
        }
    }
}
