//! Non-blocking collective engine: per-round state machines driven by a
//! modeled communication-progress thread.
//!
//! Every algorithm in this module's siblings (recursive doubling,
//! binomial tree, ring) is expressed as a [`RoundMachine`]: a state
//! machine that, given the message that just arrived, performs its
//! reduction arithmetic, posts the next round's sends, and names the
//! next receive it needs — MPI's `icollective` shape
//! ([`IAllreduce::post`] / [`progress`](IAllreduce::progress) /
//! [`test`](IAllreduce::test) / [`wait`](IAllreduce::wait)).
//!
//! ## The modeled comm-progress thread (virtual clock)
//!
//! A blocking all-reduce dependency-chains its Θ(log p) rounds on the
//! caller: each round's send is stamped at the caller's clock, which
//! drags forward with every arrival, so the rounds stay exposed even
//! when later compute could hide them.  Real AGD stacks
//! (S-Caffe/PowerAI, and the dedicated comm threads in Jin et al.)
//! instead progress collectives on a separate thread while backprop
//! continues.
//!
//! The engine models that thread without spawning one: a posted
//! collective owns a **comm clock** that starts at the post instant and
//! advances to each internal message's *arrival* instant; the next
//! round's send is stamped at that comm clock — i.e. posted the moment
//! the previous round's message arrives, regardless of where the
//! caller's main clock (busy charging later compute slices) currently
//! sits.  Because every timing quantity derives from arrival stamps,
//! *when* the caller pumps [`progress`](IAllreduce::progress) in wall
//! time is irrelevant: the virtual timeline is identical, so
//! determinism is preserved (see docs/virtual-time.md).
//!
//! ## Ledger accounting
//!
//! Collective-internal messages bypass the transport's per-message
//! hidden/exposed split (they are harvested raw) and settle the ledger
//! when the main thread harvests the collective:
//!
//! * **Overlapped** ([`IAllreduce::post`], the `--comm-thread`
//!   schedule): exposed wait is `max(0, completion − caller_now)` —
//!   only the tail the caller actually blocks on; every other
//!   nanosecond of internal wire time was hidden under the caller's
//!   compute and is credited to `Counters::comm_hidden_ns`, which is
//!   what makes `overlap_frac` meaningful for AGD.
//! * **Blocking** ([`IAllreduce::post_blocking`], used by
//!   [`Algorithm::run`](super::Algorithm::run)): per-message accounting
//!   against the chain's own running clock, reproducing the
//!   dependency-chained schedule's metrics exactly (bit-for-bit) —
//!   the pre-engine behaviour.
//!
//! On a wall-clock fabric the engine falls back to the transport's
//! measured accounting (`test`/`wait` per message); the comm clock is
//! inert there.
//!
//! The engine is link-agnostic: the raw harvest reads stamps the
//! accounting layer has already normalized to `(sent_ns, at_ns)` pairs,
//! so the same state machines drive collectives over the in-process
//! link and over `TcpLink` process meshes (where only the wall path is
//! reachable — TCP fabrics reject the virtual clock).  The TCP parity
//! tests (`tests/tcp_transport.rs`) run comm-thread AGD over a real
//! socket mesh through this engine.

use super::binomial_tree::BinomialTreeMachine;
use super::recursive_doubling::RecursiveDoublingMachine;
use super::ring_allreduce::RingMachine;
use super::Algorithm;
use crate::transport::{Endpoint, RecvReq, Tag};
use std::sync::atomic::Ordering;

/// What a state machine needs next: the `(src, tag)` of the receive
/// that unblocks its next round, or completion.
pub(crate) enum Step {
    Pending(usize, Tag),
    Finished,
}

/// Send side of a machine round: sends are stamped at the collective's
/// comm clock (virtual) or the real now (wall).
pub(crate) struct SendCtx<'a> {
    ep: &'a Endpoint,
    comm_now_ns: u64,
    virt: bool,
}

impl SendCtx<'_> {
    /// Send a copy of `data` drawn from the fabric's buffer pool, so a
    /// machine round's per-message copy recycles a warm buffer instead
    /// of allocating.
    pub(crate) fn send(&self, dst: usize, tag: Tag, data: &[f32]) {
        let data = self.ep.pool().copy_f32(data);
        if self.virt {
            self.ep.isend_at(dst, tag, data, self.comm_now_ns);
        } else {
            self.ep.isend(dst, tag, data);
        }
    }
}

/// One collective algorithm expressed round-by-round.  `start` runs the
/// rounds possible before any message arrives; `deliver` consumes the
/// message named by the previous [`Step::Pending`].  Both perform the
/// *same arithmetic in the same order* as the historical blocking
/// implementations, so results are bit-identical.
pub(crate) trait RoundMachine {
    fn start(&mut self, buf: &mut [f32], ctx: &SendCtx) -> Step;
    fn deliver(&mut self, buf: &mut [f32], data: &[f32], ctx: &SendCtx) -> Step;
}

enum Machine {
    /// p == 1: nothing to exchange.
    Solo,
    Rd(RecursiveDoublingMachine),
    Tree(BinomialTreeMachine),
    Ring(RingMachine),
}

impl Machine {
    fn build(alg: Algorithm, p: usize, me: usize, round: usize) -> Machine {
        if p == 1 {
            return Machine::Solo;
        }
        match alg {
            Algorithm::RecursiveDoubling => {
                Machine::Rd(RecursiveDoublingMachine::new(p, me, round))
            }
            Algorithm::BinomialTree => {
                Machine::Tree(BinomialTreeMachine::new(p, me, round))
            }
            Algorithm::Ring => Machine::Ring(RingMachine::new(p, me, round)),
        }
    }

    fn start(&mut self, buf: &mut [f32], ctx: &SendCtx) -> Step {
        match self {
            Machine::Solo => Step::Finished,
            Machine::Rd(m) => m.start(buf, ctx),
            Machine::Tree(m) => m.start(buf, ctx),
            Machine::Ring(m) => m.start(buf, ctx),
        }
    }

    fn deliver(&mut self, buf: &mut [f32], data: &[f32], ctx: &SendCtx) -> Step {
        match self {
            Machine::Solo => unreachable!("solo machine receives nothing"),
            Machine::Rd(m) => m.deliver(buf, data, ctx),
            Machine::Tree(m) => m.deliver(buf, data, ctx),
            Machine::Ring(m) => m.deliver(buf, data, ctx),
        }
    }
}

/// An in-flight non-blocking all-reduce (MPI_Iallreduce analogue).
pub struct IAllreduce {
    buf: Vec<f32>,
    machine: Machine,
    pending: Option<RecvReq>,
    done: bool,
    /// The modeled comm thread's clock: post instant, then the running
    /// max of internal arrival instants (virtual mode only).
    comm_now_ns: u64,
    /// Total wire time of internal messages (virtual mode only).
    wire_ns: u64,
    /// Overlapped (comm-thread) vs blocking (dependency-chained) ledger.
    overlapped: bool,
    virt: bool,
}

impl IAllreduce {
    /// Post a non-blocking all-reduce with comm-thread (overlapped)
    /// semantics: rounds advance at arrival instants concurrently with
    /// whatever the caller charges next; only the completion tail the
    /// caller blocks on in [`wait`](Self::wait) is exposed.
    pub fn post(ep: &Endpoint, alg: Algorithm, buf: Vec<f32>, round: usize) -> IAllreduce {
        IAllreduce::new(ep, alg, buf, round, true)
    }

    /// Post with the historical dependency-chained accounting: each
    /// internal message is charged against the chain's running clock as
    /// it arrives, exactly as the blocking implementations did.
    pub fn post_blocking(
        ep: &Endpoint,
        alg: Algorithm,
        buf: Vec<f32>,
        round: usize,
    ) -> IAllreduce {
        IAllreduce::new(ep, alg, buf, round, false)
    }

    fn new(
        ep: &Endpoint,
        alg: Algorithm,
        buf: Vec<f32>,
        round: usize,
        overlapped: bool,
    ) -> IAllreduce {
        let virt = ep.fabric().clock().is_virtual();
        let comm_now_ns = ep.fabric().clock().now_ns(ep.rank());
        let mut coll = IAllreduce {
            buf,
            machine: Machine::build(alg, ep.size(), ep.rank(), round),
            pending: None,
            done: false,
            comm_now_ns,
            wire_ns: 0,
            overlapped,
            virt,
        };
        let ctx = SendCtx {
            ep,
            comm_now_ns: coll.comm_now_ns,
            virt,
        };
        let step = coll.machine.start(&mut coll.buf, &ctx);
        coll.apply_step(ep, step);
        coll
    }

    fn apply_step(&mut self, ep: &Endpoint, step: Step) {
        match step {
            Step::Pending(src, tag) => self.pending = Some(ep.irecv(src, tag)),
            Step::Finished => {
                self.pending = None;
                self.done = true;
            }
        }
    }

    /// Feed one delivered internal message through the state machine,
    /// advancing the comm clock and (in blocking mode) the ledger.
    fn deliver(&mut self, ep: &Endpoint, data: Vec<f32>, sent_ns: u64, at_ns: u64) {
        if self.virt {
            let wire = at_ns - sent_ns;
            self.wire_ns += wire;
            if !self.overlapped {
                // dependency-chained schedule: this arrival's wait is
                // exposed relative to the chain's own running clock —
                // identical arithmetic to the transport's blocking
                // wait, so blocking-mode metrics are bit-stable
                let exposed = at_ns.saturating_sub(self.comm_now_ns);
                let c = ep.fabric().counters(ep.rank());
                c.recv_wait_ns.fetch_add(exposed, Ordering::Relaxed);
                c.comm_hidden_ns
                    .fetch_add(wire.saturating_sub(exposed), Ordering::Relaxed);
            }
            self.comm_now_ns = self.comm_now_ns.max(at_ns);
        }
        let ctx = SendCtx {
            ep,
            comm_now_ns: self.comm_now_ns,
            virt: self.virt,
        };
        let step = self.machine.deliver(&mut self.buf, &data, &ctx);
        // the harvested internal payload cycles back to the pool for
        // the next round's SendCtx copy
        ep.pool().put_f32(data);
        self.apply_step(ep, step);
    }

    /// Drive the state machine as far as available messages allow
    /// without blocking; returns true once the collective is complete.
    /// Pumping more or less often never changes the virtual timeline
    /// (it is a pure function of arrival stamps) — only wall-clock
    /// liveness.
    pub fn progress(&mut self, ep: &Endpoint) -> bool {
        while !self.done {
            let Some(req) = self.pending.as_mut() else {
                break;
            };
            if self.virt {
                match req.test_raw() {
                    Some((data, sent_ns, at_ns)) => {
                        self.pending = None;
                        self.deliver(ep, data, sent_ns, at_ns);
                    }
                    None => return false,
                }
            } else if req.test() {
                let data = self.pending.take().unwrap().wait();
                self.deliver(ep, data, 0, 0);
            } else {
                return false;
            }
        }
        self.done
    }

    /// Non-blocking completion poll (MPI_Test).
    pub fn test(&mut self, ep: &Endpoint) -> bool {
        self.progress(ep)
    }

    /// Harvest the reduced vector (MPI_Wait): drives the machine to
    /// completion (blocking only for payloads not yet queued), then
    /// settles the caller's clock and the hidden/exposed wire-time
    /// ledger per the posting mode.
    pub fn wait(mut self, ep: &Endpoint) -> Vec<f32> {
        while !self.done {
            if self.progress(ep) {
                break;
            }
            let req = self.pending.take().expect("incomplete collective with no pending recv");
            if self.virt {
                let (data, sent_ns, at_ns) = req.wait_raw();
                self.deliver(ep, data, sent_ns, at_ns);
            } else {
                let data = req.wait();
                self.deliver(ep, data, 0, 0);
            }
        }
        if self.virt {
            let clock = ep.fabric().clock();
            let rank = ep.rank();
            if self.overlapped {
                // the caller pays only the completion tail; all other
                // internal wire time elapsed under its compute
                let exposed = self.comm_now_ns.saturating_sub(clock.now_ns(rank));
                let c = ep.fabric().counters(rank);
                c.recv_wait_ns.fetch_add(exposed, Ordering::Relaxed);
                c.comm_hidden_ns
                    .fetch_add(self.wire_ns.saturating_sub(exposed), Ordering::Relaxed);
            }
            clock.advance_to_ns(rank, self.comm_now_ns);
        }
        self.buf
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::{CostModel, Fabric};
    use std::thread;

    /// Overlapped collectives on the virtual fabric advance at arrival
    /// instants, not at the caller's clock: with enough compute charged
    /// after the post, the whole Θ(log p) chain hides.
    #[test]
    fn overlapped_chain_hides_under_compute() {
        let p = 4;
        let f = Fabric::new_virtual(p, CostModel::new(1e-3, 0.0, 0.0, 0));
        let handles: Vec<_> = (0..p)
            .map(|r| {
                let ep = f.endpoint(r);
                thread::spawn(move || {
                    let mut h = IAllreduce::post(
                        &ep,
                        Algorithm::RecursiveDoubling,
                        vec![r as f32; 8],
                        0,
                    );
                    // 2 rounds x 1 ms chain < 10 ms compute
                    ep.advance(10e-3);
                    h.progress(&ep);
                    h.wait(&ep)
                })
            })
            .collect();
        for h in handles {
            let got = h.join().unwrap();
            assert_eq!(got, vec![1.5; 8]);
        }
        for r in 0..p {
            use std::sync::atomic::Ordering;
            let c = f.counters(r);
            assert_eq!(
                c.recv_wait_ns.load(Ordering::Relaxed),
                0,
                "rank {r}: chain should be fully hidden"
            );
            assert_eq!(
                c.comm_hidden_ns.load(Ordering::Relaxed),
                2_000_000,
                "rank {r}: 2 rounds x 1 ms of internal wire credited hidden"
            );
            assert_eq!(f.clock().now_ns(r), 10_000_000, "clock not rewound");
        }
        assert_eq!(f.in_flight(), 0);
    }

    /// Without compute after the post, the overlapped chain is fully
    /// exposed at wait() and the caller's clock jumps to completion —
    /// same step timing as the blocking schedule.
    #[test]
    fn overlapped_without_compute_exposes_chain() {
        let p = 4;
        let f = Fabric::new_virtual(p, CostModel::new(1e-3, 0.0, 0.0, 0));
        let handles: Vec<_> = (0..p)
            .map(|r| {
                let ep = f.endpoint(r);
                thread::spawn(move || {
                    IAllreduce::post(&ep, Algorithm::RecursiveDoubling, vec![1.0; 4], 0)
                        .wait(&ep)
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        for r in 0..p {
            use std::sync::atomic::Ordering;
            assert_eq!(f.clock().now_ns(r), 2_000_000, "2 chained 1 ms rounds");
            assert_eq!(
                f.counters(r).recv_wait_ns.load(Ordering::Relaxed),
                2_000_000
            );
        }
    }

    /// Blocking mode (post_blocking + immediate wait) reproduces the
    /// dependency-chained timing: identical clock and ledger to the
    /// overlapped no-compute case, message by message.
    #[test]
    fn blocking_mode_matches_chained_timing() {
        let p = 8;
        let f = Fabric::new_virtual(p, CostModel::new(2e-3, 0.0, 0.0, 0));
        let handles: Vec<_> = (0..p)
            .map(|r| {
                let ep = f.endpoint(r);
                thread::spawn(move || {
                    IAllreduce::post_blocking(
                        &ep,
                        Algorithm::RecursiveDoubling,
                        vec![r as f32; 4],
                        0,
                    )
                    .wait(&ep)
                })
            })
            .collect();
        let want = (0..p).map(|r| r as f32).sum::<f32>() / p as f32;
        for h in handles {
            assert_eq!(h.join().unwrap(), vec![want; 4]);
        }
        for r in 0..p {
            use std::sync::atomic::Ordering;
            // 3 rounds x 2 ms, every round exposed (no compute between)
            assert_eq!(f.clock().now_ns(r), 6_000_000);
            assert_eq!(
                f.counters(r).recv_wait_ns.load(Ordering::Relaxed),
                6_000_000
            );
            assert_eq!(f.counters(r).comm_hidden_ns.load(Ordering::Relaxed), 0);
        }
    }

    /// Multiple overlapped collectives in flight on one rank progress
    /// independently — different rounds, no message crossing.
    #[test]
    fn concurrent_collectives_do_not_cross() {
        let p = 4;
        let f = Fabric::new_virtual(p, CostModel::new(1e-3, 0.0, 0.0, 0));
        let handles: Vec<_> = (0..p)
            .map(|r| {
                let ep = f.endpoint(r);
                thread::spawn(move || {
                    let a = IAllreduce::post(
                        &ep,
                        Algorithm::RecursiveDoubling,
                        vec![r as f32; 8],
                        0,
                    );
                    let b = IAllreduce::post(
                        &ep,
                        Algorithm::Ring,
                        vec![(r * 10) as f32; 8],
                        1,
                    );
                    ep.advance(50e-3);
                    (a.wait(&ep), b.wait(&ep))
                })
            })
            .collect();
        let avg_a = (0..p).map(|r| r as f32).sum::<f32>() / p as f32;
        let avg_b = (0..p).map(|r| (r * 10) as f32).sum::<f32>() / p as f32;
        for h in handles {
            let (a, b) = h.join().unwrap();
            assert!((a[0] - avg_a).abs() < 1e-5, "{a:?}");
            assert!((b[0] - avg_b).abs() < 1e-5, "{b:?}");
        }
        assert_eq!(f.in_flight(), 0);
    }

    /// p == 1 completes instantly in either mode.
    #[test]
    fn solo_is_identity() {
        let f = Fabric::new_virtual(1, CostModel::new(1e-3, 0.0, 0.0, 0));
        let ep = f.endpoint(0);
        let mut h = IAllreduce::post(&ep, Algorithm::Ring, vec![4.0; 3], 0);
        assert!(h.test(&ep));
        assert_eq!(h.wait(&ep), vec![4.0; 3]);
        assert_eq!(f.clock().now_ns(0), 0);
    }

    /// The engine also runs on the wall-clock fabric (measured
    /// accounting), where correctness must be unchanged.
    #[test]
    fn wall_mode_engine_reduces_correctly() {
        let p = 3;
        let f = Fabric::new(p, CostModel::zero());
        let handles: Vec<_> = (0..p)
            .map(|r| {
                let ep = f.endpoint(r);
                thread::spawn(move || {
                    IAllreduce::post(&ep, Algorithm::BinomialTree, vec![(r + 1) as f32; 5], 0)
                        .wait(&ep)
                })
            })
            .collect();
        for h in handles {
            let got = h.join().unwrap();
            assert!((got[0] - 2.0).abs() < 1e-6, "{got:?}");
        }
        assert_eq!(f.in_flight(), 0);
    }
}
