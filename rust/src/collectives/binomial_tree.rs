//! Binomial-tree all-reduce: reduce to rank 0 up a binomial tree
//! (⌈log₂ p⌉ rounds), then broadcast back down (⌈log₂ p⌉ rounds).
//! This is the "binomial/k-nomial tree" the paper's §3.1 complexity
//! argument references.
//!
//! Expressed as a per-round state machine ([`BinomialTreeMachine`]) for
//! the non-blocking engine; reduction order (children added in
//! ascending distance, root scales, broadcast mirrors the tree) is
//! identical to the historical blocking implementation.

use super::engine::{RoundMachine, SendCtx, Step};
use super::{add_into, scale, Algorithm};
use crate::transport::{Endpoint, Tag};

/// Blocking convenience wrapper (post + wait through the engine).
pub fn binomial_tree_allreduce(ep: &Endpoint, buf: &mut [f32], round: usize) {
    Algorithm::BinomialTree.run(ep, buf, round);
}

enum TreePhase {
    /// Awaiting the child at distance `d` in the reduce tree.
    Reduce,
    /// Awaiting the parent's broadcast of the reduced vector.
    BcastWait,
}

pub(crate) struct BinomialTreeMachine {
    p: usize,
    me: usize,
    tag: Tag,
    btag: Tag,
    d: usize,
    recv_d: usize,
    phase: TreePhase,
}

impl BinomialTreeMachine {
    pub(crate) fn new(p: usize, me: usize, round: usize) -> Self {
        BinomialTreeMachine {
            p,
            me,
            tag: Tag::REDUCE.round(round),
            btag: Tag::BCAST.round(round),
            d: 1,
            recv_d: 0,
            phase: TreePhase::Reduce,
        }
    }

    /// Walk the reduce tree from the current distance until we either
    /// need a child's vector, have sent ours to the parent, or (rank 0)
    /// exhaust the tree.
    fn reduce_step(&mut self, buf: &mut [f32], ctx: &SendCtx) -> Step {
        while self.d < self.p {
            if self.me & self.d != 0 {
                ctx.send(self.me - self.d, self.tag, buf);
                return self.enter_bcast(buf, ctx);
            }
            if self.me + self.d < self.p {
                return Step::Pending(self.me + self.d, self.tag);
            }
            self.d <<= 1;
        }
        self.enter_bcast(buf, ctx)
    }

    fn enter_bcast(&mut self, buf: &mut [f32], ctx: &SendCtx) -> Step {
        if self.me == 0 {
            scale(buf, 1.0 / self.p as f32);
        }
        // first power of two >= p: rank 0's whole subtree span
        let mut full = 1usize;
        while full < self.p {
            full <<= 1;
        }
        // distance at which this rank received its value (lowest set
        // bit), or the full tree for rank 0
        self.recv_d = if self.me == 0 {
            full
        } else {
            self.me & self.me.wrapping_neg()
        };
        if self.me != 0 {
            self.phase = TreePhase::BcastWait;
            return Step::Pending(self.me - self.recv_d, self.btag);
        }
        self.forward(buf, ctx);
        Step::Finished
    }

    /// Forward down the broadcast tree: children are me + d' for
    /// d' < recv_d, largest first.
    fn forward(&mut self, buf: &mut [f32], ctx: &SendCtx) {
        let mut child_d = self.recv_d >> 1;
        while child_d >= 1 {
            let child = self.me + child_d;
            if child < self.p {
                ctx.send(child, self.btag, buf);
            }
            child_d >>= 1;
        }
    }
}

impl RoundMachine for BinomialTreeMachine {
    fn start(&mut self, buf: &mut [f32], ctx: &SendCtx) -> Step {
        self.reduce_step(buf, ctx)
    }

    fn deliver(&mut self, buf: &mut [f32], data: &[f32], ctx: &SendCtx) -> Step {
        match self.phase {
            TreePhase::Reduce => {
                add_into(buf, data);
                self.d <<= 1;
                self.reduce_step(buf, ctx)
            }
            TreePhase::BcastWait => {
                buf.copy_from_slice(data);
                self.forward(buf, ctx);
                Step::Finished
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::{CostModel, Fabric};
    use std::thread;

    #[test]
    fn averages_various_p() {
        for p in [2usize, 3, 5, 8, 11] {
            let f = Fabric::new(p, CostModel::zero());
            let h: Vec<_> = (0..p)
                .map(|r| {
                    let ep = f.endpoint(r);
                    thread::spawn(move || {
                        let mut b = vec![r as f32; 16];
                        binomial_tree_allreduce(&ep, &mut b, 0);
                        b
                    })
                })
                .collect();
            let want = (0..p).map(|r| r as f32).sum::<f32>() / p as f32;
            for t in h {
                let got = t.join().unwrap();
                assert!((got[0] - want).abs() < 1e-5, "p={p} {got:?}");
                assert!(got.iter().all(|&v| (v - want).abs() < 1e-5));
            }
        }
    }
}
