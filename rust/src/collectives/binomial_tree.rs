//! Binomial-tree all-reduce: reduce to rank 0 up a binomial tree
//! (⌈log₂ p⌉ rounds), then broadcast back down (⌈log₂ p⌉ rounds).
//! This is the "binomial/k-nomial tree" the paper's §3.1 complexity
//! argument references.

use super::{add_into, scale};
use crate::transport::{Endpoint, Tag};

pub fn binomial_tree_allreduce(ep: &Endpoint, buf: &mut [f32], round: usize) {
    let p = ep.size();
    let me = ep.rank();
    if p == 1 {
        return;
    }
    let tag = Tag::REDUCE.round(round);
    let btag = Tag::BCAST.round(round);

    // reduce phase: at distance d, ranks with (me & d) != 0 send to me-d
    let mut d = 1usize;
    while d < p {
        if me & d != 0 {
            ep.send(me - d, tag, buf.to_vec());
            break; // sender is done reducing
        }
        if me + d < p {
            let theirs = ep.recv(me + d, tag);
            add_into(buf, &theirs);
        }
        d <<= 1;
    }

    if me == 0 {
        scale(buf, 1.0 / p as f32);
    }

    // broadcast phase: mirror of the reduce tree
    let mut d = {
        // first power of two >= p, halved down to my subtree
        let mut d = 1usize;
        while d < p {
            d <<= 1;
        }
        d
    };
    // find the distance at which I received my value (me's lowest set bit),
    // or the full tree for rank 0
    let recv_d = if me == 0 { d } else { me & me.wrapping_neg() };
    if me != 0 {
        let parent = me - recv_d;
        let v = ep.recv(parent, btag);
        buf.copy_from_slice(&v);
    }
    d = recv_d;
    // forward down: children are me + d' for d' < recv_d
    let mut child_d = d >> 1;
    while child_d >= 1 {
        let child = me + child_d;
        if child < p {
            ep.isend(child, btag, buf.to_vec());
        }
        if child_d == 0 {
            break;
        }
        child_d >>= 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::{CostModel, Fabric};
    use std::thread;

    #[test]
    fn averages_various_p() {
        for p in [2usize, 3, 5, 8, 11] {
            let f = Fabric::new(p, CostModel::zero());
            let h: Vec<_> = (0..p)
                .map(|r| {
                    let ep = f.endpoint(r);
                    thread::spawn(move || {
                        let mut b = vec![r as f32; 16];
                        binomial_tree_allreduce(&ep, &mut b, 0);
                        b
                    })
                })
                .collect();
            let want = (0..p).map(|r| r as f32).sum::<f32>() / p as f32;
            for t in h {
                let got = t.join().unwrap();
                assert!((got[0] - want).abs() < 1e-5, "p={p} {got:?}");
                assert!(got.iter().all(|&v| (v - want).abs() < 1e-5));
            }
        }
    }
}
