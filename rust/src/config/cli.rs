//! Shared CLI → [`RunConfig`] construction: one `args → RunConfig`
//! helper used by the `train` and `sweep` subcommands (and any future
//! entry point), so every config field is settable from the command
//! line in exactly one place instead of per-subcommand copies.
//!
//! Layering: `--config file.json` loads a preset, CLI keys override it,
//! `--workload NAME` virtualizes onto a calibrated compute model, and
//! explicit `--compute-ms`/`--fwd-ms` override the workload's numbers.

use super::{Algo, CostModelKind, LrSchedule, RunConfig, Transport};
use crate::codec::Codec;
use crate::collectives::Algorithm;
use crate::sim::Workload;
use crate::util::args::Args;

use anyhow::{bail, Context, Result};

/// Boolean flags (no value token) recognized by the CLI.  Pass this to
/// [`Args::from_env`] so `--layerwise` etc. don't swallow the next
/// token.
pub const FLAGS: &[&str] = &[
    "no-rotation",
    "no-shuffle",
    "native",
    "lr-scaling",
    "virtual-clock",
    "layerwise",
    "comm-thread",
    "sync-mix",
    "no-pool",
    "autotune-period",
    "keep-dir",
    "legacy-ranks",
];

/// Build a [`RunConfig`] from `--config` (optional preset) + CLI
/// overrides.  Covers every `RunConfig` field:
///
/// | field | CLI |
/// |---|---|
/// | `model`, `algo`, `allreduce` | `--model`, `--algo`, `--allreduce` |
/// | `ranks`, `steps`, `lr` | `--ranks`, `--steps`, `--lr` |
/// | `lr_schedule` | `--lr-step-every N --lr-step-gamma G` |
/// | `krizhevsky_lr_scaling` | `--lr-scaling` |
/// | `rotation`, `sample_shuffle` | `--no-rotation`, `--no-shuffle` |
/// | `gossip_period`, `seed` | `--gossip-period`, `--seed` |
/// | `rows_per_rank`, `val_rows`, `eval_every` | same, dashed |
/// | `net_alpha`, `net_beta`, `net_noise` | `--alpha`, `--beta-gbps`, `--noise` |
/// | `use_artifacts`, `artifacts_dir` | `--native`, `--artifacts-dir` |
/// | `ps_servers` | `--ps-servers` |
/// | `resume_from` | `--resume DIR` |
/// | `virtual_clock`, `virt_compute_secs`, `virt_fwd_secs` | `--virtual-clock`, `--compute-ms`, `--fwd-ms` (or `--workload NAME [--device-speed F]`, which implies the noiseless virtual fabric and rejects a nonzero `--noise`) |
/// | `straggler_jitter` | `--jitter` |
/// | `virt_ps_agg_secs` | `--ps-agg-ms` |
/// | `layerwise`, `comm_thread`, `sync_mix` | flags of the same name |
/// | `codec` | `--codec f32\|bf16\|int8\|topk` |
/// | `pool` | `--no-pool` (disable payload buffer recycling) |
/// | `group_size`, `inter_period` | `--group-size`, `--inter-period` (docs/topology.md) |
/// | `cost_model` | `--cost-model flat\|hier` |
/// | `fault_plan` | `--kill-rank R@S[,..]`, `--join-at-step R@S[,..]`, `--slow-rank R@S:F[,..]`, `--drop-frac F`, `--dup-frac F`, `--fault-seed N` |
/// | `sim_threads` | `--sim-threads N` (rank scheduler workers; 0 = cores, docs/perf.md) |
/// | `legacy_ranks` | `--legacy-ranks` (thread-per-rank oracle path) |
pub fn from_args(args: &Args) -> Result<RunConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::load(path).map_err(anyhow::Error::msg)?,
        None => RunConfig::default(),
    };
    if let Some(m) = args.get("model") {
        cfg.model = m.to_string();
    }
    if let Some(a) = args.get("algo") {
        cfg.algo = Algo::parse(a).map_err(anyhow::Error::msg)?;
    }
    if let Some(a) = args.get("allreduce") {
        cfg.allreduce = Algorithm::parse(a).map_err(anyhow::Error::msg)?;
    }
    if let Some(t) = args.get("transport") {
        cfg.transport = Transport::parse(t).map_err(anyhow::Error::msg)?;
    }
    if let Some(c) = args.get("codec") {
        cfg.codec = Codec::parse(c).map_err(anyhow::Error::msg)?;
    }
    cfg.ranks = args.usize_or("ranks", cfg.ranks);
    cfg.steps = args.usize_or("steps", cfg.steps);
    cfg.lr = args.f64_or("lr", cfg.lr);
    cfg.seed = args.usize_or("seed", cfg.seed as usize) as u64;
    cfg.eval_every = args.usize_or("eval-every", cfg.eval_every);
    cfg.rows_per_rank = args.usize_or("rows-per-rank", cfg.rows_per_rank);
    cfg.val_rows = args.usize_or("val-rows", cfg.val_rows);
    cfg.gossip_period = args.usize_or("gossip-period", cfg.gossip_period);
    cfg.ps_servers = args.usize_or("ps-servers", cfg.ps_servers);
    if let Some(every) = args.get("lr-step-every") {
        let every: usize = every.parse().context("--lr-step-every")?;
        let gamma = args.f64_or("lr-step-gamma", 0.1);
        cfg.lr_schedule = LrSchedule::Step { every, gamma };
    }
    cfg.net_alpha = args.f64_or("alpha", cfg.net_alpha);
    if let Some(g) = args.get("beta-gbps") {
        let gbps: f64 = g.parse().context("--beta-gbps")?;
        cfg.net_beta = 1.0 / (gbps * 1e9);
    }
    cfg.net_noise = args.f64_or("noise", cfg.net_noise);
    if args.flag("no-rotation") {
        cfg.rotation = false;
    }
    if args.flag("no-shuffle") {
        cfg.sample_shuffle = false;
    }
    if args.flag("native") {
        cfg.use_artifacts = false;
    }
    if args.flag("lr-scaling") {
        cfg.krizhevsky_lr_scaling = true;
    }
    if args.flag("virtual-clock") {
        cfg.virtual_clock = true;
    }
    if args.flag("layerwise") {
        cfg.layerwise = true;
    }
    if args.flag("comm-thread") {
        cfg.comm_thread = true;
    }
    if args.flag("sync-mix") {
        cfg.sync_mix = true;
    }
    if args.flag("no-pool") {
        cfg.pool = false;
    }
    // rank execution knobs (docs/perf.md, "rank scheduler"): how
    // virtual-clock rank bodies are driven — results are identical
    // either way, so neither is part of the scenario content hash
    cfg.sim_threads = args.usize_or("sim-threads", cfg.sim_threads);
    if args.flag("legacy-ranks") {
        cfg.legacy_ranks = true;
    }
    // a comm thread only overlaps collectives posted mid-backprop; the
    // monolithic schedule has nothing left to hide them under
    if cfg.comm_thread && !cfg.layerwise {
        bail!("--comm-thread requires --layerwise (per-layer pipelined AGD)");
    }
    cfg.straggler_jitter = args.f64_or("jitter", cfg.straggler_jitter);
    // `--workload NAME` virtualizes onto a calibrated compute model
    // (per-step compute, forward share, PS aggregation cost) using the
    // α–β parsed above; explicit --compute-ms / --fwd-ms still override.
    if let Some(name) = args.get("workload") {
        // virtualize() zeroes net_noise by construction (the virtual
        // fabric charges nominal, deterministic wire costs) — refuse a
        // nonzero noise rather than silently dropping it
        if cfg.net_noise != 0.0 {
            bail!(
                "--workload implies the deterministic virtual fabric, \
                 which ignores wire noise — remove --noise (or the \
                 preset's net_noise)"
            );
        }
        let speed = args.f64_or("device-speed", 1.0);
        let w = Workload::by_name(name, speed)
            .ok_or_else(|| anyhow::anyhow!("unknown workload {name:?}"))?;
        cfg.virtualize(&w, cfg.net_alpha, cfg.net_beta);
    }
    cfg.virt_compute_secs =
        args.f64_or("compute-ms", cfg.virt_compute_secs * 1e3) * 1e-3;
    cfg.virt_fwd_secs = args.f64_or("fwd-ms", cfg.virt_fwd_secs * 1e3) * 1e-3;
    cfg.virt_ps_agg_secs =
        args.f64_or("ps-agg-ms", cfg.virt_ps_agg_secs * 1e3) * 1e-3;
    // A virtual run with no compute charge degenerates to pure exposed
    // wait (0% efficiency, meaningless step times) — refuse it loudly.
    if cfg.virtual_clock && cfg.virt_compute_secs <= 0.0 {
        bail!(
            "--virtual-clock needs a per-step compute cost: pass \
             --compute-ms MS (e.g. 6.25 for LeNet3@P100), --workload \
             NAME, or set virt_compute_secs in the config"
        );
    }
    // A forward share exceeding the whole compute budget would silently
    // clamp every backward slice to zero and overcharge the step.
    if cfg.virtual_clock && cfg.virt_fwd_secs > cfg.virt_compute_secs {
        bail!(
            "--fwd-ms ({} ms) must not exceed --compute-ms ({} ms)",
            cfg.virt_fwd_secs * 1e3,
            cfg.virt_compute_secs * 1e3
        );
    }
    // TCP arrival stamps are receiver-side Instants, which cannot carry
    // deterministic virtual time across a process boundary
    if cfg.transport == Transport::Tcp && cfg.virtual_clock {
        bail!(
            "--transport tcp runs on the wall clock only — drop \
             --virtual-clock/--workload (docs/transport.md)"
        );
    }
    // ---- hierarchical fabric (docs/topology.md) ----------------------
    cfg.group_size = args.usize_or("group-size", cfg.group_size);
    cfg.inter_period = args.usize_or("inter-period", cfg.inter_period);
    if let Some(k) = args.get("cost-model") {
        cfg.cost_model = CostModelKind::parse(k).map_err(anyhow::Error::msg)?;
    }
    if cfg.group_size == 0 {
        bail!("--group-size must be at least 1");
    }
    if cfg.inter_period == 0 {
        bail!("--inter-period must be at least 1");
    }
    // (divisibility, algo and transport compatibility are validated with
    // the rest of the run shape in coordinator::trainer::validate)
    if let Some(d) = args.get("artifacts-dir") {
        cfg.artifacts_dir = d.to_string();
    }
    if let Some(d) = args.get("resume") {
        cfg.resume_from = Some(d.to_string());
    }
    // ---- fault plan (docs/fault-tolerance.md) ------------------------
    if let Some(v) = args.get("kill-rank") {
        cfg.fault_plan.kills = parse_rank_steps(v).context("--kill-rank")?;
    }
    if let Some(v) = args.get("join-at-step") {
        cfg.fault_plan.joins = parse_rank_steps(v).context("--join-at-step")?;
    }
    if let Some(v) = args.get("slow-rank") {
        cfg.fault_plan.slows = parse_slows(v).context("--slow-rank")?;
    }
    cfg.fault_plan.drop_frac = args.f64_or("drop-frac", cfg.fault_plan.drop_frac);
    cfg.fault_plan.dup_frac = args.f64_or("dup-frac", cfg.fault_plan.dup_frac);
    cfg.fault_plan.seed =
        args.usize_or("fault-seed", cfg.fault_plan.seed as usize) as u64;
    Ok(cfg)
}

/// Parse `R@S[,R@S...]` lists (`--kill-rank 3@10`, `--join-at-step 7@14`).
fn parse_rank_steps(v: &str) -> Result<Vec<(usize, usize)>> {
    v.split(',')
        .map(|e| {
            let (r, s) = e
                .split_once('@')
                .ok_or_else(|| anyhow::anyhow!("expected R@S, got {e:?}"))?;
            Ok((
                r.trim().parse().with_context(|| format!("rank in {e:?}"))?,
                s.trim().parse().with_context(|| format!("step in {e:?}"))?,
            ))
        })
        .collect()
}

/// Parse `R@S:F[,R@S:F...]` lists (`--slow-rank 2@5:3.0` = rank 2's
/// frames take 3× wire time from message round 5 on).
fn parse_slows(v: &str) -> Result<Vec<(usize, usize, f64)>> {
    v.split(',')
        .map(|e| {
            let (rs, f) = e
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("expected R@S:F, got {e:?}"))?;
            let (r, s) = rs
                .split_once('@')
                .ok_or_else(|| anyhow::anyhow!("expected R@S:F, got {e:?}"))?;
            Ok((
                r.trim().parse().with_context(|| format!("rank in {e:?}"))?,
                s.trim().parse().with_context(|| format!("step in {e:?}"))?,
                f.trim().parse().with_context(|| format!("factor in {e:?}"))?,
            ))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string()), FLAGS)
            .unwrap()
    }

    #[test]
    fn every_field_settable_from_cli() {
        let a = parse(
            "train --model mlp-small --algo periodic-agd --allreduce ring \
             --ranks 16 --steps 9 --lr 0.2 --lr-step-every 3 \
             --lr-step-gamma 0.5 --lr-scaling --no-rotation --no-shuffle \
             --gossip-period 4 --seed 99 --rows-per-rank 64 --val-rows 32 \
             --eval-every 2 --alpha 0.0002 --beta-gbps 0.5 --noise 0 \
             --native --artifacts-dir elsewhere --ps-servers 3 \
             --virtual-clock --compute-ms 6.25 --fwd-ms 2 --jitter 0.25 \
             --ps-agg-ms 1.5 --layerwise --comm-thread --sync-mix \
             --codec bf16",
        );
        let c = from_args(&a).unwrap();
        assert_eq!(c.model, "mlp-small");
        assert_eq!(c.algo, Algo::PeriodicAgd);
        assert_eq!(c.allreduce, Algorithm::Ring);
        assert_eq!((c.ranks, c.steps), (16, 9));
        assert!((c.lr - 0.2).abs() < 1e-12);
        assert_eq!(c.lr_schedule, LrSchedule::Step { every: 3, gamma: 0.5 });
        assert!(c.krizhevsky_lr_scaling);
        assert!(!c.rotation && !c.sample_shuffle);
        assert_eq!(c.gossip_period, 4);
        assert_eq!(c.seed, 99);
        assert_eq!((c.rows_per_rank, c.val_rows, c.eval_every), (64, 32, 2));
        assert!((c.net_alpha - 2e-4).abs() < 1e-12);
        assert!((c.net_beta - 1.0 / 0.5e9).abs() < 1e-22);
        assert!(!c.use_artifacts);
        assert_eq!(c.artifacts_dir, "elsewhere");
        assert_eq!(c.ps_servers, 3);
        assert!(c.virtual_clock && c.layerwise && c.comm_thread && c.sync_mix);
        assert!((c.virt_compute_secs - 6.25e-3).abs() < 1e-12);
        assert!((c.virt_fwd_secs - 2e-3).abs() < 1e-12);
        assert!((c.straggler_jitter - 0.25).abs() < 1e-12);
        assert!((c.virt_ps_agg_secs - 1.5e-3).abs() < 1e-12);
        assert_eq!(c.codec, Codec::Bf16);
    }

    #[test]
    fn scheduler_knobs_parse_and_default_off() {
        let c = from_args(&parse("train")).unwrap();
        assert_eq!(c.sim_threads, 0, "0 = one worker per core");
        assert!(!c.legacy_ranks);
        let c = from_args(&parse("train --sim-threads 4 --legacy-ranks")).unwrap();
        assert_eq!(c.sim_threads, 4);
        assert!(c.legacy_ranks);
    }

    #[test]
    fn codec_flag_parses_and_defaults_to_f32() {
        assert_eq!(from_args(&parse("train")).unwrap().codec, Codec::F32);
        for (s, codec) in [
            ("f32", Codec::F32),
            ("bf16", Codec::Bf16),
            ("int8", Codec::Int8),
            ("topk", Codec::TopK),
        ] {
            let c = from_args(&parse(&format!("train --codec {s}"))).unwrap();
            assert_eq!(c.codec, codec);
        }
        assert!(from_args(&parse("train --codec fp8")).is_err());
    }

    #[test]
    fn workload_virtualizes_the_config() {
        let a = parse(
            "sweep --workload lenet3 --device-speed 4 --alpha 0.0002 \
             --beta-gbps 0.5 --native --layerwise",
        );
        let c = from_args(&a).unwrap();
        let w = Workload::lenet3(4.0);
        assert!(c.virtual_clock, "--workload implies the virtual clock");
        assert!((c.virt_compute_secs - w.t_compute()).abs() < 1e-12);
        assert!((c.virt_fwd_secs - w.t_fwd).abs() < 1e-12);
        assert!(c.virt_ps_agg_secs > 0.0);
        assert_eq!(c.net_noise, 0.0, "virtual fabric charges nominal costs");
        // an explicit nonzero --noise contradicts --workload: error,
        // don't silently drop it
        assert!(
            from_args(&parse("train --workload lenet3 --noise 0.1")).is_err()
        );
    }

    #[test]
    fn fault_flags_build_the_plan() {
        let c = from_args(&parse(
            "train --kill-rank 3@10,5@12 --join-at-step 7@14 \
             --slow-rank 2@5:3.0 --drop-frac 0.05 --dup-frac 0.01 \
             --fault-seed 77",
        ))
        .unwrap();
        assert_eq!(c.fault_plan.kills, vec![(3, 10), (5, 12)]);
        assert_eq!(c.fault_plan.joins, vec![(7, 14)]);
        assert_eq!(c.fault_plan.slows, vec![(2, 5, 3.0)]);
        assert!((c.fault_plan.drop_frac - 0.05).abs() < 1e-12);
        assert!((c.fault_plan.dup_frac - 0.01).abs() < 1e-12);
        assert_eq!(c.fault_plan.seed, 77);
        // no fault flags → the default plan (omitted from config JSON)
        assert!(from_args(&parse("train")).unwrap().fault_plan.is_default());
        // malformed entries fail loudly
        assert!(from_args(&parse("train --kill-rank 3-10")).is_err());
        assert!(from_args(&parse("train --slow-rank 2@5")).is_err());
    }

    #[test]
    fn hier_flags_parse_and_default_flat() {
        let d = from_args(&parse("train")).unwrap();
        assert_eq!((d.group_size, d.inter_period), (1, 1));
        assert_eq!(d.cost_model, CostModelKind::Flat);
        let c = from_args(&parse(
            "train --ranks 16 --group-size 4 --inter-period 2 --cost-model hier",
        ))
        .unwrap();
        assert_eq!((c.group_size, c.inter_period), (4, 2));
        assert_eq!(c.cost_model, CostModelKind::Hier);
        assert!(from_args(&parse("train --group-size 0")).is_err());
        assert!(from_args(&parse("train --inter-period 0")).is_err());
        assert!(from_args(&parse("train --cost-model torus")).is_err());
    }

    #[test]
    fn no_pool_flag_disables_buffer_recycling() {
        assert!(from_args(&parse("train")).unwrap().pool);
        assert!(!from_args(&parse("train --no-pool")).unwrap().pool);
    }

    #[test]
    fn comm_thread_requires_layerwise() {
        assert!(from_args(&parse("train --comm-thread")).is_err());
        assert!(from_args(&parse("train --comm-thread --layerwise")).is_ok());
    }

    #[test]
    fn transport_flag_parses_and_rejects_virtual_tcp() {
        let c = from_args(&parse("train --transport tcp")).unwrap();
        assert_eq!(c.transport, Transport::Tcp);
        assert_eq!(
            from_args(&parse("train")).unwrap().transport,
            Transport::Inproc
        );
        assert!(from_args(&parse("train --transport carrier-pigeon")).is_err());
        // the TCP link is wall-clock only
        assert!(from_args(&parse(
            "train --transport tcp --virtual-clock --compute-ms 6.25"
        ))
        .is_err());
        assert!(
            from_args(&parse("train --transport tcp --workload lenet3")).is_err()
        );
    }

    #[test]
    fn virtual_clock_requires_compute_budget() {
        assert!(from_args(&parse("train --virtual-clock")).is_err());
        assert!(
            from_args(&parse("train --virtual-clock --compute-ms 6.25")).is_ok()
        );
        // fwd share must fit inside the compute budget
        assert!(from_args(&parse(
            "train --virtual-clock --compute-ms 2 --fwd-ms 3"
        ))
        .is_err());
    }
}
