//! Run configuration: a single struct covering every experiment knob,
//! JSON presets on disk, CLI overrides on top.
//!
//! Presets mirror the paper's setups (`configs/*.json`): e.g.
//! `mnist_gossip_32.json` = LeNet3-analog, 32 ranks, dissemination +
//! rotation + ring shuffle, IB-EDR cost model.

use crate::codec::Codec;
use crate::collectives::Algorithm;
use crate::membership::FaultPlan;
use crate::transport::{CostModel, GroupMap, HierCostModel};
use crate::util::json::{self, num, obj, Json};

pub mod cli;

/// Which training algorithm the coordinator runs (paper Table 6 + §7.5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    /// GossipGraD: dissemination gossip + rotation + ring sample shuffle.
    Gossip,
    /// GossipGraD on the hypercube virtual topology (§4.4.1 variant).
    GossipHypercube,
    /// Random gossip (Jin/Blot baseline).
    GossipRandom,
    /// Synchronous all-reduce SGD.
    SgdSync,
    /// Asynchronous layer-wise all-reduce (AGD — the paper's baseline).
    Agd,
    /// AGD every ⌈log₂ p⌉ steps (Fig 17).
    PeriodicAgd,
    /// Parameter-server baseline.
    ParamServer,
}

impl Algo {
    pub fn parse(s: &str) -> Result<Algo, String> {
        Ok(match s {
            "gossip" | "gossipgrad" => Algo::Gossip,
            "gossip-hypercube" => Algo::GossipHypercube,
            "gossip-random" => Algo::GossipRandom,
            "sgd" | "sgd-sync" => Algo::SgdSync,
            "agd" => Algo::Agd,
            "periodic-agd" => Algo::PeriodicAgd,
            "ps" | "param-server" => Algo::ParamServer,
            other => return Err(format!("unknown algo {other:?}")),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Algo::Gossip => "gossipgrad",
            Algo::GossipHypercube => "gossip-hypercube",
            Algo::GossipRandom => "gossip-random",
            Algo::SgdSync => "sgd-sync",
            Algo::Agd => "agd",
            Algo::PeriodicAgd => "periodic-agd",
            Algo::ParamServer => "param-server",
        }
    }
}

/// Which wire the fabric runs over (the transport's link layer; see
/// docs/transport.md).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Transport {
    /// Threads-as-ranks over in-process mailboxes (the default; wall or
    /// virtual clock).
    #[default]
    Inproc,
    /// One OS process per rank over TCP sockets (wall clock only; run
    /// via the `rank`/`launch` subcommands or
    /// `coordinator::trainer::run_tcp_loopback`).
    Tcp,
}

impl Transport {
    pub fn parse(s: &str) -> Result<Transport, String> {
        Ok(match s {
            "inproc" | "in-proc" | "threads" => Transport::Inproc,
            "tcp" => Transport::Tcp,
            other => return Err(format!("unknown transport {other:?}")),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Transport::Inproc => "inproc",
            Transport::Tcp => "tcp",
        }
    }
}

/// Which per-message cost model the virtual/wall fabric charges
/// (docs/topology.md).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum CostModelKind {
    /// One α–β pair for every rank pair (the historical model).
    #[default]
    Flat,
    /// Two-tier: NVLink-class costs inside a host group of
    /// `group_size` consecutive ranks, the configured α–β across
    /// groups.  In-process fabric only.
    Hier,
}

impl CostModelKind {
    pub fn parse(s: &str) -> Result<CostModelKind, String> {
        Ok(match s {
            "flat" => CostModelKind::Flat,
            "hier" | "hierarchical" => CostModelKind::Hier,
            other => return Err(format!("unknown cost model {other:?}")),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            CostModelKind::Flat => "flat",
            CostModelKind::Hier => "hier",
        }
    }
}

/// Learning-rate schedule (§7.3.2: ResNet50 step regimen ×0.1/30 epochs).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LrSchedule {
    Const,
    /// Multiply by `gamma` every `every` steps.
    Step { every: usize, gamma: f64 },
}

impl LrSchedule {
    pub fn lr_at(self, base: f64, step: usize) -> f64 {
        match self {
            LrSchedule::Const => base,
            LrSchedule::Step { every, gamma } => {
                base * gamma.powi((step / every.max(1)) as i32)
            }
        }
    }
}

/// Full run configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct RunConfig {
    pub algo: Algo,
    pub model: String,
    pub ranks: usize,
    pub steps: usize,
    pub lr: f64,
    pub lr_schedule: LrSchedule,
    /// Paper §7.1: AGD/SGD weak scaling multiplies lr by sqrt(p);
    /// GossipGraD keeps the single-device lr.
    pub krizhevsky_lr_scaling: bool,
    pub allreduce: Algorithm,
    pub rotation: bool,
    pub sample_shuffle: bool,
    /// Gossip every `gossip_period` steps (1 = every batch).
    pub gossip_period: usize,
    pub seed: u64,
    /// Dataset rows per rank.
    pub rows_per_rank: usize,
    /// Evaluate validation accuracy every N steps (0 = never).
    pub eval_every: usize,
    pub val_rows: usize,
    /// α seconds; β as 1/(bytes per second); noise fraction.
    pub net_alpha: f64,
    pub net_beta: f64,
    pub net_noise: f64,
    /// Use the PJRT artifacts (true) or the native backend (false).
    pub use_artifacts: bool,
    pub artifacts_dir: String,
    /// Parameter-server count (ParamServer algo only).
    pub ps_servers: usize,
    /// Optional checkpoint directory to resume parameters from.
    pub resume_from: Option<String>,
    /// Run the fabric on the deterministic virtual clock (discrete-event
    /// simulated time) instead of the wall clock.  Timing metrics become
    /// bit-reproducible and independent of host speed; see
    /// `docs/virtual-time.md`.
    pub virtual_clock: bool,
    /// Modeled compute seconds charged per step per rank in virtual-clock
    /// mode (typically a calibrated
    /// [`Workload::t_compute`](crate::sim::Workload::t_compute)).
    /// Ignored in wall mode, where compute takes real time.
    pub virt_compute_secs: f64,
    /// Run the layer-wise asynchronous pipeline (paper §5): the per-step
    /// compute is charged in per-layer backprop slices (output layer
    /// first) and each layer's exchange is posted the instant its slice
    /// completes, instead of charging the whole backward pass and then
    /// exchanging the whole model.  On backends with an elementwise
    /// update kernel (the native backend; see
    /// [`ModelBackend::apply_update_slice`](crate::runtime::ModelBackend::apply_update_slice))
    /// this is numerically bit-identical to the monolithic schedule —
    /// same elementwise ops in the same order — so only the timing, and
    /// therefore the measurable comm/compute overlap, changes.  A PJRT
    /// backend's slice updates go through the native momentum-SGD kernel
    /// rather than its compiled full-buffer executable, so there the two
    /// schedules may differ in final bits (not in math).
    pub layerwise: bool,
    /// Forward-pass seconds within `virt_compute_secs` (charged before
    /// the first backward slice in layer-wise mode; set by
    /// [`virtualize`](Self::virtualize) from the workload's `t_fwd`).
    pub virt_fwd_secs: f64,
    /// Deterministic per-(rank, step) straggler jitter amplitude for the
    /// virtual fabric: each rank's compute charge is multiplied by
    /// `1 + jitter · Exp(1)` where the exponential draw is a pure hash
    /// of (seed, rank, step) — see [`crate::sim::jitter_factor`].  0
    /// disables jitter.  This reproduces the `sim/straggler.rs` noise
    /// ablation on the *measured* fabric.
    pub straggler_jitter: f64,
    /// Server-side aggregation compute charged on the PS rank per worker
    /// per step in virtual-clock mode (one reduction pass over the
    /// model).  Combined with the serialized broadcast this reproduces
    /// the Fig 2(a) parameter-server bottleneck at scale.
    pub virt_ps_agg_secs: f64,
    /// Model a dedicated communication-progress thread for AGD's
    /// collectives (the S-Caffe/PowerAI/Jin-et-al. design): each
    /// layer's all-reduce is *posted* non-blocking at its grad-ready
    /// instant and its rounds advance at message-arrival instants
    /// concurrently with later backprop slices, instead of being
    /// dependency-chained on the caller; results are harvested at the
    /// update point.  Only meaningful with `layerwise` on the AGD path
    /// (see docs/virtual-time.md).  Numerics are identical to the
    /// blocking schedule; only timing/overlap change.
    pub comm_thread: bool,
    /// Gossip mixes synchronously: block for the *current* step's
    /// partner model instead of draining the previous exchange (the
    /// convergence-property schedule — exposed comm is paid in full).
    pub sync_mix: bool,
    /// Which wire the fabric runs over: in-process mailboxes (threads
    /// as ranks) or TCP sockets (one process per rank, wall clock
    /// only).  Recorded in experiment artifacts so sweeps key on it.
    pub transport: Transport,
    /// Wire codec for model/gradient payloads (`--codec`,
    /// docs/wire-codecs.md): `f32` (bit-parity default), `bf16`,
    /// `int8`, or `topk` (error-feedback sparsification).  Compressed
    /// bytes are what the fabric charges, so this axis moves both
    /// measured and closed-form efficiency.
    pub codec: Codec,
    /// Recycle payload buffers through the fabric's [`crate::pool`]
    /// (`--no-pool` disables).  Steady-state training then performs
    /// zero per-message payload allocations; numerics are bit-identical
    /// either way (the pool only changes where buffers come from, never
    /// their contents — see docs/perf.md and `tests/pooling.rs`).
    pub pool: bool,
    /// Host-group width: `group_size` consecutive ranks model one node
    /// (`--group-size`; docs/topology.md).  Must divide `ranks`.  Drives
    /// three things at once: the two-level gossip schedule (dense
    /// intra-group dissemination, sparse inter-group partners), the
    /// hierarchical cost model's tier split, and — under the TCP
    /// transport — the hybrid link's mailbox/socket split.  1 = flat
    /// (every rank its own group; bit-identical to the historical
    /// routing, property-tested).
    pub group_size: usize,
    /// Gossip steps between inter-group exchanges in the two-level
    /// schedule (`--inter-period`).  Dense intra-group mixing runs every
    /// step; every `inter_period`-th step sends across groups instead.
    /// Ignored when `group_size` is 1 (or equals `ranks`): the schedule
    /// is flat.
    pub inter_period: usize,
    /// Which per-message cost model the fabric charges
    /// (`--cost-model flat|hier`).  `hier` splits costs by group
    /// locality: NVLink-class inside a group, the configured
    /// `net_alpha`/`net_beta` across groups.
    pub cost_model: CostModelKind,
    /// Seeded fault scenario: planned kills/joins/slowdowns and
    /// frame-level drop/dup fractions (`--kill-rank`, `--join-at-step`,
    /// `--drop-frac`, …; docs/fault-tolerance.md).  The plan rides in
    /// the config so every rank derives identical membership views with
    /// no consensus traffic.  Default = no faults, omitted from the
    /// JSON so historical content hashes are unchanged.
    pub fault_plan: FaultPlan,
    /// Worker threads for the cooperative rank scheduler
    /// (`--sim-threads`; 0 = one per available core).  Execution-only:
    /// results are bit-identical at any setting, so this field is
    /// *excluded* from [`to_json`](Self::to_json) and
    /// [`content_hash`](Self::content_hash) — sweep cache entries and
    /// artifacts are shared across thread counts (docs/perf.md).
    pub sim_threads: usize,
    /// Run virtual-clock ranks on the legacy one-OS-thread-per-rank
    /// launcher instead of the cooperative scheduler
    /// (`--legacy-ranks`).  Kept as the differential-testing oracle
    /// (tests/scheduler.rs pins bit parity).  Execution-only: excluded
    /// from the JSON and the content hash like `sim_threads`.
    pub legacy_ranks: bool,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            algo: Algo::Gossip,
            model: "mlp".into(),
            ranks: 8,
            steps: 100,
            lr: 0.05,
            lr_schedule: LrSchedule::Const,
            krizhevsky_lr_scaling: false,
            allreduce: Algorithm::RecursiveDoubling,
            rotation: true,
            sample_shuffle: true,
            gossip_period: 1,
            seed: 42,
            rows_per_rank: 512,
            eval_every: 0,
            val_rows: 512,
            net_alpha: 0.0,
            net_beta: 0.0,
            net_noise: 0.0,
            use_artifacts: true,
            artifacts_dir: "artifacts".into(),
            ps_servers: 1,
            resume_from: None,
            virtual_clock: false,
            virt_compute_secs: 0.0,
            layerwise: false,
            virt_fwd_secs: 0.0,
            straggler_jitter: 0.0,
            virt_ps_agg_secs: 0.0,
            comm_thread: false,
            sync_mix: false,
            transport: Transport::Inproc,
            codec: Codec::F32,
            pool: true,
            group_size: 1,
            inter_period: 1,
            cost_model: CostModelKind::Flat,
            fault_plan: FaultPlan::default(),
            sim_threads: 0,
            legacy_ranks: false,
        }
    }
}

impl RunConfig {
    pub fn cost_model(&self) -> CostModel {
        CostModel::new(self.net_alpha, self.net_beta, self.net_noise, self.seed)
    }

    /// The hierarchical cost model this run charges, or `None` under
    /// the flat (historical) model.  The configured α–β pair becomes
    /// the *inter-group* tier; the intra-group tier is NVLink-class
    /// ([`CostModel::nvlink`]).  With `group_size = 1` every pair is
    /// inter-group, so the charges match the flat model exactly.
    pub fn hier_cost_model(&self) -> Option<HierCostModel> {
        match self.cost_model {
            CostModelKind::Flat => None,
            CostModelKind::Hier => Some(HierCostModel::with_inter(
                self.cost_model(),
                GroupMap::new(self.ranks, self.group_size),
            )),
        }
    }

    /// Effective base learning rate for this algorithm at this scale
    /// (paper §7.1: ×√p for AGD/SGD weak scaling; unchanged for gossip).
    pub fn effective_lr(&self) -> f64 {
        let scaled = matches!(
            self.algo,
            Algo::SgdSync | Algo::Agd | Algo::PeriodicAgd | Algo::ParamServer
        );
        if self.krizhevsky_lr_scaling && scaled {
            self.lr * (self.ranks as f64).sqrt()
        } else {
            self.lr
        }
    }

    /// Switch this run onto the virtual clock, charging the calibrated
    /// workload's per-step compute cost and the given α–β wire costs.
    /// Noise is zeroed: the virtual fabric charges nominal
    /// (deterministic) message costs by construction.  Also records the
    /// workload's forward-pass share (for the layer-wise pipeline's
    /// backprop-slice schedule) and a parameter-server aggregation cost
    /// (one ~50 GB/s host-memory reduction pass over the model per
    /// worker — PS frameworks aggregate on the host, Fig 2(a)).
    pub fn virtualize(&mut self, w: &crate::sim::Workload, alpha: f64, beta: f64) {
        self.virtual_clock = true;
        self.virt_compute_secs = w.t_compute();
        self.virt_fwd_secs = w.t_fwd;
        self.virt_ps_agg_secs = w.model_bytes() as f64 / 50.0e9;
        self.net_alpha = alpha;
        self.net_beta = beta;
        self.net_noise = 0.0;
    }

    /// Serialize every field under the same keys [`from_json`]
    /// (Self::from_json) reads, so a config round-trips losslessly
    /// through `util::json`.  Keys are emitted from a `BTreeMap`
    /// (sorted) and `resume_from = None` / `LrSchedule::Const` are
    /// omitted, so the serialization is *canonical*: equal configs
    /// produce byte-equal JSON — the property
    /// [`content_hash`](Self::content_hash) relies on.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("algo", json::s(self.algo.name())),
            ("model", json::s(&self.model)),
            ("ranks", num(self.ranks as f64)),
            ("steps", num(self.steps as f64)),
            ("lr", num(self.lr)),
            ("gossip_period", num(self.gossip_period as f64)),
            // as a string: a u64 seed above 2^53 would round through
            // the JSON f64 number type, and two configs differing only
            // in such seeds would collide on content_hash
            ("seed", json::s(&self.seed.to_string())),
            ("rows_per_rank", num(self.rows_per_rank as f64)),
            ("eval_every", num(self.eval_every as f64)),
            ("val_rows", num(self.val_rows as f64)),
            ("net_alpha", num(self.net_alpha)),
            ("net_beta", num(self.net_beta)),
            ("net_noise", num(self.net_noise)),
            ("ps_servers", num(self.ps_servers as f64)),
            ("virt_compute_secs", num(self.virt_compute_secs)),
            ("virt_fwd_secs", num(self.virt_fwd_secs)),
            ("straggler_jitter", num(self.straggler_jitter)),
            ("virt_ps_agg_secs", num(self.virt_ps_agg_secs)),
            ("virtual_clock", Json::Bool(self.virtual_clock)),
            ("layerwise", Json::Bool(self.layerwise)),
            ("comm_thread", Json::Bool(self.comm_thread)),
            ("sync_mix", Json::Bool(self.sync_mix)),
            ("rotation", Json::Bool(self.rotation)),
            ("sample_shuffle", Json::Bool(self.sample_shuffle)),
            (
                "krizhevsky_lr_scaling",
                Json::Bool(self.krizhevsky_lr_scaling),
            ),
            ("use_artifacts", Json::Bool(self.use_artifacts)),
            ("artifacts_dir", json::s(&self.artifacts_dir)),
            ("allreduce", json::s(self.allreduce.name())),
            ("transport", json::s(self.transport.name())),
            ("codec", json::s(self.codec.name())),
            ("pool", Json::Bool(self.pool)),
        ];
        if let Some(dir) = &self.resume_from {
            pairs.push(("resume_from", json::s(dir)));
        }
        // hierarchical-fabric knobs: omitted at their flat defaults so
        // every pre-existing content hash is unchanged
        if self.group_size != 1 {
            pairs.push(("group_size", num(self.group_size as f64)));
        }
        if self.inter_period != 1 {
            pairs.push(("inter_period", num(self.inter_period as f64)));
        }
        if self.cost_model != CostModelKind::Flat {
            pairs.push(("cost_model", json::s(self.cost_model.name())));
        }
        if let LrSchedule::Step { every, gamma } = self.lr_schedule {
            pairs.push(("lr_step_every", num(every as f64)));
            pairs.push(("lr_step_gamma", num(gamma)));
        }
        if !self.fault_plan.is_default() {
            pairs.push(("fault_plan", self.fault_plan.to_json()));
        }
        obj(pairs)
    }

    /// Stable content hash of this config (16 hex chars): FNV-1a over
    /// the canonical JSON serialization.  Equal configs hash equal;
    /// any field change reshapes the hash.  The experiment engine
    /// (`crate::exp`) uses it as the scenario key for result caching
    /// and artifact naming.
    pub fn content_hash(&self) -> String {
        format!("{:016x}", crate::util::fnv1a64(self.to_json().to_string().as_bytes()))
    }

    /// Load a JSON preset, then apply this config's fields as defaults
    /// for anything missing.
    pub fn from_json(j: &Json) -> Result<RunConfig, String> {
        let mut c = RunConfig::default();
        if let Some(v) = j.get("algo").and_then(Json::as_str) {
            c.algo = Algo::parse(v)?;
        }
        if let Some(v) = j.get("model").and_then(Json::as_str) {
            c.model = v.to_string();
        }
        macro_rules! num_field {
            ($key:literal, $field:ident, $ty:ty) => {
                if let Some(v) = j.get($key).and_then(Json::as_f64) {
                    c.$field = v as $ty;
                }
            };
        }
        num_field!("ranks", ranks, usize);
        num_field!("steps", steps, usize);
        num_field!("lr", lr, f64);
        num_field!("gossip_period", gossip_period, usize);
        // seed: string (lossless, what to_json emits) or number (hand
        // written presets)
        match j.get("seed") {
            Some(Json::Str(s)) => {
                c.seed = s.parse().map_err(|e| format!("seed: {e}"))?;
            }
            Some(v) => {
                if let Some(n) = v.as_f64() {
                    c.seed = n as u64;
                }
            }
            None => {}
        }
        num_field!("rows_per_rank", rows_per_rank, usize);
        num_field!("eval_every", eval_every, usize);
        num_field!("val_rows", val_rows, usize);
        num_field!("net_alpha", net_alpha, f64);
        num_field!("net_beta", net_beta, f64);
        num_field!("net_noise", net_noise, f64);
        num_field!("ps_servers", ps_servers, usize);
        num_field!("virt_compute_secs", virt_compute_secs, f64);
        num_field!("virt_fwd_secs", virt_fwd_secs, f64);
        num_field!("straggler_jitter", straggler_jitter, f64);
        num_field!("virt_ps_agg_secs", virt_ps_agg_secs, f64);
        if let Some(v) = j.get("virtual_clock").and_then(Json::as_bool) {
            c.virtual_clock = v;
        }
        if let Some(v) = j.get("layerwise").and_then(Json::as_bool) {
            c.layerwise = v;
        }
        if let Some(v) = j.get("comm_thread").and_then(Json::as_bool) {
            c.comm_thread = v;
        }
        if let Some(v) = j.get("sync_mix").and_then(Json::as_bool) {
            c.sync_mix = v;
        }
        if let Some(v) = j.get("rotation").and_then(Json::as_bool) {
            c.rotation = v;
        }
        if let Some(v) = j.get("sample_shuffle").and_then(Json::as_bool) {
            c.sample_shuffle = v;
        }
        if let Some(v) = j.get("krizhevsky_lr_scaling").and_then(Json::as_bool) {
            c.krizhevsky_lr_scaling = v;
        }
        if let Some(v) = j.get("use_artifacts").and_then(Json::as_bool) {
            c.use_artifacts = v;
        }
        if let Some(v) = j.get("artifacts_dir").and_then(Json::as_str) {
            c.artifacts_dir = v.to_string();
        }
        if let Some(v) = j.get("resume_from").and_then(Json::as_str) {
            c.resume_from = Some(v.to_string());
        }
        if let Some(v) = j.get("allreduce").and_then(Json::as_str) {
            c.allreduce = Algorithm::parse(v)?;
        }
        if let Some(v) = j.get("transport").and_then(Json::as_str) {
            c.transport = Transport::parse(v)?;
        }
        if let Some(v) = j.get("codec").and_then(Json::as_str) {
            c.codec = Codec::parse(v)?;
        }
        if let Some(v) = j.get("pool").and_then(Json::as_bool) {
            c.pool = v;
        }
        num_field!("group_size", group_size, usize);
        num_field!("inter_period", inter_period, usize);
        if let Some(v) = j.get("cost_model").and_then(Json::as_str) {
            c.cost_model = CostModelKind::parse(v)?;
        }
        if let Some(v) = j.get("fault_plan") {
            c.fault_plan = FaultPlan::from_json(v)?;
        }
        if let Some(sched) = j.get("lr_step_every").and_then(Json::as_usize) {
            let gamma = j
                .get("lr_step_gamma")
                .and_then(Json::as_f64)
                .unwrap_or(0.1);
            c.lr_schedule = LrSchedule::Step {
                every: sched,
                gamma,
            };
        }
        Ok(c)
    }

    pub fn load(path: &str) -> Result<RunConfig, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("{path}: {e}"))?;
        RunConfig::from_json(&Json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_preset() {
        let j = Json::parse(
            r#"{"algo":"agd","model":"cnn","ranks":16,"steps":50,
                "lr":0.1,"krizhevsky_lr_scaling":true,
                "allreduce":"ring","rotation":false,
                "lr_step_every":30,"lr_step_gamma":0.1}"#,
        )
        .unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert_eq!(c.algo, Algo::Agd);
        assert_eq!(c.ranks, 16);
        assert_eq!(c.allreduce, Algorithm::Ring);
        assert!(!c.rotation);
        // √16 = 4× lr scaling for AGD
        assert!((c.effective_lr() - 0.4).abs() < 1e-12);
        assert_eq!(
            c.lr_schedule,
            LrSchedule::Step {
                every: 30,
                gamma: 0.1
            }
        );
    }

    #[test]
    fn gossip_keeps_single_device_lr() {
        let mut c = RunConfig::default();
        c.krizhevsky_lr_scaling = true;
        c.ranks = 64;
        c.algo = Algo::Gossip;
        assert_eq!(c.effective_lr(), c.lr);
    }

    #[test]
    fn lr_step_schedule() {
        let s = LrSchedule::Step {
            every: 30,
            gamma: 0.1,
        };
        assert!((s.lr_at(0.1, 0) - 0.1).abs() < 1e-12);
        assert!((s.lr_at(0.1, 29) - 0.1).abs() < 1e-12);
        assert!((s.lr_at(0.1, 30) - 0.01).abs() < 1e-12);
        assert!((s.lr_at(0.1, 65) - 0.001).abs() < 1e-12);
    }

    #[test]
    fn virtualize_pulls_workload_compute_cost() {
        let mut c = RunConfig::default();
        c.net_noise = 0.3;
        let w = crate::sim::Workload::resnet50_p100();
        c.virtualize(&w, 1e-6, 1e-10);
        assert!(c.virtual_clock);
        assert!((c.virt_compute_secs - 0.096).abs() < 1e-9);
        assert!((c.virt_fwd_secs - w.t_fwd).abs() < 1e-12);
        assert!(c.virt_ps_agg_secs > 0.0, "PS aggregation cost modeled");
        assert_eq!(c.net_noise, 0.0);
        let j = Json::parse(r#"{"virtual_clock": true, "virt_compute_secs": 0.004}"#)
            .unwrap();
        let c2 = RunConfig::from_json(&j).unwrap();
        assert!(c2.virtual_clock);
        assert!((c2.virt_compute_secs - 0.004).abs() < 1e-12);
    }

    #[test]
    fn layerwise_and_jitter_fields_parse() {
        let j = Json::parse(
            r#"{"layerwise": true, "virt_fwd_secs": 0.002,
                "straggler_jitter": 0.15, "virt_ps_agg_secs": 0.001,
                "comm_thread": true, "sync_mix": true}"#,
        )
        .unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert!(c.layerwise);
        assert!((c.virt_fwd_secs - 0.002).abs() < 1e-12);
        assert!((c.straggler_jitter - 0.15).abs() < 1e-12);
        assert!((c.virt_ps_agg_secs - 0.001).abs() < 1e-12);
        assert!(c.comm_thread);
        assert!(c.sync_mix);
        // defaults keep the monolithic, dependency-chained schedule
        assert!(!RunConfig::default().layerwise);
        assert!(!RunConfig::default().comm_thread);
        assert!(!RunConfig::default().sync_mix);
        assert_eq!(RunConfig::default().straggler_jitter, 0.0);
    }

    #[test]
    fn config_json_roundtrip_every_field() {
        let mut c = RunConfig::default();
        c.algo = Algo::PeriodicAgd;
        c.model = "mlp-small".into();
        c.ranks = 37;
        c.steps = 11;
        c.lr = 0.125;
        c.lr_schedule = LrSchedule::Step { every: 30, gamma: 0.1 };
        c.krizhevsky_lr_scaling = true;
        c.allreduce = Algorithm::Ring;
        c.rotation = false;
        c.sample_shuffle = false;
        c.gossip_period = 4;
        c.seed = 1234567;
        c.rows_per_rank = 48;
        c.eval_every = 5;
        c.val_rows = 96;
        c.net_alpha = 2e-4;
        c.net_beta = 1.0 / 0.5e9;
        c.net_noise = 0.0;
        c.use_artifacts = false;
        c.artifacts_dir = "elsewhere".into();
        c.ps_servers = 2;
        c.resume_from = Some("ckpt".into());
        c.virtual_clock = true;
        c.virt_compute_secs = 6.25e-3;
        c.layerwise = true;
        c.virt_fwd_secs = 2.08e-3;
        c.straggler_jitter = 0.3;
        c.virt_ps_agg_secs = 1e-3;
        c.comm_thread = true;
        c.sync_mix = true;
        c.transport = Transport::Tcp;
        c.codec = Codec::TopK;
        c.pool = false;
        c.group_size = 4;
        c.inter_period = 3;
        c.cost_model = CostModelKind::Hier;
        c.fault_plan = FaultPlan {
            kills: vec![(3, 10)],
            joins: vec![(5, 7)],
            slows: vec![(1, 2, 4.0)],
            drop_frac: 0.05,
            dup_frac: 0.02,
            seed: (1u64 << 53) + 9,
        };
        let j = c.to_json();
        let back = RunConfig::from_json(&j).unwrap();
        assert_eq!(back, c, "to_json/from_json must round-trip losslessly");
        // canonical: serializing the round-tripped config is byte-equal
        assert_eq!(back.to_json().to_string(), j.to_string());
        // and survives a parse through text
        let reparsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(RunConfig::from_json(&reparsed).unwrap(), c);
    }

    #[test]
    fn content_hash_stable_and_field_sensitive() {
        let a = RunConfig::default();
        let b = RunConfig::default();
        assert_eq!(a.content_hash(), b.content_hash());
        assert_eq!(a.content_hash().len(), 16);
        let mut c = RunConfig::default();
        c.gossip_period = 2;
        assert_ne!(a.content_hash(), c.content_hash());
        let mut d = RunConfig::default();
        d.straggler_jitter = 0.1;
        assert_ne!(a.content_hash(), d.content_hash());
        assert_ne!(c.content_hash(), d.content_hash());
        // seeds above 2^53 must not collide (lossless string encoding)
        let mut s1 = RunConfig::default();
        s1.seed = (1u64 << 53) + 1;
        let mut s2 = RunConfig::default();
        s2.seed = (1u64 << 53) + 3;
        assert_ne!(s1.content_hash(), s2.content_hash());
        assert_eq!(RunConfig::from_json(&s1.to_json()).unwrap().seed, s1.seed);
        // numeric seeds in hand-written presets still parse
        let j = Json::parse(r#"{"seed": 77}"#).unwrap();
        assert_eq!(RunConfig::from_json(&j).unwrap().seed, 77);
        // the default (empty) fault plan is omitted entirely, so every
        // pre-existing content hash is unchanged…
        assert!(RunConfig::default().to_json().get("fault_plan").is_none());
        // …and a non-default plan reshapes the scenario identity
        let mut f = RunConfig::default();
        f.fault_plan.drop_frac = 0.1;
        assert_ne!(f.content_hash(), RunConfig::default().content_hash());
    }

    #[test]
    fn execution_knobs_do_not_reshape_scenario_identity() {
        // sim_threads / legacy_ranks pick HOW ranks execute, never what
        // they compute: results are bit-identical at any setting, so
        // the knobs stay out of the canonical JSON and the content hash
        // (sweep caches and artifacts are shared across them)
        let base = RunConfig::default();
        let mut c = RunConfig::default();
        c.sim_threads = 1;
        c.legacy_ranks = true;
        assert_eq!(c.to_json().to_string(), base.to_json().to_string());
        assert_eq!(c.content_hash(), base.content_hash());
        assert!(c.to_json().get("sim_threads").is_none());
        assert!(c.to_json().get("legacy_ranks").is_none());
    }

    #[test]
    fn transport_axis_parses_and_reshapes_hash() {
        assert_eq!(RunConfig::default().transport, Transport::Inproc);
        for t in [Transport::Inproc, Transport::Tcp] {
            assert_eq!(Transport::parse(t.name()).unwrap(), t);
        }
        assert!(Transport::parse("udp").is_err());
        let mut c = RunConfig::default();
        c.transport = Transport::Tcp;
        // the transport is part of the scenario identity: a TCP run must
        // not collide with the equivalent in-proc run in a sweep cache
        assert_ne!(c.content_hash(), RunConfig::default().content_hash());
        let j = Json::parse(r#"{"transport": "tcp"}"#).unwrap();
        assert_eq!(RunConfig::from_json(&j).unwrap().transport, Transport::Tcp);
    }

    #[test]
    fn codec_axis_parses_and_reshapes_hash() {
        assert_eq!(RunConfig::default().codec, Codec::F32);
        for codec in [Codec::F32, Codec::Bf16, Codec::Int8, Codec::TopK] {
            let j = Json::parse(&format!(r#"{{"codec": "{}"}}"#, codec.name()))
                .unwrap();
            assert_eq!(RunConfig::from_json(&j).unwrap().codec, codec);
        }
        assert!(RunConfig::from_json(
            &Json::parse(r#"{"codec": "fp8"}"#).unwrap()
        )
        .is_err());
        // a compressed run must never share a cache entry with the
        // bit-parity f32 run of the same scenario
        let mut c = RunConfig::default();
        c.codec = Codec::Bf16;
        assert_ne!(c.content_hash(), RunConfig::default().content_hash());
    }

    #[test]
    fn hier_fields_default_flat_and_reshape_hash() {
        let d = RunConfig::default();
        assert_eq!(d.group_size, 1);
        assert_eq!(d.inter_period, 1);
        assert_eq!(d.cost_model, CostModelKind::Flat);
        // flat defaults are omitted: historical content hashes unchanged
        assert!(d.to_json().get("group_size").is_none());
        assert!(d.to_json().get("inter_period").is_none());
        assert!(d.to_json().get("cost_model").is_none());
        assert!(d.hier_cost_model().is_none());
        for (f, want) in [("flat", CostModelKind::Flat), ("hier", CostModelKind::Hier)] {
            assert_eq!(CostModelKind::parse(f).unwrap(), want);
        }
        assert!(CostModelKind::parse("torus").is_err());
        let mut c = RunConfig::default();
        c.ranks = 8;
        c.group_size = 4;
        c.inter_period = 2;
        c.cost_model = CostModelKind::Hier;
        assert_ne!(c.content_hash(), d.content_hash());
        let back = RunConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back, c);
        // the configured α–β becomes the inter tier; intra is NVLink
        let mut h = RunConfig::default();
        h.ranks = 8;
        h.group_size = 4;
        h.cost_model = CostModelKind::Hier;
        h.net_alpha = 1e-3;
        let hier = h.hier_cost_model().unwrap();
        assert!(hier.message_time(0, 4, 0) >= 1e-3, "cross-group pays α");
        assert!(hier.message_time(0, 1, 0) < 1e-4, "in-group is NVLink-class");
    }

    #[test]
    fn algo_names_roundtrip() {
        for a in [
            Algo::Gossip,
            Algo::GossipHypercube,
            Algo::GossipRandom,
            Algo::SgdSync,
            Algo::Agd,
            Algo::PeriodicAgd,
            Algo::ParamServer,
        ] {
            assert_eq!(Algo::parse(a.name()).unwrap(), a);
        }
        assert!(Algo::parse("nope").is_err());
    }
}
