//! Minimal JSON codec (parse + emit) — stands in for serde_json in this
//! offline environment.  Supports the full JSON grammar minus exotic
//! number forms; used for `artifacts/*.meta.json`, config presets and
//! metrics emission.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let b = s.as_bytes();
        let mut i = 0;
        let v = parse_value(b, &mut i)?;
        skip_ws(b, &mut i);
        if i != b.len() {
            return Err(format!("trailing garbage at byte {i}"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Builder helpers for emitting metrics/config objects.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn num(n: f64) -> Json {
    Json::Num(n)
}
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}
pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], i: &mut usize) {
    while *i < b.len() && matches!(b[*i], b' ' | b'\t' | b'\n' | b'\r') {
        *i += 1;
    }
}

fn parse_value(b: &[u8], i: &mut usize) -> Result<Json, String> {
    skip_ws(b, i);
    if *i >= b.len() {
        return Err("unexpected end".into());
    }
    match b[*i] {
        b'{' => parse_obj(b, i),
        b'[' => parse_arr(b, i),
        b'"' => Ok(Json::Str(parse_str(b, i)?)),
        b't' => lit(b, i, "true", Json::Bool(true)),
        b'f' => lit(b, i, "false", Json::Bool(false)),
        b'n' => lit(b, i, "null", Json::Null),
        _ => parse_num(b, i),
    }
}

fn lit(b: &[u8], i: &mut usize, word: &str, v: Json) -> Result<Json, String> {
    if b[*i..].starts_with(word.as_bytes()) {
        *i += word.len();
        Ok(v)
    } else {
        Err(format!("bad literal at {i}", i = *i))
    }
}

fn parse_num(b: &[u8], i: &mut usize) -> Result<Json, String> {
    let start = *i;
    while *i < b.len()
        && matches!(b[*i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
    {
        *i += 1;
    }
    std::str::from_utf8(&b[start..*i])
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(Json::Num)
        .ok_or_else(|| format!("bad number at {start}"))
}

fn parse_str(b: &[u8], i: &mut usize) -> Result<String, String> {
    debug_assert_eq!(b[*i], b'"');
    *i += 1;
    let mut out = String::new();
    while *i < b.len() {
        match b[*i] {
            b'"' => {
                *i += 1;
                return Ok(out);
            }
            b'\\' => {
                *i += 1;
                if *i >= b.len() {
                    break;
                }
                match b[*i] {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        if *i + 4 >= b.len() {
                            return Err("bad \\u".into());
                        }
                        let hex =
                            std::str::from_utf8(&b[*i + 1..*i + 5]).unwrap();
                        let cp = u32::from_str_radix(hex, 16)
                            .map_err(|e| e.to_string())?;
                        out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        *i += 4;
                    }
                    c => return Err(format!("bad escape {c}")),
                }
                *i += 1;
            }
            _ => {
                // copy one UTF-8 scalar
                let s = std::str::from_utf8(&b[*i..])
                    .map_err(|e| e.to_string())?;
                let c = s.chars().next().unwrap();
                out.push(c);
                *i += c.len_utf8();
            }
        }
    }
    Err("unterminated string".into())
}

fn parse_arr(b: &[u8], i: &mut usize) -> Result<Json, String> {
    *i += 1; // [
    let mut v = Vec::new();
    skip_ws(b, i);
    if *i < b.len() && b[*i] == b']' {
        *i += 1;
        return Ok(Json::Arr(v));
    }
    loop {
        v.push(parse_value(b, i)?);
        skip_ws(b, i);
        match b.get(*i) {
            Some(b',') => *i += 1,
            Some(b']') => {
                *i += 1;
                return Ok(Json::Arr(v));
            }
            _ => return Err(format!("expected , or ] at {i}", i = *i)),
        }
    }
}

fn parse_obj(b: &[u8], i: &mut usize) -> Result<Json, String> {
    *i += 1; // {
    let mut m = BTreeMap::new();
    skip_ws(b, i);
    if *i < b.len() && b[*i] == b'}' {
        *i += 1;
        return Ok(Json::Obj(m));
    }
    loop {
        skip_ws(b, i);
        if *i >= b.len() || b[*i] != b'"' {
            return Err(format!("expected key at {i}", i = *i));
        }
        let k = parse_str(b, i)?;
        skip_ws(b, i);
        if b.get(*i) != Some(&b':') {
            return Err(format!("expected : at {i}", i = *i));
        }
        *i += 1;
        m.insert(k, parse_value(b, i)?);
        skip_ws(b, i);
        match b.get(*i) {
            Some(b',') => *i += 1,
            Some(b'}') => {
                *i += 1;
                return Ok(Json::Obj(m));
            }
            _ => return Err(format!("expected , or }} at {i}", i = *i)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_meta_like() {
        let src = r#"{"model":"mlp","param_count":535818,
            "layers":[{"name":"fc0","offset":0,"len":401920}],
            "x_shape":[64,784],"ok":true,"none":null,"lr":0.05}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("model").unwrap().as_str().unwrap(), "mlp");
        assert_eq!(j.get("param_count").unwrap().as_usize().unwrap(), 535818);
        let l0 = j.get("layers").unwrap().idx(0).unwrap();
        assert_eq!(l0.get("len").unwrap().as_usize().unwrap(), 401920);
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("none"), Some(&Json::Null));
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn string_escapes() {
        let j = Json::parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\"b\\c\ndA");
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(Json::parse("0").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }
}
