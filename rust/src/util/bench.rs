//! Micro-benchmark harness (criterion stand-in for the offline env).
//!
//! `cargo bench` targets use `harness = false` and drive this directly:
//! warmup, N timed samples, median/mean/p10/p90, throughput helpers,
//! paper-style table printing, and machine-readable `--json` emission
//! ([`BenchReport`]) for the CI regression gate (`tools/bench_diff.py`,
//! docs/perf.md).

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

pub struct Sample {
    pub name: String,
    pub secs: Vec<f64>,
}

impl Sample {
    pub fn median(&self) -> f64 {
        let mut v = self.secs.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v[v.len() / 2]
    }
    pub fn mean(&self) -> f64 {
        crate::util::mean(&self.secs)
    }
    pub fn stddev(&self) -> f64 {
        crate::util::stddev(&self.secs)
    }
    pub fn pct(&self, q: f64) -> f64 {
        let mut v = self.secs.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((v.len() - 1) as f64 * q).round() as usize;
        v[idx]
    }
}

/// Time `f` — `warmup` untimed runs then `iters` timed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Sample {
    for _ in 0..warmup {
        f();
    }
    let mut secs = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        secs.push(t0.elapsed().as_secs_f64());
    }
    let s = Sample {
        name: name.to_string(),
        secs,
    };
    println!(
        "{:<44} median {:>10}  mean {:>10} ± {:>8}",
        s.name,
        fmt_dur(s.median()),
        fmt_dur(s.mean()),
        fmt_dur(s.stddev()),
    );
    s
}

/// Run `f` until `budget` elapses (at least once); report iterations/sec.
pub fn bench_throughput<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> f64 {
    f(); // warmup
    let t0 = Instant::now();
    let mut n = 0u64;
    while t0.elapsed() < budget {
        f();
        n += 1;
    }
    let per_sec = n as f64 / t0.elapsed().as_secs_f64();
    println!("{name:<44} {per_sec:>12.1} iters/s");
    per_sec
}

pub fn fmt_dur(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Paper-style table printer: header row + aligned numeric rows.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells.to_vec());
    }
    pub fn print(&self, title: &str) {
        println!("\n== {title} ==");
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:>w$}  ", c, w = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.header);
        for r in &self.rows {
            line(r);
        }
    }
}

/// Machine-readable bench output for the CI regression gate.
///
/// Entries are named metric sets; `tools/bench_diff.py` hard-gates the
/// `allocs` (lower is better) and `gbs` (higher is better) keys against
/// the committed `BENCH_*.json` baseline and treats timing keys
/// (`median_secs`, …) as advisory — wall timings on shared runners are
/// too noisy to gate.
pub struct BenchReport {
    bench: String,
    entries: Vec<(String, Vec<(String, f64)>)>,
}

impl BenchReport {
    pub fn new(bench: &str) -> BenchReport {
        BenchReport {
            bench: bench.to_string(),
            entries: Vec::new(),
        }
    }

    /// Record one entry's metrics (`[("gbs", 12.3), ("allocs", 0.0)]`).
    pub fn entry(&mut self, name: &str, metrics: &[(&str, f64)]) {
        self.entries.push((
            name.to_string(),
            metrics.iter().map(|&(k, v)| (k.to_string(), v)).collect(),
        ));
    }

    pub fn to_json(&self) -> Json {
        let mut entries = BTreeMap::new();
        for (name, metrics) in &self.entries {
            let m: BTreeMap<String, Json> = metrics
                .iter()
                .map(|(k, v)| (k.clone(), Json::Num(*v)))
                .collect();
            entries.insert(name.clone(), Json::Obj(m));
        }
        let mut top = BTreeMap::new();
        top.insert("bench".to_string(), Json::Str(self.bench.clone()));
        top.insert("entries".to_string(), Json::Obj(entries));
        Json::Obj(top)
    }

    pub fn write(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string() + "\n")?;
        println!("wrote {path}");
        Ok(())
    }
}

/// Parse `--json [PATH]` from the bench binary's argv.  Returns the
/// output path (the `default` when `--json` has no following path
/// operand); `None` when `--json` was not passed.  Tolerates the flags
/// cargo itself forwards to `harness = false` bench binaries
/// (`--bench`, filter strings, …).
pub fn json_out_path(default: &str) -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.iter().position(|a| a == "--json").map(|i| {
        args.get(i + 1)
            .filter(|s| !s.starts_with('-'))
            .cloned()
            .unwrap_or_else(|| default.to_string())
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_all_samples() {
        let s = bench("noop", 1, 5, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(s.secs.len(), 5);
        assert!(s.median() >= 0.0);
        assert!(s.pct(0.9) >= s.pct(0.1));
    }

    #[test]
    fn fmt_dur_ranges() {
        assert!(fmt_dur(2.0).ends_with(" s"));
        assert!(fmt_dur(2e-3).ends_with("ms"));
        assert!(fmt_dur(2e-6).ends_with("µs"));
        assert!(fmt_dur(2e-9).ends_with("ns"));
    }

    #[test]
    fn table_shapes() {
        let mut t = Table::new(&["p", "eff"]);
        t.row(&["4".into(), "100.0".into()]);
        t.print("test");
    }

    #[test]
    fn bench_report_emits_sorted_entries() {
        let mut r = BenchReport::new("hotpath");
        r.entry("zeta", &[("gbs", 10.0)]);
        r.entry("alpha", &[("allocs", 0.0), ("median_secs", 0.5)]);
        let j = r.to_json();
        assert_eq!(j.get("bench").and_then(Json::as_str), Some("hotpath"));
        let e = j.get("entries").unwrap();
        assert_eq!(
            e.get("alpha").unwrap().get("allocs").and_then(Json::as_f64),
            Some(0.0)
        );
        assert_eq!(
            e.get("zeta").unwrap().get("gbs").and_then(Json::as_f64),
            Some(10.0)
        );
        // round-trips through the in-tree JSON codec
        let text = j.to_string();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }
}
