//! Tiny argv parser (clap stand-in): `--key value`, `--flag`, positionals.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse argv-style tokens.  `flag_names` lists boolean flags that
    /// take no value; every other `--key` consumes the next token.
    pub fn parse<I: IntoIterator<Item = String>>(
        tokens: I,
        flag_names: &[&str],
    ) -> Result<Args, String> {
        let mut a = Args::default();
        let mut it = tokens.into_iter();
        while let Some(t) = it.next() {
            if let Some(key) = t.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    a.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&key) {
                    a.flags.push(key.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| format!("--{key} needs a value"))?;
                    a.options.insert(key.to_string(), v);
                }
            } else {
                a.positional.push(t);
            }
        }
        Ok(a)
    }

    pub fn from_env(flag_names: &[&str]) -> Result<Args, String> {
        Args::parse(std::env::args().skip(1), flag_names)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse(
            toks("train --model mlp --ranks 8 --verbose --lr=0.05 out.csv"),
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.positional, vec!["train", "out.csv"]);
        assert_eq!(a.get("model"), Some("mlp"));
        assert_eq!(a.usize_or("ranks", 1), 8);
        assert_eq!(a.f64_or("lr", 0.1), 0.05);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(toks("--model"), &[]).is_err());
    }

    #[test]
    fn defaults() {
        let a = Args::parse(toks(""), &[]).unwrap();
        assert_eq!(a.usize_or("ranks", 4), 4);
        assert_eq!(a.get_or("model", "mlp"), "mlp");
    }
}
