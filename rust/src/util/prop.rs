//! Tiny property-testing harness (proptest stand-in for the offline env).
//!
//! `forall(cases, gen, check)` runs `check` on `cases` generated inputs;
//! on failure it reports the case index and the seed so the exact input
//! can be replayed (`GG_PROP_SEED=<seed> cargo test ...`).  No shrinking —
//! generators are asked to keep inputs small instead.

use crate::util::Rng;

pub const DEFAULT_CASES: usize = 64;

fn base_seed() -> u64 {
    std::env::var("GG_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

/// Run `check(gen(rng))` for `cases` different rng streams.
/// Panics with case index + seed on the first failure.
pub fn forall<T, G, C>(cases: usize, mut gen: G, mut check: C)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    C: FnMut(&T) -> Result<(), String>,
{
    let seed = base_seed();
    for case in 0..cases {
        let mut rng = Rng::new(seed ^ (case as u64).wrapping_mul(0x9E3779B9));
        let input = gen(&mut rng);
        if let Err(msg) = check(&input) {
            panic!(
                "property failed at case {case} (GG_PROP_SEED={seed}):\n  \
                 input: {input:?}\n  {msg}"
            );
        }
    }
}

/// Generator helpers.
pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    lo + rng.below(hi - lo + 1)
}

pub fn f32_vec(rng: &mut Rng, len: usize, scale: f32) -> Vec<f32> {
    (0..len).map(|_| rng.normal_f32() * scale).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        forall(
            32,
            |r| usize_in(r, 1, 100),
            |&n| {
                if n >= 1 && n <= 100 {
                    Ok(())
                } else {
                    Err(format!("out of range: {n}"))
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failure() {
        forall(32, |r| usize_in(r, 0, 10), |&n| {
            if n < 5 {
                Ok(())
            } else {
                Err("too big".into())
            }
        });
    }

    #[test]
    fn f32_vec_len_and_scale() {
        let mut r = Rng::new(3);
        let v = f32_vec(&mut r, 1000, 2.0);
        assert_eq!(v.len(), 1000);
        let m: f64 = v.iter().map(|&x| x as f64).sum::<f64>() / 1000.0;
        assert!(m.abs() < 0.5);
    }
}
